"""Canonical traffic patterns (Fig. 7 and friends).

Each builder returns (QueueGraph, Workload, dict of expectations). The
expectations encode the paper's quantitative claims so benchmarks/tests can
assert against them:

* incast (Fig. 7, group 4): j,k,l,m -> i. RCCC assigns 25% each — optimal.
* outcast (Fig. 7, group 1): o -> p,q,r,v plus w -> v. The sender o can
  only source 25% per flow; RCCC at v blindly grants 50/50, wasting 25% of
  v's ingress — w *could* get 75%. NSCC converges to ~75%.
* in-network (Fig. 7, groups 2/3): 12 pairs across a 3:1-oversubscribed
  uplink set deliver 33% each; a same-leaf flow into one of the receivers
  could take 67% but RCCC grants it only 50%.
* permutation: all-to-all-shifted full-rate traffic — the spraying /
  polarization benchmark (Sec. 2.1).
"""
from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.network.fabric import Workload
from repro.network.profile import (CCAlgo, TransportProfile, cc_ablation)
from repro.network.topology import QueueGraph, fat_tree3, leaf_spine


# ------------------------------------------------------------------------
# scenario-axis padding (device sharding wants B % devices == 0)
# ------------------------------------------------------------------------

def noop_scenarios(f: int, b: int) -> Workload:
    """[b, f] inert scenario lanes: zero-size flows (src == dst == host
    0, no deps, no reduction groups). A zero-size flow is source- and
    receiver-complete from tick 0, never becomes eligible to inject, and
    leaves queues and the control ring untouched — the lane is quiescent
    at the first chunk boundary and freezes there."""
    z = jnp.zeros((b, f), jnp.int32)
    neg1 = jnp.full((b, f), -1, jnp.int32)
    return Workload(src=z, dst=z, size=z, start=z, dep=neg1, red=neg1)


def pad_scenarios(wls: Workload, multiple: int) -> "tuple[Workload, int]":
    """Pad a stacked [B, F] workload along the scenario axis up to a
    multiple of ``multiple`` with :func:`noop_scenarios` lanes, so the
    axis shards evenly across devices. Lanes are independent, so padding
    never changes a real lane's bits. Returns (padded, pad_count)."""
    if multiple < 1:
        raise ValueError(f"multiple must be >= 1, got {multiple}")
    b, f = wls.src.shape
    pad = (-b) % multiple
    if pad == 0:
        return wls, 0
    extra = noop_scenarios(f, pad)
    return jax.tree_util.tree_map(
        lambda a, e: jnp.concatenate([jnp.asarray(a), e], axis=0),
        wls, extra), pad


# ------------------------------------------------------------------------
# scenario sweeps (batched: feed to fabric.simulate_batch)
# ------------------------------------------------------------------------

def victim_sweep(pairs: int = 12, uplinks: int = 4, size: int = 100000):
    """The canonical victim-share scenario: the Fig. 7 in-network
    oversubscription pattern (:func:`in_network`) at bench scale —
    `pairs` cross-leaf flows squeezed through `uplinks` spine links
    while one same-leaf "victim" flow shares one of the receivers.

    ONE definition shared by the profile-ablation bench, the
    ``fabric_health`` telemetry bench, the telemetry canary
    (``python -m repro.network.telemetry``) and the tests, so they all
    observe the same fabric. Returns ``(g, wl, exp)`` with
    ``exp["victim_flow"]`` the index of the discriminating same-leaf
    flow and ``exp["uplinks"]`` the leaf-0 uplink queue ids (the
    contended links — the natural fault-injection targets).
    """
    g, wl, exp = in_network(pairs, uplinks, size=size)
    return g, wl, dict(
        exp, victim_flow=pairs,
        uplinks=tuple(int(g.up1_table[0, i]) for i in range(uplinks)))


def profile_ablation_sweep(pairs: int = 12, uplinks: int = 4,
                           size: int = 100000):
    """The paper's operating-point grid as ONE ``simulate_batch`` call:
    the three named profiles (ai_base / ai_full / hpc) plus the CC
    ablation over the ai_full composition (NSCC-only vs RCCC-only vs
    hybrid vs open-loop), all on the Fig. 7 in-network oversubscription
    pattern (:func:`in_network`): `pairs` cross-leaf flows squeeze
    through `uplinks` spine links while one same-leaf "victim" flow
    shares one of the receivers.

    This scenario actually DIFFERENTIATES congestion control — a plain
    incast does not: every functioning policy converges onto the
    receiver fair share, which is why the pre-PR-4 bench reported six
    identical goodput numbers. Here the victim flow's share is the
    discriminator: ~0.5 under blind receiver credits (RCCC grants its
    ingress 50/50 regardless of what the cross traffic can use), rising
    toward the ``1 - uplinks/pairs`` optimum under NSCC's network
    signals, with open loop floating in between.

    Returns (g, wls [P, F], profiles [P], names [P], expectations);
    ``expectations["victim_flow"]`` indexes the discriminating flow.
    Pass the profiles list straight to ``simulate_batch(g, wls,
    profiles, p)``; the engine groups scenarios by profile (one
    executable each, run concurrently).
    """
    g, wl, exp = victim_sweep(pairs, uplinks, size=size)
    profiles = [TransportProfile.ai_base(), TransportProfile.ai_full(),
                TransportProfile.hpc(), *cc_ablation(),
                replace(TransportProfile.ai_full(), cc=CCAlgo.NONE,
                        name="open_loop")]
    wls = Workload.stack([wl] * len(profiles))
    return g, wls, profiles, [p.name for p in profiles], exp

def collective_sweep(n: int = 8, size: int = 40, hosts_per_leaf: int = 2):
    """The collective ablation grid — kind x algorithm x INC on/off x
    transport profile — as ONE ``simulate_batch`` call.

    Scenarios (15 with the defaults):

    * all-reduce x {ring, recursive_doubling, tree} x {INC off, on}
      under both ai_full (NSCC) and ai_base (RCCC)  -> 12
    * reduce-scatter / all-gather / all-to-all (ring schedules, ai_full,
      INC off) -> 3 more kinds for the kind axis.

    Workloads have heterogeneous flow counts (a ring all-reduce is
    2(n-1)*n flows, a tree 2(n-1)), so they are padded with inert size-0
    flows (`collectives.stack_padded`) into one [B, Fmax] batch. INC
    on/off is a TRACED axis: every scenario runs under an ``inc=True``
    profile and the off lanes simply carry ``red=-1`` workloads
    (`build_workload(..., inc_groups=False)` — bitwise identical to an
    inc=False executable), so the whole grid compiles to ONE executable
    per transport profile (two here) instead of four.

    `size` must stay <= SimParams.max_cwnd for the ai_base x INC lanes:
    RCCC's receiver only grants credits to flows it has *seen*, and a
    fully-absorbed INC member never surfaces at the receiver — it rides
    its optimistic initial BDP credit (see DESIGN.md).

    Returns (g, wls [B, Fmax], profiles [B], names [B]).
    """
    from dataclasses import replace

    from repro.network import collectives as coll

    leaves = max(2, -(-n // hosts_per_leaf))
    g = leaf_spine(leaves=leaves, spines=4, hosts_per_leaf=hosts_per_leaf)
    hosts = tuple(range(n))
    grid = []
    for prof in (TransportProfile.ai_full(), TransportProfile.ai_base()):
        for kind, algo in (("all_reduce", "ring"),
                           ("all_reduce", "recursive_doubling"),
                           ("all_reduce", "tree")):
            for inc in (False, True):
                grid.append((prof, kind, algo, inc))
    for kind in ("reduce_scatter", "all_gather", "all_to_all"):
        grid.append((TransportProfile.ai_full(), kind, "ring", False))

    wls, profiles, names = [], [], []
    for prof, kind, algo, inc in grid:
        spec = coll.CollectiveSpec(kind, hosts, size)
        wls.append(coll.build_workload(spec, algo, inc_groups=inc))
        profiles.append(replace(prof, inc=True, name=prof.name + "+inc"))
        names.append(f"{prof.name}/{kind}/{algo}{'/inc' if inc else ''}")
    return g, coll.stack_padded(wls), profiles, names


def failure_sweep(spines: int = 4, hosts_per_leaf: int = 8,
                  size: int = 100000):
    """One scenario per failed leaf-0 uplink, plus a no-failure baseline.

    The REPS failure-mitigation experiment (Sec. 3.2.4 configuration
    drops) as a batch: scenario 0 is healthy; scenario 1+i kills uplink i.
    Returns (g, wls [S+1, F], masks [S+1, Q], expectations).
    """
    g = leaf_spine(leaves=2, spines=spines, hosts_per_leaf=hosts_per_leaf)
    f = hosts_per_leaf
    wl = Workload.of(list(range(f)), [f + i for i in range(f)], size)
    b = spines + 1
    masks = np.zeros((b, g.num_queues), bool)
    for i in range(spines):
        masks[1 + i, int(g.up1_table[0, i])] = True
    wls = Workload.stack([wl] * b)
    live = (spines - 1) / spines
    return g, wls, masks, {
        "healthy_share": min(1.0, spines / f),
        "degraded_share": live * spines / f,  # (S-1) live uplinks over F flows
    }


def fault_sweep(spines: int = 4, hosts_per_leaf: int = 8, size: int = 600,
                flap_at: int = 150, heal_at: int = 1200, gray_p: float = 0.05):
    """The dynamic-fault grid as one batch: per-scenario FaultSchedules
    riding the scenario axis (flaps + gray links, repro.network.faults).

    Six scenarios over cross-leaf pairs sharing leaf-0's uplinks:

    0. healthy baseline
    1. one uplink flaps over [flap_at, heal_at)
    2. two uplinks flap, staggered (second offset by half the window)
    3. one gray uplink losing ``gray_p`` of packets for the whole run
    4. one gray uplink losing ``4 * gray_p`` (a badly corrupting link)
    5. one uplink dies at ``flap_at`` and never heals

    Every scenario keeps >= 1 healthy uplink at all times, so with a
    sane transport ALL flows must complete (the liveness invariant
    tests/check.sh lock). Returns (g, wls [6, F], faults [6, Q],
    expectations).
    """
    from repro.network.faults import FaultSchedule

    g = leaf_spine(leaves=2, spines=spines, hosts_per_leaf=hosts_per_leaf)
    f = hosts_per_leaf
    wl = Workload.of(list(range(f)), [f + i for i in range(f)], size)
    ups = [int(g.up1_table[0, i]) for i in range(spines)]
    mid = flap_at + (heal_at - flap_at) // 2
    healthy = FaultSchedule.healthy(g.num_queues)
    scheds = [
        healthy,
        healthy.flap(ups[0], flap_at, heal_at),
        healthy.flap(ups[0], flap_at, heal_at).flap(ups[1], mid,
                                                    mid + (heal_at - flap_at)),
        healthy.lossy(ups[0], gray_p),
        healthy.lossy(ups[0], min(1.0, 4 * gray_p)),
        healthy.flap(ups[0], flap_at),
    ]
    names = ["healthy", "flap_1", "flap_2_staggered", f"gray_{gray_p:g}",
             f"gray_{min(1.0, 4 * gray_p):g}", "dead_mid"]
    wls = Workload.stack([wl] * len(scheds))
    return g, wls, FaultSchedule.stack(scheds), {
        "names": names,
        "surviving_uplinks_min": spines - 2,  # scenario 2's worst moment
    }


def host_fault_sweep(spines: int = 4, hosts_per_leaf: int = 4,
                     size: int = 600, fail_at: int = 100,
                     stall_heal: int = 800, budget: int = 6000):
    """The endpoint-failure grid as one batch: per-scenario host/NIC
    outage lanes riding the scenario axis (repro.network.faults), under
    the ``resilient`` profile's PDC liveness teardown. ONE definition
    shared by the resilience bench, the endpoint canary
    (``python -m repro.network.faults --endpoint``) and the tests.

    Four scenarios over cross-leaf pairs (flow i: leaf-0 host i ->
    leaf-1 host i):

    0. ``host_dead`` — flow 1's SOURCE host and flow 0's DESTINATION
       host die at ``fail_at`` and never heal (both teardown directions:
       a dead source stops ACK-processing and injecting; a dead
       destination silently eats traffic until the PDC strikes out).
       Must quiesce EARLY with exactly those flows abandoned.
    1. ``host_dead_pdc_off`` — the same schedule under a
       ``pdc_dead_after=0`` twin profile: no teardown, the run burns the
       whole tick budget (the liveness hazard the quarantine fixes).
    2. ``nic_stall`` — flow 0's source NIC freezes over
       [fail_at, stall_heal) but stays ACK-live: no teardown, every
       flow completes after the heal.
    3. ``healthy`` — no faults (the bitwise-inertness anchor).

    Returns (g, wls [4, F], faults [4, Q]/[4, H], expectations) with
    ``expectations["profile"]`` the per-scenario profile LIST (feed it
    straight to ``simulate_batch``), ``["dead_flows"]`` the flow ids
    scenario 0 must abandon, and ``["budget"]`` the tick budget the
    early-quiescence assertions are made against.
    """
    from repro.network.faults import FaultSchedule

    g = leaf_spine(leaves=2, spines=spines, hosts_per_leaf=hosts_per_leaf)
    f = hosts_per_leaf
    wl = Workload.of(list(range(f)), [f + i for i in range(f)], size)
    prof = TransportProfile.resilient()
    prof_off = replace(prof, pdc_dead_after=0, name="resilient-pdc_off")
    healthy = FaultSchedule.healthy(g.num_queues, num_hosts=g.num_hosts)
    dead = healthy.host_fail([1, f], fail_at)   # flow 1 src, flow 0 dst
    stall = healthy.nic_stall(0, fail_at, stall_heal)
    scheds = [dead, dead, stall, healthy]
    names = ["host_dead", "host_dead_pdc_off", "nic_stall", "healthy"]
    wls = Workload.stack([wl] * len(scheds))
    return g, wls, FaultSchedule.stack(scheds), {
        "names": names,
        "profile": [prof, prof_off, prof, prof],
        "dead_flows": (0, 1),
        "budget": budget,
    }


def corruption_sweep(bers=(0.0, 0.01, 0.03, 0.08), pairs: int = 4,
                     uplinks: int = 2, size: int = 400, budget: int = 6000):
    """The link-corruption grid as one batch: the victim-share pattern
    (:func:`victim_sweep`) with a per-scenario bit-error rate on leaf-0's
    uplinks — the BER axis of the BER x LLR-on/off grid. ONE definition
    shared by the ``corruption_sweep`` bench block, the link canary
    (``python -m repro.core.link``) and the tests.

    The LLR-on/off axis is a COMPILE-TIME static (``link=`` selects the
    executable like a ``TelemetrySpec``), so it cannot ride the scenario
    axis: callers run the SAME returned batch twice, once with
    ``link=exp["link"]`` (LLR armed) and once with ``link=None``
    (corruption leaks into end-to-end recovery). The BER=0 lane is the
    bitwise-inertness anchor — with nothing to corrupt, the two arms
    must agree bit-for-bit on every pre-feature lane.

    Returns (g, wls [B, F], faults [B, Q], expectations) with
    ``expectations["link"]`` the LLR spec for the on arm,
    ``["cbfc"]`` the LLR+CBFC spec (the lossless-credit arm),
    ``["params"]`` the shared SimParams (a large ``timeout_ticks`` so
    hop-local replay at ~link RTT visibly beats end-to-end RTO tails),
    ``["bers"]``/``["names"]`` the BER axis, ``["uplinks"]`` the
    corrupted queue ids, and ``["budget"]`` the tick budget.
    """
    from repro.core.lb.schemes import LBScheme
    from repro.core.link import LinkConfig
    from repro.network.fabric import SimParams
    from repro.network.faults import FaultSchedule

    g, wl, exp = victim_sweep(pairs, uplinks, size=size)
    healthy = FaultSchedule.healthy(g.num_queues)
    scheds = [healthy.corrupt(exp["uplinks"], ber) if ber else healthy
              for ber in bers]
    wls = Workload.stack([wl] * len(scheds))
    return g, wls, FaultSchedule.stack(scheds), dict(
        exp,
        names=[f"ber_{ber:g}" for ber in bers],
        bers=tuple(float(b) for b in bers),
        link=LinkConfig.on(llr=True),
        cbfc=LinkConfig.on(llr=True, cbfc=True),
        params=SimParams(ticks=budget, timeout_ticks=256, ooo_threshold=24),
        profile=TransportProfile.ai_full(lb=LBScheme.REPS),
        budget=budget,
    )


def size_sweep(sizes, fan_in: int = 4):
    """Incast message-size sweep: same flow set, per-scenario sizes.

    Message size is traced, so the whole sweep shares one executable.
    Returns (g, wls [B, F], expectations).
    """
    g = leaf_spine(leaves=fan_in + 1, spines=4, hosts_per_leaf=4)
    dst = 0
    srcs = [4 * (l + 1) for l in range(fan_in)]
    wls = Workload.stack(
        [Workload.of(srcs, [dst] * fan_in, int(s)) for s in sizes])
    return g, wls, {"share": 1.0 / fan_in}


def incast(fan_in: int = 4, size: int = 600):
    """`fan_in` senders on distinct leaves -> one destination host."""
    g = leaf_spine(leaves=fan_in + 1, spines=4, hosts_per_leaf=4)
    dst = 0  # host 0 on leaf 0
    srcs = [4 * (l + 1) for l in range(fan_in)]  # first host of other leaves
    wl = Workload.of(srcs, [dst] * fan_in, size)
    return g, wl, {"share": 1.0 / fan_in}


def outcast(fan_out: int = 4, size: int = 500):
    """One source o -> `fan_out` dests; plus w -> v (v also fed by o).

    Hosts: o = 0 (leaf 0); dests p,q,r on leaves 1..3; v on leaf 4;
    w = host on leaf 5. Flow layout: flows 0..3 from o, flow 4 = w->v.
    """
    g = leaf_spine(leaves=6, spines=4, hosts_per_leaf=4)
    o = 0
    dests = [4, 8, 12, 16][:fan_out]  # p, q, r, v
    v = dests[-1]
    w = 20
    src = [o] * fan_out + [w]
    dst = dests + [v]
    wl = Workload.of(src, dst, size)
    return g, wl, {
        "o_share": 1.0 / fan_out,      # o fair-shares its uplink
        "rccc_w_share": 0.5,            # RCCC blindly grants v's ingress 50/50
        "nscc_w_share": 1.0 - 1.0 / fan_out,  # NSCC lets w fill the rest (75%)
    }


def in_network(pairs: int = 12, uplinks: int = 4, size: int = 500):
    """`pairs` cross-leaf flows share `uplinks` spine links (3:1 oversub),
    plus one same-leaf flow into one of the receivers.

    Two leaves with `pairs` hosts each + `uplinks` spines. Flow i: host i on
    leaf 0 -> host i on leaf 1. Extra flow: another host on leaf 1 -> host 0
    on leaf 1 (same-leaf, bypasses the fabric bottleneck).
    """
    hosts_per_leaf = pairs + 1
    g = leaf_spine(leaves=2, spines=uplinks, hosts_per_leaf=hosts_per_leaf)
    src = [i for i in range(pairs)]
    dst = [hosts_per_leaf + i for i in range(pairs)]
    # same-leaf flow: last host of leaf 1 -> first host of leaf 1
    src.append(hosts_per_leaf + pairs)
    dst.append(hosts_per_leaf + 0)
    wl = Workload.of(src, dst, size)
    cross = uplinks / pairs
    return g, wl, {
        "cross_share": cross,                  # 4/12 = 33%
        "rccc_local_share": 0.5,               # RCCC blind grant
        "optimal_local_share": 1.0 - cross,    # 67%
    }


def permutation(k: int = 8, pods: int = 4, shift: int = 17, size: int = 400):
    """Cross-pod permutation on the Fig. 2 fat tree: host i -> (i+shift)%H.

    Full-bisection network: optimum is 100% per flow; static single-path
    ECMP collides and polarizes, spraying restores near-full throughput.
    """
    g = fat_tree3(k=k, pods=pods)
    H = g.num_hosts
    src = list(range(H))
    dst = [(i + shift) % H for i in range(H)]
    wl = Workload.of(src, dst, size)
    return g, wl, {"share": 1.0}


def two_flow_collision(size: int = 400):
    """Two cross-pod flows that *may* share a path depending on their EVs —
    the Sec. 2.1 collision scenario (25% same-pod / 6.25% cross-pod)."""
    g = fat_tree3(k=8, pods=4)
    # same pod, different leaves: hosts 0 (leaf 0) and 5 (leaf 1) -> pod 1
    wl = Workload.of([0, 5], [16, 21], size)
    return g, wl, {}
