"""Decode/train parity: streaming one token at a time through the decode
caches must reproduce the full-sequence forward logits.

This is the strongest end-to-end correctness check of the model stack: it
exercises RoPE position handling, ring-cache writes, GQA repeat, SWA
masking, Mamba state recurrences, RWKV wkv/token-shift state — any
off-by-one shows up as a mismatch.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm

SEQ = 24
BATCH = 2


def _parity(arch: str, atol: float):
    cfg = configs.reduced(arch, seq=SEQ)
    if cfg.num_experts:
        # disable expert capacity drops for exactness: generous capacity
        cfg = dataclasses.replace(cfg, num_experts=2, experts_per_token=2)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key, jnp.float32)
    toks = jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab)

    fwd = lm.build_forward(cfg, mesh=None, remat=False)
    full_logits, _, _ = jax.jit(lambda p, t: fwd(p, t))(params, toks)

    dfwd = lm.build_forward(cfg, mesh=None, decode=True, remat=False)
    dstep = jax.jit(lambda p, t, c, i: dfwd(p, t, cache=c, pos0=i))
    cache = lm.init_cache(cfg, BATCH, SEQ, jnp.float32)
    outs = []
    for i in range(SEQ):
        lg, _, cache = dstep(params, toks[:, i:i + 1], cache, jnp.int32(i))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)

    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), atol=atol,
                               rtol=1e-4)


@pytest.mark.parametrize("arch,atol", [
    ("glm4-9b", 2e-4),            # dense GQA + RoPE
    ("starcoder2-15b", 2e-4),     # GQA kv=4
    ("rwkv6-3b", 2e-4),           # wkv state + token shift
    ("mixtral-8x22b", 5e-3),      # SWA ring cache + MoE (top2-of-2 exact)
    ("jamba-1.5-large-398b", 5e-3),  # mamba state + attn + MoE interleave
])
def test_decode_matches_full_forward(arch, atol):
    _parity(arch, atol)


def test_swa_window_masks_old_tokens():
    """SWA: a token further than `window` back must not affect logits."""
    cfg = configs.reduced("mixtral-8x22b", seq=SEQ)
    cfg = dataclasses.replace(cfg, sliding_window=8, num_experts=2,
                              experts_per_token=2)
    key = jax.random.PRNGKey(1)
    params = lm.init_params(cfg, key, jnp.float32)
    toks = jax.random.randint(key, (1, SEQ), 0, cfg.vocab)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab)
    fwd = lm.build_forward(cfg, mesh=None, remat=False)
    l1, _, _ = jax.jit(lambda p, t: fwd(p, t))(params, toks)
    l2, _, _ = jax.jit(lambda p, t: fwd(p, t))(params, toks2)
    # last position is > window away from position 0: identical logits
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               atol=1e-5)
    # a within-window position must differ
    assert float(jnp.abs(l1[0, 4] - l2[0, 4]).max()) > 1e-5


def test_sharded_cache_attention_matches_dense():
    """The §Perf decode path (shard_map distributed softmax) must equal
    the dense cache attention numerically (here on a 1x1 mesh)."""
    import jax
    from repro.launch.mesh import make_host_mesh
    from repro.serve.serve_step import make_decode_step

    cfg = configs.reduced("glm4-9b", seq=SEQ)
    key = jax.random.PRNGKey(3)
    params = lm.init_params(cfg, key, jnp.float32)
    toks = jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab)
    mesh = make_host_mesh()

    def run(sharded):
        dstep = jax.jit(make_decode_step(
            cfg, mesh=mesh, dp_axes=("data",),
            select_write=sharded, sharded_cache_attn=sharded))
        cache = lm.init_cache(cfg, BATCH, SEQ, jnp.float32)
        outs = []
        for i in range(SEQ):
            lg, cache = dstep(params, cache, toks[:, i:i + 1], jnp.int32(i))
            outs.append(lg)
        return jnp.stack(outs, 1)

    np.testing.assert_allclose(np.asarray(run(True)),
                               np.asarray(run(False)),
                               atol=2e-4, rtol=1e-4)
