"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cms.nscc import NSCCParams
from repro.kernels import ops

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("n", [1, 7, 128, 129, 1000, 4096])
def test_nscc_update_matches_ref(n):
    cwnd = jnp.asarray(RNG.uniform(1, 48, n), jnp.float32)
    ecn = jnp.asarray(RNG.integers(0, 2, n), jnp.int32)
    rtt = jnp.asarray(RNG.uniform(0.5, 60, n), jnp.float32)
    cnt = jnp.asarray(RNG.integers(0, 5, n), jnp.int32)
    a = ops.nscc_update(cwnd, ecn, rtt, cnt, use_pallas=True)
    b = ops.nscc_update(cwnd, ecn, rtt, cnt, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@pytest.mark.parametrize("params", [
    NSCCParams(), NSCCParams(base_rtt=20.0, md=0.3),
    NSCCParams(max_cwnd=128.0, quick_gain=1.5),
])
def test_nscc_update_param_sweep(params):
    n = 512
    cwnd = jnp.asarray(RNG.uniform(params.min_cwnd, params.max_cwnd, n),
                       jnp.float32)
    ecn = jnp.asarray(RNG.integers(0, 2, n), jnp.int32)
    rtt = jnp.asarray(RNG.uniform(0.5, 80, n), jnp.float32)
    cnt = jnp.asarray(RNG.integers(0, 3, n), jnp.int32)
    a = ops.nscc_update(cwnd, ecn, rtt, cnt, params, use_pallas=True)
    b = ops.nscc_update(cwnd, ecn, rtt, cnt, params, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    assert (np.asarray(a) >= params.min_cwnd - 1e-6).all()
    assert (np.asarray(a) <= params.max_cwnd + 1e-6).all()


@pytest.mark.parametrize("n,w", [(1, 2), (5, 4), (64, 16), (300, 32),
                                 (1000, 8)])
def test_sack_advance_matches_ref(n, w):
    ring = jnp.asarray(
        RNG.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    base = jnp.asarray(RNG.integers(0, 10000, n, dtype=np.uint32))
    r1, b1, a1 = ops.sack_advance(ring, base, use_pallas=True)
    r2, b2, a2 = ops.sack_advance(ring, base, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_sack_advance_edge_cases():
    # all-ones rows advance the full window; all-zero rows advance 0
    ring = jnp.stack([jnp.full((8,), 0xFFFFFFFF, jnp.uint32),
                      jnp.zeros((8,), jnp.uint32),
                      jnp.asarray([1, 0, 0, 0, 0, 0, 0, 0], jnp.uint32)])
    base = jnp.zeros((3,), jnp.uint32)
    r, b, a = ops.sack_advance(ring, base, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(a), [256, 0, 1])
    np.testing.assert_array_equal(np.asarray(b), [256, 0, 1])
    assert int(np.asarray(r)[0].sum()) == 0


@pytest.mark.parametrize("n", [3, 500, 4096])
@pytest.mark.parametrize("fanout", [2, 4, 7, 8, 13, 16])
def test_ecmp_select_matches_ref(n, fanout):
    src = jnp.asarray(RNG.integers(0, 1 << 20, n), jnp.int32)
    dst = jnp.asarray(RNG.integers(0, 1 << 20, n), jnp.int32)
    ev = jnp.asarray(RNG.integers(0, 65536, n), jnp.int32)
    salt = jnp.asarray(RNG.integers(0, 256, n), jnp.int32)
    a = ops.ecmp_select(src, dst, ev, salt, fanout, use_pallas=True)
    b = ops.ecmp_select(src, dst, ev, salt, fanout, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (np.asarray(a) >= 0).all() and (np.asarray(a) < fanout).all()


def test_ecmp_determinism_and_spread():
    """Same EV => same port; the port histogram over EVs is well mixed."""
    n = 1 << 14
    ev = jnp.arange(n, dtype=jnp.int32)
    src = jnp.zeros((n,), jnp.int32)
    dst = jnp.ones((n,), jnp.int32)
    salt = jnp.full((n,), 3, jnp.int32)
    p1 = ops.ecmp_select(src, dst, ev, salt, 4, use_pallas=True)
    p2 = ops.ecmp_select(src, dst, ev, salt, 4, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    hist = np.bincount(np.asarray(p1), minlength=4) / n
    np.testing.assert_allclose(hist, 0.25, atol=0.02)


@pytest.mark.parametrize("f,w,lanes", [(1, 2, 5), (9, 16, 64), (130, 4, 300)])
def test_nack_mark_matches_ref(f, w, lanes):
    rtx = jnp.asarray(RNG.integers(0, 2 ** 32, (f, w), dtype=np.uint32))
    flow = jnp.asarray(RNG.integers(-2, f + 2, lanes), jnp.int32)
    off = jnp.asarray(RNG.integers(-4, w * 32 + 8, lanes), jnp.int32)
    valid = jnp.asarray(RNG.integers(0, 2, lanes).astype(bool))
    # the fabric always hands the kernel in-range rows/offsets; clip the
    # sweep the same way so both paths see the contract inputs
    valid = valid & (flow >= 0) & (flow < f) & (off >= 0) & (off < w * 32)
    a = ops.nack_mark(rtx, flow, off, valid, use_pallas=True)
    b = ops.nack_mark(rtx, flow, off, valid, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_nack_mark_or_semantics_with_duplicates():
    """Two lanes carrying the SAME (flow, offset) must set the bit once
    (OR, not add) — the packet + its retransmission trimmed in one tick."""
    rtx = jnp.zeros((3, 2), jnp.uint32)
    flow = jnp.asarray([1, 1, 1, 2, 0], jnp.int32)
    off = jnp.asarray([5, 5, 37, 0, 63], jnp.int32)
    valid = jnp.asarray([True, True, True, True, False])
    for up in (True, False):
        out = np.asarray(ops.nack_mark(rtx, flow, off, valid, use_pallas=up))
        assert out[1, 0] == 1 << 5
        assert out[1, 1] == 1 << (37 - 32)
        assert out[2, 0] == 1
        assert out[0].sum() == 0, "invalid lane must mark nothing"


def test_nack_mark_preserves_existing_bits():
    rtx = jnp.full((2, 2), 0x80000001, jnp.uint32)
    out = np.asarray(ops.nack_mark(
        rtx, jnp.asarray([0], jnp.int32), jnp.asarray([1], jnp.int32),
        jnp.asarray([True]), use_pallas=True))
    assert out[0, 0] == 0x80000003 and out[1, 0] == 0x80000001
