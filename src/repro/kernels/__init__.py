# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
import jax

#: single import-time backend probe shared by every kernel module, so the
#: compiled-vs-interpret dispatch policy lives in exactly one place.
ON_TPU = jax.default_backend() == "tpu"


def auto_interpret(interpret: "bool | None") -> bool:
    """Resolve a kernel's `interpret` arg: None = auto (compiled on TPU,
    interpreted elsewhere)."""
    return (not ON_TPU) if interpret is None else interpret
