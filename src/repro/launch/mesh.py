"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches JAX device state. Single pod: 256 chips as (data=16, model=16).
Multi-pod: 2 pods x 256 chips as (pod=2, data=16, model=16); the "pod"
axis extends data parallelism across the pod boundary (gradient
all-reduce crosses the UET backend fabric — exactly the traffic the
paper's transport carries).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A trivial 1-device mesh with the production axis names, so the same
    sharded code paths (shard_map MoE etc.) run in CPU tests."""
    return jax.make_mesh((1, 1), ("data", "model"))
