"""A guided tour of the UET transport layers (Sec. 3): addressing ->
matching -> large-message protocols -> PDC lifecycle, each exercised
with the real vectorized implementations.

Everything this tour walks through composes into ONE declarative object
at the top of the stack: a `repro.network.profile.TransportProfile`.
The profile says which congestion control runs (NSCC / RCCC / both),
which Entropy-Value load-balancing scheme sprays packets, and which
delivery mode each flow uses (ROD / RUD / RUDI) — the paper's Sec. 2.2
profile table is `TransportProfile.ai_base() / ai_full() / hpc()`, and
the fabric engine (`repro.network.fabric.simulate`) compiles whatever
composition you declare. This file tours the *semantic* layers beneath
that surface; see examples/quickstart.py for driving the fabric with
profiles.

Run: PYTHONPATH=src python examples/uet_transport_tour.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import addressing, matching, messaging, pdc
from repro.core.types import MsgProtocol, Profile
from repro.network.profile import DeliveryMode, TransportProfile


def main():
    print("=== [profiles] declarative transport compositions (Sec 2.2) ===")
    for prof in (TransportProfile.ai_base(), TransportProfile.ai_full(),
                 TransportProfile.hpc()):
        print(f"  {prof.describe()}")
    print("=== [SES] relative addressing (Sec 3.1.1) ===")
    t = addressing.FEPTables.create(num_jobs=4, procs_per_job=8,
                                    ris_per_proc=4)
    ris = jnp.arange(32, dtype=jnp.int32).reshape(8, 4) + 100
    t = addressing.register_job(t, 1, jobid=0x2313, proc_ids=jnp.arange(8),
                                ri_contexts=ris)
    ctx, ok = addressing.resolve(
        t, jobid=jnp.array([0x2313, 0xBAD]),
        pid_on_fep=jnp.array([2, 2]), ri=jnp.array([1, 1]),
        rel=jnp.array([1, 1]))
    print(f"  JobID 0x2313/PID 2/RI 1 -> ctx {int(ctx[0])} (ok={bool(ok[0])})")
    print(f"  unknown JobID           -> ctx {int(ctx[1])} "
          f"(authorization is the JobID)")
    print(f"  directory: relative {addressing.directory_entries(10000, 1000, True):,}"
          f" entries vs direct "
          f"{addressing.directory_entries(10000, 1000, False):,}")

    print("\n=== [SES] tag matching (Sec 3.1.2) ===")
    q = matching.RecvQueue.create(8)
    hi, lo = matching.encode_match_key(comm_id=7, tag=42, msg_seq=0)
    q = matching.post_receive(q, 0, hi, lo, 0, 0, matching.ANY_INITIATOR,
                              seq=0, buffer_id=5)
    slot, ok = matching.match(q, jnp.array([hi]), jnp.array([lo]),
                              jnp.array([3], jnp.uint32), Profile.AI_FULL)
    print(f"  exact match (AI Full): slot {int(slot[0])}, "
          f"matched={bool(ok[0])}")
    mh, ml = matching.wildcard_mask(match_tag=False, match_seq=False)
    q2 = matching.RecvQueue.create(8)
    bh, bl = matching.encode_match_key(7, 0, 0)
    q2 = matching.post_receive(q2, 0, bh, bl, mh, ml,
                               matching.ANY_INITIATOR, 0, 6)
    th, tl = matching.encode_match_key(7, 999, 4)
    slot, ok = matching.match(q2, jnp.array([th]), jnp.array([tl]),
                              jnp.array([3], jnp.uint32), Profile.HPC)
    print(f"  wildcard ANY_TAG (HPC): matched={bool(ok[0])}")

    print("\n=== [SES] large-message protocols (Sec 3.1.3, Fig 5) ===")
    link = messaging.LinkModel(alpha=1.0, beta=0.01)
    print(f"  {'protocol':22s} {'expected':>9s} {'unexpected':>10s}")
    for proto in MsgProtocol:
        te = messaging.simulate_protocol(proto, 1000, 5.0, 2.0, link,
                                         eager_limit=2000).receiver_complete
        tu = messaging.simulate_protocol(proto, 1000, 2.0, 12.0, link,
                                         eager_limit=2000).receiver_complete
        print(f"  {proto.name:22s} {te:9.2f} {tu:10.2f}")

    print("\n=== [PDS] PDC lifecycle, Fig 6 ===")
    pool = pdc.PDCPool.create(2)
    pool = pdc.open_pdc(pool, jnp.int32(0), jnp.int32(7), jnp.uint32(4),
                        mode=int(DeliveryMode.ROD))
    print(f"  after first send : state={pdc.PDCState(int(pool.state[0])).name}"
          f" (sending at FULL RATE during establishment; one PDC per "
          f"delivery mode — this one is "
          f"{DeliveryMode(int(pool.mode[0])).name})")
    pool = pdc.on_ack(pool, jnp.int32(0), jnp.int32(19), jnp.int32(1))
    print(f"  after first ACK  : state={pdc.PDCState(int(pool.state[0])).name},"
          f" remote PDCID={int(pool.remote_id[0])}")
    st = pool.state[:1]
    for ev in (pdc.InitEvent.CLOSE_REQ, pdc.InitEvent.DRAINED,
               pdc.InitEvent.CLOSE_ACK):
        st = pdc.step_initiator(st, jnp.array([int(ev)]))
        print(f"  {ev.name:10s}       -> {pdc.PDCState(int(st[0])).name}")


if __name__ == "__main__":
    main()
