"""minitron-4b: pruned nemotron dense decoder [arXiv:2407.14679; hf]."""
from repro.configs.base import ArchConfig, pad_for_tp, MIXER_ATTN, FFN_MLP

CONFIG = pad_for_tp(ArchConfig(
    name="minitron-4b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    head_dim=128, d_ff=9216, vocab_size=256_000,
    pattern=((MIXER_ATTN, FFN_MLP),),
    source="arXiv:2407.14679; hf",
))
