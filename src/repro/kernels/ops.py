"""Public jit'd entry points for the kernel package.

Every op has a Pallas kernel and a pure-jnp oracle (ref.py); dispatch is
``use_pallas``:

* ``None`` (default) — auto: Pallas on TPU (compiled), jnp oracle on CPU,
  where XLA fuses the reference well and Pallas interpret mode would be
  the silent slow path.
* ``True`` — force the Pallas kernel (interpret mode off-TPU, for
  validation).
* ``False`` — force the jnp oracle.

The fabric simulator calls these on its per-tick hot path, so the auto
default is what makes a TPU deployment run the fused kernels.
"""
from __future__ import annotations

from repro.core.cms.nscc import NSCCParams
from repro.kernels import ON_TPU as _ON_TPU, ref
from repro.kernels.ecmp_hash import ecmp_select as _ecmp_pallas
from repro.kernels.nack_mark import nack_mark as _nack_mark_pallas
from repro.kernels.nscc_update import nscc_update as _nscc_pallas
from repro.kernels.sack_bitmap import sack_advance as _sack_pallas
from repro.kernels.sack_fused import sack_fused as _sack_fused_pallas


def _use_pallas(use_pallas: bool | None) -> bool:
    return _ON_TPU if use_pallas is None else use_pallas


def nscc_update(cwnd, ecn, rtt, count, params: NSCCParams = NSCCParams(),
                use_pallas: bool | None = None):
    if _use_pallas(use_pallas):
        return _nscc_pallas(cwnd, ecn, rtt, count, params,
                            interpret=not _ON_TPU)
    return ref.nscc_update_ref(cwnd, ecn, rtt, count, params)


def sack_advance(ring, base, use_pallas: bool | None = None):
    if _use_pallas(use_pallas):
        return _sack_pallas(ring, base, interpret=not _ON_TPU)
    return ref.sack_advance_ref(ring, base)


def sack_fused(ring, base, rtx, mask, use_pallas: bool | None = None):
    """Fused record-rx OR + CACK advance + dual ring shift (Sec. 3.2.5)."""
    if _use_pallas(use_pallas):
        return _sack_fused_pallas(ring, base, rtx, mask,
                                  interpret=not _ON_TPU)
    return ref.sack_fused_ref(ring, base, rtx, mask)


def nack_mark(rtx, flow, off, valid, use_pallas: bool | None = None):
    """Duplicate-safe OR of NACK-requested retransmit bits (Sec. 3.2.4)."""
    if _use_pallas(use_pallas):
        return _nack_mark_pallas(rtx, flow, off, valid,
                                 interpret=not _ON_TPU)
    return ref.nack_mark_ref(rtx, flow, off, valid)


def ecmp_select(src, dst, ev, salt, fanout: int,
                use_pallas: bool | None = None):
    if _use_pallas(use_pallas):
        return _ecmp_pallas(src, dst, ev, salt, fanout,
                            interpret=not _ON_TPU)
    return ref.ecmp_hash_ref(src, dst, ev, salt, fanout)
