"""Architecture + shape configuration system.

Every assigned architecture is an `ArchConfig`; every input shape is a
`ShapeConfig`. The (arch x shape) grid drives smoke tests, the multi-pod
dry-run, and the roofline table.

Padding policy (recorded per-arch in `pad_note`): attention head counts
are padded up to the smallest multiple that shards over the 16-way
"model" axis while preserving the GQA group structure; RWKV's inner dim
is padded to a 16-divisible head count. Padding overhead shows up in the
MODEL_FLOPS / HLO_FLOPS ratio of the roofline table — it is reported, not
hidden.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional


# block descriptors: (mixer, ffn) per layer position within a repeating unit
MIXER_ATTN = "attn"
MIXER_MAMBA = "mamba"
MIXER_RWKV = "rwkv"
FFN_MLP = "mlp"
FFN_MOE = "moe"
FFN_RWKV = "rwkv_mlp"


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int               # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    num_experts: int = 0
    experts_per_token: int = 0
    rope_theta: float = 10_000.0
    sliding_window: int = 0      # 0 = full attention
    pattern: tuple = ((MIXER_ATTN, FFN_MLP),)
    ssm_state: int = 16
    mamba_expand: int = 2
    rwkv_head_dim: int = 64
    frontend: Optional[str] = None  # "vit_stub" | "encodec_stub"
    source: str = ""
    # padding for 16-way TP (computed in __post_init__ if left 0)
    pad_heads_to: int = 0
    pad_kv_to: int = 0
    pad_vocab_to: int = 0
    pad_note: str = ""
    tp_pad: int = 16             # TP width sharded dims must divide

    @property
    def n_q(self) -> int:
        return self.pad_heads_to or self.num_heads

    @property
    def n_kv(self) -> int:
        return self.pad_kv_to or self.num_kv_heads

    @property
    def vocab(self) -> int:
        return self.pad_vocab_to or self.vocab_size

    @property
    def unit_len(self) -> int:
        return len(self.pattern)

    @property
    def repeats(self) -> int:
        assert self.num_layers % self.unit_len == 0
        return self.num_layers // self.unit_len

    @property
    def rwkv_heads(self) -> int:
        # padded so heads shard tp_pad-way (see module docstring)
        h = self.d_model // self.rwkv_head_dim
        return _round_up(h, self.tp_pad)

    @property
    def rwkv_inner(self) -> int:
        return self.rwkv_heads * self.rwkv_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """May run long_500k: SSM / hybrid / sliding-window attention."""
        return (self.sliding_window > 0
                or any(m != MIXER_ATTN for m, _ in self.pattern))

    def param_count(self) -> int:
        """Analytic parameter count (unpadded, for 6ND roofline numbers)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        total = V * D  # embedding
        total += D * V  # lm head
        for (mixer, ffn) in self.pattern:
            reps = self.repeats
            if mixer == MIXER_ATTN:
                hd = self.head_dim
                total += reps * D * hd * (self.num_heads * 2
                                          + self.num_kv_heads * 2)
            elif mixer == MIXER_MAMBA:
                di = self.mamba_expand * D
                total += reps * (D * 2 * di + di * D
                                 + di * (2 * self.ssm_state + 1))
            elif mixer == MIXER_RWKV:
                total += reps * 6 * D * D
            if ffn == FFN_MLP:
                total += reps * 3 * D * F
            elif ffn == FFN_MOE:
                total += reps * (D * self.num_experts
                                 + self.num_experts * 3 * D * F)
            elif ffn == FFN_RWKV:
                total += reps * (2 * D * F // 2 + D * D)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.num_experts == 0:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        moe_layers = sum(1 for _, f in self.pattern if f == FFN_MOE) \
            * self.repeats
        inactive = moe_layers * (self.num_experts - self.experts_per_token) \
            * 3 * D * F
        return self.param_count() - inactive

    def param_leaves(self) -> "list[tuple[tuple, tuple]]":
        """Analytic parameter leaves as (path, shape) pairs, in the layer
        vocabulary the sharding rules pattern-match (wq/wk/wv/wo, w_gate/
        w_up/w_down, embed/head/router, ...). Block leaves carry the
        stacked leading repeats dim, path-prefixed ``blocks/`` exactly
        like the real params pytree, so ``sharding.param_pspec`` applies
        unchanged — the parallelism planner (`repro.distributed.plan`)
        classifies each leaf with the REAL rules rather than re-deriving
        a parallel set. Head/vocab counts use the TP-padded values (the
        sharded, communicated tensors); tiny vectors (norms, biases,
        mix gates) are omitted — they are noise at collective scale.
        """
        D, F, R = self.d_model, self.d_ff, self.repeats
        hd = self.head_dim
        leaves: list[tuple[tuple, tuple]] = [
            (("embed",), (self.vocab, D)),
            (("head",), (D, self.vocab)),
        ]
        for u, (mixer, ffn) in enumerate(self.pattern):
            blk = ("blocks", f"u{u}")
            if mixer == MIXER_ATTN:
                leaves += [
                    (blk + ("wq",), (R, D, self.n_q * hd)),
                    (blk + ("wk",), (R, D, self.n_kv * hd)),
                    (blk + ("wv",), (R, D, self.n_kv * hd)),
                    (blk + ("wo",), (R, self.n_q * hd, D)),
                ]
            elif mixer == MIXER_MAMBA:
                di = self.mamba_expand * D
                leaves += [
                    (blk + ("w_in",), (R, D, 2 * di)),
                    (blk + ("w_out",), (R, di, D)),
                    (blk + ("w_bcdt",), (R, di, 2 * self.ssm_state + 1)),
                ]
            elif mixer == MIXER_RWKV:
                leaves += [(blk + (n,), (R, D, D))
                           for n in ("w_r", "w_k", "w_v", "w_g",
                                     "w_decay", "w_o")]
            if ffn == FFN_MLP:
                leaves += [
                    (blk + ("w_gate",), (R, D, F)),
                    (blk + ("w_up",), (R, D, F)),
                    (blk + ("w_down",), (R, F, D)),
                ]
            elif ffn == FFN_MOE:
                E = self.num_experts
                leaves += [
                    (blk + ("router",), (R, D, E)),
                    (blk + ("w_gate",), (R, E, D, F)),
                    (blk + ("w_up",), (R, E, D, F)),
                    (blk + ("w_down",), (R, E, F, D)),
                ]
            elif ffn == FFN_RWKV:
                leaves += [
                    (blk + ("w_k",), (R, D, F // 2)),
                    (blk + ("w_v",), (R, F // 2, D)),
                    (blk + ("w_o",), (R, D, D)),
                ]
        return leaves


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_for_tp(cfg: ArchConfig, tp: int = 16) -> ArchConfig:
    """Pad head counts / vocab so every sharded dim divides the TP width."""
    from dataclasses import replace
    n_q, n_kv = cfg.num_heads, cfg.num_kv_heads
    note = []
    if n_q and n_q % tp:
        group = max(1, n_q // max(n_kv, 1))
        new_q = _round_up(n_q, tp)
        if n_kv and new_q % n_kv:
            new_kv = math.gcd(new_q, _round_up(n_kv, 1))
            # keep GQA structure: grow kv so that q % kv == 0
            new_kv = n_kv
            while new_q % new_kv:
                new_kv += 1
            note.append(f"kv {n_kv}->{new_kv}")
        else:
            new_kv = n_kv
        note.append(f"q {n_q}->{new_q}")
        cfg = replace(cfg, pad_heads_to=new_q, pad_kv_to=new_kv,
                      pad_note="; ".join(note))
    if cfg.vocab_size % 256:
        cfg = replace(cfg, pad_vocab_to=_round_up(cfg.vocab_size, 256))
    return cfg


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shapes_for(cfg: ArchConfig) -> list[str]:
    """The shape cells this architecture runs (long_500k only for
    sub-quadratic archs, per the assignment rules)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.is_subquadratic:
        out.append("long_500k")
    return out
