"""Core UET types, enums and constants.

Mirrors the vocabulary of the UE 1.0 spec overview paper:
  - profiles (HPC / AI Full / AI Base), Sec. 2.2
  - PDS transport modes (RUD / ROD / UUD / RUDI), Sec. 3.2.1
  - packet types (request / ack / control), Sec. 3.2
  - drop causes ("the three Cs"), Sec. 3.2.4

Everything that ends up inside a jitted simulator is an int32 code; the
enums here are the single source of truth for those codes.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class Profile(enum.IntEnum):
    """UE profiles (Sec. 2.2). HPC ⊃ AI_BASE; HPC + deferrable send ⊃ AI_FULL."""

    HPC = 0
    AI_FULL = 1
    AI_BASE = 2


class TransportMode(enum.IntEnum):
    """PDS packet ordering / reliability modes (Sec. 3.2.1)."""

    RUD = 0   # Reliable Unordered Delivery — default bulk mode, enables spraying
    ROD = 1   # Reliable Ordered Delivery — go-back-N, single path per flowlet
    UUD = 2   # Unreliable Unordered Delivery — datagrams
    RUDI = 3  # Reliable Unordered for Idempotent ops — no receiver dedup state


class PacketType(enum.IntEnum):
    """PDS packet types (Sec. 3.2)."""

    REQUEST = 0  # carries data (initiator->target for write/send; reverse for read)
    ACK = 1      # acknowledges request packets; rides the control TC
    CONTROL = 2  # transport control (probe path, close PDC, NACK, credit grant)


class DropCause(enum.IntEnum):
    """The "three Cs" of packet drops (Sec. 3.2.4)."""

    NONE = 0
    CONGESTION = 1     # switch buffer full
    CORRUPTION = 2     # checksum/FEC failure
    CONFIGURATION = 3  # firewall / TTL expiry
    TRIMMED = 4        # payload trimmed by switch, header delivered (Sec. 3.2.4)


class SemOp(enum.IntEnum):
    """SES operation kinds (Sec. 3.1)."""

    SEND = 0            # (optionally tagged) send
    TAGGED_SEND = 1
    RMA_WRITE = 2
    RMA_READ = 3        # single-packet reads (Sec. 3.1.4)
    ATOMIC = 4
    RENDEZVOUS_READ = 5  # the read step of the rendezvous protocol
    DEFER_RESUME = 6     # restart-token control messages of deferrable send


class MsgProtocol(enum.IntEnum):
    """Large-unexpected-message protocols (Sec. 3.1.3 / Fig. 5)."""

    RENDEZVOUS = 0          # HPC
    DEFERRABLE_SEND = 1     # AI Full
    RECEIVER_INITIATED = 2  # AI Base


class AddrMode(enum.IntEnum):
    """SES addressing modes (Sec. 3.1.1), selected by the `rel` header bit."""

    RELATIVE = 0   # parallel jobs: JobID -> PIDonFEP table -> RI table
    ABSOLUTE = 1   # client/server: PIDonFEP acts like a UDP port


class PDCState(enum.IntEnum):
    """PDC state machine states (Fig. 6). Used by initiator and target pools."""

    CLOSED = 0
    SYN = 1        # initiator sent first packet(s) with SYN, no PDCID echo yet
    ESTABLISHED = 2
    QUIESCE = 3    # draining: finishes started messages, refuses new ones
    ACK_WAIT = 4   # all drained, waiting for outstanding replies
    PENDING = 5    # target-side secure-PSN pending state (Sec. 3.4.2)


# ---------------------------------------------------------------------------
# Wire / fabric constants
# ---------------------------------------------------------------------------

#: UDP destination port assigned to UET by IANA ("beautiful large prime",
#: and == RoCEv2's 4791 + 2).
UET_UDP_PORT = 4793

#: Default MTU payload for full packets. UE prohibits fragmentation and sends
#: all but the last packet of a message with a full MTU payload (Sec. 3.2).
DEFAULT_MTU = 4096

#: Entropy Value space: the EV replaces the 16-bit UDP source port (Sec. 2.1).
EV_BITS = 16
EV_SPACE = 1 << EV_BITS

#: Sentinel tick meaning "never" in fault-schedule lanes (int32 max, so
#: `tick < NEVER_TICK` is always true for any reachable simulator tick).
#: A statically-failed queue is `fail_at=0, heal_at=NEVER_TICK`; a healthy
#: one is `fail_at=NEVER_TICK` (see repro.network.faults.FaultSchedule).
NEVER_TICK = 2 ** 31 - 1

#: SACK bitmap width carried in ACK packets (Sec. 3.2.5).
SACK_BITMAP_BITS = 64

#: Default Maximum PSN Range — receiver packet-tracking resource bound
#: (Sec. 3.2.5). Powers of two keep the bitmap ring arithmetic cheap.
DEFAULT_MP_RANGE = 1024

#: TSS key lifetime bounds, in packets (Sec. 3.4.1).
TSS_KEY_LIFETIME_MIN = 2 ** 27
TSS_KEY_LIFETIME_MAX = int(2 ** 34.5)

#: Encrypted PDCs must close + reopen after this many packets so PSNs never
#: wrap under one key (Sec. 3.4.2).
TSS_PDC_MAX_PACKETS = 2_000_000_000


@dataclass(frozen=True)
class FabricParams:
    """Physical parameters of the modeled backend fabric.

    Defaults model the paper's design point: 400+ Gbps links, 10-150 m
    reach, MTU-sized packets. One simulator tick == the serialization time
    of one MTU payload on one link, so bandwidth shares are exact and all
    latencies are expressed in packet-times.
    """

    link_gbps: float = 400.0
    mtu_bytes: int = DEFAULT_MTU
    #: one-way propagation+pipeline latency per hop, in ticks
    hop_latency_ticks: int = 1
    #: switch egress queue capacity, in packets (per output port)
    queue_capacity: int = 64
    #: ECN marking threshold (egress queue occupancy, packets). Egress
    #: marking per the spec (differs from RFC 3168 ingress marking).
    ecn_threshold: int = 16
    #: when True, switches trim instead of dropping on overflow (Sec. 3.2.4)
    trimming: bool = True
    #: number of return-path ticks for ACKs on the control TC (uncongested
    #: second traffic class, Sec. 3.1.4)
    ack_return_ticks: int = 3

    @property
    def tick_seconds(self) -> float:
        return self.mtu_bytes * 8 / (self.link_gbps * 1e9)


@dataclass(frozen=True)
class UETConfig:
    """Top-level transport configuration used by the simulator."""

    profile: Profile = Profile.AI_FULL
    mode: TransportMode = TransportMode.RUD
    mtu: int = DEFAULT_MTU
    mp_range: int = DEFAULT_MP_RANGE
    #: congestion control switches (either/both; Sec. 3.3)
    nscc: bool = True
    rccc: bool = False
    #: load balancing scheme name: "oblivious" | "reps" | "evbitmap" | "static"
    lb: str = "oblivious"
    #: security on/off (adds TSS header + ICV overhead and secure-PSN rules)
    tss: bool = False
    fabric: FabricParams = field(default_factory=FabricParams)
