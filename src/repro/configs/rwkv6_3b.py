"""rwkv6-3b (Finch): attention-free, data-dependent decay
[arXiv:2404.05892; hf]."""
from repro.configs.base import ArchConfig, pad_for_tp, MIXER_RWKV, FFN_RWKV

CONFIG = pad_for_tp(ArchConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=8960, vocab_size=65536,
    pattern=((MIXER_RWKV, FFN_RWKV),),
    rwkv_head_dim=64,
    source="arXiv:2404.05892; hf",
))
