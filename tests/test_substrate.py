"""Substrate tests: checkpointing (incl. crash-restart + elastic restore),
data pipeline determinism, gradient compression invariants, trainer fault
tolerance, optimizer behaviour."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.ckpt import checkpointing as ckpt
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.distributed import compression as comp
from repro.train.optimizer import AdamWConfig, apply_updates, init_opt_state


# ------------------------------------------------------------- checkpoint
def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (32, 16)),
            "nested": {"b": jax.random.normal(k2, (8,)),
                       "step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 42, tree)
    assert ckpt.latest_step(str(tmp_path)) == 42
    restored = ckpt.restore(str(tmp_path), 42, tree)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        tree, restored)


def test_checkpoint_latest_pointer_and_atomicity(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    ckpt.save(str(tmp_path), 1, tree)
    tree2 = jax.tree_util.tree_map(lambda x: x + 1 if x.dtype != jnp.int32
                                   else x, tree)
    ckpt.save(str(tmp_path), 2, tree2)
    assert ckpt.latest_step(str(tmp_path)) == 2
    # a stale tmp dir never corrupts restores
    os.makedirs(os.path.join(str(tmp_path), "step_00000003.tmp"),
                exist_ok=True)
    assert ckpt.latest_step(str(tmp_path)) == 2
    r = ckpt.restore(str(tmp_path), 2, tree)
    np.testing.assert_array_equal(np.asarray(r["a"]),
                                  np.asarray(tree2["a"]))


def test_async_checkpointer_overlap(tmp_path):
    c = ckpt.AsyncCheckpointer(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(2))
    c.save(5, tree)
    c.save(6, tree)  # joins the previous write first
    c.wait()
    assert ckpt.latest_step(str(tmp_path)) == 6


def test_elastic_restore_resharding(tmp_path):
    """Restore redistributes to the live mesh layout (device_put path)."""
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(str(tmp_path), 0, tree)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", None))}
    r = ckpt.restore(str(tmp_path), 0, tree, sh)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(tree["w"]))
    assert r["w"].sharding == sh["w"]


# ------------------------------------------------------------------ data
def test_data_determinism_and_host_slicing():
    cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=8)
    d1, d2 = SyntheticTokens(cfg), SyntheticTokens(cfg)
    b1, b2 = d1.global_batch(13), d2.global_batch(13)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    assert not np.array_equal(b1["inputs"], d1.global_batch(14)["inputs"])
    # host slices tile the global batch
    h0 = d1.host_batch(13, 0, 2)
    h1 = d1.host_batch(13, 1, 2)
    np.testing.assert_array_equal(
        np.concatenate([h0["inputs"], h1["inputs"]]), b1["inputs"])
    # labels are inputs shifted by one
    np.testing.assert_array_equal(b1["inputs"][:, 1:], b1["labels"][:, :-1])


# ----------------------------------------------------------- compression
@given(st.integers(1, 2000), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_quantize_roundtrip_bounded_error(n, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(0, 3, n), jnp.float32)
    q, s = comp.quantize(g)
    deq = comp.dequantize(q, s, g.shape, g.dtype)
    blocks, _ = comp._pad_to_block(g)
    maxabs = np.asarray(jnp.max(jnp.abs(blocks), axis=1))
    # error bounded by half a quantization step per block
    err = np.abs(np.asarray(deq - g))
    bidx = np.arange(n) // comp.BLOCK
    assert (err <= maxabs[bidx] / 127.0 * 0.5001 + 1e-7).all()


def test_error_feedback_accumulates():
    g = {"w": jnp.asarray([0.3, -0.2, 0.7, 1.4], jnp.float32)}
    qt, err = comp.compress_tree(g, None)
    deq = comp.decompress_tree(qt, g)
    resid = g["w"] - deq["w"]
    np.testing.assert_allclose(np.asarray(err["w"]), np.asarray(resid),
                               atol=1e-7)
    # wire bytes ~4x smaller than f32 (once past block-padding granularity)
    big = {"w": jnp.ones((4096,), jnp.float32)}
    assert comp.compressed_bytes(big) < 4 * 4096 / 3.5


# -------------------------------------------------------------- optimizer
def test_adamw_descends_quadratic():
    params = {"x": jnp.asarray([3.0, -2.0])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    opt = init_opt_state(params, cfg)
    for _ in range(150):
        grads = {"x": 2 * params["x"]}
        params, opt, gnorm = apply_updates(params, grads, opt, cfg)
    assert float(jnp.abs(params["x"]).max()) < 0.15
    assert int(opt["step"]) == 150


def test_adamw_grad_clip():
    params = {"x": jnp.asarray([0.0])}
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0)
    opt = init_opt_state(params, cfg)
    _, _, gnorm = apply_updates(params, {"x": jnp.asarray([1e6])}, opt, cfg)
    assert float(gnorm) == pytest.approx(1e6)


# ---------------------------------------------------------------- trainer
def test_trainer_resumes_after_failure(tmp_path):
    """A poisoned step triggers restore-from-checkpoint, then the run
    completes — the checkpoint/restart drill."""
    from repro.train.trainer import Trainer, TrainerConfig

    calls = {"n": 0}

    def train_step(params, opt, batch):
        calls["n"] += 1
        if calls["n"] == 7:  # simulated node failure mid-run
            raise RuntimeError("injected failure")
        p = {"w": params["w"] + 1.0}
        return p, opt, {"loss": jnp.float32(1.0 / calls["n"]),
                        "grad_norm": jnp.float32(1.0)}

    tr = Trainer(TrainerConfig(total_steps=10, ckpt_every=2, log_every=100,
                               ckpt_dir=str(tmp_path)),
                 train_step, lambda s: {"x": np.zeros(1)},
                 {"w": jnp.zeros(())}, {"step": jnp.int32(0)})
    hist = tr.run()
    assert tr.state.step == 10
    assert tr.state.failures == 1
    # the rewind replays the steps since the last durable checkpoint, so
    # history contains the replayed steps and ends at the target
    assert hist[-1]["step"] == 10
    assert len(hist) >= 10
