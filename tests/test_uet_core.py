"""Unit + property tests for the UET core: headers, addressing, matching,
messaging cost model, PDC state machine, PSN/SACK tracking, TSS rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import addressing, headers, matching, messaging, pdc, pds
from repro.core.types import (MsgProtocol, Profile, TransportMode,
                              UET_UDP_PORT)


# ---------------------------------------------------------------- headers
def test_uet_udp_port_is_rocev2_plus_two_and_prime():
    assert UET_UDP_PORT == 4793 == 4791 + 2
    assert all(UET_UDP_PORT % k for k in range(2, int(4793 ** 0.5) + 1))


def test_header_byte_model_matches_spec_table():
    """Sec. 3.2.2: PDS 12B RUD/ROD (16 w/ RCCC), 8B RUDI, 4B UUD; SES
    44/32/20B; TSS 12B (+16B ICV); Ethernet 14+4."""
    h = headers.HeaderConfig()  # RUD / UDP / IPv4 / SES std
    assert h.overhead_bytes() == 14 + 4 + 20 + 8 + 12 + 44
    rcc = headers.HeaderConfig(rccc=True)
    assert rcc.overhead_bytes() - h.overhead_bytes() == 4
    uud = headers.HeaderConfig(mode=TransportMode.UUD,
                               ses=headers.SES_HEADER_MIN)
    assert uud.overhead_bytes() == 14 + 4 + 20 + 8 + 4 + 20
    rudi = headers.HeaderConfig(mode=TransportMode.RUDI,
                                ses=headers.SES_HEADER_MIN)
    assert rudi.overhead_bytes() - uud.overhead_bytes() == 4
    tss = headers.HeaderConfig(tss=True)
    assert tss.overhead_bytes() - h.overhead_bytes() == 12 + 16
    native = headers.HeaderConfig(native_ip=True)
    assert h.overhead_bytes() - native.overhead_bytes() == 4  # 8B UDP -> 4B EV
    crc = headers.HeaderConfig(e2e_crc=True)
    assert crc.overhead_bytes() - h.overhead_bytes() == 4


@given(payload=st.integers(min_value=64, max_value=9000))
def test_header_efficiency_monotone(payload):
    h = headers.HeaderConfig()
    assert 0 < h.efficiency(payload) < 1
    assert h.efficiency(payload + 64) > h.efficiency(payload)


# ------------------------------------------------------------- addressing
def test_relative_addressing_resolves_and_authorizes():
    t = addressing.FEPTables.create(num_jobs=4, procs_per_job=8,
                                    ris_per_proc=4)
    ris = jnp.arange(8 * 4, dtype=jnp.int32).reshape(8, 4) + 100
    t = addressing.register_job(t, 1, jobid=0xABCDE,
                                proc_ids=jnp.arange(8), ri_contexts=ris)
    ctx, ok = addressing.resolve(
        t,
        jobid=jnp.array([0xABCDE, 0xABCDE, 0xDEAD]),
        pid_on_fep=jnp.array([3, 99, 0]),
        ri=jnp.array([2, 0, 0]),
        rel=jnp.array([1, 1, 1]))
    assert bool(ok[0]) and int(ctx[0]) == 100 + 3 * 4 + 2
    assert not bool(ok[1])   # PIDonFEP out of range
    assert not bool(ok[2])   # unknown JobID => unauthorized
    assert int(ctx[1]) == -1 and int(ctx[2]) == -1


def test_absolute_addressing_service_table():
    t = addressing.FEPTables.create(2, 2, 2, num_services=16)
    t = addressing.FEPTables(
        t.jobid_keys, t.jobid_to_pid, t.pid_table, t.ri_table,
        t.service_table.at[5].set(777))
    ctx, ok = addressing.resolve(
        t, jobid=jnp.array([0]), pid_on_fep=jnp.array([5]),
        ri=jnp.array([0]), rel=jnp.array([0]))
    assert bool(ok[0]) and int(ctx[0]) == 777


def test_directory_scaling_claim():
    """Sec. 3.1.1: relative addressing stores N entries, not N*P."""
    assert addressing.directory_entries(10_000, 1000, relative=True) == 10_000
    assert addressing.directory_entries(10_000, 1000,
                                        relative=False) == 10_000_000


# ---------------------------------------------------------------- matching
def test_exact_match_and_consume():
    q = matching.RecvQueue.create(8)
    hi, lo = matching.encode_match_key(3, 42, 7)
    q = matching.post_receive(q, 0, hi, lo, 0, 0, matching.ANY_INITIATOR,
                              seq=0, buffer_id=11)
    slot, ok = matching.match(q, jnp.array([hi]), jnp.array([lo]),
                              jnp.array([1], jnp.uint32), Profile.AI_FULL)
    assert bool(ok[0]) and int(slot[0]) == 0
    q = matching.consume(q, slot[0], ok[0])
    slot2, ok2 = matching.match(q, jnp.array([hi]), jnp.array([lo]),
                                jnp.array([1], jnp.uint32), Profile.AI_FULL)
    assert not bool(ok2[0])  # consumed => unexpected now


def test_hpc_wildcard_in_order():
    """HPC: lowest posting order wins among wildcard matches."""
    q = matching.RecvQueue.create(8)
    mh, ml = matching.wildcard_mask(match_tag=False, match_seq=False)
    bh, bl = matching.encode_match_key(1, 0, 0)
    q = matching.post_receive(q, 3, bh, bl, mh, ml, matching.ANY_INITIATOR,
                              seq=5, buffer_id=1)
    q = matching.post_receive(q, 1, bh, bl, mh, ml, matching.ANY_INITIATOR,
                              seq=2, buffer_id=2)
    th, tl = matching.encode_match_key(1, 77, 9)
    slot, ok = matching.match(q, jnp.array([th]), jnp.array([tl]),
                              jnp.array([0], jnp.uint32), Profile.HPC)
    assert bool(ok[0]) and int(slot[0]) == 1  # seq=2 posted earlier


def test_ai_full_rejects_wildcards():
    q = matching.RecvQueue.create(4)
    mh, ml = matching.wildcard_mask(match_tag=False)
    bh, bl = matching.encode_match_key(1, 0, 0)
    q = matching.post_receive(q, 0, bh, bl, mh, ml, matching.ANY_INITIATOR,
                              0, 1)
    th, tl = matching.encode_match_key(1, 5, 0)
    _, ok = matching.match(q, jnp.array([th]), jnp.array([tl]),
                           jnp.array([0], jnp.uint32), Profile.AI_FULL)
    assert not bool(ok[0])


@given(comm=st.integers(0, 0xFFFF), tag=st.integers(0, 0xFFFFFF),
       seq=st.integers(0, 0xFFFFFF))
@settings(max_examples=50)
def test_match_key_roundtrip_distinct(comm, tag, seq):
    """The in-order-over-unordered trick (Sec. 3.2.1): distinct message
    seqs produce distinct keys, so unordered RUD still fills in order."""
    hi1, lo1 = matching.encode_match_key(comm, tag, seq)
    hi2, lo2 = matching.encode_match_key(comm, tag, (seq + 1) & 0xFFFFFF)
    assert (int(hi1), int(lo1)) != (int(hi2), int(lo2))


# -------------------------------------------------------------- messaging
@pytest.mark.parametrize("proto", list(MsgProtocol))
@pytest.mark.parametrize("expected", [True, False])
def test_completion_time_table(proto, expected):
    """Sec. 3.1.3 table: playout equals the alpha/beta model for all six
    (protocol x expectedness) cells."""
    link = messaging.LinkModel(alpha=2.0, beta=0.05)
    size = 400.0
    ts, tr = (10.0, 4.0) if expected else (4.0, 30.0)
    model = messaging.model_completion(proto, expected, size, ts, tr, link)
    sim = messaging.simulate_protocol(proto, size, ts, tr, link,
                                      eager_limit=1000.0)
    assert abs(model - sim.receiver_complete) < 1e-9


def test_receiver_initiated_extra_rtt():
    """AI Base pays +2 alpha in the expected case vs rendezvous."""
    link = messaging.LinkModel(alpha=3.0, beta=0.01)
    r = messaging.model_completion(MsgProtocol.RENDEZVOUS, True, 100, 0, 0,
                                   link)
    ri = messaging.model_completion(MsgProtocol.RECEIVER_INITIATED, True,
                                    100, 0, 0, link)
    assert ri - r == pytest.approx(2 * link.alpha)


def test_deferrable_tracks_window():
    out = messaging.deferrable_vs_rendezvous_bandwidth(
        size=1e6, link=messaging.LinkModel(alpha=1e-6, beta=2.5e-12),
        eager_limit=16384.0, true_window=1e6)
    assert out["deferrable"] > out["rendezvous"]
    # the window-paced stall branches live in tests/test_messaging_window.py
    # (this module is hypothesis-gated and skips without dev deps)


# ------------------------------------------------------------------- PDC
def test_pdc_fig6_scenario():
    """Fig. 6: open -> full-rate during SYN -> establish on first ACK ->
    quiesce -> drain -> close."""
    pool = pdc.PDCPool.create(4)
    pool = pdc.open_pdc(pool, jnp.int32(0), jnp.int32(7), jnp.uint32(4))
    assert int(pool.state[0]) == pdc.PDCState.SYN
    assert bool(pdc.may_send_data(pool.state)[0])  # full rate during SYN!
    pool = pdc.on_ack(pool, jnp.int32(0), jnp.int32(19), jnp.int32(1))
    assert int(pool.state[0]) == pdc.PDCState.ESTABLISHED
    assert int(pool.remote_id[0]) == 19
    st = pdc.step_initiator(pool.state[:1],
                            jnp.array([int(pdc.InitEvent.CLOSE_REQ)]))
    assert int(st[0]) == pdc.PDCState.QUIESCE
    assert not bool(pdc.may_accept_new_message(st)[0])
    st = pdc.step_initiator(st, jnp.array([int(pdc.InitEvent.DRAINED)]))
    assert int(st[0]) == pdc.PDCState.ACK_WAIT
    st = pdc.step_initiator(st, jnp.array([int(pdc.InitEvent.CLOSE_ACK)]))
    assert int(st[0]) == pdc.PDCState.CLOSED


def test_pdc_target_machine():
    st = jnp.array([int(pdc.PDCState.CLOSED)])
    st = pdc.step_target(st, jnp.array([int(pdc.TgtEvent.RX_SYN)]))
    assert int(st[0]) == pdc.PDCState.SYN
    st = pdc.step_target(st, jnp.array([int(pdc.TgtEvent.RX_NOSYN)]))
    assert int(st[0]) == pdc.PDCState.ESTABLISHED
    st = pdc.step_target(st, jnp.array([int(pdc.TgtEvent.RX_CLOSE)]))
    assert int(st[0]) == pdc.PDCState.CLOSED


def test_pdc_secure_pending_path():
    """Sec. 3.4.2: secure PSN establishment goes through PENDING."""
    st = jnp.array([int(pdc.PDCState.CLOSED)])
    st = pdc.step_target(st, jnp.array([int(pdc.TgtEvent.SECURE_PENDING)]))
    assert int(st[0]) == pdc.PDCState.PENDING
    st = pdc.step_target(st, jnp.array([int(pdc.TgtEvent.SECURE_OK)]))
    assert int(st[0]) == pdc.PDCState.SYN


# ---------------------------------------------------------------- PSN/SACK
@given(st.lists(st.integers(0, 255), min_size=1, max_size=64, unique=True))
@settings(max_examples=40, deadline=None)
def test_psn_tracker_property(psns):
    """Property: after receiving an arbitrary PSN set, CACK advances to
    exactly the first gap, and every received PSN is marked."""
    t = pds.PSNTracker.create(1, 256)
    arr = jnp.asarray(psns, jnp.uint32)
    t, fresh = pds.record_rx(t, jnp.zeros(len(psns), jnp.int32), arr,
                             jnp.ones(len(psns), bool))
    assert bool(fresh.all())
    t, adv = pds.advance_cack(t)
    expect = 0
    s = set(psns)
    while expect in s:
        expect += 1
    assert int(t.base[0]) == expect
    assert int(adv[0]) == expect


def test_mp_range_rejection():
    """Sec. 3.2.5: PSNs beyond MP_RANGE are not accepted — receiver
    resource protection."""
    t = pds.PSNTracker.create(1, 64)
    t, fresh = pds.record_rx(t, jnp.array([0, 0], jnp.int32),
                             jnp.array([63, 64], jnp.uint32),
                             jnp.ones(2, bool))
    assert bool(fresh[0]) and not bool(fresh[1])
    assert int(t.oor[0]) == 1


def test_duplicate_detection():
    t = pds.PSNTracker.create(1, 64)
    one = jnp.array([5], jnp.uint32)
    t, f1 = pds.record_rx(t, jnp.array([0], jnp.int32), one,
                          jnp.ones(1, bool))
    t, f2 = pds.record_rx(t, jnp.array([0], jnp.int32), one,
                          jnp.ones(1, bool))
    assert bool(f1[0]) and not bool(f2[0])
    assert int(t.dup[0]) == 1


def test_sack_view_and_ooo():
    t = pds.PSNTracker.create(1, 128)
    for p in (0, 1, 5, 9):
        t, _ = pds.record_rx(t, jnp.array([0], jnp.int32),
                             jnp.array([p], jnp.uint32), jnp.ones(1, bool))
    t, adv = pds.advance_cack(t)
    assert int(adv[0]) == 2
    cack, lo, hi = pds.sack_view(t)
    assert int(cack[0]) == 2
    # bits now at offsets 3 (psn 5) and 7 (psn 9)
    assert int(lo[0]) == (1 << 3) | (1 << 7)
    assert int(pds.ooo_distance(t)[0]) == 8


# ------------------------------------------------------------------- DFC
def test_dfc_scales_rccc_credit_rate():
    """Sec. 3.3.4: Destination Flow Control throttles senders below what
    the network could deliver — RCCC grants scale by the destination's
    absorption rate."""
    from repro.core.cms import rccc as R
    st = R.RCCCState.create(4, initial_credit=0.0)
    st = R.mark_seen(st, jnp.arange(4), jnp.ones(4, bool))
    dst = jnp.array([0, 0, 1, 1], jnp.int32)
    active = jnp.ones(4, bool)
    # destination 1 can only absorb half rate (memory pressure)
    dfc = jnp.array([1.0, 0.5], jnp.float32)
    st = R.grant_credits(st, dst, active, num_hosts=2, rate=1.0, dfc=dfc)
    np.testing.assert_allclose(np.asarray(st.balance),
                               [0.5, 0.5, 0.25, 0.25], atol=1e-6)


def test_dfc_nscc_window_penalty():
    """Sec. 3.3.4 NSCC path: the receiver's window penalty scales the
    sender's congestion window."""
    from repro.core.cms import nscc as N
    params = N.NSCCParams()
    st = N.NSCCState.create(4, params)
    st2 = N.apply_dfc_penalty(st, params, jnp.array([1, 2]),
                              jnp.array([0.5, 0.25], jnp.float32),
                              jnp.ones(2, bool))
    np.testing.assert_allclose(np.asarray(st2.cwnd),
                               [64.0, 32.0, 48.0, 64.0], atol=1e-4)
