"""Fabric-simulator performance benchmarks: ticks/sec and scenarios/sec.

Measures the hot path of the UET fabric engine in four configurations —

* ``single``         — one compiled scan, one scenario (ticks/sec; the
                       per-tick hot-path number the fused kernels moved);
* ``serial_seed``    — B scenarios the way the *seed* architecture ran a
                       sweep: the failure set was a static tuple closed
                       over by jit, so EVERY scenario paid its own
                       trace+compile before running. This is the baseline
                       the batched engine exists to kill (and the
                       acceptance comparison for scenarios/sec).
* ``serial_shared``  — B sequential ``simulate`` calls on this PR's
                       serial path (failure masks/seeds/workloads are
                       traced, so one warm executable is reused). Reported
                       for transparency: most of the sweep win is the
                       recompile removal, the rest is vmap amortization.
* ``batched``        — the same B scenarios in one ``simulate_batch``
                       (vmapped scan, carry donated), cold and warm.

Also runs the profile-ablation sweep (ai_base / ai_full / hpc plus the
NSCC-only / RCCC-only / hybrid / open-loop CC ablation) as ONE
``simulate_batch`` call — the engine groups the grid by distinct
profile, one executable each, run concurrently — and records
per-profile goodput under ``profile_ablation``. The scenario is the
oversubscribed in-network pattern whose same-leaf victim flow actually
separates the CC policies (asserted — a bench whose ablation axis
reports one number is measuring nothing).

The collective ablation grid (kind x algorithm x INC on/off x profile,
15 dependency-scheduled whole collectives padded into one batch) runs
as ONE ``simulate_batch`` call too and lands under ``collective_sweep``:
per-scenario completion ticks, scenarios/sec, and the in-network-
reduction win (INC-on / INC-off completion ratio for the tree
all-reduce). Both sweeps run the default ``trace="stats"`` tier on the
adaptive-horizon engine: completion ticks stream out of the chunked
while-scan, scenarios exit at quiescence instead of padding to the
budget, and INC on/off rides the traced ``red`` lanes (one executable
per transport profile for the whole grid).

Writes ``BENCH_fabric.json`` at the repo root so the perf trajectory
accumulates across PRs (``api_version`` 4 == adaptive-horizon engine).

Usage: PYTHONPATH=src python -m benchmarks.perf_benches [--scenarios 8]
       [--ticks 600] [--out BENCH_fabric.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _bench_config(ticks: int):
    from repro.core.lb.schemes import LBScheme
    from repro.network.fabric import SimParams, Workload
    from repro.network.profile import TransportProfile
    from repro.network.topology import leaf_spine

    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=8)
    f = 8
    wl = Workload.of(list(range(f)), [f + i for i in range(f)], 100000)
    prof = TransportProfile.ai_full(lb=LBScheme.REPS)
    p = SimParams(ticks=ticks, timeout_ticks=64, ooo_threshold=24)
    return g, wl, prof, p


def _scenarios(g, wl, b: int):
    """B scenarios: scenario i fails leaf-0 uplink (i mod spines) for odd
    i and uses a distinct LB seed — a failure x seed sweep."""
    from repro.network.fabric import DEFAULT_SEED, Workload

    spines = g.up1_table.shape[1]
    masks = np.zeros((b, g.num_queues), bool)
    seeds = np.zeros((b,), np.uint32)
    for i in range(b):
        seeds[i] = DEFAULT_SEED + i
        if i % 2 == 1:
            masks[i, int(g.up1_table[0, i % spines])] = True
    wls = Workload.stack([wl] * b)
    return wls, masks, seeds


def _seed_style_simulate(g, wl, prof, p, mask, seed):
    """One scenario the way the seed architecture ran it: the failure set
    baked into the executable as a static constant, so this scenario's
    run starts with its own trace+compile (no sharing across the sweep)."""
    import jax
    import jax.numpy as jnp

    from repro.network import fabric

    F = int(wl.src.shape[0])
    step = fabric.make_step(g, prof, p, F)
    dead_const = jnp.asarray(mask)

    def scan_one(s0, wl_):
        def body(s, tick):
            return step(s, tick, wl_, dead_const)
        return jax.lax.scan(body, s0, jnp.arange(p.ticks, dtype=jnp.int32))

    run = jax.jit(scan_one, donate_argnums=(0,))
    s0 = fabric.init_state(g, wl, prof, p, jnp.uint32(seed))
    final, outs = run(s0, wl)
    return fabric._to_result(final, outs, wl.size)


def run_benches(b: int, ticks: int) -> dict:
    import jax

    from repro.network.fabric import simulate, simulate_batch

    g, wl, prof, p = _bench_config(ticks)
    wls, masks, seeds = _scenarios(g, wl, b)
    fq = [tuple(np.nonzero(masks[i])[0].tolist()) for i in range(b)]

    results = {
        "api_version": 4,
        "backend": jax.default_backend(),
        "topology": g.name,
        "flows": int(wl.src.shape[0]),
        "ticks": ticks,
        "scenarios": b,
        "profile": prof.name,
        "profile_spec": prof.describe(),
    }

    # --- single scenario: compile + warm ticks/sec ---
    t0 = time.perf_counter()
    simulate(g, wl, prof, p)
    results["single_cold_s"] = time.perf_counter() - t0
    warm = min(_timed(lambda: simulate(g, wl, prof, p)) for _ in range(5))
    results["single_warm_s"] = warm
    results["ticks_per_sec_single"] = ticks / warm

    # --- seed-style serial sweep: fresh executable per scenario ---
    t0 = time.perf_counter()
    for i in range(b):
        _seed_style_simulate(g, wl, prof, p, masks[i], int(seeds[i]))
    serial_seed = time.perf_counter() - t0
    results["serial_seed_sweep_s"] = serial_seed
    results["scenarios_per_sec_serial"] = b / serial_seed
    results["serial_mode"] = ("per-scenario trace+compile (static failure "
                              "set, the seed architecture)")

    # --- shared-executable serial sweep: the warm serial path ---
    for i in range(2):  # warm
        simulate(g, wl, prof, p, failed=fq[i], seed=int(seeds[i]))
    t0 = time.perf_counter()
    for i in range(b):
        simulate(g, wl, prof, p, failed=fq[i], seed=int(seeds[i]))
    serial_shared = time.perf_counter() - t0
    results["serial_shared_sweep_s"] = serial_shared
    results["scenarios_per_sec_serial_shared"] = b / serial_shared

    # --- batched sweep: one simulate_batch() call ---
    t0 = time.perf_counter()
    simulate_batch(g, wls, prof, p, failed=masks, seeds=seeds)
    batched_cold = time.perf_counter() - t0
    results["batched_cold_s"] = batched_cold
    batched = min(_timed(
        lambda: simulate_batch(g, wls, prof, p, failed=masks, seeds=seeds))
        for _ in range(3))
    results["batched_sweep_s"] = batched
    results["scenarios_per_sec_batched"] = b / batched
    results["ticks_per_sec_batched"] = b * ticks / batched
    # acceptance metric: one batched sweep (incl. its compile) vs the
    # seed architecture's sweep (per-scenario compiles)
    results["batch_speedup_vs_serial"] = serial_seed / batched_cold
    results["batch_speedup_vs_serial_shared_warm"] = serial_shared / batched

    results["profile_ablation"] = _profile_ablation(ticks)
    results["collective_sweep"] = _collective_sweep()
    return results


def _profile_ablation(ticks: int) -> dict:
    """The operating-point grid as ONE simulate_batch call: the three
    named profiles + the CC ablation (7 scenarios, grouped by profile
    into one executable each) on the oversubscribed in-network pattern.

    Asserts the realism property the old incast version silently lacked:
    nscc_only / rccc_only / open_loop must visibly diverge on the
    same-leaf victim flow (blind receiver credits cap it at ~50%; NSCC
    pushes it toward the 1 - uplinks/pairs optimum)."""
    from repro.network import workloads
    from repro.network.fabric import SimParams, simulate_batch

    g, wls, profiles, names, exp = workloads.profile_ablation_sweep()
    p = SimParams(ticks=ticks, timeout_ticks=64)
    window = (ticks // 3, ticks)
    run = lambda: simulate_batch(g, wls, profiles, p,  # noqa: E731
                                 goodput_window=window)
    t0 = time.perf_counter()
    rs = run()
    cold = time.perf_counter() - t0
    warm = min(_timed(run) for _ in range(2))
    v = exp["victim_flow"]
    gp = {name: r.goodput(window) for name, r in zip(names, rs)}
    victim = {name: round(float(x[v]), 4) for name, x in gp.items()}
    # realism gate: if the CC axis reports one number, the sweep is
    # differentiating nothing and the bench is broken
    assert victim["nscc_only"] > victim["open_loop"] + 0.05, victim
    assert victim["open_loop"] > victim["rccc_only"] + 0.05, victim
    assert abs(victim["rccc_only"] - exp["rccc_local_share"]) < 0.08, victim
    return {
        "scenarios": len(profiles),
        "distinct_profiles": len(set(profiles)),
        "sweep_cold_s": cold,
        "sweep_warm_s": warm,
        "scenarios_per_sec": len(profiles) / warm,
        "victim_flow_share": victim,
        "victim_share_optimal": exp["optimal_local_share"],
        "goodput_mean": {
            name: round(float(x.mean()), 4) for name, x in gp.items()
        },
    }


def _collective_sweep(ticks: int = 1600) -> dict:
    """The collective ablation grid — kind x algorithm x INC on/off x
    profile, 15 whole dependency-scheduled collectives — as ONE
    ``simulate_batch`` call on the adaptive-horizon engine: INC on/off
    rides the traced ``red`` lanes, so the grid compiles to just 2
    executables (ai_full / ai_base), run concurrently, and every
    scenario exits at quiescence instead of padding to the 1600-tick
    budget (completions land at 71-542 ticks)."""
    from repro.network import collectives as coll
    from repro.network import workloads
    from repro.network.fabric import SimParams, simulate_batch

    g, wls, profiles, names = workloads.collective_sweep()
    p = SimParams(ticks=ticks)
    t0 = time.perf_counter()
    rs = simulate_batch(g, wls, profiles, p)
    cold = time.perf_counter() - t0
    warm = min(_timed(lambda: simulate_batch(g, wls, profiles, p))
               for _ in range(2))
    cts = {name: coll.collective_completion_ticks(r)
           for name, r in zip(names, rs)}
    inc_red = {name: int(r.state.inc_reduced)
               for name, r in zip(names, rs) if "/inc" in name}

    def ratio(prof):
        off = cts[f"{prof}/all_reduce/tree"]
        on = cts[f"{prof}/all_reduce/tree/inc"]
        return round(on / off, 4) if off > 0 and on > 0 else None

    return {
        "scenarios": len(names),
        "flows_padded": int(wls.src.shape[1]),
        "distinct_profiles": len(set(profiles)),
        "ticks": ticks,
        "horizons": sorted({int(r.horizon) for r in rs}),
        "sweep_cold_s": cold,
        "sweep_warm_s": warm,
        "scenarios_per_sec": len(names) / warm,
        "completion_ticks": cts,
        "inc_reduced_pkts": inc_red,
        "inc_tree_allreduce_ratio": ratio("ai_full"),
        "inc_tree_allreduce_ratio_ai_base": ratio("ai_base"),
    }


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", type=int, default=8)
    ap.add_argument("--ticks", type=int, default=600)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_fabric.json"))
    args = ap.parse_args()

    results = run_benches(args.scenarios, args.ticks)
    results["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")

    print(json.dumps(results, indent=2, sort_keys=True))
    cs = results["collective_sweep"]
    print(f"\nbatched sweep (cold, incl. compile) is "
          f"{results['batch_speedup_vs_serial']:.1f}x the seed-style serial "
          f"sweep; warm-vs-warm against the shared-executable serial loop it "
          f"is {results['batch_speedup_vs_serial_shared_warm']:.2f}x; "
          f"collective grid ran {cs['scenarios']} scenarios at "
          f"{cs['scenarios_per_sec']:.2f}/s, INC tree-all-reduce completion "
          f"ratio {cs['inc_tree_allreduce_ratio']}; wrote {out}")


if __name__ == "__main__":
    main()
