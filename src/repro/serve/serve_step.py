"""Serving: prefill + single-token decode steps.

decode: one new token per sequence against a ring KV cache (full-context
or sliding-window) / SSM state. The long-context (B=1) cells shard the KV
sequence over "data" and use select-based ring writes (see
distributed/sharding.py and models/layers.py).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm


def make_decode_step(cfg: ArchConfig, mesh=None, dp_axes=("data",),
                     select_write: bool = False, moe_token_spec=None,
                     scan_layers: bool = True, attn_head_specs=None,
                     sharded_cache_attn: bool = False):
    """decode_step(params, cache, tokens [B,1], pos []) ->
    (logits [B, vocab], cache')."""
    fwd = lm.build_forward(cfg, mesh=mesh, dp_axes=dp_axes, decode=True,
                           remat=False, select_write=select_write,
                           moe_token_spec=moe_token_spec,
                           scan_layers=scan_layers,
                           attn_head_specs=attn_head_specs,
                           sharded_cache_attn=sharded_cache_attn)

    def decode_step(params, cache, tokens, pos):
        logits, _, new_cache = fwd(params, tokens, cache=cache, pos0=pos)
        return logits[:, -1], new_cache

    return decode_step


def make_prefill(cfg: ArchConfig, mesh=None, dp_axes=("data",),
                 act_spec=None, moe_token_spec=None,
                 scan_layers: bool = True, attn_head_specs=None):
    """prefill(params, tokens [B, S]) -> logits of last position.

    (The dry-run lowers prefill as a pure forward; cache extraction for
    chained decode is exercised in the serving example at small scale.)
    """
    fwd = lm.build_forward(cfg, mesh=mesh, dp_axes=dp_axes, remat=False,
                           act_spec=act_spec, moe_token_spec=moe_token_spec,
                           scan_layers=scan_layers,
                           attn_head_specs=attn_head_specs)

    def prefill(params, tokens):
        logits, _, _ = fwd(params, tokens)
        return logits[:, -1]

    return prefill


def sample_greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def serving_rate(cfg: ArchConfig, *, dp: int = 4, tp: int = 4,
                 layout: str = "tp_only", shape: str = "decode_32k",
                 g=None, profile=None, **step_kw) -> dict:
    """Decode-time serving economics on a simulated UET fabric.

    Derives the parallelism plan for ``cfg`` on a (dp, tp) serving mesh,
    compiles its per-step collective schedule (TP activation all-reduces
    plus the frontend request incast; the fsdp_tp layout additionally
    pays the ZeRO-3 param gather decode penalty), runs it through the
    packet-level simulator and prices tokens/sec served. The network
    term is SIMULATED: topology and transport profile move the number.

    Returns {tokens_per_sec_served, step_s, net_s, eff, ...}.
    """
    from repro.distributed.plan import derive_plan
    from repro.network import traffic

    plan = derive_plan(cfg, shape, dp=dp, tp=tp, layout=layout)
    t = traffic.step_time(plan, g, profile, **step_kw)
    return {
        "arch": cfg.name, "shape": shape, "layout": layout,
        "dp": dp, "tp": tp,
        "tokens_per_sec_served": t.tokens_per_sec,
        "step_s": t.step_s, "net_s": t.net_s,
        "compute_s": t.compute_s, "memory_s": t.memory_s,
        "eff": t.eff, "sim_ticks": t.sim_ticks,
    }
