"""Public jit'd entry points for the kernel package.

``use_pallas=True`` routes to the Pallas kernels (interpret mode on CPU,
compiled on TPU); ``False`` routes to the pure-jnp oracles in ref.py.
The fabric simulator uses the oracles by default on CPU (XLA fuses them
well there); on a TPU deployment the Pallas path is the fast one.
"""
from __future__ import annotations

import jax

from repro.core.cms.nscc import NSCCParams
from repro.kernels import ref
from repro.kernels.ecmp_hash import ecmp_select as _ecmp_pallas
from repro.kernels.nscc_update import nscc_update as _nscc_pallas
from repro.kernels.sack_bitmap import sack_advance as _sack_pallas

_ON_TPU = jax.default_backend() == "tpu"


def nscc_update(cwnd, ecn, rtt, count, params: NSCCParams = NSCCParams(),
                use_pallas: bool = False):
    if use_pallas:
        return _nscc_pallas(cwnd, ecn, rtt, count, params,
                            interpret=not _ON_TPU)
    return ref.nscc_update_ref(cwnd, ecn, rtt, count, params)


def sack_advance(ring, base, use_pallas: bool = False):
    if use_pallas:
        return _sack_pallas(ring, base, interpret=not _ON_TPU)
    return ref.sack_advance_ref(ring, base)


def ecmp_select(src, dst, ev, salt, fanout: int, use_pallas: bool = False):
    if use_pallas:
        return _ecmp_pallas(src, dst, ev, salt, fanout,
                            interpret=not _ON_TPU)
    return ref.ecmp_hash_ref(src, dst, ev, salt, fanout)
