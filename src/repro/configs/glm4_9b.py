"""glm4-9b: RoPE + GQA dense decoder [hf:THUDM/glm-4-9b]."""
from repro.configs.base import ArchConfig, pad_for_tp, MIXER_ATTN, FFN_MLP

CONFIG = pad_for_tp(ArchConfig(
    name="glm4-9b", family="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
    head_dim=128, d_ff=13696, vocab_size=151_552,
    pattern=((MIXER_ATTN, FFN_MLP),),
    source="hf:THUDM/glm-4-9b",
))
