"""Sharded checkpointing with elastic restore (no orbax dependency).

Design for 1000+ node fleets:

* every host writes only its OWN array shards (`save`: one file per host,
  msgpack + zstd), so checkpoint bandwidth scales with the fleet;
* a tiny manifest records the tree structure, global shapes and the mesh
  layout at save time;
* `restore` reshards on load: a checkpoint taken at DP=32 restores onto
  DP=16 or DP=64 (elastic scaling after node loss / growth) — shards are
  reassembled to global arrays host-side and re-sharded to the live mesh;
* `save_async` overlaps the serialization with the next train step
  (compute/IO overlap), with a barrier before the following save;
* atomic rename + `latest` pointer; failed/partial writes never corrupt
  the previous checkpoint (crash-consistent restart).

On this single-process container "per-host" degenerates to one file, but
the format and code paths are the multi-host ones.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import zstandard


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_paths(tree: Any) -> list[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in path))
    return paths


def save(ckpt_dir: str, step: int, tree: Any, process_index: int = 0,
         num_processes: int = 1) -> str:
    """Write one checkpoint. Returns the checkpoint path."""
    tmp = os.path.join(ckpt_dir, f"step_{step:08d}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)

    leaves, _ = _flatten(tree)
    paths = _leaf_paths(tree)
    manifest = {
        "step": step,
        "num_processes": num_processes,
        "leaves": [
            {"path": p, "shape": list(np.shape(l)),
             "dtype": str(np.asarray(jax.device_get(l)).dtype
                          if not isinstance(l, jax.Array)
                          else l.dtype)}
            for p, l in zip(paths, leaves)
        ],
    }

    # each process writes its local shards
    cctx = zstandard.ZstdCompressor(level=3)
    shard_blobs = {}
    for p, leaf in zip(paths, leaves):
        arr = np.asarray(jax.device_get(leaf))
        shard_blobs[p] = {
            "data": cctx.compress(arr.tobytes()),
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, f"shards_{process_index:05d}.msgpack"),
              "wb") as f:
        f.write(msgpack.packb(shard_blobs, use_bin_type=True))
    if process_index == 0:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)

    os.replace(tmp, final)  # atomic publish
    with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(ckpt_dir, "latest.tmp"),
               os.path.join(ckpt_dir, "latest"))
    return final


class AsyncCheckpointer:
    """Overlap checkpoint IO with compute: `save` returns immediately;
    the previous write is joined before a new one starts (and on close)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    try:
        with open(os.path.join(ckpt_dir, "latest")) as f:
            name = f.read().strip()
        return int(name.split("_")[1])
    except (FileNotFoundError, IndexError, ValueError):
        return None


def restore(ckpt_dir: str, step: int, target_tree: Any,
            shardings: Any = None) -> Any:
    """Load a checkpoint into the structure of `target_tree`.

    `shardings`: optional tree of NamedShardings for the LIVE mesh — this
    is the elastic-rescale path: the checkpoint's mesh layout at save time
    is irrelevant, shards reassemble to global arrays and redistribute.
    """
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    dctx = zstandard.ZstdDecompressor()
    blobs: dict[str, dict] = {}
    for fn in sorted(os.listdir(final)):
        if not fn.startswith("shards_"):
            continue
        with open(os.path.join(final, fn), "rb") as f:
            blobs.update(msgpack.unpackb(f.read(), raw=False))

    paths = _leaf_paths(target_tree)
    leaves, treedef = _flatten(target_tree)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for p, ref, sh in zip(paths, leaves, shard_leaves):
        blob = blobs[p]
        arr = np.frombuffer(dctx.decompress(blob["data"]),
                            dtype=np.dtype(blob["dtype"]))
        arr = arr.reshape(blob["shape"])
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
