"""Serving example: batched prefill + greedy decode with ring KV caches.

Uses a reduced mixtral-family config (MoE + sliding-window attention) to
exercise the full serving path: prefill fills the cache, then the decode
step extends it one token per request.

Run: PYTHONPATH=src python examples/serve_decode.py --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import lm
from repro.serve.serve_step import make_decode_step, sample_greedy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.reduced("mixtral-8x22b", seq=64)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key, jnp.float32)

    B, P = args.batch, args.prompt_len
    cache_len = P + args.tokens
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab)

    # prefill: run the prompt through decode steps to warm the cache (a
    # batched single-pass prefill-with-cache-export is the production
    # path; token-at-a-time keeps this example minimal and exercises the
    # ring-slot write P times)
    cache = lm.init_cache(cfg, B, cache_len, jnp.float32)
    dstep = jax.jit(make_decode_step(cfg, mesh=None))
    t0 = time.time()
    logits = None
    for i in range(P):
        logits, cache = dstep(params, cache, prompts[:, i:i + 1],
                              jnp.int32(i))
    print(f"prefill: {P} steps in {time.time()-t0:.2f}s")

    out = []
    tok = sample_greedy(logits)[:, None]
    t0 = time.time()
    for i in range(args.tokens):
        out.append(tok)
        logits, cache = dstep(params, cache, tok, jnp.int32(P + i))
        tok = sample_greedy(logits)[:, None]
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decode: {args.tokens} tokens x {B} requests in {dt:.2f}s "
          f"({args.tokens*B/dt:.1f} tok/s)")
    print("generated ids (request 0):", gen[0].tolist())
    assert bool(jnp.isfinite(logits).all())


if __name__ == "__main__":
    main()
