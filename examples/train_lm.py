"""End-to-end driver: train a GLM4-family LM on the synthetic pipeline
with the fault-tolerant trainer (checkpoint/restart, straggler watch).

Sizes: --size tiny (~4M, CI), small (~25M, default), 100m (~100M params).
A few hundred steps drop the loss well below the unigram entropy.

Run: PYTHONPATH=src python examples/train_lm.py --steps 300 --size small
Restart behaviour: re-running the same command resumes from the latest
checkpoint in --ckpt-dir (delete the dir for a fresh run).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import lm
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig

SIZES = {
    # (layers, d_model, heads, kv, d_ff, vocab)
    "tiny": (2, 128, 4, 2, 256, 2048),
    "small": (4, 384, 8, 2, 1024, 8192),
    "100m": (12, 768, 12, 4, 2048, 16384),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--size", choices=SIZES, default="small")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    L, D, H, KV, F, V = SIZES[args.size]
    cfg = dataclasses.replace(
        configs.get("glm4-9b"), num_layers=L, d_model=D, num_heads=H,
        num_kv_heads=KV, d_ff=F, vocab_size=V, head_dim=D // H,
        pad_heads_to=0, pad_kv_to=0, pad_vocab_to=0, tp_pad=1)
    print(f"model: {cfg.param_count()/1e6:.1f}M params "
          f"({L}L d{D} h{H} ff{F} v{V})")

    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key, jnp.float32)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=50)
    opt = init_opt_state(params, ocfg)
    step = jax.jit(make_train_step(cfg, mesh=None, opt_cfg=ocfg))

    data = SyntheticTokens(DataConfig(
        vocab_size=V, seq_len=args.seq, global_batch=args.batch))

    def data_fn(step_idx):
        b = data.global_batch(step_idx)
        return {"inputs": jnp.asarray(b["inputs"]),
                "labels": jnp.asarray(b["labels"])}

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, log_every=10),
        step, data_fn, params, opt)
    if trainer.try_resume():
        print(f"resumed from checkpoint at step {trainer.state.step}")
    hist = trainer.run()
    first = hist[0]["loss"] if hist else float("nan")
    last = sum(h["loss"] for h in hist[-10:]) / max(len(hist[-10:]), 1)
    print(f"\nloss: first {first:.3f} -> last-10 avg {last:.3f} "
          f"(stragglers flagged: {trainer.state.straggler_steps})")
    assert last < first, "loss did not improve"


if __name__ == "__main__":
    main()
