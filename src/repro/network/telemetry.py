"""Fabric telemetry plane: streamed per-queue/per-flow observability.

UET's congestion management runs on signals the fabric already computes
every tick — egress ECN marks, trim NACKs, per-ACK RTT samples, queue
occupancy — and the engine used to throw them away the moment the tick
consumed them. This module turns the simulator from a scoreboard (final
counters) into an instrument (time-resolved signals) without giving up
any of the scenario engine's contracts:

* :class:`TelemetrySpec` is STATIC — it joins the compile key exactly
  like :class:`~repro.network.profile.TransportProfile`, so a spec picks
  the compiled program. ``TelemetrySpec.off()`` is the default and is
  FREE: off-runs compile the identical program as before telemetry
  existed (the probe lanes are never built — the same gating trick the
  fault engine uses for its ``lossy`` static), which keeps the PR-2
  golden anchors bitwise intact.
* Probe lanes ride the chunked while-scan's streaming stats carry
  (``trace="stats"``): memory is ``O(slots * channels)``, independent of
  the horizon, and the lanes compose with ``simulate_batch``,
  per-profile groups, ``shard_map`` sharding (inert pad lanes) and
  ``FaultSchedule``s bit for bit — a sharded lane's trace equals the
  serial run's.
* Sampling is an ADAPTIVE-DECIMATION ring: a sample is considered every
  ``probe_every`` ticks; when the ring fills, every other sample is
  dropped and the sampling stride doubles, so one fixed-size buffer
  covers ANY horizon at uniform spacing (slot ``i`` always holds the
  sample from tick ``i * stride * probe_every``). The decimation
  decision depends only on (tick, carried count, carried stride), so it
  is invariant to chunk size, batching, sharding and freeze boundaries
  by construction.

Channels (each independently selectable):

* ``queues`` — per-queue occupancy EWMA (+ running peak) and CUMULATIVE
  egress ECN-mark / trim / silent-drop counters, plus the link-layer
  reliability counters (LLR replays fired and CBFC credit stalls per
  queue — all-zero unless the run armed ``link=LinkConfig(...)``).
  Cumulative counters survive decimation losslessly: the rate over any
  window between two surviving samples is exact, not subsampled.
* ``flows``  — per-flow latest RTT sample (from real ACK timestamps)
  and congestion-window samples.
* ``gauges`` — scenario-wide inflight packets, cumulative degraded
  ticks, cumulative delivered packets (per-window goodput).

Host side, :class:`FabricTrace` reconstructs the time series, computes
summaries (p50/p99 occupancy, mark/trim fractions, window rates) and
exports Chrome-trace/Perfetto JSON (``scripts/trace_export.py`` is the
CLI). ``python -m repro.network.telemetry`` runs the health canary used
by ``scripts/check.sh``: a mid-run multi-uplink flap on the victim-share
scenario must be VISIBLE in the probe lanes — trim/drop rates spike
inside ``[fail_at, heal_at)`` and recover after.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

__all__ = ["TelemetrySpec", "FabricTrace", "create", "make_update"]


@dataclass(frozen=True)
class TelemetrySpec:
    """Static probe-channel selection (hashable; part of the compile
    key, like ``TransportProfile``). The default is OFF and costs
    nothing: every existing call site compiles the identical program.

    probe_every: base sampling cadence in ticks. A sample is considered
        at every multiple of ``probe_every``; decimation only ever
        doubles the effective stride.
    slots: ring capacity (must be even, >= 2). When full, occupancy
        halves and the stride doubles — one buffer serves any horizon.
    queues / flows / gauges: channel groups (see module docstring).
        Disabled groups carry width-0 lanes — no memory, no compute.
    ewma_shift: occupancy EWMA smoothing ``alpha = 2**-ewma_shift``.
    """

    enabled: bool = False
    probe_every: int = 16
    slots: int = 64
    queues: bool = True
    flows: bool = True
    gauges: bool = True
    ewma_shift: int = 3

    def __post_init__(self):
        if self.probe_every < 1:
            raise ValueError(f"probe_every must be >= 1, got "
                             f"{self.probe_every}")
        if self.slots < 2 or self.slots % 2:
            raise ValueError(f"slots must be even and >= 2, got "
                             f"{self.slots}")
        if not 0 <= self.ewma_shift <= 16:
            raise ValueError(f"ewma_shift must be in [0, 16], got "
                             f"{self.ewma_shift}")

    @staticmethod
    def off() -> "TelemetrySpec":
        """The free default: no probes, bitwise-identical executables."""
        return TelemetrySpec()

    @staticmethod
    def on(probe_every: int = 16, slots: int = 64, *, queues: bool = True,
           flows: bool = True, gauges: bool = True,
           ewma_shift: int = 3) -> "TelemetrySpec":
        return TelemetrySpec(enabled=True, probe_every=probe_every,
                             slots=slots, queues=queues, flows=flows,
                             gauges=gauges, ewma_shift=ewma_shift)


def create(spec: TelemetrySpec, Q: int, F: int) -> dict:
    """Initial telemetry carry for one scenario (a plain dict pytree —
    it rides inside the streaming stats carry and is broadcast /
    sharded / frozen exactly like the other stat lanes). Disabled
    channel groups get width-0 lanes along the channel axis, so one
    update path serves every spec."""
    S = spec.slots
    Qc = Q if spec.queues else 0
    Fc = F if spec.flows else 0
    Gc = 1 if spec.gauges else 0
    return {
        # ring bookkeeping: sample count, current decimation stride
        # (in units of probe_every), per-slot sample tick (-1 = empty)
        "n": jnp.int32(0),
        "stride": jnp.int32(1),
        "stamp": jnp.full((S,), -1, jnp.int32),
        # every-tick accumulators
        "ewma_q": jnp.zeros((Qc,), jnp.float32),
        "peak_q": jnp.zeros((Qc,), jnp.int32),
        "ecn_q": jnp.zeros((Qc,), jnp.int32),
        "trim_q": jnp.zeros((Qc,), jnp.int32),
        "drop_q": jnp.zeros((Qc,), jnp.int32),
        "llr_q": jnp.zeros((Qc,), jnp.int32),
        "stall_q": jnp.zeros((Qc,), jnp.int32),
        "rtt_f": jnp.zeros((Fc,), jnp.float32),
        # decimated ring lanes (slot i <-> tick i * stride * probe_every)
        "s_occ": jnp.zeros((S, Qc), jnp.float32),
        "s_ecn": jnp.zeros((S, Qc), jnp.int32),
        "s_trim": jnp.zeros((S, Qc), jnp.int32),
        "s_drop": jnp.zeros((S, Qc), jnp.int32),
        "s_llr": jnp.zeros((S, Qc), jnp.int32),
        "s_stall": jnp.zeros((S, Qc), jnp.int32),
        "s_rtt": jnp.zeros((S, Fc), jnp.float32),
        "s_cwnd": jnp.zeros((S, Fc), jnp.float32),
        "s_inflight": jnp.zeros((S, Gc), jnp.int32),
        "s_degraded": jnp.zeros((S, Gc), jnp.int32),
        "s_delivered": jnp.zeros((S, Gc), jnp.int32),
    }


def make_update(spec: TelemetrySpec, Q: int, F: int):
    """Build the per-tick telemetry transition ``update(tel, s, probe,
    tick)`` for one (spec, topology, flow-count) shape. Pure and
    elementwise/gather only — vmap- and shard_map-safe, and bitwise
    deterministic across serial / batched / sharded execution.

    ``probe`` is the per-tick signal dict the step emits when telemetry
    is enabled (see ``fabric.make_step``): per-queue ``mark``/``trim``/
    ``drop`` increments, per-flow ``rtt``/``has_rtt``/``cwnd``.

    Decimation invariant: a sample is taken at tick t iff
    ``t % probe_every == 0`` and ``(t // probe_every) % stride == 0``.
    When the ring holds ``slots`` samples at a sample point, the odd
    slots are dropped (keep ticks ``0, 2*d, 4*d, ...``), occupancy
    halves and the stride doubles — the pending tick is then exactly
    slot ``slots/2`` of the coarser grid (``slots`` even guarantees it
    qualifies), so the ring is always tick-uniform.
    """
    S = spec.slots
    pe = spec.probe_every
    Qc = Q if spec.queues else 0
    Fc = F if spec.flows else 0
    Gc = 1 if spec.gauges else 0
    alpha = jnp.float32(1.0 / (1 << spec.ewma_shift))
    # compaction keeps even slots; the stale upper half is masked on
    # read by slot >= n and overwritten as the ring refills
    comp_idx = jnp.concatenate([jnp.arange(S // 2) * 2,
                                jnp.arange(S // 2, S)]).astype(jnp.int32)
    slot_ids = jnp.arange(S, dtype=jnp.int32)

    def update(tel: dict, s, probe: dict, tick) -> dict:
        # ---- every-tick accumulators (decimation-proof: cumulative) ----
        occ = s.q_len[:Qc].astype(jnp.float32)
        ewma_q = tel["ewma_q"] + alpha * (occ - tel["ewma_q"])
        peak_q = jnp.maximum(tel["peak_q"], s.q_len[:Qc])
        ecn_q = tel["ecn_q"] + probe["mark"][:Qc]
        trim_q = tel["trim_q"] + probe["trim"][:Qc]
        drop_q = tel["drop_q"] + probe["drop"][:Qc]
        llr_q = tel["llr_q"] + probe["llr"][:Qc]
        stall_q = tel["stall_q"] + probe["stall"][:Qc]
        rtt_f = jnp.where(probe["has_rtt"][:Fc], probe["rtt"][:Fc],
                          tel["rtt_f"])

        # ---- sample decision (chunk/batch/shard-invariant) -------------
        k = tick // pe
        aligned = (tick % pe == 0) & (k % tel["stride"] == 0)
        dec = aligned & (tel["n"] >= S)
        n = jnp.where(dec, jnp.int32(S // 2), tel["n"])
        stride = jnp.where(dec, tel["stride"] * 2, tel["stride"])

        def ring(a):
            return jnp.where(dec, a[comp_idx], a)

        hot = (slot_ids == n) & aligned

        def put(a, val):
            h = hot.reshape((S,) + (1,) * (a.ndim - 1))
            return jnp.where(h, val, ring(a))

        out = {
            "n": jnp.where(aligned, n + 1, n),
            "stride": stride,
            "stamp": jnp.where(hot, tick, ring(tel["stamp"])),
            "ewma_q": ewma_q, "peak_q": peak_q,
            "ecn_q": ecn_q, "trim_q": trim_q, "drop_q": drop_q,
            "llr_q": llr_q, "stall_q": stall_q,
            "rtt_f": rtt_f,
            "s_occ": put(tel["s_occ"], ewma_q),
            "s_ecn": put(tel["s_ecn"], ecn_q),
            "s_trim": put(tel["s_trim"], trim_q),
            "s_drop": put(tel["s_drop"], drop_q),
            "s_llr": put(tel["s_llr"], llr_q),
            "s_stall": put(tel["s_stall"], stall_q),
            "s_rtt": put(tel["s_rtt"], rtt_f),
            "s_cwnd": put(tel["s_cwnd"], probe["cwnd"][:Fc]),
            "s_inflight": put(tel["s_inflight"],
                              s.inflight.sum(dtype=jnp.int32)[None][:Gc]),
            "s_degraded": put(tel["s_degraded"],
                              s.ticks_degraded[None][:Gc]),
            "s_delivered": put(tel["s_delivered"],
                               s.delivered.sum(dtype=jnp.int32)[None][:Gc]),
        }
        return out

    return update


# --------------------------------------------------------------------------
# host-side report object
# --------------------------------------------------------------------------

def _col(a: np.ndarray) -> "np.ndarray | None":
    """Squeeze a [n, 0/1] gauge lane to [n], or None when disabled."""
    return a[:, 0] if a.shape[-1] else None


@dataclass(frozen=True)
class FabricTrace:
    """One scenario's reconstructed telemetry time series (host-side,
    plain numpy). Built by ``FabricTrace.from_lanes`` from the device
    probe lanes; attached to ``SimResult.telemetry``.

    ``ticks`` is the surviving sample grid (uniform at ``stride *
    probe_every`` spacing). ``ecn``/``trim``/``drop``/``degraded``/
    ``delivered`` are CUMULATIVE at each sample — window rates between
    any two samples are exact (see :meth:`window_rates`); ``occ`` is the
    occupancy EWMA, ``rtt``/``cwnd`` the latest per-flow samples.
    Channel lanes of disabled groups are empty/None.
    """

    spec: TelemetrySpec
    horizon: int
    ticks: np.ndarray                      # [n] sample ticks
    occ: np.ndarray                        # [n, Qc] occupancy EWMA
    ecn: np.ndarray                        # [n, Qc] cumulative marks
    trim: np.ndarray                       # [n, Qc] cumulative trims
    drop: np.ndarray                       # [n, Qc] cumulative drops
    llr: np.ndarray                        # [n, Qc] cumulative LLR replays
    stall: np.ndarray                      # [n, Qc] cumulative credit stalls
    peak_q: np.ndarray                     # [Qc] running peak occupancy
    rtt: np.ndarray                        # [n, Fc] latest RTT sample
    cwnd: np.ndarray                       # [n, Fc] congestion window
    inflight: "np.ndarray | None"          # [n] packets in flight
    degraded: "np.ndarray | None"          # [n] cumulative degraded ticks
    delivered: "np.ndarray | None"         # [n] cumulative delivered
    stride: int = 1                        # final decimation stride
    final: dict = field(default_factory=dict)  # final accumulator values

    @staticmethod
    def from_lanes(spec: TelemetrySpec, tel: dict,
                   horizon: int) -> "FabricTrace":
        n = int(tel["n"])
        g = {k: np.asarray(tel[k]) for k in tel}
        return FabricTrace(
            spec=spec, horizon=int(horizon),
            ticks=g["stamp"][:n].astype(np.int64),
            occ=g["s_occ"][:n], ecn=g["s_ecn"][:n], trim=g["s_trim"][:n],
            drop=g["s_drop"][:n], llr=g["s_llr"][:n],
            stall=g["s_stall"][:n], peak_q=g["peak_q"],
            rtt=g["s_rtt"][:n], cwnd=g["s_cwnd"][:n],
            inflight=_col(g["s_inflight"][:n]),
            degraded=_col(g["s_degraded"][:n]),
            delivered=_col(g["s_delivered"][:n]),
            stride=int(g["stride"]),
            final={"ecn_q": g["ecn_q"], "trim_q": g["trim_q"],
                   "drop_q": g["drop_q"], "llr_q": g["llr_q"],
                   "stall_q": g["stall_q"], "ewma_q": g["ewma_q"],
                   "rtt_f": g["rtt_f"]},
        )

    @property
    def num_samples(self) -> int:
        return int(self.ticks.shape[0])

    @property
    def sample_spacing(self) -> int:
        """Ticks between surviving samples (stride * probe_every)."""
        return self.stride * self.spec.probe_every

    # ---- windowed rates off the cumulative channels ---------------------
    def _at(self, cum: np.ndarray, t: float) -> np.ndarray:
        """Cumulative channel value at time t: the last sample with
        tick <= t (zeros before the first sample)."""
        j = int(np.searchsorted(self.ticks, t, side="right")) - 1
        return cum[j] if j >= 0 else np.zeros_like(cum[0:1]).reshape(
            cum.shape[1:]) if cum.ndim > 1 else np.zeros((), cum.dtype)

    def window_rates(self, w0: int, w1: int) -> dict:
        """Per-queue mark/trim/drop rates (events per tick) and scenario
        goodput (packets per tick) over [w0, w1), from the cumulative
        channels at the nearest enclosed sample points. Exact between
        samples — decimation never loses a count, only time resolution."""
        if not self.spec.queues:
            raise ValueError("queue channels disabled in this TelemetrySpec")
        dt = float(w1 - w0)
        if dt <= 0:
            raise ValueError(f"empty window [{w0}, {w1})")
        rates = {
            "mark": (self._at(self.ecn, w1 - 1)
                     - self._at(self.ecn, w0 - 1)) / dt,
            "trim": (self._at(self.trim, w1 - 1)
                     - self._at(self.trim, w0 - 1)) / dt,
            "drop": (self._at(self.drop, w1 - 1)
                     - self._at(self.drop, w0 - 1)) / dt,
        }
        if self.delivered is not None:
            rates["goodput"] = float(
                self._at(self.delivered, w1 - 1)
                - self._at(self.delivered, w0 - 1)) / dt
        return rates

    def summary(self) -> dict:
        """Headline health numbers for the run."""
        out: dict = {"horizon": self.horizon,
                     "samples": self.num_samples,
                     "sample_spacing_ticks": self.sample_spacing}
        if self.spec.queues and self.num_samples:
            out.update(
                occ_p50=float(np.percentile(self.occ, 50)),
                occ_p99=float(np.percentile(self.occ, 99)),
                occ_peak=int(self.peak_q.max()) if self.peak_q.size else 0,
                marks_total=int(self.final["ecn_q"].sum()),
                trims_total=int(self.final["trim_q"].sum()),
                drops_total=int(self.final["drop_q"].sum()),
                llr_replays_total=int(self.final["llr_q"].sum()),
                credit_stalls_total=int(self.final["stall_q"].sum()),
                mark_rate=float(self.final["ecn_q"].sum()) / self.horizon,
                trim_rate=float(self.final["trim_q"].sum()) / self.horizon,
                drop_rate=float(self.final["drop_q"].sum()) / self.horizon,
            )
        if self.spec.flows and self.num_samples:
            seen = self.rtt[self.rtt > 0]
            if seen.size:
                out.update(rtt_p50=float(np.percentile(seen, 50)),
                           rtt_p99=float(np.percentile(seen, 99)))
        if self.delivered is not None and self.num_samples:
            out["goodput"] = float(self.delivered[-1]) / max(
                int(self.ticks[-1]), 1)
        return out

    # ---- Chrome-trace / Perfetto export ---------------------------------
    def to_chrome_trace(self, label: str = "fabric") -> list:
        """Chrome-trace counter events (``chrome://tracing`` /
        https://ui.perfetto.dev load the JSON directly). One counter
        track per channel; ``ts`` is the sample tick (microseconds in
        the viewer — one tick rendered as 1us)."""
        ev = []

        def counter(name, ts, args, pid=0):
            ev.append({"ph": "C", "name": name, "pid": pid, "tid": 0,
                       "ts": int(ts), "args": args})

        ts_prev = None
        for i, t in enumerate(self.ticks):
            if self.spec.queues:
                counter(f"{label}.occ_ewma", t,
                        {f"q{q}": float(self.occ[i, q])
                         for q in range(self.occ.shape[1])})
                prev = (np.zeros_like(self.ecn[0]) if ts_prev is None
                        else None)
                dt = float(t - (self.ticks[i - 1] if i else -1))
                for ch, lane in (("mark", self.ecn), ("trim", self.trim),
                                 ("drop", self.drop), ("llr", self.llr),
                                 ("stall", self.stall)):
                    base = lane[i - 1] if i else np.zeros_like(lane[0])
                    counter(f"{label}.{ch}_rate", t,
                            {f"q{q}": float((lane[i, q] - base[q]) / dt)
                             for q in range(lane.shape[1])})
            if self.spec.flows:
                counter(f"{label}.rtt", t,
                        {f"f{fl}": float(self.rtt[i, fl])
                         for fl in range(self.rtt.shape[1])})
                counter(f"{label}.cwnd", t,
                        {f"f{fl}": float(self.cwnd[i, fl])
                         for fl in range(self.cwnd.shape[1])})
            if self.inflight is not None:
                counter(f"{label}.inflight", t,
                        {"pkts": int(self.inflight[i])})
            ts_prev = t
        return ev

    def save_chrome_trace(self, path: str, label: str = "fabric") -> str:
        """Write ``{"traceEvents": [...]}`` JSON to ``path``."""
        with open(path, "w") as f:
            json.dump({"traceEvents": self.to_chrome_trace(label),
                       "displayTimeUnit": "ms"}, f)
        return path


# --------------------------------------------------------------------------
# health canary (scripts/check.sh)
# --------------------------------------------------------------------------

def flap_victim_scenario(fail_at: int = 1000, heal_at: int = 1800):
    """The canonical telemetry scenario: the victim-share pattern
    (``workloads.victim_sweep``) with 3 of leaf-0's 4 uplinks flapping
    over [fail_at, heal_at) — the 12 cross-leaf flows pile onto the one
    surviving uplink, so occupancy/mark/trim/drop probes must spike
    inside the window and recover after. Shared by the check.sh canary,
    the ``fabric_health`` bench block, the export CLI and the tests.

    Returns (g, wl, profile, params, sched, spec, (fail_at, heal_at)).
    """
    from repro.core.lb.schemes import LBScheme
    from repro.network import workloads
    # import the spec class through the canonical module path: under
    # ``python -m repro.network.telemetry`` this file is also loaded as
    # __main__, and fabric's isinstance check needs the real class
    from repro.network import telemetry
    from repro.network.fabric import SimParams
    from repro.network.faults import FaultSchedule
    from repro.network.profile import TransportProfile

    g, wl, exp = workloads.victim_sweep()
    ups = exp["uplinks"]
    sched = FaultSchedule.healthy(g.num_queues).flap(
        list(ups[:-1]), fail_at, heal_at)
    prof = TransportProfile.ai_full(lb=LBScheme.REPS)
    p = SimParams(ticks=3000, timeout_ticks=64, ooo_threshold=24)
    return (g, wl, prof, p, sched,
            telemetry.TelemetrySpec.on(probe_every=16),
            (fail_at, heal_at))


def outage_visibility(trace: FabricTrace, fail_at: int,
                      heal_at: int, budget: int) -> dict:
    """Rate comparison around an outage window: pre-fault steady state,
    in-window, the heal-boundary burst, and post-recovery.

    What a REAL transport's probes show (and what the ``fabric_health``
    bench asserts) is richer than "trims spike in the window": inside
    the window the dead links eat packets SILENTLY (drop rate spikes,
    confined to [fail_at, heal_at) bit-exactly) while NSCC sees the
    shrinking ACK stream and backs off — so mark/trim rates CRATER, not
    spike, and goodput dips. The trim/mark spike lands at the HEAL
    boundary, when every flow's retransmit backlog floods back into the
    restored capacity at once. Each of those four signatures is an
    is-the-outage-visible check on a different probe channel."""
    pad = (heal_at - fail_at) // 4
    pre = trace.window_rates(fail_at // 2, fail_at)
    dur = trace.window_rates(fail_at + pad, heal_at)
    burst = trace.window_rates(heal_at, min(heal_at + 2 * pad, budget))
    post = trace.window_rates(min(heal_at + 2 * pad, budget), budget)
    s = lambda r, ch: float(r[ch].sum())  # noqa: E731
    return {
        "pre": pre, "during": dur, "burst": burst, "post": post,
        "drop_pre": s(pre, "drop"), "drop_during": s(dur, "drop"),
        "drop_post": s(post, "drop"),
        "mark_pre": s(pre, "mark"), "mark_during": s(dur, "mark"),
        "trim_pre": s(pre, "trim"), "trim_burst": s(burst, "trim"),
        "goodput_pre": pre["goodput"], "goodput_during": dur["goodput"],
        "goodput_post": post["goodput"],
    }


def assert_outage_visible(vis: dict) -> None:
    """The four-signature visibility gate shared by the canary and the
    ``fabric_health`` bench (see :func:`outage_visibility`)."""
    # 1. silent drops: confined to the fault window, bit-exactly — dead
    #    links are the ONLY silent-drop source in this scenario
    assert vis["drop_pre"] == 0.0 and vis["drop_post"] == 0.0, vis
    assert vis["drop_during"] > 0.1, vis
    # 2. goodput: dips during the outage, climbs back after (full
    #    reconvergence takes thousands of ticks past heal — the gate is
    #    the direction, well clear of both the dip and noise)
    assert vis["goodput_during"] < 0.75 * vis["goodput_pre"], vis
    assert vis["goodput_post"] > 1.3 * vis["goodput_during"], vis
    assert vis["goodput_post"] > 0.65 * vis["goodput_pre"], vis
    # 3. CC response: NSCC backs off on the vanishing ACK stream, so the
    #    in-window mark rate falls visibly below the pre-fault baseline
    assert vis["mark_during"] < 0.75 * vis["mark_pre"], vis
    # 4. heal burst: the backlog flush trims hard right after heal_at,
    #    far above the (near-zero) pre-fault trim rate
    assert vis["trim_burst"] > vis["trim_pre"] + 1.0, vis


def _smoke() -> int:  # pragma: no cover — CLI canary for scripts/check.sh
    """Telemetry canary: the flap window must be VISIBLE in the probe
    lanes — silent-drop rate spikes inside [fail_at, heal_at) and is
    zero outside, goodput dips and recovers, the CC throttle and the
    heal-boundary trim burst both register. Also asserts the probes
    never perturb: the telemetry-on run's final state is bitwise the
    telemetry-off run's."""
    import jax

    from repro.network.fabric import simulate

    g, wl, prof, p, sched, spec, (fail_at, heal_at) = flap_victim_scenario()
    r_on = simulate(g, wl, prof, p, faults=sched, telemetry=spec)
    r_off = simulate(g, wl, prof, p, faults=sched)
    eq = jax.tree_util.tree_map(
        lambda a, b: bool(np.array_equal(np.asarray(a), np.asarray(b))),
        r_on.state, r_off.state)
    assert all(jax.tree_util.tree_leaves(eq)), \
        "telemetry must not perturb the simulation"

    tr = r_on.telemetry
    vis = outage_visibility(tr, fail_at, heal_at, p.ticks)
    assert_outage_visible(vis)
    s = tr.summary()
    print(f"telemetry canary ok: {tr.num_samples} samples at spacing "
          f"{tr.sample_spacing} ticks; window [{fail_at}, {heal_at}): "
          f"drops 0 -> {vis['drop_during']:.2f}/tick -> 0, goodput "
          f"{vis['goodput_pre']:.2f} -> {vis['goodput_during']:.2f} -> "
          f"{vis['goodput_post']:.2f} pkts/tick, heal trim burst "
          f"{vis['trim_burst']:.2f}/tick; occ p50/p99 {s['occ_p50']:.1f}/"
          f"{s['occ_p99']:.1f}, rtt p99 {s.get('rtt_p99', 0):.0f}")
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(_smoke())
