"""UET transport core: semantics (addressing, matching, messaging),
packet delivery (PDC, PSN/SACK), congestion management (cms/), load
balancing (lb/), security (tss), link layer (link)."""
