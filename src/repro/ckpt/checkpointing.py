"""Sharded checkpointing with elastic restore (no orbax dependency).

Design for 1000+ node fleets:

* every host writes only its OWN array shards (`save`: one file per host,
  msgpack + zstd), so checkpoint bandwidth scales with the fleet;
* a tiny manifest records the tree structure, global shapes and the mesh
  layout at save time;
* `restore` reshards on load: a checkpoint taken at DP=32 restores onto
  DP=16 or DP=64 (elastic scaling after node loss / growth) — shards are
  reassembled to global arrays host-side and re-sharded to the live mesh;
* `save_async` overlaps the serialization with the next train step
  (compute/IO overlap), with a barrier before the following save;
* atomic rename + `latest` pointer; failed/partial writes never corrupt
  the previous checkpoint (crash-consistent restart).

On this single-process container "per-host" degenerates to one file, but
the format and code paths are the multi-host ones.

The module also carries the checkpoint-restart ECONOMICS used by the
endpoint-failure recovery loop (:func:`young_daly_interval`,
:func:`availability`, :func:`effective_rate`): the
`repro.network.traffic` recovery-pricing path measures detection /
restore / replan costs and these closed forms price effective
throughput over an MTBF x checkpoint-interval grid.
"""
from __future__ import annotations

import json
import math
import os
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

try:  # IO deps gated: the economics functions above need neither
    import msgpack
    import zstandard
except ImportError:  # pragma: no cover — slim containers
    msgpack = None
    zstandard = None


def _require_io():
    if msgpack is None or zstandard is None:
        raise ImportError("checkpoint IO needs msgpack + zstandard; only "
                          "the Young/Daly economics work without them")


# ---------------------------------------------------------------------------
# checkpoint-restart economics (Young/Daly)
#
# The pricing side of the endpoint-failure recovery loop (DESIGN.md
# "Endpoint failure & recovery contract"): given a failure rate (MTBF), a
# checkpoint write cost, and the measured recovery costs — detection time
# from the fabric's PDC-teardown signal, elastic restore, replan — what
# fraction of wall time is NEW forward progress, and what checkpoint
# interval maximizes it?
# ---------------------------------------------------------------------------

def young_daly_interval(mtbf_s: float, write_s: float) -> float:
    """Young/Daly first-order optimal checkpoint interval:
    ``tau* = sqrt(2 * write_s * mtbf_s)``.

    Within :func:`availability`'s overhead model this tau is EXACTLY the
    argmax (d/dtau of ``write/tau + tau/(2*MTBF)`` vanishes there), so
    any fixed interval != tau* prices strictly worse — the inequality
    the resilience bench asserts."""
    if mtbf_s <= 0:
        raise ValueError(f"mtbf_s must be > 0, got {mtbf_s}")
    if write_s < 0:
        raise ValueError(f"write_s must be >= 0, got {write_s}")
    return math.sqrt(2.0 * write_s * mtbf_s)


def availability(interval_s: float, mtbf_s: float, *, write_s: float,
                 detect_s: float = 0.0, restore_s: float = 0.0,
                 replan_s: float = 0.0) -> float:
    """Fraction of wall time spent on new forward progress under
    periodic checkpointing with exponential failures of rate 1/MTBF:

    * every interval pays one checkpoint write (``write_s / interval_s``
      of the time);
    * every failure pays detection (the fabric's fault -> PDC-teardown
      latency), checkpoint restore, collective replan, and on average
      half an interval of lost work (``interval_s / 2``).

    ``availability = 1 / (1 + write/tau + (tau/2 + D + R + P) / MTBF)``

    Strictly increasing in MTBF and strictly unimodal in ``interval_s``
    with its maximum at :func:`young_daly_interval`."""
    if interval_s <= 0:
        raise ValueError(f"interval_s must be > 0, got {interval_s}")
    if mtbf_s <= 0:
        raise ValueError(f"mtbf_s must be > 0, got {mtbf_s}")
    for name, v in (("write_s", write_s), ("detect_s", detect_s),
                    ("restore_s", restore_s), ("replan_s", replan_s)):
        if v < 0:
            raise ValueError(f"{name} must be >= 0, got {v}")
    per_failure = detect_s + restore_s + replan_s + interval_s / 2.0
    overhead = write_s / interval_s + per_failure / mtbf_s
    return 1.0 / (1.0 + overhead)


def effective_rate(healthy_rate: float, interval_s: float, mtbf_s: float,
                   **costs) -> float:
    """Throughput after the checkpoint-restart tax: e.g. effective
    tokens/sec = healthy tokens/sec x :func:`availability`."""
    return healthy_rate * availability(interval_s, mtbf_s, **costs)


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_paths(tree: Any) -> list[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in path))
    return paths


def save(ckpt_dir: str, step: int, tree: Any, process_index: int = 0,
         num_processes: int = 1) -> str:
    """Write one checkpoint. Returns the checkpoint path."""
    _require_io()
    tmp = os.path.join(ckpt_dir, f"step_{step:08d}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)

    leaves, _ = _flatten(tree)
    paths = _leaf_paths(tree)
    manifest = {
        "step": step,
        "num_processes": num_processes,
        "leaves": [
            {"path": p, "shape": list(np.shape(l)),
             "dtype": str(np.asarray(jax.device_get(l)).dtype
                          if not isinstance(l, jax.Array)
                          else l.dtype)}
            for p, l in zip(paths, leaves)
        ],
    }

    # each process writes its local shards
    cctx = zstandard.ZstdCompressor(level=3)
    shard_blobs = {}
    for p, leaf in zip(paths, leaves):
        arr = np.asarray(jax.device_get(leaf))
        shard_blobs[p] = {
            "data": cctx.compress(arr.tobytes()),
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, f"shards_{process_index:05d}.msgpack"),
              "wb") as f:
        f.write(msgpack.packb(shard_blobs, use_bin_type=True))
    if process_index == 0:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)

    os.replace(tmp, final)  # atomic publish
    with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(ckpt_dir, "latest.tmp"),
               os.path.join(ckpt_dir, "latest"))
    return final


class AsyncCheckpointer:
    """Overlap checkpoint IO with compute: `save` returns immediately;
    the previous write is joined before a new one starts (and on close)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    try:
        with open(os.path.join(ckpt_dir, "latest")) as f:
            name = f.read().strip()
        return int(name.split("_")[1])
    except (FileNotFoundError, IndexError, ValueError):
        return None


def restore(ckpt_dir: str, step: int, target_tree: Any,
            shardings: Any = None) -> Any:
    """Load a checkpoint into the structure of `target_tree`.

    `shardings`: optional tree of NamedShardings for the LIVE mesh — this
    is the elastic-rescale path: the checkpoint's mesh layout at save time
    is irrelevant, shards reassemble to global arrays and redistribute.
    """
    _require_io()
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    dctx = zstandard.ZstdDecompressor()
    blobs: dict[str, dict] = {}
    for fn in sorted(os.listdir(final)):
        if not fn.startswith("shards_"):
            continue
        with open(os.path.join(final, fn), "rb") as f:
            blobs.update(msgpack.unpackb(f.read(), raw=False))

    paths = _leaf_paths(target_tree)
    leaves, treedef = _flatten(target_tree)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for p, ref, sh in zip(paths, leaves, shard_leaves):
        blob = blobs[p]
        arr = np.frombuffer(dctx.decompress(blob["data"]),
                            dtype=np.dtype(blob["dtype"]))
        arr = arr.reshape(blob["shape"])
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
