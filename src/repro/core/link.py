"""Link-layer options: Link-Level Retry and Credit-Based Flow Control
(Sec. 3.5).

LLR: go-back-N retransmission confined to one link. Justified at this
layer (unlike end-to-end, which UET redesigned away from go-back-N)
because the link RTT is ~1 us, bounded, and congestion plays no role —
only PHY corruption drops. Modeled as a replay-buffer state machine whose
invariants (no loss escapes the link; buffer bounded by link BDP) are
tested in tests/test_link_tss.py.

CBFC: 20-bit cyclic credit counters at sender and receiver per virtual
channel, periodically synchronized. Compared against PFC headroom:
PFC needs RTT+MTU headroom per (port, priority) to be lossless; CBFC
needs only the actual receive buffer it advertises (Sec. 3.5.2 claims
(1)-(4); `pfc_headroom_bytes` / `cbfc_buffer_bytes` quantify claim (1)).
"""
from __future__ import annotations

from dataclasses import dataclass, replace


CTR_BITS = 20
CTR_MOD = 1 << CTR_BITS


# ---------------------------------------------------------------------------
# LLR — go-back-N on one link
# ---------------------------------------------------------------------------


@dataclass
class LLRLink:
    """One LLR-enabled link direction (host-side model, event-driven)."""

    replay_capacity: int = 64
    timeout: int = 8               # ~link RTT in frame times
    # state
    next_seq: int = 0              # next new frame sequence
    send_base: int = 0             # oldest unacked
    now: int = 0
    last_progress: int = 0
    retransmissions: int = 0

    def in_flight(self) -> int:
        return self.next_seq - self.send_base

    def can_send(self) -> bool:
        return self.in_flight() < self.replay_capacity

    def send(self) -> int:
        assert self.can_send()
        seq = self.next_seq
        self.next_seq += 1
        return seq

    def on_ack(self, seq: int):
        """Cumulative ACK frees the replay buffer up to seq."""
        if seq >= self.send_base:
            self.send_base = seq + 1
            self.last_progress = self.now

    def on_nack(self, seq: int) -> list[int]:
        """Receiver saw a gap: go-back-N from `seq`. A duplicate or
        late NACK (seq below the cumulative-ACK base) is stale — the
        frames it names are already freed from the replay buffer, so
        replay starts at `send_base`, never before it."""
        seq = max(seq, self.send_base)
        self.retransmissions += self.next_seq - seq
        resend = list(range(seq, self.next_seq))
        return resend

    def tick(self) -> list[int]:
        """Timeout guard for tail loss: resend everything outstanding."""
        self.now += 1
        if (self.in_flight() > 0
                and self.now - self.last_progress > self.timeout):
            self.last_progress = self.now
            self.retransmissions += self.in_flight()
            return list(range(self.send_base, self.next_seq))
        return []


def llr_deliver(frames_sent: list[int], corrupt: set[int],
                expected: int = 0) -> list[int]:
    """Receiver view: frames arrive in order; corrupted ones are dropped
    and NACK'd by the first out-of-order arrival. `expected` carries the
    receiver's next-in-order sequence across retransmission rounds."""
    delivered = []
    for f in frames_sent:
        if f in corrupt:
            continue
        if f == expected:
            delivered.append(f)
            expected += 1
    return delivered


# ---------------------------------------------------------------------------
# CBFC — credit counters per virtual channel
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CBFCState:
    """20-bit cyclic counters (Sec. 3.5.2): sender tracks consumed,
    receiver tracks freed; available = buffer - (consumed - freed)."""

    buffer_bytes: int
    consumed: int = 0   # sender-side, mod 2^20 (units: cells/bytes)
    freed: int = 0      # receiver-side, mod 2^20

    def available(self) -> int:
        return self.buffer_bytes - ((self.consumed - self.freed) % CTR_MOD)

    def can_send(self, size: int) -> bool:
        return self.available() >= size

    def send(self, size: int) -> "CBFCState":
        assert self.can_send(size), "CBFC never oversends"
        return replace(self, consumed=(self.consumed + size) % CTR_MOD)

    def drain(self, size: int) -> "CBFCState":
        """Receiver forwards a packet out of its buffer -> credit update
        message back to the sender."""
        return replace(self, freed=(self.freed + size) % CTR_MOD)


def pfc_headroom_bytes(link_gbps: float, cable_m: float, mtu: int,
                       priorities: int = 8) -> float:
    """Lossless PFC headroom per port: in-flight bytes during the pause
    round trip (2x propagation + 2x MTU serialization + response time),
    per priority class."""
    c = 2e8  # m/s in fiber
    rtt_s = 2 * cable_m / c
    inflight = link_gbps * 1e9 / 8 * rtt_s
    return priorities * (inflight + 2 * mtu)


def cbfc_buffer_bytes(link_gbps: float, cable_m: float, mtu: int,
                      active_vcs: int = 2) -> float:
    """CBFC needs one link-BDP of credited buffer to keep the pipe full —
    and only for the VCs actually in use (claims (1) and (4))."""
    c = 2e8
    rtt_s = 2 * cable_m / c
    bdp = link_gbps * 1e9 / 8 * rtt_s
    return active_vcs * (bdp + mtu)


# ---------------------------------------------------------------------------
# LinkConfig — the traced-engine gating spec (repro.network.fabric)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LinkConfig:
    """Link-layer reliability spec for the batched tick engine — a
    compile-key STATIC joining ``fabric._cache_key`` the way
    ``TelemetrySpec`` does: ``None`` / ``LinkConfig.off()`` normalize
    out of the key, so reliability-off runs compile the exact
    pre-feature program (golden-locked bitwise).

    ``llr`` arms per-queue go-back-N replay confined to the hop: a
    PHY-corrupted head-of-line frame holds its queue for ``llr_rtt``
    ticks (the link-NACK turnaround plus the go-back-N replay of the
    in-flight window, ~1 us on a real link) and is then retransmitted —
    delivery is DELAYED by replay, never dropped, and nothing downstream
    or end-to-end sees the loss. Replay occupancy is implicitly bounded
    by ``llr_rtt`` frames (the hop serves one frame per tick), the
    traced analogue of :class:`LLRLink`'s ``replay_capacity``.

    ``cbfc`` arms the per-queue credit gate at enqueue: 20-bit cyclic
    consumed/freed counters (:class:`CBFCState` semantics) with a
    ``credit_return_ticks`` update latency. Credit exhaustion
    back-pressures the sender — the upstream hop holds its head frame
    and injectors stall — instead of overflowing the buffer, so a
    CBFC-on fabric never trims for lack of credited space.
    """

    llr: bool = False
    llr_rtt: int = 8                # link NACK turnaround + replay, ticks
    cbfc: bool = False
    credit_return_ticks: int = 4    # credit-update message latency, ticks

    def __post_init__(self):
        if self.llr_rtt < 1:
            raise ValueError(f"llr_rtt must be >= 1, got {self.llr_rtt}")
        if self.credit_return_ticks < 1:
            raise ValueError("credit_return_ticks must be >= 1, got "
                             f"{self.credit_return_ticks}")

    @property
    def enabled(self) -> bool:
        return self.llr or self.cbfc

    @classmethod
    def off(cls) -> "LinkConfig":
        return cls()

    @classmethod
    def on(cls, llr: bool = True, cbfc: bool = False, **kw) -> "LinkConfig":
        return cls(llr=llr, cbfc=cbfc, **kw)


def fabric_buffer_pricing(num_queues: int, link_gbps: float = 400.0,
                          cable_m: float = 100.0, mtu: int = 4096) -> dict:
    """Price the lossless-fabric buffer bill both ways for a topology:
    PFC's per-(port, priority) RTT+MTU headroom vs the buffer CBFC
    actually advertises (Sec. 3.5.2 claim (1)). One fabric queue is one
    link direction in the simulator, so `num_queues` is the port count
    the bill scales with."""
    pfc = pfc_headroom_bytes(link_gbps, cable_m, mtu)
    cbfc = cbfc_buffer_bytes(link_gbps, cable_m, mtu)
    return {
        "num_queues": num_queues,
        "link_gbps": link_gbps,
        "cable_m": cable_m,
        "mtu": mtu,
        "pfc_headroom_bytes_per_port": pfc,
        "cbfc_buffer_bytes_per_port": cbfc,
        "pfc_total_bytes": pfc * num_queues,
        "cbfc_total_bytes": cbfc * num_queues,
        "cbfc_over_pfc": cbfc / pfc,
    }


LINK_STATE_LANES = frozenset({
    "llr_busy_until", "llr_replays", "cbfc_consumed", "cbfc_freed",
    "cbfc_ret", "credit_stall_ticks"})
"""SimState lanes owned by the link layer — the only fields whose
SHAPES differ between a ``link=``-armed executable and the pre-feature
program. Bitwise on-vs-off comparisons (canary, bench, tests) skip
exactly this set."""


def state_bitwise_equal(a, b, skip=LINK_STATE_LANES) -> "str | None":
    """Field-by-field bitwise compare of two SimStates, skipping `skip`.
    Returns the first drifted field name, or None when bitwise equal."""
    import jax
    import numpy as np
    from dataclasses import fields

    for f in fields(a):
        if f.name in skip:
            continue
        eq = jax.tree_util.tree_map(
            lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
            getattr(a, f.name), getattr(b, f.name))
        if not all(jax.tree_util.tree_leaves(eq)):
            return f.name
    return None


def _smoke() -> None:
    """check.sh link-layer canary: corruption confinement on the traced
    engine (an LLR-armed BER-y fabric delivers every flow with ZERO
    end-to-end drops, while the LLR-off twin leaks the corruption into
    end-to-end recovery) and the CBFC-beats-PFC buffer claim. Runs the
    shared ``workloads.corruption_sweep`` grid at two BER points —
    lane 0 (BER=0) is the bitwise-inertness anchor."""
    # import through the canonical module path: under ``python -m
    # repro.core.link`` this file is also loaded as __main__, and
    # fabric's isinstance check needs the real LinkConfig class
    from repro.core import link as linkmod
    from repro.network import workloads
    from repro.network.fabric import simulate_batch

    g, wls, scheds, exp = workloads.corruption_sweep(bers=(0.0, 0.03))
    prof, p, link = exp["profile"], exp["params"], exp["link"]
    on = simulate_batch(g, wls, prof, p, faults=scheds, link=link)
    off = simulate_batch(g, wls, prof, p, faults=scheds)

    r_llr, r_e2e = on[1], off[1]
    ct_llr, ct_e2e = r_llr.completion_tick(), r_e2e.completion_tick()
    assert int(r_llr.drops) == 0, \
        f"LLR must confine corruption to the hop, saw {int(r_llr.drops)} drops"
    assert r_llr.llr_replays > 0, "the BER lane must actually corrupt"
    assert ct_llr > 0, "every flow must complete under LLR"
    assert int(r_e2e.drops) > 0, "LLR-off must leak corruption end-to-end"
    e2e_str = str(ct_e2e) if ct_e2e > 0 else f"DNF@{p.ticks}"
    ct_e2e_eff = ct_e2e if ct_e2e > 0 else p.ticks
    assert ct_llr < ct_e2e_eff, (ct_llr, ct_e2e)
    print(f"link canary: LLR confined {r_llr.llr_replays} corrupted "
          f"frames (0 e2e drops), completion {ct_llr} vs e2e-only {e2e_str} "
          f"({int(r_e2e.drops)} silent drops, {int(r_e2e.timeouts)} RTOs)")

    # the clean-link inertness half of the contract: BER=0 + LLR armed
    # must be bitwise the plain run
    drift = linkmod.state_bitwise_equal(on[0].state, off[0].state)
    assert drift is None, f"clean-link LLR run drifted: {drift}"
    print("link canary: clean-link LLR-on run is bitwise the LLR-off run")

    # Sec. 3.5.2 claim (1): CBFC is lossless on the buffer it advertises;
    # PFC needs RTT+MTU headroom per (port, priority) on top
    pricing = fabric_buffer_pricing(g.num_queues)
    assert pricing["cbfc_total_bytes"] < pricing["pfc_total_bytes"] / 2, \
        pricing
    print(f"link canary: {g.name} lossless buffer bill "
          f"CBFC {pricing['cbfc_total_bytes'] / 1e6:.2f} MB vs "
          f"PFC {pricing['pfc_total_bytes'] / 1e6:.2f} MB "
          f"({pricing['cbfc_over_pfc']:.2f}x per port)")


if __name__ == "__main__":
    _smoke()
