#!/usr/bin/env python
"""Append one run's headline bench metrics to ``BENCH_history.jsonl``.

``BENCH_fabric.json`` is a snapshot — it is overwritten by every
``python -m benchmarks.perf_benches`` run, so until now the perf
TRAJECTORY across PRs lived only in prose (ROADMAP/CHANGES). This
script distills the snapshot into one compact JSONL record and appends
it, so regressions and wins are greppable across the whole history:

    PYTHONPATH=src python -m benchmarks.perf_benches   # writes snapshot
    python scripts/bench_history.py                    # appends record
    python scripts/bench_history.py --dry-run          # print, no write

Each record carries the run timestamp, api_version, backend, the
headline throughput metrics (ticks/sec single + batched, scenarios/sec,
the sweep blocks' scenarios/sec), the calibration reference that makes
cross-machine numbers comparable, and — api_version >= 8 — the
``fabric_health`` telemetry overhead ratio, — api_version >= 9 —
the resilience grid's scenarios/sec plus its 1h-MTBF Young/Daly
availability headline, and — api_version >= 10 — the corruption grid's
scenarios/sec plus the worst-BER LLR-vs-e2e recovery ratio. Missing
blocks are simply omitted, so records from any bench version coexist
in one file.
"""
import argparse
import datetime
import json
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

#: (record key, path into BENCH_fabric.json)
HEADLINE = (
    ("api_version", ("api_version",)),
    ("backend", ("backend",)),
    ("ticks_per_sec_single", ("ticks_per_sec_single",)),
    ("ticks_per_sec_batched", ("ticks_per_sec_batched",)),
    ("ticks_per_sec_batched_fastpath", ("ticks_per_sec_batched_fastpath",)),
    ("scenarios_per_sec_batched", ("scenarios_per_sec_batched",)),
    ("calibration_ticks_per_sec", ("calibration", "ticks_per_sec")),
    ("fastpath_vs_fixed_scan", ("fastpath_vs_fixed_scan",)),
    ("collective_scenarios_per_sec",
     ("collective_sweep", "scenarios_per_sec")),
    ("fault_scenarios_per_sec", ("fault_sweep", "scenarios_per_sec")),
    ("model_scenarios_per_sec", ("model_sweep", "scenarios_per_sec")),
    ("resilience_scenarios_per_sec",
     ("resilience_sweep", "scenarios_per_sec")),
    ("availability_mtbf_3600", ("resilience_sweep",
                                "availability_mtbf_3600")),
    ("profile_scenarios_per_sec",
     ("profile_ablation", "scenarios_per_sec")),
    ("shard_speedup", ("sharded_sweep", "shard_speedup")),
    ("shard_devices", ("sharded_sweep", "devices")),
    ("telemetry_overhead", ("fabric_health", "telemetry_overhead")),
    ("fabric_health_warm_s", ("fabric_health", "telemetry_on_warm_s")),
    ("corruption_scenarios_per_sec",
     ("corruption_sweep", "scenarios_per_sec")),
    ("llr_vs_e2e_recovery", ("corruption_sweep", "llr_vs_e2e_recovery")),
)


def distill(bench: dict, timestamp: "str | None" = None) -> dict:
    rec = {"timestamp": timestamp
           or datetime.datetime.now(datetime.timezone.utc).isoformat(
               timespec="seconds")}
    for key, path in HEADLINE:
        node = bench
        for p in path:
            if not isinstance(node, dict) or p not in node:
                node = None
                break
            node = node[p]
        if node is not None:
            rec[key] = round(node, 4) if isinstance(node, float) else node
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", default=str(REPO / "BENCH_fabric.json"),
                    help="snapshot to distill (default: BENCH_fabric.json)")
    ap.add_argument("--history", default=str(REPO / "BENCH_history.jsonl"),
                    help="JSONL file to append to "
                         "(default: BENCH_history.jsonl)")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the record without appending")
    args = ap.parse_args(argv)

    with open(args.bench) as f:
        bench = json.load(f)
    rec = distill(bench)
    line = json.dumps(rec, sort_keys=True)
    if args.dry_run:
        print(line)
        return 0
    with open(args.history, "a") as f:
        f.write(line + "\n")
    n = sum(1 for _ in open(args.history))
    print(f"appended record #{n} to {args.history} "
          f"(api {rec.get('api_version')}, "
          f"{rec.get('ticks_per_sec_batched', 0):.0f} ticks/sec batched)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
