"""Gradient compression with error feedback (beyond-paper optimization).

int8 block-quantized gradient exchange: before the data-parallel
all-reduce, gradients are quantized to int8 with a per-block f32 scale
(block = last dim tile of 256), and the quantization error is carried to
the next step (error feedback keeps SGD/Adam convergence — Karimireddy et
al.). This cuts the dominant DP all-reduce bytes 4x (bf16 -> int8+scales),
directly shrinking the roofline's collective term for the all-reduce-bound
architectures; the fabric-level view is fewer packets through the UET
transport for the same step.

Used by train_step when `compress_grads=True`; exact-allclose invariants
are property-tested in tests/test_compression.py.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x: jax.Array):
    n = x.size
    pad = (-n) % BLOCK
    flat = jnp.pad(x.reshape(-1), (0, pad))
    return flat.reshape(-1, BLOCK), n


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """g (any shape, float) -> (int8 blocks [N, BLOCK], scales [N] f32)."""
    blocks, _ = _pad_to_block(g.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)[:, None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_tree(grads: Any, error: Any | None):
    """Quantize a gradient pytree, adding carried error feedback.

    Returns (quantized tree of (q, scale), new_error tree).
    """
    if error is None:
        error = jax.tree_util.tree_map(jnp.zeros_like, grads)

    def one(g, e):
        corrected = g + e.astype(g.dtype)
        q, s = quantize(corrected)
        deq = dequantize(q, s, g.shape, g.dtype)
        return (q, s), (corrected - deq).astype(g.dtype)

    pairs = jax.tree_util.tree_map(one, grads, error)
    leaves, treedef = jax.tree_util.tree_flatten(
        pairs, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)
    qs = [l[0] for l in leaves]
    errs = [l[1] for l in leaves]
    return (jax.tree_util.tree_unflatten(treedef, qs),
            jax.tree_util.tree_unflatten(treedef, errs))


def decompress_tree(qtree: Any, ref: Any) -> Any:
    """Inverse of compress_tree against reference shapes/dtypes."""
    return jax.tree_util.tree_map(
        lambda qs, r: dequantize(qs[0], qs[1], r.shape, r.dtype),
        qtree, ref,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)


def compressed_bytes(tree: Any) -> int:
    """Wire bytes after compression (int8 payload + f32 scales)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        n = leaf.size
        blocks = -(-n // BLOCK)
        total += blocks * BLOCK + blocks * 4
    return total
