#!/usr/bin/env python
"""Perf-regression gate: diff a fresh BENCH_fabric.json against the
committed one.

Guarded metrics (throughput — higher is better):

* ``ticks_per_sec_batched``
* ``scenarios_per_sec_batched``
* ``collective_sweep.scenarios_per_sec``
* ``fault_sweep.scenarios_per_sec``
* ``model_sweep.scenarios_per_sec`` (api_version >= 7; skipped when the
  committed baseline predates it)
* ``resilience_sweep.scenarios_per_sec`` (api_version >= 9; the
  endpoint-fault grid, host-fault lanes riding the scenario axis)
* ``corruption_sweep.scenarios_per_sec`` (api_version >= 10; the BER
  grid's LLR-armed arm — also gates the link-layer off-gating contract,
  since every OTHER guarded block runs with ``link=None``)

All guarded throughput blocks run with telemetry OFF — the off spec is
normalized to the pre-telemetry compile key, so these numbers also gate
the telemetry plane's zero-cost-when-off contract (api_version >= 8; a
regression here means the off-gating broke). The telemetry-ON price is
reported separately as ``fabric_health.telemetry_overhead`` in the
snapshot and tracked in ``BENCH_history.jsonl`` via
``scripts/bench_history.py``, not gated here.

A metric that drops more than ``--threshold`` (default 20%) below the
committed value is a regression: the script prints the table and exits
2. ``scripts/check.sh`` wires this in as a SOFT gate — it warns and
flags the output but does not fail the smoke run, because wall-clock
benches on shared/loaded machines are advisory; CI or a reviewer reads
the flag.

Box-drift calibration: when both jsons carry the ``calibration`` block
(api_version >= 5; a fixed tiny scenario re-measured on every box),
every regression ratio is divided by the calibration ratio
(fresh / committed) before judging. A box that is uniformly 30% slower
shifts the metrics and the calibration together, so the normalized
ratios stay ~1.0 — cross-box noise stops masquerading as engine
regressions (the PR-4 27.2k->17.2k confusion). Disable with
``--no-calibrate`` to judge raw wall-clock.

KNOWN LIMITATION: the calibration scenario runs the same engine code it
guards, so a change that slows EVERY tick uniformly (a per-tick tax in
``make_step`` itself) shifts the calibration too and normalizes itself
away. The script therefore prints a loud warning whenever the
calibration itself moved beyond the threshold — on the same box that
can only be an engine-wide per-tick change (or heavy load), and the raw
columns must be read by hand (or rerun with ``--no-calibrate``).

Usage:
    python scripts/bench_compare.py --fresh /tmp/BENCH_fresh.json
    python scripts/bench_compare.py --run          # regenerate first (slow)
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
COMMITTED = os.path.join(REPO, "BENCH_fabric.json")

#: (label, path into the bench dict)
METRICS = (
    ("ticks_per_sec_batched", ("ticks_per_sec_batched",)),
    ("scenarios_per_sec_batched", ("scenarios_per_sec_batched",)),
    ("collective_sweep.scenarios_per_sec",
     ("collective_sweep", "scenarios_per_sec")),
    ("fault_sweep.scenarios_per_sec",
     ("fault_sweep", "scenarios_per_sec")),
    ("model_sweep.scenarios_per_sec",
     ("model_sweep", "scenarios_per_sec")),
    ("resilience_sweep.scenarios_per_sec",
     ("resilience_sweep", "scenarios_per_sec")),
    ("corruption_sweep.scenarios_per_sec",
     ("corruption_sweep", "scenarios_per_sec")),
)


def _get(d: dict, path):
    for k in path:
        d = d[k]
    return float(d)


def calibration_scale(committed: dict, fresh: dict) -> "float | None":
    """fresh/committed ratio of the fixed calibration scenario — the
    box-speed factor every throughput ratio is normalized by. None when
    either json predates the calibration block (api_version < 5)."""
    try:
        base = _get(committed, ("calibration", "ticks_per_sec"))
        new = _get(fresh, ("calibration", "ticks_per_sec"))
    except (KeyError, TypeError):
        return None
    if base <= 0 or new <= 0:
        return None
    return new / base


def compare(committed: dict, fresh: dict, threshold: float,
            scale: "float | None" = None):
    """Returns (ok, rows); rows are (label, base, new, norm_ratio,
    regressed). `scale` is the calibration box-speed factor (None =
    judge raw ratios); the regression verdict uses ratio / scale."""
    rows, ok = [], True
    for label, path in METRICS:
        try:
            base = _get(committed, path)
        except (KeyError, TypeError):
            rows.append((label, None, None, None, False))
            continue
        new = _get(fresh, path)  # a fresh bench missing a metric IS a bug
        ratio = new / base if base > 0 else float("inf")
        if scale:
            ratio = ratio / scale
        regressed = ratio < 1.0 - threshold
        ok = ok and not regressed
        rows.append((label, base, new, ratio, regressed))
    return ok, rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--committed", default=COMMITTED,
                    help="baseline bench json (default: repo root)")
    ap.add_argument("--fresh", default=None,
                    help="freshly generated bench json to judge")
    ap.add_argument("--run", action="store_true",
                    help="regenerate a fresh bench first (slow: runs "
                         "benchmarks.perf_benches)")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="allowed fractional drop (default 0.20)")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="judge raw wall-clock ratios (skip the box-drift "
                         "calibration normalization)")
    args = ap.parse_args()

    if args.run:
        fd, args.fresh = tempfile.mkstemp(prefix="BENCH_fresh_",
                                          suffix=".json")
        os.close(fd)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src") + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        subprocess.run(
            [sys.executable, "-m", "benchmarks.perf_benches",
             "--out", args.fresh],
            cwd=REPO, env=env, check=True, stdout=subprocess.DEVNULL)
    if not args.fresh:
        ap.error("give --fresh PATH or --run")

    with open(args.committed) as f:
        committed = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    scale = (None if args.no_calibrate
             else calibration_scale(committed, fresh))
    ok, rows = compare(committed, fresh, args.threshold, scale)

    if scale is None:
        print("calibration: unavailable — judging raw wall-clock ratios")
    else:
        print(f"calibration: this box measures {scale * 100:.1f}% of the "
              f"baseline box (ratios normalized by it)")
        if abs(scale - 1.0) > args.threshold:
            print(f"CALIBRATION-SHIFT: the calibration scenario itself "
                  f"moved {scale * 100:.1f}% — on the same box this means "
                  f"an engine-wide per-tick change (or heavy load), which "
                  f"normalization CANNOT distinguish from box drift; read "
                  f"the raw columns or rerun with --no-calibrate")
    width = max(len(r[0]) for r in rows)
    for label, base, new, ratio, regressed in rows:
        if base is None:
            print(f"{label:<{width}}  (missing in committed baseline — "
                  f"skipped)")
            continue
        flag = "REGRESSION" if regressed else "ok"
        norm = "" if scale is None else " normalized"
        print(f"{label:<{width}}  {base:12.2f} -> {new:12.2f}  "
              f"({ratio * 100:6.1f}%{norm})  {flag}")
    if not ok:
        print(f"\nPERF REGRESSION: a guarded metric dropped >"
              f"{args.threshold * 100:.0f}% vs {args.committed}"
              + ("" if scale is None else " (box-drift normalized)"))
        return 2
    print("\nperf gate ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
