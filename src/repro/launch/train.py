"""Production training launcher: ``--arch <id>`` selects any of the 10
assigned architectures; the same entry point drives the real mesh on a
TPU fleet and a reduced config on this CPU container.

    PYTHONPATH=src python -m repro.launch.train --arch rwkv6-3b \
        --steps 50 --reduced            # CPU-sized smoke run
    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b  # fleet

On a fleet (jax.device_count() >= 256) the production mesh and the 2-D
FSDP x TP sharding rules are used; otherwise a host mesh + reduced config
keeps the identical code path (sharded train_step, shard_map MoE,
fault-tolerant trainer, async checkpoints) runnable anywhere.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import lm
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b", choices=configs.ARCH_NAMES)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    args = ap.parse_args()

    fleet = jax.device_count() >= 256
    if fleet:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = configs.get(args.arch)
    else:
        mesh = make_host_mesh()
        cfg = configs.reduced(args.arch, seq=args.seq)
    dp = shd.dp_axes(mesh)

    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key,
                            jnp.bfloat16 if fleet else jnp.float32)
    pspecs = shd.param_pspecs(params, mesh)
    psh = shd.named(mesh, pspecs)
    params = jax.device_put(params, psh)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=20)
    opt = init_opt_state(params, ocfg)
    osh = {"m": psh, "v": psh,
           "step": NamedSharding(mesh, P())}
    opt = jax.device_put(opt, osh)

    step = make_train_step(
        cfg, mesh=mesh, dp_axes=dp, opt_cfg=ocfg,
        act_spec=NamedSharding(mesh, shd.activation_pspec(cfg, mesh)),
        attn_head_specs=shd.attn_head_specs(cfg, mesh),
        loss_spec=NamedSharding(
            mesh, P(dp if len(dp) > 1 else dp[0], None, None)))
    jstep = jax.jit(step, in_shardings=(psh, osh, None),
                    out_shardings=(psh, osh, None), donate_argnums=(0, 1))

    data = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab, seq_len=args.seq, global_batch=args.batch))

    def data_fn(i):
        b = data.global_batch(i)
        return {"inputs": jnp.asarray(b["inputs"]),
                "labels": jnp.asarray(b["labels"])}

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_every=max(20, args.steps
                                                             // 3),
                      ckpt_dir=args.ckpt_dir, log_every=10),
        jstep, data_fn, params, opt,
        param_shardings=psh, opt_shardings=osh)
    if trainer.try_resume():
        print(f"resumed at step {trainer.state.step}")
    hist = trainer.run()
    losses = [h["loss"] for h in hist]
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-5:]):.3f}")


if __name__ == "__main__":
    main()
