"""Receiver Credit-based Congestion Control (Sec. 3.3.2).

Unlike NSCC, the sender does not interpret network signals: it spends
credits granted by the *receiver*, which knows the exact number of
incoming flows and divides its ingress capacity among them. This makes
incast handling exact (each of F incoming flows gets 1/F of the line rate,
Fig. 7 group 4) but is blind to in-network congestion and outcast — the
scenarios that motivate running NSCC alongside (Sec. 3.3.3).

Receiver side (`grant_credits`): once per tick, each destination splits its
ingress line rate `rate * dfc` evenly across its currently-active incoming
flows. Demand-aware weighting is supported via `demand` ("RCCC can also
consider the sources' demands").

Sender side: a flow may inject a packet when `balance >= 1`; injection
deducts one credit. Optimistic start: balances begin at the BDP so flows
start at full rate, exactly as the spec prescribes.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class RCCCState:
    """SoA over F flows.

    balance: [F] float32 — credits available to spend at the sender (pkts)
    seen:    [F] bool    — receiver has observed this flow (first packet
                            arrived); credits flow only afterwards
    """

    balance: jax.Array
    seen: jax.Array

    @staticmethod
    def create(f: int, initial_credit: float) -> "RCCCState":
        return RCCCState(
            balance=jnp.full((f,), initial_credit, jnp.float32),
            seen=jnp.zeros((f,), jnp.bool_),
        )


def grant_credits(state: RCCCState, flow_dst: jax.Array, active: jax.Array,
                  num_hosts: int, rate: float = 1.0,
                  dfc: jax.Array | None = None,
                  demand: jax.Array | None = None) -> RCCCState:
    """One receiver scheduling round.

    flow_dst: [F] int32 destination host of each flow
    active:   [F] bool  flow still has data to move and has been seen
    dfc:      [H] float32 per-destination rate scale (Destination Flow
              Control, Sec. 3.3.4) — e.g. 0.5 when destination memory can
              only absorb half rate
    demand:   [F] float32 optional source demand weights

    Each destination h grants `rate * dfc[h]` credits split across its
    active incoming flows proportionally to demand (default: evenly).
    """
    act = active & state.seen
    w = jnp.where(act, 1.0, 0.0) if demand is None else jnp.where(act, demand, 0.0)
    # sum of weights per destination
    per_dst = jnp.zeros((num_hosts,), jnp.float32).at[flow_dst].add(w)
    share = jnp.where(per_dst[flow_dst] > 0, w / jnp.maximum(per_dst[flow_dst], 1e-9), 0.0)
    scale = rate if dfc is None else rate * dfc[flow_dst]
    grant = share * scale
    return replace(state, balance=state.balance + grant)


@jax.tree_util.register_static
@dataclass(frozen=True)
class RCCCPolicy:
    """RCCC as a pluggable CC policy for the fabric engine (protocol in
    `repro.network.profile`).

    ``initial_credit`` is the optimistic-start balance (~BDP, so flows
    start at full rate). ``report_cwnd`` is what the engine's per-tick
    "cwnd" stat lane shows for this policy: RCCC has no window, so it
    reports the static cap — matching what the pre-refactor engine
    reported for rccc-only runs. The credit *balance* is the live signal
    and stays inspectable in the final state.
    """

    initial_credit: float
    report_cwnd: float

    def create(self, f: int) -> RCCCState:
        return RCCCState.create(f, self.initial_credit)

    def on_ack(self, st, has_ack, ecn, rtt):
        return st  # receiver-driven: network signals are ignored

    def on_nack(self, st, count):
        return st

    def on_grant_tick(self, st: RCCCState, flow_dst: jax.Array,
                      active: jax.Array, num_hosts: int) -> RCCCState:
        return grant_credits(st, flow_dst, active, num_hosts)

    def on_send_gate(self, st: RCCCState, inflight: jax.Array) -> jax.Array:
        return (inflight < jnp.int32(int(self.report_cwnd))) & can_send(st)

    def on_inject(self, st: RCCCState, injected: jax.Array) -> RCCCState:
        return replace(st, balance=st.balance - injected.astype(jnp.float32))

    def on_rx_seen(self, st: RCCCState, seen: jax.Array) -> RCCCState:
        return replace(st, seen=st.seen | seen)

    def on_timeout(self, st, stalled):
        return st

    def end_of_tick(self, st, tick):
        return st

    def cwnd_view(self, st: RCCCState, f: int) -> jax.Array:
        return jnp.full((f,), self.report_cwnd, jnp.float32)


def mark_seen(state: RCCCState, flow: jax.Array, valid: jax.Array) -> RCCCState:
    """Receiver observed first packet(s) of flow(s): credits start flowing."""
    f = state.seen.shape[0]
    drop = jnp.where(valid, flow, f)
    return replace(state, seen=state.seen.at[drop].set(True, mode="drop"))


def can_send(state: RCCCState) -> jax.Array:
    """[F] bool: flow holds at least one packet credit."""
    return state.balance >= 1.0


def spend(state: RCCCState, flow: jax.Array, valid: jax.Array) -> RCCCState:
    """Deduct one credit per injected packet."""
    f = state.balance.shape[0]
    drop = jnp.where(valid, flow, f)
    return replace(state, balance=state.balance.at[drop].add(
        jnp.where(valid, -1.0, 0.0), mode="drop"))
