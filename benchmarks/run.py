"""Benchmark harness: one entry per paper table/figure.

Prints ``bench,name,value,expected,us_per_call,note`` CSV. Heavier
simulator benches report their wall time; value==expected (within printed
tolerance) reproduces the corresponding paper claim.

Usage: PYTHONPATH=src python -m benchmarks.run [--only substr]

``--only headers`` and ``--only collectives`` are the two fast
import/consistency canaries scripts/check.sh runs pre-commit (the
latter exercises the dependency-scheduled collective engine + INC).
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks.paper_benches import ALL_BENCHES

    print("bench,name,value,expected,us_per_call,note")
    failures = 0
    for bench_name, fn in ALL_BENCHES:
        if args.only and args.only not in bench_name:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # pragma: no cover
            print(f"{bench_name},ERROR,{e!r},,,", flush=True)
            failures += 1
            continue
        us = (time.time() - t0) * 1e6 / max(len(rows), 1)
        for name, value, expected, note in rows:
            exp = "" if expected is None else expected
            print(f"{bench_name},{name},{value},{exp},{us:.0f},{note}",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
