"""Vectorized packet-level fabric simulator — the UET reproduction engine.

One simulator tick == the serialization time of one MTU packet on one link.
Every link is a FIFO queue; each tick every queue dequeues at most one
packet (line rate) and forwards it one hop. All protocol state — PSN
bitmaps, congestion windows, credit balances, EV recycle rings — is
structure-of-arrays, and a tick is a pure function stepped by
``jax.lax.scan`` under ``jit``. This is the TPU-native re-architecture of
the paper's protocol: what a hardware UET NIC does per packet, the
simulator does per *vector of flows* per tick.

Modeled faithfully (paper sections in parens):

* ECMP spraying with per-packet EVs through a real Clos topology (2.1)
* egress ECN marking above a queue threshold (3.3.1)
* packet trimming on overflow -> fast NACK to the source (3.2.4)
* RUD selective-repeat with a source retransmit bitmap; ROD go-back-N on a
  single static path (3.2.1)
* receiver PSN tracking with SACK rings + MP_RANGE rejection (3.2.5)
* NSCC 4-case window control + Quick Adapt; RCCC receiver credits; both
  composable, as the spec prescribes (3.3)
* LB schemes: static / oblivious / RR-slots / REPS / EV-bitmap (3.3.5)
* OOO-count and EV-based loss inference, timeout fallback (3.2.4)
* control traffic (ACKs, NACKs, credits) rides the second traffic class,
  modeled as a fixed-latency uncongested return path (3.1.4)

Simplifications recorded in DESIGN.md: RCCC credit grants apply without
path delay (the grant *rate* is what the algorithm controls); trimmed
headers travel on the control TC (elevated priority per the spec).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pds
from repro.core.cms import nscc as nscc_mod
from repro.core.cms.rccc import RCCCState, grant_credits
from repro.core.lb.schemes import LBScheme, LBState, select_ev, on_ack as lb_on_ack
from repro.core.types import TransportMode
from repro.network.ecmp import DELIVERED, RoutingTables
from repro.network.topology import QueueGraph, Stage

# packet meta bits
META_TRIMMED = 1
META_ECN = 2

# event types
EV_NONE, EV_ACK, EV_NACK, EV_OOO = 0, 1, 2, 3


@dataclass(frozen=True)
class SimParams:
    """Static simulation parameters (hashable; closed over by jit)."""

    ticks: int = 2000
    queue_capacity: int = 64
    ecn_threshold: int = 12
    trimming: bool = True
    mode: TransportMode = TransportMode.RUD
    lb: LBScheme = LBScheme.OBLIVIOUS
    #: queue ids whose link is DOWN: packets routed into them are silently
    #: dropped (Configuration drops, Sec. 3.2.4) — the failure-mitigation
    #: scenario for REPS (dead-path EVs never return and leave circulation)
    failed_queues: tuple = ()
    nscc: bool = True
    rccc: bool = False
    dfc: bool = False
    ack_return_ticks: int = 4
    mp_range: int = 512           # receiver tracking window (PSNs)
    ev_slots: int = 16            # K for RR/REPS/EVBITMAP
    timeout_ticks: int = 256
    ooo_threshold: int = 0        # 0 = disabled
    max_cwnd: float = 48.0        # ~BDP in packets (optimistic start)
    base_rtt: float = 10.0        # unloaded RTT in ticks, for NSCC


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class Workload:
    """Static flow set: src/dst host ids, message size (packets), start."""

    src: jax.Array   # [F] int32
    dst: jax.Array   # [F] int32
    size: jax.Array  # [F] int32
    start: jax.Array  # [F] int32

    @staticmethod
    def of(src, dst, size, start=None) -> "Workload":
        src = jnp.asarray(src, jnp.int32)
        f = src.shape[0]
        return Workload(
            src=src, dst=jnp.asarray(dst, jnp.int32),
            size=jnp.asarray(size, jnp.int32) * jnp.ones((f,), jnp.int32),
            start=(jnp.zeros((f,), jnp.int32) if start is None
                   else jnp.asarray(start, jnp.int32)),
        )


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class SimState:
    """The lax.scan carry: the entire fabric + protocol state."""

    # queues (SoA ring buffers)
    q_flow: jax.Array   # [Q, C] int32, -1 empty
    q_psn: jax.Array    # [Q, C] int32
    q_ev: jax.Array     # [Q, C] int32
    q_meta: jax.Array   # [Q, C] int32
    q_tsent: jax.Array  # [Q, C] int32
    q_head: jax.Array   # [Q] int32
    q_len: jax.Array    # [Q] int32
    # sender state
    next_psn: jax.Array     # [F] int32
    inflight: jax.Array     # [F] int32
    src_track: pds.PSNTracker  # ACK tracking at the source (base = CACK)
    rtx: jax.Array          # [F, W] uint32 retransmit bitmap (rel. to base)
    last_progress: jax.Array  # [F] int32
    slot_last_ack: jax.Array  # [F, K] int32, EV-based loss detection
    # receiver state
    dst_track: pds.PSNTracker
    last_ooo_nack: jax.Array  # [F] int32
    # congestion control + LB
    nscc: nscc_mod.NSCCState
    rccc: RCCCState
    lb: LBState
    # control-TC delay ring
    ev_type: jax.Array   # [D, E] int32
    ev_flow: jax.Array   # [D, E] int32
    ev_psn: jax.Array    # [D, E] int32
    ev_val: jax.Array    # [D, E] int32 (EV of the packet)
    ev_ecn: jax.Array    # [D, E] int32 (ECN bit seen)
    ev_tsent: jax.Array  # [D, E] int32
    # stats
    delivered: jax.Array  # [F] int32 packets delivered (first copies)
    trims: jax.Array      # [] int32
    drops: jax.Array      # [] int32
    dups: jax.Array       # [] int32
    retransmits: jax.Array  # [] int32


def _first_set_bit(ring: jax.Array) -> jax.Array:
    """Per-row index of the lowest set bit of a [N, W] uint32 ring, or -1."""
    nz = ring != 0
    has = nz.any(axis=1)
    W = ring.shape[1]
    first_w = jnp.argmax(nz, axis=1)
    w = ring[jnp.arange(ring.shape[0]), first_w]
    lsb = w & (jnp.uint32(0) - w)
    ctz = pds._popcount32(lsb - jnp.uint32(1))
    return jnp.where(has, first_w * 32 + ctz, -1).astype(jnp.int32)


def _clear_bit(ring: jax.Array, row: jax.Array, off: jax.Array,
               valid: jax.Array) -> jax.Array:
    safe = jnp.where(valid, row, ring.shape[0])
    word = jnp.clip(off, 0, ring.shape[1] * 32 - 1) // 32
    bit = jnp.uint32(1) << (jnp.clip(off, 0, ring.shape[1] * 32 - 1) % 32).astype(jnp.uint32)
    cur = ring[jnp.where(valid, row, 0), word]
    return ring.at[safe, word].set(cur & ~bit, mode="drop")


def _set_bits(ring: jax.Array, row: jax.Array, off: jax.Array,
              valid: jax.Array) -> jax.Array:
    """OR-scatter bits (duplicate-safe, like pds.record_rx)."""
    N, W = ring.shape
    ok = valid & (off >= 0) & (off < W * 32)
    word = jnp.clip(off, 0, W * 32 - 1) // 32
    bitpos = jnp.clip(off, 0, W * 32 - 1) % 32
    plane = jnp.zeros((N, W, 32), jnp.bool_)
    plane = plane.at[jnp.where(ok, row, N), word, bitpos].set(True, mode="drop")
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    packed = (plane.astype(jnp.uint32) * weights[None, None, :]).sum(
        axis=-1, dtype=jnp.uint32)
    return ring | packed


def init_state(g: QueueGraph, wl: Workload, p: SimParams) -> SimState:
    Q, C = g.num_queues, p.queue_capacity
    F = wl.src.shape[0]
    D = p.ack_return_ticks + 1
    E = 2 * Q + 2 * F
    W = p.mp_range // 32
    nparams = nscc_mod.NSCCParams(base_rtt=p.base_rtt, max_cwnd=p.max_cwnd)
    return SimState(
        q_flow=jnp.full((Q, C), -1, jnp.int32),
        q_psn=jnp.zeros((Q, C), jnp.int32),
        q_ev=jnp.zeros((Q, C), jnp.int32),
        q_meta=jnp.zeros((Q, C), jnp.int32),
        q_tsent=jnp.zeros((Q, C), jnp.int32),
        q_head=jnp.zeros((Q,), jnp.int32),
        q_len=jnp.zeros((Q,), jnp.int32),
        next_psn=jnp.zeros((F,), jnp.int32),
        inflight=jnp.zeros((F,), jnp.int32),
        src_track=pds.PSNTracker.create(F, p.mp_range),
        rtx=jnp.zeros((F, W), jnp.uint32),
        last_progress=jnp.zeros((F,), jnp.int32),
        slot_last_ack=jnp.full((F, p.ev_slots), -1, jnp.int32),
        dst_track=pds.PSNTracker.create(F, p.mp_range),
        last_ooo_nack=jnp.full((F,), -10**6, jnp.int32),
        nscc=nscc_mod.NSCCState.create(F, nparams),
        rccc=RCCCState.create(F, p.max_cwnd),
        lb=LBState.create(F, p.ev_slots),
        ev_type=jnp.zeros((D, E), jnp.int32),
        ev_flow=jnp.zeros((D, E), jnp.int32),
        ev_psn=jnp.zeros((D, E), jnp.int32),
        ev_val=jnp.zeros((D, E), jnp.int32),
        ev_ecn=jnp.zeros((D, E), jnp.int32),
        ev_tsent=jnp.zeros((D, E), jnp.int32),
        delivered=jnp.zeros((F,), jnp.int32),
        trims=jnp.int32(0), drops=jnp.int32(0), dups=jnp.int32(0),
        retransmits=jnp.int32(0),
    )


def _rank_within(target: jax.Array, valid: jax.Array, n_targets: int,
                 base: jax.Array) -> tuple[jax.Array, jax.Array]:
    """For candidate lanes with target queue ids, compute each lane's
    arrival rank within its target and the resulting queue position.

    Returns (pos, order_key) where pos[i] = base[target[i]] + rank.
    """
    n = target.shape[0]
    t = jnp.where(valid, target, n_targets)  # invalid -> sentinel bucket
    order = jnp.argsort(t, stable=True)
    t_sorted = t[order]
    idx = jnp.arange(n)
    seg_start = jnp.concatenate(
        [jnp.array([0]), jnp.cumsum((t_sorted[1:] != t_sorted[:-1]))])
    # first index of each segment
    is_first = jnp.concatenate(
        [jnp.array([True]), t_sorted[1:] != t_sorted[:-1]])
    first_idx = jnp.where(is_first, idx, 0)
    first_idx = jax.lax.associative_scan(jnp.maximum, first_idx)
    rank_sorted = idx - first_idx
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    pos = base[jnp.where(valid, target, 0)] + rank
    return pos, rank


def make_step(g: QueueGraph, wl: Workload, p: SimParams):
    """Build the jitted per-tick transition function."""
    rt = RoutingTables(g)
    F = int(wl.src.shape[0])
    Q = g.num_queues
    C = p.queue_capacity
    D = p.ack_return_ticks + 1
    E = 2 * Q + 2 * F
    H = g.num_hosts
    K = p.ev_slots
    nparams = nscc_mod.NSCCParams(base_rtt=p.base_rtt, max_cwnd=p.max_cwnd)
    lb_scheme = LBScheme.STATIC if p.mode == TransportMode.ROD else p.lb
    is_rod = p.mode == TransportMode.ROD
    is_rudi = p.mode == TransportMode.RUDI
    host_q = jnp.asarray(g.host_queue)

    flow_src = wl.src
    flow_dst = wl.dst

    def step(s: SimState, tick: jax.Array):
        slot = tick % D

        # ------------------------------------------------ 1. control events
        et = s.ev_type[slot]
        ef = s.ev_flow[slot]
        ep = s.ev_psn[slot]
        ee = s.ev_val[slot]
        ec = s.ev_ecn[slot]
        ets = s.ev_tsent[slot]
        is_ack = et == EV_ACK
        is_nack = (et == EV_NACK) | (et == EV_OOO)

        # ACKs: record at source, retire inflight, CC + LB feedback
        src_track, fresh_ack = pds.record_rx(
            s.src_track, ef, ep.astype(jnp.uint32), is_ack)
        src_track, adv = pds.advance_cack(src_track)
        retire = jnp.zeros((F,), jnp.int32).at[
            jnp.where(is_ack | is_nack, ef, F)].add(1, mode="drop")
        inflight = jnp.maximum(s.inflight - retire, 0)
        rtt = (tick - ets).astype(jnp.float32)
        nst = nscc_mod.on_acks(s.nscc, nparams, ef, ec.astype(jnp.bool_),
                               rtt, is_ack) if p.nscc else s.nscc
        nst = nscc_mod.on_loss(nst, ef, jnp.ones_like(ef), is_nack) \
            if p.nscc else nst
        lbs = lb_on_ack(s.lb, lb_scheme, ef, ee,
                        ec.astype(jnp.bool_) | is_nack, is_ack | is_nack)

        # progress clock: any ACK freshens the flow
        last_progress = s.last_progress.at[
            jnp.where(is_ack, ef, F)].set(tick, mode="drop")

        # ACK'd PSNs can't be pending retransmit anymore
        rtx = s.rtx
        ack_off = ep - src_track.base[jnp.where(is_ack, ef, 0)].astype(jnp.int32)
        rtx = _clear_bit(rtx, ef, ack_off,
                         is_ack & (ack_off >= 0) & (ack_off < rtx.shape[1] * 32))
        # base advanced -> shift retransmit bitmap in lockstep
        rtx = pds.shift_ring(rtx, adv)

        # NACKs (trim / OOO): mark PSN for selective retransmit (RUD);
        # ROD does go-back-N instead (handled at injection via next_psn).
        nack_off = ep - src_track.base[jnp.where(is_nack, ef, 0)].astype(jnp.int32)
        if not is_rod:
            rtx = _set_bits(rtx, ef, nack_off, is_nack)
        rod_gbn = jnp.zeros((F,), jnp.bool_).at[
            jnp.where(is_nack, ef, F)].set(True, mode="drop")

        # EV-based loss detection (Sec. 3.2.4), RR_SLOTS layout:
        # slot i carries PSNs i, i+K, i+2K...; an ACK for PSN x implies
        # every unacked PSN x-K, x-2K... in the same slot was lost.
        slot_last_ack = s.slot_last_ack
        if p.lb == LBScheme.RR_SLOTS and not is_rod:
            sl = ep % K
            prev = slot_last_ack[jnp.where(is_ack, ef, 0), jnp.where(is_ack, sl, 0)]
            # mark up to 2 predecessors (losses per ACK are almost always <=1)
            for back in (1, 2):
                miss = ep - back * K
                off = miss - src_track.base[jnp.where(is_ack, ef, 0)].astype(jnp.int32)
                # skip PSNs already SACKed at the source (not actually lost)
                w_i = jnp.clip(off, 0, rtx.shape[1] * 32 - 1)
                sacked = (src_track.ring[jnp.where(is_ack, ef, 0), w_i // 32]
                          >> (w_i % 32).astype(jnp.uint32)) & jnp.uint32(1)
                lost = is_ack & (miss > prev) & (miss >= 0) & (sacked == 0)
                rtx = _set_bits(rtx, ef, off, lost & (off >= 0))
            slot_last_ack = slot_last_ack.at[
                jnp.where(is_ack, ef, F), jnp.where(is_ack, sl, 0)].max(
                ep, mode="drop")

        # consume the slot
        ev_type = s.ev_type.at[slot].set(jnp.zeros((E,), jnp.int32))

        # ------------------------------------------- 2. RCCC receiver grants
        done = src_track.base.astype(jnp.int32) >= wl.size
        rcc = s.rccc
        if p.rccc:
            active = ~done & (tick >= wl.start)
            rcc = grant_credits(rcc, flow_dst, active, H)

        # --------------------------------------------------- 3. injection
        has_rtx = (rtx != 0).any(axis=1) if not is_rod else jnp.zeros((F,), jnp.bool_)
        # ROD go-back-N: on NACK or timeout, rewind next_psn to base
        next_psn = s.next_psn
        if is_rod:
            timeout_rod = (inflight > 0) & (tick - last_progress > p.timeout_ticks)
            rewind = rod_gbn | timeout_rod
            next_psn = jnp.where(rewind, src_track.base.astype(jnp.int32), next_psn)
            inflight = jnp.where(rewind, 0, inflight)
            last_progress = jnp.where(rewind, tick, last_progress)

        window = jnp.floor(nst.cwnd).astype(jnp.int32) if p.nscc \
            else jnp.full((F,), int(p.max_cwnd), jnp.int32)
        win_ok = inflight < window
        if p.rccc:
            win_ok = win_ok & (rcc.balance >= 1.0)
        mp_ok = (next_psn - src_track.base.astype(jnp.int32)) < p.mp_range
        can_new = (next_psn < wl.size) & mp_ok
        eligible = (tick >= wl.start) & ~done & win_ok & (has_rtx | can_new)

        # fair per-host pick: per-tick pseudo-random rotation, flow id in
        # the low bits so exactly one winner exists per host
        from repro.core.lb.schemes import _mix32
        rot = (_mix32(jnp.arange(F, dtype=jnp.uint32) * jnp.uint32(2654435761)
                      ^ tick.astype(jnp.uint32)) >> 16).astype(jnp.int32)
        key = rot * F + jnp.arange(F)
        key = jnp.where(eligible, key, jnp.int32(2 ** 30))
        host_min = jnp.full((H,), 2 ** 30, jnp.int32).at[flow_src].min(key)
        injected = eligible & (key == host_min[flow_src]) & (key < 2 ** 30)

        rtx_off = _first_set_bit(rtx)
        rtx_psn = src_track.base.astype(jnp.int32) + rtx_off
        use_rtx = injected & has_rtx & (rtx_off >= 0)
        psn_out = jnp.where(use_rtx, rtx_psn, next_psn)
        rtx = _clear_bit(rtx, jnp.arange(F), rtx_off, use_rtx)
        next_psn = jnp.where(injected & ~use_rtx, next_psn + 1, next_psn)

        lbs2, ev_sel = select_ev(lbs, lb_scheme, psn_out.astype(jnp.uint32), tick)
        lbs = jax.tree_util.tree_map(
            lambda a, b: jnp.where(
                injected.reshape((-1,) + (1,) * (a.ndim - 1)), b, a),
            lbs, lbs2)
        inj_q = rt.injection_queue(flow_src, flow_dst, ev_sel)
        inflight = inflight + injected.astype(jnp.int32)
        if p.rccc:
            rcc = replace(rcc, balance=rcc.balance - injected.astype(jnp.float32))
        retransmits = s.retransmits + use_rtx.sum(dtype=jnp.int32)

        # ------------------------------------------------- 4. forwarding
        qidx = jnp.arange(Q)
        nonempty = s.q_len > 0
        hpos = s.q_head
        pf = s.q_flow[qidx, hpos]
        pp = s.q_psn[qidx, hpos]
        pe = s.q_ev[qidx, hpos]
        pm = s.q_meta[qidx, hpos]
        pt = s.q_tsent[qidx, hpos]
        # egress ECN marking: queue length at departure above threshold
        mark = nonempty & (s.q_len > p.ecn_threshold)
        pm = jnp.where(mark, pm | META_ECN, pm)
        q_head = jnp.where(nonempty, (s.q_head + 1) % C, s.q_head)
        q_len = jnp.where(nonempty, s.q_len - 1, s.q_len)

        safe_pf = jnp.where(nonempty, pf, 0)
        nq = rt.route_step(qidx, flow_src[safe_pf], flow_dst[safe_pf], pe)
        deliver = nonempty & (nq == DELIVERED)
        forward = nonempty & (nq >= 0)

        # --------------------------------------------- 5. delivery at FEPs
        dtrim = deliver & ((pm & META_TRIMMED) != 0)
        ddata = deliver & ~dtrim
        dst_track, fresh = pds.record_rx(
            s.dst_track, safe_pf, pp.astype(jnp.uint32), ddata)
        dst_track, _ = pds.advance_cack(dst_track)
        dups = s.dups + (ddata & ~fresh).sum(dtype=jnp.int32)
        delivered_ctr = s.delivered.at[jnp.where(ddata & fresh, safe_pf, F)].add(
            1, mode="drop")
        if is_rudi:
            # idempotent ops: re-applied duplicates also count as delivered
            delivered_ctr = delivered_ctr  # (payload applied; stats keep first-copy)
        if p.rccc:
            from repro.core.cms.rccc import mark_seen
            rcc = mark_seen(rcc, safe_pf, deliver)

        # ------------------------------------- 6. OOO-count loss inference
        ooo_fire = jnp.zeros((F,), jnp.bool_)
        if p.ooo_threshold > 0:
            dist = pds.ooo_distance(dst_track)
            due = (dist > p.ooo_threshold) & (
                tick - s.last_ooo_nack > jnp.int32(p.base_rtt))
            ooo_fire = due
        last_ooo_nack = jnp.where(ooo_fire, tick, s.last_ooo_nack)

        # ------------------------------------------------- 7. enqueue phase
        # candidates: forwarded packets (Q lanes) + injections (F lanes)
        cand_q = jnp.concatenate([jnp.where(forward, nq, -1),
                                  jnp.where(injected, inj_q, -1)])
        cand_flow = jnp.concatenate([pf, jnp.arange(F)])
        cand_psn = jnp.concatenate([pp, psn_out])
        cand_ev = jnp.concatenate([pe, ev_sel])
        cand_meta = jnp.concatenate([pm, jnp.zeros((F,), jnp.int32)])
        cand_ts = jnp.concatenate([pt, jnp.full((F,), 1, jnp.int32) * tick])
        cvalid = cand_q >= 0
        if p.failed_queues:
            dead = jnp.zeros((Q + 1,), jnp.bool_)
            for fq in p.failed_queues:
                dead = dead.at[fq].set(True)
            is_dead = dead[jnp.where(cvalid, cand_q, Q)]
            cvalid = cvalid & ~is_dead
        else:
            is_dead = None
        pos, _ = _rank_within(cand_q, cvalid, Q, q_len)
        fits = cvalid & (pos < C)
        overflow = cvalid & ~fits

        wslot = (q_head[jnp.where(cvalid, cand_q, 0)] + pos) % C
        tq = jnp.where(fits, cand_q, Q)
        q_flow = s.q_flow.at[tq, wslot].set(cand_flow, mode="drop")
        q_psn = s.q_psn.at[tq, wslot].set(cand_psn, mode="drop")
        q_ev = s.q_ev.at[tq, wslot].set(cand_ev, mode="drop")
        q_meta = s.q_meta.at[tq, wslot].set(cand_meta, mode="drop")
        q_tsent = s.q_tsent.at[tq, wslot].set(cand_ts, mode="drop")
        added = jnp.zeros((Q,), jnp.int32).at[
            jnp.where(fits, cand_q, Q)].add(1, mode="drop")
        q_len = q_len + added

        # overflow: trim (fast NACK via control TC) or drop
        if p.trimming:
            trims = s.trims + overflow.sum(dtype=jnp.int32)
            drops = s.drops
            nack_mask = overflow
        else:
            trims = s.trims
            drops = s.drops + overflow.sum(dtype=jnp.int32)
            nack_mask = jnp.zeros_like(overflow)
        if is_dead is not None:
            # failed links drop silently: no trim header, no NACK — only
            # timeout / EV-based inference recovers (Sec. 3.2.4 config drops)
            drops = drops + is_dead.sum(dtype=jnp.int32)

        # ------------------------------------------- 8. schedule control TC
        out_slot = (tick + p.ack_return_ticks) % D
        # lanes [0, Q): ACKs from deliveries
        ack_lane_t = jnp.where(ddata, EV_ACK, EV_NONE)
        # lanes [Q, Q + (Q+F)): trim NACKs from enqueue overflow
        nack_lane_t = jnp.where(nack_mask, EV_NACK, EV_NONE)
        # lanes [2Q+F, 2Q+2F): OOO NACKs (psn = receiver base = first gap)
        ooo_lane_t = jnp.where(ooo_fire, EV_OOO, EV_NONE)
        new_type = jnp.concatenate([ack_lane_t, nack_lane_t, ooo_lane_t])
        new_flow = jnp.concatenate([safe_pf, cand_flow, jnp.arange(F)])
        new_psn = jnp.concatenate(
            [pp, cand_psn, dst_track.base.astype(jnp.int32)])
        new_val = jnp.concatenate([pe, cand_ev, jnp.zeros((F,), jnp.int32)])
        new_ecn = jnp.concatenate(
            [((pm & META_ECN) != 0).astype(jnp.int32),
             jnp.zeros((Q + F,), jnp.int32), jnp.zeros((F,), jnp.int32)])
        new_ts = jnp.concatenate([pt, cand_ts, jnp.zeros((F,), jnp.int32)])
        ev_type = ev_type.at[out_slot].set(new_type)
        ev_flow = s.ev_flow.at[out_slot].set(new_flow)
        ev_psn = s.ev_psn.at[out_slot].set(new_psn)
        ev_val = s.ev_val.at[out_slot].set(new_val)
        ev_ecn = s.ev_ecn.at[out_slot].set(new_ecn)
        ev_tsent = s.ev_tsent.at[out_slot].set(new_ts)

        # ------------------------------------------------- 9. timeouts + QA
        if not is_rod:
            stalled = (inflight > 0) & (tick - last_progress > p.timeout_ticks) \
                & ~done
            rtx = _set_bits(rtx, jnp.arange(F), jnp.zeros((F,), jnp.int32),
                            stalled)  # offset 0 == oldest unacked PSN
            # a timeout implies the outstanding packets are gone (dropped
            # without trim); reset the inflight estimate so the window
            # reopens — otherwise non-trimmed drops leak inflight forever.
            inflight = jnp.where(stalled, 0, inflight)
            last_progress = jnp.where(stalled, tick, last_progress)
            nst = nscc_mod.on_loss(nst, jnp.arange(F), jnp.ones((F,), jnp.int32),
                                   stalled) if p.nscc else nst
        if p.nscc:
            nst = nscc_mod.quick_adapt(nst, nparams, tick)

        ns = SimState(
            q_flow=q_flow, q_psn=q_psn, q_ev=q_ev, q_meta=q_meta,
            q_tsent=q_tsent, q_head=q_head, q_len=q_len,
            next_psn=next_psn, inflight=inflight, src_track=src_track,
            rtx=rtx, last_progress=last_progress, slot_last_ack=slot_last_ack,
            dst_track=dst_track, last_ooo_nack=last_ooo_nack,
            nscc=nst, rccc=rcc, lb=lbs,
            ev_type=ev_type, ev_flow=ev_flow, ev_psn=ev_psn, ev_val=ev_val,
            ev_ecn=ev_ecn, ev_tsent=ev_tsent,
            delivered=delivered_ctr, trims=trims, drops=drops, dups=dups,
            retransmits=retransmits,
        )
        out = {
            "delivered": jnp.zeros((F,), jnp.int32).at[
                jnp.where(ddata & fresh, safe_pf, F)].add(1, mode="drop"),
            "cwnd": nst.cwnd,
            "qlen_max": q_len.max(),
        }
        return ns, out

    return step


@dataclass(frozen=True)
class SimResult:
    state: SimState
    delivered_per_tick: np.ndarray  # [T, F]
    cwnd_per_tick: np.ndarray       # [T, F]
    qlen_max: np.ndarray            # [T]

    def completion_tick(self) -> np.ndarray:
        """First tick by which each flow's full message was delivered."""
        cum = self.delivered_per_tick.cumsum(axis=0)
        size = cum[-1]
        reached = cum >= size[None, :]
        return np.where(reached.any(0), reached.argmax(axis=0), -1)

    def goodput(self, window: tuple[int, int] | None = None) -> np.ndarray:
        """Per-flow delivered packets / tick over a window (fraction of
        line rate, since line rate == 1 packet/tick)."""
        d = self.delivered_per_tick
        if window is not None:
            d = d[window[0]:window[1]]
        return d.mean(axis=0)


def simulate(g: QueueGraph, wl: Workload, p: SimParams) -> SimResult:
    """Run the fabric for p.ticks; returns dense per-tick stats."""
    step = make_step(g, wl, p)
    s0 = init_state(g, wl, p)

    @jax.jit
    def run(s0):
        return jax.lax.scan(step, s0, jnp.arange(p.ticks, dtype=jnp.int32))

    final, outs = run(s0)
    return SimResult(
        state=jax.device_get(final),
        delivered_per_tick=np.asarray(outs["delivered"]),
        cwnd_per_tick=np.asarray(outs["cwnd"]),
        qlen_max=np.asarray(outs["qlen_max"]),
    )
