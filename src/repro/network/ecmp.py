"""ECMP hashing and per-hop routing (Sec. 2.1).

Switches resolve a destination to a *set* of equal-cost ports and pick one
with a deterministic hash of header fields including the Entropy Value:
``p = H(x) mod n_ports``. Properties the transport relies on (Sec. 3.3.5):
same EV => same path; different EV => likely different path (collisions
expected and modeled — e.g. 4 same-pod paths vs 2^16 EVs => 25% pairwise
collision probability, which `benchmarks/bench_ecmp_collisions.py`
reproduces).

`route_step` advances a batch of dequeued packets one hop through a
`QueueGraph`; `injection_queue` picks the first queue at the source leaf.
Hash = xxhash-style avalanche over (src, dst, ev, switch-salt) — the
"well-mixing hash functions in use today" the paper assumes. The batched
hash is also implemented as a Pallas kernel (repro/kernels/ecmp_hash.py);
this module is its reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.network.topology import QueueGraph, Stage

DELIVERED = jnp.int32(-2)
INVALID = jnp.int32(-1)


def ecmp_hash(src: jax.Array, dst: jax.Array, ev: jax.Array,
              salt: jax.Array) -> jax.Array:
    """Deterministic well-mixed 32-bit hash of the ECMP field set.

    All inputs int32/uint32, broadcastable. Mirrors a hardware 5-tuple
    hash: mix each field with distinct odd constants, then avalanche.
    """
    x = (src.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
         ^ dst.astype(jnp.uint32) * jnp.uint32(0x85EBCA77)
         ^ ev.astype(jnp.uint32) * jnp.uint32(0xC2B2AE3D)
         ^ salt.astype(jnp.uint32) * jnp.uint32(0x27D4EB2F))
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x2C1B3C6D)
    x = x ^ (x >> 12)
    x = x * jnp.uint32(0x297A2D39)
    x = x ^ (x >> 15)
    return x


class RoutingTables:
    """Device-resident copies of the QueueGraph routing arrays."""

    def __init__(self, g: QueueGraph):
        self.g = g
        self.stage = jnp.asarray(g.stage)
        self.host_queue = jnp.asarray(g.host_queue)
        self.host_leaf = jnp.asarray(g.host_leaf)
        self.host_pod = jnp.asarray(g.host_pod)
        self.up1 = jnp.asarray(g.up1_table)
        self.down1 = jnp.asarray(g.down1_table)
        self.up2 = jnp.asarray(g.up2_table) if g.up2_table.size else None
        self.down2 = jnp.asarray(g.down2_table) if g.down2_table.size else None
        self.next_switch = jnp.asarray(g.queue_next_switch)
        self.three_level = g.up2_table.size > 0
        self.leaves_per_pod = (g.down1_table.shape[1]
                               if self.three_level else 1)
        self.aggs_per_pod = g.fanout1

    def injection_queue(self, src: jax.Array, dst: jax.Array,
                        ev: jax.Array) -> jax.Array:
        """First queue for a packet injected at host `src` toward `dst`."""
        sleaf = self.host_leaf[src]
        dleaf = self.host_leaf[dst]
        same_leaf = sleaf == dleaf
        h = ecmp_hash(src, dst, ev, sleaf) % jnp.uint32(self.g.fanout1)
        up = self.up1[sleaf, h.astype(jnp.int32)]
        return jnp.where(same_leaf, self.host_queue[dst], up)

    def route_step(self, queue: jax.Array, src: jax.Array, dst: jax.Array,
                   ev: jax.Array) -> jax.Array:
        """Next queue for packets just dequeued from `queue`.

        Returns DELIVERED for packets leaving a HOST queue.
        """
        st = self.stage[queue]
        sw = self.next_switch[queue]  # switch the packet is *now* at
        dleaf = self.host_leaf[dst]

        if not self.three_level:
            L = self.up1.shape[0]
            spine = sw - L
            nxt_up1 = self.down1[jnp.clip(spine, 0, self.down1.shape[0] - 1),
                                 dleaf]
            nxt_down1 = self.host_queue[dst]
            out = jnp.where(st == Stage.UP1, nxt_up1,
                            jnp.where(st == Stage.DOWN1, nxt_down1, DELIVERED))
            return jnp.where(st == Stage.HOST, DELIVERED, out)

        L = self.up1.shape[0]            # leaves
        A = self.down1.shape[0]          # aggs
        Lp = self.leaves_per_pod
        Ap = self.aggs_per_pod
        half = self.up2.shape[1]
        dpod = self.host_pod[dst]

        # at agg (arrived via UP1): same pod -> DOWN1; else UP2 via hash
        agg = jnp.clip(sw - L, 0, A - 1)
        agg_pod = agg // Ap
        dleaf_local = dleaf % Lp
        go_down = self.down1[agg, dleaf_local]
        h2 = ecmp_hash(src, dst, ev, sw) % jnp.uint32(half)
        go_up = self.up2[agg, h2.astype(jnp.int32)]
        nxt_up1 = jnp.where(agg_pod == dpod, go_down, go_up)

        # at core (arrived via UP2): down to the destination pod's agg
        core = jnp.clip(sw - L - A, 0, self.down2.shape[0] - 1)
        nxt_up2 = self.down2[core, dpod]

        # at agg (arrived via DOWN2): down to destination leaf
        nxt_down2 = self.down1[agg, dleaf_local]

        # at leaf (arrived via DOWN1): host downlink
        nxt_down1 = self.host_queue[dst]

        out = jnp.where(st == Stage.UP1, nxt_up1,
              jnp.where(st == Stage.UP2, nxt_up2,
              jnp.where(st == Stage.DOWN2, nxt_down2,
              jnp.where(st == Stage.DOWN1, nxt_down1, DELIVERED))))
        return out

    def path_fingerprint(self, src: jax.Array, dst: jax.Array,
                         ev: jax.Array) -> jax.Array:
        """Identify the full path an EV selects (for collision statistics).

        Combines every hash choice along the path into one int32 id;
        two packets share a fingerprint iff they traverse the same links.
        Vectorized over (src, dst, ev) — no simulation involved.
        """
        sleaf = self.host_leaf[src]
        dleaf = self.host_leaf[dst]
        h1 = (ecmp_hash(src, dst, ev, sleaf)
              % jnp.uint32(self.g.fanout1)).astype(jnp.int32)
        if not self.three_level:
            return jnp.where(sleaf == dleaf, -1, h1)
        spod = self.host_pod[src]
        dpod = self.host_pod[dst]
        agg = spod * self.aggs_per_pod + h1
        sw = self.up1.shape[0] + agg
        half = self.up2.shape[1]
        h2 = (ecmp_hash(src, dst, ev, sw) % jnp.uint32(half)).astype(jnp.int32)
        same_pod = spod == dpod
        same_leaf = sleaf == dleaf
        fp = jnp.where(same_pod, h1, h1 * half + h2)
        return jnp.where(same_leaf, -1, fp)
