"""Link-layer options: Link-Level Retry and Credit-Based Flow Control
(Sec. 3.5).

LLR: go-back-N retransmission confined to one link. Justified at this
layer (unlike end-to-end, which UET redesigned away from go-back-N)
because the link RTT is ~1 us, bounded, and congestion plays no role —
only PHY corruption drops. Modeled as a replay-buffer state machine whose
invariants (no loss escapes the link; buffer bounded by link BDP) are
tested in tests/test_link_tss.py.

CBFC: 20-bit cyclic credit counters at sender and receiver per virtual
channel, periodically synchronized. Compared against PFC headroom:
PFC needs RTT+MTU headroom per (port, priority) to be lossless; CBFC
needs only the actual receive buffer it advertises (Sec. 3.5.2 claims
(1)-(4); `pfc_headroom_bytes` / `cbfc_buffer_bytes` quantify claim (1)).
"""
from __future__ import annotations

from dataclasses import dataclass, replace


CTR_BITS = 20
CTR_MOD = 1 << CTR_BITS


# ---------------------------------------------------------------------------
# LLR — go-back-N on one link
# ---------------------------------------------------------------------------


@dataclass
class LLRLink:
    """One LLR-enabled link direction (host-side model, event-driven)."""

    replay_capacity: int = 64
    timeout: int = 8               # ~link RTT in frame times
    # state
    next_seq: int = 0              # next new frame sequence
    send_base: int = 0             # oldest unacked
    now: int = 0
    last_progress: int = 0
    retransmissions: int = 0

    def in_flight(self) -> int:
        return self.next_seq - self.send_base

    def can_send(self) -> bool:
        return self.in_flight() < self.replay_capacity

    def send(self) -> int:
        assert self.can_send()
        seq = self.next_seq
        self.next_seq += 1
        return seq

    def on_ack(self, seq: int):
        """Cumulative ACK frees the replay buffer up to seq."""
        if seq >= self.send_base:
            self.send_base = seq + 1
            self.last_progress = self.now

    def on_nack(self, seq: int) -> list[int]:
        """Receiver saw a gap: go-back-N from `seq`."""
        self.retransmissions += self.next_seq - seq
        resend = list(range(seq, self.next_seq))
        return resend

    def tick(self) -> list[int]:
        """Timeout guard for tail loss: resend everything outstanding."""
        self.now += 1
        if (self.in_flight() > 0
                and self.now - self.last_progress > self.timeout):
            self.last_progress = self.now
            self.retransmissions += self.in_flight()
            return list(range(self.send_base, self.next_seq))
        return []


def llr_deliver(frames_sent: list[int], corrupt: set[int],
                expected: int = 0) -> list[int]:
    """Receiver view: frames arrive in order; corrupted ones are dropped
    and NACK'd by the first out-of-order arrival. `expected` carries the
    receiver's next-in-order sequence across retransmission rounds."""
    delivered = []
    for f in frames_sent:
        if f in corrupt:
            continue
        if f == expected:
            delivered.append(f)
            expected += 1
    return delivered


# ---------------------------------------------------------------------------
# CBFC — credit counters per virtual channel
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CBFCState:
    """20-bit cyclic counters (Sec. 3.5.2): sender tracks consumed,
    receiver tracks freed; available = buffer - (consumed - freed)."""

    buffer_bytes: int
    consumed: int = 0   # sender-side, mod 2^20 (units: cells/bytes)
    freed: int = 0      # receiver-side, mod 2^20

    def available(self) -> int:
        return self.buffer_bytes - ((self.consumed - self.freed) % CTR_MOD)

    def can_send(self, size: int) -> bool:
        return self.available() >= size

    def send(self, size: int) -> "CBFCState":
        assert self.can_send(size), "CBFC never oversends"
        return replace(self, consumed=(self.consumed + size) % CTR_MOD)

    def drain(self, size: int) -> "CBFCState":
        """Receiver forwards a packet out of its buffer -> credit update
        message back to the sender."""
        return replace(self, freed=(self.freed + size) % CTR_MOD)


def pfc_headroom_bytes(link_gbps: float, cable_m: float, mtu: int,
                       priorities: int = 8) -> float:
    """Lossless PFC headroom per port: in-flight bytes during the pause
    round trip (2x propagation + 2x MTU serialization + response time),
    per priority class."""
    c = 2e8  # m/s in fiber
    rtt_s = 2 * cable_m / c
    inflight = link_gbps * 1e9 / 8 * rtt_s
    return priorities * (inflight + 2 * mtu)


def cbfc_buffer_bytes(link_gbps: float, cable_m: float, mtu: int,
                      active_vcs: int = 2) -> float:
    """CBFC needs one link-BDP of credited buffer to keep the pipe full —
    and only for the VCs actually in use (claims (1) and (4))."""
    c = 2e8
    rtt_s = 2 * cable_m / c
    bdp = link_gbps * 1e9 / 8 * rtt_s
    return active_vcs * (bdp + mtu)
