"""Fault-tolerant training loop.

Scale features (designed for 1000+ nodes, exercised here at CPU scale):

* checkpoint/restart: async sharded checkpoints every `ckpt_every` steps;
  on (re)start the loop resumes from the latest durable step and the
  deterministic data pipeline replays from that exact cursor;
* failure handling: a step that throws (device OOM, preempted host, NaN
  loss with `halt_on_nan`) triggers restore-from-last-checkpoint instead
  of killing the job; `max_failures` bounds the retry budget;
* straggler mitigation: per-step wall times feed an EWMA; steps slower
  than `straggler_factor` x EWMA are counted and surfaced in metrics —
  the deployment hook for backup-task dispatch (and the network-level
  mitigation is REPS load balancing inside the UET fabric, see
  repro/core/lb); on this container it degrades to monitoring;
* elastic rescale: `Trainer.restore` accepts a different mesh/sharding
  layout than the checkpoint was written with (see repro/ckpt).
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from repro.ckpt import checkpointing as ckpt


@dataclass
class TrainerConfig:
    total_steps: int = 300
    ckpt_every: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    halt_on_nan: bool = True
    max_failures: int = 3
    straggler_factor: float = 2.5


@dataclass
class TrainerState:
    step: int = 0
    failures: int = 0
    straggler_steps: int = 0
    step_time_ewma: float = 0.0


class Trainer:
    def __init__(self, cfg: TrainerConfig, train_step: Callable,
                 data_fn: Callable[[int], dict],
                 params: Any, opt_state: Any,
                 param_shardings: Any = None, opt_shardings: Any = None):
        self.cfg = cfg
        self.train_step = train_step
        self.data_fn = data_fn
        self.params = params
        self.opt_state = opt_state
        self.param_shardings = param_shardings
        self.opt_shardings = opt_shardings
        self.state = TrainerState()
        self.checkpointer = ckpt.AsyncCheckpointer(cfg.ckpt_dir)
        os.makedirs(cfg.ckpt_dir, exist_ok=True)
        self.history: list[dict] = []

    # ------------------------------------------------------------------
    def try_resume(self) -> bool:
        step = ckpt.latest_step(self.cfg.ckpt_dir)
        if step is None:
            return False
        tree = {"params": self.params, "opt": self.opt_state}
        sh = None
        if self.param_shardings is not None:
            sh = {"params": self.param_shardings, "opt": self.opt_shardings}
        restored = ckpt.restore(self.cfg.ckpt_dir, step, tree, sh)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.state.step = step
        return True

    def _checkpoint(self):
        self.checkpointer.save(self.state.step,
                               {"params": self.params,
                                "opt": self.opt_state})

    # ------------------------------------------------------------------
    def run(self) -> list[dict]:
        cfg, st = self.cfg, self.state
        while st.step < cfg.total_steps:
            batch = self.data_fn(st.step)
            t0 = time.time()
            try:
                params, opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch)
                loss = float(metrics["loss"])
                if cfg.halt_on_nan and not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss {loss} at "
                                             f"step {st.step}")
                self.params, self.opt_state = params, opt_state
            except Exception as e:  # noqa: BLE001 — failure domain boundary
                st.failures += 1
                if st.failures > cfg.max_failures:
                    raise
                resumed = self.try_resume()
                print(f"[trainer] step {st.step} failed ({e!r}); "
                      f"{'resumed from checkpoint' if resumed else 'retrying'}"
                      f" (failure {st.failures}/{cfg.max_failures})")
                continue

            dt = time.time() - t0
            if st.step_time_ewma == 0.0:
                st.step_time_ewma = dt
            else:
                if dt > cfg.straggler_factor * st.step_time_ewma:
                    st.straggler_steps += 1
                st.step_time_ewma = 0.9 * st.step_time_ewma + 0.1 * dt

            st.step += 1
            rec = {"step": st.step, "loss": loss,
                   "grad_norm": float(metrics["grad_norm"]),
                   "dt": dt, "stragglers": st.straggler_steps}
            self.history.append(rec)
            if st.step % cfg.log_every == 0:
                print(f"[trainer] step {st.step:5d} loss {loss:8.4f} "
                      f"gnorm {rec['grad_norm']:7.3f} {dt*1e3:7.1f} ms")
            if st.step % cfg.ckpt_every == 0:
                self._checkpoint()
        self.checkpointer.wait()
        return self.history
