"""Dynamic fault schedules: link flaps, gray (lossy) links, mid-run death.

The engine's original failure model was a static per-scenario ``failed=``
queue mask — links dead from tick 0 to the horizon, drops silent. At
hyperscale the interesting regime is links that FLAP and GRAY-FAIL while
traffic is in flight ("Datacenter Ethernet and RDMA: Issues at
Hyperscale"), so the mask generalizes to a :class:`FaultSchedule`:

* ``fail_at`` / ``heal_at`` — per-queue tick lanes bounding one outage
  window per queue: the queue is dead exactly while
  ``fail_at <= tick < heal_at``. ``fail_at = NEVER_TICK`` means always
  healthy; ``heal_at = NEVER_TICK`` means the failure is permanent. The
  static mask is the degenerate schedule ``fail_at=0, heal_at=NEVER``
  (:meth:`FaultSchedule.from_mask`) and reproduces the old ``failed=``
  semantics bit for bit.
* ``loss_p`` — per-queue independent packet-loss probability (gray
  links / corruption drops, Sec. 3.2.4's second "C"). Losses are drawn
  from a counter-based hash of ``(seed, tick, enqueue lane)`` — no RNG
  state in the carry — so the draw stream is reproducible across
  batch/shard/chunk boundaries and identical between ``simulate`` and
  ``simulate_batch`` lanes.

All lanes are TRACED inputs (like workloads and seeds): sweeping fault
schedules never recompiles, and a ``[B, ...]``-stacked schedule rides the
scenario axis of ``simulate_batch`` / ``shard=True`` like any other
per-scenario input. Both kinds of fault drop packets silently (no trim
header, no NACK); recovery is the transport's job — RTO (+ optional
exponential backoff), OOO/EV loss inference, and LB path eviction (see
``TransportProfile.ev_eviction`` and DESIGN.md "Fault model & recovery
contract").

``python -m repro.network.faults`` runs the recovery smoke used by
``scripts/check.sh``: a mid-run flap must be survived (timeouts fire,
the flow completes after heal) and a permanent mid-run failure must be
escaped via path eviction.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import NEVER_TICK


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class FaultSchedule:
    """Per-queue fault lanes for one scenario ([Q]) or a stacked
    scenario batch ([B, Q]; ``seed`` is [] / [B]).

    Build with :meth:`healthy` / :meth:`from_mask`, then layer faults
    with :meth:`flap` / :meth:`lossy`; stack scenarios with
    :meth:`stack`. Dead window: ``fail_at <= tick < heal_at``.
    """

    fail_at: jax.Array   # [.., Q] int32 first dead tick (NEVER = healthy)
    heal_at: jax.Array   # [.., Q] int32 first live-again tick (NEVER = forever)
    loss_p: jax.Array    # [.., Q] float32 per-packet loss probability
    seed: jax.Array      # [..] uint32 loss-draw stream seed

    # -- builders ---------------------------------------------------------
    @staticmethod
    def healthy(num_queues: int, batch: "int | None" = None,
                seed: int = 0) -> "FaultSchedule":
        """All-healthy lanes ([Q], or [batch, Q] when batch is given)."""
        shape = (num_queues,) if batch is None else (batch, num_queues)
        return FaultSchedule(
            fail_at=jnp.full(shape, NEVER_TICK, jnp.int32),
            heal_at=jnp.full(shape, NEVER_TICK, jnp.int32),
            loss_p=jnp.zeros(shape, jnp.float32),
            seed=jnp.full(shape[:-1], seed, jnp.uint32),
        )

    @staticmethod
    def from_mask(mask, seed: int = 0) -> "FaultSchedule":
        """The degenerate static schedule: queues set in ``mask`` (bool,
        [Q] or [B, Q]) are dead from tick 0 forever — bitwise the old
        ``failed=`` semantics."""
        mask = jnp.asarray(mask, bool)
        return FaultSchedule(
            fail_at=jnp.where(mask, 0, NEVER_TICK).astype(jnp.int32),
            heal_at=jnp.full(mask.shape, NEVER_TICK, jnp.int32),
            loss_p=jnp.zeros(mask.shape, jnp.float32),
            seed=jnp.full(mask.shape[:-1], seed, jnp.uint32),
        )

    # -- combinators (return a new schedule; queues are ids into [Q]) -----
    def flap(self, queues, fail_at: int,
             heal_at: int = NEVER_TICK) -> "FaultSchedule":
        """Give ``queues`` the outage window [fail_at, heal_at). One
        window per queue (a later flap overwrites an earlier one)."""
        qs = np.atleast_1d(np.asarray(queues, np.int64))
        hot = np.zeros(self.fail_at.shape[-1:], bool)
        hot[qs] = True
        hot = jnp.broadcast_to(jnp.asarray(hot), self.fail_at.shape)
        return replace(
            self,
            fail_at=jnp.where(hot, jnp.int32(fail_at), self.fail_at),
            heal_at=jnp.where(hot, jnp.int32(heal_at), self.heal_at),
        )

    def lossy(self, queues, p: float) -> "FaultSchedule":
        """Make ``queues`` gray links dropping each packet w.p. ``p``."""
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"loss probability must be in [0, 1], got {p}")
        qs = np.atleast_1d(np.asarray(queues, np.int64))
        hot = np.zeros(self.loss_p.shape[-1:], bool)
        hot[qs] = True
        hot = jnp.broadcast_to(jnp.asarray(hot), self.loss_p.shape)
        return replace(self, loss_p=jnp.where(hot, jnp.float32(p),
                                              self.loss_p))

    def with_seed(self, seed) -> "FaultSchedule":
        return replace(self, seed=jnp.broadcast_to(
            jnp.asarray(seed, jnp.uint32), self.seed.shape))

    @staticmethod
    def stack(scheds: "list[FaultSchedule]") -> "FaultSchedule":
        """Stack per-scenario [Q] schedules into a [B, Q] batch."""
        return jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls), *scheds)

    # -- views ------------------------------------------------------------
    @property
    def num_queues(self) -> int:
        return int(self.fail_at.shape[-1])

    def dead_at(self, tick) -> jax.Array:
        """[.., Q] bool — queues dead at ``tick`` (the engine's per-tick
        derivation; exposed for tests/diagnostics)."""
        t = jnp.asarray(tick, jnp.int32)
        return (self.fail_at <= t) & (t < self.heal_at)


def loss_threshold(loss_p: jax.Array) -> jax.Array:
    """[.., Q] uint32 compare threshold for the counter-based loss draw:
    a packet is lost iff its uniform hash u32 < threshold. p=0 maps to
    threshold 0 (never — bitwise inert); p=1 maps to the largest float32
    below 2**32 (loses all but ~1 in 2**24 draws; use a dead window for
    hard cuts)."""
    return (jnp.clip(loss_p, 0.0, 1.0) * jnp.float32(4294967040.0)
            ).astype(jnp.uint32)


def as_schedule(g_num_queues: int, failed, faults, batch: "int | None" = None,
                ) -> FaultSchedule:
    """Normalize the public (failed=, faults=) pair to one FaultSchedule
    with [Q] (serial) or [batch, Q] leaves. Exactly one of the two may
    be given; neither means all-healthy."""
    if faults is not None:
        if failed is not None:
            raise ValueError("pass either failed= (static mask) or "
                             "faults= (FaultSchedule), not both")
        if not isinstance(faults, FaultSchedule):
            raise TypeError(f"faults= must be a FaultSchedule, got "
                            f"{type(faults).__name__}")
        if faults.num_queues != g_num_queues:
            raise ValueError(
                f"fault schedule is over {faults.num_queues} queues but "
                f"the topology has {g_num_queues}")
        if batch is None:
            if faults.fail_at.ndim != 1:
                raise ValueError("serial simulate() takes a [Q] fault "
                                 f"schedule, got {faults.fail_at.shape}")
            return faults
        if faults.fail_at.ndim == 1:
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (batch,) + a.shape), faults)
        if faults.fail_at.shape[0] != batch:
            raise ValueError(f"fault schedule batch axis is "
                             f"{faults.fail_at.shape[0]}, expected {batch}")
        return faults
    return None  # caller falls back to the failed= mask path


def _smoke() -> int:  # pragma: no cover — CLI smoke for scripts/check.sh
    """Recovery canary: (1) a mid-run uplink flap is survived — timeouts
    fire while the link is down, the flow completes after heal, and the
    degraded-tick counter brackets the outage; (2) a PERMANENT mid-run
    failure of a pinned static path is escaped via EV eviction (the
    eviction-off twin stays stuck)."""
    from dataclasses import replace as _rep

    from repro.core.lb.schemes import LBScheme
    # canonical class, NOT the __main__ copy this file becomes under -m
    from repro.network.faults import FaultSchedule as FS
    from repro.network.fabric import (SimParams, TransportProfile, Workload,
                                      simulate)
    from repro.network.topology import leaf_spine

    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2, 3], [4, 5, 6, 7], 150)
    p = SimParams(ticks=4000, timeout_ticks=64)
    up = [int(g.up1_table[0, i]) for i in range(2)]

    # 1) flap: both uplinks down over [120, 420) — no surviving path
    #    during the window, full recovery after heal
    flap = FS.healthy(g.num_queues).flap(up, 120, 420)
    r = simulate(g, wl, TransportProfile.ai_full(), p, faults=flap)
    ct = r.completion_tick()
    assert ct > 420, f"flap scenario should finish after heal, got {ct}"
    assert r.timeouts > 0, "outage must trigger RTO timeouts"
    assert r.ticks_degraded == 300, r.ticks_degraded

    # 2) permanent failure of a static path: eviction-on escapes,
    #    eviction-off is stuck at the budget
    dead = FS.healthy(g.num_queues).flap(up[0], 120)
    off = TransportProfile.ai_full(lb=LBScheme.STATIC, name="static")
    on = _rep(off, ev_eviction=True, name="static+evict")
    r_off = simulate(g, wl, off, p, faults=dead)
    r_on = simulate(g, wl, on, p, faults=dead)
    ct_on = r_on.completion_tick()
    assert ct_on != -1, "eviction must migrate flows off the dead path"
    assert r_on.ev_evictions > 0
    ct_off = r_off.completion_tick()
    assert ct_off == -1 or ct_on < ct_off, (ct_on, ct_off)
    print(f"fault smoke ok: flap survived (completion {ct}, "
          f"{r.timeouts} timeouts, {r.ticks_degraded} degraded ticks); "
          f"permanent failure escaped via {r_on.ev_evictions} evictions "
          f"(completion {ct_on} vs eviction-off "
          f"{'stuck' if ct_off == -1 else ct_off})")
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(_smoke())
