"""Dynamic fault schedules: link flaps, gray links, and dead endpoints.

The engine's original failure model was a static per-scenario ``failed=``
queue mask — links dead from tick 0 to the horizon, drops silent. At
hyperscale the interesting regime is links that FLAP and GRAY-FAIL while
traffic is in flight ("Datacenter Ethernet and RDMA: Issues at
Hyperscale"), so the mask generalizes to a :class:`FaultSchedule`:

* ``fail_at`` / ``heal_at`` — per-queue tick lanes bounding one outage
  window per queue: the queue is dead exactly while
  ``fail_at <= tick < heal_at``. ``fail_at = NEVER_TICK`` means always
  healthy; ``heal_at = NEVER_TICK`` means the failure is permanent. The
  static mask is the degenerate schedule ``fail_at=0, heal_at=NEVER``
  (:meth:`FaultSchedule.from_mask`) and reproduces the old ``failed=``
  semantics bit for bit.
* ``loss_p`` — per-queue independent packet-loss probability (gray
  links / corruption drops, Sec. 3.2.4's second "C"). Losses are drawn
  from a counter-based hash of ``(seed, tick, enqueue lane)`` — no RNG
  state in the carry — so the draw stream is reproducible across
  batch/shard/chunk boundaries and identical between ``simulate`` and
  ``simulate_batch`` lanes.
* ``corrupt_p`` — per-queue PHY bit-error probability, drawn per
  TRANSMISSION (a counter-based hash of ``(seed, tick, queue)`` at
  dequeue, a stream independent of the gray-link draw). Distinct from
  ``loss_p`` by recoverability: a corrupted frame is LINK-recoverable —
  with ``LinkConfig(llr=True)`` armed the hop replays it and delivery
  is merely delayed — while gray-link drops are not (they vanish
  whatever the link layer does, like congestion drops minus the trim
  header). Without LLR a corrupted frame is silently dropped, exactly
  like ``loss_p`` but charged at the transmitting hop.
* ``host_fail_at`` / ``host_heal_at`` — per-HOST outage lanes (node
  death): while ``host_fail_at <= tick < host_heal_at`` the host stops
  injecting, stops processing/ emitting ACKs, and stops absorbing
  deliveries on every queue it touches (its downlink eats enqueues as
  silent drops). Detection and teardown are the transport's job — see
  ``TransportProfile.pdc_dead_after`` and DESIGN.md "Endpoint failure &
  recovery contract".
* ``nic_stall_at`` / ``nic_heal_at`` — the NIC-stall variant: injection
  freezes but the host stays ACK-live (inbound deliveries are absorbed
  and acknowledged, the RTO clock keeps running). Models a wedged send
  engine / PCIe backpressure rather than node death.

Host lanes are width-[H] and OPTIONAL: schedules built without
``num_hosts`` carry zero-width lanes, dispatch detects the all-healthy
case (``has_host_faults``) and compiles the exact pre-endpoint-fault
program — all-healthy schedules stay bitwise-inert.

All lanes are TRACED inputs (like workloads and seeds): sweeping fault
schedules never recompiles, and a ``[B, ...]``-stacked schedule rides the
scenario axis of ``simulate_batch`` / ``shard=True`` like any other
per-scenario input. Link faults drop packets silently (no trim header,
no NACK); recovery is the transport's job — RTO (+ optional exponential
backoff), OOO/EV loss inference, LB path eviction, and PDC liveness
teardown (see ``TransportProfile`` and DESIGN.md "Fault model & recovery
contract").

``python -m repro.network.faults`` runs the recovery smoke used by
``scripts/check.sh``: a mid-run flap must be survived (timeouts fire,
the flow completes after heal) and a permanent mid-run failure must be
escaped via path eviction. ``--endpoint`` runs the endpoint canary
instead: a never-healing dead host under a ``pdc_dead_after`` profile
must be detected and quarantined, quiescing EARLY with abandonment
visible while the surviving flows complete.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import NEVER_TICK


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class FaultSchedule:
    """Per-queue + per-host fault lanes for one scenario ([Q] / [H]) or
    a stacked scenario batch ([B, Q] / [B, H]; ``seed`` is [] / [B]).

    Build with :meth:`healthy` / :meth:`from_mask`, then layer faults
    with :meth:`flap` / :meth:`lossy` / :meth:`host_fail` /
    :meth:`nic_stall`; stack scenarios with :meth:`stack`. Dead windows:
    ``fail_at <= tick < heal_at`` (links),
    ``host_fail_at <= tick < host_heal_at`` (hosts). Host lanes may be
    zero-width (no endpoint faults expressible — the default).
    """

    fail_at: jax.Array   # [.., Q] int32 first dead tick (NEVER = healthy)
    heal_at: jax.Array   # [.., Q] int32 first live-again tick (NEVER = forever)
    loss_p: jax.Array    # [.., Q] float32 per-packet loss probability
    corrupt_p: jax.Array  # [.., Q] float32 per-transmission BER (PHY)
    seed: jax.Array      # [..] uint32 loss-draw stream seed
    host_fail_at: jax.Array  # [.., H] int32 host dead from (NEVER = healthy)
    host_heal_at: jax.Array  # [.., H] int32 host live again (NEVER = forever)
    nic_stall_at: jax.Array  # [.., H] int32 injection frozen from
    nic_heal_at: jax.Array   # [.., H] int32 injection live again

    # -- builders ---------------------------------------------------------
    @staticmethod
    def healthy(num_queues: int, batch: "int | None" = None,
                seed: int = 0, num_hosts: int = 0) -> "FaultSchedule":
        """All-healthy lanes ([Q], or [batch, Q] when batch is given).
        ``num_hosts`` sizes the per-host lanes (0 — the default — builds
        a schedule that cannot express endpoint faults and is free)."""
        shape = (num_queues,) if batch is None else (batch, num_queues)
        hshape = shape[:-1] + (num_hosts,)
        return FaultSchedule(
            fail_at=jnp.full(shape, NEVER_TICK, jnp.int32),
            heal_at=jnp.full(shape, NEVER_TICK, jnp.int32),
            loss_p=jnp.zeros(shape, jnp.float32),
            corrupt_p=jnp.zeros(shape, jnp.float32),
            seed=jnp.full(shape[:-1], seed, jnp.uint32),
            host_fail_at=jnp.full(hshape, NEVER_TICK, jnp.int32),
            host_heal_at=jnp.full(hshape, NEVER_TICK, jnp.int32),
            nic_stall_at=jnp.full(hshape, NEVER_TICK, jnp.int32),
            nic_heal_at=jnp.full(hshape, NEVER_TICK, jnp.int32),
        )

    @staticmethod
    def from_mask(mask, seed: int = 0) -> "FaultSchedule":
        """The degenerate static schedule: queues set in ``mask`` (bool,
        [Q] or [B, Q]) are dead from tick 0 forever — bitwise the old
        ``failed=`` semantics. Host lanes are zero-width."""
        mask = jnp.asarray(mask, bool)
        hshape = mask.shape[:-1] + (0,)
        return FaultSchedule(
            fail_at=jnp.where(mask, 0, NEVER_TICK).astype(jnp.int32),
            heal_at=jnp.full(mask.shape, NEVER_TICK, jnp.int32),
            loss_p=jnp.zeros(mask.shape, jnp.float32),
            corrupt_p=jnp.zeros(mask.shape, jnp.float32),
            seed=jnp.full(mask.shape[:-1], seed, jnp.uint32),
            host_fail_at=jnp.full(hshape, NEVER_TICK, jnp.int32),
            host_heal_at=jnp.full(hshape, NEVER_TICK, jnp.int32),
            nic_stall_at=jnp.full(hshape, NEVER_TICK, jnp.int32),
            nic_heal_at=jnp.full(hshape, NEVER_TICK, jnp.int32),
        )

    def with_hosts(self, num_hosts: int) -> "FaultSchedule":
        """Widen zero-width host lanes to [.., num_hosts] all-healthy
        lanes (so :meth:`host_fail` / :meth:`nic_stall` can address
        hosts). A schedule already at ``num_hosts`` is returned as-is;
        any other nonzero width is an error."""
        if self.num_hosts == num_hosts:
            return self
        if self.num_hosts != 0:
            raise ValueError(
                f"schedule already has host lanes over {self.num_hosts} "
                f"hosts; cannot re-widen to {num_hosts}")
        hshape = self.fail_at.shape[:-1] + (num_hosts,)
        never = jnp.full(hshape, NEVER_TICK, jnp.int32)
        return replace(self, host_fail_at=never, host_heal_at=never,
                       nic_stall_at=never, nic_heal_at=never)

    # -- combinators (return a new schedule; queues are ids into [Q]) -----
    def flap(self, queues, fail_at: int,
             heal_at: int = NEVER_TICK) -> "FaultSchedule":
        """Give ``queues`` the outage window [fail_at, heal_at). One
        window per queue (a later flap overwrites an earlier one)."""
        qs = np.atleast_1d(np.asarray(queues, np.int64))
        hot = np.zeros(self.fail_at.shape[-1:], bool)
        hot[qs] = True
        hot = jnp.broadcast_to(jnp.asarray(hot), self.fail_at.shape)
        return replace(
            self,
            fail_at=jnp.where(hot, jnp.int32(fail_at), self.fail_at),
            heal_at=jnp.where(hot, jnp.int32(heal_at), self.heal_at),
        )

    def lossy(self, queues, p: float) -> "FaultSchedule":
        """Make ``queues`` gray links dropping each packet w.p. ``p``."""
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"loss probability must be in [0, 1], got {p}")
        qs = np.atleast_1d(np.asarray(queues, np.int64))
        hot = np.zeros(self.loss_p.shape[-1:], bool)
        hot[qs] = True
        hot = jnp.broadcast_to(jnp.asarray(hot), self.loss_p.shape)
        return replace(self, loss_p=jnp.where(hot, jnp.float32(p),
                                              self.loss_p))

    def corrupt(self, queues, p: float) -> "FaultSchedule":
        """Give ``queues`` a PHY bit-error rate: each TRANSMISSION out of
        the queue is corrupted independently w.p. ``p``. Link-recoverable
        (see the module docstring) — arm ``LinkConfig(llr=True)`` to
        replay at the hop instead of dropping silently."""
        if not 0.0 <= p <= 1.0:
            raise ValueError(
                f"corruption probability must be in [0, 1], got {p}")
        qs = np.atleast_1d(np.asarray(queues, np.int64))
        hot = np.zeros(self.corrupt_p.shape[-1:], bool)
        hot[qs] = True
        hot = jnp.broadcast_to(jnp.asarray(hot), self.corrupt_p.shape)
        return replace(self, corrupt_p=jnp.where(hot, jnp.float32(p),
                                                 self.corrupt_p))

    def _host_window(self, hosts, at: int, heal_at: int, kind: str
                     ) -> tuple:
        if self.num_hosts == 0:
            raise ValueError(
                f"{kind} needs host lanes: build the schedule with "
                f"FaultSchedule.healthy(num_queues, num_hosts=H) or call "
                f".with_hosts(H) first")
        hs = np.atleast_1d(np.asarray(hosts, np.int64))
        if hs.size and (hs.min() < 0 or hs.max() >= self.num_hosts):
            raise ValueError(f"{kind} host ids must be in "
                             f"[0, {self.num_hosts}), got {hs.tolist()}")
        hot = np.zeros((self.num_hosts,), bool)
        hot[hs] = True
        hot = jnp.broadcast_to(jnp.asarray(hot), self.host_fail_at.shape)
        return hot, jnp.int32(at), jnp.int32(heal_at)

    def host_fail(self, hosts, fail_at: int,
                  heal_at: int = NEVER_TICK) -> "FaultSchedule":
        """Kill ``hosts`` over [fail_at, heal_at): no injection, no ACK
        processing or generation, no delivery absorption. One window per
        host (a later call overwrites an earlier one)."""
        hot, f, h = self._host_window(hosts, fail_at, heal_at, "host_fail")
        return replace(self,
                       host_fail_at=jnp.where(hot, f, self.host_fail_at),
                       host_heal_at=jnp.where(hot, h, self.host_heal_at))

    def nic_stall(self, hosts, stall_at: int,
                  heal_at: int = NEVER_TICK) -> "FaultSchedule":
        """Freeze ``hosts``' injection over [stall_at, heal_at) while
        keeping them ACK-live (deliveries absorbed + acknowledged, RTO
        clocks running) — the wedged-send-engine fault class."""
        hot, f, h = self._host_window(hosts, stall_at, heal_at, "nic_stall")
        return replace(self,
                       nic_stall_at=jnp.where(hot, f, self.nic_stall_at),
                       nic_heal_at=jnp.where(hot, h, self.nic_heal_at))

    def with_seed(self, seed) -> "FaultSchedule":
        return replace(self, seed=jnp.broadcast_to(
            jnp.asarray(seed, jnp.uint32), self.seed.shape))

    @staticmethod
    def stack(scheds: "list[FaultSchedule]") -> "FaultSchedule":
        """Stack per-scenario [Q] schedules into a [B, Q] batch. Mixed
        host-lane widths {0, H} are normalized (zero-width lanes widen
        to all-healthy [H] lanes); two distinct nonzero widths are an
        error."""
        widths = {s.num_hosts for s in scheds}
        nz = sorted(w for w in widths if w)
        if len(nz) > 1:
            raise ValueError(f"cannot stack schedules with host lanes "
                             f"over different host counts: {nz}")
        if nz and 0 in widths:
            scheds = [s.with_hosts(nz[0]) for s in scheds]
        return jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls), *scheds)

    # -- views ------------------------------------------------------------
    @property
    def num_queues(self) -> int:
        return int(self.fail_at.shape[-1])

    @property
    def num_hosts(self) -> int:
        """Width of the per-host lanes (0 = no endpoint faults)."""
        return int(self.host_fail_at.shape[-1])

    @property
    def has_host_faults(self) -> bool:
        """True iff any host outage / NIC stall is actually scheduled —
        the dispatch-time static that selects the endpoint-aware
        executable (mirrors the gray-link ``lossy`` static). All-healthy
        host lanes compile the exact pre-endpoint-fault program."""
        if self.num_hosts == 0:
            return False
        return bool(
            (np.asarray(self.host_fail_at) != NEVER_TICK).any()
            or (np.asarray(self.nic_stall_at) != NEVER_TICK).any())

    @property
    def has_corruption(self) -> bool:
        """True iff any queue has a nonzero BER lane — the dispatch-time
        static (``corrupty``) that selects the corruption-aware
        executable, mirroring ``lossy``. BER-free schedules compile the
        exact pre-corruption program."""
        return bool(np.asarray(self.corrupt_p).any())

    def dead_at(self, tick) -> jax.Array:
        """[.., Q] bool — queues dead at ``tick`` (the engine's per-tick
        derivation; exposed for tests/diagnostics)."""
        t = jnp.asarray(tick, jnp.int32)
        return (self.fail_at <= t) & (t < self.heal_at)

    def host_dead_at(self, tick) -> jax.Array:
        """[.., H] bool — hosts dead at ``tick``."""
        t = jnp.asarray(tick, jnp.int32)
        return (self.host_fail_at <= t) & (t < self.host_heal_at)

    def nic_stalled_at(self, tick) -> jax.Array:
        """[.., H] bool — hosts with frozen injection at ``tick``."""
        t = jnp.asarray(tick, jnp.int32)
        return (self.nic_stall_at <= t) & (t < self.nic_heal_at)


def loss_threshold(loss_p: jax.Array) -> jax.Array:
    """[.., Q] uint32 compare threshold for the counter-based loss draw:
    a packet is lost iff its uniform hash u32 < threshold. p=0 maps to
    threshold 0 (never — bitwise inert); p=1 maps to the largest float32
    below 2**32 (loses all but ~1 in 2**24 draws; use a dead window for
    hard cuts)."""
    return (jnp.clip(loss_p, 0.0, 1.0) * jnp.float32(4294967040.0)
            ).astype(jnp.uint32)


def as_schedule(g_num_queues: int, failed, faults, batch: "int | None" = None,
                g_num_hosts: "int | None" = None) -> FaultSchedule:
    """Normalize the public (failed=, faults=) pair to one FaultSchedule
    with [Q] (serial) or [batch, Q] leaves. Exactly one of the two may
    be given; neither means all-healthy. ``g_num_hosts`` (when given)
    validates nonzero host lanes against the topology."""
    if faults is not None:
        if failed is not None:
            raise ValueError("pass either failed= (static mask) or "
                             "faults= (FaultSchedule), not both")
        if not isinstance(faults, FaultSchedule):
            raise TypeError(f"faults= must be a FaultSchedule, got "
                            f"{type(faults).__name__}")
        if faults.num_queues != g_num_queues:
            raise ValueError(
                f"fault schedule is over {faults.num_queues} queues but "
                f"the topology has {g_num_queues}")
        if (g_num_hosts is not None and faults.num_hosts
                and faults.num_hosts != g_num_hosts):
            raise ValueError(
                f"fault schedule host lanes are over {faults.num_hosts} "
                f"hosts but the topology has {g_num_hosts}")
        if batch is None:
            if faults.fail_at.ndim != 1:
                raise ValueError("serial simulate() takes a [Q] fault "
                                 f"schedule, got {faults.fail_at.shape}")
            return faults
        if faults.fail_at.ndim == 1:
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (batch,) + a.shape), faults)
        if faults.fail_at.shape[0] != batch:
            raise ValueError(f"fault schedule batch axis is "
                             f"{faults.fail_at.shape[0]}, expected {batch}")
        return faults
    return None  # caller falls back to the failed= mask path


def _smoke() -> int:  # pragma: no cover — CLI smoke for scripts/check.sh
    """Recovery canary: (1) a mid-run uplink flap is survived — timeouts
    fire while the link is down, the flow completes after heal, and the
    degraded-tick counter brackets the outage; (2) a PERMANENT mid-run
    failure of a pinned static path is escaped via EV eviction (the
    eviction-off twin stays stuck)."""
    from dataclasses import replace as _rep

    from repro.core.lb.schemes import LBScheme
    # canonical class, NOT the __main__ copy this file becomes under -m
    from repro.network.faults import FaultSchedule as FS
    from repro.network.fabric import (SimParams, TransportProfile, Workload,
                                      simulate)
    from repro.network.topology import leaf_spine

    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2, 3], [4, 5, 6, 7], 150)
    p = SimParams(ticks=4000, timeout_ticks=64)
    up = [int(g.up1_table[0, i]) for i in range(2)]

    # 1) flap: both uplinks down over [120, 420) — no surviving path
    #    during the window, full recovery after heal
    flap = FS.healthy(g.num_queues).flap(up, 120, 420)
    r = simulate(g, wl, TransportProfile.ai_full(), p, faults=flap)
    ct = r.completion_tick()
    assert ct > 420, f"flap scenario should finish after heal, got {ct}"
    assert r.timeouts > 0, "outage must trigger RTO timeouts"
    assert r.ticks_degraded == 300, r.ticks_degraded

    # 2) permanent failure of a static path: eviction-on escapes,
    #    eviction-off is stuck at the budget
    dead = FS.healthy(g.num_queues).flap(up[0], 120)
    off = TransportProfile.ai_full(lb=LBScheme.STATIC, name="static")
    on = _rep(off, ev_eviction=True, name="static+evict")
    r_off = simulate(g, wl, off, p, faults=dead)
    r_on = simulate(g, wl, on, p, faults=dead)
    ct_on = r_on.completion_tick()
    assert ct_on != -1, "eviction must migrate flows off the dead path"
    assert r_on.ev_evictions > 0
    ct_off = r_off.completion_tick()
    assert ct_off == -1 or ct_on < ct_off, (ct_on, ct_off)
    print(f"fault smoke ok: flap survived (completion {ct}, "
          f"{r.timeouts} timeouts, {r.ticks_degraded} degraded ticks); "
          f"permanent failure escaped via {r_on.ev_evictions} evictions "
          f"(completion {ct_on} vs eviction-off "
          f"{'stuck' if ct_off == -1 else ct_off})")
    return 0


def _endpoint_smoke() -> int:  # pragma: no cover — CLI canary (check.sh)
    """Endpoint canary: a never-healing dead host under a
    ``pdc_dead_after`` profile must be DETECTED (flows to/from it
    quarantined, abandonment visible in the stat lanes) and the run must
    quiesce EARLY — strictly before the tick budget — while every
    surviving flow still completes. The pdc-off twin burns the whole
    budget on the same schedule (the liveness hazard the quarantine
    path exists to fix)."""
    from repro.network import workloads
    from repro.network.fabric import SimParams, simulate_batch

    g, wls, scheds, exp = workloads.host_fault_sweep()
    budget = int(exp["budget"])
    p = SimParams(ticks=budget, timeout_ticks=64)
    rs = simulate_batch(g, wls, exp["profile"], p, faults=scheds)
    by = dict(zip(exp["names"], rs))

    r = by["host_dead"]
    dead_flows = exp["dead_flows"]
    assert r.horizon < budget, \
        f"dead host must quiesce early, ran {r.horizon}/{budget}"
    assert r.flows_abandoned == len(dead_flows), \
        (r.flows_abandoned, dead_flows)
    assert r.ticks_unreachable > 0 and r.abandon_tick > 0
    ct = r.completion_ticks()
    surviving = [f for f in range(ct.shape[0]) if f not in dead_flows]
    assert all(ct[f] > 0 for f in surviving), ct
    assert all(ct[f] == -1 for f in dead_flows), ct

    r_off = by["host_dead_pdc_off"]
    assert r_off.horizon == budget, \
        f"pdc-off twin must burn the budget, exited at {r_off.horizon}"
    assert r_off.flows_abandoned == 0

    r_stall = by["nic_stall"]
    assert r_stall.flows_abandoned == 0, \
        "an ACK-live NIC stall must not be declared dead"
    assert r_stall.completion_tick() > 0, "stall heals -> all complete"

    healthy = by["healthy"]
    assert healthy.flows_abandoned == 0 and healthy.ticks_unreachable == 0
    print(f"endpoint smoke ok: dead host detected at tick "
          f"{r.abandon_tick} ({r.flows_abandoned} flows abandoned, "
          f"{r.ticks_unreachable} unreachable ticks), quiesced at "
          f"{r.horizon}/{budget} vs pdc-off stuck at {r_off.horizon}; "
          f"NIC stall stayed live (completion "
          f"{r_stall.completion_tick()})")
    return 0


if __name__ == "__main__":  # pragma: no cover
    import sys
    if "--endpoint" in sys.argv[1:]:
        raise SystemExit(_endpoint_smoke())
    raise SystemExit(_smoke())
