"""mixtral-8x22b: MoE 8e top-2, sliding-window attention
[arXiv:2401.04088; hf]."""
from repro.configs.base import ArchConfig, pad_for_tp, MIXER_ATTN, FFN_MOE

CONFIG = pad_for_tp(ArchConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=16384, vocab_size=32768,
    num_experts=8, experts_per_token=2,
    sliding_window=4096,
    pattern=((MIXER_ATTN, FFN_MOE),),
    source="arXiv:2401.04088; hf",
))
