"""Quickstart: the UET transport in 60 seconds.

The API is declarative: pick a ``TransportProfile`` (WHAT transport
composition to run — congestion control, load balancing, per-flow
delivery modes; the paper's Sec. 2.2 profile table ships as
``ai_base()`` / ``ai_full()`` / ``hpc()``), pass numeric knobs in
``SimParams``, and call ``simulate`` / ``simulate_batch``. Exploring a
new operating point is a config sweep, not a code fork: this script runs

  [1] a 4->1 incast under the three named profiles (one batched call),
  [2] the CC ablation (NSCC-only vs RCCC-only vs hybrid) on an outcast,
  [3] static ECMP vs REPS spraying on permutation traffic (Sec. 2.1),
  [4] a mixed ROD+RUD profile showing the in-order delivery invariant,
  [5] a failure sweep batched into ONE compiled scan,
  [6] whole collectives (dep-scheduled) + in-network reduction,
  [7] the adaptive-horizon engine: quiescence early-exit + trace tiers,
  [8] dynamic faults: a mid-run link flap + a gray link, survived by
      the recovery loop (RTO backoff + path eviction, Sec 3.2.4),
  [9] model-driven traffic: a real model config's parallelism plan
      (derived from the ACTUAL sharding rules) compiled to a dep-chained
      multi-collective step on the fabric and priced end-to-end —
      simulated step time and tokens/sec for one operating point,
  [10] the telemetry plane: O(1)-memory probe rings that make a mid-run
      outage VISIBLE (drop/mark/goodput signatures) without perturbing
      a single bit of the simulation,
  [11] endpoint failure, priced: a host dies mid-run, the PDC liveness
      teardown detects and quarantines it (early quiescence instead of
      a burned budget), and the checkpoint-restart economics turn the
      measured detection/restore/replan costs into effective tokens/sec
      at the Young/Daly optimal checkpoint interval,
  [12] link-layer reliability (Sec 3.5): a BER-y link corrupts frames,
      LLR replays them at the hop (zero end-to-end drops, tail
      completion beats end-to-end RTO recovery), CBFC credits make the
      fabric lossless by back-pressure instead of trims — with the
      telemetry view showing WHERE the replays landed.

The engine runs every scenario on a chunked while-scan that EXITS as
soon as the scenario is quiescent — a generous tick budget costs only
what the scenario actually needs, and the budget is traced, so one
compiled executable serves every horizon. By default results carry
streaming statistics only (``trace="stats"``): per-flow completion
ticks and any goodput window you register up front
(``goodput_window=(w0, w1)``). Ask for ``trace="full"`` when you want
the dense per-tick lanes. On this repo's 2-core reference box the
15-scenario collective sweep (1600-tick budget) went from 19.5 s warm /
32 s cold (PR 3, fixed-horizon) to ~2.3 s warm / ~14.5 s cold — same
completion ticks, bit for bit.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.lb.schemes import LBScheme
from repro.network import workloads
from repro.network.fabric import SimParams, Workload, simulate, simulate_batch
from repro.network.profile import (CCAlgo, DeliveryMode, TransportProfile,
                                   cc_ablation)


def main():
    print("=== UET quickstart ===")

    print("\n[1] incast 4->1 across the paper's profiles (Sec 2.2) — one "
          "simulate_batch call, grouped by profile")
    g, wl, exp = workloads.incast(4, size=100000)
    profiles = [TransportProfile.ai_base(), TransportProfile.ai_full(),
                TransportProfile.hpc()]
    results = simulate_batch(g, Workload.stack([wl] * 3), profiles,
                             SimParams(ticks=1200),
                             goodput_window=(300, 1200))
    for prof, r in zip(profiles, results):
        gp = r.goodput((300, 1200))
        print(f"    {prof.name:8s} ({prof.describe()[len(prof.name):]}): "
              f"per-flow goodput {np.round(gp, 3)}")
    print(f"    (paper: {exp['share']:.2f} each is optimal; ai_base's RCCC "
          f"hits it exactly, Fig. 7 group 4)")

    print("\n[2] CC ablation on an outcast (Fig. 7 group 1): receiver "
          "credits are blind to the sender bottleneck")
    g, wl, exp = workloads.outcast(4, size=100000)
    for prof in cc_ablation():
        r = simulate(g, wl, prof, SimParams(ticks=2500),
                     goodput_window=(1200, 2500))
        print(f"    {prof.name:9s}: w->v share {r.goodput((1200, 2500))[4]:.3f} "
              f"(RCCC grants {exp['rccc_w_share']:.2f}, optimum "
              f"{exp['nscc_w_share']:.2f})")

    print("\n[3] permutation traffic: static ECMP vs REPS spraying "
          "(Sec 2.1 polarization)")
    g, wl, _ = workloads.permutation(k=8, pods=4, shift=17, size=100000)
    for scheme in (LBScheme.STATIC, LBScheme.REPS):
        r = simulate(g, wl, TransportProfile.ai_full(lb=scheme),
                     SimParams(ticks=1500), goodput_window=(700, 1500))
        gp = r.goodput((700, 1500))
        print(f"    {scheme.name:9s}: mean {gp.mean():.3f}  "
              f"worst flow {gp.min():.3f}")

    print("\n[4] per-flow delivery modes: flow 0 ordered (ROD), flow 1 "
          "sprayed (RUD) in ONE profile (Sec 3.2.1)")
    g, wl, _ = workloads.incast(2, size=400)
    prof = TransportProfile(cc=CCAlgo.NSCC, lb=LBScheme.REPS,
                            delivery=(DeliveryMode.ROD, DeliveryMode.RUD),
                            name="mixed")
    # the in-order invariant needs the dense per-tick lanes: trace="full"
    r = simulate(g, wl, prof, SimParams(ticks=3000), trace="full")
    cum = r.delivered_per_tick.cumsum(axis=0)
    in_order = bool((cum[:, 0].astype(np.uint32)
                     == r.rx_base_per_tick[:, 0]).all())
    print(f"    completion tick {r.completion_tick()}; ROD lane delivered "
          f"strictly in order: {in_order} (trims={int(r.state.trims)})")

    print("\n[5] failure sweep, batched: healthy + one-dead-uplink x4, "
          "one vmapped scan (REPS, Sec 3.2.4)")
    g, wls, masks, exp = workloads.failure_sweep(spines=4, hosts_per_leaf=8)
    p = SimParams(ticks=3000, timeout_ticks=64, ooo_threshold=24)
    results = simulate_batch(g, wls, TransportProfile.ai_full(lb=LBScheme.REPS),
                             p, failed=masks, goodput_window=(1500, 3000))
    for i, r in enumerate(results):
        tag = "healthy   " if i == 0 else f"uplink {i - 1} dead"
        gp = r.goodput((1500, 3000)).mean()
        ref = exp["healthy_share"] if i == 0 else exp["degraded_share"]
        print(f"    {tag}: mean goodput {gp:.3f} (optimum {ref:.3f}, "
              f"drops {int(r.state.drops)})")

    print("\n[6] whole collectives on the fabric: dependency-scheduled "
          "all-reduce algorithms + in-network reduction (INC)")
    # a collective is a Workload whose `dep` lane encodes the phase DAG;
    # the whole multi-phase exchange runs inside ONE compiled scan, and a
    # kind x algorithm x INC grid is one simulate_batch call.
    from dataclasses import replace

    from repro.network import collectives as coll
    g = workloads.leaf_spine(leaves=4, spines=4, hosts_per_leaf=2)
    spec = coll.CollectiveSpec("all_reduce", tuple(range(8)), 32)
    ai = TransportProfile.ai_full()
    cfgs = [("ring", ai), ("recursive_doubling", ai), ("tree", ai),
            ("tree", replace(ai, inc=True, name="ai_full+inc"))]
    wls = coll.stack_padded([coll.build_workload(spec, a) for a, _ in cfgs])
    rs = simulate_batch(g, wls, [pr for _, pr in cfgs], SimParams(ticks=1200))
    cts = {}
    for (algo, pr), r in zip(cfgs, rs):
        name = f"{algo}{'+inc' if pr.inc else ''}"
        cts[name] = coll.collective_completion_ticks(r)
        extra = (f", {int(r.state.inc_reduced)} pkts absorbed at the ToR"
                 if pr.inc else "")
        print(f"    {name:22s}: completion tick {cts[name]}{extra}")
    if cts["tree"] > 0 and cts["tree+inc"] > 0:
        print(f"    (INC-on tree finishes in "
              f"{cts['tree+inc'] / cts['tree']:.2f}x the INC-off time: the "
              f"switch reduces the incast away)")

    print("\n[7] adaptive horizon: the budget is a bound, not a cost")
    g, wl, _ = workloads.incast(4, size=600)
    # a wildly generous budget: the chunked while-scan exits at the
    # first quiescent chunk boundary, and because max_ticks is traced,
    # both runs below share ONE compiled executable
    r1 = simulate(g, wl, TransportProfile.ai_full(), SimParams(),
                  max_ticks=50_000)
    r2 = simulate(g, wl, TransportProfile.ai_full(), SimParams(),
                  max_ticks=5_000)
    print(f"    budget 50000: executed {r1.horizon} ticks "
          f"(completion {r1.completion_tick()}); budget 5000: executed "
          f"{r2.horizon} — same executable, same bits")
    assert r1.completion_tick() == r2.completion_tick()

    print("\n[8] dynamic faults: links that flap and gray-fail mid-run, "
          "and the recovery loop that survives them")
    # a FaultSchedule is a traced input like the workload: per-queue
    # outage windows (dead while fail_at <= t < heal_at) + per-queue
    # packet-loss probability; drops are SILENT (no NACK), recovery is
    # the transport's job — RTO backoff, OOO loss inference, and EV
    # (path) eviction off dead paths
    from repro.network.faults import FaultSchedule
    g = workloads.leaf_spine(leaves=2, spines=2, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2, 3], [4, 5, 6, 7], 150)
    ups = [int(g.up1_table[0, i]) for i in range(2)]
    sched = (FaultSchedule.healthy(g.num_queues)
             .flap(ups[0], 150, 500)       # uplink 0 flaps for 350 ticks
             .lossy(ups[1], 0.05))         # uplink 1 drops 5% silently
    prof = replace(TransportProfile.ai_full(lb=LBScheme.REPS),
                   ev_eviction=True, rto_backoff=2.0, name="ai_full+rec")
    r = simulate(g, wl, prof,
                 SimParams(ticks=8000, timeout_ticks=64, ooo_threshold=24),
                 faults=sched)
    print(f"    completion tick {r.completion_tick()} (healthy fabric "
          f"~{wl.size.max()}+): {r.timeouts} timeouts, "
          f"{r.rtx_packets} rtx, {r.ev_evictions} evictions, "
          f"{r.ticks_degraded} degraded ticks, "
          f"{int(r.state.drops)} silent drops recovered")
    assert r.completion_tick() != -1

    print("\n[9] model-driven traffic: one config, plan -> schedule -> "
          "simulated step time")
    # derive the per-step collective demand from the real sharding rules
    # (ZeRO-3 param gathers, per-layer TP all-reduces, grad
    # reduce-scatter), compile it to ONE dep-chained workload on a
    # leaf-spine, simulate, and price the training step
    from repro import configs
    from repro.distributed.plan import derive_plan, describe
    from repro.network.traffic import step_time
    from repro.network.topology import leaf_spine
    plan = derive_plan(configs.get("deepseek-coder-33b"), "train_4k",
                       dp=16, tp=16, layout="fsdp_tp")
    print("    " + describe(plan).replace("\n", "\n    "))
    t = step_time(plan, leaf_spine(4, 2, 4), TransportProfile.ai_full(),
                  max_pkts=8)
    print(f"    simulated step: {t.step_s * 1e3:.1f} ms "
          f"(net {t.net_s * 1e3:.1f} ms vs {t.analytic_net_s * 1e3:.1f} ms "
          f"alpha-beta bound; compute {t.compute_s * 1e3:.1f} ms) -> "
          f"{t.tokens_per_sec:,.0f} tokens/s, "
          f"{t.time_to_train(1e12) / 86400:.1f} days to 1T tokens")
    assert t.net_s >= t.analytic_net_s

    print("\n[10] telemetry: fixed-memory probe rings streamed off the "
          "signals the tick already computes")
    # TelemetrySpec is static like the profile — off (the default) is
    # literally free, on attaches a FabricTrace with decimated
    # per-queue/per-flow time series; probes never perturb the run
    from repro.network.faults import FaultSchedule
    from repro.network.telemetry import TelemetrySpec
    g, wl, exp = workloads.victim_sweep(pairs=4, uplinks=2, size=2500)
    sched = FaultSchedule.healthy(g.num_queues).flap(
        exp["uplinks"][0], 300, 700)
    r = simulate(g, wl, TransportProfile.ai_full(lb=LBScheme.REPS),
                 SimParams(ticks=1200, timeout_ticks=64, ooo_threshold=24),
                 faults=sched, telemetry=TelemetrySpec.on())
    tr = r.telemetry
    pre, dur = tr.window_rates(100, 300), tr.window_rates(350, 700)
    print(f"    {tr.num_samples} samples at {tr.sample_spacing}-tick "
          f"spacing (ring decimated to stride {tr.stride})")
    print(f"    flap [300, 700): silent drops {pre['drop'].sum():.2f} -> "
          f"{dur['drop'].sum():.2f}/tick, goodput {pre['goodput']:.2f} -> "
          f"{dur['goodput']:.2f} pkts/tick — the outage is in the lanes")
    print("    (scripts/trace_export.py writes the same channels as "
          "Perfetto counter tracks)")
    assert dur["drop"].sum() > pre["drop"].sum()

    print("\n[11] endpoint failure, priced: fault -> detection -> teardown "
          "-> checkpoint-restart economics")
    # host lanes ride the schedule like link lanes; detection is the
    # transport's job (pdc_dead_after consecutive zero-progress RTO
    # strikes declare the peer dead and quarantine its flows), and the
    # layers above the fabric price what the loss costs
    g = workloads.leaf_spine(leaves=2, spines=2, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2, 6], [4, 5, 3, 0], 150)
    sched = FaultSchedule.healthy(
        g.num_queues, num_hosts=g.num_hosts).host_fail(4, 100)  # dies at 100
    prof = TransportProfile.resilient()   # NSCC + RUD + backoff + teardown
    budget = 6000
    r = simulate(g, wl, prof, SimParams(ticks=budget, timeout_ticks=64),
                 faults=sched)
    print(f"    host 4 dead at tick 100: detected at tick {r.abandon_tick} "
          f"({r.flows_abandoned} flows abandoned), run quiesced at "
          f"{r.horizon}/{budget} — no budget burn; survivors "
          f"{[int(c) for c in r.completion_ticks() if c > 0]}")
    assert r.horizon < budget and r.flows_abandoned > 0
    # price the full recovery loop for a real train plan: detection
    # (simulated, above), sharded-checkpoint restore, replan onto the
    # survivors — then the Young/Daly interval maximizes availability
    from repro.ckpt.checkpointing import (availability, effective_rate,
                                          young_daly_interval)
    from repro.network.traffic import checkpoint_seconds, price_recovery
    plan = derive_plan(configs.get("deepseek-coder-33b"), "train_4k",
                       dp=4, tp=4, layout="fsdp_tp")
    rc = price_recovery(plan)
    write_s = checkpoint_seconds(plan)
    mtbf = 3600.0
    tau = young_daly_interval(mtbf, write_s)
    kw = dict(write_s=write_s, detect_s=rc.detect_s,
              restore_s=rc.restore_s, replan_s=rc.replan_s)
    print(f"    recovery costs: detect {rc.detect_s * 1e3:.3f} ms "
          f"({rc.detect_ticks} ticks), restore {rc.restore_s:.2f} s, "
          f"replan {rc.replan_s:.1f} s; degraded rate "
          f"{rc.degraded_tokens_per_sec:,.0f} of "
          f"{rc.healthy_tokens_per_sec:,.0f} tokens/s")
    print(f"    1h MTBF: checkpoint every {tau:.0f} s (Young/Daly) -> "
          f"availability {availability(tau, mtbf, **kw):.4f}, "
          f"{effective_rate(rc.healthy_tokens_per_sec, tau, mtbf, **kw):,.0f}"
          f" effective tokens/s (vs "
          f"{effective_rate(rc.healthy_tokens_per_sec, 900.0, mtbf, **kw):,.0f}"
          f" at a naive 15-min interval)")
    assert (effective_rate(rc.healthy_tokens_per_sec, tau, mtbf, **kw)
            > effective_rate(rc.healthy_tokens_per_sec, 900.0, mtbf, **kw))

    print("\n[12] link-layer reliability (Sec 3.5): corruption confined to "
          "the hop by LLR replay, losslessness by CBFC credits")
    # LinkConfig is a compile-key static like TelemetrySpec: off (the
    # default) compiles the exact pre-feature program; llr=True arms
    # per-queue go-back-N replay (a corrupted frame is DELAYED by the
    # link RTT, never dropped), cbfc=True meters enqueues with 20-bit
    # cyclic credits (exhaustion back-pressures instead of trimming)
    from repro.core.link import LinkConfig, fabric_buffer_pricing
    g, wls, scheds, exp = workloads.corruption_sweep(bers=(0.0, 0.04))
    prof, p = exp["profile"], exp["params"]
    on = simulate_batch(g, wls, prof, p, faults=scheds, link=exp["link"],
                        telemetry=TelemetrySpec.on())
    off = simulate_batch(g, wls, prof, p, faults=scheds)
    r_llr, r_e2e = on[1], off[1]
    print(f"    BER 4% on {len(exp['uplinks'])} uplinks: LLR replayed "
          f"{r_llr.llr_replays} corrupted frames at their hop "
          f"({int(r_llr.drops)} e2e drops), completion "
          f"{r_llr.completion_tick()} vs {r_e2e.completion_tick()} under "
          f"e2e-only recovery ({int(r_e2e.drops)} silent drops, "
          f"{r_e2e.timeouts} RTOs)")
    llr_q = np.asarray(on[1].telemetry.final["llr_q"])
    print(f"    telemetry: replays landed on queues "
          f"{np.nonzero(llr_q)[0].tolist()} (the corrupted uplinks are "
          f"{list(exp['uplinks'])})")
    assert int(r_llr.drops) == 0 and int(r_e2e.drops) > 0
    assert r_llr.completion_tick() < r_e2e.completion_tick()
    # CBFC: the congested clean lane stops trimming, and the buffer it
    # needs undercuts PFC's per-(port, priority) headroom
    cb = simulate_batch(g, wls, prof, p, faults=scheds,
                        link=LinkConfig.on(llr=True, cbfc=True))[0]
    bill = fabric_buffer_pricing(g.num_queues)
    print(f"    CBFC on the clean congested lane: {int(cb.trims)} trims "
          f"(e2e arm trimmed {int(off[0].trims)}), "
          f"{cb.credit_stall_ticks} stall ticks; lossless buffer bill "
          f"{bill['cbfc_total_bytes'] / 1e6:.1f} MB vs "
          f"{bill['pfc_total_bytes'] / 1e6:.1f} MB PFC headroom "
          f"({bill['cbfc_over_pfc']:.2f}x per port)")
    assert int(cb.trims) == 0 and cb.credit_stall_ticks > 0


if __name__ == "__main__":
    main()
