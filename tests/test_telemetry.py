"""Fabric telemetry plane (repro.network.telemetry): contracts.

Locked here (see DESIGN.md "Telemetry contract"):

* telemetry OFF is FREE — ``telemetry=None`` and an off spec normalize
  to the same compile-cache key as the pre-telemetry engine, and an
  off-run's ``trace="full"`` lanes stay bitwise equal to the PR-2
  golden anchors;
* probes OBSERVE, never perturb — a telemetry-on run's final SimState
  is bitwise the off-run's;
* probe lanes are bitwise deterministic across serial / batched /
  device-sharded execution, and invariant to ``chunk_ticks`` and to
  freeze boundaries (a completed lane's ring stops, the live lanes
  keep sampling);
* adaptive decimation keeps ONE fixed-size ring tick-uniform at any
  horizon, and a finer ``probe_every`` agrees with a coarser one at
  every common sample tick (cumulative channels are lossless);
* idle (zero-size) scenario lanes are telemetry-inert — all-zero rings,
  the padding-lane story for sharding;
* ``workloads.victim_sweep`` is the ONE victim-share definition shared
  with ``profile_ablation_sweep`` and the flap canary;
* the SimResult convenience counters (trims / drops / dups) mirror the
  final-state scalars.

conftest.py forces 4 virtual CPU devices; sharded tests skip (not
fail) with fewer than 2.
"""
import os
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lb.schemes import LBScheme
from repro.network import workloads
from repro.network.fabric import (SimParams, Workload, _cache_key, simulate,
                                  simulate_batch)
from repro.network.faults import FaultSchedule
from repro.network.profile import TransportProfile
from repro.network.telemetry import (FabricTrace, TelemetrySpec,
                                     flap_victim_scenario)
from repro.network.topology import leaf_spine

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "fabric_golden.npz")

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=4; set by tests/conftest.py unless overridden)")

_TRACE_FIELDS = ("ticks", "occ", "ecn", "trim", "drop", "peak_q", "rtt",
                 "cwnd", "inflight", "degraded", "delivered")


def _state_equal(a, b) -> bool:
    return all(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b)))


def _assert_traces_equal(a: FabricTrace, b: FabricTrace, label=""):
    assert a.stride == b.stride, label
    for f in _TRACE_FIELDS:
        x, y = getattr(a, f), getattr(b, f)
        if x is None or y is None:
            assert x is None and y is None, f"{label} {f}"
            continue
        np.testing.assert_array_equal(x, y, err_msg=f"{label} {f}")


def _small_flap(fail_at=200, heal_at=700):
    """A compile-cheap victim-share flap: 4 cross-leaf pairs through 2
    uplinks, one uplink flapping mid-run, non-completing budget."""
    g, wl, exp = workloads.victim_sweep(pairs=4, uplinks=2, size=2500)
    sched = FaultSchedule.healthy(g.num_queues).flap(
        exp["uplinks"][0], fail_at, heal_at)
    prof = TransportProfile.ai_full(lb=LBScheme.REPS)
    p = SimParams(ticks=1200, timeout_ticks=64, ooo_threshold=24)
    return g, wl, prof, p, sched


# ------------------------------------------------------------------------
# spec validation + off-gating
# ------------------------------------------------------------------------

def test_spec_validation():
    assert not TelemetrySpec.off().enabled
    assert TelemetrySpec.on(probe_every=8, slots=32).enabled
    with pytest.raises(ValueError, match="probe_every"):
        TelemetrySpec(probe_every=0)
    with pytest.raises(ValueError, match="slots"):
        TelemetrySpec(slots=7)
    with pytest.raises(ValueError, match="slots"):
        TelemetrySpec(slots=0)
    with pytest.raises(ValueError, match="ewma_shift"):
        TelemetrySpec(ewma_shift=17)


def test_off_spec_shares_the_pre_telemetry_cache_key():
    """None and TelemetrySpec.off() must hit the SAME executable as the
    pre-telemetry engine; an enabled spec must not."""
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    prof = TransportProfile.ai_full()
    p = SimParams()
    base = _cache_key(g, prof, p, 2, True, "stats")
    assert base == _cache_key(g, prof, p, 2, True, "stats", tel=None)
    assert base == _cache_key(g, prof, p, 2, True, "stats",
                              tel=TelemetrySpec.off())
    on = _cache_key(g, prof, p, 2, True, "stats", tel=TelemetrySpec.on())
    assert on != base
    # the spec's knobs pick the program: a different cadence recompiles
    assert on != _cache_key(g, prof, p, 2, True, "stats",
                            tel=TelemetrySpec.on(probe_every=8))


def test_telemetry_off_keeps_golden_full_trace_bitwise():
    """An explicit off spec through the public API reproduces the PR-2
    golden lanes bitwise — telemetry-off IS the pre-telemetry engine."""
    gold = np.load(GOLDEN)
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2], [4, 5, 6], 200)
    r = simulate(g, wl, TransportProfile.ai_full(), SimParams(ticks=300),
                 trace="full", telemetry=TelemetrySpec.off())
    assert r.telemetry is None
    h = r.horizon
    np.testing.assert_array_equal(r.delivered_per_tick,
                                  gold["a_delivered"][:h])
    np.testing.assert_array_equal(r.cwnd_per_tick, gold["a_cwnd"][:h])
    np.testing.assert_array_equal(np.asarray(r.state.delivered),
                                  gold["a_state_delivered"])


def test_enabled_spec_rejects_full_trace_and_wrong_types():
    g, wl, prof, p, sched = _small_flap()
    with pytest.raises(ValueError, match="stats"):
        simulate(g, wl, prof, p, trace="full",
                 telemetry=TelemetrySpec.on())
    with pytest.raises(TypeError, match="TelemetrySpec"):
        simulate(g, wl, prof, p, telemetry=True)


# ------------------------------------------------------------------------
# probes observe, never perturb
# ------------------------------------------------------------------------

def test_probes_do_not_perturb_and_counters_mirror_state():
    g, wl, prof, p, sched = _small_flap()
    r_on = simulate(g, wl, prof, p, faults=sched,
                    telemetry=TelemetrySpec.on())
    r_off = simulate(g, wl, prof, p, faults=sched)
    assert r_on.horizon == r_off.horizon
    assert _state_equal(r_on.state, r_off.state)
    assert r_off.telemetry is None
    tr = r_on.telemetry
    assert isinstance(tr, FabricTrace) and tr.spec.enabled
    assert tr.num_samples > 0 and tr.horizon == r_on.horizon
    # satellite: the SimResult counter properties mirror the state
    for r in (r_on, r_off):
        assert r.trims == int(np.asarray(r.state.trims))
        assert r.drops == int(np.asarray(r.state.drops))
        assert r.dups == int(np.asarray(r.state.dups))
    assert r_on.drops > 0, "the flap must actually drop packets"
    # the cumulative drop ring agrees with the scoreboard at the end
    assert int(tr.drop[-1].sum()) == int(tr.final["drop_q"].sum())


# ------------------------------------------------------------------------
# serial == batched == sharded, with freeze boundaries in play
# ------------------------------------------------------------------------

def _flap_batch():
    """Ragged 3-lane flap batch: one lane completes mid-run (freeze
    boundary), two run to budget; per-lane fault windows + seeds."""
    g, wl, prof, p, _ = _small_flap()
    sizes = (300, 2500, 900)
    wls = Workload.stack([replace(wl, size=jnp.full_like(wl.size, s))
                          for s in sizes])
    q = int(g.up1_table[0, 0])
    scheds = FaultSchedule.stack([
        FaultSchedule.healthy(g.num_queues).flap(q, 200, 500),
        FaultSchedule.healthy(g.num_queues).flap(q, 300, 800),
        FaultSchedule.healthy(g.num_queues).flap(q, 100, 1100),
    ])
    seeds = np.arange(3, dtype=np.uint32) + 0x5EED
    return g, wls, prof, p, scheds, seeds


def test_batched_traces_match_serial_bitwise():
    g, wls, prof, p, scheds, seeds = _flap_batch()
    spec = TelemetrySpec.on()
    rs = simulate_batch(g, wls, prof, p, faults=scheds, seeds=seeds,
                        telemetry=spec)
    assert len({r.horizon for r in rs}) > 1, "batch must be ragged"
    for i, r in enumerate(rs):
        solo = simulate(
            g, jax.tree_util.tree_map(lambda a: a[i], wls), prof, p,
            faults=jax.tree_util.tree_map(lambda a: a[i], scheds),
            seed=int(seeds[i]), telemetry=spec)
        assert solo.horizon == r.horizon, f"lane {i}"
        assert _state_equal(solo.state, r.state), f"lane {i}"
        _assert_traces_equal(solo.telemetry, r.telemetry, f"lane {i}")


@multi_device
def test_sharded_traces_match_batched_bitwise():
    """B=3 on all devices (ragged -> one padding lane) with per-lane
    FaultSchedules: the sharded probe rings equal the unsharded ones."""
    g, wls, prof, p, scheds, seeds = _flap_batch()
    spec = TelemetrySpec.on()
    base = simulate_batch(g, wls, prof, p, faults=scheds, seeds=seeds,
                          telemetry=spec)
    shd = simulate_batch(g, wls, prof, p, faults=scheds, seeds=seeds,
                         telemetry=spec, shard=True)
    assert len(shd) == len(base) == 3
    for i, (a, b) in enumerate(zip(base, shd)):
        assert a.horizon == b.horizon, f"lane {i}"
        assert _state_equal(a.state, b.state), f"lane {i}"
        _assert_traces_equal(a.telemetry, b.telemetry, f"lane {i}")


def test_chunk_size_is_invisible_in_the_probe_lanes():
    """chunk_ticks only tiles the while-scan; the sample decision
    depends on (tick, count, stride) alone, so 64/96/128-tick chunks
    (the 128 case takes a masked remainder chunk) must produce the
    identical FabricTrace."""
    g, wl, prof, p, sched = _small_flap()
    spec = TelemetrySpec.on()
    p = replace(p, ticks=960)
    traces = []
    for ck in (64, 96, 128):
        r = simulate(g, wl, prof, replace(p, chunk_ticks=ck),
                     faults=sched, telemetry=spec)
        assert r.horizon == 960, f"chunk {ck}: scenario must not complete"
        traces.append(r.telemetry)
    _assert_traces_equal(traces[0], traces[1], "chunk 64 vs 96")
    _assert_traces_equal(traces[0], traces[2], "chunk 64 vs 128")


# ------------------------------------------------------------------------
# adaptive decimation
# ------------------------------------------------------------------------

def test_decimation_keeps_the_ring_uniform_at_any_horizon():
    """slots=8 over a 1200-tick run forces several decimations: the
    surviving grid must stay tick-uniform at stride * probe_every
    spacing, within capacity, starting at tick 0."""
    g, wl, prof, p, sched = _small_flap()
    spec = TelemetrySpec.on(probe_every=16, slots=8)
    tr = simulate(g, wl, prof, p, faults=sched, telemetry=spec).telemetry
    assert tr.stride > 1, "the ring must have decimated"
    assert 0 < tr.num_samples <= 8
    assert tr.ticks[0] == 0
    assert (np.diff(tr.ticks) == tr.sample_spacing).all()
    assert tr.sample_spacing == tr.stride * 16


def test_finer_probe_every_agrees_at_common_sample_ticks():
    """probe_every=8 vs 16 on the same run: every channel is equal at
    the sample ticks both grids retain — the EWMA and the cumulative
    counters advance every tick, so WHEN you sample never changes WHAT
    you sample."""
    g, wl, prof, p, sched = _small_flap()
    fine = simulate(g, wl, prof, p, faults=sched,
                    telemetry=TelemetrySpec.on(probe_every=8)).telemetry
    coarse = simulate(g, wl, prof, p, faults=sched,
                      telemetry=TelemetrySpec.on(probe_every=16)).telemetry
    common, fi, ci = np.intersect1d(fine.ticks, coarse.ticks,
                                    return_indices=True)
    assert common.size >= 16, "grids must overlap substantially"
    for f in ("occ", "ecn", "trim", "drop", "rtt", "cwnd"):
        np.testing.assert_array_equal(getattr(fine, f)[fi],
                                      getattr(coarse, f)[ci], err_msg=f)
    for f in ("inflight", "degraded", "delivered"):
        np.testing.assert_array_equal(getattr(fine, f)[fi],
                                      getattr(coarse, f)[ci], err_msg=f)
    np.testing.assert_array_equal(fine.peak_q, coarse.peak_q)


def test_window_rates_are_exact_across_decimation():
    """Cumulative channels survive decimation losslessly: the drop count
    over the whole run recovered from window_rates equals the final
    accumulator, even after the ring decimated."""
    g, wl, prof, p, sched = _small_flap()
    tr = simulate(g, wl, prof, p, faults=sched,
                  telemetry=TelemetrySpec.on(slots=16)).telemetry
    assert tr.stride > 1
    last = int(tr.ticks[-1])
    r = tr.window_rates(0, last + 1)
    assert float(r["drop"].sum()) * (last + 1) == pytest.approx(
        float(tr.drop[-1].sum()))


# ------------------------------------------------------------------------
# channel gating + idle lanes
# ------------------------------------------------------------------------

def test_disabled_channel_groups_carry_no_lanes():
    g, wl, prof, p, sched = _small_flap()
    spec = TelemetrySpec.on(queues=False, gauges=False)
    tr = simulate(g, wl, prof, p, faults=sched, telemetry=spec).telemetry
    assert tr.occ.shape[1] == 0 and tr.ecn.shape[1] == 0
    assert tr.inflight is None and tr.delivered is None
    assert tr.rtt.shape[1] > 0, "flow channels stay on"
    with pytest.raises(ValueError, match="queue channels"):
        tr.window_rates(0, 100)


def test_idle_lane_rings_are_all_zero():
    """A zero-size lane (the sharding padding story) never injects:
    its probe rings must be identically zero."""
    g, wl, prof, p, _ = _small_flap()
    idle = jax.tree_util.tree_map(lambda a: a[0],
                                  workloads.noop_scenarios(wl.src.shape[0], 1))
    rs = simulate_batch(g, Workload.stack([wl, idle]), prof, p,
                        telemetry=TelemetrySpec.on())
    tr = rs[1].telemetry
    for f in ("occ", "ecn", "trim", "drop", "rtt"):
        assert (np.asarray(getattr(tr, f)) == 0).all(), f
    assert (tr.inflight == 0).all() and (tr.delivered == 0).all()
    assert int(np.asarray(rs[1].state.delivered).sum()) == 0
    # the busy lane next door is unaffected by sharing the batch
    assert (np.asarray(rs[0].telemetry.ecn) != 0).any()


# ------------------------------------------------------------------------
# the shared victim-share definition
# ------------------------------------------------------------------------

def test_victim_sweep_is_the_shared_definition():
    g, wl, exp = workloads.victim_sweep(pairs=6, uplinks=2, size=500)
    assert exp["victim_flow"] == 6
    assert len(exp["uplinks"]) == 2
    assert wl.src.shape[0] == 7           # pairs cross-leaf + 1 victim
    ga, wls, profiles, names, exp_a = workloads.profile_ablation_sweep(
        pairs=6, uplinks=2, size=500)
    assert exp_a["victim_flow"] == exp["victim_flow"]
    assert exp_a["uplinks"] == exp["uplinks"]
    for i in range(len(profiles)):        # every ablation lane IS wl
        np.testing.assert_array_equal(np.asarray(wls.src[i]),
                                      np.asarray(wl.src))
        np.testing.assert_array_equal(np.asarray(wls.size[i]),
                                      np.asarray(wl.size))
    g2, wl2, prof, p, sched, spec, (fail_at, heal_at) = \
        flap_victim_scenario()
    assert spec.enabled and fail_at < heal_at <= p.ticks
    assert sched.num_queues == g2.num_queues
