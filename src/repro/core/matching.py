"""SES message matching (Sec. 3.1.2).

Hardware message matching uses a packet-carried initiator ID (32b) and a
64-bit matching key. Profiles differ:

* HPC      — in-order *wildcard* matching (MPI semantics): the lowest-index
             posted entry whose unmasked bits equal the message key wins.
* AI FULL  — *exact* matching, unordered: any entry with full 64b+initiator
             equality may match (CAM-style); we return the lowest index for
             determinism, which a CAM is free to do.
* AI BASE  — no transport-layer matching (handled by the libfabric provider).

64-bit keys are carried as (hi, lo) uint32 pairs — the simulator runs in
JAX's default 32-bit mode, and two 32-bit lanes is exactly how a hardware
matcher would slice the key anyway.

The receive queue is a fixed-capacity structure-of-arrays; matching a batch
of arriving messages is one vectorized comparison — the shape of a hardware
matcher. Unexpected messages (no posted entry) return -1 and the caller
chooses the paper's options: discard + "buffer not ready", buffer headers,
or buffer partial payload (Sec. 3.1.2-3.1.3).

The message-id trick for in-order RUD matching (Sec. 3.2.1: the CCL places
a sequence number in the match bits so an unordered wire still fills buffers
in order) is `encode_match_key` / tested in tests/test_matching.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.types import Profile

ANY_INITIATOR = 0xFFFFFFFF

# 64-bit key layout: [comm:16 | tag:24 | seq:24]
#   hi word = [comm:16 | tag_hi:16], lo word = [tag_lo:8 | seq:24]
_COMM_BITS, _TAG_BITS, _SEQ_BITS = 16, 24, 24


def encode_match_key(comm_id: int, tag: int, msg_seq: int):
    """Pack (communicator, user tag, message seq) into the 64b match key,
    returned as (hi, lo) uint32. Placing a per-(src,dst) message sequence
    number in the low bits is the paper's recipe for in-order message
    matching over unordered RUD (Sec. 3.2.1)."""
    comm = jnp.uint32(comm_id) & jnp.uint32(0xFFFF)
    tag = jnp.uint32(tag) & jnp.uint32(0xFFFFFF)
    seq = jnp.uint32(msg_seq) & jnp.uint32(0xFFFFFF)
    hi = (comm << jnp.uint32(16)) | (tag >> jnp.uint32(8))
    lo = ((tag & jnp.uint32(0xFF)) << jnp.uint32(24)) | seq
    return hi, lo


def wildcard_mask(match_comm: bool = True, match_tag: bool = True,
                  match_seq: bool = True):
    """Wildcard mask (hi, lo) for `encode_match_key` layout (HPC profile).
    A field set to False is wildcarded (its bits are ignored)."""
    hi = jnp.uint32(0)
    lo = jnp.uint32(0)
    if not match_comm:
        hi |= jnp.uint32(0xFFFF) << jnp.uint32(16)
    if not match_tag:
        hi |= jnp.uint32(0xFFFF)
        lo |= jnp.uint32(0xFF) << jnp.uint32(24)
    if not match_seq:
        lo |= jnp.uint32(0xFFFFFF)
    return hi, lo


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class RecvQueue:
    """Posted-receive table of one receive context (RI). All [E] arrays.

    tag_hi/tag_lo:   uint32 match key words of posted entries
    mask_hi/mask_lo: uint32 wildcard masks — a 1 bit means "ignore this bit"
    initiators:      uint32 expected initiator, ANY_INITIATOR = wildcard
    seq:             int32 posting order (for HPC in-order semantics)
    valid:           bool
    buffer_id:       int32 destination buffer handle
    """

    tag_hi: jax.Array
    tag_lo: jax.Array
    mask_hi: jax.Array
    mask_lo: jax.Array
    initiators: jax.Array
    seq: jax.Array
    valid: jax.Array
    buffer_id: jax.Array

    @staticmethod
    def create(capacity: int) -> "RecvQueue":
        u = jnp.zeros((capacity,), jnp.uint32)
        return RecvQueue(
            tag_hi=u, tag_lo=u, mask_hi=u, mask_lo=u, initiators=u,
            seq=jnp.full((capacity,), -1, jnp.int32),
            valid=jnp.zeros((capacity,), jnp.bool_),
            buffer_id=jnp.full((capacity,), -1, jnp.int32),
        )


def post_receive(q: RecvQueue, slot, key_hi, key_lo, mask_hi, mask_lo,
                 initiator, seq, buffer_id) -> RecvQueue:
    """Post one receive into table slot `slot`."""
    return RecvQueue(
        tag_hi=q.tag_hi.at[slot].set(jnp.uint32(key_hi)),
        tag_lo=q.tag_lo.at[slot].set(jnp.uint32(key_lo)),
        mask_hi=q.mask_hi.at[slot].set(jnp.uint32(mask_hi)),
        mask_lo=q.mask_lo.at[slot].set(jnp.uint32(mask_lo)),
        initiators=q.initiators.at[slot].set(jnp.uint32(initiator)),
        seq=q.seq.at[slot].set(jnp.int32(seq)),
        valid=q.valid.at[slot].set(True),
        buffer_id=q.buffer_id.at[slot].set(jnp.int32(buffer_id)),
    )


def _entry_hits(q: RecvQueue, key_hi, key_lo, initiator) -> jax.Array:
    """[B, E] bool: does entry e match message b (ignoring order)."""
    khi = key_hi.astype(jnp.uint32)[:, None]
    klo = key_lo.astype(jnp.uint32)[:, None]
    init = initiator.astype(jnp.uint32)[:, None]
    hi_eq = (q.tag_hi[None, :] | q.mask_hi[None, :]) == (khi | q.mask_hi[None, :])
    lo_eq = (q.tag_lo[None, :] | q.mask_lo[None, :]) == (klo | q.mask_lo[None, :])
    init_eq = (q.initiators[None, :] == init) | (
        q.initiators[None, :] == jnp.uint32(ANY_INITIATOR))
    return hi_eq & lo_eq & init_eq & q.valid[None, :]


@partial(jax.jit, static_argnames=("profile",))
def match(q: RecvQueue, key_hi: jax.Array, key_lo: jax.Array,
          initiator: jax.Array,
          profile: Profile = Profile.AI_FULL) -> tuple[jax.Array, jax.Array]:
    """Match a batch of arriving messages against the posted-receive table.

    NOTE: entries are matched independently (a batch does not consume
    entries as it goes); the caller consumes matched slots between batches.

    Returns (slot [B] int32, matched [B] bool); slot == -1 if unexpected.
    """
    hits = _entry_hits(q, key_hi, key_lo, initiator)
    if profile == Profile.HPC:
        # In-order wildcard matching: lowest posting-seq valid hit wins.
        BIG = jnp.int32(2 ** 30)
        order = jnp.where(hits, q.seq[None, :], BIG)
        best = jnp.argmin(order, axis=1)
        matched = jnp.take_along_axis(hits, best[:, None], axis=1)[:, 0]
    elif profile == Profile.AI_FULL:
        # Exact matching: wildcard masks are illegal — treat masked entries
        # as non-matching (the spec constrains AI Full to exact match).
        exact = hits & (q.mask_hi[None, :] == 0) & (q.mask_lo[None, :] == 0)
        best = jnp.argmax(exact, axis=1)
        matched = exact.any(axis=1)
    else:  # AI_BASE: no transport-layer matching
        best = jnp.zeros(key_hi.shape[0], jnp.int32)
        matched = jnp.zeros(key_hi.shape[0], jnp.bool_)
    slot = jnp.where(matched, best.astype(jnp.int32), -1)
    return slot, matched


def consume(q: RecvQueue, slot: jax.Array, matched: jax.Array) -> RecvQueue:
    """Invalidate a matched entry (one message per call)."""
    safe = jnp.where(matched, slot, 0)
    valid = q.valid.at[safe].set(jnp.where(matched, False, q.valid[safe]))
    return RecvQueue(q.tag_hi, q.tag_lo, q.mask_hi, q.mask_lo,
                     q.initiators, q.seq, valid, q.buffer_id)
