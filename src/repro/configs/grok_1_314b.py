"""grok-1-314b: MoE 8 experts top-2 [hf:xai-org/grok-1; unverified]."""
from repro.configs.base import ArchConfig, pad_for_tp, MIXER_ATTN, FFN_MOE

CONFIG = pad_for_tp(ArchConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=32768, vocab_size=131_072,
    num_experts=8, experts_per_token=2,
    pattern=((MIXER_ATTN, FFN_MOE),),
    source="hf:xai-org/grok-1; unverified",
))
