"""Entropy-Value load balancing schemes (Sec. 2.1, 3.3.5).

UE cannot pick paths directly — only Entropy Values, which the fabric
hashes to paths. Guarantees assumed: same EV => same path (failure-free);
different EV *likely* different path. Schemes implemented:

* STATIC    — one EV for the whole flow (ROD-like single path; the
              polarization-prone baseline, Sec. 2.1).
* OBLIVIOUS — fresh pseudo-random EV per packet ("oblivious spraying");
              recommended together with a fast loss detector.
* RR_SLOTS  — round-robin over k EV slots (ev_slot = psn % k); the layout
              assumed by the EV-based loss detection scheme (Sec. 3.2.4:
              "PSNs expected at each slot are i, i+k, i+2k, ...").
* REPS      — Recycled Entropies Packet Spraying [5]: EVs returned by
              (non-congested) ACKs are pushed onto a recycle ring and
              reused first; fresh random EVs are drawn only when the ring
              is empty. Self-clocking: path capacities are discovered by
              the rate their EVs come back.
* EVBITMAP  — the spec's other example: a set of K EVs with a congestion
              bitmap; rotate through EVs, skip-and-clear marked ones [27].

All state is SoA over flows; selection for every flow happens in one
vectorized call per tick.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp

from repro.core.types import EV_SPACE


class LBScheme(enum.IntEnum):
    STATIC = 0
    OBLIVIOUS = 1
    RR_SLOTS = 2
    REPS = 3
    EVBITMAP = 4


def _mix32(x: jax.Array) -> jax.Array:
    """xxhash-style avalanche finalizer (uint32 -> uint32)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class LBState:
    """Unified LB state; schemes use the fields they need. SoA over F flows.

    rr_ptr:    [F] int32  — round-robin pointer (RR_SLOTS / EVBITMAP)
    reps_ring: [F, K] int32 — recycled EVs (REPS); -1 = empty/evicted slot
    reps_head: [F] int32  — pop position
    reps_size: [F] int32  — entries available
    ev_set:    [F, K] int32 — candidate EV per slot (RR_SLOTS / EVBITMAP)
    cong_bits: [F, K] bool  — EVBITMAP congestion marks
    salt:      [F] uint32 — per-flow PRNG salt (OBLIVIOUS / fresh draws)
    bad_ev:    [F, K] int32 — eviction blacklist ring (-1 = empty); only
               mutated when the profile enables ``ev_eviction``
    bad_n:     [F] int32  — evictions so far (ring write pointer + salt)
    last_ev:   [F] int32  — EV of the flow's last injected packet (-1 =
               none); the timeout-eviction target
    """

    rr_ptr: jax.Array
    reps_ring: jax.Array
    reps_head: jax.Array
    reps_size: jax.Array
    ev_set: jax.Array
    cong_bits: jax.Array
    salt: jax.Array
    bad_ev: jax.Array
    bad_n: jax.Array
    last_ev: jax.Array

    @staticmethod
    def create(f: int, k: int, seed: int | jax.Array = 0x5EED) -> "LBState":
        # seed may be a traced uint32 scalar: batched scenario sweeps vmap
        # over per-scenario seeds without recompiling
        seed = jnp.asarray(seed).astype(jnp.uint32)
        flows = jnp.arange(f, dtype=jnp.uint32)
        # per-flow, per-slot initial EVs: well-mixed distinct values
        slot_ev = _mix32(flows[:, None] * jnp.uint32(977) +
                         jnp.arange(k, dtype=jnp.uint32)[None, :] +
                         seed) % EV_SPACE
        return LBState(
            rr_ptr=jnp.zeros((f,), jnp.int32),
            reps_ring=jnp.full((f, k), -1, jnp.int32),
            reps_head=jnp.zeros((f,), jnp.int32),
            reps_size=jnp.zeros((f,), jnp.int32),
            ev_set=slot_ev.astype(jnp.int32),
            cong_bits=jnp.zeros((f, k), jnp.bool_),
            salt=_mix32(flows + seed * jnp.uint32(2654435761)),
            bad_ev=jnp.full((f, k), -1, jnp.int32),
            bad_n=jnp.zeros((f,), jnp.int32),
            last_ev=jnp.full((f,), -1, jnp.int32),
        )


def select_ev(state: LBState, scheme: LBScheme, psn: jax.Array,
              tick: jax.Array) -> tuple[LBState, jax.Array]:
    """Choose the EV for the next packet of every flow.

    psn: [F] uint32 — the PSN about to be stamped (drives RR slots).
    Returns (state', ev [F] int32). Callers only use lanes for flows that
    actually inject this tick; state advance for non-injecting flows is
    prevented by the caller passing back the old state lanes (see
    `commit_selection`).
    """
    F, K = state.ev_set.shape
    flows = jnp.arange(F, dtype=jnp.uint32)

    if scheme == LBScheme.STATIC:
        return state, state.ev_set[:, 0]

    if scheme == LBScheme.OBLIVIOUS:
        ev = (_mix32(state.salt ^ _mix32(psn.astype(jnp.uint32) +
                                         (tick.astype(jnp.uint32) << 8)))
              % EV_SPACE).astype(jnp.int32)
        return state, ev

    if scheme == LBScheme.RR_SLOTS:
        slot = (psn.astype(jnp.int32)) % K
        return state, state.ev_set[jnp.arange(F), slot]

    if scheme == LBScheme.REPS:
        has = state.reps_size > 0
        pos = state.reps_head % K
        recycled = state.reps_ring[jnp.arange(F), pos]
        fresh = (_mix32(state.salt ^ _mix32(psn.astype(jnp.uint32) *
                                            jnp.uint32(2246822519)))
                 % EV_SPACE).astype(jnp.int32)
        # an evicted ring entry is tombstoned to -1 (see LBPolicy.evict);
        # consume the slot but substitute a fresh draw. Recycled entries
        # are always real EVs (>= 0) when eviction is off, so this select
        # is bitwise the pre-eviction one there.
        ev = jnp.where(has & (recycled >= 0), recycled, fresh)
        return replace(
            state,
            reps_head=jnp.where(has, (state.reps_head + 1) % K, state.reps_head),
            reps_size=jnp.where(has, state.reps_size - 1, state.reps_size),
        ), ev

    # EVBITMAP: advance the pointer, skipping (and clearing) congested slots.
    # One skip per selection (the spec's skip-then-unset round semantics).
    ptr = state.rr_ptr % K
    congested = state.cong_bits[jnp.arange(F), ptr]
    ptr2 = (ptr + 1) % K
    use = jnp.where(congested, ptr2, ptr)
    ev = state.ev_set[jnp.arange(F), use]
    # clear the skipped bit so it is retried next round
    cong = state.cong_bits.at[jnp.arange(F), ptr].set(
        jnp.where(congested, False, state.cong_bits[jnp.arange(F), ptr]))
    return replace(state, rr_ptr=(use + 1) % K, cong_bits=cong), ev


def _pick_lane(hot: jax.Array, vals: jax.Array) -> jax.Array:
    """Per-row value from <= 1 active lane: hot [R, L] bool, vals [L]."""
    return jnp.sum(jnp.where(hot, vals[None, :], 0), axis=1)


def _in_blacklist(st: LBState, ev: jax.Array) -> jax.Array:
    """[F] bool — is each flow's ``ev`` currently on its blacklist?"""
    return ((st.bad_ev == ev[:, None]) & (st.bad_ev >= 0)).any(axis=1)


@jax.tree_util.register_static
@dataclass(frozen=True)
class LBPolicy:
    """One LB scheme as a pluggable policy object for the fabric engine.

    The engine composes the tick from `on_ack` (path feedback over the
    control-event lanes, densified per flow where the scheme allows),
    `select` (per-flow EV choice) and — when the profile enables the
    recovery loop — `evict` (blacklist an EV implicated by a timeout or
    trim NACK, purge it from the scheme's circulating state, and steer
    future draws away from it). `static_ev` is the single-path pick used
    for ROD flows in mixed-delivery profiles. The bodies are the scheme
    dispatch the engine used to inline — bitwise-parity preserved; with
    ``evict=False`` (the default) every eviction lane is statically
    elided.
    """

    scheme: LBScheme
    evict_enabled: bool = False

    def create(self, f: int, k: int, seed) -> LBState:
        return LBState.create(f, k, seed)

    def on_ack(self, st: LBState, hot_ack: jax.Array, ef: jax.Array,
               ee: jax.Array, ec: jax.Array, is_ack: jax.Array,
               is_nack: jax.Array,
               flow_ok: jax.Array | None = None) -> LBState:
        """Feedback from this tick's control events.

        hot_ack: [F, E] one-hot ACK lanes per flow; ef/ee/ec: [E] lane
        flow/EV/ECN; is_ack/is_nack: [E] lane types. ``flow_ok`` masks
        flows whose feedback the engine withholds (ROD flows in a
        mixed-delivery profile — their static-path EVs must not enter
        the spraying state).
        """
        if self.scheme == LBScheme.REPS:
            # recycle EVs that came back on clean (un-marked) ACKs
            hot_clean = hot_ack & (ec[None, :] == 0)
            if flow_ok is not None:
                hot_clean = hot_clean & flow_ok[:, None]
            return reps_recycle(st, _pick_lane(hot_clean, ee),
                                hot_clean.any(axis=1))
        if self.scheme == LBScheme.EVBITMAP:
            valid = is_ack | is_nack
            if flow_ok is not None:
                valid = valid & flow_ok[jnp.where(valid, ef, 0)]
            return on_ack(st, self.scheme, ef, ee,
                          ec.astype(jnp.bool_) | is_nack, valid)
        return st  # STATIC / OBLIVIOUS / RR take no path feedback

    def select(self, st: LBState, psn: jax.Array,
               tick: jax.Array) -> tuple[LBState, jax.Array]:
        st2, ev = select_ev(st, self.scheme, psn, tick)
        if self.evict_enabled:
            # steer draws away from the blacklist: a selected EV that is
            # currently blacklisted is re-mixed once. One round suffices
            # in practice (K blacklisted values out of EV_SPACE=2**16);
            # a colliding re-mix just behaves like the pre-eviction draw.
            bad = _in_blacklist(st, ev)
            alt = (_mix32(ev.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
                          ^ st.salt) % EV_SPACE).astype(jnp.int32)
            ev = jnp.where(bad, alt, ev)
        return st2, ev

    def static_ev(self, st: LBState) -> jax.Array:
        """The flow's pinned single-path EV (ROD lanes)."""
        return st.ev_set[:, 0]

    def evict(self, st: LBState, ev: jax.Array,
              valid: jax.Array) -> LBState:
        """Blacklist ``ev`` for flows with ``valid`` set and purge it
        from the scheme's circulating state: matching ``ev_set`` slots
        are re-rolled to fresh draws (steered off the blacklist) and
        matching REPS recycle-ring entries are tombstoned to -1 (the
        select path substitutes a fresh draw when it pops one), so REPS
        self-clocking composes instead of resurrecting the dead path.

        ev, valid: [F]. Only called by the engine when the profile has
        ``ev_eviction=True``.
        """
        F, K = st.ev_set.shape
        # push into the blacklist ring (overwrites the oldest entry)
        pos = st.bad_n % K
        hot = (jnp.arange(K)[None, :] == pos[:, None]) & valid[:, None]
        bad_ev = jnp.where(hot, ev[:, None], st.bad_ev)
        bad_n = st.bad_n + valid.astype(jnp.int32)
        # re-roll ev_set slots that carry the evicted EV; the fresh value
        # is salted by the eviction count so repeated evictions of one
        # slot keep exploring, and re-mixed once if it lands on the
        # (updated) blacklist
        slot_match = (st.ev_set == ev[:, None]) & valid[:, None]
        fresh = (_mix32(st.salt[:, None]
                        ^ _mix32((bad_n[:, None] * K +
                                  jnp.arange(K)[None, :]).astype(jnp.uint32)
                                 * jnp.uint32(0x85EBCA77)))
                 % EV_SPACE).astype(jnp.int32)
        fresh_bad = ((bad_ev[:, :, None] == fresh[:, None, :])
                     & (bad_ev[:, :, None] >= 0)).any(axis=1)
        fresh = jnp.where(
            fresh_bad,
            (_mix32(fresh.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
                    ^ st.salt[:, None]) % EV_SPACE).astype(jnp.int32),
            fresh)
        ev_set = jnp.where(slot_match, fresh, st.ev_set)
        # tombstone matching REPS recycle entries (do NOT touch head/size:
        # ring arithmetic stays intact; pops skip via the select path)
        ring_match = (st.reps_ring == ev[:, None]) & valid[:, None]
        reps_ring = jnp.where(ring_match, -1, st.reps_ring)
        return replace(st, bad_ev=bad_ev, bad_n=bad_n, ev_set=ev_set,
                       reps_ring=reps_ring)


def commit_selection(old: LBState, new: LBState, injected: jax.Array) -> LBState:
    """Keep `new` lanes only where a packet was actually injected."""
    pick = lambda a, b: jnp.where(
        injected.reshape((-1,) + (1,) * (a.ndim - 1)), b, a)
    return LBState(*(pick(a, b) for a, b in
                     zip(jax.tree_util.tree_leaves(old),
                         jax.tree_util.tree_leaves(new))))


def reps_recycle(state: LBState, ev: jax.Array,
                 valid: jax.Array) -> LBState:
    """Per-flow REPS recycle: push one clean-ACK EV per flow.

    ev, valid: [F] — the fabric's dense feedback path. Clean ACKs arrive
    at most once per flow per tick (one host downlink per destination),
    so the ring push is pure elementwise + one-hot work, no scatter.
    Equivalent to `on_ack(..., scheme=REPS)` restricted to those lanes.
    """
    F, K = state.ev_set.shape
    push = valid & (state.reps_size < K)
    pos = (state.reps_head + state.reps_size) % K
    hot = (jnp.arange(K)[None, :] == pos[:, None]) & push[:, None]
    return replace(
        state,
        reps_ring=jnp.where(hot, ev[:, None], state.reps_ring),
        reps_size=state.reps_size + push.astype(jnp.int32),
    )


def on_ack(state: LBState, scheme: LBScheme, flow: jax.Array, ev: jax.Array,
           congested: jax.Array, valid: jax.Array) -> LBState:
    """Feed ACK/NACK path feedback back into the scheme.

    flow, ev: [B]; congested: [B] bool (ECN-CE marked ACK or trim NACK);
    valid: [B] lane mask.
    """
    F, K = state.ev_set.shape
    if scheme == LBScheme.REPS:
        # Recycle EVs that came back clean; congested EVs are dropped from
        # circulation (their slot refills with a fresh random draw later).
        ok = valid & ~congested
        drop_f = jnp.where(ok, flow, F)
        pos = (state.reps_head + state.reps_size) % K
        # room check: ring holds at most K
        room = state.reps_size[jnp.where(ok, flow, 0)] < K
        drop_f = jnp.where(ok & room, flow, F)
        ring = state.reps_ring.at[drop_f, pos[jnp.where(ok, flow, 0)]].set(
            ev, mode="drop")
        size = state.reps_size.at[drop_f].add(1, mode="drop")
        return replace(state, reps_ring=ring, reps_size=size)
    if scheme == LBScheme.EVBITMAP:
        # mark the slot whose EV saw congestion
        hit = (state.ev_set[jnp.where(valid, flow, 0)] ==
               ev[:, None]) & congested[:, None] & valid[:, None]
        # scatter OR across possibly-duplicate flows
        upd = jnp.zeros((F, K), jnp.bool_).at[
            jnp.where(valid, flow, F)[:, None].repeat(K, 1),
            jnp.arange(K)[None, :].repeat(flow.shape[0], 0)].max(
            hit, mode="drop")
        return replace(state, cong_bits=state.cong_bits | upd)
    return state
