"""Config registry: ``get(name)`` returns the full ArchConfig; ``reduced``
returns a tiny same-family config for CPU smoke tests."""
from __future__ import annotations

from dataclasses import replace

from repro.configs.base import (ArchConfig, ShapeConfig, SHAPES, shapes_for,
                                pad_for_tp)

_MODULES = {
    "deepseek-coder-33b": "deepseek_coder_33b",
    "minitron-4b": "minitron_4b",
    "starcoder2-15b": "starcoder2_15b",
    "glm4-9b": "glm4_9b",
    "grok-1-314b": "grok_1_314b",
    "mixtral-8x22b": "mixtral_8x22b",
    "internvl2-1b": "internvl2_1b",
    "musicgen-medium": "musicgen_medium",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "rwkv6-3b": "rwkv6_3b",
}

ARCH_NAMES = list(_MODULES)


def get(name: str) -> ArchConfig:
    import importlib
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def reduced(name: str, seq: int = 64) -> ArchConfig:
    """A tiny config of the same family: small widths, few layers/experts,
    tiny vocab - runs a forward/train step on CPU in seconds."""
    cfg = get(name)
    unit = cfg.unit_len
    small = dict(
        num_layers=2 * unit,
        d_model=128,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        pad_heads_to=0, pad_kv_to=0, pad_vocab_to=0,
        tp_pad=2,
    )
    if cfg.num_heads:
        small["num_heads"] = 4
        small["num_kv_heads"] = min(cfg.num_kv_heads, 2)
    if cfg.num_experts:
        small["num_experts"] = 4
        small["experts_per_token"] = 2
    if cfg.sliding_window:
        small["sliding_window"] = max(seq // 2, 16)
    return replace(cfg, **small)


__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "shapes_for", "get",
           "reduced", "ARCH_NAMES", "pad_for_tp"]
