import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes, with 512 placeholder host devices.

The XLA_FLAGS line above MUST precede every other import (jax locks the
device count at first init). Do not set that flag anywhere global —
smoke tests and benchmarks run on 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b \
        --shape train_4k --mesh pod                 # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Per cell this produces reports/dryrun/<arch>__<shape>__<mesh>.json with:
  memory_analysis (bytes/device), cost_analysis (FLOPs, bytes accessed),
  per-collective byte totals parsed from the optimized HLO — the inputs
  to the §Roofline table (repro/launch/roofline.py).

Everything is lowered from ShapeDtypeStructs: no parameter or batch is
ever materialized.
"""
import argparse
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, shapes_for
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.serve.serve_step import make_decode_step
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")

# bf16 moments for the 300B+ archs (HBM budget, see DESIGN.md)
BF16_MOMENT_ARCHS = {"grok-1-314b", "jamba-1.5-large-398b"}
# gradient accumulation for the MoE/hybrid trains (activation transients)
MICROBATCH_ARCHS = {"grok-1-314b", "jamba-1.5-large-398b", "mixtral-8x22b"}

_COLL_RE = re.compile(
    r"(\w+)\[([0-9,]*)\][^=]*\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the optimized HLO."""
    totals: dict[str, float] = {}
    counts: dict[str, int] = {}
    for m in re.finditer(
            r"= \(?([a-z0-9]+)\[([0-9,]*)\][^)]*?\)? (all-gather|all-reduce|"
            r"reduce-scatter|all-to-all|collective-permute)", hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        size = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                size *= int(d)
        totals[kind] = totals.get(kind, 0) + size
        counts[kind] = counts.get(kind, 0) + 1
    totals["total"] = sum(v for k, v in totals.items())
    return {"bytes": totals, "counts": counts}


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh) -> dict:
    """ShapeDtypeStructs (with shardings) for every model input —
    weak-type-correct, shardable, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    bspec = shd.batch_pspec(cfg, shape, mesh)
    if shape.kind == "decode":
        if cfg.frontend == "vit_stub":
            toks = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)
        else:
            toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        return {"tokens": toks}
    if cfg.frontend == "vit_stub":
        inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        inputs = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return {"inputs": inputs,
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def build_lowered(cfg: ArchConfig, shape: ShapeConfig, mesh,
                  scan_layers: bool = True, variant: str = "base"):
    """Lower one (cfg, shape, mesh) cell; no compilation.

    variant: "base" (paper-faithful baseline layout) or "opt" (the §Perf
    hillclimbed layout for this cell — see sharding.py variant docs).
    """
    arch = cfg.name
    dp = shd.dp_axes(mesh)

    params_shape = jax.eval_shape(
        partial(lm.init_params, cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    dp_wide = variant == "opt" and cfg.d_model < 2048 \
        and shape.kind == "train"
    zero2 = variant == "opt" and cfg.d_model >= 2048 \
        and shape.kind == "train"
    tp_only = variant == "opt" and shape.kind == "decode"
    if dp_wide:
        pspecs = shd.param_pspecs_dp_wide(params_shape, mesh)
    elif zero2:
        pspecs = shd.param_pspecs_zero2(params_shape, mesh)
    elif tp_only:
        pspecs = shd.param_pspecs_decode_row(params_shape, mesh)
    else:
        pspecs = shd.param_pspecs(params_shape, mesh)
    psharding = shd.named(mesh, pspecs)
    if dp_wide:
        axes = tuple(mesh.axis_names)
        act_spec = NamedSharding(mesh, P(axes, None, None))
        head_specs = None
    else:
        act_spec = NamedSharding(mesh, shd.activation_pspec(cfg, mesh))
        head_specs = shd.attn_head_specs(cfg, mesh)
    dpx = dp if len(dp) > 1 else dp[0]
    if dp_wide:
        loss_spec = NamedSharding(mesh, P(tuple(mesh.axis_names), None, None))
    else:
        loss_spec = NamedSharding(mesh, P(dpx, None, None))

    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    moe_tokens_shardable = (shape.global_batch * (
        1 if shape.kind == "decode" else shape.seq_len)) % dp_total == 0
    moe_spec = (P(dp, None) if moe_tokens_shardable else P(None, None))

    ins = input_specs(cfg, shape, mesh)
    bspec = (shd.batch_pspec_dp_wide(cfg, shape, mesh) if dp_wide
             else shd.batch_pspec(cfg, shape, mesh))
    if dp_wide:
        moe_spec = P(tuple(mesh.axis_names), None)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(
            moment_dtype=jnp.bfloat16 if arch in BF16_MOMENT_ARCHS
            else jnp.float32)
        step = make_train_step(cfg, mesh=mesh, dp_axes=dp, opt_cfg=opt_cfg,
                               act_spec=act_spec, moe_token_spec=moe_spec,
                               scan_layers=scan_layers,
                               attn_head_specs=head_specs,
                               loss_spec=loss_spec,
                               microbatches=2 if arch in MICROBATCH_ARCHS
                               else 1,
                               remat_policy="nothing")
        opt_shape = jax.eval_shape(
            partial(init_opt_state, cfg=opt_cfg), params_shape)
        mspecs = (shd.param_pspecs(params_shape, mesh) if zero2 else pspecs)
        ospecs = {"m": mspecs, "v": mspecs, "step": P()}
        osharding = shd.named(mesh, ospecs)
        bsharding = shd.named(mesh, bspec)
        jitted = jax.jit(
            step,
            in_shardings=(psharding, osharding, bsharding),
            out_shardings=(psharding, osharding, None),
            donate_argnums=(0, 1))
        args = (params_shape, opt_shape,
                {"inputs": ins["inputs"], "labels": ins["labels"]})
        lowered = jitted.lower(*args)
    elif shape.kind == "prefill":
        from repro.serve.serve_step import make_prefill
        pf = make_prefill(cfg, mesh=mesh, dp_axes=dp, act_spec=act_spec,
                          moe_token_spec=moe_spec, scan_layers=scan_layers,
                          attn_head_specs=head_specs)
        bsharding = shd.named(mesh, bspec["inputs"])
        jitted = jax.jit(pf, in_shardings=(psharding, bsharding),
                         out_shardings=None)
        lowered = jitted.lower(params_shape, ins["inputs"])
    else:  # decode
        long_ctx = shape.global_batch < dp_total
        dstep = make_decode_step(cfg, mesh=mesh, dp_axes=dp,
                                 select_write=long_ctx or variant == "opt",
                                 moe_token_spec=(
                                     P(dp, None) if moe_tokens_shardable
                                     else P(None, None)),
                                 scan_layers=scan_layers,
                                 sharded_cache_attn=variant == "opt"
                                 and not long_ctx)
        cache_len = min(shape.seq_len, cfg.sliding_window) \
            if cfg.sliding_window else shape.seq_len
        cache_shape = jax.eval_shape(
            partial(lm.init_cache, cfg, shape.global_batch, cache_len,
                    jnp.bfloat16))
        cspecs = (shd.cache_pspecs_decode_row(cfg, shape, mesh, cache_shape)
                  if variant == "opt" and not long_ctx
                  else shd.cache_pspecs(cfg, shape, mesh, cache_shape))
        csharding = shd.named(mesh, cspecs)
        tsharding = shd.named(mesh, shd.batch_pspec(cfg, shape, mesh)["inputs"])
        jitted = jax.jit(
            dstep,
            in_shardings=(psharding, csharding, tsharding, None),
            out_shardings=(None, csharding),
            donate_argnums=(1,))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = jitted.lower(params_shape, cache_shape, ins["tokens"], pos)
    return lowered


def _analyze(lowered):
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "collectives": coll,
    }


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "base"):
    """Lower + compile one cell and its R=1/R=2 FLOP-calibration variants.

    XLA's cost analysis counts a while-loop (lax.scan) body ONCE and
    reports per-device numbers. The scanned full model gives the true
    memory analysis; two Python-unrolled variants with 1 and 2 repeating
    units give per-unit FLOPs/bytes/collectives, from which the true
    per-device totals are reconstructed:
        total = f(1) + (f(2) - f(1)) * (R - 1).
    """
    import dataclasses
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    t0 = time.time()
    full = _analyze(build_lowered(cfg, shape, mesh, scan_layers=True,
                                  variant=variant))
    t_full = time.time() - t0

    cfg1 = dataclasses.replace(cfg, num_layers=cfg.unit_len)
    cfg2 = dataclasses.replace(cfg, num_layers=2 * cfg.unit_len)
    a1 = _analyze(build_lowered(cfg1, shape, mesh, scan_layers=False,
                                variant=variant))
    a2 = _analyze(build_lowered(cfg2, shape, mesh, scan_layers=False,
                                variant=variant))
    R = cfg.repeats

    def extrap(key):
        f1, f2 = a1[key], a2[key]
        return f1 + (f2 - f1) * (R - 1)

    coll_total = {}
    for kind in set(a1["collectives"]["bytes"]) | set(
            a2["collectives"]["bytes"]):
        c1 = a1["collectives"]["bytes"].get(kind, 0)
        c2 = a2["collectives"]["bytes"].get(kind, 0)
        coll_total[kind] = c1 + (c2 - c1) * (R - 1)

    report = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": 512 if multi_pod else 256,
        "kind": shape.kind,
        "compile_s": round(time.time() - t0, 1),
        "compile_full_s": round(t_full, 1),
        # per-device totals, loop-corrected via the R1/R2 calibration
        "flops": extrap("flops"),
        "bytes_accessed": extrap("bytes_accessed"),
        "collectives": {"bytes": coll_total,
                        "counts_full_hlo": full["collectives"]["counts"]},
        # raw (body-counted-once) numbers from the scanned full build
        "flops_scanned_raw": full["flops"],
        "memory": full["memory"],
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    return report


def run_cell(arch, shape_name, multi_pod, outdir, variant="base"):
    tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    if variant != "base":
        tag += f"__{variant}"
    try:
        rep = lower_cell(arch, shape_name, multi_pod, variant)
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(outdir, tag + ".json"), "w") as f:
            json.dump(rep, f, indent=1)
        dev_mem = (rep["memory"]["argument_bytes"]
                   + rep["memory"]["temp_bytes"])
        print(f"OK   {tag}: flops/dev={rep['flops']:.3e} "
              f"coll/dev={rep['collectives']['bytes'].get('total', 0):.3e}B "
              f"mem/dev={dev_mem/1e9:.2f}GB "
              f"(compile {rep['compile_s']}s)", flush=True)
        return True
    except Exception as e:
        print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
        traceback.print_exc()
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    ap.add_argument("--out", default=os.path.abspath(REPORT_DIR))
    args = ap.parse_args()

    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[
        args.mesh]
    ok = fail = 0
    if args.all:
        for arch in configs.ARCH_NAMES:
            cfg = configs.get(arch)
            for shape_name in shapes_for(cfg):
                for mp in meshes:
                    if run_cell(arch, shape_name, mp, args.out):
                        ok += 1
                    else:
                        fail += 1
    else:
        assert args.arch and args.shape
        for mp in meshes:
            if run_cell(args.arch, args.shape, mp, args.out, args.variant):
                ok += 1
            else:
                fail += 1
    print(f"dry-run: {ok} ok, {fail} failed")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
