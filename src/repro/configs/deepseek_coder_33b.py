"""deepseek-coder-33b: llama-arch dense decoder [arXiv:2401.14196; hf]."""
from repro.configs.base import ArchConfig, pad_for_tp, MIXER_ATTN, FFN_MLP

CONFIG = pad_for_tp(ArchConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
    head_dim=128, d_ff=19200, vocab_size=32256,
    rope_theta=100_000.0,
    pattern=((MIXER_ATTN, FFN_MLP),),
    source="arXiv:2401.14196; hf",
))
