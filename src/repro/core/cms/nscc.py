"""Network Signal-based Congestion Control (Sec. 3.3.1).

NSCC runs a control loop at the *source*, combining two signals per ACK:

* ECN — a fast statistical 1-bit signal, marked at switch **egress** (the
  spec's departure from RFC 3168) so it skips the queue it describes;
* RTT — a lagging multi-bit signal measured request->response, excluding
  receiver service time.

Four cases on each arriving ACK (paper's enumeration):

  1. ECN && low RTT   -> congestion *building*: do not react.
  2. ECN && high RTT  -> congested/overloaded: aggressive multiplicative
                         decrease per incoming ACK.
  3. !ECN && low RTT  -> underloaded: quick increase, sized by the gap
                         between measured and expected RTT.
  4. !ECN && high RTT -> congestion draining: gentle additive increase.

Plus **Quick Adapt (QA)**: on packet-loss evidence (e.g. trimming NACKs),
once per RTT-epoch rescale the window to the fraction of traffic actually
delivered — the incast fast path.

All state is SoA over congestion-control contexts (CCCs) and the update is
a pure function over a batch of ACKs, so one call services every CCC in
one fused op — mirroring a hardware NIC pipeline. The Pallas kernel in
repro/kernels/nscc_update.py implements `nscc_update` blockwise; this
module is the reference semantics (its `ref.py` re-exports from here).

Windows are measured in MTU packet units (float32); the fabric simulator
works in packet-time ticks.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class NSCCParams:
    """Control-loop gains. Class-level defaults; tune via replace()."""

    base_rtt: float = 8.0        # unloaded RTT estimate, ticks
    target_factor: float = 1.25  # high/low RTT threshold = base_rtt * this
    md: float = 0.65             # case-2 multiplicative decrease per ACK
    quick_gain: float = 0.60     # case-3 increase gain (packets per ACK max)
    ai: float = 1.0              # case-4 additive increase (pkts per cwnd ACKs)
    min_cwnd: float = 1.0
    max_cwnd: float = 64.0       # slightly above BDP; optimistic start value
    qa_min_frac: float = 0.125   # QA floor as a fraction of max_cwnd


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class NSCCState:
    """Per-CCC state (SoA over N contexts).

    cwnd:        [N] float32 congestion window, packets
    epoch_acked: [N] int32 packets delivered in current QA epoch
    epoch_lost:  [N] int32 packets reported lost in current QA epoch
    epoch_tick:  [N] int32 tick when the current QA epoch started
    """

    cwnd: jax.Array
    epoch_acked: jax.Array
    epoch_lost: jax.Array
    epoch_tick: jax.Array

    @staticmethod
    def create(n: int, params: NSCCParams) -> "NSCCState":
        # Optimistic start: window at/near BDP, i.e. start at full rate
        # (Sec. 3.3.3 "Both RCCC and NSCC ... start at full rate").
        return NSCCState(
            cwnd=jnp.full((n,), params.max_cwnd, jnp.float32),
            epoch_acked=jnp.zeros((n,), jnp.int32),
            epoch_lost=jnp.zeros((n,), jnp.int32),
            epoch_tick=jnp.zeros((n,), jnp.int32),
        )


def classify(ecn: jax.Array, rtt: jax.Array, params: NSCCParams) -> jax.Array:
    """Return the paper's case number (1..4) per ACK."""
    high = rtt > params.base_rtt * params.target_factor
    return jnp.where(ecn, jnp.where(high, 2, 1), jnp.where(high, 4, 3))


def window_delta(cwnd: jax.Array, ecn: jax.Array, rtt: jax.Array,
                 params: NSCCParams) -> jax.Array:
    """Per-ACK window adjustment (packets); the four-case core.

    Vectorized over ACKs; `cwnd` is the current window of the ACK's CCC.
    """
    target = params.base_rtt * params.target_factor
    high = rtt > target
    # case 2: aggressive MD proportional to RTT excess, per incoming ACK
    overload = jnp.clip((rtt - target) / jnp.maximum(rtt, 1e-6), 0.0, 1.0)
    dec = -params.md * overload  # packets per ACK
    # case 3: quick increase guessing from measured vs expected RTT
    gap = jnp.clip((target - rtt) / target, 0.0, 1.0)
    quick = params.quick_gain * gap
    # case 4: gentle additive increase (+ai per full window of ACKs)
    gentle = params.ai / jnp.maximum(cwnd, 1.0)
    return jnp.where(ecn, jnp.where(high, dec, 0.0),
                     jnp.where(high, gentle, quick))


def on_acks(state: NSCCState, params: NSCCParams, ccc: jax.Array,
            ecn: jax.Array, rtt: jax.Array,
            valid: jax.Array) -> NSCCState:
    """Apply a batch of ACKs: ccc [B] int32, ecn [B] bool, rtt [B] float32.

    Multiple ACKs may target the same CCC in one batch; deltas accumulate
    via scatter-add (order-independent by construction).
    """
    cw = state.cwnd[ccc]
    delta = window_delta(cw, ecn, rtt.astype(jnp.float32), params)
    delta = jnp.where(valid, delta, 0.0)
    n = state.cwnd.shape[0]
    drop = jnp.where(valid, ccc, n)  # OOB -> dropped
    cwnd = state.cwnd.at[drop].add(delta, mode="drop")
    cwnd = jnp.clip(cwnd, params.min_cwnd, params.max_cwnd)
    acked = state.epoch_acked.at[drop].add(
        jnp.where(valid, 1, 0), mode="drop")
    return replace(state, cwnd=cwnd, epoch_acked=acked)


def on_ack_per_flow(state: NSCCState, params: NSCCParams, ecn: jax.Array,
                    rtt: jax.Array, active: jax.Array) -> NSCCState:
    """Dense variant of `on_acks` for the one-ACK-per-CCC-per-round case
    (the fabric tick: one host downlink per destination): ecn/rtt/active
    are [N] per-CCC lanes, so the update is pure elementwise — no
    scatter. Matches `on_acks` exactly when each CCC has <= 1 valid lane.
    """
    delta = window_delta(state.cwnd, ecn, rtt.astype(jnp.float32), params)
    cwnd = jnp.where(active, state.cwnd + delta, state.cwnd)
    return replace(
        state,
        cwnd=jnp.clip(cwnd, params.min_cwnd, params.max_cwnd),
        epoch_acked=state.epoch_acked + active.astype(jnp.int32),
    )


def on_loss_per_flow(state: NSCCState, count: jax.Array) -> NSCCState:
    """Dense variant of `on_loss`: count [N] losses per CCC, elementwise."""
    return replace(state, epoch_lost=state.epoch_lost + count)


def on_loss(state: NSCCState, ccc: jax.Array, count: jax.Array,
            valid: jax.Array) -> NSCCState:
    """Record loss evidence (trim NACK / EV-inference / timeout) for QA."""
    n = state.cwnd.shape[0]
    drop = jnp.where(valid, ccc, n)
    return replace(state, epoch_lost=state.epoch_lost.at[drop].add(
        jnp.where(valid, count, 0), mode="drop"))


def quick_adapt(state: NSCCState, params: NSCCParams,
                now: jax.Array) -> NSCCState:
    """Once per RTT-epoch: if losses were seen, rescale cwnd to the
    delivered fraction (Sec. 3.3.1 QA / SMaRTT)."""
    epoch_len = jnp.int32(params.base_rtt * params.target_factor)
    due = (now - state.epoch_tick) >= epoch_len
    delivered = state.epoch_acked.astype(jnp.float32)
    lost = state.epoch_lost.astype(jnp.float32)
    frac = delivered / jnp.maximum(delivered + lost, 1.0)
    lossy = due & (state.epoch_lost > 0)
    new_cwnd = jnp.where(
        lossy,
        jnp.clip(state.cwnd * frac, params.qa_min_frac * params.max_cwnd,
                 params.max_cwnd),
        state.cwnd)
    reset = due
    return NSCCState(
        cwnd=jnp.maximum(new_cwnd, params.min_cwnd),
        epoch_acked=jnp.where(reset, 0, state.epoch_acked),
        epoch_lost=jnp.where(reset, 0, state.epoch_lost),
        epoch_tick=jnp.where(reset, now, state.epoch_tick),
    )


@jax.tree_util.register_static
@dataclass(frozen=True)
class NSCCPolicy:
    """NSCC as a pluggable CC policy for the fabric engine.

    Implements the policy protocol documented in
    `repro.network.profile`: per-tick hooks over densified [F] lanes.
    State is one `NSCCState` pytree carried in the simulator's scan
    carry. The hook bodies are exactly the calls the engine used to
    inline — the composition point moved, the ops did not (the profile
    refactor is bitwise-parity-tested against the pre-refactor engine).
    """

    params: NSCCParams

    def create(self, f: int) -> NSCCState:
        return NSCCState.create(f, self.params)

    def on_ack(self, st: NSCCState, has_ack: jax.Array, ecn: jax.Array,
               rtt: jax.Array) -> NSCCState:
        return on_ack_per_flow(st, self.params, ecn, rtt, has_ack)

    def on_nack(self, st: NSCCState, count: jax.Array) -> NSCCState:
        return on_loss_per_flow(st, count)

    def on_grant_tick(self, st, flow_dst, active, num_hosts):
        return st  # sender-based: no receiver scheduling round

    def on_send_gate(self, st: NSCCState, inflight: jax.Array) -> jax.Array:
        return inflight < jnp.floor(st.cwnd).astype(jnp.int32)

    def on_inject(self, st, injected):
        return st  # window-based: nothing to spend per packet

    def on_rx_seen(self, st, seen):
        return st

    def on_timeout(self, st: NSCCState, stalled: jax.Array) -> NSCCState:
        return on_loss_per_flow(st, stalled.astype(jnp.int32))

    def end_of_tick(self, st: NSCCState, tick: jax.Array) -> NSCCState:
        return quick_adapt(st, self.params, tick)

    def cwnd_view(self, st: NSCCState, f: int) -> jax.Array:
        return st.cwnd


def apply_dfc_penalty(state: NSCCState, params: NSCCParams, ccc: jax.Array,
                      penalty: jax.Array, valid: jax.Array) -> NSCCState:
    """Destination Flow Control for NSCC (Sec. 3.3.4): the receiver sends a
    window *penalty* that scales the sender's congestion window."""
    n = state.cwnd.shape[0]
    drop = jnp.where(valid, ccc, n)
    scale = jnp.clip(1.0 - penalty, 0.05, 1.0)
    cwnd = state.cwnd.at[drop].mul(jnp.where(valid, scale, 1.0), mode="drop")
    return replace(state, cwnd=jnp.clip(cwnd, params.min_cwnd, params.max_cwnd))
