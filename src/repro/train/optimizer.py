"""AdamW, implemented in-house (no optax dependency), sharding-aware.

Moments are stored with the same PartitionSpec as their parameter, so the
optimizer state inherits the 2-D FSDP x TP layout for free. `moment_dtype`
trades memory for precision: the 300B+ architectures run bf16 moments to
fit the v5e HBM budget (see DESIGN.md §memory); everything else uses f32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32
    warmup_steps: int = 100


def init_opt_state(params: Any, cfg: AdamWConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def apply_updates(params: Any, grads: Any, opt_state: dict,
                  cfg: AdamWConfig) -> tuple[Any, dict, jax.Array]:
    """One AdamW step. Returns (params', opt_state', grad_norm)."""
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = opt_state["step"] + 1
    lr = _schedule(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), m32.astype(cfg.moment_dtype),
                v32.astype(cfg.moment_dtype))

    out = jax.tree_util.tree_map(upd, params, grads,
                                 opt_state["m"], opt_state["v"])
    new_params = jax.tree_util.tree_map(lambda o: o[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
