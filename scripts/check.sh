#!/usr/bin/env bash
# Fast smoke gate: tier-1 tests minus the slow-marked heavies, plus the
# header-stack paper bench as an import/consistency canary.
#
#   ./scripts/check.sh            # ~40s on a laptop CPU
#
# The full tier-1 gate (everything, including slow) stays
#   PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== lint (ruff) =="
if command -v ruff >/dev/null 2>&1; then
  ruff check src tests benchmarks examples
elif python -c "import ruff" >/dev/null 2>&1; then
  python -m ruff check src tests benchmarks examples
else
  echo "ruff not installed (pip install -r requirements-dev.txt); skipping lint"
fi

echo "== tier-1 tests (minus slow) =="
python -m pytest -x -q -m "not slow"

echo "== paper bench smoke: header stacks =="
python -m benchmarks.run --only headers

echo "== paper bench smoke: collectives (dep lane + INC canary) =="
python -m benchmarks.run --only collectives

echo "== fault engine smoke: flap recovery + eviction escape =="
# A mid-run link flap must be survived (timeouts fire, flows complete
# after heal) and a permanent mid-run failure of a static path must be
# escaped via EV eviction (repro.network.faults).
python -m repro.network.faults

echo "== endpoint canary: dead host -> early quiescence + abandonment =="
# A mid-run host death under a liveness-enabled profile must be torn
# down by the PDC (victim flows abandoned, run quiesces early) while
# the pdc-off twin burns the full budget; a healing NIC stall must
# complete with nothing abandoned (repro.network.faults --endpoint).
python -m repro.network.faults --endpoint

echo "== telemetry canary: the flap must be visible in the probe lanes =="
# The flap-victim scenario with telemetry on: silent drops confined to
# the fault window, goodput dip + recovery, NSCC mark back-off, heal
# trim burst — and the probes must not perturb a single bit
# (repro.network.telemetry).
python -m repro.network.telemetry

echo "== link-layer canary: LLR confinement + CBFC buffer bill =="
# The shared corruption grid at two BER points: an LLR-armed BER-y
# fabric must deliver every flow with ZERO end-to-end drops and beat
# the e2e-recovery twin on tail completion; the clean-link lane must be
# bitwise the link-off program; CBFC's credited buffer must undercut
# PFC headroom (repro.core.link).
python -m repro.core.link

echo "== traffic engine canary: plan -> schedule -> simulated step time =="
# One small config priced end-to-end: the simulated network term must
# land within [1, 10]x of the plan's alpha-beta lower bound
# (repro.network.traffic).
python -m repro.network.traffic

echo "== sharded engine smoke: 4 virtual devices, bitwise parity =="
# Fresh interpreter so the forced host-device split lands before jax
# locks the backend; the smoke runs a ragged sharded batch and asserts
# bitwise parity with the unsharded engine (repro.network.shard).
XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=4}" \
  python -m repro.network.shard

echo "== perf gate (soft): BENCH_fabric.json regression diff =="
# Soft gate: warns + flags, never fails the smoke run (wall-clock
# benches are advisory on shared machines). Set RUN_BENCH=1 to
# regenerate a fresh bench (~2 min) and diff it against the committed
# BENCH_fabric.json; >20% throughput regressions are flagged loudly.
# api_version >= 5 jsons carry a calibration scenario: ratios are
# box-drift normalized, so the diff is meaningful across machines.
if [ "${RUN_BENCH:-0}" = "1" ]; then
  rc=0
  python scripts/bench_compare.py --run || rc=$?
  if [ "$rc" -eq 2 ]; then
    echo "PERF-REGRESSION-FLAG: bench_compare reported >20% drop" \
         "(soft gate — check.sh continues; see table above)"
  elif [ "$rc" -ne 0 ]; then
    echo "BENCH-ERROR: bench_compare failed to run (exit $rc) —" \
         "no comparison was produced; fix the bench before reading perf"
  fi
else
  echo "skipped (RUN_BENCH=1 ./scripts/check.sh to run the perf diff)"
fi

echo "OK"
