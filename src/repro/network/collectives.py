"""Dependency-scheduled collectives compiled onto the packet fabric.

A collective (all-reduce, reduce-scatter, all-gather, all-to-all) is a
multi-phase exchange with data dependencies between phases: a ring
all-reduce host may forward a chunk only after it received (and reduced)
the previous phase's chunk from its left neighbor. The seed repo modeled
this as ONE steady-state neighbor-exchange phase (a netmodel proxy, now
removed) — blind to phase structure, stragglers, and algorithm choice.

This module lowers a :class:`CollectiveSpec` to a fabric
:class:`~repro.network.fabric.Workload` whose ``dep`` lane encodes the
algorithm's phase DAG (flow f eligible once flow ``dep[f]`` completes at
its source) and whose ``red`` lane marks switch-reducible fan-in groups
for in-network reduction (``repro.core.inc``, enabled by
``TransportProfile(inc=True)``). The whole collective then runs inside
one ``lax.scan`` and batches through ``simulate_batch`` like any other
workload — a kind x algorithm x INC x profile ablation grid is one call.

Algorithms
----------
* ``ring`` — 2(n-1) phases for all-reduce (reduce-scatter then
  all-gather around the ring), n-1 for reduce-scatter / all-gather;
  all-reduce and reduce-scatter circulate ceil(S/n) chunks, all-gather
  forwards whole S-sized blocks; flow (p, i) depends on (p-1, i-1 mod
  n) — the classic pipelined ring.
* ``recursive_doubling`` — log2(n) phases (n must be a power of two);
  all-reduce exchanges the full vector each phase; reduce-scatter halves
  (distance n/2 first), all-gather doubles; flow (p, i) depends on the
  phase-(p-1) flow INTO i.
* ``tree`` — all-reduce only: a switch-rooted flat tree. Every non-root
  host sends its full vector to the root (ONE reduction group — the
  fabric's switches are the tree), then the root streams the result
  back; broadcast flow to host i depends on the reduce flow from host i
  (the root pipelines results as contributions complete). With INC off
  this is the naive incast baseline; with INC on the ToR absorbs all but
  one child packet per PSN — the comparison that prices in-network
  reduction.
* ``all_to_all`` uses round-robin rounds r = 1..n-1 (i -> i+r, chunked),
  each host's rounds chained by ``dep``.

``size_pkts`` is the per-rank INPUT size S in MTU packets throughout.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.network.fabric import SimResult, Workload

KINDS = ("all_reduce", "reduce_scatter", "all_gather", "all_to_all")
ALGOS = ("ring", "recursive_doubling", "tree")


@dataclass(frozen=True)
class CollectiveSpec:
    """One collective: kind, participating host ids, per-rank size (pkts)."""

    kind: str
    hosts: tuple
    size_pkts: int

    def __post_init__(self):
        kind = self.kind.replace("-", "_")
        if kind not in KINDS:
            raise ValueError(f"unknown collective kind {self.kind!r}; "
                             f"choose from {KINDS}")
        object.__setattr__(self, "kind", kind)
        object.__setattr__(self, "hosts", tuple(int(h) for h in self.hosts))
        if len(self.hosts) < 2:
            raise ValueError("a collective needs >= 2 hosts")
        if len(set(self.hosts)) != len(self.hosts):
            raise ValueError("collective hosts must be distinct")
        if self.size_pkts < 1:
            raise ValueError("size_pkts must be >= 1")

    @property
    def n(self) -> int:
        return len(self.hosts)

    @classmethod
    def from_bytes(cls, kind: str, hosts, bytes_per_rank: float,
                   mtu: int = 4096) -> "CollectiveSpec":
        """Byte-denominated constructor (per-rank payload -> MTU packets;
        one simulator tick is one MTU serialization). True float ceiling
        with a >= 1 packet floor: any positive payload — including the
        sub-packet per-rank messages of decode-time TP all-reduces —
        occupies at least one packet, and fractional bytes are never
        truncated before rounding (4096.5 bytes is 2 packets, not 1)."""
        return cls(kind, tuple(hosts),
                   max(1, math.ceil(bytes_per_rank / mtu)))


@dataclass(frozen=True)
class FlowTable:
    """The lowered schedule, in host-INDEX space (0..n-1), as numpy.

    Kept separate from the device Workload so tests and benchmarks can
    inspect the phase structure without tracing anything.
    """

    src: np.ndarray    # [F] host index
    dst: np.ndarray    # [F]
    size: np.ndarray   # [F] packets
    dep: np.ndarray    # [F] flow index or -1
    red: np.ndarray    # [F] reduction group id or -1
    phase: np.ndarray  # [F] phase number (diagnostics)
    meta: dict = field(default_factory=dict)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _ring(n: int, s: int, kind: str) -> FlowTable:
    # per-rank INPUT denomination (see module docstring): all-reduce and
    # reduce-scatter circulate 1/n-sized chunks of the S-sized input;
    # all-gather forwards whole S-sized blocks (its input IS one block),
    # matching recursive-doubling's (n-1)*S per-host total.
    c = s if kind == "all_gather" else _ceil_div(s, n)
    phases = 2 * (n - 1) if kind == "all_reduce" else (n - 1)
    src, dst, dep, ph = [], [], [], []
    for p in range(phases):
        for i in range(n):
            src.append(i)
            dst.append((i + 1) % n)
            dep.append(-1 if p == 0 else (p - 1) * n + (i - 1) % n)
            ph.append(p)
    f = len(src)
    return FlowTable(np.asarray(src, np.int32), np.asarray(dst, np.int32),
                     np.full((f,), c, np.int32), np.asarray(dep, np.int32),
                     np.full((f,), -1, np.int32), np.asarray(ph, np.int32),
                     {"chunk": c, "phases": phases})


def _recursive_doubling(n: int, s: int, kind: str) -> FlowTable:
    d = n.bit_length() - 1
    if (1 << d) != n:
        raise ValueError(f"recursive_doubling needs a power-of-two host "
                         f"count, got {n}")
    if kind == "all_reduce":
        dist = [1 << p for p in range(d)]
        sizes = [s] * d
    elif kind == "reduce_scatter":     # recursive halving, far pairs first
        dist = [n >> (p + 1) for p in range(d)]
        sizes = [_ceil_div(s, 1 << (p + 1)) for p in range(d)]
    else:                              # all_gather: doubling
        dist = [1 << p for p in range(d)]
        sizes = [s * (1 << p) for p in range(d)]
    src, dst, size, dep, ph = [], [], [], [], []
    for p in range(d):
        for i in range(n):
            src.append(i)
            dst.append(i ^ dist[p])
            size.append(sizes[p])
            # the phase-(p-1) flow INTO i came from i ^ dist[p-1]
            dep.append(-1 if p == 0 else (p - 1) * n + (i ^ dist[p - 1]))
            ph.append(p)
    f = len(src)
    return FlowTable(np.asarray(src, np.int32), np.asarray(dst, np.int32),
                     np.asarray(size, np.int32), np.asarray(dep, np.int32),
                     np.full((f,), -1, np.int32), np.asarray(ph, np.int32),
                     {"phases": d})


def _tree(n: int, s: int, kind: str) -> FlowTable:
    if kind != "all_reduce":
        raise ValueError("the tree algorithm is defined for all_reduce only")
    src, dst, size, dep, red, ph = [], [], [], [], [], []
    for i in range(1, n):              # reduce: every non-root -> root
        src.append(i)
        dst.append(0)
        size.append(s)
        dep.append(-1)
        red.append(0)                  # one switch-reducible fan-in group
        ph.append(0)
    for i in range(1, n):              # broadcast: root -> every non-root
        src.append(0)
        dst.append(i)
        size.append(s)
        dep.append(i - 1)              # pipelined on reduce flow from i
        red.append(-1)
        ph.append(1)
    f = len(src)
    return FlowTable(np.asarray(src, np.int32), np.asarray(dst, np.int32),
                     np.asarray(size, np.int32), np.asarray(dep, np.int32),
                     np.asarray(red, np.int32), np.asarray(ph, np.int32),
                     {"phases": 2, "root": 0})


def _all_to_all(n: int, s: int) -> FlowTable:
    c = _ceil_div(s, n)
    src, dst, dep, ph = [], [], [], []
    for r in range(1, n):
        for i in range(n):
            src.append(i)
            dst.append((i + r) % n)
            dep.append(-1 if r == 1 else (r - 2) * n + i)
            ph.append(r - 1)
    f = len(src)
    return FlowTable(np.asarray(src, np.int32), np.asarray(dst, np.int32),
                     np.full((f,), c, np.int32), np.asarray(dep, np.int32),
                     np.full((f,), -1, np.int32), np.asarray(ph, np.int32),
                     {"chunk": c, "rounds": n - 1})


def flow_table(spec: CollectiveSpec, algo: str = "ring") -> FlowTable:
    """Lower a spec to its dependency-scheduled flow table."""
    n, s = spec.n, spec.size_pkts
    if spec.kind == "all_to_all":
        return _all_to_all(n, s)
    if algo == "ring":
        return _ring(n, s, spec.kind)
    if algo == "recursive_doubling":
        return _recursive_doubling(n, s, spec.kind)
    if algo == "tree":
        return _tree(n, s, spec.kind)
    raise ValueError(f"unknown algorithm {algo!r}; choose from {ALGOS}")


def build_workload(spec: CollectiveSpec, algo: str = "ring", *,
                   inc_groups: bool = True) -> Workload:
    """The fabric Workload for one whole collective (host-id space).

    ``inc_groups=False`` strips the ``red`` lanes (all -1), turning
    in-network reduction off *for this scenario* even under an
    ``inc=True`` profile. Because ``red`` is a traced lane and the INC
    machinery is an exact no-op on group-free traffic (bitwise — see
    tests), INC on/off is a data axis, not a compile axis: an INC
    ablation grid shares one executable per transport profile instead
    of two."""
    t = flow_table(spec, algo)
    hosts = np.asarray(spec.hosts, np.int32)
    red = t.red if inc_groups else np.full_like(t.red, -1)
    return Workload.of(hosts[t.src], hosts[t.dst], t.size,
                       dep=t.dep, red=red)


def expected_host_rx(spec: CollectiveSpec, algo: str = "ring") -> np.ndarray:
    """[n] packets each host receives with INC OFF (reliable delivery =>
    exact counts; the INC correctness tests anchor on these)."""
    t = flow_table(spec, algo)
    rx = np.zeros((spec.n,), np.int64)
    np.add.at(rx, t.dst, t.size.astype(np.int64))
    return rx


def analytic_ticks(spec: CollectiveSpec, algo: str = "ring") -> int:
    """Serialization lower bound in ticks (1 packet/tick line rate,
    latency ignored): the longest per-host send/receive chain."""
    t = flow_table(spec, algo)
    n = spec.n
    # per-host injected packets lower-bounds by NIC serialization; the
    # dependency chain lower-bounds by phase structure
    tx = np.zeros((n,), np.int64)
    np.add.at(tx, t.src, t.size.astype(np.int64))
    rx = expected_host_rx(spec, algo)
    chain = np.zeros((len(t.src),), np.int64)
    for f in np.argsort(t.phase, kind="stable"):
        d = int(t.dep[f])
        chain[f] = int(t.size[f]) + (chain[d] if d >= 0 else 0)
    return int(max(tx.max(), rx.max(), chain.max()))


def collective_completion_ticks(result: SimResult) -> int:
    """Tick at which the collective finished: every flow source-complete
    (the INC-correct notion — absorbed packets are ACKed at the switch
    and never surface at the receiver). -1 = did not finish in the run.

    Works on both trace tiers: under the default ``trace="stats"`` this
    reads the completion lane streamed inside the chunked while-scan, so
    pricing a collective costs no dense per-tick trace at all."""
    return result.source_completion_tick()


def stack_padded(wls: "list[Workload]") -> Workload:
    """Stack workloads of different flow counts along a scenario axis by
    padding each with inert flows (size 0 => complete at tick 0, never
    eligible, deliver nothing) up to the widest scenario. This is how a
    heterogeneous collective sweep (ring vs tree vs all-to-all have very
    different F) becomes ONE ``simulate_batch`` call."""
    import jax.numpy as jnp
    fmax = max(int(w.src.shape[0]) for w in wls)
    padded = []
    for w in wls:
        f = int(w.src.shape[0])
        pad = fmax - f
        if pad == 0:
            padded.append(w)
            continue
        z = jnp.zeros((pad,), jnp.int32)
        neg = jnp.full((pad,), -1, jnp.int32)
        padded.append(Workload(
            src=jnp.concatenate([w.src, z]),
            dst=jnp.concatenate([w.dst, z]),
            size=jnp.concatenate([w.size, z]),
            start=jnp.concatenate([w.start, z]),
            dep=jnp.concatenate([w.dep, neg]),
            red=jnp.concatenate([w.red, neg]),
        ))
    return Workload.stack(padded)
