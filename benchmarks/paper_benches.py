"""One benchmark per paper table/figure. Each returns rows of
(name, value, expected, note); run.py prints CSV and wall-times."""
from __future__ import annotations

import numpy as np

from repro.core import headers, messaging
from repro.core.lb.schemes import LBScheme
from repro.core.types import MsgProtocol, TransportMode
from repro.network import workloads
from repro.network.ecmp import RoutingTables
from repro.network.fabric import SimParams, simulate
from repro.network.profile import TransportProfile
from repro.network.topology import paper_fig2

import jax.numpy as jnp


def bench_ecmp_collisions():
    """Sec. 2.1: EV path-collision probability on the Fig. 2 fat tree —
    25% same-pod (4 paths), 6.25% cross-pod (16 paths)."""
    g = paper_fig2()
    rt = RoutingTables(g)
    n = 200_000
    rng = np.random.default_rng(0)
    ev1 = jnp.asarray(rng.integers(0, 65536, n), jnp.int32)
    ev2 = jnp.asarray(rng.integers(0, 65536, n), jnp.int32)

    # same pod, different leaves: host 0 (leaf 0) -> host 5 (leaf 1)
    src = jnp.zeros((n,), jnp.int32)
    dst = jnp.full((n,), 5, jnp.int32)
    fp1 = rt.path_fingerprint(src, dst, ev1)
    fp2 = rt.path_fingerprint(src, dst, ev2)
    same_pod = float(np.mean(np.asarray(fp1) == np.asarray(fp2)))

    # cross pod: host 0 -> host 17 (pod 1)
    dst2 = jnp.full((n,), 17, jnp.int32)
    fp1x = rt.path_fingerprint(src, dst2, ev1)
    fp2x = rt.path_fingerprint(src, dst2, ev2)
    cross_pod = float(np.mean(np.asarray(fp1x) == np.asarray(fp2x)))

    return [
        ("ev_collision_same_pod", same_pod, 0.25,
         "4 equal-cost paths in-pod"),
        ("ev_collision_cross_pod", cross_pod, 0.0625,
         "16 equal-cost paths cross-pod"),
        ("paths_same_pod", g.num_paths_same_pod, 4, ""),
        ("paths_cross_pod", g.num_paths_cross_pod, 16, ""),
    ]


def bench_headers():
    """Sec. 3.2.2 / Fig. 3: header stack byte counts and wire efficiency."""
    rows = []
    stacks = {
        "rud_udp_ipv4": headers.HeaderConfig(),
        "rud_rccc": headers.HeaderConfig(rccc=True),
        "rud_native_ip_min": headers.HeaderConfig(
            native_ip=True, ses=headers.SES_HEADER_MIN),
        "rud_tss_ipv6": headers.HeaderConfig(ipv6=True, tss=True),
        "uud_min": headers.HeaderConfig(mode=TransportMode.UUD,
                                        ses=headers.SES_HEADER_MIN),
        "rudi_min": headers.HeaderConfig(mode=TransportMode.RUDI,
                                         ses=headers.SES_HEADER_MIN),
    }
    expect_overhead = {"rud_udp_ipv4": 102, "rud_rccc": 106,
                       "rud_native_ip_min": 74, "rud_tss_ipv6": 150,
                       "uud_min": 70, "rudi_min": 74}
    for name, cfg in stacks.items():
        rows.append((f"overhead_{name}", cfg.overhead_bytes(),
                     expect_overhead[name], "bytes/packet"))
        rows.append((f"efficiency_{name}", round(cfg.efficiency(4096), 4),
                     None, "goodput fraction @4KiB MTU"))
    return rows


def bench_messaging():
    """Sec. 3.1.3 table: completion time of the three large-message
    protocols, expected and unexpected, playout vs alpha/beta model."""
    link = messaging.LinkModel(alpha=1.0, beta=0.01)
    size = 1000.0
    rows = []
    for proto in MsgProtocol:
        for expected in (True, False):
            ts, tr = (5.0, 2.0) if expected else (2.0, 12.0)
            model = messaging.model_completion(proto, expected, size, ts,
                                               tr, link)
            sim = messaging.simulate_protocol(proto, size, ts, tr, link,
                                              eager_limit=2000.0)
            tag = f"{proto.name.lower()}_{'exp' if expected else 'unexp'}"
            rows.append((f"t_complete_{tag}", sim.receiver_complete, model,
                         "playout == table model"))
    return rows


def bench_congestion():
    """Fig. 7: incast / outcast / in-network bandwidth shares.

    RCCC-only == TransportProfile.ai_base(); NSCC-only == ai_full()."""
    rows = []
    g, wl, exp = workloads.incast(4, size=100000)
    r = simulate(g, wl, TransportProfile.ai_base(), SimParams(ticks=1200),
                 goodput_window=(300, 1200))
    rows.append(("incast_rccc_share", round(float(
        r.goodput((300, 1200)).mean()), 3), exp["share"],
        "4->1 incast, RCCC exact fair share"))

    g, wl, exp = workloads.outcast(4, size=100000)
    r = simulate(g, wl, TransportProfile.ai_base(), SimParams(ticks=2500),
                 goodput_window=(800, 2500))
    rows.append(("outcast_rccc_w_share", round(float(
        r.goodput((800, 2500))[4]), 3), exp["rccc_w_share"],
        "RCCC blind grant wastes 25%"))
    r = simulate(g, wl, TransportProfile.ai_full(), SimParams(ticks=2500),
                 goodput_window=(1200, 2500))
    rows.append(("outcast_nscc_w_share", round(float(
        r.goodput((1200, 2500))[4]), 3), exp["nscc_w_share"],
        "NSCC converges to the optimum"))

    g, wl, exp = workloads.in_network(12, 4, size=100000)
    r = simulate(g, wl, TransportProfile.ai_base(), SimParams(ticks=2500),
                 goodput_window=(800, 2500))
    gp = r.goodput((800, 2500))
    rows.append(("innetwork_cross_share", round(float(gp[:12].mean()), 3),
                 exp["cross_share"], "12 flows over 4 uplinks"))
    rows.append(("innetwork_rccc_local", round(float(gp[12]), 3),
                 exp["rccc_local_share"], "granted 50% though 67% free"))
    return rows


def bench_loadbalance():
    """Sec. 2.1 + 3.3.5: polarization vs spraying vs REPS/EV-bitmap."""
    g, wl, _ = workloads.permutation(k=8, pods=4, shift=17, size=100000)
    rows = []
    for scheme in (LBScheme.STATIC, LBScheme.OBLIVIOUS, LBScheme.RR_SLOTS,
                   LBScheme.REPS, LBScheme.EVBITMAP):
        r = simulate(g, wl, TransportProfile.ai_full(lb=scheme),
                     SimParams(ticks=1500), goodput_window=(700, 1500))
        gp = r.goodput((700, 1500))
        rows.append((f"perm_goodput_{scheme.name.lower()}",
                     round(float(gp.mean()), 3), None,
                     f"min {gp.min():.3f} trims {int(r.state.trims)}"))
    return rows


def bench_loss_detection():
    """Sec. 3.2.4: trimming vs OOO-count vs timeout-only recovery."""
    rows = []
    # short burst: recovery latency (not downlink capacity) dominates
    g, wl, _ = workloads.incast(8, size=48)
    prof = TransportProfile.ai_full()
    base = dict(ticks=2500, timeout_ticks=300)
    r = simulate(g, wl, prof, SimParams(trimming=True, **base))
    rows.append(("completion_trimming", int(r.completion_ticks().mean()),
                 None, f"trims {int(r.state.trims)}"))
    r = simulate(g, wl, prof, SimParams(trimming=False, ooo_threshold=48,
                                        **base))
    ct = r.completion_ticks()
    rows.append(("completion_ooo_count",
                 int(ct.mean()) if (ct >= 0).all() else -1, None,
                 f"drops {int(r.state.drops)}"))
    r = simulate(g, wl, prof, SimParams(trimming=False, **base))
    ct = r.completion_ticks()
    rows.append(("completion_timeout_only",
                 int(ct.mean()) if (ct >= 0).all() else -1, None,
                 f"drops {int(r.state.drops)} (-1 = unfinished)"))
    return rows


def bench_collective_efficiency():
    """Framework integration: achieved efficiency (analytic alpha-beta
    time / simulated completion) of WHOLE dependency-scheduled
    collectives under UET transport options (feeds the roofline
    collective term; see repro/distributed/netmodel.py)."""
    from repro.distributed.netmodel import simulated_efficiency
    rows = []
    for kind in ("all-reduce", "all-to-all"):
        for lb, name in ((LBScheme.STATIC, "static"),
                         (LBScheme.OBLIVIOUS, "spray"),
                         (LBScheme.REPS, "reps")):
            eff = simulated_efficiency(kind=kind, hosts=8, size_pkts=64,
                                       lb=lb)
            rows.append((f"eff_{kind.replace('-', '_')}_{name}",
                         round(eff, 3), None,
                         "analytic/simulated completion time"))
    return rows


def bench_collectives():
    """Dependency-scheduled collectives + in-network reduction: a small
    all-reduce algorithm ablation (ring vs recursive-doubling vs tree,
    INC off/on) as ONE simulate_batch call — the import/consistency
    canary scripts/check.sh runs (`benchmarks.run --only collectives`)."""
    from dataclasses import replace

    from repro.distributed.netmodel import (FabricSpec,
                                            analytic_time_for_spec,
                                            simulated_collective_time)
    from repro.network import collectives as coll
    from repro.network.fabric import SimParams, simulate_batch
    from repro.network.topology import leaf_spine

    n, s = 8, 24
    g = leaf_spine(leaves=4, spines=4, hosts_per_leaf=2)
    spec = coll.CollectiveSpec("all_reduce", tuple(range(n)), s)
    ai = TransportProfile.ai_full()
    ai_inc = replace(ai, inc=True, name="ai_full+inc")
    cfgs = [("ring", ai), ("recursive_doubling", ai),
            ("tree", ai), ("tree", ai_inc)]
    wls = coll.stack_padded([coll.build_workload(spec, a) for a, _ in cfgs])
    rs = simulate_batch(g, wls, [p for _, p in cfgs], SimParams(ticks=900))
    cts = {f"{a}{'_inc' if p.inc else ''}":
           coll.collective_completion_ticks(r)
           for (a, p), r in zip(cfgs, rs)}
    rows = [(f"allreduce_ct_{name}", ct, None,
             f"n={n} S={s}pkts (ticks to completion, -1 = unfinished)")
            for name, ct in cts.items()]
    ratio = (round(cts["tree_inc"] / cts["tree"], 3)
             if cts["tree"] > 0 and cts["tree_inc"] > 0 else "unfinished")
    rows.append(("inc_tree_ct_ratio", ratio, None,
                 "INC on/off completion ratio, < 1.0 = switch wins"))
    rows.append(("inc_reduced_pkts", int(rs[3].state.inc_reduced), None,
                 "packets absorbed at the ToR (upstream savings)"))
    # the modeling-contract anchor: packet-level >= alpha-beta bound
    fs = FabricSpec()
    t_sim = simulated_collective_time("all-reduce", chips=n, size_pkts=s,
                                      fabric=fs)
    t_ana = analytic_time_for_spec("all-reduce", s, n, fs)
    rows.append(("simulated_ge_analytic", int(t_sim >= t_ana), 1,
                 f"sim {t_sim:.2e}s vs analytic {t_ana:.2e}s"))
    rows.append(("host_rx_total", int(np.asarray(rs[0].state.delivered).sum()),
                 int(coll.expected_host_rx(spec, "ring").sum()),
                 "reliable delivery: exact per-host totals (ring, INC off)"))
    return rows


def bench_failure_mitigation():
    """REPS failure mitigation [5]: one of 4 uplinks dead; 8 flows share 3
    live uplinks (optimum 3/8 = 0.375/flow). REPS converges near optimum;
    oblivious spraying keeps paying the dead path."""
    from repro.network.fabric import Workload
    from repro.network.topology import leaf_spine
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=8)
    wl = Workload.of(list(range(8)), [8 + i for i in range(8)], 100000)
    dead = (int(g.up1_table[0, 0]),)
    rows = []
    for scheme in (LBScheme.OBLIVIOUS, LBScheme.REPS):
        p = SimParams(ticks=3000, timeout_ticks=64, ooo_threshold=24)
        r = simulate(g, wl, TransportProfile.ai_full(lb=scheme), p,
                     failed=dead, goodput_window=(1500, 3000))
        rows.append((f"fail_goodput_{scheme.name.lower()}",
                     round(float(r.goodput((1500, 3000)).mean()), 3),
                     0.375 if scheme == LBScheme.REPS else None,
                     "optimum 3/8 with 1 of 4 uplinks dead"))
    return rows


def bench_failure_sweep_batched():
    """REPS under every single-uplink failure at once: one vmapped scan
    over (healthy + 4 single-failure) scenarios via simulate_batch —
    the scenario-diversity story (each dead uplink must degrade to the
    same 3-live-uplink optimum; the fabric is symmetric)."""
    from repro.network.fabric import simulate_batch
    g, wls, masks, exp = workloads.failure_sweep(spines=4, hosts_per_leaf=8)
    p = SimParams(ticks=3000, timeout_ticks=64, ooo_threshold=24)
    results = simulate_batch(g, wls, TransportProfile.ai_full(lb=LBScheme.REPS),
                             p, failed=masks, goodput_window=(1500, 3000))
    rows = [("sweep_goodput_healthy",
             round(float(results[0].goodput((1500, 3000)).mean()), 3),
             exp["healthy_share"], "no failures")]
    deg = [float(r.goodput((1500, 3000)).mean()) for r in results[1:]]
    rows.append(("sweep_goodput_degraded_mean", round(float(np.mean(deg)), 3),
                 exp["degraded_share"], "mean over 4 single-uplink failures"))
    rows.append(("sweep_goodput_degraded_spread",
                 round(float(np.max(deg) - np.min(deg)), 3), None,
                 "symmetry: all dead uplinks look alike"))
    return rows


ALL_BENCHES = [
    ("ecmp_collisions(Fig2/Sec2.1)", bench_ecmp_collisions),
    ("headers(Sec3.2.2/Fig3)", bench_headers),
    ("messaging(Sec3.1.3/Fig5)", bench_messaging),
    ("congestion(Fig7)", bench_congestion),
    ("loadbalance(Sec3.3.5)", bench_loadbalance),
    ("loss_detection(Sec3.2.4)", bench_loss_detection),
    ("collective_efficiency(netmodel)", bench_collective_efficiency),
    ("collectives(dep-sched+INC)", bench_collectives),
    ("failure_mitigation(REPS[5])", bench_failure_mitigation),
    ("failure_sweep_batched(REPS[5])", bench_failure_sweep_batched),
]
