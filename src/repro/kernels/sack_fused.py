"""Pallas TPU kernel: fused SACK record-rx + CACK-advance + rtx shift.

The per-ACK-round hot loop of a UET source PDS (Sec. 3.2.5 + 3.2.4) is
three dense per-PDC ring operations in sequence:

  1. **record-rx** — OR the freshly SACKed PSN bits into the tracking ring
     (the lane->word scatter mask is built by XLA outside the kernel —
     data-dependent cross-lane scatter is not a TPU vector op — and
     applied here);
  2. **CACK-advance** — count the contiguous received prefix, advance the
     base PSN;
  3. **ring shifts** — funnel-shift *both* the SACK ring and the source's
     retransmit-pending bitmap down by the advance, keeping the two rings
     anchored at the same base.

Running them as separate kernels round-trips every ring through HBM three
times; fused, each [N, W] operand is read and written exactly once, and
the two funnel shifts share one pair of one-hot gather matrices (the
gather-free TPU idiom from sack_bitmap.py: a W x W masked reduction
instead of a per-row variable gather).

Block layout: (BLOCK_R rows) x (128 lanes) per grid step, all in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pds import _popcount32
from repro.kernels import auto_interpret

BLOCK_R = 64
WORD = 32


def _funnel_shift(ring, one_hot_lo, one_hot_hi, bits):
    """Per-row right-shift by (words, bits) using shared one-hot selectors."""
    ring_b = ring[:, None, :]                                  # [R, 1, W]
    lo = jnp.sum(ring_b * one_hot_lo, axis=2, dtype=jnp.uint32)
    hi = jnp.sum(ring_b * one_hot_hi, axis=2, dtype=jnp.uint32)
    b = bits[:, None]
    return jnp.where(b == 0, lo,
                     (lo >> b) | (hi << (jnp.uint32(WORD) - b)))


def _fused_kernel(ring_ref, base_ref, rtx_ref, mask_ref,
                  ring_out_ref, base_out_ref, rtx_out_ref, adv_ref,
                  *, w: int):
    ring = ring_ref[...][:, :w] | mask_ref[...][:, :w]   # 1. record-rx
    rtx = rtx_ref[...][:, :w]
    base = base_ref[...]                                 # [R, 128] col 0 used
    R = ring.shape[0]

    # --- 2. trailing ones per row -> advance ---
    inv = ~ring
    lsb = inv & (jnp.uint32(0) - inv)
    ctz = _popcount32(lsb - jnp.uint32(1))
    ctz = jnp.where(inv == jnp.uint32(0), WORD, ctz)          # all-ones word
    full = ring == jnp.uint32(0xFFFFFFFF)
    col = jax.lax.broadcasted_iota(jnp.int32, (R, w), 1)
    first_partial = jnp.min(jnp.where(~full, col, w), axis=1)  # [R]
    sel = col == first_partial[:, None]
    partial_bits = jnp.sum(jnp.where(sel, ctz, 0), axis=1)
    adv = jnp.where(first_partial == w, w * WORD,
                    first_partial * WORD + partial_bits)       # [R]

    # --- 3. shared funnel shift of both rings, gather-free ---
    words = adv // WORD
    bits = (adv % WORD).astype(jnp.uint32)
    shift_idx = col + words[:, None]                           # [R, W]
    k = jax.lax.broadcasted_iota(jnp.int32, (R, w, w), 2)      # [R, W, W]
    one_hot_lo = (k == shift_idx[:, :, None]).astype(jnp.uint32)
    one_hot_hi = (k == (shift_idx + 1)[:, :, None]).astype(jnp.uint32)
    ring_s = _funnel_shift(ring, one_hot_lo, one_hot_hi, bits)
    rtx_s = _funnel_shift(rtx, one_hot_lo, one_hot_hi, bits)

    out = ring_out_ref[...]
    ring_out_ref[...] = out.at[:, :w].set(ring_s)
    out = rtx_out_ref[...]
    rtx_out_ref[...] = out.at[:, :w].set(rtx_s)
    col0 = (jax.lax.broadcasted_iota(jnp.int32, base.shape, 1) == 0)
    base_out_ref[...] = base + adv.astype(jnp.uint32)[:, None] * col0.astype(
        jnp.uint32)
    adv_ref[...] = adv[:, None] * col0


@functools.partial(jax.jit, static_argnames=("interpret",))
def sack_fused(ring: jax.Array, base: jax.Array, rtx: jax.Array,
               mask: jax.Array, interpret: bool | None = None):
    """Fused record-rx / CACK-advance / dual ring shift over N PDCs.

    ring, rtx, mask: [N, W] uint32 (W <= 32 words); base: [N] uint32.
    `mask` carries the bits to OR into `ring` (built by pds.or_mask).
    Returns (new_ring, new_base, new_rtx, advanced[int32]).
    """
    interpret = auto_interpret(interpret)
    n, w = ring.shape
    assert rtx.shape == ring.shape and mask.shape == ring.shape
    assert w <= 128
    rows = -(-n // BLOCK_R) * BLOCK_R
    padr = rows - n
    pad2 = lambda a: jnp.pad(a, ((0, padr), (0, 128 - w)))
    base_p = jnp.pad(base.reshape(-1, 1), ((0, padr), (0, 127)))

    grid = (rows // BLOCK_R,)
    spec128 = pl.BlockSpec((BLOCK_R, 128), lambda i: (i, 0))
    ring_o, base_o, rtx_o, adv_o = pl.pallas_call(
        functools.partial(_fused_kernel, w=w),
        grid=grid,
        in_specs=[spec128, spec128, spec128, spec128],
        out_specs=[spec128, spec128, spec128, spec128],
        out_shape=[
            jax.ShapeDtypeStruct((rows, 128), jnp.uint32),
            jax.ShapeDtypeStruct((rows, 128), jnp.uint32),
            jax.ShapeDtypeStruct((rows, 128), jnp.uint32),
            jax.ShapeDtypeStruct((rows, 128), jnp.int32),
        ],
        interpret=interpret,
    )(pad2(ring), base_p, pad2(rtx), pad2(mask))
    return ring_o[:n, :w], base_o[:n, 0], rtx_o[:n, :w], adv_o[:n, 0]
