"""Declarative UET transport profiles (Sec. 2.2) — the public knob surface.

The UET spec's usability claim is that ONE transport composes into many
operating points: profiles (AI Base / AI Full / HPC), per-flow delivery
modes (ROD / RUD / RUDI), and selectable congestion control (sender-based
NSCC, receiver-based RCCC, or both, Sec. 3.3.3). A
:class:`TransportProfile` is the frozen, hashable spec of one such
composition; the fabric engine (`repro.network.fabric`) compiles one
executable per profile and sweeps everything else (workloads, seeds,
failure masks) as traced inputs.

Composition contract
--------------------
* ``cc`` picks the congestion-control policy object (see `make_cc_policy`):
  a small protocol of per-tick hooks (``on_ack`` / ``on_nack`` /
  ``on_send_gate`` / ...) over the engine's densified per-flow lanes.
  New CC algorithms implement the same protocol and land without touching
  the engine.
* ``lb`` picks the Entropy-Value load-balancing scheme
  (`repro.core.lb.schemes.LBPolicy`). A profile whose flows are all ROD
  pins the scheme to STATIC (single path per flow, as the spec requires
  for ordered delivery).
* ``delivery`` is either one :class:`DeliveryMode` for every flow or a
  tuple with one mode per flow. ROD flows use go-back-N on a static path
  and additionally gate injection on in-order CACK advance; RUD flows
  spray with selective retransmit; RUDI flows are RUD with idempotent
  re-application at the receiver (no semantic dedup needed — the fabric
  still counts first copies for stats).

Everything in a profile is **static**: it is part of the compile-cache
key, so two profiles never share an executable, and sweeping a profile
axis means one compiled scan per distinct profile (the batched entry
point groups scenarios by profile for you).

Named profiles — the paper's Sec. 2.2 table, mapped onto the transport
compositions this simulator models:

* ``ai_base()``  — minimal NICs: receiver-driven credits (RCCC) pair with
  the profile's receiver-initiated large-message protocol (Sec. 3.1.3);
  oblivious spraying; unordered delivery.
* ``ai_full()``  — AI Base's semantic surface plus deferrable send; on the
  wire it is the engine's default operating point: sender-based NSCC,
  oblivious spraying, RUD. (This profile is the bitwise-parity anchor
  against the pre-refactor engine.)
* ``hpc()``      — the full feature set: ordered delivery (ROD) for tag
  ordering, both CC loops composed, and REPS recycling for any flows
  overridden back to RUD.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field, replace

import jax.numpy as jnp
import numpy as np

from repro.core.cms.nscc import NSCCParams, NSCCPolicy
from repro.core.cms.rccc import RCCCPolicy
from repro.core.lb.schemes import LBScheme


class CCAlgo(enum.IntEnum):
    """Congestion-control composition (Sec. 3.3): sender-based, receiver-
    based, both (the spec's recommended hybrid), or open loop (fixed
    window — the ablation baseline)."""

    NONE = 0
    NSCC = 1
    RCCC = 2
    NSCC_AND_RCCC = 3


class DeliveryMode(enum.IntEnum):
    """Per-flow PDS delivery mode (Sec. 3.2.1). Codes match
    `repro.core.types.TransportMode` so headers and the fabric agree."""

    RUD = 0   # reliable unordered — spraying + selective retransmit
    ROD = 1   # reliable ordered — go-back-N on one static path
    RUDI = 3  # reliable unordered, idempotent ops — dedup-free receiver


@dataclass(frozen=True)
class TransportProfile:
    """Frozen, hashable spec of one transport operating point.

    ``name`` is a display label only — it is excluded from equality and
    hashing, so `replace(ai_full(), cc=...)` still keys the compile cache
    by what it *does*, not what it is called.
    """

    cc: CCAlgo = CCAlgo.NSCC
    lb: LBScheme = LBScheme.OBLIVIOUS
    delivery: "DeliveryMode | tuple[DeliveryMode, ...]" = DeliveryMode.RUD
    #: in-network collectives: switch-resident reduction of flows marked
    #: with a ``Workload.red`` group id (see repro.core.inc). Static —
    #: INC-off profiles compile the exact pre-INC tick.
    inc: bool = False
    #: retransmission-timeout backoff: each RTO that fires multiplies the
    #: flow's timeout by this factor (capped at ``rto_max_scale`` x the
    #: base ``SimParams.timeout_ticks``); any ACK resets it. 1.0 = fixed
    #: RTO, bitwise the pre-fault-engine behavior (and compiled as such:
    #: the backoff lanes are statically elided).
    rto_backoff: float = 1.0
    #: cap on the backoff, as a multiple of the base timeout.
    rto_max_scale: int = 8
    #: closed recovery loop: on RTO expiry (and on trim NACKs for sprayed
    #: flows) the LB policy EVICTS the offending Entropy Value — it is
    #: blacklisted, purged from the EV set / REPS recycle ring, and fresh
    #: draws re-roll away from it — so flows migrate off dead paths
    #: instead of re-rolling into them (SMaRTT-style path penalization).
    #: Static: eviction-off profiles compile the exact pre-eviction tick.
    ev_eviction: bool = False
    #: PDC liveness teardown (the endpoint-failure recovery loop): after
    #: this many CONSECUTIVE RTO expiries with zero forward progress (no
    #: ACK between them), the flow's Packet Delivery Context is declared
    #: unreachable and torn down — the flow is QUARANTINED (no further
    #: injection or retransmit bandwidth), counted in
    #: ``SimResult.flows_abandoned``, and treated as settled by the
    #: quiescence predicate so permanent endpoint death terminates the
    #: run early instead of burning the whole tick budget. 0 disables
    #: (bitwise the pre-teardown behavior; the lanes are statically
    #: elided). Detection time ~ the sum of the backed-off RTO series,
    #: so compose with ``rto_backoff`` for production-style spacing.
    pdc_dead_after: int = 0
    name: str = field(default="custom", compare=False)

    def __post_init__(self):
        if isinstance(self.delivery, (list, tuple)):
            object.__setattr__(
                self, "delivery",
                tuple(DeliveryMode(m) for m in self.delivery))
        else:
            object.__setattr__(self, "delivery", DeliveryMode(self.delivery))
        if self.rto_backoff < 1.0:
            raise ValueError(f"rto_backoff must be >= 1.0 (got "
                             f"{self.rto_backoff}); 1.0 disables backoff")
        if self.rto_max_scale < 1:
            raise ValueError(f"rto_max_scale must be >= 1, got "
                             f"{self.rto_max_scale}")
        if self.pdc_dead_after < 0:
            raise ValueError(f"pdc_dead_after must be >= 0 (got "
                             f"{self.pdc_dead_after}); 0 disables liveness "
                             f"teardown")

    # -- named constructors (paper Sec. 2.2 profile table) ----------------
    @classmethod
    def ai_base(cls, **overrides) -> "TransportProfile":
        return cls(**{"cc": CCAlgo.RCCC, "lb": LBScheme.OBLIVIOUS,
                      "delivery": DeliveryMode.RUD, "name": "ai_base",
                      **overrides})

    @classmethod
    def ai_full(cls, **overrides) -> "TransportProfile":
        return cls(**{"cc": CCAlgo.NSCC, "lb": LBScheme.OBLIVIOUS,
                      "delivery": DeliveryMode.RUD, "name": "ai_full",
                      **overrides})

    @classmethod
    def hpc(cls, **overrides) -> "TransportProfile":
        return cls(**{"cc": CCAlgo.NSCC_AND_RCCC, "lb": LBScheme.REPS,
                      "delivery": DeliveryMode.ROD, "name": "hpc",
                      **overrides})

    @classmethod
    def resilient(cls, **overrides) -> "TransportProfile":
        """ai_full plus the whole recovery loop: exponential RTO backoff,
        EV path eviction, and PDC liveness teardown after 4 consecutive
        dead RTOs — the endpoint-failure operating point the resilience
        sweep and the host-fault canary run."""
        return cls(**{"cc": CCAlgo.NSCC, "lb": LBScheme.OBLIVIOUS,
                      "delivery": DeliveryMode.RUD, "rto_backoff": 2.0,
                      "ev_eviction": True, "pdc_dead_after": 4,
                      "name": "resilient", **overrides})

    # -- derived views -----------------------------------------------------
    def delivery_modes(self, num_flows: int) -> np.ndarray:
        """[F] int array of DeliveryMode codes (validates per-flow tuples)."""
        if isinstance(self.delivery, tuple):
            if len(self.delivery) != num_flows:
                raise ValueError(
                    f"profile has {len(self.delivery)} per-flow delivery "
                    f"modes but the workload has {num_flows} flows")
            return np.asarray([int(m) for m in self.delivery], np.int32)
        return np.full((num_flows,), int(self.delivery), np.int32)

    def describe(self) -> str:
        d = (self.delivery.name if isinstance(self.delivery, DeliveryMode)
             else "per-flow[" + ",".join(m.name for m in self.delivery) + "]")
        inc = ", inc=on" if self.inc else ""
        rec = ""
        if self.rto_backoff != 1.0:
            rec += (f", rto_backoff={self.rto_backoff:g}x"
                    f"(cap {self.rto_max_scale}x)")
        if self.ev_eviction:
            rec += ", ev_eviction=on"
        if self.pdc_dead_after:
            rec += f", pdc_dead_after={self.pdc_dead_after}"
        return (f"{self.name}(cc={self.cc.name}, lb={self.lb.name}, "
                f"delivery={d}{inc}{rec})")


# ---------------------------------------------------------------------------
# CC policy protocol + composition
# ---------------------------------------------------------------------------
#
# A CC policy is a frozen object the engine composes the tick from. Its
# state is an arbitrary pytree carried inside SimState; the hooks run at
# fixed points of the tick, all over densified [F] lanes:
#
#   create(F)                      -> state pytree
#   on_ack(st, has_ack, ecn, rtt)  -> st    ACK arrived (<=1 per flow/tick)
#   on_nack(st, count)             -> st    loss evidence (trim/OOO NACKs)
#   on_grant_tick(st, dst, active, H) -> st receiver scheduling round
#   on_send_gate(st, inflight)     -> [F] bool  may this flow inject?
#   on_inject(st, injected)        -> st    a packet actually left
#   on_rx_seen(st, seen)           -> st    receiver observed flow activity
#   on_timeout(st, stalled)        -> st    retransmit timer fired
#   end_of_tick(st, tick)          -> st    epoch work (Quick Adapt)
#   cwnd_view(st)                  -> [F] float32  reported window lane
#
# NSCCPolicy / RCCCPolicy live next to their algorithms in
# repro.core.cms; the open-loop baseline and the hybrid composition below.


@dataclass(frozen=True)
class OpenLoopPolicy:
    """No congestion control: a fixed window of `max_cwnd` packets."""

    max_cwnd: float

    def create(self, f: int):
        return jnp.zeros((0,), jnp.int32)  # stateless placeholder

    def on_ack(self, st, has_ack, ecn, rtt):
        return st

    def on_nack(self, st, count):
        return st

    def on_grant_tick(self, st, flow_dst, active, num_hosts):
        return st

    def on_send_gate(self, st, inflight):
        return inflight < jnp.int32(int(self.max_cwnd))

    def on_inject(self, st, injected):
        return st

    def on_rx_seen(self, st, seen):
        return st

    def on_timeout(self, st, stalled):
        return st

    def end_of_tick(self, st, tick):
        return st

    def cwnd_view(self, st, f: int):
        return jnp.full((f,), self.max_cwnd, jnp.float32)


@dataclass(frozen=True)
class HybridCCPolicy:
    """NSCC and RCCC composed, as Sec. 3.3.3 prescribes: the sender obeys
    BOTH the network-signal window and the receiver credit balance; each
    sub-policy sees the same feedback it would see running alone."""

    nscc: NSCCPolicy
    rccc: RCCCPolicy

    def create(self, f: int):
        return {"nscc": self.nscc.create(f), "rccc": self.rccc.create(f)}

    def on_ack(self, st, has_ack, ecn, rtt):
        return {"nscc": self.nscc.on_ack(st["nscc"], has_ack, ecn, rtt),
                "rccc": st["rccc"]}

    def on_nack(self, st, count):
        return {"nscc": self.nscc.on_nack(st["nscc"], count),
                "rccc": st["rccc"]}

    def on_grant_tick(self, st, flow_dst, active, num_hosts):
        return {"nscc": st["nscc"],
                "rccc": self.rccc.on_grant_tick(st["rccc"], flow_dst,
                                                active, num_hosts)}

    def on_send_gate(self, st, inflight):
        return (self.nscc.on_send_gate(st["nscc"], inflight)
                & self.rccc.on_send_gate(st["rccc"], inflight))

    def on_inject(self, st, injected):
        return {"nscc": st["nscc"],
                "rccc": self.rccc.on_inject(st["rccc"], injected)}

    def on_rx_seen(self, st, seen):
        return {"nscc": st["nscc"],
                "rccc": self.rccc.on_rx_seen(st["rccc"], seen)}

    def on_timeout(self, st, stalled):
        return {"nscc": self.nscc.on_timeout(st["nscc"], stalled),
                "rccc": st["rccc"]}

    def end_of_tick(self, st, tick):
        return {"nscc": self.nscc.end_of_tick(st["nscc"], tick),
                "rccc": st["rccc"]}

    def cwnd_view(self, st, f: int):
        return self.nscc.cwnd_view(st["nscc"], f)


def make_cc_policy(cc: CCAlgo, nparams: NSCCParams, max_cwnd: float):
    """Instantiate the CC policy object a profile asks for."""
    if cc == CCAlgo.NSCC:
        return NSCCPolicy(params=nparams)
    if cc == CCAlgo.RCCC:
        return RCCCPolicy(initial_credit=max_cwnd, report_cwnd=max_cwnd)
    if cc == CCAlgo.NSCC_AND_RCCC:
        return HybridCCPolicy(
            nscc=NSCCPolicy(params=nparams),
            rccc=RCCCPolicy(initial_credit=max_cwnd, report_cwnd=max_cwnd))
    if cc == CCAlgo.NONE:
        return OpenLoopPolicy(max_cwnd=max_cwnd)
    raise ValueError(f"unknown CC algorithm: {cc!r}")


def cc_ablation(base: "TransportProfile | None" = None
                ) -> "list[TransportProfile]":
    """The CC-ablation axis over one composition: NSCC-only vs RCCC-only
    vs hybrid, all else (lb, delivery) held from `base` (default ai_full)."""
    base = TransportProfile.ai_full() if base is None else base
    return [replace(base, cc=CCAlgo.NSCC, name="nscc_only"),
            replace(base, cc=CCAlgo.RCCC, name="rccc_only"),
            replace(base, cc=CCAlgo.NSCC_AND_RCCC, name="hybrid")]
