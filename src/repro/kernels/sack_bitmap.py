"""Pallas TPU kernel: SACK-ring cumulative-ACK advance (Sec. 3.2.5).

Per PDC, the receiver keeps a ring bitmap of arrived PSNs anchored at the
CACK point. Every ACK-coalescing round the hardware must (a) count the
contiguous prefix of received packets, (b) advance the base PSN, and
(c) shift the ring down — across every active PDC. That is the hot loop
this kernel implements blockwise.

TPU adaptation: the per-row variable shift (a gather in the reference)
is re-expressed as a one-hot masked reduction — for output word j we sum
ring[:, k] * [k == j + word_shift] over k, an MXU/VPU-friendly W x W
contraction with W = ring words (W <= 32), instead of a data-dependent
gather which the TPU vector unit cannot do across lanes. Bit-level ops
(ctz/popcount) stay in uint32 lanes.

Block layout: (BLOCK_R rows) x (W words padded to 128 lanes) per grid
step; every operand tile lives in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pds import _popcount32
from repro.kernels import auto_interpret

BLOCK_R = 64
WORD = 32


def _sack_kernel(ring_ref, base_ref, ring_out_ref, base_out_ref, adv_ref,
                 *, w: int):
    ring = ring_ref[...][:, :w]          # [R, W] uint32
    base = base_ref[...]                 # [R, 128] uint32 (col 0 used)
    R = ring.shape[0]

    # --- trailing ones per row ---
    inv = ~ring
    lsb = inv & (jnp.uint32(0) - inv)
    ctz = _popcount32(lsb - jnp.uint32(1))
    ctz = jnp.where(inv == jnp.uint32(0), WORD, ctz)          # all-ones word
    full = ring == jnp.uint32(0xFFFFFFFF)                      # [R, W]
    # number of leading full words = index of first non-full word
    not_full = ~full
    col = jax.lax.broadcasted_iota(jnp.int32, (R, w), 1)
    first_partial = jnp.min(jnp.where(not_full, col, w), axis=1)  # [R]
    # bits from the first partial word (0 if none)
    sel = col == first_partial[:, None]
    partial_bits = jnp.sum(jnp.where(sel, ctz, 0), axis=1)
    adv = jnp.where(first_partial == w, w * WORD,
                    first_partial * WORD + partial_bits)       # [R]

    # --- funnel shift right by adv bits, expressed gather-free ---
    words = adv // WORD                                        # [R]
    bits = (adv % WORD).astype(jnp.uint32)                     # [R]
    # lo[i, j] = ring[i, j + words[i]] ; hi[i, j] = ring[i, j + words[i] + 1]
    shift_idx = col + words[:, None]                           # [R, W]
    k = jax.lax.broadcasted_iota(jnp.int32, (R, w, w), 2)      # [R, W, W]
    one_hot_lo = (k == shift_idx[:, :, None]).astype(jnp.uint32)
    one_hot_hi = (k == (shift_idx + 1)[:, :, None]).astype(jnp.uint32)
    ring_b = ring[:, None, :]                                  # [R, 1, W]
    lo = jnp.sum(ring_b * one_hot_lo, axis=2, dtype=jnp.uint32)
    hi = jnp.sum(ring_b * one_hot_hi, axis=2, dtype=jnp.uint32)
    b = bits[:, None]
    shifted = jnp.where(b == 0, lo,
                        (lo >> b) | (hi << (jnp.uint32(WORD) - b)))

    out = ring_out_ref[...]
    out = out.at[:, :w].set(shifted)
    ring_out_ref[...] = out
    base_out_ref[...] = base + adv.astype(jnp.uint32)[:, None] * (
        jax.lax.broadcasted_iota(jnp.int32, base.shape, 1) == 0
    ).astype(jnp.uint32)
    adv_ref[...] = adv[:, None] * (
        jax.lax.broadcasted_iota(jnp.int32, base.shape, 1) == 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sack_advance(ring: jax.Array, base: jax.Array,
                 interpret: bool | None = None):
    """CACK-advance every PDC's SACK ring.

    ring: [N, W] uint32 (W <= 32 words = up to 1024-PSN MP_RANGE window)
    base: [N] uint32
    Returns (new_ring, new_base, advanced[int32]).
    """
    interpret = auto_interpret(interpret)
    n, w = ring.shape
    assert w <= 128
    rows = -(-n // BLOCK_R) * BLOCK_R
    padr = rows - n
    ring_p = jnp.pad(ring, ((0, padr), (0, 128 - w)))
    base_p = jnp.pad(base.reshape(-1, 1), ((0, padr), (0, 127)))

    grid = (rows // BLOCK_R,)
    spec128 = pl.BlockSpec((BLOCK_R, 128), lambda i: (i, 0))
    ring_o, base_o, adv_o = pl.pallas_call(
        functools.partial(_sack_kernel, w=w),
        grid=grid,
        in_specs=[spec128, spec128],
        out_specs=[spec128, spec128, spec128],
        out_shape=[
            jax.ShapeDtypeStruct((rows, 128), jnp.uint32),
            jax.ShapeDtypeStruct((rows, 128), jnp.uint32),
            jax.ShapeDtypeStruct((rows, 128), jnp.int32),
        ],
        interpret=interpret,
    )(ring_p, base_p)
    return ring_o[:n, :w], base_o[:n, 0], adv_o[:n, 0]
