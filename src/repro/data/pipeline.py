"""Deterministic sharded synthetic-token pipeline.

A production data loader's contract, minus the storage backend: globally
deterministic batches keyed by (seed, step) so that (a) every data-parallel
host slices only its own rows, (b) restart from a checkpoint replays the
exact token stream (step index is the cursor — no separate dataloader
state to checkpoint), and (c) elastic rescaling re-slices the same global
batch across a different host count.

The synthetic distribution is a mixture of Zipfian unigrams and short
repeated motifs, giving a learnable (compressible) stream — loss drops
measurably within a few hundred steps, which the training example relies
on.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    motif_len: int = 16
    motif_count: int = 64
    motif_prob: float = 0.7


class SyntheticTokens:
    """Deterministic (seed, step) -> global batch of token ids."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # Zipfian unigram table
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.unigram = p / p.sum()
        # fixed motif bank drawn from the unigram distribution
        self.motifs = rng.choice(
            cfg.vocab_size, size=(cfg.motif_count, cfg.motif_len),
            p=self.unigram)

    def global_batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(B, S + 1), p=self.unigram)
        # overwrite spans with motifs (the learnable structure)
        n_spans = int((S // cfg.motif_len) * cfg.motif_prob)
        for b in range(B):
            ids = rng.integers(0, cfg.motif_count, size=n_spans)
            offs = rng.integers(0, S + 1 - cfg.motif_len, size=n_spans)
            for m, o in zip(ids, offs):
                toks[b, o:o + cfg.motif_len] = self.motifs[m]
        return {"inputs": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def host_batch(self, step: int, process_index: int,
                   num_processes: int) -> dict:
        """This host's row slice of the deterministic global batch."""
        g = self.global_batch(step)
        B = self.cfg.global_batch
        assert B % num_processes == 0
        rows = slice(process_index * B // num_processes,
                     (process_index + 1) * B // num_processes)
        return {k: v[rows] for k, v in g.items()}
