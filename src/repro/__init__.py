"""repro: Ultra Ethernet Transport (UET) reproduced as a multi-pod JAX
training/serving framework. See README.md / DESIGN.md / EXPERIMENTS.md."""

__version__ = "1.0.0"
