"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads reports/dryrun/*.json (written by repro.launch.dryrun) and derives,
per (arch x shape x mesh):

  compute term    = HLO_FLOPs_per_device / peak_FLOPs
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = sum over kinds of algorithmic ring time at link_bw
                    (per-device collective bytes from the optimized HLO)

Hardware constants (TPU v5e class, per the assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.

Also reported: MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per
device per step for train (2*N*D forward-only for prefill/decode), the
usefulness ratio MODEL_FLOPS / HLO_FLOPs, the dominant term, and one-line
bottleneck guidance. CPU-compile caveats (bf16 float-normalization in
temp sizes) are annotated, not hidden.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--dir reports/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)


_SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,      # one token per sequence
    "long_500k": 1,
}


def model_flops_per_device(rep: dict) -> float:
    """6*N_active*D train / 2*N_active*D forward, per device."""
    n = rep["active_params"]
    mult = 6.0 if rep["kind"] == "train" else 2.0
    tokens = _SHAPE_TOKENS[rep["shape"]]
    return mult * n * tokens / rep["devices"]


def ring_time(kind: str, bytes_per_dev: float, chips: int) -> float:
    n = max(chips, 2)
    factor = {"all-reduce": 2 * (n - 1) / n,
              "all-gather": (n - 1) / n,
              "reduce-scatter": (n - 1) / n,
              "all-to-all": (n - 1) / n,
              "collective-permute": 1.0}.get(kind, 1.0)
    return bytes_per_dev * factor / LINK_BW


def kind_sizes_from_reports(reps, mtu: int = 4096, max_pkts: int = 128,
                            min_pkts: int = 4) -> dict:
    """Representative per-kind payloads (per-rank packets) from the
    dry-run reports' OWN per-device collective byte volumes, clamped to
    the simulator's tractable size band. This replaces a fixed
    one-size-fits-all probe: small-message kinds (permutes, decode-time
    reductions) and bulk kinds (grad all-reduces) now derate at their
    own operating size regime."""
    import math
    best: dict = {}
    for rep in reps:
        for k, b in rep["collectives"]["bytes"].items():
            if k == "total":
                continue
            best[k] = max(best.get(k, 0.0), float(b))
    return {k: max(min_pkts, min(max_pkts, math.ceil(b / mtu)))
            for k, b in best.items()}


def uet_efficiencies(kinds, hosts: int = 8, size_pkts: int = 64,
                     sizes: "dict | None" = None) -> dict:
    """Per-kind achieved-efficiency derates from the packet-level UET
    collective simulator: analytic alpha-beta time / simulated
    dependency-scheduled completion on a representative leaf-spine,
    applied as a divisor to the collective term — the paper's transport
    mechanics priced into the roofline. All kinds run as ONE
    ``simulate_batch`` call (heterogeneous flow counts padded, one
    executable) rather than one compile per kind. ``sizes`` overrides
    the probe payload per kind (see `kind_sizes_from_reports`)."""
    from repro.distributed.netmodel import (FabricSpec,
                                            _collective_fabric,
                                            analytic_time_for_spec)
    from repro.network import collectives as coll
    from repro.network.fabric import SimParams, simulate_batch
    from repro.network.profile import TransportProfile

    ks = [k for k in kinds if k not in ("total", "collective-permute")]
    if not ks:
        return {}
    fs = FabricSpec()
    sz = {k: int((sizes or {}).get(k, size_pkts)) for k in ks}
    specs = [coll.CollectiveSpec(k, tuple(range(hosts)), sz[k])
             for k in ks]
    budget = max(6 * coll.analytic_ticks(s, "ring") + 800 for s in specs)
    # budget is a traced bound on the adaptive-horizon engine: every
    # (kind, size) sweep shares the executable and exits at quiescence,
    # so the worst-case budget is free for the kinds that finish early
    rs = simulate_batch(
        _collective_fabric(hosts, hosts_per_leaf=4, oversub=1),
        coll.stack_padded([coll.build_workload(s, "ring") for s in specs]),
        TransportProfile.ai_full(), SimParams(), max_ticks=budget)
    out = {}
    for k, r in zip(ks, rs):
        ct = coll.collective_completion_ticks(r)
        if ct < 0:
            # never report a timeout as a measured efficiency: leave the
            # kind underated (analyze() falls back to 1.0) and say so
            print(f"uet_efficiencies: {k} did not complete within "
                  f"{budget} ticks — no derate applied")
            continue
        out[k] = min(1.0, analytic_time_for_spec(k, sz[k], hosts, fs)
                     / (ct * fs.tick_seconds))
    return out


def analyze(rep: dict, coll_eff: "dict | None" = None) -> dict:
    chips = rep["devices"]
    compute_t = rep["flops"] / PEAK_FLOPS
    memory_t = rep["bytes_accessed"] / HBM_BW
    eff = coll_eff or {}
    coll_t = sum(ring_time(k, b, chips) / max(eff.get(k, 1.0), 1e-6)
                 for k, b in rep["collectives"]["bytes"].items()
                 if k != "total")
    mf = model_flops_per_device(rep)
    terms = {"compute": compute_t, "memory": memory_t,
             "collective": coll_t}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        **{f"{k}_s": v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / rep["flops"] if rep["flops"] else 0.0,
        "roofline_frac": (mf / PEAK_FLOPS) / bound if bound else 0.0,
        "step_s_lower_bound": bound,
    }


ADVICE = {
    "compute": "compute-bound: cut redundant FLOPs (causal-mask waste, "
               "remat recompute, head/vocab padding) or raise MFU via "
               "larger matmul tiles",
    "memory": "HBM-bound: fuse elementwise chains, cut f32 upcasts, "
              "reuse KV/cache reads (batch decode), widen arithmetic "
              "intensity per byte",
    "collective": "collective-bound: overlap collectives with compute, "
                  "shrink bytes (gradient compression, bf16 reductions), "
                  "or reshard to cheaper collectives",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="reports/dryrun")
    ap.add_argument("--json-out", default="reports/roofline.json")
    ap.add_argument("--uet", action="store_true",
                    help="derate the collective term by packet-level UET "
                         "simulated efficiencies (slower: runs the fabric)")
    args = ap.parse_args()

    reps = [json.load(open(path))
            for path in sorted(glob.glob(os.path.join(args.dir, "*.json")))]
    coll_eff = None
    if args.uet and reps:
        kinds = {k for rep in reps
                 for k in rep["collectives"]["bytes"]}
        sizes = kind_sizes_from_reports(reps)
        coll_eff = uet_efficiencies(sorted(kinds), sizes=sizes)
        print("UET probe sizes (pkts/rank):", sizes)
        print("UET simulated collective efficiencies:",
              {k: round(v, 3) for k, v in coll_eff.items()})
    rows = []
    for rep in reps:
        rows.append({**rep, **analyze(rep, coll_eff)})

    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"],
                             r.get("variant", "base")))
    hdr = (f"{'arch':22s} {'shape':12s} {'var':5s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'dom':>10s} "
           f"{'useful':>7s} {'roofl%':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r["mesh"] != "16x16":
            continue  # roofline table is single-pod per the assignment
        print(f"{r['arch']:22s} {r['shape']:12s} "
              f"{r.get('variant', 'base'):5s} "
              f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
              f"{r['collective_s']:10.4f} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.2f} {100*r['roofline_frac']:6.1f}%")
    os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {args.json_out} ({len(rows)} cells)")
    for r in rows[:1]:
        print("advice for dominant terms:",
              {k: ADVICE[k] for k in {x['dominant'] for x in rows}})
        break


if __name__ == "__main__":
    main()
