"""Fabric-simulator performance benchmarks: ticks/sec and scenarios/sec.

Measures the hot path of the UET fabric engine in four configurations —

* ``single``         — one compiled scan, one scenario (ticks/sec; the
                       per-tick hot-path number the fused kernels moved);
* ``serial_seed``    — B scenarios the way the *seed* architecture ran a
                       sweep: the failure set was a static tuple closed
                       over by jit, so EVERY scenario paid its own
                       trace+compile before running. This is the baseline
                       the batched engine exists to kill (and the
                       acceptance comparison for scenarios/sec).
* ``serial_shared``  — B sequential ``simulate`` calls on this PR's
                       serial path (failure masks/seeds/workloads are
                       traced, so one warm executable is reused). Reported
                       for transparency: most of the sweep win is the
                       recompile removal, the rest is vmap amortization.
* ``batched``        — the same B scenarios in one ``simulate_batch``
                       (vmapped scan, carry donated), cold and warm.

Also runs the profile-ablation sweep (ai_base / ai_full / hpc plus the
NSCC-only / RCCC-only / hybrid / open-loop CC ablation) as ONE
``simulate_batch`` call — the engine groups the grid by distinct
profile, one executable each, run concurrently — and records
per-profile goodput under ``profile_ablation``. The scenario is the
oversubscribed in-network pattern whose same-leaf victim flow actually
separates the CC policies (asserted — a bench whose ablation axis
reports one number is measuring nothing).

The collective ablation grid (kind x algorithm x INC on/off x profile,
15 dependency-scheduled whole collectives padded into one batch) runs
as ONE ``simulate_batch`` call too and lands under ``collective_sweep``:
per-scenario completion ticks, scenarios/sec, and the in-network-
reduction win (INC-on / INC-off completion ratio for the tree
all-reduce). Both sweeps run the default ``trace="stats"`` tier on the
adaptive-horizon engine: completion ticks stream out of the chunked
while-scan, scenarios exit at quiescence instead of padding to the
budget, and INC on/off rides the traced ``red`` lanes (one executable
per transport profile for the whole grid).

api_version 5 additions (the scale-out engine):

* ``ticks_per_sec_fixed_scan`` — the PR-3 driver reproduced (one
  vmapped fixed-length scan, dense out lanes materialized into
  SimResults) as the same-box head-to-head reference for the chunked
  driver's fast path; ``..._device`` is the device-program-only
  variant (no gather/result build), isolating driver speed from the
  trace tiers;
* ``ticks_per_sec_batched_fastpath`` — the chunked driver with
  ``chunk_ticks`` aligned to divide the budget, so every chunk takes
  the select-free fast body (no masked remainder);
* ``sharded_sweep`` — a heterogeneous-horizon scenario sweep, sorted by
  expected horizon, run unsharded vs ``shard=True``. Runs in a CHILD
  process with ``--devices`` virtual CPU devices forced, so the main
  process — and every guarded regression metric — stays on an unsplit
  host: ``scenarios_per_sec_sharded``, device count, and the speedup;
* ``calibration`` — a fixed tiny scenario re-measured on every box;
  ``scripts/bench_compare.py`` normalizes cross-box regression ratios
  by it so machine drift stops masquerading as engine regressions.

api_version 6 additions (the fault-injection engine): ``fault_sweep``
— the dynamic-fault grid (link flaps, gray links, mid-run death;
``workloads.fault_sweep``) as one batch with per-scenario
FaultSchedules riding the scenario axis, with in-bench gates: liveness
(>= 1 surviving path -> every flow completes), degradation (faults
cost ticks and fire timeouts), and the recovery-loop separation
(``ev_eviction=True`` beats eviction-off under a permanent mid-run
failure of a static path).

api_version 7 additions (the model-driven traffic engine):
``model_sweep`` — the co-design grid (model x sharding layout x
topology x transport profile), every operating point's per-step
collective schedule derived from the REAL sharding rules
(``repro.distributed.plan``), compiled to one dep-chained fabric
workload (``repro.network.traffic``) and priced end-to-end (step time,
tokens/sec) from ONE ``simulate_batch`` call over per-scenario graphs
AND profiles. In-bench gates assert the axes actually separate: the
fsdp_tp decode penalty vs the tp_only serving layout, the hpc-vs-ai
transport separation on the oversubscribed fabric, and topology
monotonicity.

api_version 8 additions (the telemetry plane): ``fabric_health`` — the
flap scenario on the shared victim-share fabric
(``workloads.victim_sweep``) with ``TelemetrySpec.on()`` probes, gated
on outage VISIBILITY (silent-drop rate confined to the fault window,
goodput dip + recovery, the NSCC mark-rate throttle response, the
heal-boundary trim burst) and on non-perturbation (telemetry-on final
state bitwise equals telemetry-off). Prices the plane itself as the
``telemetry_overhead`` warm-time ratio. Telemetry-off runs compile the
identical pre-telemetry program, so every existing guarded metric
doubles as the telemetry-off regression gate.

api_version 9 additions (endpoint-failure resilience):
``resilience_sweep`` — the endpoint-fault grid
(``workloads.host_fault_sweep``: host death, the same death with PDC
liveness off, a healing NIC stall, healthy) as one batch with
per-scenario host-fault lanes, gated on the teardown contract (the
dead-host lane quiesces EARLY with its victim flows abandoned; the
pdc-off twin burns the full budget; the NIC stall completes with
nothing abandoned), plus the priced checkpoint-restart recovery loop:
``traffic.price_recovery`` measures detection (fault ->
``abandon_tick``), sharded-restore and replan-onto-survivors costs for
a train plan, and the Young/Daly closed forms price effective
tokens/sec over an MTBF x checkpoint-interval grid — asserting
in-bench that the Young/Daly interval beats naive fixed intervals at
every MTBF and that availability is monotone in MTBF.

Writes ``BENCH_fabric.json`` at the repo root so the perf trajectory
accumulates across PRs; append each run's headline numbers to
``BENCH_history.jsonl`` with ``python scripts/bench_history.py``.

Usage: PYTHONPATH=src python -m benchmarks.perf_benches [--scenarios 8]
       [--ticks 600] [--devices 4] [--out BENCH_fabric.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _force_host_devices(n: int) -> None:
    """Split the host CPU into n virtual devices (the sharded-sweep
    child process). Only effective before the first jax import (jax
    locks the backend), and only when the user hasn't already forced a
    count."""
    flags = os.environ.get("XLA_FLAGS", "")
    if n > 1 and "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())


def _sharded_sweep_subprocess(devices: int) -> dict:
    """Run `_sharded_sweep` in a child interpreter with the device split
    forced there, so the main bench process — and every guarded
    regression metric measured in it — runs on an unsplit host (the
    split redistributes XLA's CPU threads and would skew the other
    numbers)."""
    import subprocess
    import sys

    if devices <= 1:
        return {"devices": max(devices, 1),
                "skipped": "sharding disabled (--devices <= 1)"}
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "benchmarks.perf_benches",
           "--sharded-only", "--devices", str(devices)]
    r = subprocess.run(cmd, capture_output=True, text=True, cwd=repo,
                       env=env)
    if r.returncode != 0:
        return {"devices": devices,
                "skipped": f"child process failed: {r.stderr[-500:]}"}
    return json.loads(r.stdout)


def _bench_config(ticks: int):
    from repro.core.lb.schemes import LBScheme
    from repro.network.fabric import SimParams, Workload
    from repro.network.profile import TransportProfile
    from repro.network.topology import leaf_spine

    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=8)
    f = 8
    wl = Workload.of(list(range(f)), [f + i for i in range(f)], 100000)
    prof = TransportProfile.ai_full(lb=LBScheme.REPS)
    p = SimParams(ticks=ticks, timeout_ticks=64, ooo_threshold=24)
    return g, wl, prof, p


def _scenarios(g, wl, b: int):
    """B scenarios: scenario i fails leaf-0 uplink (i mod spines) for odd
    i and uses a distinct LB seed — a failure x seed sweep."""
    from repro.network.fabric import DEFAULT_SEED, Workload

    spines = g.up1_table.shape[1]
    masks = np.zeros((b, g.num_queues), bool)
    seeds = np.zeros((b,), np.uint32)
    for i in range(b):
        seeds[i] = DEFAULT_SEED + i
        if i % 2 == 1:
            masks[i, int(g.up1_table[0, i % spines])] = True
    wls = Workload.stack([wl] * b)
    return wls, masks, seeds


def _fixed_scan_batched(g, wls, prof, p, masks, seeds, b: int):
    """The PR-3 batched driver reproduced: ONE vmapped fixed-length
    ``lax.scan`` over the whole tick budget with dense per-tick out
    lanes, materialized into full-trace SimResults — the head-to-head
    reference the chunked driver's fast path is measured against.
    Returns (call, call_device_only): the first materializes results as
    PR-3's simulate_batch did, the second just blocks on the device
    program (isolates driver speed from the trace tier)."""
    import jax
    import jax.numpy as jnp

    from repro.network import fabric

    from repro.network.faults import FaultSchedule

    F = int(wls.src.shape[-1])
    step = fabric.make_step(g, prof, p, F)
    xs = jnp.arange(p.ticks, dtype=jnp.int32)

    def scan_one(s0, wl_, fault):
        def body(s, tick):
            return step(s, tick, wl_, fault)
        return jax.lax.scan(body, s0, xs)

    run = jax.jit(jax.vmap(scan_one), donate_argnums=(0,))
    init = jax.jit(jax.vmap(
        lambda w_, s_: fabric.init_state(g, w_, prof, p, s_)))
    fault = FaultSchedule.from_mask(jnp.asarray(masks))
    sds = jnp.asarray(seeds, jnp.uint32)
    sizes = np.asarray(wls.size)

    def call():
        s0 = init(wls, sds)
        final, outs = run(s0, wls, fault)
        final = jax.device_get(final)
        outs = jax.device_get(outs)
        return [
            fabric._full_result(
                jax.tree_util.tree_map(lambda a: a[i], final),
                {k: v[i] for k, v in outs.items()},
                sizes[i], p.ticks, p.ticks)
            for i in range(b)
        ]

    def call_device_only():
        s0 = init(wls, sds)
        jax.block_until_ready(run(s0, wls, fault))

    return call, call_device_only


def _aligned_chunk(budget: int, target: int = 128) -> int:
    """Divisor of `budget` near `target`: a chunk size under which every
    chunk of the budget takes the driver fast path (no masked
    remainder). Budgets with no usable divisor (e.g. primes) fall back
    to `target` — one masked remainder, same as the default chunking —
    rather than degenerating to a tiny chunk that measures while-loop
    overhead instead of the fast path."""
    k = max(1, round(budget / target))
    while k <= budget and budget % k:
        k += 1
    chunk = budget // k if k <= budget else budget
    return chunk if chunk >= 16 else min(budget, target)


def _seed_style_simulate(g, wl, prof, p, mask, seed):
    """One scenario the way the seed architecture ran it: the failure set
    baked into the executable as a static constant, so this scenario's
    run starts with its own trace+compile (no sharing across the sweep)."""
    import jax
    import jax.numpy as jnp

    from repro.network import fabric

    from repro.network.faults import FaultSchedule

    F = int(wl.src.shape[0])
    step = fabric.make_step(g, prof, p, F)
    fault_const = FaultSchedule.from_mask(jnp.asarray(mask))

    def scan_one(s0, wl_):
        def body(s, tick):
            return step(s, tick, wl_, fault_const)
        return jax.lax.scan(body, s0, jnp.arange(p.ticks, dtype=jnp.int32))

    run = jax.jit(scan_one, donate_argnums=(0,))
    s0 = fabric.init_state(g, wl, prof, p, jnp.uint32(seed))
    final, outs = run(s0, wl)
    return fabric._to_result(final, outs, wl.size)


def run_benches(b: int, ticks: int, devices: int = 4) -> dict:
    import jax

    from repro.network.fabric import simulate, simulate_batch

    g, wl, prof, p = _bench_config(ticks)
    wls, masks, seeds = _scenarios(g, wl, b)
    fq = [tuple(np.nonzero(masks[i])[0].tolist()) for i in range(b)]

    results = {
        "api_version": 10,
        "backend": jax.default_backend(),
        "topology": g.name,
        "flows": int(wl.src.shape[0]),
        "ticks": ticks,
        "scenarios": b,
        "profile": prof.name,
        "profile_spec": prof.describe(),
    }

    # --- single scenario: compile + warm ticks/sec ---
    t0 = time.perf_counter()
    simulate(g, wl, prof, p)
    results["single_cold_s"] = time.perf_counter() - t0
    warm = min(_timed(lambda: simulate(g, wl, prof, p)) for _ in range(5))
    results["single_warm_s"] = warm
    results["ticks_per_sec_single"] = ticks / warm

    # --- seed-style serial sweep: fresh executable per scenario ---
    t0 = time.perf_counter()
    for i in range(b):
        _seed_style_simulate(g, wl, prof, p, masks[i], int(seeds[i]))
    serial_seed = time.perf_counter() - t0
    results["serial_seed_sweep_s"] = serial_seed
    results["scenarios_per_sec_serial"] = b / serial_seed
    results["serial_mode"] = ("per-scenario trace+compile (static failure "
                              "set, the seed architecture)")

    # --- shared-executable serial sweep: the warm serial path ---
    for i in range(2):  # warm
        simulate(g, wl, prof, p, failed=fq[i], seed=int(seeds[i]))
    t0 = time.perf_counter()
    for i in range(b):
        simulate(g, wl, prof, p, failed=fq[i], seed=int(seeds[i]))
    serial_shared = time.perf_counter() - t0
    results["serial_shared_sweep_s"] = serial_shared
    results["scenarios_per_sec_serial_shared"] = b / serial_shared

    # --- batched sweep: one simulate_batch() call ---
    t0 = time.perf_counter()
    simulate_batch(g, wls, prof, p, failed=masks, seeds=seeds)
    batched_cold = time.perf_counter() - t0
    results["batched_cold_s"] = batched_cold
    batched = min(_timed(
        lambda: simulate_batch(g, wls, prof, p, failed=masks, seeds=seeds))
        for _ in range(3))
    results["batched_sweep_s"] = batched
    results["scenarios_per_sec_batched"] = b / batched
    results["ticks_per_sec_batched"] = b * ticks / batched
    # acceptance metric: one batched sweep (incl. its compile) vs the
    # seed architecture's sweep (per-scenario compiles)
    results["batch_speedup_vs_serial"] = serial_seed / batched_cold
    results["batch_speedup_vs_serial_shared_warm"] = serial_shared / batched

    # --- fixed-scan head-to-head: the driver the chunked engine replaced ---
    from dataclasses import replace as _replace
    fixed, fixed_dev = _fixed_scan_batched(g, wls, prof, p, masks, seeds, b)
    fixed()  # compile
    fixed_warm = min(_timed(fixed) for _ in range(3))
    results["fixed_scan_sweep_s"] = fixed_warm
    results["ticks_per_sec_fixed_scan"] = b * ticks / fixed_warm
    # device-program-only variant (block_until_ready, nothing gathered):
    # isolates raw driver speed from each engine's result tier — the
    # fixed scan ships dense [T, B, F] lanes, the chunked default ships
    # streamed stats, and the as-shipped comparison below includes each
    # one's own materialization cost.
    fixed_dev_warm = min(_timed(fixed_dev) for _ in range(3))
    results["ticks_per_sec_fixed_scan_device"] = b * ticks / fixed_dev_warm
    # the acceptance ratio: chunked driver (fast path, stats tier) vs
    # the fixed-scan driver as PR-3 shipped it (dense tier), same box,
    # same sweep, each materializing its own results
    results["fastpath_vs_fixed_scan"] = (
        results["ticks_per_sec_batched"] / results["ticks_per_sec_fixed_scan"])

    # --- fast path with a budget-aligned chunk: no masked remainder ---
    chunk = _aligned_chunk(ticks)
    pf = _replace(p, chunk_ticks=chunk)
    simulate_batch(g, wls, prof, pf, failed=masks, seeds=seeds)
    fast = min(_timed(
        lambda: simulate_batch(g, wls, prof, pf, failed=masks, seeds=seeds))
        for _ in range(3))
    results["fastpath_chunk_ticks"] = chunk
    results["ticks_per_sec_batched_fastpath"] = b * ticks / fast

    results["profile_ablation"] = _profile_ablation(ticks)
    results["collective_sweep"] = _collective_sweep()
    results["fault_sweep"] = _fault_sweep()
    results["resilience_sweep"] = _resilience_sweep()
    results["fabric_health"] = _fabric_health()
    results["corruption_sweep"] = _corruption_sweep()
    results["model_sweep"] = _model_sweep()
    results["sharded_sweep"] = _sharded_sweep_subprocess(devices)
    results["calibration"] = _calibration()
    return results


def _sharded_sweep(b: int = 32, budget: int = 4096) -> dict:
    """Scenario sharding across devices: a heterogeneous incast-free
    sweep (per-scenario message sizes spanning ~20x, sorted ascending so
    each device gets a contiguous horizon band) run unsharded vs
    ``shard=True``. Sorting matters: the unsharded engine pays the
    max-lane horizon for every lane, while each device's while loop
    exits at its own band's quiescence — the speedup is device
    parallelism times that work saving."""
    import jax

    from repro.core.lb.schemes import LBScheme
    from repro.network.fabric import SimParams, Workload, simulate_batch
    from repro.network.profile import TransportProfile
    from repro.network.topology import leaf_spine

    ndev = len(jax.devices())
    if ndev < 2:
        return {"devices": ndev,
                "skipped": "one device visible (pass --devices N on CPU)"}
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=8)
    f = 8
    sizes = np.geomspace(60, 1200, b).astype(int)
    wls = Workload.stack(
        [Workload.of(list(range(f)), [f + i for i in range(f)], int(s))
         for s in sizes])
    prof = TransportProfile.ai_full(lb=LBScheme.REPS)
    p = SimParams(ticks=budget, timeout_ticks=64, ooo_threshold=24)

    t0 = time.perf_counter()
    rs = simulate_batch(g, wls, prof, p)
    unsh_cold = time.perf_counter() - t0
    unsh = min(_timed(lambda: simulate_batch(g, wls, prof, p))
               for _ in range(2))
    t0 = time.perf_counter()
    rs_sh = simulate_batch(g, wls, prof, p, shard=True)
    sh_cold = time.perf_counter() - t0
    sh = min(_timed(lambda: simulate_batch(g, wls, prof, p, shard=True))
             for _ in range(2))
    # the whole point is bitwise-equal lanes: assert it on every run
    for a, c in zip(rs, rs_sh):
        assert a.horizon == c.horizon
        np.testing.assert_array_equal(a.completion_ticks(),
                                      c.completion_ticks())
    return {
        "devices": ndev,
        "scenarios": b,
        "horizon_band": [int(rs[0].horizon), int(rs[-1].horizon)],
        "unsharded_cold_s": unsh_cold,
        "unsharded_warm_s": unsh,
        "sharded_cold_s": sh_cold,
        "sharded_warm_s": sh,
        "scenarios_per_sec_unsharded": b / unsh,
        "scenarios_per_sec_sharded": b / sh,
        "shard_speedup": unsh / sh,
    }


def _calibration() -> dict:
    """Fixed tiny scenario re-measured on every box. bench_compare
    divides cross-box regression ratios by (fresh / committed) of this
    number, so a slower/faster machine shifts every metric AND the
    calibration together and cancels out — the PR-4 27.2k->17.2k
    confusion (box drift read as an engine regression) can't recur.
    Limitation: this scenario runs the engine itself, so an engine-wide
    per-tick regression shifts it too; bench_compare prints a loud
    CALIBRATION-SHIFT warning in that case instead of silently
    normalizing it away."""
    from repro.network.fabric import SimParams, Workload, simulate
    from repro.network.profile import TransportProfile
    from repro.network.topology import leaf_spine

    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    wl = Workload.of([0, 1], [2, 3], 10**6)      # never completes
    p = SimParams(ticks=256)
    prof = TransportProfile.ai_full()
    simulate(g, wl, prof, p)                     # compile
    warm = min(_timed(lambda: simulate(g, wl, prof, p)) for _ in range(7))
    return {
        "config": "leafspine_L2_S2_H2 / 2 flows / 256 ticks / ai_full",
        "ticks_per_sec": 256 / warm,
    }


def _profile_ablation(ticks: int) -> dict:
    """The operating-point grid as ONE simulate_batch call: the three
    named profiles + the CC ablation (7 scenarios, grouped by profile
    into one executable each) on the oversubscribed in-network pattern.

    Asserts the realism property the old incast version silently lacked:
    nscc_only / rccc_only / open_loop must visibly diverge on the
    same-leaf victim flow (blind receiver credits cap it at ~50%; NSCC
    pushes it toward the 1 - uplinks/pairs optimum)."""
    from repro.network import workloads
    from repro.network.fabric import SimParams, simulate_batch

    g, wls, profiles, names, exp = workloads.profile_ablation_sweep()
    p = SimParams(ticks=ticks, timeout_ticks=64)
    window = (ticks // 3, ticks)
    run = lambda: simulate_batch(g, wls, profiles, p,  # noqa: E731
                                 goodput_window=window)
    t0 = time.perf_counter()
    rs = run()
    cold = time.perf_counter() - t0
    warm = min(_timed(run) for _ in range(2))
    v = exp["victim_flow"]
    gp = {name: r.goodput(window) for name, r in zip(names, rs)}
    victim = {name: round(float(x[v]), 4) for name, x in gp.items()}
    # realism gate: if the CC axis reports one number, the sweep is
    # differentiating nothing and the bench is broken
    assert victim["nscc_only"] > victim["open_loop"] + 0.05, victim
    assert victim["open_loop"] > victim["rccc_only"] + 0.05, victim
    assert abs(victim["rccc_only"] - exp["rccc_local_share"]) < 0.08, victim
    return {
        "scenarios": len(profiles),
        "distinct_profiles": len(set(profiles)),
        "sweep_cold_s": cold,
        "sweep_warm_s": warm,
        "scenarios_per_sec": len(profiles) / warm,
        "victim_flow_share": victim,
        "victim_share_optimal": exp["optimal_local_share"],
        "goodput_mean": {
            name: round(float(x.mean()), 4) for name, x in gp.items()
        },
    }


def _collective_sweep(ticks: int = 1600) -> dict:
    """The collective ablation grid — kind x algorithm x INC on/off x
    profile, 15 whole dependency-scheduled collectives — as ONE
    ``simulate_batch`` call on the adaptive-horizon engine: INC on/off
    rides the traced ``red`` lanes, so the grid compiles to just 2
    executables (ai_full / ai_base), run concurrently, and every
    scenario exits at quiescence instead of padding to the 1600-tick
    budget (completions land at 71-542 ticks)."""
    from repro.network import collectives as coll
    from repro.network import workloads
    from repro.network.fabric import SimParams, simulate_batch

    g, wls, profiles, names = workloads.collective_sweep()
    p = SimParams(ticks=ticks)
    t0 = time.perf_counter()
    rs = simulate_batch(g, wls, profiles, p)
    cold = time.perf_counter() - t0
    warm = min(_timed(lambda: simulate_batch(g, wls, profiles, p))
               for _ in range(2))
    cts = {name: coll.collective_completion_ticks(r)
           for name, r in zip(names, rs)}
    inc_red = {name: int(r.state.inc_reduced)
               for name, r in zip(names, rs) if "/inc" in name}

    def ratio(prof):
        off = cts[f"{prof}/all_reduce/tree"]
        on = cts[f"{prof}/all_reduce/tree/inc"]
        return round(on / off, 4) if off > 0 and on > 0 else None

    return {
        "scenarios": len(names),
        "flows_padded": int(wls.src.shape[1]),
        "distinct_profiles": len(set(profiles)),
        "ticks": ticks,
        "horizons": sorted({int(r.horizon) for r in rs}),
        "sweep_cold_s": cold,
        "sweep_warm_s": warm,
        "scenarios_per_sec": len(names) / warm,
        "completion_ticks": cts,
        "inc_reduced_pkts": inc_red,
        "inc_tree_allreduce_ratio": ratio("ai_full"),
        "inc_tree_allreduce_ratio_ai_base": ratio("ai_base"),
    }


def _fault_sweep(ticks: int = 4000) -> dict:
    """The dynamic-fault grid (workloads.fault_sweep: flaps, gray links,
    a mid-run permanent death) as ONE ``simulate_batch`` call with the
    per-scenario FaultSchedule riding the scenario axis, plus the
    closed-recovery-loop separation experiment.

    In-bench realism gates (a fault bench whose faults change nothing is
    measuring nothing):

    * every scenario keeps >= 1 healthy uplink, so every flow must
      complete within the budget (the liveness invariant);
    * fault scenarios must actually degrade (timeouts fire, completion
      later than healthy);
    * under a permanent mid-run failure pinned to a static path,
      ``ev_eviction=True`` must complete while eviction-off must be
      slower or stuck (the recovery loop separates).
    """
    from dataclasses import replace as _replace

    from repro.core.lb.schemes import LBScheme
    from repro.network import workloads
    from repro.network.fabric import SimParams, Workload, simulate, \
        simulate_batch
    from repro.network.faults import FaultSchedule
    from repro.network.profile import TransportProfile
    from repro.network.topology import leaf_spine

    g, wls, faults, exp = workloads.fault_sweep()
    prof = TransportProfile.ai_full(lb=LBScheme.REPS)
    p = SimParams(ticks=ticks, timeout_ticks=64, ooo_threshold=24)
    run = lambda: simulate_batch(g, wls, prof, p, faults=faults)  # noqa: E731
    t0 = time.perf_counter()
    rs = run()
    cold = time.perf_counter() - t0
    warm = min(_timed(run) for _ in range(2))
    names = exp["names"]
    cts = {n: int(r.completion_tick()) for n, r in zip(names, rs)}
    # liveness: >= 1 healthy uplink everywhere -> everything completes
    assert all(ct > 0 for ct in cts.values()), cts
    # the faults must bite: timeouts fire, completion degrades
    assert rs[1].timeouts > 0 and cts["flap_1"] > cts["healthy"], cts

    # recovery-loop separation: permanent mid-run death of a STATIC
    # path; eviction-on must migrate off it and beat eviction-off
    g2 = leaf_spine(leaves=2, spines=4, hosts_per_leaf=4)
    wl2 = Workload.of([0, 1, 2, 3], [4, 5, 6, 7], 150)
    dead = FaultSchedule.healthy(g2.num_queues).flap(
        int(g2.up1_table[0, 0]), 100)
    off = TransportProfile.ai_full(lb=LBScheme.STATIC, name="static")
    on = _replace(off, ev_eviction=True, rto_backoff=2.0,
                  name="static_evict")
    p2 = SimParams(ticks=ticks, timeout_ticks=64)
    r_off = simulate(g2, wl2, off, p2, faults=dead)
    r_on = simulate(g2, wl2, on, p2, faults=dead)
    ct_on, ct_off = r_on.completion_tick(), r_off.completion_tick()
    assert ct_on > 0, "eviction must migrate flows off the dead path"
    assert r_on.ev_evictions > 0
    assert ct_off == -1 or ct_on < ct_off, (ct_on, ct_off)

    return {
        "scenarios": len(names),
        "ticks": ticks,
        "sweep_cold_s": cold,
        "sweep_warm_s": warm,
        "scenarios_per_sec": len(names) / warm,
        "completion_ticks": cts,
        "timeouts": {n: int(r.timeouts) for n, r in zip(names, rs)},
        "rtx_packets": {n: int(r.rtx_packets) for n, r in zip(names, rs)},
        "ticks_degraded": {n: int(r.ticks_degraded)
                           for n, r in zip(names, rs)},
        "eviction_separation": {
            "completion_evict_on": ct_on,
            "completion_evict_off": ct_off,
            "ev_evictions": int(r_on.ev_evictions),
        },
    }


def _resilience_sweep() -> dict:
    """Endpoint-failure resilience: the host-fault grid plus the priced
    checkpoint-restart recovery loop.

    In-bench teardown gates (a resilience bench whose dead host changes
    nothing is measuring nothing):

    * the dead-host lane must quiesce EARLY (horizon < budget) with
      exactly its victim flows abandoned and its survivors complete —
      PDC liveness teardown turns a permanent endpoint death from a
      budget burn into an early exit;
    * the pdc-off twin of the SAME scenario must burn the full budget
      with nothing abandoned (the separation the feature buys);
    * the healing NIC stall must complete with nothing abandoned (a
      wedged-but-ACK-live endpoint is not dead);
    * the healthy lane abandons nothing.

    Economics gates (guaranteed by the closed forms, asserted against
    the MEASURED recovery costs): the Young/Daly interval beats naive
    fixed checkpoint intervals at every MTBF, and availability at the
    per-MTBF optimum is monotone non-decreasing in MTBF.
    """
    from repro import configs
    from repro.ckpt.checkpointing import (availability, effective_rate,
                                          young_daly_interval)
    from repro.distributed.plan import derive_plan
    from repro.network import workloads
    from repro.network.fabric import SimParams, simulate_batch
    from repro.network.traffic import checkpoint_seconds, price_recovery

    # --- the endpoint-fault grid: one batched call, host lanes riding ---
    g, wls, scheds, exp = workloads.host_fault_sweep()
    budget = exp["budget"]
    p = SimParams(ticks=budget, timeout_ticks=64)
    run = lambda: simulate_batch(g, wls, exp["profile"], p,  # noqa: E731
                                 faults=scheds)
    t0 = time.perf_counter()
    rs = run()
    cold = time.perf_counter() - t0
    warm = min(_timed(run) for _ in range(2))
    by = dict(zip(exp["names"], rs))

    dead, off = by["host_dead"], by["host_dead_pdc_off"]
    assert dead.flows_abandoned == len(exp["dead_flows"]), \
        int(dead.flows_abandoned)
    assert dead.horizon < budget, (dead.horizon, budget)
    assert int(dead.abandon_tick) > 0 and dead.ticks_unreachable > 0
    cts = dead.completion_ticks()
    assert all(int(cts[i]) == -1 for i in exp["dead_flows"])
    assert all(int(ct) > 0 for i, ct in enumerate(cts)
               if i not in exp["dead_flows"]), cts.tolist()
    assert off.flows_abandoned == 0 and off.horizon == budget, \
        (int(off.flows_abandoned), off.horizon)
    stall = by["nic_stall"]
    assert stall.flows_abandoned == 0 and stall.completion_tick() > 0
    assert by["healthy"].flows_abandoned == 0

    # --- the priced recovery loop: one train plan, one host loss ---
    plan = derive_plan(configs.get("deepseek-coder-33b"), "train_4k",
                       dp=4, tp=4, layout="fsdp_tp")
    t0 = time.perf_counter()
    rc = price_recovery(plan)
    recovery_s = time.perf_counter() - t0
    assert rc.horizon < rc.budget, (rc.horizon, rc.budget)
    write_s = checkpoint_seconds(plan)
    kw = dict(write_s=write_s, detect_s=rc.detect_s,
              restore_s=rc.restore_s, replan_s=rc.replan_s)

    naive = (30.0, 900.0)
    grid = []
    prev_av = 0.0
    for mtbf in (1800.0, 3600.0, 7200.0, 14400.0):
        tau = young_daly_interval(mtbf, write_s)
        av = availability(tau, mtbf, **kw)
        eff = effective_rate(rc.healthy_tokens_per_sec, tau, mtbf, **kw)
        for iv in naive:
            eff_iv = effective_rate(rc.healthy_tokens_per_sec, iv, mtbf,
                                    **kw)
            assert eff > eff_iv, (mtbf, iv, eff, eff_iv)
        assert av >= prev_av, (mtbf, av, prev_av)
        prev_av = av
        grid.append({
            "mtbf_s": mtbf,
            "daly_interval_s": round(tau, 2),
            "availability": round(av, 5),
            "effective_tokens_per_sec": round(eff, 1),
            "naive_effective_tokens_per_sec": {
                str(int(iv)): round(effective_rate(
                    rc.healthy_tokens_per_sec, iv, mtbf, **kw), 1)
                for iv in naive},
        })

    return {
        "scenarios": len(exp["names"]),
        "budget": budget,
        "sweep_cold_s": cold,
        "sweep_warm_s": warm,
        "scenarios_per_sec": len(exp["names"]) / warm,
        "abandon_tick": int(dead.abandon_tick),
        "horizon_pdc_on": int(dead.horizon),
        "horizon_pdc_off": int(off.horizon),
        "ticks_unreachable": int(dead.ticks_unreachable),
        "recovery": {
            "plan": f"{plan.arch} x {plan.shape} dp={plan.dp} tp={plan.tp}",
            "wall_s": recovery_s,
            "detect_ticks": rc.detect_ticks,
            "detect_s": rc.detect_s,
            "restore_s": rc.restore_s,
            "replan_s": rc.replan_s,
            "flows_abandoned": rc.flows_abandoned,
            "healthy_tokens_per_sec": rc.healthy_tokens_per_sec,
            "degraded_tokens_per_sec": rc.degraded_tokens_per_sec,
        },
        "checkpoint_write_s": write_s,
        "availability_grid": grid,
        # headline: availability at the 1h-MTBF Young/Daly optimum
        "availability_mtbf_3600": grid[1]["availability"],
    }


def _fabric_health(ticks: int = 3000) -> dict:
    """The telemetry plane on the PR-6-style flap scenario: the shared
    victim-share fabric (``workloads.victim_sweep``) with 3 of 4 leaf-0
    uplinks flapping over [1000, 1800), probes on.

    In-bench visibility gates (an observability plane that can't see an
    outage is measuring nothing) — the four-signature check shared with
    the ``python -m repro.network.telemetry`` canary:

    * silent-drop rate is confined to [fail_at, heal_at) bit-exactly
      (zero before and after, spiking inside);
    * goodput dips inside the window and climbs back after;
    * the CC response registers: NSCC backs off on the vanishing ACK
      stream, so the in-window ECN-mark rate falls below baseline
      (the naive "trims spike in-window" expectation is exactly what a
      real closed-loop transport does NOT do — the trim spike lands at
      the heal boundary, when the retransmit backlog floods back);
    * probes never perturb: the telemetry-on final state is bitwise the
      telemetry-off state.

    Also prices the plane itself: warm telemetry-on vs telemetry-off
    wall time on the same scenario (``telemetry_overhead`` ratio).
    """
    from dataclasses import replace as _replace

    import jax

    from repro.network.fabric import simulate
    from repro.network.telemetry import (assert_outage_visible,
                                         flap_victim_scenario,
                                         outage_visibility)

    g, wl, prof, p, sched, spec, (fail_at, heal_at) = flap_victim_scenario()
    p = _replace(p, ticks=ticks)
    run_on = lambda: simulate(g, wl, prof, p, faults=sched,  # noqa: E731
                              telemetry=spec)
    run_off = lambda: simulate(g, wl, prof, p, faults=sched)  # noqa: E731
    t0 = time.perf_counter()
    r_on = run_on()
    cold = time.perf_counter() - t0
    r_off = run_off()
    warm_on = min(_timed(run_on) for _ in range(3))
    warm_off = min(_timed(run_off) for _ in range(3))

    eq = jax.tree_util.tree_map(
        lambda a, c: bool(np.array_equal(np.asarray(a), np.asarray(c))),
        r_on.state, r_off.state)
    assert all(jax.tree_util.tree_leaves(eq)), \
        "telemetry must not perturb the simulation"
    tr = r_on.telemetry
    vis = outage_visibility(tr, fail_at, heal_at, ticks)
    assert_outage_visible(vis)

    s = tr.summary()
    rnd = lambda x: round(float(x), 4)  # noqa: E731
    return {
        "ticks": ticks,
        "fault_window": [fail_at, heal_at],
        "probe_every": spec.probe_every,
        "slots": spec.slots,
        "samples": tr.num_samples,
        "sample_spacing_ticks": tr.sample_spacing,
        "telemetry_cold_s": cold,
        "telemetry_on_warm_s": warm_on,
        "telemetry_off_warm_s": warm_off,
        "telemetry_overhead": warm_on / warm_off,
        "drop_rate": [rnd(vis["drop_pre"]), rnd(vis["drop_during"]),
                      rnd(vis["drop_post"])],
        "mark_rate_pre_during": [rnd(vis["mark_pre"]),
                                 rnd(vis["mark_during"])],
        "goodput_pre_during_post": [rnd(vis["goodput_pre"]),
                                    rnd(vis["goodput_during"]),
                                    rnd(vis["goodput_post"])],
        "heal_trim_burst": rnd(vis["trim_burst"]),
        "occ_p99": rnd(s["occ_p99"]),
        "rtt_p99": rnd(s.get("rtt_p99", 0.0)),
    }


def _corruption_sweep() -> dict:
    """Link-layer reliability on a BER-y fabric: the shared
    ``workloads.corruption_sweep`` BER grid run through BOTH arms of the
    LLR-on/off axis (``link=`` is a compile-time static, so the axis is
    two ``simulate_batch`` calls over the same batch), plus the
    LLR+CBFC lossless arm and the PFC-vs-CBFC buffer bill.

    In-bench recovery gates (a reliability layer that doesn't beat the
    recovery path it replaces is measuring nothing):

    * at EVERY nonzero BER, hop-local LLR replay beats end-to-end RTO
      recovery on tail completion AND per-flow goodput — and confines
      the loss: zero end-to-end drops, nonzero replays, all flows
      complete;
    * at BER=0 the LLR-armed run is bitwise the plain run on every
      pre-feature lane (the `lossy`-idiom inertness contract), and
      congestion trims are NOT masked: the clean lane trims end-to-end
      identically under both arms (LLR protects against PHY corruption
      only — trims still NACK end-to-end);
    * the CBFC arm is lossless on the clean congested lane: credit
      exhaustion back-pressures (``credit_stall_ticks > 0``) instead of
      trimming (``trims == 0``), and everything still completes;
    * the Sec. 3.5.2 buffer bill: CBFC's credited buffer undercuts
      PFC's per-(port, priority) headroom by > 2x on this topology.
    """
    from repro.core.link import (fabric_buffer_pricing, state_bitwise_equal)
    from repro.network import workloads
    from repro.network.fabric import simulate_batch

    g, wls, scheds, exp = workloads.corruption_sweep()
    prof, p, budget = exp["profile"], exp["params"], exp["budget"]
    bers, names = exp["bers"], exp["names"]
    run_on = lambda: simulate_batch(g, wls, prof, p, faults=scheds,  # noqa: E731
                                    link=exp["link"])
    run_off = lambda: simulate_batch(g, wls, prof, p, faults=scheds)  # noqa: E731
    t0 = time.perf_counter()
    on = run_on()
    cold = time.perf_counter() - t0
    off = run_off()
    cb = simulate_batch(g, wls, prof, p, faults=scheds, link=exp["cbfc"])
    warm_on = min(_timed(run_on) for _ in range(2))
    warm_off = min(_timed(run_off) for _ in range(2))

    def tail(r):
        ct = r.completion_tick()
        return ct if ct > 0 else budget

    def scenario_goodput(r):
        # delivered packets over the makespan (time for EVERY flow to
        # finish, budget if some never did) — the collective-completion
        # goodput an app sees. Per-flow mean would reward e2e's failure
        # mode (a silent drop hurts one flow; an LLR replay holds the
        # whole queue briefly), but the app waits for the tail.
        return float(np.sum(np.asarray(r.state.delivered))) / tail(r)

    grid = []
    for i, (name, ber) in enumerate(zip(names, bers)):
        t_on, t_off = tail(on[i]), tail(off[i])
        gp_on, gp_off = scenario_goodput(on[i]), scenario_goodput(off[i])
        if ber > 0:
            assert int(on[i].drops) == 0, (name, int(on[i].drops))
            assert on[i].llr_replays > 0, name
            assert on[i].completion_tick() > 0, name
            assert int(off[i].drops) > 0, (name, "BER lane must corrupt")
            assert t_on < t_off, (name, t_on, t_off)
            assert gp_on > gp_off, (name, gp_on, gp_off)
        grid.append({
            "name": name, "ber": ber,
            "completion_llr": int(on[i].completion_tick()),
            "completion_e2e": int(off[i].completion_tick()),
            "llr_replays": on[i].llr_replays,
            "e2e_drops": int(off[i].drops),
            "e2e_timeouts": int(off[i].timeouts),
            "goodput_llr": round(gp_on, 5),
            "goodput_e2e": round(gp_off, 5),
        })

    # clean-lane gates: bitwise inertness + trims not masked
    drift = state_bitwise_equal(on[0].state, off[0].state)
    assert drift is None, f"clean-link LLR run drifted: {drift}"
    assert int(on[0].trims) == int(off[0].trims) > 0, \
        (int(on[0].trims), int(off[0].trims))

    # CBFC losslessness on the clean congested lane
    assert int(cb[0].trims) == 0, int(cb[0].trims)
    assert cb[0].credit_stall_ticks > 0
    assert all(r.completion_tick() > 0 for r in cb)

    pricing = fabric_buffer_pricing(g.num_queues)
    assert pricing["cbfc_total_bytes"] < pricing["pfc_total_bytes"] / 2

    worst = grid[-1]
    return {
        "scenarios": len(names),
        "bers": list(bers),
        "budget": budget,
        "sweep_cold_s": cold,
        "sweep_warm_s": warm_on,
        "sweep_warm_off_s": warm_off,
        "scenarios_per_sec": len(names) / warm_on,
        "llr_overhead_warm": warm_on / warm_off,
        "grid": grid,
        # headline: e2e-recovery tail over LLR tail at the worst BER
        "llr_vs_e2e_recovery": round(
            tail(off[-1]) / tail(on[-1]), 3),
        "worst_ber_completion": [worst["completion_llr"],
                                 worst["completion_e2e"]],
        "cbfc_trims_clean": int(cb[0].trims),
        "cbfc_stall_ticks_clean": cb[0].credit_stall_ticks,
        "cbfc_over_pfc_buffer": round(pricing["cbfc_over_pfc"], 3),
    }


def _model_sweep() -> dict:
    """The model-driven co-design grid: 2 models x 2 sharding layouts x
    2 topologies x 3 transport profiles at decode, every operating
    point's collective schedule derived from the real sharding rules
    and priced end-to-end from ONE ``simulate_batch`` call (scenarios
    carry per-scenario graphs AND profiles; the engine groups them into
    one executable per (topology, profile) pair).

    In-bench separation gates (a co-design sweep whose axes don't move
    the step time is measuring nothing):

    * layout: at decode the fsdp_tp layout pays the ZeRO-3 param-gather
      penalty — strictly slower than the tp_only serving layout at
      EVERY (model, topology, profile) point;
    * profile: on the oversubscribed fabric under fsdp_tp, the hpc
      composition (packet-spray + in-order ROD delivery) prices the DP
      gather stream strictly slower than the ai composition (RUD) —
      the documented transport-driven step-time separation;
    * topology: 2:1 oversubscription can only slow an fsdp_tp point
      down (DP traffic crosses the spine; TP stays intra-leaf).
    """
    from repro.network import traffic

    t0 = time.perf_counter()
    pts = traffic.run_model_sweep()
    elapsed = time.perf_counter() - t0

    by = {(p["arch"], p["layout"], p["topology"], p["profile"]): p
          for p in pts}
    archs = sorted({p["arch"] for p in pts})
    seps = {}
    for a in archs:
        for topo in ("full", "oversub2"):
            for prof in ("ai_base", "ai_full", "hpc"):
                assert (by[(a, "fsdp_tp", topo, prof)]["step_s"]
                        > by[(a, "tp_only", topo, prof)]["step_s"]), \
                    (a, topo, prof)
        hpc = by[(a, "fsdp_tp", "oversub2", "hpc")]["step_s"]
        ai = by[(a, "fsdp_tp", "oversub2", "ai_full")]["step_s"]
        assert hpc > 1.05 * ai, (a, hpc, ai)
        full = by[(a, "fsdp_tp", "full", "ai_full")]["step_s"]
        over = by[(a, "fsdp_tp", "oversub2", "ai_full")]["step_s"]
        assert over >= full, (a, over, full)
        seps[a] = {
            "layout_tp_only_speedup": round(
                by[(a, "fsdp_tp", "oversub2", "ai_full")]["step_s"]
                / by[(a, "tp_only", "oversub2", "ai_full")]["step_s"], 2),
            "profile_hpc_over_ai_oversub2": round(hpc / ai, 3),
            "topology_oversub2_over_full": round(over / full, 3),
        }

    return {
        "scenarios": len(pts),
        "shape": "decode_32k",
        "dp": 16, "tp": 16,
        "sweep_s": elapsed,
        "scenarios_per_sec": len(pts) / elapsed,
        "separations": seps,
        "points": pts,
    }


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", type=int, default=8)
    ap.add_argument("--ticks", type=int, default=600)
    ap.add_argument("--devices", type=int, default=4,
                    help="virtual CPU devices for the sharded sweep "
                         "(forced in a child process only; 0/1 disables)")
    ap.add_argument("--sharded-only", action="store_true",
                    help="internal: run just the sharded sweep with the "
                         "device split forced, print its json to stdout "
                         "(the child-process half of the main bench)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_fabric.json"))
    args = ap.parse_args()

    if args.sharded_only:
        _force_host_devices(args.devices)
        print(json.dumps(_sharded_sweep(), indent=2, sort_keys=True))
        return

    results = run_benches(args.scenarios, args.ticks, args.devices)
    results["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")

    print(json.dumps(results, indent=2, sort_keys=True))
    cs = results["collective_sweep"]
    fs = results["fault_sweep"]
    rz = results["resilience_sweep"]
    fh = results["fabric_health"]
    cr = results["corruption_sweep"]
    ms = results["model_sweep"]
    sh = results["sharded_sweep"]
    sh_line = (f"sharded sweep skipped ({sh['skipped']})" if "skipped" in sh
               else f"sharded sweep {sh['shard_speedup']:.2f}x on "
                    f"{sh['devices']} devices "
                    f"({sh['scenarios_per_sec_sharded']:.1f} scen/s)")
    print(f"\nbatched sweep (cold, incl. compile) is "
          f"{results['batch_speedup_vs_serial']:.1f}x the seed-style serial "
          f"sweep; warm-vs-warm against the shared-executable serial loop it "
          f"is {results['batch_speedup_vs_serial_shared_warm']:.2f}x; "
          f"chunked driver vs fixed scan "
          f"{results['fastpath_vs_fixed_scan']:.2f}x "
          f"(aligned-chunk fast path "
          f"{results['ticks_per_sec_batched_fastpath']:.0f} ticks/s); "
          f"{sh_line}; "
          f"collective grid ran {cs['scenarios']} scenarios at "
          f"{cs['scenarios_per_sec']:.2f}/s, INC tree-all-reduce completion "
          f"ratio {cs['inc_tree_allreduce_ratio']}; fault grid "
          f"{fs['scenarios']} scenarios at {fs['scenarios_per_sec']:.2f}/s, "
          f"eviction separation "
          f"{fs['eviction_separation']['completion_evict_on']} vs "
          f"{fs['eviction_separation']['completion_evict_off']}; "
          f"resilience grid {rz['scenarios']} scenarios at "
          f"{rz['scenarios_per_sec']:.2f}/s, dead host detected at tick "
          f"{rz['abandon_tick']} and quiesced at {rz['horizon_pdc_on']} vs "
          f"pdc-off stuck at {rz['horizon_pdc_off']}, 1h-MTBF Young/Daly "
          f"availability {rz['availability_mtbf_3600']:.4f}; "
          f"model sweep {ms['scenarios']} operating points at "
          f"{ms['scenarios_per_sec']:.2f}/s, separations {ms['separations']}; "
          f"fabric health: outage visible (drops "
          f"{fh['drop_rate'][0]} -> {fh['drop_rate'][1]} -> "
          f"{fh['drop_rate'][2]}/tick, heal trim burst "
          f"{fh['heal_trim_burst']}/tick) at "
          f"{fh['telemetry_overhead']:.2f}x telemetry overhead; "
          f"corruption grid {cr['scenarios']} BER points at "
          f"{cr['scenarios_per_sec']:.2f}/s, worst-BER completion LLR "
          f"{cr['worst_ber_completion'][0]} vs e2e "
          f"{cr['worst_ber_completion'][1]} "
          f"({cr['llr_vs_e2e_recovery']:.2f}x recovery win), CBFC buffer "
          f"{cr['cbfc_over_pfc_buffer']:.2f}x of PFC; "
          f"wrote {out}")


if __name__ == "__main__":
    main()
