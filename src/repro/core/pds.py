"""PDS packet tracking: PSN space, SACK bitmaps, CACK, MP_RANGE
(Sec. 3.2.5).

The target of an unordered (RUD/RUDI) flow tracks arrived packets in a ring
bitmap anchored at the cumulative-ACK point:

    bit i of the ring  <=>  PSN (base + i) has arrived

* `record_rx` sets bits for a batch of arriving PSNs, enforcing MP_RANGE —
  packets beyond the advertised tracking range are NOT accepted (this is
  the receiver-resource protection; the source must back off).
* `advance_cack` counts the contiguous prefix of received PSNs, advances
  `base`, and shifts the ring — the hot loop a hardware PDS runs per ACK
  coalescing interval. (Pallas kernel: repro/kernels/sack_bitmap.py.)
* `sack_view` extracts the 64-bit SACK window + CACK PSN carried in ACK
  packets.

Duplicate arrivals (bit already set) are reported so RUD can drop them;
RUDI by definition skips dedup (idempotent ops) and the tracker is not
consulted for delivery there, only for ACK generation.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

WORD = 32  # ring bitmap word width


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class PSNTracker:
    """Per-PDC receive tracking state (SoA over N PDCs).

    base:   [N] uint32 — lowest not-cumulatively-acked PSN
    ring:   [N, W] uint32 — ring bitmap covering mp_range = W*32 PSNs
    rx_ok:  [N] uint32 — accepted packets (stats)
    dup:    [N] uint32 — duplicate arrivals (stats)
    oor:    [N] uint32 — rejected: outside MP_RANGE (stats)
    """

    base: jax.Array
    ring: jax.Array
    rx_ok: jax.Array
    dup: jax.Array
    oor: jax.Array

    @staticmethod
    def create(n: int, mp_range: int) -> "PSNTracker":
        assert mp_range % WORD == 0
        return PSNTracker(
            base=jnp.zeros((n,), jnp.uint32),
            ring=jnp.zeros((n, mp_range // WORD), jnp.uint32),
            rx_ok=jnp.zeros((n,), jnp.uint32),
            dup=jnp.zeros((n,), jnp.uint32),
            oor=jnp.zeros((n,), jnp.uint32),
        )

    @property
    def mp_range(self) -> int:
        return self.ring.shape[1] * WORD


def or_mask(ring: jax.Array, row: jax.Array, off: jax.Array,
            valid: jax.Array,
            unique_rows: bool = False) -> tuple[jax.Array, jax.Array]:
    """Build the uint32 OR-mask a batch of lanes wants to set in `ring`.

    ring: [N, W] uint32; row, off: [B] int32 (off = bit offset within the
    row's window); valid: [B] bool. Out-of-window offsets are dropped.
    Returns (mask [N, W] uint32, already [B] bool) where `already` flags
    lanes whose bit is set in `ring` before this batch.

    The mask is built with a direct scatter-add of single-bit words —
    no [N, W, 32] boolean plane. Addition equals OR because each kept
    lane contributes a bit that is (a) not already in `ring` and (b) not
    contributed by any other kept lane: exact-duplicate (row, off) lanes
    are deduplicated by a first-lane-wins claim scatter. Callers that
    guarantee at most one lane per row (most fabric call sites are
    structurally unique) pass unique_rows=True to skip the claim pass.
    """
    N, W = ring.shape
    mp = W * WORD
    ok = valid & (off >= 0) & (off < mp)
    o = jnp.clip(off, 0, mp - 1)
    safe_row = jnp.where(ok, row, 0)
    word = o // WORD
    bit = jnp.uint32(1) << (o % WORD).astype(jnp.uint32)
    already = (ring[safe_row, word] & bit) != 0
    keep = ok & ~already
    if not unique_rows:
        # first-lane-wins on the exact (row, bit-offset) key so duplicate
        # lanes add the same power of two only once: a pairwise earlier-
        # lane-same-key test — O(B^2) fused bools, no [N, mp] claim buffer
        B = row.shape[0]
        key = jnp.where(keep, safe_row * mp + o, -1)
        lane = jnp.arange(B)
        dup = ((key[None, :] == key[:, None])
               & (lane[None, :] < lane[:, None])).any(axis=1)
        keep = keep & ~dup
    idx = jnp.where(keep, safe_row * W + word, N * W)  # OOB => dropped
    mask = jnp.zeros((N * W,), jnp.uint32).at[idx].add(
        jnp.where(keep, bit, jnp.uint32(0)), mode="drop")
    return mask.reshape(N, W), already


def record_rx(t: PSNTracker, pdc: jax.Array, psn: jax.Array,
              valid: jax.Array,
              unique_rows: bool = False) -> tuple[PSNTracker, jax.Array]:
    """Record a batch of arriving packets.

    pdc, psn: int32/uint32 [B]; valid: bool [B] (False = no packet in lane).
    Returns (tracker', accepted [B] bool) — accepted means in-range and not
    a duplicate. Duplicate-safe by default; unique_rows=True skips the
    dedup pass when the caller guarantees at most one valid lane per PDC.
    (The fabric tick no longer routes through record_rx — its receive
    path is densified per-flow; this stays the general batch API.)
    """
    mp = t.mp_range
    off = (psn.astype(jnp.uint32) - t.base[jnp.where(valid, pdc, 0)])
    in_range = (off < mp) & valid
    mask, already = or_mask(t.ring, pdc, off.astype(jnp.int32), in_range,
                            unique_rows=unique_rows)
    fresh = in_range & ~already
    ring = t.ring | mask
    one = jnp.uint32(1)
    safe_pdc = jnp.where(valid, pdc, 0)
    return PSNTracker(
        base=t.base,
        ring=ring,
        rx_ok=t.rx_ok.at[safe_pdc].add(jnp.where(fresh, one, 0)),
        dup=t.dup.at[safe_pdc].add(jnp.where(in_range & already, one, 0)),
        oor=t.oor.at[safe_pdc].add(jnp.where(valid & ~in_range, one, 0)),
    ), fresh


def trailing_ones(ring: jax.Array) -> jax.Array:
    """Per-row count of contiguous set bits from bit 0 of word 0.

    ring: [N, W] uint32 -> [N] int32 in [0, W*32].
    """
    full = ring == jnp.uint32(0xFFFFFFFF)
    # trailing ones within each word = trailing zeros of ~word
    inv = ~ring
    # count trailing zeros via bit twiddling: ctz(x) = popcount((x & -x) - 1)
    lsb = inv & (jnp.uint32(0) - inv)
    ctz = _popcount32(lsb - jnp.uint32(1))
    ctz = jnp.where(inv == 0, WORD, ctz)  # all-ones word
    # prefix: words before the first non-full word contribute 32 each
    first_partial = jnp.argmin(full.astype(jnp.int32), axis=1)
    all_full = full.all(axis=1)
    W = ring.shape[1]
    n_full = jnp.where(all_full, W, first_partial)
    partial_bits = jnp.where(
        all_full, 0, ctz[jnp.arange(ring.shape[0]), jnp.clip(first_partial, 0, W - 1)])
    return (n_full * WORD + partial_bits).astype(jnp.int32)


def _popcount32(x: jax.Array) -> jax.Array:
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def shift_ring(ring: jax.Array, count: jax.Array) -> jax.Array:
    """Logical right-shift each row of the ring bitmap by `count` bits
    (cross-word funnel shift), vectorized over rows."""
    N, W = ring.shape
    words = count // WORD
    bits = (count % WORD).astype(jnp.uint32)
    idx = jnp.arange(W)[None, :] + words[:, None]
    lo = jnp.where(idx < W, ring[jnp.arange(N)[:, None], jnp.clip(idx, 0, W - 1)],
                   jnp.uint32(0))
    hi = jnp.where(idx + 1 < W,
                   ring[jnp.arange(N)[:, None], jnp.clip(idx + 1, 0, W - 1)],
                   jnp.uint32(0))
    b = bits[:, None]
    # (lo >> b) | (hi << (32-b)), careful with b == 0 (shift by 32 is UB-ish)
    shifted = jnp.where(b == 0, lo, (lo >> b) | (hi << (jnp.uint32(WORD) - b)))
    return shifted


def advance_cack(t: PSNTracker) -> tuple[PSNTracker, jax.Array]:
    """Advance the cumulative-ACK point past every contiguous received PSN.

    Returns (tracker', advanced [N] int32). Reference implementation; the
    Pallas kernel in repro/kernels/sack_bitmap.py computes the same thing
    blockwise in VMEM.
    """
    adv = trailing_ones(t.ring)
    ring = shift_ring(t.ring, adv)
    return PSNTracker(
        base=t.base + adv.astype(jnp.uint32),
        ring=ring, rx_ok=t.rx_ok, dup=t.dup, oor=t.oor,
    ), adv


def sack_view(t: PSNTracker) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(cack_psn, sack_lo, sack_hi) per PDC: the ACK-carried fields.

    cack_psn acknowledges every PSN < base; (sack_hi:sack_lo) is the 64-bit
    SACK bitmap immediately above base (Sec. 3.2.5). Two uint32 words since
    the simulator runs without x64 mode — exactly the two words a wire
    header would carry.
    """
    cack = t.base
    lo = t.ring[:, 0]
    hi = t.ring[:, 1] if t.ring.shape[1] > 1 else jnp.zeros_like(lo)
    return cack, lo, hi


def ooo_distance(t: PSNTracker) -> jax.Array:
    """Out-of-order span: distance between the highest received PSN and the
    CACK point — the OOO_COUNT loss-inference signal (Sec. 3.2.4)."""
    W = t.ring.shape[1]
    any_bit = t.ring != 0
    # highest set bit position per row
    word_idx = (W - 1) - jnp.argmax(any_bit[:, ::-1].astype(jnp.int32), axis=1)
    has = any_bit.any(axis=1)
    w = t.ring[jnp.arange(t.ring.shape[0]), jnp.clip(word_idx, 0, W - 1)]
    # floor(log2(w)) via popcount trick
    msb = 31 - _clz32(w)
    return jnp.where(has, word_idx * WORD + msb + 1, 0).astype(jnp.int32)


def _clz32(x: jax.Array) -> jax.Array:
    x = x | (x >> 1)
    x = x | (x >> 2)
    x = x | (x >> 4)
    x = x | (x >> 8)
    x = x | (x >> 16)
    return (WORD - _popcount32(x)).astype(jnp.int32)
