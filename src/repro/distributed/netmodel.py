"""UET-aware collective network model — the bridge between the paper's
transport and the training framework.

Given the collective byte counts parsed from a compiled step (the dry-run
artifacts), this module estimates collective wall time on a UET backend
fabric two ways:

1. `analytic_collective_time` — algorithmic lower bound: ring/tree costs
   on `links` of `link_gbps`, the classical alpha-beta model. This is the
   roofline's collective term.
2. `simulated_efficiency` — run the actual packet-level UET fabric
   simulator on the collective's traffic pattern (all-reduce => ring
   neighbor exchange; all-to-all => full permutation bursts; all-gather =>
   broadcast-like fan-in) under a chosen transport config (NSCC/RCCC,
   spraying scheme, trimming) and report achieved goodput vs line rate.
   This prices the paper's mechanisms into the framework's performance
   model: e.g. oblivious spraying vs single-path ECMP changes the
   delivered bandwidth of the gradient all-reduce, exactly the
   polarization effect of Sec. 2.1.

The estimates feed launch/roofline.py (term = bytes / (chips * link_bw *
efficiency)) and give the sharding planner a UET-aware cost signal.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.lb.schemes import LBScheme
from repro.network.fabric import SimParams, Workload, simulate
from repro.network.topology import leaf_spine


@dataclass(frozen=True)
class FabricSpec:
    link_gbps: float = 400.0   # per ICI/NIC link — paper's design point
    links_per_chip: int = 1
    mtu: int = 4096


def analytic_collective_time(kind: str, bytes_total: float, chips: int,
                             fabric: FabricSpec = FabricSpec()) -> float:
    """Alpha-beta time for one collective of `bytes_total` output bytes.

    Ring all-reduce moves 2*(n-1)/n of the data per chip; all-gather and
    reduce-scatter (n-1)/n; all-to-all (n-1)/n across bisection;
    collective-permute exactly its payload.
    """
    bw = fabric.link_gbps * 1e9 / 8 * fabric.links_per_chip
    per_chip = bytes_total / max(chips, 1)
    n = max(chips, 2)
    factor = {
        "all-reduce": 2 * (n - 1) / n,
        "all-gather": (n - 1) / n,
        "reduce-scatter": (n - 1) / n,
        "all-to-all": (n - 1) / n,
        "collective-permute": 1.0,
    }.get(kind, 1.0)
    return per_chip * factor / bw


def collective_term_seconds(coll_bytes: dict, chips: int,
                            fabric: FabricSpec = FabricSpec(),
                            efficiency: float = 1.0) -> float:
    """Total collective seconds for a {kind: bytes} dict (per-device HLO
    numbers -> aggregate wall estimate at `efficiency` of line rate)."""
    t = 0.0
    for kind, b in coll_bytes.items():
        if kind == "total":
            continue
        t += analytic_collective_time(kind, b * chips, chips, fabric)
    return t / max(efficiency, 1e-6)


# ---------------------------------------------------------------------------
# packet-level efficiency factors from the UET simulator
# ---------------------------------------------------------------------------


def _pattern_workload(kind: str, hosts: int, size_pkts: int):
    """Map a collective onto a fabric traffic pattern."""
    if kind in ("all-reduce", "reduce-scatter", "all-gather",
                "collective-permute"):
        # ring neighbor exchange: host i -> i+1 (the dominant phase of
        # ring collectives); permutation distance 1
        src = list(range(hosts))
        dst = [(i + 1) % hosts for i in range(hosts)]
    else:  # all-to-all: worst-case full shuffle, modeled as a rotating
        # permutation burst at max distance
        src = list(range(hosts))
        dst = [(i + hosts // 2) % hosts for i in range(hosts)]
    return Workload.of(src, dst, size_pkts)


def simulated_efficiency(kind: str = "all-reduce", hosts: int = 32,
                         size_pkts: int = 2000,
                         lb: "LBScheme | None" = None,
                         profile=None,
                         trimming: bool = True,
                         oversub: int = 1,
                         ticks: int = 3000) -> float:
    """Achieved goodput fraction of line rate for one collective phase on
    the packet-level UET fabric (leaf-spine, `oversub`:1).

    ``profile`` selects the full transport composition; ``lb`` is the
    shorthand for the common collective ablation axis (ai_full profile
    with that scheme). Passing both is ambiguous and raises.
    """
    from repro.network.profile import TransportProfile
    if profile is None:
        profile = TransportProfile.ai_full(
            lb=LBScheme.OBLIVIOUS if lb is None else lb)
    elif lb is not None:
        raise ValueError("pass either profile= or lb=, not both — encode "
                         "the LB scheme in the profile")
    hosts_per_leaf = 4
    leaves = hosts // hosts_per_leaf
    g = leaf_spine(leaves=leaves, spines=max(2, leaves // oversub),
                   hosts_per_leaf=hosts_per_leaf)
    wl = _pattern_workload(kind, g.num_hosts, size_pkts)
    p = SimParams(ticks=ticks, trimming=trimming)
    r = simulate(g, wl, profile, p)
    gp = r.goodput((ticks // 3, ticks))
    return float(np.mean(gp))
