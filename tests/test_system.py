"""End-to-end behaviour tests: the paper's quantitative claims on the
packet-level UET fabric simulator, expressed through TransportProfiles."""
import numpy as np
import pytest

from repro.core.lb.schemes import LBScheme
from repro.network import workloads
from repro.network.fabric import SimParams, simulate
from repro.network.profile import CCAlgo, DeliveryMode, TransportProfile


@pytest.fixture(scope="module")
def incast_rccc():
    g, wl, exp = workloads.incast(4, size=100000)
    # ai_base: receiver-credit CC only — the exact-share incast profile
    # default trace="stats": the (300, 1200) goodput window is
    # registered up front and streamed inside the scan
    return simulate(g, wl, TransportProfile.ai_base(),
                    SimParams(ticks=1200),
                    goodput_window=(300, 1200)), exp


def test_incast_rccc_optimal_shares(incast_rccc):
    """Fig. 7 group 4: RCCC assigns each of 4 incast flows 25% — optimal."""
    r, exp = incast_rccc
    gp = r.goodput((300, 1200))
    np.testing.assert_allclose(gp, exp["share"], atol=0.02)


@pytest.mark.slow
def test_outcast_rccc_blind_vs_nscc():
    """Fig. 7 group 1: RCCC grants w->v only 50% (waste); NSCC converges
    toward the 75% optimum."""
    g, wl, exp = workloads.outcast(4, size=100000)
    r = simulate(g, wl, TransportProfile.ai_base(), SimParams(ticks=2500),
                 goodput_window=(800, 2500))
    w_share_rccc = r.goodput((800, 2500))[4]
    assert abs(w_share_rccc - exp["rccc_w_share"]) < 0.03
    r2 = simulate(g, wl, TransportProfile.ai_full(), SimParams(ticks=2500),
                  goodput_window=(1200, 2500))
    w_share_nscc = r2.goodput((1200, 2500))[4]
    assert w_share_nscc > 0.65  # approaches 0.75, strictly beats RCCC
    assert w_share_nscc > w_share_rccc + 0.1


def test_in_network_rccc_grant():
    """Fig. 7 groups 2/3: 12 flows over 4 uplinks deliver ~33% each; the
    same-leaf flow is granted only 50% by RCCC though 67% is available."""
    g, wl, exp = workloads.in_network(12, 4, size=100000)
    r = simulate(g, wl, TransportProfile.ai_base(), SimParams(ticks=2500),
                 goodput_window=(800, 2500))
    gp = r.goodput((800, 2500))
    assert abs(gp[:12].mean() - exp["cross_share"]) < 0.04
    assert abs(gp[12] - exp["rccc_local_share"]) < 0.04


@pytest.mark.slow
def test_spraying_beats_static_ecmp():
    """Sec. 2.1: per-packet spraying avoids polarization; static
    single-path ECMP collapses under hash collisions."""
    g, wl, _ = workloads.permutation(k=8, pods=4, shift=17, size=100000)
    res = {}
    for scheme in (LBScheme.STATIC, LBScheme.OBLIVIOUS, LBScheme.REPS):
        r = simulate(g, wl, TransportProfile.ai_full(lb=scheme),
                     SimParams(ticks=1500), goodput_window=(700, 1500))
        res[scheme] = r.goodput((700, 1500)).mean()
    assert res[LBScheme.OBLIVIOUS] > res[LBScheme.STATIC] + 0.2
    assert res[LBScheme.REPS] >= res[LBScheme.OBLIVIOUS] - 0.02
    assert res[LBScheme.REPS] > 0.9


@pytest.mark.slow
def test_trimming_recovers_faster_than_timeout():
    """Sec. 3.2.4: fast loss detection (trimming) beats timeout-only
    recovery on completion time. The burst must be SHORT so that recovery
    latency (not downlink capacity) dominates completion — a long incast
    is capacity-bound for both and hides the difference."""
    g, wl, _ = workloads.incast(8, size=48)
    prof = TransportProfile.ai_full()
    base = dict(ticks=1500, timeout_ticks=300)
    r_trim = simulate(g, wl, prof, SimParams(trimming=True, **base))
    r_drop = simulate(g, wl, prof, SimParams(trimming=False, **base))
    ct_trim = r_trim.completion_ticks()
    ct_drop = r_drop.completion_ticks()
    assert (ct_trim >= 0).all(), "trimming run must complete"
    # timeout-only either doesn't finish in budget or is strictly slower
    unfinished = (ct_drop < 0).any()
    assert unfinished or ct_drop.mean() > ct_trim.mean() + 50
    assert int(r_trim.state.trims) > 0
    assert int(r_drop.state.drops) > 0


def test_rod_single_path_and_delivery():
    """ROD delivers reliably in order on a single path (go-back-N)."""
    g, wl, _ = workloads.incast(2, size=400)
    prof = TransportProfile(cc=CCAlgo.NSCC, delivery=DeliveryMode.ROD,
                            name="rod")
    r = simulate(g, wl, prof, SimParams(ticks=3000))
    assert r.completion_tick() >= 0
    assert int(r.state.delivered.sum()) == 2 * 400


def test_reliability_all_flows_complete_under_losses():
    """RUD + trimming: every message completes despite congestion drops."""
    g, wl, _ = workloads.in_network(12, 4, size=300)
    r = simulate(g, wl, TransportProfile.ai_full(), SimParams(ticks=6000))
    assert (r.completion_ticks() >= 0).all()
    # conservation: delivered first-copies == message sizes
    np.testing.assert_array_equal(
        np.asarray(r.state.delivered), np.asarray(wl.size))


@pytest.mark.slow
def test_reps_failure_mitigation():
    """REPS title claim: '...Adaptive Load Balancing and Failure
    Mitigation'. With one of 4 uplinks dead (silent Configuration drops,
    Sec. 3.2.4), 8 flows share 3 live uplinks => optimum 3/8 = 0.375 per
    flow. REPS stops recycling dead-path EVs and approaches the optimum;
    oblivious spraying keeps wasting 1/4 of transmissions forever."""
    from repro.network.fabric import Workload
    from repro.network.topology import leaf_spine

    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=8)
    wl = Workload.of(list(range(8)), [8 + i for i in range(8)], 100000)
    dead = (int(g.up1_table[0, 0]),)
    p = SimParams(ticks=3000, timeout_ticks=64, ooo_threshold=24)
    res = {}
    for scheme in (LBScheme.OBLIVIOUS, LBScheme.REPS):
        r = simulate(g, wl, TransportProfile.ai_full(lb=scheme), p,
                     failed=dead, goodput_window=(1500, 3000))
        res[scheme] = float(r.goodput((1500, 3000)).mean())
    optimum = 3.0 / 8.0
    assert res[LBScheme.REPS] > 0.9 * optimum
    assert res[LBScheme.REPS] > res[LBScheme.OBLIVIOUS] * 1.3
