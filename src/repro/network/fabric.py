"""Vectorized packet-level fabric simulator — the UET reproduction engine.

One simulator tick == the serialization time of one MTU packet on one link.
Every link is a FIFO queue; each tick every queue dequeues at most one
packet (line rate) and forwards it one hop. All protocol state — PSN
bitmaps, congestion windows, credit balances, EV recycle rings — is
structure-of-arrays, and a tick is a pure function stepped by
``jax.lax.scan`` under ``jit``. This is the TPU-native re-architecture of
the paper's protocol: what a hardware UET NIC does per packet, the
simulator does per *vector of flows* per tick.

The public API is declarative: a :class:`~repro.network.profile.
TransportProfile` says WHAT transport composition to run (CC algorithm,
LB scheme, per-flow delivery modes — the paper's profile table), and
``SimParams`` holds the numeric knobs (tick budget, queue depths,
thresholds). ``make_step`` composes the tick from pluggable CC and LB
policy objects; new policies implement the small protocol documented in
`repro.network.profile` and land without touching this engine.

The engine runs in two modes:

* ``simulate(g, wl, profile, p)`` — one scenario per call;
* ``simulate_batch(g, wls, profile, p)`` — a whole scenario sweep
  (different workloads, LB seeds, failure sets) ``vmap``-ed over a
  leading scenario axis, so an entire failure or incast sweep is ONE
  compiled ``scan``. Workloads, seeds and failure masks are traced
  inputs: sweeping them never recompiles. Profiles are *static* (they
  pick the compiled composition); passing a list of per-scenario
  profiles groups the batch by profile — one executable per distinct
  profile, e.g. a 3-profile x N-scenario ablation is 3 compiles and 3
  device launches for the whole grid. Per-lane results are bitwise
  identical to serial ``simulate`` calls. ``shard=True`` (or
  ``devices=``) additionally shards the scenario axis across devices
  with ``shard_map`` — see `repro.network.shard` — still bitwise
  identical, with each device exiting at its own lanes' quiescence.

Execution model (the adaptive-horizon driver): the tick budget is NOT a
fixed scan length. The driver runs a ``lax.while_loop`` over fixed-size
scan chunks (``SimParams.chunk_ticks``) and exits as soon as a scenario
is *quiescent* — every source CACK-complete, nothing inflight, queues
and control-event buffers drained — so a 1600-tick budget costs only as
many chunks as the scenario actually needs. The budget (``max_ticks`` /
``SimParams.ticks``) is a traced bound: one compiled executable serves
every horizon for a given (topology, profile, flow count, chunk) shape.
Results come in two trace tiers (see :class:`SimResult`): the default
``trace="stats"`` streams completion ticks / windowed goodput inside the
scan (no per-tick lanes, memory independent of the horizon);
``trace="full"`` buffers the dense per-tick lanes chunk by chunk and
concatenates them on the host. The state trajectory on the ticks that
run is bitwise identical across tiers, batching, and horizons.

Modeled faithfully (paper sections in parens):

* ECMP spraying with per-packet EVs through a real Clos topology (2.1)
* egress ECN marking above a queue threshold (3.3.1)
* packet trimming on overflow -> fast NACK to the source (3.2.4)
* RUD selective-repeat with a source retransmit bitmap; ROD go-back-N on a
  single static path with an in-order-only receiver (3.2.1)
* receiver PSN tracking with SACK rings + MP_RANGE rejection (3.2.5)
* NSCC 4-case window control + Quick Adapt; RCCC receiver credits; both
  composable, as the spec prescribes (3.3)
* LB schemes: static / oblivious / RR-slots / REPS / EV-bitmap (3.3.5)
* OOO-count and EV-based loss inference, timeout fallback (3.2.4)
* control traffic (ACKs, NACKs, credits) rides the second traffic class,
  modeled as a fixed-latency uncongested return path (3.1.4)
* dependency-scheduled flows (``Workload.dep``): multi-phase collectives
  (repro.network.collectives) gate each phase on its parent's source
  completion inside the scan — a whole ring/recursive-doubling/tree
  collective is one compiled run
* in-network reduction (``TransportProfile.inc`` + ``Workload.red``):
  switch-resident accumulator contexts absorb all but one child packet
  per PSN at the destination ToR and ACK the absorbed sources
  (repro.core.inc; the UE roadmap's in-network-collectives frontier)

Simplifications recorded in DESIGN.md: RCCC credit grants apply without
path delay (the grant *rate* is what the algorithm controls); trimmed
headers travel on the control TC (elevated priority per the spec).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import inc, pds
from repro.core import pdc as pdc_fsm
from repro.core.cms.nscc import NSCCParams
from repro.core.link import CTR_MOD, LinkConfig
from repro.core.lb.schemes import LBPolicy, LBScheme, LBState, _mix32
from repro.core.lb.schemes import _pick_lane as _pick
from repro.kernels import ops as kops
from repro.network.ecmp import DELIVERED, RoutingTables
from repro.network import telemetry as telem
from repro.network.faults import FaultSchedule, as_schedule, loss_threshold
from repro.network.profile import (DeliveryMode, TransportProfile,
                                   make_cc_policy)
from repro.network.telemetry import TelemetrySpec
from repro.network.topology import QueueGraph, Stage

# packet meta bits
META_TRIMMED = 1
META_ECN = 2

# event types
EV_NONE, EV_ACK, EV_NACK, EV_OOO = 0, 1, 2, 3

# packed packet-field lanes of SimState.q_pkt (one scatter/gather moves a
# whole packet record instead of five scalar planes)
PKT_FLOW, PKT_PSN, PKT_EV, PKT_META, PKT_TSENT, PKT_FIELDS = 0, 1, 2, 3, 4, 5
# packed control-event lanes of SimState.ev_buf
EVF_TYPE, EVF_FLOW, EVF_PSN, EVF_VAL, EVF_ECN, EVF_TSENT, EVF_FIELDS = \
    0, 1, 2, 3, 4, 5, 6

DEFAULT_SEED = 0x5EED


@dataclass(frozen=True)
class SimParams:
    """Numeric simulation knobs (hashable; closed over by jit).

    Transport *composition* — CC algorithm, LB scheme, delivery modes —
    lives in :class:`TransportProfile`, not here. (The pre-profile
    transport fields — ``mode``/``lb``/``nscc``/``rccc``/
    ``failed_queues`` — are gone; constructing with them is a TypeError.
    The positional-SimParams call form still warns for one release, see
    ``_normalize_call``.)
    """

    ticks: int = 2000
    #: while-scan chunk size: quiescence is checked (and the dense trace
    #: is flushed) every `chunk_ticks` ticks. Static — it shapes the
    #: compiled chunk body — but the horizon itself is traced, so
    #: executables are shared across every tick budget.
    chunk_ticks: int = 128
    queue_capacity: int = 64
    ecn_threshold: int = 12
    trimming: bool = True
    ack_return_ticks: int = 4
    mp_range: int = 512           # receiver tracking window (PSNs)
    ev_slots: int = 16            # K for RR/REPS/EVBITMAP
    timeout_ticks: int = 256
    ooo_threshold: int = 0        # 0 = disabled
    max_cwnd: float = 48.0        # ~BDP in packets (optimistic start)
    base_rtt: float = 10.0        # unloaded RTT in ticks, for NSCC
    inc_slots: int = 64           # INC accumulator slots per reduction group


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class Workload:
    """Flow set: src/dst host ids, message size (packets), start tick,
    plus two scheduling lanes:

    * ``dep`` — flow dependency: flow f becomes eligible to inject only
      after flow ``dep[f]`` *completes at its source* (CACK reaches its
      message size); -1 = no dependency. Gated in-scan exactly like
      ``start``, so a whole multi-phase collective (repro.network.
      collectives) compiles to ONE ``lax.scan``. Dependencies must be
      acyclic (builders emit phase-ordered chains; a cycle never becomes
      eligible).
    * ``red`` — in-network-reduction group id (-1 = none): flows sharing
      a ``red`` id and destination form one switch-resident reduction
      group when the profile has ``inc=True`` (repro.core.inc).

    All fields are traced arrays — a Workload can carry a leading scenario
    axis ([B, F]) for ``simulate_batch``; build one with ``Workload.stack``.
    """

    src: jax.Array   # [F] int32
    dst: jax.Array   # [F] int32
    size: jax.Array  # [F] int32
    start: jax.Array  # [F] int32
    dep: jax.Array   # [F] int32 flow index this flow waits on (-1 = none)
    red: jax.Array   # [F] int32 INC reduction-group id (-1 = none)

    @staticmethod
    def of(src, dst, size, start=None, dep=None, red=None) -> "Workload":
        src = jnp.asarray(src, jnp.int32)
        f = src.shape[0]
        neg1 = jnp.full((f,), -1, jnp.int32)
        return Workload(
            src=src, dst=jnp.asarray(dst, jnp.int32),
            size=jnp.asarray(size, jnp.int32) * jnp.ones((f,), jnp.int32),
            start=(jnp.zeros((f,), jnp.int32) if start is None
                   else jnp.asarray(start, jnp.int32)),
            dep=(neg1 if dep is None else jnp.asarray(dep, jnp.int32)),
            red=(neg1 if red is None else jnp.asarray(red, jnp.int32)),
        )

    @staticmethod
    def stack(wls: "list[Workload] | tuple[Workload, ...]") -> "Workload":
        """Stack same-F workloads along a leading scenario axis ([B, F])."""
        f = {int(w.src.shape[-1]) for w in wls}
        if len(f) != 1:
            raise ValueError(f"scenario batch needs a uniform flow count, "
                             f"got {sorted(f)}")
        return Workload(
            src=jnp.stack([w.src for w in wls]),
            dst=jnp.stack([w.dst for w in wls]),
            size=jnp.stack([w.size for w in wls]),
            start=jnp.stack([w.start for w in wls]),
            dep=jnp.stack([w.dep for w in wls]),
            red=jnp.stack([w.red for w in wls]),
        )


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class SimState:
    """The lax.scan carry: the entire fabric + protocol state."""

    # queues (ring buffers; packet records packed along the last axis so
    # one enqueue scatter / dequeue gather moves whole packets)
    q_pkt: jax.Array    # [Q, C, PKT_FIELDS] int32 (flow = -1 => empty)
    q_head: jax.Array   # [Q] int32
    q_len: jax.Array    # [Q] int32
    # sender state
    next_psn: jax.Array     # [F] int32
    inflight: jax.Array     # [F] int32
    src_track: pds.PSNTracker  # ACK tracking at the source (base = CACK)
    rtx: jax.Array          # [F, W] uint32 retransmit bitmap (rel. to base)
    last_progress: jax.Array  # [F] int32
    slot_last_ack: jax.Array  # [F, K] int32, EV-based loss detection
    # receiver state
    dst_track: pds.PSNTracker
    last_ooo_nack: jax.Array  # [F] int32
    # congestion control (policy-owned pytree) + LB
    cc: object
    lb: LBState
    # control-TC delay ring (packed: type/flow/psn/ev/ecn/tsent lanes)
    ev_buf: jax.Array   # [D, E, EVF_FIELDS] int32
    # in-network reduction contexts (repro.core.inc; zero-size when the
    # profile has INC off)
    inc: object
    # stats
    delivered: jax.Array  # [F] int32 packets delivered (first copies)
    trims: jax.Array      # [] int32
    drops: jax.Array      # [] int32
    dups: jax.Array       # [] int32
    #: packets absorbed by switch-resident reduction (each one a packet
    #: the parent downlink never carried) / aggregates emitted
    inc_reduced: jax.Array  # [] int32
    inc_emits: jax.Array    # [] int32
    #: in-range arrivals a ROD receiver discarded for being out of order
    #: (go-back-N rejects; NOT duplicates — counted separately from dups)
    rod_rejects: jax.Array  # [] int32
    retransmits: jax.Array  # [] int32
    #: per-flow retransmission timeout, in ticks. Constant at
    #: ``SimParams.timeout_ticks`` unless the profile sets
    #: ``rto_backoff > 1``: then each timeout multiplies it (capped at
    #: ``rto_max_scale`` x base) and any ACK resets it.
    rto: jax.Array          # [F] int32
    #: recovery-loop counters (streamed: O(1) carry, present in both
    #: trace tiers via SimResult.timeouts / .ev_evictions / ...)
    timeouts: jax.Array       # [] int32 RTO expiries (incl. ROD rewinds)
    ev_evictions: jax.Array   # [] int32 EVs blacklisted by the LB policy
    ticks_degraded: jax.Array  # [] int32 ticks with >= 1 link/host dead
    #: PDC liveness lanes (value-inert unless the profile sets
    #: ``pdc_dead_after > 0`` — the updates are statically elided)
    rto_strikes: jax.Array    # [F] int32 consecutive zero-progress RTOs
    quarantined: jax.Array    # [F] bool PDC torn down, flow abandoned
    flows_abandoned: jax.Array    # [] int32 PDCs declared unreachable
    ticks_unreachable: jax.Array  # [] int32 ticks with >= 1 quarantined flow
    #: link-layer reliability lanes (repro.core.link.LinkConfig; the
    #: per-queue arrays are zero-size unless the dispatching `link=`
    #: spec arms them — the scalars stream 0 on unarmed runs)
    llr_busy_until: jax.Array  # [Q] int32 LLR go-back-N replay deadline
    llr_replays: jax.Array     # [] int32 frames corrupted + replayed at hop
    cbfc_consumed: jax.Array   # [Q] uint32 20-bit cyclic credits consumed
    cbfc_freed: jax.Array      # [Q] uint32 20-bit cyclic credits freed
    cbfc_ret: jax.Array        # [Rd, Q] int32 credit-return delay ring
    credit_stall_ticks: jax.Array  # [] int32 ticks with >= 1 credit stall


def _first_set_bit(ring: jax.Array) -> jax.Array:
    """Per-row index of the lowest set bit of a [N, W] uint32 ring, or -1."""
    nz = ring != 0
    has = nz.any(axis=1)
    W = ring.shape[1]
    first_w = jnp.argmax(nz, axis=1)
    w = ring[jnp.arange(ring.shape[0]), first_w]
    lsb = w & (jnp.uint32(0) - w)
    ctz = pds._popcount32(lsb - jnp.uint32(1))
    return jnp.where(has, first_w * 32 + ctz, -1).astype(jnp.int32)


def _bit_plane(off: jax.Array, valid: jax.Array, w: int) -> jax.Array:
    """[F, W] uint32 plane with row i's bit `off[i]` set (elementwise —
    the dense replacement for a one-lane-per-row bit scatter)."""
    o = jnp.clip(off, 0, w * 32 - 1)
    wordsel = jnp.arange(w)[None, :] == (o // 32)[:, None]
    bit = (jnp.uint32(1) << (o % 32).astype(jnp.uint32))[:, None]
    ok = valid & (off >= 0) & (off < w * 32)
    return jnp.where(ok[:, None] & wordsel, bit, jnp.uint32(0))


def _set_own_bit(ring: jax.Array, off: jax.Array,
                 valid: jax.Array) -> jax.Array:
    """Row i sets bit off[i] — elementwise, no scatter."""
    return ring | _bit_plane(off, valid, ring.shape[1])


def _clear_own_bit(ring: jax.Array, off: jax.Array,
                   valid: jax.Array) -> jax.Array:
    """Row i clears bit off[i] — elementwise, no scatter."""
    return ring & ~_bit_plane(off, valid, ring.shape[1])


def _own_word(ring: jax.Array, off: jax.Array) -> jax.Array:
    """Row i's ring word containing bit offset off[i] (clipped)."""
    w = ring.shape[1]
    word = jnp.clip(off, 0, w * 32 - 1) // 32
    return jnp.take_along_axis(ring, word[:, None], axis=1)[:, 0]


def init_state(g: QueueGraph, wl: Workload, profile: TransportProfile,
               p: SimParams, seed: "int | jax.Array" = DEFAULT_SEED,
               link: "LinkConfig | None" = None) -> SimState:
    Q, C = g.num_queues, p.queue_capacity
    F = wl.src.shape[0]
    D = p.ack_return_ticks + 1
    E = 2 * Q + 2 * F
    W = p.mp_range // 32
    nparams = NSCCParams(base_rtt=p.base_rtt, max_cwnd=p.max_cwnd)
    cc_pol = make_cc_policy(profile.cc, nparams, p.max_cwnd)
    q_pkt = jnp.zeros((Q, C, PKT_FIELDS), jnp.int32).at[:, :, PKT_FLOW].set(-1)
    return SimState(
        q_pkt=q_pkt,
        q_head=jnp.zeros((Q,), jnp.int32),
        q_len=jnp.zeros((Q,), jnp.int32),
        next_psn=jnp.zeros((F,), jnp.int32),
        inflight=jnp.zeros((F,), jnp.int32),
        src_track=pds.PSNTracker.create(F, p.mp_range),
        rtx=jnp.zeros((F, W), jnp.uint32),
        last_progress=jnp.zeros((F,), jnp.int32),
        slot_last_ack=jnp.full((F, p.ev_slots), -1, jnp.int32),
        dst_track=pds.PSNTracker.create(F, p.mp_range),
        last_ooo_nack=jnp.full((F,), -10**6, jnp.int32),
        cc=cc_pol.create(F),
        lb=LBState.create(F, p.ev_slots, seed),
        ev_buf=jnp.zeros((D, E, EVF_FIELDS), jnp.int32),
        inc=(inc.INCState.create(F, p.inc_slots) if profile.inc
             else inc.INCState.empty()),
        delivered=jnp.zeros((F,), jnp.int32),
        trims=jnp.int32(0), drops=jnp.int32(0), dups=jnp.int32(0),
        inc_reduced=jnp.int32(0), inc_emits=jnp.int32(0),
        rod_rejects=jnp.int32(0), retransmits=jnp.int32(0),
        rto=jnp.full((F,), p.timeout_ticks, jnp.int32),
        timeouts=jnp.int32(0), ev_evictions=jnp.int32(0),
        ticks_degraded=jnp.int32(0),
        rto_strikes=jnp.zeros((F,), jnp.int32),
        quarantined=jnp.zeros((F,), jnp.bool_),
        flows_abandoned=jnp.int32(0), ticks_unreachable=jnp.int32(0),
        llr_busy_until=jnp.zeros(
            (Q if link is not None and link.llr else 0,), jnp.int32),
        llr_replays=jnp.int32(0),
        cbfc_consumed=jnp.zeros(
            (Q if link is not None and link.cbfc else 0,), jnp.uint32),
        cbfc_freed=jnp.zeros(
            (Q if link is not None and link.cbfc else 0,), jnp.uint32),
        cbfc_ret=jnp.zeros(
            ((link.credit_return_ticks, Q)
             if link is not None and link.cbfc else (0, 0)), jnp.int32),
        credit_stall_ticks=jnp.int32(0),
    )


def _rank_within(target: jax.Array, valid: jax.Array,
                 base: jax.Array) -> tuple[jax.Array, jax.Array]:
    """For candidate lanes with target queue ids, compute each lane's
    arrival rank within its target and the resulting queue position.

    Segment-count scheme: rank[i] = #{j < i : target[j] == target[i] and
    valid[j]} via a masked pairwise count — a few fused vector passes
    instead of the per-tick stable argsort the seed used (XLA sorts are
    slow on CPU and batch poorly under vmap).

    Returns (pos, rank) where pos[i] = base[target[i]] + rank.
    """
    n = target.shape[0]
    t = jnp.where(valid, target, -1)
    lane = jnp.arange(n)
    same = (t[None, :] == t[:, None]) & valid[None, :] \
        & (lane[None, :] < lane[:, None])
    rank = same.sum(axis=1, dtype=jnp.int32)
    pos = base[jnp.where(valid, target, 0)] + rank
    return pos, rank


def make_step(g: QueueGraph, profile: TransportProfile, p: SimParams, F: int,
              lossy: bool = False, tel: "TelemetrySpec | None" = None,
              hosty: bool = False, corrupty: bool = False,
              link: "LinkConfig | None" = None):
    """Build the per-tick transition function for one transport profile.

    The tick is composed from the profile's pluggable policy objects: a
    CC policy (``make_cc_policy``) hooked at the ACK/NACK/grant/gate/
    inject/timeout points, and an ``LBPolicy`` hooked at the feedback and
    EV-selection points. Delivery modes are per-flow static masks: ROD
    flows run go-back-N on one static path, gate injection on in-order
    CACK advance, and their receiver accepts only the next expected PSN;
    RUD/RUDI flows keep spray + selective-retransmit semantics.

    The returned ``step(s, tick, wl, fault)`` takes the workload and the
    per-queue fault schedule as *traced* arguments so one compiled step
    serves every scenario of a sweep (and vmaps over a scenario axis).
    ``lossy`` is the one schedule-derived STATIC: the gray-link loss
    draw (two hash rounds per enqueue lane per tick) is only compiled
    in when the dispatching schedule has a nonzero ``loss_p`` lane, so
    loss-free runs — every pre-fault-engine call site — pay nothing
    for it.

    ``tel`` (a :class:`~repro.network.telemetry.TelemetrySpec`) is the
    same kind of static: when enabled, the step additionally emits a
    ``probe`` dict in its out lanes — per-queue egress-mark / trim /
    silent-drop increments and per-flow RTT-sample and cwnd views, all
    signals the tick already computed — for the telemetry lanes riding
    the stats carry. Disabled (the default), no probe is built and the
    compiled step is bitwise the pre-telemetry one.

    ``hosty`` is the endpoint analogue of ``lossy``: the per-host
    outage/NIC-stall semantics (dead hosts stop injecting, processing
    ACKs, and absorbing deliveries; stalled hosts only stop injecting)
    are compiled in only when the dispatching schedule actually carries
    host faults, so all-healthy runs pay nothing and stay bitwise the
    pre-endpoint-fault program.

    ``corrupty`` gates the PHY-corruption draw the same way ``lossy``
    gates the gray-link draw: compiled in only when the dispatching
    schedule has a nonzero ``corrupt_p`` lane. Corruption is drawn per
    TRANSMISSION (at dequeue, one frame per queue per tick — so
    retransmitted frames re-draw) from an independent hash stream.

    ``link`` (a :class:`~repro.core.link.LinkConfig`, static like
    ``tel``) arms the link-layer reliability lanes: ``llr`` confines a
    corrupted transmission to the hop — the queue holds its head frame
    for ``llr_rtt`` ticks (link NACK turnaround + go-back-N replay) and
    then retransmits it, so delivery is delayed, never dropped; without
    it a corrupted frame is a silent end-to-end loss. ``cbfc`` puts a
    20-bit cyclic credit gate at enqueue: a candidate whose target queue
    has no credited space left is back-pressured in place (the upstream
    hop keeps its head frame, an injector waits at the NIC) instead of
    overflowing, with dequeue credits returning after
    ``credit_return_ticks``. ``link=None`` (or an off spec) compiles
    the exact pre-feature program.
    """
    tel_on = tel is not None and tel.enabled
    llr = link is not None and link.llr
    cbfc = link is not None and link.cbfc
    llr_rtt = int(link.llr_rtt) if llr else 0
    Rd = int(link.credit_return_ticks) if cbfc else 1
    MASK20 = jnp.uint32(CTR_MOD - 1)
    rt = RoutingTables(g)
    Q = g.num_queues
    C = p.queue_capacity
    D = p.ack_return_ticks + 1
    E = 2 * Q + 2 * F
    H = g.num_hosts
    K = p.ev_slots
    mp = p.mp_range
    W = mp // 32
    flow_ids = jnp.arange(F)
    nparams = NSCCParams(base_rtt=p.base_rtt, max_cwnd=p.max_cwnd)
    cc_pol = make_cc_policy(profile.cc, nparams, p.max_cwnd)
    # per-flow delivery modes are static: compiled straight into the step
    dm = profile.delivery_modes(F)
    rod_np = dm == int(DeliveryMode.ROD)
    all_rod = bool(rod_np.all())
    any_rod = bool(rod_np.any())
    mixed_rod = any_rod and not all_rod
    rod_mask = jnp.asarray(rod_np)
    # an all-ROD profile is single-path by definition (spec: ordered
    # delivery forbids spraying); mixed profiles spray the RUD lanes and
    # pin the ROD lanes to their static EV below
    lb_pol = LBPolicy(LBScheme.STATIC if all_rod else profile.lb,
                      evict_enabled=profile.ev_eviction)
    # recovery-loop statics: with the defaults (rto_backoff=1.0,
    # ev_eviction=False) every gated lane below is elided and the
    # compiled tick is exactly the pre-fault-engine one
    backoff_on = profile.rto_backoff != 1.0
    evict_on = profile.ev_eviction
    rto_cap = int(p.timeout_ticks) * int(profile.rto_max_scale)
    lane_ids = jnp.arange(Q + F, dtype=jnp.uint32)
    # PDC liveness teardown static (mirrors repro.core.pdc.unreachable):
    # off (the default) elides every quarantine lane update below.
    pdc_on = profile.pdc_dead_after > 0
    dead_after = int(profile.pdc_dead_after)
    if hosty:
        # static queue -> host map for the dead-host downlink mask (only
        # each host's final downlink is host-owned; fabric queues carry
        # -1 and never inherit a host outage)
        qh_np = np.full((Q,), -1, np.int64)
        qh_np[np.asarray(g.host_queue, np.int64)] = np.arange(H)
        q_is_host = jnp.asarray(qh_np >= 0)
        q_host = jnp.asarray(np.where(qh_np >= 0, qh_np, 0), jnp.int32)

    def step(s: SimState, tick: jax.Array, wl: Workload,
             fault: FaultSchedule):
        flow_src = wl.src
        flow_dst = wl.dst
        slot = tick % D
        # fault lanes -> this tick's dead-queue mask. The static failed=
        # mask degenerates to fail_at=0, heal_at=NEVER_TICK, making this
        # window test bitwise the old constant mask.
        dead = (fault.fail_at <= tick) & (tick < fault.heal_at)
        if hosty:
            # endpoint fault lanes: hd = dead hosts (no inject / no ACK
            # / no absorb), nic = stalled NICs (no inject only). A dead
            # host's downlink eats enqueues like a dead link — silent
            # drops, counted below — and the host's flows are frozen via
            # the per-flow masks.
            hd = (fault.host_fail_at <= tick) & (tick < fault.host_heal_at)
            nic = (fault.nic_stall_at <= tick) & (tick < fault.nic_heal_at)
            dead = dead | (q_is_host & hd[q_host])
            src_dead = hd[flow_src]            # [F] source host is dead
            dst_dead = hd[flow_dst]            # [F] destination host is dead
            # a dead destination does NOT freeze the source: it keeps
            # retransmitting into the dead downlink (silent drops) until
            # the PDC liveness teardown quarantines the flow
            inj_frozen = src_dead | nic[flow_src]

        # ------------------------------------------------ 1. control events
        evs = s.ev_buf[slot]                                  # [E, 6]
        et = evs[:, EVF_TYPE]
        ef = evs[:, EVF_FLOW]
        ep = evs[:, EVF_PSN]
        ee = evs[:, EVF_VAL]
        ec = evs[:, EVF_ECN]
        ets = evs[:, EVF_TSENT]
        is_ack = et == EV_ACK
        is_nack = (et == EV_NACK) | (et == EV_OOO)
        if hosty:
            # a dead SOURCE host processes no returning control traffic:
            # its lanes' ACKs/NACKs are lost on arrival (the events were
            # consumed from the ring, so nothing replays after heal)
            lane_src_dead = src_dead[jnp.clip(ef, 0, F - 1)]
            is_ack = is_ack & ~lane_src_dead
            is_nack = is_nack & ~lane_src_dead

        # Per-flow densification of the ACK lanes: a flow's ACKs all come
        # from its destination's single host downlink, so at most ONE ACK
        # lane per flow is active per tick. That turns every ACK-driven
        # update (SACK record, CC, LB, progress) into elementwise [F] or
        # [F, W] work — one [F, E] one-hot is the only lane-wide pass.
        # (NACK lanes stay lane-wise: several trims can hit one flow.)
        hot_ack = (ef[None, :] == flow_ids[:, None]) & is_ack[None, :]
        hot_nack = (ef[None, :] == flow_ids[:, None]) & is_nack[None, :]
        has_ack = hot_ack.any(axis=1)
        nack_count = hot_nack.sum(axis=1, dtype=jnp.int32)
        ack_psn = _pick(hot_ack, ep)

        # ACKs: record at source, advance CACK, shift the rtx ring in
        # lockstep — the fused SACK hot path (kernels/sack_fused.py).
        ack_off0 = (ack_psn.astype(jnp.uint32)
                    - s.src_track.base).astype(jnp.int32)
        ack_in_range = has_ack & (ack_off0 >= 0) & (ack_off0 < mp)
        ack_bit = jnp.uint32(1) << (ack_off0 % 32).astype(jnp.uint32)
        ack_already = ack_in_range & (
            (_own_word(s.src_track.ring, ack_off0) & ack_bit) != 0)
        ack_mask = _bit_plane(ack_off0, ack_in_range, W)
        src_ring, src_base, rtx, adv = kops.sack_fused(
            s.src_track.ring, s.src_track.base, s.rtx, ack_mask)
        one = jnp.uint32(1)
        src_track = pds.PSNTracker(
            base=src_base, ring=src_ring,
            rx_ok=s.src_track.rx_ok + jnp.where(
                ack_in_range & ~ack_already, one, 0),
            dup=s.src_track.dup + jnp.where(ack_already, one, 0),
            oor=s.src_track.oor + jnp.where(
                has_ack & ~ack_in_range, one, 0),
        )

        # retire inflight, CC + LB feedback (policy hooks over [F] lanes)
        retire = has_ack.astype(jnp.int32) + nack_count
        inflight = jnp.maximum(s.inflight - retire, 0)
        ack_ecn = _pick(hot_ack, ec).astype(jnp.bool_)
        rtt = (tick - _pick(hot_ack, ets)).astype(jnp.float32)
        cc_st = cc_pol.on_ack(s.cc, has_ack, ack_ecn, rtt)
        cc_st = cc_pol.on_nack(cc_st, nack_count)
        lbs = lb_pol.on_ack(s.lb, hot_ack, ef, ee, ec, is_ack, is_nack,
                            flow_ok=(~rod_mask) if mixed_rod else None)

        # progress clock: any ACK freshens the flow
        last_progress = jnp.where(has_ack, tick, s.last_progress)
        # per-flow RTO lane: an ACK resets backed-off timeouts to base.
        # With rto_backoff == 1.0 the lane is never mutated (constant ==
        # timeout_ticks), so every predicate on it compiles to the old
        # fixed-constant comparison.
        rto = (jnp.where(has_ack, jnp.int32(p.timeout_ticks), s.rto)
               if backoff_on else s.rto)
        if evict_on:
            # trim NACKs implicate the path EV they carry: collect one
            # per flow for the eviction hook in section 9. OOO NACKs are
            # receiver gap reports, not path evidence — excluded. ROD
            # lanes are excluded too (an ordered flow's static path must
            # not churn on congestion; it evicts on timeout instead).
            hot_tnack = hot_nack & (et == EV_NACK)[None, :]
            nack_ev = jnp.max(jnp.where(hot_tnack, ee[None, :], -1), axis=1)
            nack_evict = hot_tnack.any(axis=1)
            if any_rod:
                nack_evict = nack_evict & ~rod_mask

        # ACK'd PSNs can't be pending retransmit anymore (rtx was already
        # shifted by the fused op, so offsets are relative to the new base)
        ack_off = ack_psn - src_track.base.astype(jnp.int32)
        rtx = _clear_own_bit(rtx, ack_off, has_ack)

        # NACKs (trim / OOO): mark PSN for selective retransmit (RUD);
        # ROD does go-back-N instead (handled at injection via next_psn).
        # Several NACKs may hit one flow, so this stays lane-wise — but
        # as a dense bitwise-OR fold over the NACK-capable lanes (ACK
        # lanes [0, Q) carry NACKs only for ROD flows, which never take
        # the selective-retransmit path), not a scatter: OR is naturally
        # duplicate-safe, so no dedup or already-set pass is needed.
        nf, nep = ef[Q:], ep[Q:]
        n_nack = is_nack[Q:]
        nack_off = nep - src_track.base[jnp.where(n_nack, nf, 0)].astype(jnp.int32)
        if not all_rod:
            n_ok = n_nack & (nack_off >= 0) & (nack_off < mp)
            if mixed_rod:
                n_ok = n_ok & ~rod_mask[jnp.where(n_nack, nf, 0)]
            # duplicate-safe OR of the NACKed PSN bits into the rtx ring
            # (kernels/nack_mark.py; jnp oracle scatters one bit per lane
            # onto an [F, mp] bool plane and packs it into ring words).
            # Replaces the [F, W, E-Q] dense OR-fold — the tick's largest
            # intermediate by an order of magnitude.
            rtx = kops.nack_mark(rtx, nf, jnp.clip(nack_off, 0, mp - 1),
                                 n_ok)
        rod_gbn = hot_nack.any(axis=1)

        # EV-based loss detection (Sec. 3.2.4), RR_SLOTS layout:
        # slot i carries PSNs i, i+K, i+2K...; an ACK for PSN x implies
        # every unacked PSN x-K, x-2K... in the same slot was lost.
        slot_last_ack = s.slot_last_ack
        if profile.lb == LBScheme.RR_SLOTS and not all_rod:
            has_ack_rr = has_ack & ~rod_mask if mixed_rod else has_ack
            sl = ack_psn % K
            prev = jnp.take_along_axis(slot_last_ack, sl[:, None],
                                       axis=1)[:, 0]
            # mark up to 2 predecessors (losses per ACK are almost always <=1)
            for back in (1, 2):
                miss = ack_psn - back * K
                off = miss - src_track.base.astype(jnp.int32)
                # skip PSNs already SACKed at the source (not actually lost)
                w_i = jnp.clip(off, 0, rtx.shape[1] * 32 - 1)
                sacked = (_own_word(src_track.ring, off)
                          >> (w_i % 32).astype(jnp.uint32)) & jnp.uint32(1)
                lost = has_ack_rr & (miss > prev) & (miss >= 0) & (sacked == 0)
                rtx = _set_own_bit(rtx, off, lost)
            hot_sl = (jnp.arange(K)[None, :] == sl[:, None]) \
                & has_ack_rr[:, None]
            slot_last_ack = jnp.where(
                hot_sl, jnp.maximum(slot_last_ack, ack_psn[:, None]),
                slot_last_ack)

        # consume the slot: clear only the EVF_TYPE lane (every read of
        # the other lanes is masked by type != NONE, and the slot is
        # fully rewritten when it next comes up as out_slot) — a [E, 1]
        # dynamic-update-slice instead of the whole [E, EVF_FIELDS]
        # record, and no zeros materialized
        ev_buf = s.ev_buf.at[slot, :, EVF_TYPE].set(jnp.int32(EV_NONE))

        # ------------------------------------------- 2. RCCC receiver grants
        done = src_track.base.astype(jnp.int32) >= wl.size
        # dependency lane: flow f is eligible only once flow dep[f] has
        # completed at ITS source (CACK == size) — gated in-scan like
        # `start`, so multi-phase collectives run inside one scan. dep is
        # traced: dep = -1 everywhere reproduces the ungated schedule.
        safe_dep = jnp.where(wl.dep >= 0, wl.dep, 0)
        dep_ok = (wl.dep < 0) | done[safe_dep]
        active = ~done & (tick >= wl.start) & dep_ok
        if pdc_on:
            # a torn-down PDC holds no receiver credit claim
            active = active & ~s.quarantined
        cc_st = cc_pol.on_grant_tick(cc_st, flow_dst, active, H)

        # --------------------------------------------------- 3. injection
        has_rtx = (rtx != 0).any(axis=1)
        if all_rod:
            has_rtx = jnp.zeros((F,), jnp.bool_)
        elif mixed_rod:
            has_rtx = has_rtx & ~rod_mask
        # Shared RTO time predicate. Hoisting ONLY the clock comparison is
        # bitwise-safe for both consumers (ROD rewind here, RUD stall in
        # section 9): rewind mutates last_progress solely on ROD lanes,
        # which section 9 masks back out, and `inflight` — which injection
        # DOES mutate between the two sites — stays site-local.
        overdue = tick - last_progress > rto
        # ROD go-back-N: on NACK or timeout, rewind next_psn to base
        next_psn = s.next_psn
        timeout_rod = jnp.zeros((F,), jnp.bool_)
        if any_rod:
            timeout_rod = (inflight > 0) & overdue
            if pdc_on:
                timeout_rod = timeout_rod & ~s.quarantined
            rewind = rod_gbn | timeout_rod
            if mixed_rod:
                rewind = rewind & rod_mask
                timeout_rod = timeout_rod & rod_mask
            next_psn = jnp.where(rewind, src_track.base.astype(jnp.int32), next_psn)
            inflight = jnp.where(rewind, 0, inflight)
            last_progress = jnp.where(rewind, tick, last_progress)

        win_ok = cc_pol.on_send_gate(cc_st, inflight)
        if any_rod:
            # in-order CACK gate (ROD): the ordered window may not race
            # more than one congestion window past the cumulative ACK
            rod_win = jnp.maximum(
                jnp.floor(cc_pol.cwnd_view(cc_st, F)).astype(jnp.int32), 1)
            rod_ok = (next_psn - src_track.base.astype(jnp.int32)) < rod_win
            win_ok = win_ok & jnp.where(rod_mask, rod_ok, True)
        mp_ok = (next_psn - src_track.base.astype(jnp.int32)) < p.mp_range
        can_new = (next_psn < wl.size) & mp_ok
        eligible = (tick >= wl.start) & ~done & dep_ok & win_ok \
            & (has_rtx | can_new)
        if hosty:
            # frozen injectors: dead source hosts and stalled NICs emit
            # nothing. A stalled NIC's flows stay ACK-live and simply
            # wait; a dead host's flows decay into the timeout path.
            eligible = eligible & ~inj_frozen
        if pdc_on:
            # a quarantined flow gets no retransmit bandwidth
            eligible = eligible & ~s.quarantined

        # fair per-host pick: per-tick pseudo-random rotation, flow id in
        # the low bits so exactly one winner exists per host
        from repro.core.lb.schemes import _mix32
        rot = (_mix32(jnp.arange(F, dtype=jnp.uint32) * jnp.uint32(2654435761)
                      ^ tick.astype(jnp.uint32)) >> 16).astype(jnp.int32)
        key = rot * F + jnp.arange(F)
        key = jnp.where(eligible, key, jnp.int32(2 ** 30))
        hot_host = flow_src[None, :] == jnp.arange(H)[:, None]   # [H, F]
        host_min = jnp.min(jnp.where(hot_host, key[None, :], 2 ** 30), axis=1)
        injected = eligible & (key == host_min[flow_src]) & (key < 2 ** 30)

        rtx_off = _first_set_bit(rtx)
        rtx_psn = src_track.base.astype(jnp.int32) + rtx_off
        use_rtx = injected & has_rtx & (rtx_off >= 0)
        psn_out = jnp.where(use_rtx, rtx_psn, next_psn)

        lbs2, ev_sel = lb_pol.select(lbs, psn_out.astype(jnp.uint32), tick)
        if mixed_rod:
            # ROD lanes are pinned to their static single-path EV and do
            # not advance the spraying state
            ev_sel = jnp.where(rod_mask, lb_pol.static_ev(lbs), ev_sel)
        inj_q = rt.injection_queue(flow_src, flow_dst, ev_sel)

        def commit_injection(injected, use_rtx, rtx, next_psn, lbs,
                             inflight, cc_st):
            """Sender-state commit for this tick's injections. With CBFC
            off it runs right here (the pre-feature program); with CBFC
            on it is deferred past the section-7 credit gate, which may
            cancel injections (`stall_inj`) — a cancelled injection must
            leave NO sender-state trace, or the flow would leak PSNs and
            window."""
            rtx = _clear_own_bit(rtx, rtx_off, use_rtx)
            next_psn = jnp.where(injected & ~use_rtx, next_psn + 1,
                                 next_psn)
            commit = injected & ~rod_mask if mixed_rod else injected
            lbs = jax.tree_util.tree_map(
                lambda a, b: jnp.where(
                    commit.reshape((-1,) + (1,) * (a.ndim - 1)), b, a),
                lbs, lbs2)
            if evict_on:
                # remember each flow's most recent EV: the path a later
                # RTO expiry implicates (covers ROD lanes, whose pinned
                # EV never passes through commit_selection)
                lbs = replace(lbs, last_ev=jnp.where(
                    injected, ev_sel.astype(jnp.int32), lbs.last_ev))
            inflight = inflight + injected.astype(jnp.int32)
            cc_st = cc_pol.on_inject(cc_st, injected)
            retransmits = s.retransmits + use_rtx.sum(dtype=jnp.int32)
            return rtx, next_psn, lbs, inflight, cc_st, retransmits

        if not cbfc:
            rtx, next_psn, lbs, inflight, cc_st, retransmits = \
                commit_injection(injected, use_rtx, rtx, next_psn, lbs,
                                 inflight, cc_st)

        # ------------------------------------------------- 4. forwarding
        qidx = jnp.arange(Q)
        nonempty = s.q_len > 0
        # link-layer transmission gate: `txq` is the set of queues whose
        # head frame actually REACHES the next hop this tick, `leaves`
        # the set whose head frame leaves its queue. With the link
        # statics off both are `nonempty` and the block compiles away.
        txq = nonempty
        if llr:
            # a queue mid-replay is re-sending the corrupted window at
            # the link layer: nothing reaches the next hop until
            # `llr_busy_until` (the hop-confined go-back-N penalty)
            txq = txq & (tick >= s.llr_busy_until)
        if corrupty:
            # per-transmission BER draw hashed from (seed, tick, queue)
            # — an independent stream from the gray-link draw (distinct
            # hash constants), equally reproducible across batch/shard/
            # chunk boundaries. One frame transmits per queue per tick,
            # so one draw per queue IS per transmission — and replayed
            # or retransmitted frames re-draw: a bad cable hits those
            # too.
            uc = _mix32(_mix32(tick.astype(jnp.uint32)
                               ^ fault.seed * jnp.uint32(0x85EBCA77))
                        ^ qidx.astype(jnp.uint32) * jnp.uint32(0xC2B2AE35))
            corrupt_hit = txq & (uc < loss_threshold(fault.corrupt_p))
        else:
            corrupt_hit = jnp.zeros((Q,), jnp.bool_)
        if llr:
            # LLR confines the loss to the hop: the corrupted frame is
            # link-NACKed and the queue holds it for a go-back-N replay
            # window — delivery is DELAYED, never dropped, and nothing
            # downstream or end-to-end ever sees the corruption
            txq = txq & ~corrupt_hit
            leaves = txq
            llr_busy_until = jnp.where(
                corrupt_hit, tick + jnp.int32(llr_rtt), s.llr_busy_until)
            llr_replays = s.llr_replays + corrupt_hit.sum(dtype=jnp.int32)
            corrupt_lost = jnp.zeros((Q,), jnp.bool_)
        else:
            # no link-layer recovery: the corrupted frame was
            # transmitted and died on the wire — a silent drop charged
            # at the transmitting hop (section 7), recovered end-to-end
            # (RTO / OOO inference) exactly like a gray-link loss
            corrupt_lost = corrupt_hit
            leaves = txq
            txq = txq & ~corrupt_hit
            llr_busy_until = s.llr_busy_until
            llr_replays = s.llr_replays
        hpos = s.q_head
        head_pkt = jnp.take_along_axis(
            s.q_pkt, hpos[:, None, None], axis=1)[:, 0]        # [Q, 5]
        pf = head_pkt[:, PKT_FLOW]
        pp = head_pkt[:, PKT_PSN]
        pe = head_pkt[:, PKT_EV]
        pm = head_pkt[:, PKT_META]
        pt = head_pkt[:, PKT_TSENT]
        # egress ECN marking: queue length at departure above threshold
        mark = txq & (s.q_len > p.ecn_threshold)
        pm = jnp.where(mark, pm | META_ECN, pm)
        if not cbfc:
            # with CBFC the dequeue commit is deferred past the section-7
            # credit gate, which can hold a head frame in place
            q_head = jnp.where(leaves, (s.q_head + 1) % C, s.q_head)
            q_len = jnp.where(leaves, s.q_len - 1, s.q_len)

        safe_pf = jnp.where(nonempty, pf, 0)
        nq = rt.route_step(qidx, flow_src[safe_pf], flow_dst[safe_pf], pe)
        deliver = txq & (nq == DELIVERED)
        if hosty:
            # packets dequeued toward a dead destination vanish at the
            # dead NIC (silent drops, counted in section 7): the
            # dead-queue mask only eats ENQUEUES, so packets already
            # queued when the host died drain through here — and a dead
            # host must not ACK, so they may not count as deliveries
            dst_gone = deliver & dst_dead[safe_pf]
            deliver = deliver & ~dst_gone
        forward = txq & (nq >= 0)

        # --------------------------------------------- 5. delivery at FEPs
        dtrim = deliver & ((pm & META_TRIMMED) != 0)
        ddata = deliver & ~dtrim
        # one host downlink per destination => at most one delivery per
        # flow per tick: densify the [Q] delivery lanes to per-flow [F]
        # values and the whole receive path goes elementwise (no scatter)
        hot_d = (pf[None, :] == flow_ids[:, None]) & ddata[None, :]  # [F, Q]
        has_d = hot_d.any(axis=1)
        d_psn = _pick(hot_d, pp)
        d_off = (d_psn.astype(jnp.uint32)
                 - s.dst_track.base).astype(jnp.int32)
        d_in_range = has_d & (d_off >= 0) & (d_off < mp)
        if any_rod:
            # ROD receiver accepts only the next in-order PSN (go-back-N
            # semantics): out-of-order arrivals are discarded and NACKed
            # with the first-gap PSN so the source rewinds immediately
            rod_rej_f = d_in_range & (d_off != 0)
            if mixed_rod:
                rod_rej_f = rod_rej_f & rod_mask
            d_rec = d_in_range & ~rod_rej_f
        else:
            d_rec = d_in_range
        d_bit = jnp.uint32(1) << (d_off % 32).astype(jnp.uint32)
        d_already = d_rec & (
            (_own_word(s.dst_track.ring, d_off) & d_bit) != 0)
        fresh_f = d_rec & ~d_already
        d_ring = s.dst_track.ring | _bit_plane(d_off, d_rec, W)
        d_ring, d_base, _ = kops.sack_advance(d_ring, s.dst_track.base)
        dst_track = pds.PSNTracker(
            base=d_base, ring=d_ring,
            rx_ok=s.dst_track.rx_ok + jnp.where(fresh_f, one, 0),
            dup=s.dst_track.dup + jnp.where(d_already, one, 0),
            oor=s.dst_track.oor + jnp.where(has_d & ~d_in_range, one, 0),
        )
        if any_rod:
            dups = s.dups + (has_d & ~fresh_f & ~rod_rej_f).sum(
                dtype=jnp.int32)
            rod_rejects = s.rod_rejects + rod_rej_f.sum(dtype=jnp.int32)
        else:
            dups = s.dups + (has_d & ~fresh_f).sum(dtype=jnp.int32)
            rod_rejects = s.rod_rejects
        delivered_ctr = s.delivered + fresh_f.astype(jnp.int32)
        # RUDI lanes: idempotent ops are re-applied on duplicates (no
        # receiver dedup state needed); stats still count first copies
        hot_seen = (pf[None, :] == flow_ids[:, None]) & deliver[None, :]
        cc_st = cc_pol.on_rx_seen(cc_st, hot_seen.any(axis=1))

        # ------------------------------------- 6. OOO-count loss inference
        ooo_fire = jnp.zeros((F,), jnp.bool_)
        if p.ooo_threshold > 0:
            dist = pds.ooo_distance(dst_track)
            due = (dist > p.ooo_threshold) & (
                tick - s.last_ooo_nack > jnp.int32(p.base_rtt))
            ooo_fire = due
        last_ooo_nack = jnp.where(ooo_fire, tick, s.last_ooo_nack)

        # ---------------------------------- 6b. in-network reduction (INC)
        # Forwarded packets about to enter their destination host downlink
        # and belonging to a reduction group are offered to the ToR's
        # accumulator context: all but the bitmap-completing child are
        # absorbed (switch ACKs the source, lane leaves the enqueue set);
        # the completing child forwards as the aggregate. Static flag:
        # INC-off profiles compile the exact pre-INC tick.
        inc_st = s.inc
        inc_absorb = jnp.zeros((Q,), jnp.bool_)
        inc_emit = jnp.zeros((Q,), jnp.bool_)
        if profile.inc:
            member, grank, gsz = inc.member_ranks(
                wl.red, rt.host_leaf[flow_src] != rt.host_leaf[flow_dst],
                (~rod_mask) if any_rod else None)
            into_host = forward & (rt.stage[jnp.clip(nq, 0, Q - 1)]
                                   == jnp.int32(int(Stage.HOST))) \
                & ((pm & META_TRIMMED) == 0)
            inc_st, inc_absorb, inc_emit = inc.process(
                inc_st, lane_flow=safe_pf, lane_psn=pp,
                lane_cand=into_host, member=member, rank=grank, gsz=gsz,
                red=wl.red, has_delivery=has_d)
        inc_reduced = s.inc_reduced + inc_absorb.sum(dtype=jnp.int32)
        inc_emits = s.inc_emits + inc_emit.sum(dtype=jnp.int32)

        # ------------------------------------------------- 7. enqueue phase
        # candidates: forwarded packets (Q lanes, minus INC absorptions) +
        # injections (F lanes)
        cand_q = jnp.concatenate([jnp.where(forward & ~inc_absorb, nq, -1),
                                  jnp.where(injected, inj_q, -1)])
        cand_flow = jnp.concatenate([pf, jnp.arange(F)])
        cand_psn = jnp.concatenate([pp, psn_out])
        cand_ev = jnp.concatenate([pe, ev_sel])
        cand_meta = jnp.concatenate([pm, jnp.zeros((F,), jnp.int32)])
        cand_ts = jnp.concatenate([pt, jnp.full((F,), 1, jnp.int32) * tick])
        cvalid = cand_q >= 0
        safe_cq = jnp.where(cvalid, cand_q, 0)
        # failed links (traced window mask): packets routed into them vanish
        is_dead = dead[safe_cq] & cvalid
        cvalid = cvalid & ~is_dead
        # gray links: counter-based per-packet loss draw hashed from
        # (scenario seed, tick, enqueue lane) — stateless, so the stream
        # is reproducible across batch/shard/chunk boundaries. The draw
        # is only compiled in when the dispatching schedule has nonzero
        # loss_p (`lossy` static): loss-free runs pay nothing for it.
        if lossy:
            u = _mix32(_mix32(tick.astype(jnp.uint32)
                              ^ fault.seed * jnp.uint32(0x9E3779B1))
                       ^ lane_ids * jnp.uint32(0x85EBCA77))
            is_lost = cvalid & (u < loss_threshold(fault.loss_p)[safe_cq])
            cvalid = cvalid & ~is_lost
        else:
            is_lost = jnp.zeros_like(cvalid)
        if cbfc:
            # CBFC credit gate (repro.core.link.CBFCState semantics,
            # vectorized): available = capacity - (consumed - freed)
            # over 20-bit cyclic counters, where `freed` lags the actual
            # dequeues by the credit-return latency (the delay ring).
            # A candidate without credited space is back-pressured IN
            # PLACE: a forwarded frame never left its upstream queue
            # (that dequeue is cancelled below) and an injection waits
            # at the NIC with zero sender-state trace (the deferred
            # commit_injection). Nothing overflows, so a CBFC fabric
            # never trims for lack of buffer. Deliveries, INC
            # absorptions, and dead/gray-eaten candidates are not
            # enqueues and bypass the gate — no credit leak, and the
            # sink hop always drains, so credits always return (the
            # fabric is a DAG: no credit deadlock).
            arriving = s.cbfc_ret[tick % Rd]
            freed_now = (s.cbfc_freed + arriving.astype(jnp.uint32)) \
                & MASK20
            avail = jnp.int32(C) - ((s.cbfc_consumed - freed_now)
                                    & MASK20).astype(jnp.int32)
            # arrival rank within the target queue: candidates past the
            # credited space stall. Freed credits lag dequeues, so
            # credit-occupancy >= true occupancy and survivors always
            # fit (`fits` below stays all-true under CBFC). Stalled
            # lanes are the per-target rank suffix, so survivor ranks —
            # and hence enqueue positions — are unchanged.
            _, crank = _rank_within(cand_q, cvalid,
                                    jnp.zeros((Q,), jnp.int32))
            stall = cvalid & (crank >= avail[safe_cq])
            cvalid = cvalid & ~stall
            stall_fwd = stall[:Q]
            stall_inj = stall[Q:]
            dequeued = leaves & ~stall_fwd
            q_head = jnp.where(dequeued, (s.q_head + 1) % C, s.q_head)
            q_len = jnp.where(dequeued, s.q_len - 1, s.q_len)
            injected = injected & ~stall_inj
            use_rtx = use_rtx & ~stall_inj
            rtx, next_psn, lbs, inflight, cc_st, retransmits = \
                commit_injection(injected, use_rtx, rtx, next_psn, lbs,
                                 inflight, cc_st)
            credit_stall_ticks = s.credit_stall_ticks \
                + stall.any().astype(jnp.int32)
        else:
            dequeued = leaves
            credit_stall_ticks = s.credit_stall_ticks
        pos, _ = _rank_within(cand_q, cvalid, q_len)
        fits = cvalid & (pos < C)
        overflow = cvalid & ~fits

        wslot = (q_head[jnp.where(cvalid, cand_q, 0)] + pos) % C
        tq = jnp.where(fits, cand_q, Q)
        cand_pkt = jnp.stack(
            [cand_flow, cand_psn, cand_ev, cand_meta, cand_ts], axis=-1)
        q_pkt = s.q_pkt.at[tq, wslot].set(cand_pkt, mode="drop")
        hot_enq = (cand_q[None, :] == qidx[:, None]) & fits[None, :]  # [Q, n]
        added = hot_enq.sum(axis=1, dtype=jnp.int32)
        q_len = q_len + added
        if cbfc:
            # commit the cyclic counters: enqueues consume, this tick's
            # dequeues become the credit-update message that reaches the
            # senders `credit_return_ticks` later (the slot just read as
            # `arriving` is exactly Rd ticks old — overwrite it)
            cbfc_consumed = (s.cbfc_consumed + added.astype(jnp.uint32)) \
                & MASK20
            cbfc_freed = freed_now
            cbfc_ret = s.cbfc_ret.at[tick % Rd].set(
                dequeued.astype(jnp.int32))
        else:
            cbfc_consumed = s.cbfc_consumed
            cbfc_freed = s.cbfc_freed
            cbfc_ret = s.cbfc_ret

        # overflow: trim (fast NACK via control TC) or drop
        if p.trimming:
            trims = s.trims + overflow.sum(dtype=jnp.int32)
            drops = s.drops
            nack_mask = overflow
        else:
            trims = s.trims
            drops = s.drops + overflow.sum(dtype=jnp.int32)
            nack_mask = jnp.zeros_like(overflow)
        # failed + gray links drop silently: no trim header, no NACK —
        # only timeout / EV-based inference recovers (Sec. 3.2.4 config
        # and corruption drops)
        drops = drops + is_dead.sum(dtype=jnp.int32) \
            + is_lost.sum(dtype=jnp.int32)
        if corrupty and not llr:
            # corruption without link-layer recovery is a silent drop,
            # charged at the transmitting hop (disjoint from the
            # enqueue-side dead/gray counts above)
            drops = drops + corrupt_lost.sum(dtype=jnp.int32)
        if hosty:
            # dequeue-time losses at a dead destination NIC (section 5)
            drops = drops + dst_gone.sum(dtype=jnp.int32)

        # ------------------------------------------- 8. schedule control TC
        out_slot = (tick + p.ack_return_ticks) % D
        # lanes [0, Q): ACKs from deliveries and from INC absorptions
        # (the switch ACKs an absorbed child exactly like a delivery
        # would; disjoint from ddata — an absorbed packet never reached
        # the downlink). ROD rejects become OOO NACKs carrying the
        # receiver's first-gap PSN.
        ack_like = ddata | inc_absorb
        if any_rod:
            rod_rej_lane = ddata & rod_rej_f[safe_pf]
            ack_lane_t = jnp.where(
                rod_rej_lane, EV_OOO,
                jnp.where(ack_like, EV_ACK, EV_NONE))
            ack_lane_psn = jnp.where(
                rod_rej_lane,
                dst_track.base[safe_pf].astype(jnp.int32), pp)
        else:
            ack_lane_t = jnp.where(ack_like, EV_ACK, EV_NONE)
            ack_lane_psn = pp
        # lanes [Q, Q + (Q+F)): trim NACKs from enqueue overflow
        nack_lane_t = jnp.where(nack_mask, EV_NACK, EV_NONE)
        # lanes [2Q+F, 2Q+2F): OOO NACKs (psn = receiver base = first gap)
        ooo_lane_t = jnp.where(ooo_fire, EV_OOO, EV_NONE)
        new_type = jnp.concatenate([ack_lane_t, nack_lane_t, ooo_lane_t])
        new_flow = jnp.concatenate([safe_pf, cand_flow, jnp.arange(F)])
        new_psn = jnp.concatenate(
            [ack_lane_psn, cand_psn, dst_track.base.astype(jnp.int32)])
        new_val = jnp.concatenate([pe, cand_ev, jnp.zeros((F,), jnp.int32)])
        new_ecn = jnp.concatenate(
            [((pm & META_ECN) != 0).astype(jnp.int32),
             jnp.zeros((Q + F,), jnp.int32), jnp.zeros((F,), jnp.int32)])
        new_ts = jnp.concatenate([pt, cand_ts, jnp.zeros((F,), jnp.int32)])
        ev_buf = ev_buf.at[out_slot].set(jnp.stack(
            [new_type, new_flow, new_psn, new_val, new_ecn, new_ts],
            axis=-1))

        # ------------------------------------------------- 9. timeouts + QA
        timeout_fire = timeout_rod  # ROD rewinds already counted as expiries
        if not all_rod:
            # A flow needs the RTO not only while packets are (believed)
            # in flight but whenever sent PSNs are unacked with nothing
            # left to trigger recovery: after a silent loss (dead/gray
            # link) the last ACK can drain `inflight` to 0 with gaps
            # still open, no rtx pending and next_psn == size — without
            # the `unacked` term the flow deadlocks there forever (the
            # terminal phase of every flap scenario).
            unacked = src_track.base.astype(jnp.int32) < next_psn
            stalled = ((inflight > 0) | unacked) & overdue & ~done
            if hosty:
                # a dead endpoint is itself a stall trigger: a frozen
                # source never sends, so `unacked` can't arm — yet the
                # flow can only end via liveness teardown. Keep its RTO
                # clock running so strikes accrue and quarantine fires.
                # (NIC stalls are excluded on purpose: the host is
                # ACK-live, the flow just waits for the heal.)
                stalled = stalled | ((src_dead | dst_dead)
                                     & overdue & ~done)
            if pdc_on:
                # a torn-down PDC stops timing out (and stops striking)
                stalled = stalled & ~s.quarantined
            if mixed_rod:
                stalled = stalled & ~rod_mask  # ROD timeouts rewind instead
            rtx = _set_own_bit(rtx, jnp.zeros((F,), jnp.int32),
                               stalled)  # offset 0 == oldest unacked PSN
            # a timeout implies the outstanding packets are gone (dropped
            # without trim); reset the inflight estimate so the window
            # reopens — otherwise non-trimmed drops leak inflight forever.
            inflight = jnp.where(stalled, 0, inflight)
            last_progress = jnp.where(stalled, tick, last_progress)
            cc_st = cc_pol.on_timeout(cc_st, stalled)
            timeout_fire = timeout_fire | stalled
        cc_st = cc_pol.end_of_tick(cc_st, tick)

        # ---------------------------------------- 10. recovery loop lanes
        # (both arms statically gated: default profiles compile the exact
        # pre-fault-engine tick)
        if backoff_on:
            # exponential RTO backoff on expiry, capped: under a long
            # outage repeated timeouts space out instead of hammering the
            # dead window; any ACK resets to base (section 1).
            rto = jnp.where(
                timeout_fire,
                jnp.minimum(
                    (rto.astype(jnp.float32)
                     * jnp.float32(profile.rto_backoff)).astype(jnp.int32),
                    jnp.int32(rto_cap)),
                rto)
        if evict_on:
            # close the loop: a trim NACK implicates the exact EV it
            # carries (any scheme); an RTO expiry implicates the flow's
            # last-used EV — exact ONLY where selection is pinned
            # (STATIC scheme, incl. the all-ROD pin, and ROD lanes of
            # mixed profiles). Sprayed lanes take no timeout eviction:
            # `last_ev` there is just the most recent random draw, so
            # the guess mostly blacklists healthy EVs and tombstones
            # REPS's known-good recycle ring (observed strictly worse
            # than no eviction on a half-dead fabric) — and spraying
            # escapes dead paths by construction anyway.
            if lb_pol.scheme == LBScheme.STATIC:
                timeout_evict = timeout_fire
            elif mixed_rod:
                timeout_evict = timeout_fire & rod_mask
            else:
                timeout_evict = jnp.zeros((F,), jnp.bool_)
            evict_ev = jnp.where(nack_evict, nack_ev, lbs.last_ev)
            evict_valid = (nack_evict | timeout_evict) & (evict_ev >= 0)
            lbs = lb_pol.evict(lbs, evict_ev, evict_valid)
            ev_evictions = s.ev_evictions + evict_valid.sum(dtype=jnp.int32)
        else:
            ev_evictions = s.ev_evictions
        timeouts = s.timeouts + timeout_fire.sum(dtype=jnp.int32)
        ticks_degraded = s.ticks_degraded + dead.any().astype(jnp.int32)
        if pdc_on:
            # PDC liveness teardown (the fabric-engine mirror of
            # repro.core.pdc.unreachable / InitEvent.PEER_DEAD):
            # consecutive zero-progress RTO expiries accumulate strikes;
            # any ACK is forward progress and resets the count. At
            # `pdc_dead_after` strikes the peer is declared dead and the
            # flow quarantined — no retransmit bandwidth (section 3), no
            # further expiries (section 9), and the quiescence predicate
            # counts it as settled, so permanent endpoint death
            # terminates the run early. A quarantined flow can never
            # complete, so its dependents can never start: collapse the
            # dependency chain (one hop per tick) so those scenarios
            # terminate too.
            rto_strikes = (jnp.where(has_ack, 0, s.rto_strikes)
                           + timeout_fire.astype(jnp.int32))
            newly = (~s.quarantined & ~done
                     & pdc_fsm.unreachable(rto_strikes, dead_after))
            newly = newly | (~s.quarantined & ~done & (wl.dep >= 0)
                             & s.quarantined[safe_dep])
            quarantined = s.quarantined | newly
            inflight = jnp.where(quarantined, 0, inflight)
            flows_abandoned = s.flows_abandoned \
                + newly.sum(dtype=jnp.int32)
            ticks_unreachable = s.ticks_unreachable \
                + quarantined.any().astype(jnp.int32)
        else:
            rto_strikes = s.rto_strikes
            quarantined = s.quarantined
            flows_abandoned = s.flows_abandoned
            ticks_unreachable = s.ticks_unreachable

        ns = SimState(
            q_pkt=q_pkt, q_head=q_head, q_len=q_len,
            next_psn=next_psn, inflight=inflight, src_track=src_track,
            rtx=rtx, last_progress=last_progress, slot_last_ack=slot_last_ack,
            dst_track=dst_track, last_ooo_nack=last_ooo_nack,
            cc=cc_st, lb=lbs,
            ev_buf=ev_buf, inc=inc_st,
            delivered=delivered_ctr, trims=trims, drops=drops, dups=dups,
            inc_reduced=inc_reduced, inc_emits=inc_emits,
            rod_rejects=rod_rejects, retransmits=retransmits,
            rto=rto, timeouts=timeouts, ev_evictions=ev_evictions,
            ticks_degraded=ticks_degraded,
            rto_strikes=rto_strikes, quarantined=quarantined,
            flows_abandoned=flows_abandoned,
            ticks_unreachable=ticks_unreachable,
            llr_busy_until=llr_busy_until, llr_replays=llr_replays,
            cbfc_consumed=cbfc_consumed, cbfc_freed=cbfc_freed,
            cbfc_ret=cbfc_ret, credit_stall_ticks=credit_stall_ticks,
        )
        out = {
            "delivered": fresh_f.astype(jnp.int32),
            "cwnd": cc_pol.cwnd_view(cc_st, F),
            "qlen_max": q_len.max(),
            "rx_base": dst_track.base,
            "src_base": src_track.base,
        }
        if tel_on:
            # telemetry probe: per-queue event increments off signals
            # the tick already computed. Trim vs silent-drop follows the
            # transport's own split (no-trim profiles drop overflow);
            # dead/gray losses are silent drops by definition. safe_cq
            # holds each event lane's target queue (events are subsets
            # of the pre-filter candidate set).
            if p.trimming:
                trim_ev, drop_ev = overflow, is_dead | is_lost
            else:
                trim_ev = jnp.zeros_like(overflow)
                drop_ev = is_dead | is_lost | overflow
            hot_cand = safe_cq[None, :] == qidx[:, None]       # [Q, Q+F]
            drop_q = (hot_cand & drop_ev[None, :]).sum(
                axis=1, dtype=jnp.int32)
            if corrupty and not llr:
                # unrecovered corruption drops are charged at the
                # TRANSMITTING queue (the loss is on its egress wire)
                drop_q = drop_q + corrupt_lost.astype(jnp.int32)
            out["probe"] = {
                "mark": mark.astype(jnp.int32),
                "trim": (hot_cand & trim_ev[None, :]).sum(
                    axis=1, dtype=jnp.int32),
                "drop": drop_q,
                "rtt": rtt, "has_rtt": has_ack, "cwnd": out["cwnd"],
                # link-layer channels: per-queue LLR replays fired and
                # per-target-queue credit stalls this tick (all-zero
                # lanes when the respective spec is off)
                "llr": (corrupt_hit.astype(jnp.int32) if llr
                        else jnp.zeros((Q,), jnp.int32)),
                "stall": ((hot_cand & stall[None, :]).sum(
                    axis=1, dtype=jnp.int32) if cbfc
                    else jnp.zeros((Q,), jnp.int32)),
            }
        return ns, out

    return step


@dataclass(frozen=True)
class SimResult:
    """One scenario's outcome, in one of two trace tiers.

    ``trace="stats"`` (the default) carries only streaming statistics
    computed inside the scan — per-flow completion ticks, the delivered
    count over one pre-registered goodput window, and the peak queue
    length. Memory traffic is independent of the horizon. The dense
    per-tick lanes are ``None``.

    ``trace="full"`` additionally carries the dense per-tick lanes
    (``delivered_per_tick`` etc.), chunk-buffered on device and
    concatenated on the host — exactly the pre-chunking ``SimResult``.

    ``horizon`` is the number of ticks actually executed: the run exits
    at the first chunk boundary at which the scenario is quiescent (all
    sources CACK-complete, nothing inflight, queues and control-event
    buffers drained), clamped to ``max_ticks`` (the requested budget).
    Every tick past the horizon is provably a protocol no-op, so
    windowed statistics treat missing ticks as zero-delivery — the
    values equal a fixed-``max_ticks`` run bit for bit.

    ``telemetry`` is a :class:`~repro.network.telemetry.FabricTrace`
    when the run was dispatched with ``telemetry=TelemetrySpec.on(...)``
    (``trace="stats"`` only), else ``None``.

    Scalar stat counters (streamed in both trace tiers; each also a
    property here):

    ==================  ====================================================
    property            counts
    ==================  ====================================================
    ``trims``           packets trimmed on queue overflow (fast NACK sent)
    ``drops``           silent drops: dead-link, gray-link, and no-trim
                        overflow losses (no NACK — timeout/OOO recovery)
    ``dups``            duplicate deliveries discarded at the receiver
    ``timeouts``        RTO expiries (RUD stalls + ROD timeout rewinds)
    ``rtx_packets``     retransmitted packets injected
    ``ev_evictions``    path (EV) evictions by the recovery loop
    ``ticks_degraded``  executed ticks with at least one dead link/host
    ``flows_abandoned`` PDCs declared unreachable and torn down
    ``ticks_unreachable``  executed ticks with >= 1 quarantined flow
    ``llr_replays``     frames corrupted on a BER lane and replayed at the
                        hop by LLR (``link=LinkConfig(llr=True)``)
    ``credit_stall_ticks``  executed ticks with >= 1 enqueue back-pressured
                        by CBFC credit exhaustion (``cbfc=True``)
    ==================  ====================================================
    """

    state: SimState
    msg_size: np.ndarray            # [F] message sizes (packets)
    #: ticks actually executed (chunk-aligned early exit; <= max_ticks)
    horizon: int
    #: the requested tick budget (``max_ticks`` arg / ``SimParams.ticks``)
    max_ticks: int
    trace: str = "full"
    # ---- dense lanes (trace="full"; [horizon, ...] on the tick axis) ----
    delivered_per_tick: "np.ndarray | None" = None  # [H, F]
    cwnd_per_tick: "np.ndarray | None" = None       # [H, F]
    qlen_max: "np.ndarray | None" = None            # [H]
    rx_base_per_tick: "np.ndarray | None" = None    # [H, F] receiver CACK
    src_base_per_tick: "np.ndarray | None" = None   # [H, F] source CACK
    # ---- streaming stat lanes (trace="stats") ---------------------------
    stat_completion: "np.ndarray | None" = None      # [F] tick or -1
    stat_src_completion: "np.ndarray | None" = None  # [F] tick or -1
    stat_win_delivered: "np.ndarray | None" = None   # [F] packets in window
    goodput_window: "tuple[int, int] | None" = None
    qlen_peak: "int | None" = None
    #: first tick any PDC teardown fired (-1 = none; stats tier only)
    stat_abandon_tick: "int | None" = None
    #: reconstructed probe-lane time series (telemetry=TelemetrySpec.on())
    telemetry: "telem.FabricTrace | None" = None

    def completion_ticks(self) -> np.ndarray:
        """Per-flow first tick by which the full message was delivered
        (-1 where the flow did not finish within the run).

        Completion means the message SIZE was reached — a run that ends
        mid-transfer reports -1, it does not silently count the last
        delivery as "done" (the pre-profile API's bug)."""
        if self.trace == "stats":
            return self.stat_completion.copy()
        cum = self.delivered_per_tick.cumsum(axis=0)
        reached = cum >= self.msg_size[None, :]
        return np.where(reached.any(0), reached.argmax(axis=0), -1)

    def completion_tick(self) -> int:
        """Tick by which EVERY flow completed, as a plain int; -1 if any
        flow was still unfinished when the run ended."""
        ct = self.completion_ticks()
        return -1 if bool((ct < 0).any()) else int(ct.max())

    def source_completion_ticks(self) -> np.ndarray:
        """Per-flow first tick at which the SOURCE saw its whole message
        acknowledged (CACK == size; -1 = unfinished). This is the
        completion notion the dependency lane gates on, and the right
        one under INC, where switch-absorbed packets are ACKed to the
        source but never surface at the receiver."""
        if self.trace == "stats":
            return self.stat_src_completion.copy()
        reached = (self.src_base_per_tick.astype(np.int64)
                   >= self.msg_size[None, :].astype(np.int64))
        return np.where(reached.any(0), reached.argmax(axis=0), -1)

    def source_completion_tick(self) -> int:
        """Tick by which every flow source-completed; -1 if any didn't."""
        ct = self.source_completion_ticks()
        return -1 if bool((ct < 0).any()) else int(ct.max())

    def goodput(self, window: "tuple[int, int] | None" = None) -> np.ndarray:
        """Per-flow delivered packets / tick over a window (fraction of
        line rate, since line rate == 1 packet/tick).

        The window is in budget coordinates: ``[w0, min(w1, max_ticks))``.
        Ticks past the early-exit ``horizon`` count as zero delivery
        (post-quiescence ticks deliver nothing by construction), so the
        value is identical to a fixed-``max_ticks`` run's. Windows that
        start at or past the budget select no ticks and raise.

        ``trace="stats"`` results answer only ``window=None`` (the whole
        budget) or the window pre-registered via ``goodput_window=`` at
        ``simulate()`` time; anything else needs ``trace="full"``.
        """
        mt = self.max_ticks
        w0, w1 = (0, mt) if window is None else window
        w1 = min(int(w1), mt)
        w0 = int(w0)
        if w0 < 0 or w1 <= w0:
            raise ValueError(
                f"goodput window {window!r} selects no ticks within the "
                f"{mt}-tick budget")
        if self.trace == "stats":
            if window is None:
                return np.asarray(self.state.delivered) / float(mt)
            if (self.goodput_window is not None
                    and tuple(int(w) for w in window)
                    == tuple(int(w) for w in self.goodput_window)):
                return self.stat_win_delivered / float(w1 - w0)
            raise ValueError(
                f"trace='stats' recorded only the pre-registered goodput "
                f"window {self.goodput_window!r}; pass goodput_window="
                f"{tuple(window)!r} to simulate()/simulate_batch() or use "
                f"trace='full' for arbitrary windows")
        d = self.delivered_per_tick[w0:min(w1, self.horizon)]
        return d.sum(axis=0) / float(w1 - w0)

    # ---- scalar stat counters (streamed in both trace tiers; see the
    # ---- class docstring table) -----------------------------------------
    @property
    def trims(self) -> int:
        """Packets trimmed on queue overflow (each sent a fast NACK)."""
        return int(self.state.trims)

    @property
    def drops(self) -> int:
        """Silent drops — dead-link, gray-link, and (no-trim profiles)
        overflow losses. No NACK: only timeout/OOO inference recovers."""
        return int(self.state.drops)

    @property
    def dups(self) -> int:
        """Duplicate deliveries discarded at the receiver."""
        return int(self.state.dups)

    @property
    def timeouts(self) -> int:
        """RTO expiries over the run (RUD stalls + ROD timeout rewinds)."""
        return int(self.state.timeouts)

    @property
    def rtx_packets(self) -> int:
        """Retransmitted packets injected over the run."""
        return int(self.state.retransmits)

    @property
    def ev_evictions(self) -> int:
        """Path (EV) evictions performed by the recovery loop (0 unless
        ``TransportProfile.ev_eviction`` is on)."""
        return int(self.state.ev_evictions)

    @property
    def ticks_degraded(self) -> int:
        """Executed ticks during which at least one link or host was
        dead."""
        return int(self.state.ticks_degraded)

    @property
    def flows_abandoned(self) -> int:
        """Flows whose PDC was declared unreachable and torn down (0
        unless ``TransportProfile.pdc_dead_after`` is set)."""
        return int(self.state.flows_abandoned)

    @property
    def ticks_unreachable(self) -> int:
        """Executed ticks during which at least one flow sat
        quarantined (the unavailability window a recovery controller
        would observe)."""
        return int(self.state.ticks_unreachable)

    @property
    def llr_replays(self) -> int:
        """Frames corrupted on a BER lane (``FaultSchedule.corrupt``)
        and replayed at the hop by link-level retry — each one a loss
        that never reached end-to-end recovery (0 unless the run was
        dispatched with ``link=LinkConfig(llr=True)``)."""
        return int(self.state.llr_replays)

    @property
    def credit_stall_ticks(self) -> int:
        """Executed ticks on which at least one enqueue was
        back-pressured by CBFC credit exhaustion instead of overflowing
        (0 unless ``link=LinkConfig(cbfc=True)``)."""
        return int(self.state.credit_stall_ticks)

    @property
    def abandon_tick(self) -> int:
        """First tick at which any PDC teardown fired (-1 = none).
        Streamed on the ``trace="stats"`` tier — the detection-time
        signal the recovery-pricing path converts to seconds."""
        if self.stat_abandon_tick is None:
            raise ValueError(
                "abandon_tick is streamed on the trace='stats' tier "
                "only; rerun with trace='stats'")
        return int(self.stat_abandon_tick)


# --------------------------------------------------------------------------
# scenario engine: chunked while-scan driver + compiled-run cache
# --------------------------------------------------------------------------

TRACE_MODES = ("stats", "full")


def _quiescent(s: SimState, wl: Workload) -> jax.Array:
    """Scenario-wide quiescence: no future tick can make protocol
    progress. Requires every source CACK-complete, nothing inflight, all
    queues empty, and the control-TC delay ring free of pending events.
    (Flows that never became eligible — future ``start``, unsatisfied
    ``dep`` — keep ``done`` false, so such scenarios run to the budget.)

    Post-quiescence ticks still mutate tick-stamped bookkeeping (CC
    epoch state, stale control-ring timestamp lanes), so the engine
    FREEZES the carry once a scenario is quiescent: the executed prefix,
    final counters, and completion ticks are bitwise what a longer fixed
    run would produce.

    A quarantined flow (PDC liveness teardown, `pdc_dead_after`) counts
    as settled: it can make no further progress by construction, so a
    permanently dead endpoint no longer pins the scenario to the full
    tick budget. (With the lane all-False — every default — the
    predicate is value-identical to the pre-quarantine one.)"""
    done = ((s.src_track.base.astype(jnp.int32) >= wl.size)
            | s.quarantined).all()
    idle = (s.inflight == 0).all() & (s.q_len == 0).all()
    drained = (s.ev_buf[:, :, EVF_TYPE] == EV_NONE).all()
    return done & idle & drained


def _freeze(run, new, old):
    """Carry-wide select: keep `new` where `run` is set. `run` is a
    scalar (serial driver) or a per-lane [B] vector (the hand-batched
    driver), broadcast against each leaf's trailing axes."""
    def sel(a, b):
        r = run.reshape(run.shape + (1,) * (a.ndim - run.ndim))
        return jnp.where(r, a, b)
    return jax.tree_util.tree_map(sel, new, old)


def _stats_init(F: int) -> dict:
    return {
        "comp": jnp.full((F,), -1, jnp.int32),
        "src_comp": jnp.full((F,), -1, jnp.int32),
        "win_delivered": jnp.zeros((F,), jnp.int32),
        "qlen_peak": jnp.int32(0),
        "abandon_tick": jnp.int32(-1),
    }


def _stats_update(st: dict, prev: SimState, s: SimState, wl: Workload,
                  tick, w0, w1) -> dict:
    """In-scan streaming statistics — the trace="stats" lanes. Each is
    an elementwise [F] update off state the tick already computed, so
    recording costs no extra memory traffic on the horizon axis."""
    fresh = s.delivered - prev.delivered
    inwin = (tick >= w0) & (tick < w1)
    rx_done = s.delivered >= wl.size
    src_done = s.src_track.base.astype(jnp.int32) >= wl.size
    return {
        "comp": jnp.where((st["comp"] < 0) & rx_done, tick, st["comp"]),
        "src_comp": jnp.where((st["src_comp"] < 0) & src_done, tick,
                              st["src_comp"]),
        "win_delivered": st["win_delivered"] + jnp.where(inwin, fresh, 0),
        "qlen_peak": jnp.maximum(st["qlen_peak"], s.q_len.max()),
        # first tick any PDC teardown fired — the recovery-pricing
        # detection-time signal (-1 = no abandonment this run)
        "abandon_tick": jnp.where(
            (st["abandon_tick"] < 0) & (s.flows_abandoned > 0),
            tick, st["abandon_tick"]),
    }


#: compiled run cache. Keyed on (topology identity, profile, params
#: minus the horizon, flow count, batch mode, trace tier): workloads,
#: seeds, failure masks AND the tick budget are traced, so scenario
#: sweeps at any horizon reuse one executable; profiles are static and
#: pick the executable. `id(g)` is part of the key because the compiled
#: step bakes in g's wiring tables — two graphs sharing a name must not
#: share an executable. (The cached closure keeps `g` alive via its
#: RoutingTables, so a live entry's id can't be recycled by a different
#: graph.)
_RUN_CACHE: dict = {}


def _cache_key(g: QueueGraph, profile: TransportProfile, p: SimParams,
               F: int, batched: bool, trace: str = "stats", shard=None,
               lossy: bool = False, tel: "TelemetrySpec | None" = None,
               hosty: bool = False, corrupty: bool = False,
               link: "LinkConfig | None" = None):
    # the horizon (p.ticks) is a traced bound, not a compiled constant:
    # strip it so one executable serves every tick budget. `shard` is
    # None (unsharded) or the device-id tuple a sharded executable was
    # built for (repro.network.shard). `lossy` selects the executable
    # with the gray-link loss draw compiled in (see make_step). `tel`
    # (a TelemetrySpec, static like the profile) selects the executable
    # with the probe lanes compiled in; None and the off spec share the
    # pre-telemetry entry.
    # `hosty` selects the executable with the endpoint-fault lanes
    # compiled in (host/NIC outage windows; see make_step) — schedules
    # without host lanes share the pre-endpoint entry.
    # `corrupty` (schedule-derived, like lossy/hosty) selects the
    # executable with the PHY-corruption draw compiled in; `link` (a
    # LinkConfig, user-static like tel) selects the one with the
    # LLR/CBFC lanes armed — None and the off spec share the
    # pre-link-layer entry.
    if tel is not None and not tel.enabled:
        tel = None
    if link is not None and not link.enabled:
        link = None
    return (id(g), g.name, profile, replace(p, ticks=0), F, batched, trace,
            shard, lossy, tel, hosty, corrupty, link)


def _build_fns(g: QueueGraph, profile: TransportProfile, p: SimParams,
               F: int, batched: bool, trace: str, lossy: bool = False,
               tel: "TelemetrySpec | None" = None, hosty: bool = False,
               corrupty: bool = False,
               link: "LinkConfig | None" = None):
    """(init, run) pair for one trace tier — UN-jitted, so the sharded
    engine (repro.network.shard) can wrap the same driver in shard_map
    before compiling. `_get_fns` jits and caches; behavior contract:

    ``trace="stats"`` builds the whole adaptive-horizon run as ONE
    device program: a ``lax.while_loop`` whose body scans a
    ``chunk_ticks``-long chunk (streaming the stat lanes in the scan
    carry) and whose predicate stops once every lane is quiescent or at
    the (traced) budget.

    ``trace="full"`` builds ONE CHUNK (scan + per-tick out lanes +
    quiescence flag, time-major: ``[chunk, B?, ...]``); the host drives
    the chunk loop and concatenates the buffered lanes.

    Batching is by hand — the scenario axis is an explicit leading [B]
    axis (the per-tick step/stat/quiescence functions are vmapped, the
    chunk loop is written once over lane vectors) rather than a vmap of
    the whole driver. That keeps the chunk dispatch a SCALAR decision,
    which buys the driver fast path: whenever no lane is frozen and the
    chunk lies strictly below the budget (every chunk of a
    never-quiescing sweep except a non-multiple remainder), a
    ``lax.cond`` runs a select-free tick body — bitwise identical to
    the masked body, whose selects all have a true predicate there —
    and the carry-wide freeze/budget selects are paid only by the
    residual chunks that can actually need them. Per-lane trajectories
    are unchanged: a stopped lane is frozen at its own chunk boundary,
    and a partial final chunk cannot overrun the budget.
    """
    tel_on = tel is not None and tel.enabled
    if tel_on and trace != "stats":
        raise ValueError(
            "telemetry lanes ride the streaming stats carry — enabled "
            "TelemetrySpec requires trace='stats' (the full tier already "
            "records dense per-tick lanes)")
    step = make_step(g, profile, p, F, lossy, tel if tel_on else None,
                     hosty=hosty, corrupty=corrupty, link=link)
    chunk = int(p.chunk_ticks)
    if chunk < 1:
        raise ValueError(f"chunk_ticks must be >= 1, got {chunk}")
    xs = jnp.arange(chunk, dtype=jnp.int32)

    def init_one(wl, seed):
        return init_state(g, wl, profile, p, seed, link=link)

    # the stat transition with the telemetry lanes riding inside it:
    # st["tel"] carries the probe rings (see repro.network.telemetry).
    # Off (the default), the wrapper ignores the step's out dict and the
    # carry/stat tree — and therefore the compiled program — is exactly
    # the pre-telemetry one.
    if tel_on:
        tel_up = telem.make_update(tel, g.num_queues, F)

        def stat_one(st, prev, s, wl, tick, w0, w1, out):
            nst = _stats_update(st, prev, s, wl, tick, w0, w1)
            nst["tel"] = tel_up(st["tel"], s, out["probe"], tick)
            return nst
    else:
        def stat_one(st, prev, s, wl, tick, w0, w1, out):
            del out
            return _stats_update(st, prev, s, wl, tick, w0, w1)

    def stats_init():
        st = _stats_init(F)
        if tel_on:
            st["tel"] = telem.create(tel, g.num_queues, F)
        return st

    if batched:
        init_fn = jax.vmap(init_one)
        stepf = jax.vmap(step, in_axes=(0, None, 0, 0))
        quiet = jax.vmap(_quiescent)
        statf = jax.vmap(stat_one,
                         in_axes=(0, 0, 0, 0, None, None, None, 0))
    else:
        init_fn, stepf, quiet, statf = (init_one, step, _quiescent,
                                        stat_one)

    if trace == "stats":
        def run(s0, wl, fault, budget, w0, w1):
            bshape = wl.src.shape[:-1]          # () serial, (B,) batched

            def chunk_scan(s, st, tick0, stop):
                # ONE tick body serves both cond branches, so the
                # fast-path contract (fast == masked with all-true
                # predicates; where(True, a, b) == a, bitwise) lives
                # in one place: `stop=None` builds the select-free fast
                # body, a lane vector builds the masked residual body
                # (select against the budget + per-lane freeze flags —
                # the only carry leaves selected are the ones that can
                # change, SimState + stat lanes).
                def tick_body(c, i):
                    s, st = c
                    tick = tick0 + i
                    ns, out = stepf(s, tick, wl, fault)
                    nst = statf(st, s, ns, wl, tick, w0, w1, out)
                    if stop is None:
                        return (ns, nst), None
                    live = (tick < budget) & ~stop
                    return _freeze(live, (ns, nst), (s, st)), None

                (s, st), _ = jax.lax.scan(tick_body, (s, st), xs)
                return s, st

            def fast_chunk(ops):
                s, st, tick0, _ = ops
                return chunk_scan(s, st, tick0, None)

            def masked_chunk(ops):
                s, st, tick0, stop = ops
                return chunk_scan(s, st, tick0, stop)

            def body(c):
                s, st, tick0, stop, hz = c
                fast = (tick0 + chunk <= budget) & ~stop.any()
                s, st = jax.lax.cond(fast, fast_chunk, masked_chunk,
                                     (s, st, tick0, stop))
                tick0 = tick0 + jnp.int32(chunk)
                nstop = stop | quiet(s, wl) | (tick0 >= budget)
                hz = jnp.where(nstop & ~stop,
                               jnp.minimum(tick0, budget), hz)
                return s, st, tick0, nstop, hz

            stop0 = jnp.broadcast_to(budget <= jnp.int32(0), bshape)
            hz0 = jnp.where(stop0, jnp.minimum(jnp.int32(0), budget), -1)
            st0 = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, bshape + a.shape),
                stats_init())
            s, st, _, _, hz = jax.lax.while_loop(
                lambda c: ~c[3].all(), body,
                (s0, st0, jnp.int32(0), stop0, hz0))
            return s, st, hz

        return init_fn, run

    if trace == "full":
        def run_chunk(s0, stopped, tick0, wl, fault, budget):
            def chunk_scan(s0, stop):
                # stop=None -> the select-free fast body (see the stats
                # tier: one tick body keeps the bitwise contract)
                def tick_body(s, i):
                    tick = tick0 + i
                    ns, out = stepf(s, tick, wl, fault)
                    if stop is None:
                        return ns, out
                    live = (tick < budget) & ~stop
                    return _freeze(live, ns, s), out

                return jax.lax.scan(tick_body, s0, xs)

            do_fast = (tick0 + chunk <= budget) & ~stopped.any()
            s, outs = jax.lax.cond(do_fast,
                                   lambda s0: chunk_scan(s0, None),
                                   lambda s0: chunk_scan(s0, stopped), s0)
            return s, stopped | quiet(s, wl), outs

        return init_fn, run_chunk

    raise ValueError(
        f"unknown trace tier {trace!r}; choose from {TRACE_MODES}")


def _get_fns(g: QueueGraph, profile: TransportProfile, p: SimParams,
             F: int, batched: bool, trace: str, lossy: bool = False,
             tel: "TelemetrySpec | None" = None, hosty: bool = False,
             corrupty: bool = False, link: "LinkConfig | None" = None):
    """Jitted + cached (init, run) pair — see `_build_fns` for the
    driver contract. Both runs donate the carry."""
    key = _cache_key(g, profile, p, F, batched, trace, lossy=lossy, tel=tel,
                     hosty=hosty, corrupty=corrupty, link=link)
    fns = _RUN_CACHE.get(key)
    if fns is None:
        init_fn, run = _build_fns(g, profile, p, F, batched, trace, lossy,
                                  tel, hosty, corrupty, link)
        fns = (jax.jit(init_fn), jax.jit(run, donate_argnums=(0,)))
        _RUN_CACHE[key] = fns
    return fns


def _run_full_host(run_chunk, s0, wl, fault, budget: int, chunk: int,
                   batch: "int | None"):
    """Drive the trace="full" chunk executable from the host: run chunks
    until every scenario is quiescent or the budget is spent, buffering
    the dense out lanes per chunk and concatenating once at the end.

    Returns (final_state, outs, horizon[np int64 array]) — `horizon[b]`
    is scenario b's own stop boundary (min(chunk end, budget)), which is
    also where its carry froze, so slicing lane b to `horizon[b]` reproduces
    the serial run of that scenario exactly. The dense out lanes are
    time-major: ``[T]`` serial, ``[T, B, ...]`` batched.
    """
    serial = batch is None
    nb = 1 if serial else batch
    stopped = jnp.zeros((() if serial else (nb,)), bool)
    horizon = np.full((nb,), -1, np.int64)
    s = s0
    chunks: list = []
    tick0 = 0
    while True:
        s, stopped, outs = run_chunk(s, stopped, jnp.int32(tick0), wl, fault,
                                     jnp.int32(budget))
        chunks.append(jax.device_get(outs))
        tick0 += chunk
        t_end = min(tick0, budget)
        stop_np = np.atleast_1d(np.asarray(stopped))
        horizon[(horizon < 0) & stop_np] = t_end
        if tick0 >= budget or stop_np.all():
            break
    horizon[horizon < 0] = budget
    outs = {k: np.concatenate([c[k] for c in chunks], axis=0)
            for k in chunks[0]}
    return s, outs, horizon


def _window_bounds(goodput_window, budget: int) -> "tuple[int, int]":
    if goodput_window is None:
        return 0, budget
    w0, w1 = goodput_window
    return int(w0), int(w1)


def _check_trace(trace: str):
    if trace not in TRACE_MODES:
        raise ValueError(f"unknown trace tier {trace!r}; choose from "
                         f"{TRACE_MODES}")


def _normalize_call(profile, p, failed):
    """The single conversion point from the public signatures to the
    engine's (profile, numeric-only params, failure spec).

    The pre-profile positional form — ``simulate(g, wl, SimParams(...))``
    — is accepted for one more release: it warns and runs the default
    ai_full() composition, which is exactly what the removed legacy
    transport knobs composed to when left unset. The knobs themselves
    (``mode``/``lb``/``nscc``/``rccc``/``failed_queues``) are gone from
    SimParams: call sites that set them now fail at construction.
    """
    if isinstance(profile, SimParams):
        if p is not None:
            raise TypeError("got SimParams in the profile position AND a "
                            "params argument — pass (profile, params)")
        warnings.warn(
            "simulate(g, wl, SimParams(...)) is deprecated: pass the "
            "transport composition explicitly — "
            "simulate(g, wl, TransportProfile.ai_full(), SimParams(...))",
            DeprecationWarning, stacklevel=3)
        p = profile
        profile = TransportProfile.ai_full()
    else:
        if profile is None:
            profile = TransportProfile.ai_full()
        if p is None:
            p = SimParams()
    return profile, p, failed


def _failed_to_mask(g: QueueGraph, failed) -> np.ndarray:
    """[Q] bool mask from None / queue-id iterable / bool mask."""
    if failed is None:
        return np.zeros((g.num_queues,), bool)
    arr = np.asarray(failed)
    if arr.dtype == bool:
        if arr.shape != (g.num_queues,):
            raise ValueError(f"failed mask must be [Q={g.num_queues}], "
                             f"got {arr.shape}")
        return arr
    if arr.size and (arr.min() < 0 or arr.max() >= g.num_queues):
        raise ValueError(f"failed queue ids must be in [0, {g.num_queues}); "
                         f"pass a bool array to give a mask instead")
    mask = np.zeros((g.num_queues,), bool)
    mask[arr.astype(np.int64)] = True
    return mask


def _full_result(final: SimState, outs: dict, msg_size, horizon: int,
                 budget: int) -> SimResult:
    return SimResult(
        state=final, msg_size=np.asarray(msg_size),
        horizon=int(horizon), max_ticks=int(budget), trace="full",
        delivered_per_tick=np.asarray(outs["delivered"])[:horizon],
        cwnd_per_tick=np.asarray(outs["cwnd"])[:horizon],
        qlen_max=np.asarray(outs["qlen_max"])[:horizon],
        rx_base_per_tick=np.asarray(outs["rx_base"])[:horizon],
        src_base_per_tick=np.asarray(outs["src_base"])[:horizon],
    )


def _stats_result(final: SimState, st: dict, msg_size, horizon: int,
                  budget: int, goodput_window,
                  tel: "TelemetrySpec | None" = None) -> SimResult:
    trace_obj = None
    if tel is not None and tel.enabled:
        trace_obj = telem.FabricTrace.from_lanes(tel, st["tel"],
                                                 int(horizon))
    return SimResult(
        state=final, msg_size=np.asarray(msg_size),
        horizon=int(horizon), max_ticks=int(budget), trace="stats",
        stat_completion=np.asarray(st["comp"]),
        stat_src_completion=np.asarray(st["src_comp"]),
        stat_win_delivered=np.asarray(st["win_delivered"]),
        goodput_window=(None if goodput_window is None
                        else tuple(int(w) for w in goodput_window)),
        qlen_peak=int(st["qlen_peak"]),
        stat_abandon_tick=int(st["abandon_tick"]),
        telemetry=trace_obj,
    )


def _to_result(final: SimState, outs: dict, msg_size) -> SimResult:
    """Wrap a fixed-length scan's raw (state, out-lanes) as a full-trace
    SimResult (horizon == the recorded length; bench/diagnostic helper
    for hand-rolled scans outside the chunked driver)."""
    t = int(np.asarray(outs["delivered"]).shape[0])
    return _full_result(jax.device_get(final), outs, msg_size, t, t)


def _check_telemetry(telemetry, trace: str) -> "TelemetrySpec | None":
    """Normalize/validate the telemetry= kwarg: None or an off spec is
    the free pre-telemetry path; enabled specs need trace='stats'."""
    if telemetry is None:
        return None
    if not isinstance(telemetry, TelemetrySpec):
        raise TypeError(f"telemetry= takes a TelemetrySpec, got "
                        f"{type(telemetry).__name__}")
    if not telemetry.enabled:
        return None
    if trace != "stats":
        raise ValueError(
            "telemetry lanes ride the streaming stats carry — enabled "
            "TelemetrySpec requires trace='stats'")
    return telemetry


def _check_link(link) -> "LinkConfig | None":
    """Normalize/validate the link= kwarg: None or an off spec is the
    free pre-link-layer path (identical cache key, identical program)."""
    if link is None:
        return None
    if not isinstance(link, LinkConfig):
        raise TypeError(f"link= takes a LinkConfig, got "
                        f"{type(link).__name__}")
    if not link.enabled:
        return None
    return link


def simulate(g: QueueGraph, wl: Workload,
             profile: "TransportProfile | SimParams | None" = None,
             p: "SimParams | None" = None, *,
             seed: int = DEFAULT_SEED, failed=None, faults=None,
             trace: str = "stats", max_ticks: "int | None" = None,
             goodput_window: "tuple[int, int] | None" = None,
             telemetry: "TelemetrySpec | None" = None,
             link: "LinkConfig | None" = None) -> SimResult:
    """Run one scenario for at most ``max_ticks`` (default p.ticks),
    exiting early at the first chunk boundary where the scenario is
    quiescent.

    profile: the transport composition (defaults to ai_full()). Passing a
             SimParams here takes the deprecated pre-profile path.
    failed:  queue ids (tuple) or [Q] bool mask of dead links.
    faults:  a [Q] :class:`~repro.network.faults.FaultSchedule` — link
             flaps and gray (lossy) links with per-queue timing. Mutually
             exclusive with ``failed`` (which is sugar for the static
             ``from_mask`` schedule). Traced: sweeping schedules reuses
             the executable.
    trace:   "stats" (default — streaming stat lanes only, one device
             program) or "full" (dense per-tick lanes, chunk-buffered).
    max_ticks: plain tick-budget bound; traced, so sweeping it reuses
             the compiled executable.
    goodput_window: (w0, w1) to record in-scan for trace="stats" so
             ``result.goodput((w0, w1))`` works without a dense trace.
    telemetry: a :class:`~repro.network.telemetry.TelemetrySpec`. The
             spec is STATIC (it picks the executable, like the profile);
             enabled specs stream the selected probe channels into
             fixed-size decimated ring lanes riding the stats carry and
             attach the reconstructed :class:`~repro.network.telemetry.
             FabricTrace` as ``result.telemetry``. ``None`` / the off
             spec compile the identical pre-telemetry program.
    link:    a :class:`~repro.core.link.LinkConfig` (static, like the
             profile and the telemetry spec): arms per-queue LLR replay
             and/or the CBFC credit gate — see ``make_step``. ``None`` /
             ``LinkConfig.off()`` compile the identical pre-link-layer
             program.
    """
    profile, p, failed = _normalize_call(profile, p, failed)
    _check_trace(trace)
    tel = _check_telemetry(telemetry, trace)
    link = _check_link(link)
    budget = int(p.ticks if max_ticks is None else max_ticks)
    F = int(wl.src.shape[0])
    profile.delivery_modes(F)  # validate per-flow tuples early
    fault = as_schedule(g.num_queues, failed, faults,
                        g_num_hosts=g.num_hosts)
    if fault is None:
        fault = FaultSchedule.from_mask(_failed_to_mask(g, failed))
    lossy = bool(np.asarray(fault.loss_p).any())
    hosty = fault.has_host_faults
    corrupty = fault.has_corruption
    init, run = _get_fns(g, profile, p, F, batched=False, trace=trace,
                         lossy=lossy, tel=tel, hosty=hosty,
                         corrupty=corrupty, link=link)
    s0 = init(wl, jnp.uint32(seed))
    if trace == "stats":
        w0, w1 = _window_bounds(goodput_window, budget)
        final, st, horizon = run(s0, wl, fault, jnp.int32(budget),
                                 jnp.int32(w0), jnp.int32(w1))
        return _stats_result(jax.device_get(final), jax.device_get(st),
                             wl.size, int(horizon), budget, goodput_window,
                             tel=tel)
    final, outs, horizon = _run_full_host(run, s0, wl, fault, budget,
                                          p.chunk_ticks, batch=None)
    return _full_result(jax.device_get(final), outs, wl.size,
                        int(horizon[0]), budget)


def _split_stats_results(final, st, sizes, horizon, budget, goodput_window,
                         B: int,
                         tel: "TelemetrySpec | None" = None
                         ) -> "list[SimResult]":
    """Per-scenario SimResults from host-side batched stats lanes (lanes
    past B — shard padding — are dropped)."""
    return [
        _stats_result(
            jax.tree_util.tree_map(lambda a: a[b], final),
            jax.tree_util.tree_map(lambda a: a[b], st),
            sizes[b], int(horizon[b]), budget, goodput_window, tel=tel)
        for b in range(B)
    ]


def _split_full_results(final, outs, sizes, horizon, budget,
                        B: int) -> "list[SimResult]":
    """Per-scenario SimResults from time-major dense out lanes
    ([T, Bp, ...]; lanes past B — shard padding — are dropped)."""
    return [
        _full_result(
            jax.tree_util.tree_map(lambda a: a[b], final),
            {k: v[:, b] for k, v in outs.items()},
            sizes[b], int(horizon[b]), budget)
        for b in range(B)
    ]


def _run_batch(g, wls, profile, p, fault, seeds, trace, budget,
               goodput_window, devices=None, tel=None,
               link=None) -> "list[SimResult]":
    if devices is not None:
        from repro.network import shard
        return shard.run_sharded(g, wls, profile, p, fault, seeds, trace,
                                 budget, goodput_window, devices, tel=tel,
                                 link=link)
    B, F = wls.src.shape
    profile.delivery_modes(F)
    lossy = bool(np.asarray(fault.loss_p).any())
    hosty = fault.has_host_faults
    corrupty = fault.has_corruption
    init, run = _get_fns(g, profile, p, F, batched=True, trace=trace,
                         lossy=lossy, tel=tel, hosty=hosty,
                         corrupty=corrupty, link=link)
    s0 = init(wls, seeds)
    sizes = np.asarray(wls.size)
    if trace == "stats":
        w0, w1 = _window_bounds(goodput_window, budget)
        final, st, horizon = run(s0, wls, fault, jnp.int32(budget),
                                 jnp.int32(w0), jnp.int32(w1))
        final = jax.device_get(final)
        st = jax.device_get(st)
        horizon = np.asarray(horizon)
        return _split_stats_results(final, st, sizes, horizon, budget,
                                    goodput_window, B, tel=tel)
    final, outs, horizon = _run_full_host(run, s0, wls, fault, budget,
                                          p.chunk_ticks, batch=B)
    final = jax.device_get(final)
    return _split_full_results(final, outs, sizes, horizon, budget, B)


def simulate_batch(g: QueueGraph, wls: Workload,
                   profile=None, p: "SimParams | None" = None, *,
                   failed=None, faults=None, seeds=None,
                   trace: str = "stats", max_ticks: "int | None" = None,
                   goodput_window: "tuple[int, int] | None" = None,
                   shard: bool = False, devices=None,
                   telemetry: "TelemetrySpec | None" = None,
                   link: "LinkConfig | None" = None
                   ) -> "list[SimResult]":
    """Run B scenarios as compiled, batched chunked while-scans.

    g:       one QueueGraph for every scenario, or a length-B list of
             per-scenario graphs. Topologies, like profiles, are static
             (the compiled step bakes in a graph's wiring tables), so a
             per-scenario list groups the batch by (graph, profile) —
             one executable per distinct pair, with groups running on
             worker threads and results reassembled by scenario index.
             This is what makes a co-design sweep (topology x profile x
             workload, see `repro.network.traffic`) ONE call.
    wls:     Workload with a leading scenario axis ([B, F]); build with
             ``Workload.stack`` or pass a list of same-F Workloads.
    profile: one TransportProfile for every scenario, or a length-B list
             of per-scenario profiles. Profiles are static, so the batch
             is grouped by distinct profile — each group runs as one
             vmapped scan sharing one executable (a profile-ablation grid
             is one call here and one compile per profile).
    failed:  optional per-scenario failed-queue spec: [B, Q] bool, one
             [Q] mask, or a queue-id tuple (broadcast to every scenario).
    faults:  optional [B, Q] (or [Q], broadcast) FaultSchedule — dynamic
             flap windows + gray-link loss per scenario. Mutually
             exclusive with ``failed``; rides the scenario axis like
             workloads and seeds (traced, shard-compatible).
    seeds:   optional [B] — per-scenario LB/EV seeds (default: the same
             DEFAULT_SEED every ``simulate`` call uses).
    trace / max_ticks / goodput_window: as in :func:`simulate`. The tick
             budget is traced — sweeping it reuses the executable — and
             each group runs until its slowest scenario is quiescent,
             with faster lanes frozen at their own stop boundary.
    shard / devices: shard the scenario axis across devices with
             ``shard_map`` (repro.network.shard). ``shard=True`` uses
             every ``jax.devices()``; ``devices=`` takes an int (first n
             devices) or an explicit device sequence. Composes with
             per-scenario profiles (each profile group is sharded);
             ragged scenario counts are padded with inert no-op lanes
             and the padding is dropped from the results. Per-lane
             results stay bitwise identical to the unsharded path.
    telemetry: one :class:`~repro.network.telemetry.TelemetrySpec` for
             the whole batch (static: the spec picks the executable,
             like the profile). Enabled specs stream each scenario's
             probe channels into its own ring lanes — vmapped on the
             scenario axis, sharded with it, inert on padding lanes —
             and attach per-scenario ``result.telemetry`` traces,
             bitwise identical to the serial ``simulate`` call's.
    link:    one :class:`~repro.core.link.LinkConfig` for the whole
             batch (static, like the telemetry spec): arms the LLR /
             CBFC lanes on every scenario. ``None`` / the off spec
             compile the identical pre-link-layer program.

    Returns one SimResult per scenario, bitwise identical to the
    corresponding serial ``simulate`` call: the tick function is the same
    compiled code, vmapped over the scenario axis with the carry donated,
    and each lane freezes at the same chunk boundary the serial run
    exits at.
    """
    if isinstance(wls, (list, tuple)):
        wls = Workload.stack(wls)
    if shard or devices is not None:
        from repro.network.shard import resolve_devices
        devices = resolve_devices(devices, shard)
    else:
        devices = None
    graphs = None
    if isinstance(g, (list, tuple)):
        graphs = list(g)
        if not graphs:
            raise ValueError("per-scenario topology list is empty")
        if not all(isinstance(gr, QueueGraph) for gr in graphs):
            raise TypeError("per-scenario topologies must all be "
                            "QueueGraph instances")
        g = graphs[0]
        if all(gr is graphs[0] for gr in graphs):
            graphs = None               # degenerate list: one graph
    profiles = None
    if isinstance(profile, (list, tuple)):
        profiles = list(profile)
        profile = None
        if not all(isinstance(q, TransportProfile) for q in profiles):
            raise TypeError("per-scenario profiles must all be "
                            "TransportProfile instances")
    profile, p, failed = _normalize_call(profile, p, failed)
    _check_trace(trace)
    tel = _check_telemetry(telemetry, trace)
    link = _check_link(link)
    budget = int(p.ticks if max_ticks is None else max_ticks)
    B, F = wls.src.shape
    if graphs is not None and len(graphs) != B:
        raise ValueError(f"got {len(graphs)} topologies for B={B} scenarios")
    if seeds is None:
        seeds = np.full((B,), DEFAULT_SEED, np.uint32)
    seeds = jnp.asarray(seeds, jnp.uint32)
    # fault lanes are [B, Q]: with per-scenario topologies of DIFFERING
    # queue counts there is no uniform Q to normalize against, so the
    # failure spec must stay empty (per-group healthy schedules are
    # built below); equal-Q graph lists compose with faults normally.
    mixed_q = (graphs is not None
               and len({gr.num_queues for gr in graphs}) > 1)
    if mixed_q and (failed is not None or faults is not None):
        raise ValueError(
            "failed=/faults= with per-scenario topologies requires all "
            "graphs to share num_queues — run unequal groups separately")
    fault = None
    if not mixed_q:
        fault = as_schedule(g.num_queues, failed, faults, batch=B,
                            g_num_hosts=g.num_hosts)
        if fault is None:
            if failed is None:
                dead = np.zeros((B, g.num_queues), bool)
            else:
                arr = np.asarray(failed)
                if arr.ndim == 2:
                    # any 2-D array is a per-scenario mask (0/1 ints
                    # included — the pre-profile API accepted those)
                    dead = arr.astype(bool)
                else:
                    dead = np.broadcast_to(_failed_to_mask(g, failed),
                                           (B, g.num_queues))
            if dead.shape != (B, g.num_queues):
                raise ValueError(f"failed mask must be [B={B}, "
                                 f"Q={g.num_queues}], got {dead.shape}")
            fault = FaultSchedule.from_mask(jnp.asarray(dead, bool))

    if profiles is None and graphs is None:
        return _run_batch(g, wls, profile, p, fault, seeds, trace, budget,
                          goodput_window, devices=devices, tel=tel,
                          link=link)

    # per-scenario profiles and/or topologies: group scenarios by the
    # (static) pair and run each group as one vmapped scan — one
    # executable per distinct (graph, profile). Groups are independent
    # device programs, so they run on worker threads: their compiles
    # (the dominant cold cost of an ablation) and executions overlap
    # instead of serializing. Results are reassembled by scenario index
    # — ordering, and every lane's bits, are unaffected.
    if profiles is not None and len(profiles) != B:
        raise ValueError(f"got {len(profiles)} profiles for B={B} scenarios")
    per_g = graphs if graphs is not None else [g] * B
    per_q = profiles if profiles is not None else [profile] * B
    groups: "dict[tuple, tuple]" = {}
    for i, (gr, q) in enumerate(zip(per_g, per_q)):
        key = (id(gr), q)
        if key not in groups:
            groups[key] = (gr, q, [])
        groups[key][2].append(i)
    items = []
    for gr, prof, idxs in groups.values():
        sel = np.asarray(idxs)
        sub_wls = jax.tree_util.tree_map(lambda a, s=sel: a[s], wls)
        if fault is None:
            sub_fault = FaultSchedule.from_mask(
                np.zeros((len(idxs), gr.num_queues), bool))
        else:
            sub_fault = jax.tree_util.tree_map(lambda a, s=sel: a[s], fault)
        items.append((gr, prof, idxs, sub_wls, sub_fault, seeds[sel]))

    def _run_group(item):
        gr, prof, idxs, sub_wls, sub_fault, sub_seeds = item
        return idxs, _run_batch(gr, sub_wls, prof, p, sub_fault, sub_seeds,
                                trace, budget, goodput_window,
                                devices=devices, tel=tel, link=link)

    if len(items) > 1:
        from concurrent.futures import ThreadPoolExecutor
        with ThreadPoolExecutor(max_workers=min(len(items), 8)) as ex:
            group_results = list(ex.map(_run_group, items))
    else:
        group_results = [_run_group(items[0])]
    results: "list[SimResult | None]" = [None] * B
    for idxs, rs in group_results:
        for j, i in enumerate(idxs):
            results[i] = rs[j]
    return results
