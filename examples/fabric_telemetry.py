"""Fabric telemetry tour: watch an outage happen, then export it.

The scenario is the canonical flap-victim run (``workloads.victim_
sweep`` with 3 of leaf-0's 4 uplinks flapping over [1000, 1800)): 12
cross-leaf flows pile onto the one surviving uplink while the probes
record what UET's own congestion signals show. The walkthrough:

  [1] run with ``telemetry=TelemetrySpec.on()`` — one extra kwarg, and
      the probes provably change nothing (final state is bitwise the
      off-run's);
  [2] read the outage off the lanes: the four closed-loop signatures
      (silent-drop confinement, NSCC's mark-rate back-off, the goodput
      dip + recovery, the heal-boundary trim burst);
  [3] ASCII-plot the victim uplink's occupancy EWMA straight from the
      decimated ring;
  [4] export everything as Perfetto/Chrome-trace counter tracks.

Run: PYTHONPATH=src python examples/fabric_telemetry.py
"""
import numpy as np

from repro.network.fabric import simulate
from repro.network.telemetry import (flap_victim_scenario,
                                     outage_visibility)


def spark(vals, width=60):
    """One-line ASCII sparkline."""
    ramp = " .:-=+*#%@"
    v = np.asarray(vals, float)
    if v.size > width:  # decimate for the terminal like the ring does
        v = v[np.linspace(0, v.size - 1, width).astype(int)]
    hi = v.max() or 1.0
    return "".join(ramp[int(x / hi * (len(ramp) - 1))] for x in v)


def main():
    print("=== fabric telemetry tour ===")
    g, wl, prof, p, sched, spec, (fail_at, heal_at) = flap_victim_scenario()

    print(f"\n[1] {p.ticks}-tick victim-share run, 3 uplinks flapping over "
          f"[{fail_at}, {heal_at}), telemetry on")
    r = simulate(g, wl, prof, p, faults=sched, telemetry=spec)
    tr = r.telemetry
    print(f"    {tr.num_samples} samples at {tr.sample_spacing}-tick "
          f"spacing (probe_every={spec.probe_every}, ring of "
          f"{spec.slots} slots decimated to stride {tr.stride})")
    s = tr.summary()
    print(f"    occ p50/p99 {s['occ_p50']:.1f}/{s['occ_p99']:.1f} pkts, "
          f"rtt p50/p99 {s.get('rtt_p50', 0):.0f}/{s.get('rtt_p99', 0):.0f} "
          f"ticks, {s['marks_total']} marks, {s['trims_total']} trims, "
          f"{s['drops_total']} silent drops")

    print(f"\n[2] the outage in the lanes (what a CLOSED-LOOP transport "
          f"shows — see DESIGN.md 'Telemetry contract'):")
    vis = outage_visibility(tr, fail_at, heal_at, p.ticks)
    print(f"    silent drops   : {vis['drop_pre']:.2f} -> "
          f"{vis['drop_during']:.2f} -> {vis['drop_post']:.2f}/tick "
          f"(confined to the window — dead links say nothing)")
    print(f"    ECN mark rate  : {vis['mark_pre']:.2f} -> "
          f"{vis['mark_during']:.2f}/tick (NSCC backs off on the "
          f"shrinking ACK stream: marks CRATER, not spike)")
    print(f"    goodput        : {vis['goodput_pre']:.2f} -> "
          f"{vis['goodput_during']:.2f} -> {vis['goodput_post']:.2f} "
          f"pkts/tick (dip, then recovery)")
    print(f"    heal trim burst: {vis['trim_pre']:.2f} -> "
          f"{vis['trim_burst']:.2f}/tick right after heal_at (every "
          f"flow's retransmit backlog floods the restored links at once)")

    print("\n[3] the surviving uplink's occupancy EWMA, straight off the "
          "decimated ring:")
    # victim_sweep names leaf-0's uplink queues; the flap takes all but
    # the last, so the survivor carries the whole cross-leaf load
    from repro.network import workloads
    _, _, exp = workloads.victim_sweep()
    q = exp["uplinks"][-1]
    occ = tr.occ[:, q]
    print(f"    q{q}: {spark(occ)}")
    w = (tr.ticks >= fail_at) & (tr.ticks < heal_at)
    print(f"    in-window mean {occ[w].mean():.1f} vs outside "
          f"{occ[~w].mean():.1f} pkts")

    print("\n[4] Perfetto export")
    out = "fabric_trace.json"
    tr.save_chrome_trace(out)
    print(f"    wrote {out} ({len(tr.to_chrome_trace())} counter events) — "
          f"open in chrome://tracing or https://ui.perfetto.dev")
    print("    (scripts/trace_export.py is the CLI for custom budgets / "
          "cadences)")


if __name__ == "__main__":
    main()
