"""Pure-jnp oracles for the Pallas kernels.

These are the semantic ground truth: every kernel in this package is
validated against these functions across shape/dtype sweeps in
tests/test_kernels_*.py (interpret mode on CPU, compiled on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cms.nscc import NSCCParams, window_delta
from repro.core.pds import shift_ring, trailing_ones
from repro.network.ecmp import ecmp_hash


def nscc_update_ref(cwnd: jax.Array, ecn: jax.Array, rtt: jax.Array,
                    count: jax.Array, params: NSCCParams) -> jax.Array:
    """Batched NSCC window update.

    cwnd: [N] f32 current windows; ecn: [N] bool aggregate ECN of the ACK
    round; rtt: [N] f32 measured RTT; count: [N] i32 number of coalesced
    ACKs this round (CACK/SACK may cover several packets, Sec. 3.2.5).
    """
    delta = window_delta(cwnd, ecn, rtt, params) * count.astype(jnp.float32)
    active = count > 0
    out = jnp.where(active, cwnd + delta, cwnd)
    return jnp.clip(out, params.min_cwnd, params.max_cwnd)


def sack_advance_ref(ring: jax.Array, base: jax.Array):
    """Cumulative-ACK advance over [N, W] uint32 SACK rings.

    Returns (new_ring, new_base, advanced): count the contiguous received
    prefix, shift it out, advance the base PSN (Sec. 3.2.5).
    """
    adv = trailing_ones(ring)
    return shift_ring(ring, adv), base + adv.astype(jnp.uint32), adv


def ecmp_hash_ref(src: jax.Array, dst: jax.Array, ev: jax.Array,
                  salt: jax.Array, fanout: int) -> jax.Array:
    """Batched ECMP port selection: H(fields) mod fanout (Sec. 2.1)."""
    return (ecmp_hash(src, dst, ev, salt) % jnp.uint32(fanout)).astype(jnp.int32)


def sack_fused_ref(ring: jax.Array, base: jax.Array, rtx: jax.Array,
                   mask: jax.Array):
    """Fused SACK hot path (Sec. 3.2.5): record-rx OR-apply, CACK advance,
    and lockstep shift of the SACK ring and the retransmit-pending bitmap.

    ring, rtx, mask: [N, W] uint32; base: [N] uint32.
    Returns (new_ring, new_base, new_rtx, advanced[int32]).
    """
    ring = ring | mask
    adv = trailing_ones(ring)
    return (shift_ring(ring, adv), base + adv.astype(jnp.uint32),
            shift_ring(rtx, adv), adv)


def nack_mark_ref(rtx: jax.Array, flow: jax.Array, off: jax.Array,
                  valid: jax.Array) -> jax.Array:
    """Duplicate-safe NACK retransmit-bit marking (Sec. 3.2.4).

    Lane l with valid[l] sets bit off[l] (a PSN offset in [0, W*32)) of
    ring row flow[l]; several lanes may hit one row, and two lanes may
    carry the SAME (flow, off) — e.g. a packet and its retransmission
    trimmed in one tick — so the combine must be OR, not add.

    rtx: [F, W] uint32; flow/off: [L] int32; valid: [L] bool.
    Returns rtx with the bits OR-ed in.

    Scheme: each lane drops one True on an [F, W*32] bool plane (masked
    lanes land on an out-of-range row), then the plane packs into ring
    words — bits are distinct powers of two per word, so the pack-sum IS
    the bitwise OR. E-Q scalar updates + an [F, mp] pack instead of the
    [F, W, L] dense OR-fold this replaced (the fabric tick's largest
    intermediate by an order of magnitude).
    """
    f, w = rtx.shape
    mp = w * 32
    rows = jnp.where(valid, flow, f)
    cols = jnp.clip(off, 0, mp - 1)
    plane = jnp.zeros((f, mp), jnp.bool_).at[rows, cols].set(True,
                                                             mode="drop")
    words = (plane.reshape(f, w, 32).astype(jnp.uint32)
             << jnp.arange(32, dtype=jnp.uint32)).sum(axis=2,
                                                      dtype=jnp.uint32)
    return rtx | words
