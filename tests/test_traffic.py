"""Model-driven traffic engine: plan derivation, schedule compilation,
dep-chained phase ordering, batch parity and fault sensitivity.

* plan derivation is deterministic and classifies leaves via the REAL
  sharding rules (fsdp_tp emits DP param/grad collectives; tp_only
  collapses them into one full-size grad all-reduce);
* the compiled step is bitwise-deterministic and its phases are strictly
  dep-chained: a DP-phase root flow cannot start before the last TP
  phase source-completes at its host;
* the co-design sweep prices scenarios through ONE simulate_batch call,
  bitwise-identical to serial simulate calls — including per-scenario
  topologies;
* injected link faults can only slow the step down (monotonicity).
"""
import numpy as np
import pytest

from repro import configs
from repro.distributed.plan import collective_seconds, derive_plan
from repro.network import collectives as coll
from repro.network import traffic
from repro.network.fabric import SimParams, simulate, simulate_batch
from repro.network.faults import FaultSchedule
from repro.network.profile import TransportProfile
from repro.network.topology import leaf_spine

CFG = configs.get("deepseek-coder-33b")


# ------------------------------------------------------------------ plans

def test_plan_derivation_deterministic():
    a = derive_plan(CFG, "train_4k", dp=16, tp=16, layout="fsdp_tp")
    b = derive_plan(CFG, "train_4k", dp=16, tp=16, layout="fsdp_tp")
    assert a == b                      # frozen dataclasses, bitwise fields
    assert a.devices == 256
    assert a.param_bytes > 0 and a.tokens_per_step == a.global_batch * 4096


def test_plan_layouts_follow_sharding_rules():
    fsdp = derive_plan(CFG, "train_4k", dp=8, tp=8, layout="fsdp_tp")
    tponly = derive_plan(CFG, "train_4k", dp=8, tp=8, layout="tp_only")
    fsdp_phases = {d.phase for d in fsdp.demands}
    assert {"tp_stream", "dp_param", "dp_grad"} <= fsdp_phases
    # fsdp grad traffic is split reduce-scatter (sharded) + all-reduce
    # (replicated leaves); tp_only has NO param gathers and one full-size
    # grad all-reduce
    assert {d.kind for d in fsdp.demands if d.phase == "dp_grad"} \
        == {"reduce_scatter", "all_reduce"}
    tponly_phases = {d.phase for d in tponly.demands}
    assert "dp_param" not in tponly_phases
    (gar,) = [d for d in tponly.demands if d.phase == "dp_grad"]
    assert gar.kind == "all_reduce"
    # same total grad bytes either way, but all-reduce moves 2(n-1)/n of
    # them vs reduce-scatter's (n-1)/n — the grad phase alone is pricier
    # in tp_only (fsdp_tp pays it back in param gathers)
    def grad_s(p):
        return sum(collective_seconds(d.kind, d.n, d.bytes_per_rank, 50e9)
                   for d in p.demands if d.phase == "dp_grad")
    assert grad_s(tponly) > grad_s(fsdp)


def test_plan_decode_emits_serving_incast():
    p = derive_plan(CFG, "decode_32k", dp=4, tp=4, layout="fsdp_tp")
    kinds = {d.phase: d for d in p.demands}
    assert "serve_incast" in kinds and kinds["serve_incast"].kind == "incast"
    assert "dp_grad" not in kinds      # no gradients at inference
    # tokens_per_step collapses to the batch (one token per sequence)
    assert p.tokens_per_step == p.global_batch


def test_plan_validation():
    with pytest.raises(ValueError, match="layout"):
        derive_plan(CFG, "train_4k", dp=2, tp=2, layout="nope")
    with pytest.raises(ValueError, match="divide"):
        derive_plan(CFG, "train_4k", dp=2, tp=2, pp=7)
    with pytest.raises(ValueError, match=">= 1"):
        derive_plan(CFG, "train_4k", dp=0, tp=2)
    with pytest.raises(ValueError, match="unknown collective"):
        collective_seconds("bogus", 4, 1e6, 50e9)


def test_alpha_beta_formulas():
    bw = 50e9
    m = 1e9 / bw
    assert collective_seconds("all_reduce", 4, 1e9, bw) \
        == pytest.approx(2 * 3 / 4 * m)
    assert collective_seconds("all_gather", 4, 1e9, bw) == pytest.approx(3 * m)
    assert collective_seconds("p2p", 2, 1e9, bw) == pytest.approx(m)
    assert collective_seconds("incast", 4, 1e9, bw) == pytest.approx(4 * m)
    assert collective_seconds("all_reduce", 1, 1e9, bw) == 0.0


# ------------------------------------------------------------ compilation

def test_compiled_step_bitwise_deterministic():
    p = derive_plan(CFG, "train_4k", dp=16, tp=16, layout="fsdp_tp")
    g = leaf_spine(4, 2, 4)
    c1 = traffic.compile_step(p, g)
    c2 = traffic.compile_step(p, g)
    assert c1.phases == c2.phases
    for lane in ("src", "dst", "size", "start", "dep"):
        np.testing.assert_array_equal(
            np.asarray(getattr(c1.workload, lane)),
            np.asarray(getattr(c2.workload, lane)), err_msg=lane)


def test_compiled_phases_are_dep_chained():
    """Structural ordering: every root flow of phase k (dep not internal
    to k) points at a flow of an EARLIER phase — the schedule is one
    chain, so no phase can race ahead of its predecessor."""
    p = derive_plan(CFG, "train_4k", dp=16, tp=16, layout="fsdp_tp")
    c = traffic.compile_step(p, leaf_spine(4, 2, 4))
    dep = np.asarray(c.workload.dep)
    names = [ph.name for ph in c.phases]
    assert names[0] == "dp_param"
    assert [n for n in names if n.startswith("tp_layer")]
    # sharded grads reduce-scatter + re-gather; replicated leaves all-reduce
    assert names[-3:] == ["dp_grad_rs", "dp_grad_ag", "dp_grad_ar"]
    for k, ph in enumerate(c.phases):
        roots = [f for f in range(ph.lo, ph.hi) if dep[f] < ph.lo]
        assert roots, ph.name
        if k == 0:
            assert all(dep[f] == -1 for f in roots)
        else:
            prev = c.phases[k - 1]
            assert all(prev.lo <= dep[f] < prev.hi for f in roots), ph.name


def test_dp_cannot_start_before_last_tp_completes():
    """Behavioral: run the compiled step with a full trace and check every
    DP-grad root flow's FIRST delivery lands strictly after its gating TP
    flow source-completed (the per-host chain the builder documents: DP
    at host h waits for the last TP phase flow sourced at h)."""
    p = derive_plan(CFG, "train_4k", dp=4, tp=4, layout="fsdp_tp")
    g = leaf_spine(4, 2, 4)
    c = traffic.compile_step(p, g, max_pkts=8)
    r = simulate(g, c.workload, TransportProfile.ai_full(), SimParams(),
                 trace="full", max_ticks=c.default_budget())
    src_comp = r.source_completion_ticks()
    dep = np.asarray(c.workload.dep)
    src = np.asarray(c.workload.src)
    last_tp = max((ph for ph in c.phases if ph.name.startswith("tp_layer")),
                  key=lambda ph: ph.lo)
    (dp_rs,) = [ph for ph in c.phases if ph.name == "dp_grad_rs"]
    delivered = np.asarray(r.delivered_per_tick)
    checked = 0
    for f in range(dp_rs.lo, dp_rs.hi):
        if dep[f] < dp_rs.lo:              # root flow: gated on prior phase
            gate = int(dep[f])
            assert last_tp.lo <= gate < last_tp.hi
            assert src[gate] == src[f]     # same-host chaining
            first = int(np.argmax(delivered[:, f] > 0))
            assert delivered[:, f].sum() > 0
            assert first > int(src_comp[gate]) > 0
            checked += 1
    assert checked > 0


def test_compile_rejects_too_small_graphs():
    p = derive_plan(CFG, "train_4k", dp=4, tp=4)
    with pytest.raises(ValueError, match="hosts/leaf"):
        traffic.compile_step(p, leaf_spine(4, 2, hosts_per_leaf=1))
    with pytest.raises(ValueError, match="leaves"):
        traffic.compile_step(p, leaf_spine(1, 2, hosts_per_leaf=8))
    nothing = derive_plan(CFG, "train_4k", dp=1, tp=1)
    with pytest.raises(ValueError, match="no network phases"):
        traffic.compile_step(nothing, leaf_spine(4, 2, 4))


def test_price_step_raises_on_budget_exhaustion():
    p = derive_plan(CFG, "decode_32k", dp=4, tp=4)
    g = leaf_spine(4, 2, 4)
    c = traffic.compile_step(p, g, max_pkts=8)
    r = simulate(g, c.workload, TransportProfile.ai_full(), SimParams(),
                 max_ticks=4)
    with pytest.raises(RuntimeError, match="max_ticks"):
        traffic.price_step(c, r)


# ------------------------------------------------------- batch parity

def test_sweep_batch_matches_serial_including_incast():
    """The decode sweep (serving incast included) batched through ONE
    simulate_batch call is bitwise-identical to serial simulate calls —
    across two topologies with DIFFERENT queue counts."""
    graphs, wls, profs, points = traffic.model_sweep_scenarios(
        arch_names=("deepseek-coder-33b",), dp=4, tp=4,
        layouts=("fsdp_tp",),
        profiles=[TransportProfile.ai_full(), TransportProfile.hpc()],
        max_pkts=8)
    assert len({g.num_queues for g in graphs}) == 2   # mixed-Q batch
    budget = max(pt["compiled"].default_budget() for pt in points)
    rs = simulate_batch(graphs, coll.stack_padded(wls), profs, SimParams(),
                        max_ticks=budget)
    for g, wl, prof, r in zip(graphs, wls, profs, rs):
        r_serial = simulate(g, wl, prof, SimParams(), max_ticks=budget)
        np.testing.assert_array_equal(
            r.source_completion_ticks()[:wl.src.shape[0]],
            r_serial.source_completion_ticks())


def test_mixed_topology_batch_rejects_faults():
    graphs, wls, profs, points = traffic.model_sweep_scenarios(
        arch_names=("deepseek-coder-33b",), dp=4, tp=4,
        layouts=("fsdp_tp",), profiles=[TransportProfile.ai_full()],
        max_pkts=8)
    with pytest.raises(ValueError, match="num_queues"):
        simulate_batch(graphs, coll.stack_padded(wls), profs, SimParams(),
                       failed=(0,), max_ticks=100)


def test_step_time_monotone_under_link_flap():
    """Flapping a leaf uplink during the step can only slow it down."""
    p = derive_plan(CFG, "decode_32k", dp=4, tp=4, layout="fsdp_tp")
    g = leaf_spine(4, 2, 4)
    healthy = traffic.step_time(p, g, TransportProfile.ai_full(),
                                max_pkts=8)
    flap = FaultSchedule.healthy(g.num_queues).flap(
        [int(g.up1_table[h, 0]) for h in range(4)], fail_at=5, heal_at=120)
    faulty = traffic.step_time(p, g, TransportProfile.ai_full(),
                               faults=flap, max_pkts=8)
    assert faulty.sim_ticks >= healthy.sim_ticks
    assert faulty.step_s >= healthy.step_s


# ------------------------------------------------------------- pricing

def test_priced_net_term_at_least_analytic():
    """eff is clipped to (0, 1], so the simulated network term can never
    beat the alpha-beta bound."""
    p = derive_plan(CFG, "decode_32k", dp=4, tp=4, layout="fsdp_tp")
    t = traffic.step_time(p, leaf_spine(4, 2, 4),
                          TransportProfile.ai_full(), max_pkts=8)
    assert t.net_s >= t.analytic_net_s > 0
    assert all(0 < v <= 1 for v in t.eff.values())
    assert t.step_s == pytest.approx(max(t.compute_s, t.memory_s) + t.net_s)
    assert t.time_to_train(1e9) == pytest.approx(1e9 / t.tokens_per_sec)
