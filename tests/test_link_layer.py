"""Traced link-layer reliability (LLR replay + CBFC credits): contracts.

Locked here (see DESIGN.md "Link-layer reliability contract"):

* link-layer OFF is FREE — ``link=None`` and ``LinkConfig.off()``
  normalize to the same compile-cache key as the pre-link engine, and
  an off-run's ``trace="full"`` lanes stay bitwise equal to the PR-2
  golden anchors;
* a clean link is bitwise inert — with BER=0, an LLR/CBFC-armed run's
  final SimState equals the off-run's on every pre-feature lane (only
  the link-owned lanes, which differ in shape, are excluded);
* NO corruption escapes an LLR-enabled link: across seeds and BERs,
  end-to-end drops stay zero, every flow completes, and recovery is
  hop-local (``llr_replays`` counts it) — while the LLR-off twin leaks
  the same corruption into end-to-end recovery;
* LLR does NOT mask congestion: trims still NACK end-to-end;
* CBFC back-pressures instead of overflowing: zero trims on a clean
  congested fabric, with ``credit_stall_ticks`` pricing the stalls;
* the new stat lanes are bitwise deterministic across serial / batched /
  device-sharded execution;
* ``workloads.corruption_sweep`` is the ONE BER-grid definition shared
  by the bench block, the ``python -m repro.core.link`` canary and
  these tests.

conftest.py forces 4 virtual CPU devices; sharded tests skip (not
fail) with fewer than 2.
"""
import os

import jax
import numpy as np
import pytest

from repro.core.link import (LINK_STATE_LANES, LinkConfig,
                             fabric_buffer_pricing, state_bitwise_equal)
from repro.network import workloads
from repro.network.fabric import (SimParams, Workload, _cache_key, simulate,
                                  simulate_batch)
from repro.network.faults import FaultSchedule
from repro.network.profile import TransportProfile
from repro.network.telemetry import TelemetrySpec
from repro.network.topology import leaf_spine

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "fabric_golden.npz")

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=4; set by tests/conftest.py unless overridden)")


def _state_equal(a, b) -> bool:
    return all(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b)))


def _grid(bers=(0.0, 0.03)):
    """The shared corruption grid at test scale."""
    return workloads.corruption_sweep(bers=bers)


# ------------------------------------------------------------------------
# the event-driven LLRLink reference model
# ------------------------------------------------------------------------

def test_llr_stale_nack_clamps_to_send_base():
    """Regression: a NACK naming frames BELOW the cumulative-ACK base
    (a duplicate/reordered NACK arriving after the ACK that freed them)
    must clamp to ``send_base`` — replaying freed frames would read a
    replay buffer that no longer holds them, and the old code also
    overcounted ``retransmissions`` by the already-freed span.

    Lives here rather than test_link_tss.py so it runs without the
    optional hypothesis dependency."""
    from repro.core.link import LLRLink

    llr = LLRLink(replay_capacity=16, timeout=8)
    for _ in range(10):
        llr.send()
    llr.on_ack(6)                   # frames 0..6 freed; send_base == 7
    resend = llr.on_nack(2)         # stale: names freed frames 2..6
    assert resend == [7, 8, 9]      # replay starts at send_base, never before
    assert llr.retransmissions == 3  # not 8: freed span is not re-counted
    # and a fresh NACK at the base behaves as before
    resend = llr.on_nack(7)
    assert resend == [7, 8, 9]


# ------------------------------------------------------------------------
# spec validation + off-gating
# ------------------------------------------------------------------------

def test_linkconfig_validation():
    assert not LinkConfig.off().enabled
    assert LinkConfig.on(llr=True).enabled
    assert LinkConfig.on(llr=False, cbfc=True).enabled
    with pytest.raises(ValueError, match="llr_rtt"):
        LinkConfig(llr=True, llr_rtt=0)
    with pytest.raises(ValueError, match="credit_return_ticks"):
        LinkConfig(cbfc=True, credit_return_ticks=0)


def test_wrong_link_type_rejected():
    g, wls, scheds, exp = _grid()
    wl = jax.tree_util.tree_map(lambda a: a[0], wls)
    with pytest.raises(TypeError, match="LinkConfig"):
        simulate(g, wl, exp["profile"], exp["params"], link=True)


def test_off_spec_shares_the_pre_link_cache_key():
    """None and LinkConfig.off() must hit the SAME executable as the
    pre-link engine; an enabled spec must not."""
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    prof = TransportProfile.ai_full()
    p = SimParams()
    base = _cache_key(g, prof, p, 2, True, "stats")
    assert base == _cache_key(g, prof, p, 2, True, "stats", link=None)
    assert base == _cache_key(g, prof, p, 2, True, "stats",
                              link=LinkConfig.off())
    on = _cache_key(g, prof, p, 2, True, "stats", link=LinkConfig.on())
    assert on != base
    # the spec's knobs pick the program: a different replay RTT, the
    # CBFC axis, and the corruption lane each recompile
    assert on != _cache_key(g, prof, p, 2, True, "stats",
                            link=LinkConfig.on(llr_rtt=16))
    assert on != _cache_key(g, prof, p, 2, True, "stats",
                            link=LinkConfig.on(cbfc=True))
    assert base != _cache_key(g, prof, p, 2, True, "stats", corrupty=True)


def test_link_off_keeps_golden_full_trace_bitwise():
    """An explicit off spec through the public API reproduces the PR-2
    golden lanes bitwise — link-off IS the pre-link engine."""
    gold = np.load(GOLDEN)
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2], [4, 5, 6], 200)
    r = simulate(g, wl, TransportProfile.ai_full(), SimParams(ticks=300),
                 trace="full", link=LinkConfig.off())
    h = r.horizon
    np.testing.assert_array_equal(r.delivered_per_tick,
                                  gold["a_delivered"][:h])
    np.testing.assert_array_equal(r.cwnd_per_tick, gold["a_cwnd"][:h])
    np.testing.assert_array_equal(np.asarray(r.state.delivered),
                                  gold["a_state_delivered"])


def test_clean_link_armed_run_is_bitwise_inert():
    """BER=0 + LLR/CBFC armed must be bitwise the plain run on every
    pre-feature lane — and congestion trims are NOT masked (they land
    identically, end-to-end, under both arms)."""
    g, wls, scheds, exp = _grid(bers=(0.0,))
    prof, p = exp["profile"], exp["params"]
    on = simulate_batch(g, wls, prof, p, faults=scheds, link=exp["link"])[0]
    off = simulate_batch(g, wls, prof, p, faults=scheds)[0]
    drift = state_bitwise_equal(on.state, off.state)
    assert drift is None, f"clean-link armed run drifted: {drift}"
    assert int(on.trims) == int(off.trims) > 0, \
        "the congested clean lane must trim identically under both arms"
    assert on.llr_replays == 0 and on.credit_stall_ticks == 0
    # the link-owned lanes are exactly the shape-changing ones
    assert LINK_STATE_LANES == {
        "llr_busy_until", "llr_replays", "cbfc_consumed", "cbfc_freed",
        "cbfc_ret", "credit_stall_ticks"}


# ------------------------------------------------------------------------
# the confinement property: no corruption escapes an LLR link
# ------------------------------------------------------------------------

def test_no_corruption_escapes_llr_across_seeds_and_bers():
    """Seeded property sweep: for every (seed, BER) point, the LLR arm
    delivers every flow with ZERO end-to-end drops and hop-local
    replays, while the off arm leaks the same corruption as silent
    end-to-end drops. One executable per arm (seed and BER are traced)."""
    g, wls, _, exp = _grid(bers=(0.03,))
    wl = jax.tree_util.tree_map(lambda a: a[0], wls)
    prof, p, link = exp["profile"], exp["params"], exp["link"]
    total = int(np.asarray(wl.size).sum())
    for seed in (1, 0xBEEF, 12345):
        for ber in (0.02, 0.08):
            sched = FaultSchedule.healthy(g.num_queues).corrupt(
                exp["uplinks"], ber)
            r = simulate(g, wl, prof, p, faults=sched, seed=seed, link=link)
            assert int(r.drops) == 0, (seed, ber, int(r.drops))
            assert r.llr_replays > 0, (seed, ber)
            assert r.completion_tick() > 0, (seed, ber)
            assert int(np.asarray(r.state.delivered).sum()) == total
            leak = simulate(g, wl, prof, p, faults=sched, seed=seed)
            assert int(leak.drops) > 0, (seed, ber)


def test_cbfc_backpressures_instead_of_trimming():
    """Clean congested fabric, CBFC armed: credit exhaustion must stall
    (``credit_stall_ticks > 0``) instead of trimming (zero trims), with
    everything still completing — lossless by back-pressure, with the
    buffer bill undercutting PFC headroom."""
    g, wls, scheds, exp = _grid(bers=(0.0,))
    prof, p = exp["profile"], exp["params"]
    off = simulate_batch(g, wls, prof, p, faults=scheds)[0]
    cb = simulate_batch(g, wls, prof, p, faults=scheds,
                        link=LinkConfig.on(llr=False, cbfc=True))[0]
    assert int(off.trims) > 0, "the scenario must congest"
    assert int(cb.trims) == 0
    assert cb.credit_stall_ticks > 0
    assert cb.completion_tick() > 0
    assert int(cb.drops) == 0
    pricing = fabric_buffer_pricing(g.num_queues)
    assert pricing["cbfc_total_bytes"] < pricing["pfc_total_bytes"] / 2


# ------------------------------------------------------------------------
# serial == batched == sharded for the new stat lanes
# ------------------------------------------------------------------------

def test_batched_link_lanes_match_serial_bitwise():
    g, wls, scheds, exp = _grid(bers=(0.0, 0.02, 0.08))
    prof, p = exp["profile"], exp["params"]
    link = exp["cbfc"]            # LLR + CBFC: every new lane live
    rs = simulate_batch(g, wls, prof, p, faults=scheds, link=link)
    for i, r in enumerate(rs):
        solo = simulate(
            g, jax.tree_util.tree_map(lambda a: a[i], wls), prof, p,
            faults=jax.tree_util.tree_map(lambda a: a[i], scheds),
            link=link)
        assert solo.horizon == r.horizon, f"lane {i}"
        assert _state_equal(solo.state, r.state), f"lane {i}"
        assert solo.llr_replays == r.llr_replays, f"lane {i}"
        assert solo.credit_stall_ticks == r.credit_stall_ticks, f"lane {i}"


@multi_device
def test_sharded_link_lanes_match_batched_bitwise():
    """B=3 on all devices (ragged -> one padding lane) with corruption
    lanes riding: the sharded link stat lanes equal the unsharded ones
    (shard padding pads ``corrupt_p`` with healthy zeros)."""
    g, wls, scheds, exp = _grid(bers=(0.0, 0.02, 0.08))
    prof, p = exp["profile"], exp["params"]
    link = exp["cbfc"]
    base = simulate_batch(g, wls, prof, p, faults=scheds, link=link)
    shd = simulate_batch(g, wls, prof, p, faults=scheds, link=link,
                         shard=True)
    assert len(shd) == len(base) == 3
    for i, (a, b) in enumerate(zip(base, shd)):
        assert a.horizon == b.horizon, f"lane {i}"
        assert _state_equal(a.state, b.state), f"lane {i}"
        assert a.llr_replays == b.llr_replays, f"lane {i}"
        assert a.credit_stall_ticks == b.credit_stall_ticks, f"lane {i}"


# ------------------------------------------------------------------------
# telemetry channels + the shared grid definition
# ------------------------------------------------------------------------

def test_telemetry_llr_channel_mirrors_the_replay_scalar():
    """With probes on, the cumulative per-queue ``llr`` channel's final
    total equals the ``llr_replays`` scalar, the replays land on the
    corrupted queues only, and arming telemetry+link together perturbs
    nothing vs the probe-free run."""
    g, wls, scheds, exp = _grid(bers=(0.04,))
    wl = jax.tree_util.tree_map(lambda a: a[0], wls)
    sched = jax.tree_util.tree_map(lambda a: a[0], scheds)
    prof, p, link = exp["profile"], exp["params"], exp["link"]
    r = simulate(g, wl, prof, p, faults=sched, link=link,
                 telemetry=TelemetrySpec.on())
    bare = simulate(g, wl, prof, p, faults=sched, link=link)
    assert _state_equal(r.state, bare.state)
    tr = r.telemetry
    llr_q = np.asarray(tr.final["llr_q"])
    assert int(llr_q.sum()) == r.llr_replays > 0
    hot = set(np.nonzero(llr_q)[0].tolist())
    assert hot <= set(exp["uplinks"]), (hot, exp["uplinks"])
    assert int(tr.llr[-1].sum()) == int(llr_q.sum())
    # stall channel: all-zero without CBFC armed
    assert int(np.asarray(tr.final["stall_q"]).sum()) == 0


def test_corruption_sweep_is_the_shared_definition():
    g, wls, scheds, exp = workloads.corruption_sweep(
        bers=(0.0, 0.01, 0.05))
    assert exp["bers"] == (0.0, 0.01, 0.05)
    assert exp["names"] == ["ber_0", "ber_0.01", "ber_0.05"]
    assert wls.src.shape[0] == 3
    assert exp["link"].llr and not exp["link"].cbfc
    assert exp["cbfc"].llr and exp["cbfc"].cbfc
    assert exp["params"].ticks == exp["budget"]
    # every lane is the same victim-share workload as victim_sweep's
    gv, wl, expv = workloads.victim_sweep(pairs=4, uplinks=2, size=400)
    assert exp["uplinks"] == expv["uplinks"]
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(wls.src[i]),
                                      np.asarray(wl.src))
    # lane 0 is healthy; nonzero lanes corrupt exactly the uplinks
    cp = np.asarray(scheds.corrupt_p)
    assert (cp[0] == 0).all()
    for i, ber in enumerate(exp["bers"][1:], start=1):
        assert set(np.nonzero(cp[i])[0].tolist()) == set(exp["uplinks"])
        np.testing.assert_allclose(cp[i][list(exp["uplinks"])], ber)
