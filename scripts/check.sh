#!/usr/bin/env bash
# Fast smoke gate: tier-1 tests minus the slow-marked heavies, plus the
# header-stack paper bench as an import/consistency canary.
#
#   ./scripts/check.sh            # ~40s on a laptop CPU
#
# The full tier-1 gate (everything, including slow) stays
#   PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== lint (ruff) =="
if command -v ruff >/dev/null 2>&1; then
  ruff check src tests benchmarks examples
elif python -c "import ruff" >/dev/null 2>&1; then
  python -m ruff check src tests benchmarks examples
else
  echo "ruff not installed (pip install -r requirements-dev.txt); skipping lint"
fi

echo "== tier-1 tests (minus slow) =="
python -m pytest -x -q -m "not slow"

echo "== paper bench smoke: header stacks =="
python -m benchmarks.run --only headers

echo "== paper bench smoke: collectives (dep lane + INC canary) =="
python -m benchmarks.run --only collectives

echo "OK"
