"""Tests: TSS security rules (Sec. 3.4) and link layer LLR/CBFC (Sec. 3.5)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import link, tss


# ------------------------------------------------------------------- TSS
def test_iv_uniqueness_across_members_and_packets():
    """Nonce discipline: (member, counter) pairs never produce the same
    (key, IV) pair — the AES-GCM reuse attack surface (Sec. 3.4.1)."""
    sd = tss.SecureDomain.create(8)
    seen = set()
    for member in (0, 1, 2):
        key = int(tss.source_key(sd, jnp.int32(member)))
        for _ in range(5):
            sd, hi, lo = tss.iv_for_packet(sd, jnp.int32(member))
            tup = (key, int(hi), int(lo))
            assert tup not in seen
            seen.add(tup)


def test_key_rotation_lifetime():
    sd = tss.SecureDomain.create(2)
    assert not bool(tss.needs_key_rotation(sd)[0])
    sd = tss.SecureDomain(
        sd.sdk, sd.iv_mask, sd.epoch, sd.an,
        sd.pkt_counter, jnp.full((2,), 2 ** 31 - 1, jnp.int32))
    assert bool(tss.needs_key_rotation(sd).all())
    sd2 = tss.rotate_key(sd)
    assert int(sd2.an) == int(sd.an) + 1
    assert int(sd2.key_packets.sum()) == 0
    # derived keys change with AN
    assert int(tss.source_key(sd, jnp.int32(0))) != int(
        tss.source_key(sd2, jnp.int32(0)))


def test_zero_rtt_psn_antireplay():
    """Sec. 3.4.2 scheme 2: after a close at PSN p, any replayed open with
    PSN <= p is NACK'd with the PSN to use; fresh opens are zero-RTT."""
    g = tss.PSNGuard.create(4)
    ok, _ = tss.accept_new_pdc(g, jnp.array([1]), jnp.array([0]))
    assert bool(ok[0])  # initial state accepts (optimistic)
    g = tss.on_pdc_close(g, jnp.array([1]), jnp.array([41]))
    ok, nack = tss.accept_new_pdc(g, jnp.array([1]), jnp.array([41]))
    assert not bool(ok[0]) and int(nack[0]) == 42  # replay rejected
    ok, _ = tss.accept_new_pdc(g, jnp.array([1]), jnp.array([42]))
    assert bool(ok[0])  # ratcheted source reopens with zero RTT


def test_trimmed_packets_never_create_pdcs():
    assert tss.trimmed_packet_may_create_pdc() is False


def test_pdc_close_before_psn_wrap():
    assert not bool(tss.pdc_must_close(jnp.int32(1000)))
    assert bool(tss.pdc_must_close(jnp.int32(2 ** 31 - 1)))


# ------------------------------------------------------------------- LLR
def test_llr_go_back_n_recovers_corruption():
    l = link.LLRLink(replay_capacity=16, timeout=8)
    sent = [l.send() for _ in range(10)]
    # frame 4 corrupted on the wire; receiver NACKs at the gap
    delivered = link.llr_deliver(sent, corrupt={4})
    assert delivered == [0, 1, 2, 3]
    l.on_ack(3)
    resend = l.on_nack(4)
    assert resend[0] == 4 and resend[-1] == 9
    delivered += link.llr_deliver(resend, corrupt=set(), expected=4)
    assert delivered == list(range(10))
    assert l.retransmissions == 6  # go-back-N cost, fine at link RTT


def test_llr_timeout_recovers_tail_loss():
    l = link.LLRLink(replay_capacity=8, timeout=4)
    l.send(); l.send()
    resent = []
    for _ in range(10):
        resent += l.tick()
    assert resent[:2] == [0, 1]  # tail loss recovered by timeout
    l.on_ack(1)
    assert l.in_flight() == 0


def test_llr_replay_buffer_bounded():
    l = link.LLRLink(replay_capacity=4)
    for _ in range(4):
        l.send()
    assert not l.can_send()
    l.on_ack(0)
    assert l.can_send()


# ------------------------------------------------------------------ CBFC
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 9000)),
                min_size=1, max_size=200))
@settings(max_examples=30, deadline=None)
def test_cbfc_never_overruns_buffer(ops):
    """Property: under any send/drain interleaving, occupancy stays within
    the advertised buffer — CBFC's losslessness guarantee."""
    st_ = link.CBFCState(buffer_bytes=32768)
    occupancy = 0
    for is_send, size in ops:
        if is_send and st_.can_send(size):
            st_ = st_.send(size)
            occupancy += size
        elif not is_send and occupancy >= size:
            st_ = st_.drain(size)
            occupancy -= size
        assert 0 <= occupancy <= 32768
        assert st_.available() == 32768 - occupancy


def test_cbfc_counter_wraparound():
    st_ = link.CBFCState(buffer_bytes=4096, consumed=link.CTR_MOD - 100,
                         freed=link.CTR_MOD - 100)
    assert st_.available() == 4096
    st_ = st_.send(1000)       # wraps the 20-bit counter
    assert st_.available() == 3096
    st_ = st_.drain(1000)
    assert st_.available() == 4096


def test_cbfc_beats_pfc_buffer_requirement():
    """Sec. 3.5.2 claim (1): CBFC needs less buffer than PFC headroom for
    lossless operation (2 active VCs vs 8 PFC priorities, 100 m links)."""
    pfc = link.pfc_headroom_bytes(link_gbps=400, cable_m=100, mtu=4096)
    cbfc = link.cbfc_buffer_bytes(link_gbps=400, cable_m=100, mtu=4096)
    assert cbfc < pfc / 2
