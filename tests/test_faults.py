"""Dynamic fault-injection engine + closed recovery loop: contracts.

Locked here (see DESIGN.md "Fault model & recovery contract"):

* the static ``failed=`` mask and its degenerate FaultSchedule
  (``from_mask``) are BITWISE interchangeable, serial and batched —
  the fault engine costs nothing when faults are static;
* ``failed=`` and ``faults=`` are mutually exclusive, and schedules are
  validated (type, queue count, rank);
* LIVENESS: every named profile survives a mid-run flap that heals
  (timeouts fire during the outage, all flows complete after it), and
  escapes a PERMANENT mid-run path failure when ``ev_eviction`` is on
  — including hpc's all-ROD/STATIC pinned paths;
* gray (lossy) links are survived, and the dormant ``ooo_threshold``
  loss-inference path beats pure-RTO recovery on them;
* RTO exponential backoff spaces timeout fires during a dead window and
  is capped by ``rto_max_scale``;
* payload conservation: faults that fully heal change WHEN packets
  arrive, never HOW MANY — delivered first-copies equal the healthy
  run's exactly;
* property sweep: random schedules with a guaranteed surviving path
  never violate liveness or conservation (seeded fallback always runs;
  a hypothesis-driven twin runs where hypothesis is installed).
"""
from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.core.lb.schemes import LBScheme
from repro.core.types import NEVER_TICK
from repro.network.fabric import SimParams, Workload, simulate, simulate_batch
from repro.network.faults import FaultSchedule, loss_threshold
from repro.network.profile import TransportProfile
from repro.network.topology import leaf_spine

NAMED_PROFILES = (TransportProfile.ai_base, TransportProfile.ai_full,
                  TransportProfile.hpc)


def _state_equal(a, b) -> bool:
    return all(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b)))


def _small():
    """2 leaves x 2 spines, 4 hosts/leaf; all flows cross-leaf so every
    packet rides an uplink — uplink faults bite every flow."""
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2, 3], [4, 5, 6, 7], 150)
    ups = [int(g.up1_table[0, i]) for i in range(2)]
    return g, wl, ups


# ------------------------------------------------------------------------
# static masks: the degenerate schedule is bitwise the old failed= path
# ------------------------------------------------------------------------

def test_from_mask_bitwise_equals_failed_serial():
    g, wl, ups = _small()
    mask = np.zeros(g.num_queues, bool)
    mask[ups[0]] = True
    p = SimParams(ticks=900, timeout_ticks=64)
    for prof in (TransportProfile.ai_full(lb=LBScheme.REPS),
                 TransportProfile.hpc()):
        a = simulate(g, wl, prof, p, failed=mask)
        b = simulate(g, wl, prof, p, faults=FaultSchedule.from_mask(mask))
        assert a.horizon == b.horizon, prof.name
        np.testing.assert_array_equal(a.completion_ticks(),
                                      b.completion_ticks())
        assert _state_equal(a.state, b.state), prof.name
        assert b.ticks_degraded == b.horizon  # dead from tick 0 to the end


def test_from_mask_bitwise_equals_failed_batched():
    g, wl, ups = _small()
    wls = Workload.stack([wl, replace(wl, size=wl.size // 2)])
    masks = np.zeros((2, g.num_queues), bool)
    masks[0, ups[0]] = True
    p = SimParams(ticks=900, timeout_ticks=64)
    base = simulate_batch(g, wls, TransportProfile.ai_full(), p, failed=masks)
    via = simulate_batch(g, wls, TransportProfile.ai_full(), p,
                         faults=FaultSchedule.from_mask(masks))
    for i, (a, b) in enumerate(zip(base, via)):
        assert a.horizon == b.horizon, f"scenario {i}"
        np.testing.assert_array_equal(a.completion_ticks(),
                                      b.completion_ticks(),
                                      err_msg=f"scenario {i}")
        assert _state_equal(a.state, b.state), f"scenario {i}"


def test_healthy_schedule_is_bitwise_no_faults():
    g, wl, _ = _small()
    p = SimParams(ticks=700)
    a = simulate(g, wl, TransportProfile.ai_full(), p)
    b = simulate(g, wl, TransportProfile.ai_full(), p,
                 faults=FaultSchedule.healthy(g.num_queues))
    assert a.horizon == b.horizon
    assert _state_equal(a.state, b.state)
    assert b.timeouts == 0 and b.ticks_degraded == 0


# ------------------------------------------------------------------------
# API validation
# ------------------------------------------------------------------------

def test_failed_and_faults_are_mutually_exclusive():
    g, wl, ups = _small()
    sched = FaultSchedule.healthy(g.num_queues)
    with pytest.raises(ValueError, match="not both"):
        simulate(g, wl, TransportProfile.ai_full(), SimParams(ticks=10),
                 failed=np.zeros(g.num_queues, bool), faults=sched)


def test_schedule_validation():
    g, wl, _ = _small()
    p = SimParams(ticks=10)
    with pytest.raises(TypeError, match="FaultSchedule"):
        simulate(g, wl, TransportProfile.ai_full(), p,
                 faults=np.zeros(g.num_queues, bool))
    with pytest.raises(ValueError, match="queues"):
        simulate(g, wl, TransportProfile.ai_full(), p,
                 faults=FaultSchedule.healthy(g.num_queues + 1))
    with pytest.raises(ValueError, match=r"\[Q\]"):
        simulate(g, wl, TransportProfile.ai_full(), p,
                 faults=FaultSchedule.healthy(g.num_queues, batch=2))
    with pytest.raises(ValueError, match="batch axis"):
        simulate_batch(g, Workload.stack([wl, wl, wl]),
                       TransportProfile.ai_full(), p,
                       faults=FaultSchedule.healthy(g.num_queues, batch=2))
    with pytest.raises(ValueError, match=r"\[0, 1\]"):
        FaultSchedule.healthy(g.num_queues).lossy(0, 1.5)


def test_loss_threshold_endpoints():
    import jax.numpy as jnp
    thr = loss_threshold(jnp.asarray([0.0, 0.5, 1.0], jnp.float32))
    t = np.asarray(thr)
    assert t[0] == 0                      # p=0 draws are bitwise inert
    assert t[2] >= np.uint32(4294967040)  # p=1 loses (almost) everything
    assert 0 < t[1] < t[2]


def test_profile_knob_validation():
    with pytest.raises(ValueError, match="rto_backoff"):
        replace(TransportProfile.ai_full(), rto_backoff=0.5)
    with pytest.raises(ValueError, match="rto_max_scale"):
        replace(TransportProfile.ai_full(), rto_max_scale=0)


# ------------------------------------------------------------------------
# liveness: flap-that-heals and permanent-failure escape
# ------------------------------------------------------------------------

@pytest.mark.parametrize("mk", NAMED_PROFILES, ids=lambda m: m.__name__)
def test_flap_recovery_all_named_profiles(mk):
    """Both uplinks die mid-run and heal 300 ticks later: no path exists
    during the window, so progress must stall and then FULLY recover on
    default knobs — timeout-paced retransmission alone suffices."""
    g, wl, ups = _small()
    p = SimParams(ticks=6000, timeout_ticks=64)
    flap = FaultSchedule.healthy(g.num_queues).flap(ups, 120, 420)
    r = simulate(g, wl, mk(), p, faults=flap)
    ct = r.completion_tick()
    assert ct > 420, f"{mk.__name__}: finished {ct}, inside the outage?"
    assert r.timeouts > 0, f"{mk.__name__}: outage fired no RTOs"
    if mk.__name__ != "hpc":
        # hpc is all-ROD: recovery is go-back-N re-injection through the
        # normal PSN path, which the rtx-bitmap counter does not see
        assert r.rtx_packets > 0
    assert r.ticks_degraded == 300
    np.testing.assert_array_equal(np.asarray(r.state.delivered),
                                  np.asarray(wl.size))


@pytest.mark.parametrize(
    "prof",
    [replace(TransportProfile.hpc(), ev_eviction=True, name="hpc+evict"),
     replace(TransportProfile.ai_full(lb=LBScheme.STATIC),
             ev_eviction=True, name="static+evict")],
    ids=["hpc", "static_rud"])
def test_permanent_failure_escaped_by_eviction(prof):
    """One of two uplinks dies for good mid-run. PINNED-path transports
    (hpc's all-ROD pin, STATIC RUD) can only escape via ``ev_eviction``:
    the recovery loop must blacklist the dead path's EV and migrate
    every flow to the survivor."""
    g, wl, ups = _small()
    p = SimParams(ticks=6000, timeout_ticks=64)
    dead = FaultSchedule.healthy(g.num_queues).flap(ups[0], 120)
    r = simulate(g, wl, prof, p, faults=dead)
    assert r.completion_tick() != -1, f"{prof.name}: stuck on dead path"
    assert r.ev_evictions > 0, f"{prof.name}: recovered without evicting?"
    np.testing.assert_array_equal(np.asarray(r.state.delivered),
                                  np.asarray(wl.size))


def test_permanent_failure_escaped_by_spraying():
    """Sprayed transports escape the same permanent failure WITHOUT
    eviction — REPS self-clocking concentrates on recycled (live-path)
    EVs, and oblivious spraying plus OOO loss inference grinds through
    the re-lost retries — and timeout eviction must stay inert for them
    (a last-EV guess would poison REPS's known-good ring)."""
    g, wl, ups = _small()
    dead = FaultSchedule.healthy(g.num_queues).flap(ups[0], 120)
    p = SimParams(ticks=8000, timeout_ticks=64, ooo_threshold=24)
    reps = simulate(g, wl, TransportProfile.ai_full(lb=LBScheme.REPS), p,
                    faults=dead)
    assert reps.completion_tick() != -1
    obl = simulate(g, wl, TransportProfile.ai_full(), p, faults=dead)
    assert obl.completion_tick() != -1
    # eviction on a sprayed profile: NACK-attributed only; must not
    # break the escape (timeout evictions would — test-locked physics)
    reps_ev = simulate(g, wl,
                       replace(TransportProfile.ai_full(lb=LBScheme.REPS),
                               ev_eviction=True, name="reps+evict"),
                       p, faults=dead)
    assert reps_ev.completion_tick() != -1


def test_eviction_beats_no_eviction_on_static_path():
    """The eviction-off STATIC twin of the test above must NOT complete
    (its pinned EV hashes onto the dead uplink forever) — the knob is
    load-bearing, not decorative."""
    g, wl, ups = _small()
    p = SimParams(ticks=4000, timeout_ticks=64)
    dead = FaultSchedule.healthy(g.num_queues).flap(ups[0], 120)
    off = TransportProfile.ai_full(lb=LBScheme.STATIC, name="static")
    r_off = simulate(g, wl, off, p, faults=dead)
    r_on = simulate(g, wl, replace(off, ev_eviction=True,
                                   name="static+evict"), p, faults=dead)
    ct_on = r_on.completion_tick()
    ct_off = r_off.completion_tick()
    assert ct_on != -1
    assert ct_off == -1 or ct_on < ct_off
    assert r_off.ev_evictions == 0


# ------------------------------------------------------------------------
# gray links + loss inference
# ------------------------------------------------------------------------

def test_lossy_link_survived():
    g, wl, ups = _small()
    p = SimParams(ticks=6000, timeout_ticks=64, ooo_threshold=24)
    gray = FaultSchedule.healthy(g.num_queues).lossy(ups, 0.05)
    r = simulate(g, wl, TransportProfile.ai_full(), p, faults=gray)
    assert r.completion_tick() != -1
    assert int(r.state.drops) > 0, "a 5% gray link must drop something"
    assert r.rtx_packets > 0
    assert r.ticks_degraded == 0  # loss is not a dead window
    np.testing.assert_array_equal(np.asarray(r.state.delivered),
                                  np.asarray(wl.size))


def test_loss_draws_follow_seed():
    """Same schedule, different loss seeds => different drop streams
    (and the same seed reproduces exactly)."""
    g, wl, ups = _small()
    p = SimParams(ticks=6000, timeout_ticks=64, ooo_threshold=24)
    gray = FaultSchedule.healthy(g.num_queues).lossy(ups, 0.08)
    r0 = simulate(g, wl, TransportProfile.ai_full(), p, faults=gray)
    r0b = simulate(g, wl, TransportProfile.ai_full(), p, faults=gray)
    r1 = simulate(g, wl, TransportProfile.ai_full(), p,
                  faults=gray.with_seed(7))
    assert _state_equal(r0.state, r0b.state)
    assert int(r0.state.drops) != int(r1.state.drops) \
        or r0.completion_tick() != r1.completion_tick()


def test_ooo_inference_beats_pure_rto_on_gray_link():
    """Sec. 3.2.4's second 'C': with the default generous RTO (256
    ticks), OOO-gap loss inference must recover silent losses much
    earlier than the timeout — completion strictly improves."""
    g, wl, ups = _small()
    gray = FaultSchedule.healthy(g.num_queues).lossy(ups, 0.04)
    rto_only = simulate(g, wl, TransportProfile.ai_full(),
                        SimParams(ticks=8000), faults=gray)
    inferred = simulate(g, wl, TransportProfile.ai_full(),
                        SimParams(ticks=8000, ooo_threshold=24),
                        faults=gray)
    ct_rto, ct_inf = rto_only.completion_tick(), inferred.completion_tick()
    assert ct_inf != -1
    assert ct_rto == -1 or ct_inf < ct_rto, (ct_inf, ct_rto)


# ------------------------------------------------------------------------
# RTO backoff
# ------------------------------------------------------------------------

def test_rto_backoff_spaces_timeouts_and_cap_restores_them():
    """During a long dead window, exponential backoff fires strictly
    fewer RTOs than fixed-RTO; clamping the cap to 1x (rto_max_scale=1)
    makes backoff a no-op and restores the fixed-RTO timeout count."""
    g, wl, ups = _small()
    p = SimParams(ticks=2000, timeout_ticks=32)
    dead = FaultSchedule.healthy(g.num_queues).flap(ups, 100)  # forever
    prof = TransportProfile.ai_full()
    fixed = simulate(g, wl, prof, p, faults=dead)
    backed = simulate(g, wl, replace(prof, rto_backoff=2.0), p, faults=dead)
    capped = simulate(g, wl, replace(prof, rto_backoff=2.0,
                                     rto_max_scale=1), p, faults=dead)
    assert fixed.completion_tick() == -1  # nothing survives: pure stall
    assert fixed.timeouts > 0
    assert backed.timeouts < fixed.timeouts
    assert capped.timeouts == fixed.timeouts


def test_rto_backoff_resets_on_progress():
    """Backoff must not make a HEALING flap slower than ~one extra RTO:
    ACK progress resets the per-flow RTO to its base value."""
    g, wl, ups = _small()
    p = SimParams(ticks=6000, timeout_ticks=64)
    flap = FaultSchedule.healthy(g.num_queues).flap(ups, 120, 420)
    prof = TransportProfile.ai_full()
    base = simulate(g, wl, prof, p, faults=flap)
    backed = simulate(g, wl, replace(prof, rto_backoff=2.0, rto_max_scale=4),
                      p, faults=flap)
    assert backed.completion_tick() != -1
    assert backed.timeouts <= base.timeouts
    # recovery (post-heal) must not blow up: the reset bounds the last
    # pre-heal backoff step to rto_max_scale * timeout_ticks
    assert backed.completion_tick() <= base.completion_tick() \
        + 4 * p.timeout_ticks


# ------------------------------------------------------------------------
# conservation
# ------------------------------------------------------------------------

def test_healing_faults_conserve_payload():
    """A flap + gray window that fully heals changes WHEN first copies
    arrive, never HOW MANY: delivered lanes equal the healthy run's, and
    duplicates never inflate them."""
    g, wl, ups = _small()
    p = SimParams(ticks=8000, timeout_ticks=64, ooo_threshold=24)
    prof = TransportProfile.ai_full(lb=LBScheme.REPS)
    healthy = simulate(g, wl, prof, p)
    sched = (FaultSchedule.healthy(g.num_queues)
             .flap(ups[0], 150, 500).lossy(ups[1], 0.05))
    faulty = simulate(g, wl, prof, p, faults=sched)
    assert faulty.completion_tick() != -1
    np.testing.assert_array_equal(np.asarray(faulty.state.delivered),
                                  np.asarray(healthy.state.delivered))
    np.testing.assert_array_equal(np.asarray(faulty.state.delivered),
                                  np.asarray(wl.size))
    # faults slow things down, they don't speed them up
    assert faulty.completion_tick() >= healthy.completion_tick()


# ------------------------------------------------------------------------
# property sweep: random schedules with a guaranteed surviving path
# ------------------------------------------------------------------------

def _check_random_schedule(rng: np.random.Generator) -> None:
    """One property draw: random flap windows (all healed by 1500) and
    gray lanes on the uplinks of a 3-spine fabric, with one uplink per
    leaf left untouched — liveness and conservation must hold."""
    g = leaf_spine(leaves=2, spines=3, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2], [4, 5, 6], 120)
    sched = FaultSchedule.healthy(g.num_queues)
    survivor = int(rng.integers(3))   # this spine stays pristine
    for leaf in range(2):
        for s in range(3):
            if s == survivor:
                continue
            q = int(g.up1_table[leaf, s])
            if rng.random() < 0.7:
                start = int(rng.integers(0, 900))
                sched = sched.flap(q, start,
                                   start + int(rng.integers(50, 600)))
            if rng.random() < 0.5:
                sched = sched.lossy(q, float(rng.uniform(0.01, 0.3)))
    sched = sched.with_seed(int(rng.integers(2**32)))
    r = simulate(g, wl, TransportProfile.ai_full(),
                 SimParams(ticks=6000, timeout_ticks=64, ooo_threshold=24),
                 faults=sched)
    assert r.completion_tick() != -1, "guaranteed-survivor run stalled"
    np.testing.assert_array_equal(np.asarray(r.state.delivered),
                                  np.asarray(wl.size))
    dead = np.asarray(sched.dead_at(0))
    assert not dead[int(g.up1_table[0, survivor])]


@pytest.mark.slow
def test_random_fault_schedules_never_violate_liveness():
    """Seeded fallback sweep — always runs, hypothesis or not."""
    for seed in range(4):
        _check_random_schedule(np.random.default_rng(seed))


@pytest.mark.slow
def test_random_fault_schedules_property_hypothesis():
    pytest.importorskip(
        "hypothesis",
        reason="property twin needs hypothesis (the seeded fallback above "
               "covers the contract without it)")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(min_value=0, max_value=2**32 - 1))
    def prop(seed):
        _check_random_schedule(np.random.default_rng(seed))

    prop()


# ------------------------------------------------------------------------
# batched sweeps carry per-scenario schedules
# ------------------------------------------------------------------------

def test_batched_schedules_match_serial_lanes():
    """A [B, Q] stacked schedule (healthy / flap / gray / permanent+evict
    profile) rides the scenario axis bitwise — each lane equals its
    serial twin, eviction lanes included."""
    g, wl, ups = _small()
    p = SimParams(ticks=4000, timeout_ticks=64, ooo_threshold=24)
    scheds = [
        FaultSchedule.healthy(g.num_queues),
        FaultSchedule.healthy(g.num_queues).flap(ups, 120, 420),
        FaultSchedule.healthy(g.num_queues).lossy(ups, 0.05),
        FaultSchedule.healthy(g.num_queues).flap(ups[0], 120),
    ]
    prof = replace(TransportProfile.ai_full(lb=LBScheme.REPS),
                   ev_eviction=True, rto_backoff=2.0, name="sweep")
    batch = simulate_batch(g, Workload.stack([wl] * 4), prof, p,
                           faults=FaultSchedule.stack(scheds))
    assert all(r.completion_tick() != -1 for r in batch)
    assert batch[1].ticks_degraded == 300
    for i, (sched, r) in enumerate(zip(scheds, batch)):
        solo = simulate(g, wl, prof, p, faults=sched)
        assert solo.horizon == r.horizon, f"scenario {i}"
        np.testing.assert_array_equal(solo.completion_ticks(),
                                      r.completion_ticks(),
                                      err_msg=f"scenario {i}")
        assert _state_equal(solo.state, r.state), f"scenario {i}"
