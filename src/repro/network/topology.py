"""Parametric Clos / fat-tree topologies (Sec. 2.1, Fig. 2).

Two families:

* `LeafSpine` — 2-level Clos: L leaves × S spines, H hosts per leaf.
  Oversubscription = hosts_per_leaf / S (Fig. 7 uses 2:1-style oversub).
* `FatTree3` — 3-level k-ary fat tree (the Fig. 2 topology): pods of
  (k/2 leaves × k/2 hosts each) + k/2 aggs, (k/2)^2 cores. With k=8 and
  4 pods this is exactly the paper's 64-endpoint example: 4 equal-cost
  paths within a pod, 16 across pods.

The simulator operates on directed *queues* (one egress FIFO per link).
`QueueGraph` enumerates them and provides static routing metadata; the
per-packet ECMP choice happens in `repro/network/ecmp.py`.

Queue stages (generic across both families):
  UP1:  leaf -> spine/agg          DOWN1: agg/spine -> leaf
  UP2:  agg  -> core   (3-level)   DOWN2: core -> agg (3-level)
  HOST: leaf -> host (the destination downlink)
"""
from __future__ import annotations

import enum
from dataclasses import dataclass

import numpy as np


class Stage(enum.IntEnum):
    UP1 = 0
    UP2 = 1
    DOWN2 = 2
    DOWN1 = 3
    HOST = 4
    DELIVERED = 5


@dataclass(frozen=True)
class QueueGraph:
    """Static queue enumeration + routing tables (NumPy; built once).

    num_queues:   total directed-link FIFOs
    stage:        [Q] Stage code of each queue
    host_queue:   [H] queue id of each host's final downlink
    up1:          [L, S1] queue ids leaf->spine(2lvl) or leaf->agg(3lvl,
                  S1 = aggs per pod)
    down1:        [S1_total, L_local] spine->leaf or agg->leaf queue ids
    up2/down2:    3-level only (else shape (0,0))
    host_leaf:    [H] leaf id of each host
    host_pod:     [H] pod id (2-level: == leaf id)
    fanout1:      spray choices at injection (== S for 2lvl, aggs/pod 3lvl)
    fanout2:      second spray stage (cores per agg; 0 for 2-level)
    """

    name: str
    num_queues: int
    num_hosts: int
    stage: np.ndarray
    host_queue: np.ndarray
    host_leaf: np.ndarray
    host_pod: np.ndarray
    # routing helper tables, -1 where n/a
    up1_table: np.ndarray     # [L, F1] leaf-local uplink choice -> queue id
    down1_table: np.ndarray   # [A, Lp] agg/spine -> leaf queue id
    up2_table: np.ndarray     # [A, F2] agg -> core queue id
    down2_table: np.ndarray   # [C, P] core -> (pod) agg queue id
    queue_next_switch: np.ndarray  # [Q] switch id the queue feeds into (-1 host)
    fanout1: int
    fanout2: int
    num_paths_same_pod: int
    num_paths_cross_pod: int
    diameter_hops: int


def leaf_spine(leaves: int, spines: int, hosts_per_leaf: int) -> QueueGraph:
    """2-level Clos. Queues: UP1 (L*S), DOWN1 (S*L), HOST (H)."""
    L, S, Hp = leaves, spines, hosts_per_leaf
    H = L * Hp
    q = 0
    up1 = np.zeros((L, S), np.int32)
    for l in range(L):
        for s in range(S):
            up1[l, s] = q
            q += 1
    down1 = np.zeros((S, L), np.int32)
    for s in range(S):
        for l in range(L):
            down1[s, l] = q
            q += 1
    host_q = np.arange(q, q + H, dtype=np.int32)
    q += H
    stage = np.zeros((q,), np.int32)
    stage[up1.ravel()] = Stage.UP1
    stage[down1.ravel()] = Stage.DOWN1
    stage[host_q] = Stage.HOST
    host_leaf = np.repeat(np.arange(L, dtype=np.int32), Hp)
    # queue -> switch it feeds into: up1 -> spine s; down1 -> leaf l; host -> -1
    nxt = np.full((q,), -1, np.int32)
    for l in range(L):
        for s in range(S):
            nxt[up1[l, s]] = L + s        # switches: leaves [0,L), spines [L, L+S)
            nxt[down1[s, l]] = l
    return QueueGraph(
        name=f"leafspine_L{L}_S{S}_H{Hp}",
        num_queues=q, num_hosts=H, stage=stage, host_queue=host_q,
        host_leaf=host_leaf, host_pod=host_leaf,
        up1_table=up1, down1_table=down1,
        up2_table=np.zeros((0, 0), np.int32),
        down2_table=np.zeros((0, 0), np.int32),
        queue_next_switch=nxt,
        fanout1=S, fanout2=0,
        num_paths_same_pod=S, num_paths_cross_pod=S,
        diameter_hops=3,  # host->leaf->spine->leaf->host: 3 queue traversals
    )


def fat_tree3(k: int, pods: int) -> QueueGraph:
    """3-level k-ary fat tree with `pods` pods (pods <= k).

    Per pod: k/2 leaves (each k/2 hosts down, k/2 aggs up), k/2 aggs.
    Cores: (k/2)^2; agg j in every pod connects to cores
    [j*(k/2), (j+1)*(k/2)).  Paper example: k=8, pods=4 -> 64 hosts,
    4 same-pod paths, 16 cross-pod paths.
    """
    half = k // 2
    Lp = half           # leaves per pod
    Ap = half           # aggs per pod
    Hp = half           # hosts per leaf
    C = half * half     # cores
    L = pods * Lp
    A = pods * Ap
    H = L * Hp

    q = 0
    up1 = np.zeros((L, Ap), np.int32)          # leaf -> agg (within pod)
    for l in range(L):
        for a in range(Ap):
            up1[l, a] = q
            q += 1
    up2 = np.zeros((A, half), np.int32)        # agg -> its k/2 cores
    for a in range(A):
        for c in range(half):
            up2[a, c] = q
            q += 1
    down2 = np.zeros((C, pods), np.int32)      # core -> agg (one per pod)
    for c in range(C):
        for p in range(pods):
            down2[c, p] = q
            q += 1
    down1 = np.zeros((A, Lp), np.int32)        # agg -> leaf (within pod)
    for a in range(A):
        for l in range(Lp):
            down1[a, l] = q
            q += 1
    host_q = np.arange(q, q + H, dtype=np.int32)
    q += H

    stage = np.zeros((q,), np.int32)
    stage[up1.ravel()] = Stage.UP1
    stage[up2.ravel()] = Stage.UP2
    stage[down2.ravel()] = Stage.DOWN2
    stage[down1.ravel()] = Stage.DOWN1
    stage[host_q] = Stage.HOST

    host_leaf = np.repeat(np.arange(L, dtype=np.int32), Hp)
    host_pod = host_leaf // Lp

    # switch ids: leaves [0,L), aggs [L, L+A), cores [L+A, L+A+C)
    nxt = np.full((q,), -1, np.int32)
    for l in range(L):
        pod = l // Lp
        for a in range(Ap):
            nxt[up1[l, a]] = L + pod * Ap + a
    for a in range(A):
        j = a % Ap
        for c in range(half):
            nxt[up2[a, c]] = L + A + j * half + c
    for c in range(C):
        for p in range(pods):
            nxt[down2[c, p]] = L + p * Ap + (c // half)
    for a in range(A):
        pod = a // Ap
        for l in range(Lp):
            nxt[down1[a, l]] = pod * Lp + l

    return QueueGraph(
        name=f"fattree3_k{k}_p{pods}",
        num_queues=q, num_hosts=H, stage=stage, host_queue=host_q,
        host_leaf=host_leaf, host_pod=host_pod,
        up1_table=up1, down1_table=down1, up2_table=up2, down2_table=down2,
        queue_next_switch=nxt,
        fanout1=Ap, fanout2=half,
        num_paths_same_pod=Ap, num_paths_cross_pod=Ap * half,
        diameter_hops=5,
    )


def paper_fig2() -> QueueGraph:
    """The paper's Fig. 2 example: 8-port switches, 64 endpoints, 4 groups."""
    return fat_tree3(k=8, pods=4)
