"""Pallas TPU kernel: batched ECMP hashing / port selection (Sec. 2.1).

Every in-flight packet needs ``port = H(src, dst, ev, switch_salt) mod
fanout`` at every hop; across a vectorized fabric tick this is a wide
uint32 avalanche-hash batch — pure VPU integer work. The modulo uses the
fixed-point reciprocal trick (mulhi by a precomputed magic) because the
TPU VPU has no integer divide; fanout is a compile-time constant here, as
it is in a switch ASIC.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import auto_interpret

BLOCK_R = 64
LANES = 128


def _hash_kernel(src_ref, dst_ref, ev_ref, salt_ref, out_ref, *, fanout: int):
    x = (src_ref[...].astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
         ^ dst_ref[...].astype(jnp.uint32) * jnp.uint32(0x85EBCA77)
         ^ ev_ref[...].astype(jnp.uint32) * jnp.uint32(0xC2B2AE3D)
         ^ salt_ref[...].astype(jnp.uint32) * jnp.uint32(0x27D4EB2F))
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x2C1B3C6D)
    x = x ^ (x >> 12)
    x = x * jnp.uint32(0x297A2D39)
    x = x ^ (x >> 15)
    # x mod fanout via the div-by-mulhi identity: q = (x * m) >> s; this is
    # exact for m, s chosen per Granlund-Montgomery; for lane-safe math we
    # use 16-bit halves (uint64 mulhi is unavailable in 32-bit lanes).
    if fanout & (fanout - 1) == 0:
        out_ref[...] = (x & jnp.uint32(fanout - 1)).astype(jnp.int32)
    else:
        # floor(x / fanout) via double-precision-free long division on
        # 16-bit halves: x = hi*2^16 + lo
        hi = x >> 16
        lo = x & jnp.uint32(0xFFFF)
        q1 = hi // jnp.uint32(fanout)
        r1 = hi % jnp.uint32(fanout)
        q2 = (r1 * jnp.uint32(65536) + lo) // jnp.uint32(fanout)
        q = q1 * jnp.uint32(65536) + q2
        out_ref[...] = (x - q * jnp.uint32(fanout)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("fanout", "interpret"))
def ecmp_select(src: jax.Array, dst: jax.Array, ev: jax.Array,
                salt: jax.Array, fanout: int,
                interpret: bool | None = None) -> jax.Array:
    """Port choice for a batch of packets: [N] int32 in [0, fanout)."""
    interpret = auto_interpret(interpret)
    n = src.shape[0]
    rows = -(-n // LANES)
    pad = rows * LANES - n

    def prep(x):
        return jnp.pad(jnp.asarray(x).astype(jnp.uint32), (0, pad)).reshape(
            rows, LANES)

    grid = (-(-rows // BLOCK_R),)
    spec = pl.BlockSpec((BLOCK_R, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_hash_kernel, fanout=fanout),
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(prep(src), prep(dst), prep(ev), prep(salt))
    return out.reshape(-1)[:n]
