"""Parallelism plans: ArchConfig + mesh shape -> per-step collective demand.

A `ParallelismPlan` is the static, analytic half of the model-driven
traffic engine (`repro.network.traffic` is the dynamic half that compiles
a plan onto the packet fabric). It answers: for THIS architecture on THIS
(dp, tp, pp) mesh under THIS sharding layout, which collectives run every
step, over which group sizes, moving how many bytes per rank?

The classification is NOT a re-derivation: every parameter leaf from
`ArchConfig.param_leaves()` is classified by the real sharding rule
(`sharding.param_pspec`) — "data" in the spec means the leaf is FSDP-
sharded (per-step param all-gathers + grad reduce-scatter over DP),
no "data" means the gradient is all-reduced; "model" means the leaf is
TP-sharded (its DP payload shrinks by 1/tp). The two supported layouts
mirror the real pspec builders:

* ``fsdp_tp``  — `sharding.param_pspecs`       (2-D ZeRO-3 x Megatron)
* ``tp_only``  — `sharding.param_pspecs_tp_only` (serving layout; no DP
  param/grad collectives for inference, full-size grad all-reduce if
  trained)

Byte volumes are per-rank INPUT denominated, matching
`collectives.CollectiveSpec`. All derivation is pure python/numpy:
same (config, shape, mesh, layout) -> bitwise-identical plan.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES
from repro.distributed import sharding


# ---------------------------------------------------------------------------
# demand records
# ---------------------------------------------------------------------------

# scope -> what the group is made of
SCOPE_TP = "tp"        # tensor-parallel group (contiguous, intra-leaf ideally)
SCOPE_DP = "dp"        # data-parallel group (crosses the fabric)
SCOPE_PP = "pp"        # pipeline neighbours
SCOPE_SERVE = "serve"  # serving frontend incast


@dataclass(frozen=True)
class CollectiveDemand:
    """One per-step collective requirement.

    bytes_per_rank is the per-rank INPUT payload (CollectiveSpec
    denomination); count is how many times per step this collective runs
    (e.g. TP all-reduces run `count` times across the layers of a stage);
    concurrent is how many disjoint groups run it in parallel (DP
    collectives run once per TP rank, on disjoint host sets)."""
    phase: str            # "tp_stream" | "dp_grad" | "dp_param" | "pp_p2p" | "serve_incast"
    kind: str             # collectives kind: all_reduce/reduce_scatter/all_gather/p2p/incast
    scope: str
    n: int                # group size
    bytes_per_rank: float
    count: int = 1
    concurrent: int = 1


@dataclass(frozen=True)
class ParallelismPlan:
    arch: str
    shape: str
    kind: str             # train | prefill | decode
    dp: int
    tp: int
    pp: int
    layout: str           # fsdp_tp | tp_only
    dtype_bytes: int
    num_layers: int
    d_model: int
    global_batch: int
    tokens_per_step: int
    param_bytes: int          # full model, all leaves
    active_param_bytes: int
    demands: tuple            # tuple[CollectiveDemand, ...]

    @property
    def devices(self) -> int:
        return self.dp * self.tp * self.pp

    def demands_for(self, scope: str) -> tuple:
        return tuple(d for d in self.demands if d.scope == scope)

    # -- analytic pricing (alpha-beta, bandwidth-only lower bounds) ---------

    def analytic_net_seconds(self, bw_bytes_per_s: float) -> dict:
        """Per-scope bandwidth-bound lower-bound seconds for one step.

        Groups in the same scope run concurrently on disjoint hosts, so a
        scope's time is the per-group time, not the sum over groups."""
        out: dict[str, float] = {}
        for d in self.demands:
            t = collective_seconds(d.kind, d.n, d.bytes_per_rank,
                                   bw_bytes_per_s) * d.count
            out[d.scope] = out.get(d.scope, 0.0) + t
        return out

    def compute_seconds(self, peak_flops: float) -> float:
        mult = 6 if self.kind == "train" else 2
        flops = mult * (self.active_param_bytes / self.dtype_bytes) \
            * self.tokens_per_step / self.devices
        return flops / peak_flops

    def memory_seconds(self, hbm_bw: float) -> float:
        """Heuristic HBM term: decode is weight-read bound (each TP shard
        streams its resident weights once per token step); train reads
        params + writes grads/updates, sharded over all devices."""
        if self.kind == "decode":
            return (self.active_param_bytes / self.tp) / hbm_bw
        return 3 * (self.param_bytes / self.devices) / hbm_bw


def collective_seconds(kind: str, n: int, bytes_per_rank: float,
                       bw_bytes_per_s: float) -> float:
    """Bandwidth-term alpha-beta time, per-rank-INPUT denominated
    (same convention as `collectives.CollectiveSpec` / `analytic_ticks`)."""
    if n <= 1:
        return 0.0
    m = bytes_per_rank / bw_bytes_per_s
    if kind == "all_reduce":
        return 2 * (n - 1) / n * m
    if kind == "reduce_scatter":
        return (n - 1) / n * m
    if kind == "all_gather":      # input block per rank -> (n-1) blocks rx'd
        return (n - 1) * m
    if kind == "all_to_all":
        return (n - 1) / n * m
    if kind == "p2p":
        return m
    if kind == "incast":          # n senders share one receiver downlink
        return n * m
    raise ValueError(f"unknown collective kind {kind!r}")


# ---------------------------------------------------------------------------
# leaf classification via the real sharding rules
# ---------------------------------------------------------------------------

class _LeafShim:
    """Duck-typed leaf for `sharding.param_pspec` (only .ndim is read)."""
    __slots__ = ("ndim",)

    def __init__(self, ndim: int):
        self.ndim = ndim


def classify_leaves(cfg: ArchConfig, layout: str) -> list:
    """[(path, shape, tp_sharded, dp_sharded)] via `sharding.param_pspec`."""
    out = []
    for path, shape in cfg.param_leaves():
        stacked = path[0] == "blocks"
        spec = sharding.param_pspec(path, _LeafShim(len(shape)),
                                    "data", stacked)
        axes = set(a for a in spec if a is not None)
        tp_sh = "model" in axes
        dp_sh = "data" in axes and layout == "fsdp_tp"
        out.append((path, shape, tp_sh, dp_sh))
    return out


def _numel(shape: Iterable[int]) -> int:
    return math.prod(shape)


# ---------------------------------------------------------------------------
# plan derivation
# ---------------------------------------------------------------------------

LAYOUTS = ("fsdp_tp", "tp_only")


def derive_plan(cfg: ArchConfig, shape: "ShapeConfig | str", *,
                dp: int, tp: int, pp: int = 1,
                layout: str = "fsdp_tp",
                dtype_bytes: int = 2) -> ParallelismPlan:
    """Derive the per-step collective demand for cfg on a (dp, tp, pp) mesh.

    Per-step phases emitted (train):
      tp_stream   — 2 fwd + 2 bwd activation all-reduces per layer over TP
      dp_param    — ZeRO-3 param all-gathers over DP (fwd + remat bwd),
                    fsdp_tp layout only
      dp_grad     — grad reduce-scatter (FSDP leaves) + grad all-reduce
                    (replicated leaves) over DP
      pp_p2p      — activation sends between stages, 2x per microbatch

    Inference (prefill/decode): tp_stream at 2 all-reduces per layer; the
    fsdp_tp layout pays ONE param all-gather per step (the decode penalty
    the tp_only serving layout exists to remove); decode adds a
    serve_incast phase (request fan-in at the serving frontend).
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    if layout not in LAYOUTS:
        raise ValueError(f"layout must be one of {LAYOUTS}, got {layout!r}")
    if min(dp, tp, pp) < 1:
        raise ValueError("dp/tp/pp must be >= 1")
    if cfg.num_layers % pp:
        raise ValueError(f"pp={pp} does not divide num_layers={cfg.num_layers}")

    kind = shape.kind
    train = kind == "train"
    tokens_per_step = shape.global_batch * (shape.seq_len if kind != "decode"
                                            else 1)
    tokens_local = tokens_per_step / dp          # per DP replica
    layers_per_stage = cfg.num_layers // pp
    D = cfg.d_model

    leaves = classify_leaves(cfg, layout)
    param_bytes = sum(_numel(s) for _, s, _, _ in leaves) * dtype_bytes
    # active bytes scale the same way param counts do
    active_frac = cfg.active_param_count() / max(1, cfg.param_count())
    active_param_bytes = int(param_bytes * active_frac)

    # per-TP-rank resident bytes, split FSDP vs replicated-over-dp
    fsdp_shard = 0.0
    repl_shard = 0.0
    for _, s, tp_sh, dp_sh in leaves:
        b = _numel(s) * dtype_bytes / (tp if tp_sh else 1) / pp
        if dp_sh:
            fsdp_shard += b
        else:
            repl_shard += b

    demands: list[CollectiveDemand] = []

    # -- TP activation stream (the per-layer phase chain) -------------------
    if tp > 1:
        ar_per_layer = 4 if train else 2
        demands.append(CollectiveDemand(
            phase="tp_stream", kind="all_reduce", scope=SCOPE_TP, n=tp,
            bytes_per_rank=tokens_local * D * dtype_bytes,
            count=ar_per_layer * layers_per_stage, concurrent=dp * pp))

    # -- DP param / grad collectives ---------------------------------------
    if dp > 1 and fsdp_shard > 0:
        gathers = 2 if train else 1
        demands.append(CollectiveDemand(
            phase="dp_param", kind="all_gather", scope=SCOPE_DP, n=dp,
            bytes_per_rank=fsdp_shard / dp, count=gathers, concurrent=tp * pp))
    if train and dp > 1:
        if fsdp_shard > 0:
            demands.append(CollectiveDemand(
                phase="dp_grad", kind="reduce_scatter", scope=SCOPE_DP, n=dp,
                bytes_per_rank=fsdp_shard, count=1, concurrent=tp * pp))
        if repl_shard > 0:
            demands.append(CollectiveDemand(
                phase="dp_grad", kind="all_reduce", scope=SCOPE_DP, n=dp,
                bytes_per_rank=repl_shard, count=1, concurrent=tp * pp))

    # -- PP activation point-to-point --------------------------------------
    if pp > 1:
        micro = max(pp, 4)
        per_send = tokens_local / micro * D * dtype_bytes
        sends = (2 if train else 1) * (pp - 1) * micro
        demands.append(CollectiveDemand(
            phase="pp_p2p", kind="p2p", scope=SCOPE_PP, n=2,
            bytes_per_rank=per_send, count=sends, concurrent=dp))

    # -- decode-time serving incast ----------------------------------------
    if kind == "decode":
        fan = 4
        demands.append(CollectiveDemand(
            phase="serve_incast", kind="incast", scope=SCOPE_SERVE, n=fan,
            bytes_per_rank=shape.global_batch * 256 / fan, count=1))

    return ParallelismPlan(
        arch=cfg.name, shape=shape.name, kind=kind, dp=dp, tp=tp, pp=pp,
        layout=layout, dtype_bytes=dtype_bytes, num_layers=cfg.num_layers,
        d_model=D, global_batch=shape.global_batch,
        tokens_per_step=tokens_per_step, param_bytes=param_bytes,
        active_param_bytes=active_param_bytes, demands=tuple(demands))


def replan_onto_survivors(plan: ParallelismPlan,
                          failed_hosts: int = 1) -> ParallelismPlan:
    """Recovery replan: re-derive the collective schedule on the mesh
    that SURVIVES ``failed_hosts`` node losses.

    A DP replica spans ``tp * pp`` devices and a failed host takes its
    whole replica out of rotation (worst case: every failed host hits a
    distinct replica), so the surviving mesh is ``dp - failed_hosts``
    replicas wide. The checkpoint restores elastically onto it
    (``repro.ckpt.checkpointing.restore`` reshards on load), the global
    batch is unchanged — each survivor carries more tokens and a larger
    per-rank DP payload, which is exactly the degraded step time the
    recovery-pricing path charges until the fleet is healed."""
    if failed_hosts < 0:
        raise ValueError(f"failed_hosts must be >= 0, got {failed_hosts}")
    if failed_hosts == 0:
        return plan
    new_dp = plan.dp - failed_hosts
    if new_dp < 1:
        raise ValueError(
            f"cannot replan: {failed_hosts} failed hosts leave no "
            f"surviving DP replica (dp={plan.dp})")
    from repro import configs
    cfg = configs.get(plan.arch)
    return derive_plan(cfg, plan.shape, dp=new_dp, tp=plan.tp, pp=plan.pp,
                       layout=plan.layout, dtype_bytes=plan.dtype_bytes)


def describe(plan: ParallelismPlan) -> str:
    lines = [f"{plan.arch} x {plan.shape}: dp={plan.dp} tp={plan.tp} "
             f"pp={plan.pp} layout={plan.layout} "
             f"({plan.param_bytes / 1e9:.1f} GB params)"]
    for d in plan.demands:
        lines.append(f"  {d.phase:12s} {d.kind:14s} n={d.n:<3d} "
                     f"{d.bytes_per_rank / 1e6:10.3f} MB/rank x{d.count}")
    return "\n".join(lines)
