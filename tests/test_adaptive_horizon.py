"""Adaptive-horizon engine: chunked while-scan, quiescence early-exit,
streaming stat lanes, and horizon-free executables.

Contracts locked here (see DESIGN.md "Chunked while-scan driver"):

* chunked-vs-fixed bitwise parity: the executed trace is a PREFIX of
  the fixed-horizon golden lanes (PR-2 goldens, both configs incl.
  REPS + failure + non-default seed), and the golden tail is inert;
* ``trace="stats"`` results equal the stats derived from a
  ``trace="full"`` run — completion ticks, source completion, windowed
  goodput, and the final state, bitwise;
* the early-exited completion ticks equal the golden-derived ones;
* a scenario that never completes runs to ``max_ticks`` (and batches
  fine next to early-exiting lanes, each frozen at its own boundary);
* the tick budget is traced: different horizons share one executable;
* goodput window semantics on early-exited traces (zero-extension past
  the horizon, clamp to the budget, ValueError past the budget);
* INC on/off as a traced axis: ``red=-1`` under an ``inc=True`` profile
  is bitwise the ``inc=False`` executable.
"""
import os
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lb.schemes import LBScheme
from repro.network import collectives as coll
from repro.network.fabric import (SimParams, Workload, _cache_key, simulate,
                                  simulate_batch)
from repro.network.profile import TransportProfile
from repro.network.topology import leaf_spine

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "fabric_golden.npz")


def _state_equal(a, b) -> bool:
    return all(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b)))


def _config_a():
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2], [4, 5, 6], 200)
    return g, wl, SimParams(ticks=300)


# ------------------------------------------------------------------------
# early exit + golden prefix parity
# ------------------------------------------------------------------------

def test_early_exit_is_chunk_aligned_prefix_of_golden():
    gold = np.load(GOLDEN)
    g, wl, p = _config_a()
    r = simulate(g, wl, TransportProfile.ai_full(), p, trace="full")
    assert 0 < r.horizon < 300 and r.horizon % p.chunk_ticks == 0
    np.testing.assert_array_equal(r.delivered_per_tick,
                                  gold["a_delivered"][:r.horizon])
    np.testing.assert_array_equal(r.rx_base_per_tick.shape,
                                  (r.horizon, 3))
    # the golden tail is provably inert: quiescence means a longer run
    # delivers nothing more
    assert (gold["a_delivered"][r.horizon:] == 0).all()
    np.testing.assert_array_equal(np.asarray(r.state.delivered),
                                  gold["a_state_delivered"])


def test_early_exit_completion_equals_golden_completion():
    """The streamed completion lane must equal the completion derived
    from the fixed-horizon golden trace."""
    gold = np.load(GOLDEN)
    g, wl, p = _config_a()
    r = simulate(g, wl, TransportProfile.ai_full(), p)  # trace="stats"
    cum = gold["a_delivered"].cumsum(axis=0)
    reached = cum >= np.asarray(wl.size)[None, :]
    golden_ct = np.where(reached.any(0), reached.argmax(axis=0), -1)
    np.testing.assert_array_equal(r.completion_ticks(), golden_ct)


# ------------------------------------------------------------------------
# trace="stats" == trace="full"-derived statistics (bitwise)
# ------------------------------------------------------------------------

def _assert_stats_match(rs, rf, window):
    np.testing.assert_array_equal(rs.completion_ticks(),
                                  rf.completion_ticks())
    np.testing.assert_array_equal(rs.source_completion_ticks(),
                                  rf.source_completion_ticks())
    np.testing.assert_array_equal(rs.goodput(window), rf.goodput(window))
    np.testing.assert_array_equal(rs.goodput(), rf.goodput())
    assert rs.horizon == rf.horizon
    assert rs.qlen_peak == int(rf.qlen_max.max()) if rf.horizon else True
    assert _state_equal(rs.state, rf.state), "trace tiers diverged in state"


def test_stats_equals_full_derived_plain():
    g, wl, p = _config_a()
    win = (50, 250)
    rf = simulate(g, wl, TransportProfile.ai_full(), p, trace="full")
    rs = simulate(g, wl, TransportProfile.ai_full(), p, trace="stats",
                  goodput_window=win)
    _assert_stats_match(rs, rf, win)


def test_stats_equals_full_derived_reps_failure_seed():
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=8)
    wl = Workload.of(list(range(8)), [8 + i for i in range(8)], 300)
    prof = TransportProfile.ai_full(lb=LBScheme.REPS)
    p = SimParams(ticks=900, timeout_ticks=64, ooo_threshold=24)
    dead = (int(g.up1_table[0, 1]),)
    win = (100, 900)
    rf = simulate(g, wl, prof, p, failed=dead, seed=0x5EED + 7,
                  trace="full")
    rs = simulate(g, wl, prof, p, failed=dead, seed=0x5EED + 7,
                  trace="stats", goodput_window=win)
    _assert_stats_match(rs, rf, win)


def test_stats_equals_full_derived_inc_collective_batch():
    """Dep-scheduled tree all-reduce with INC, batched: the stats tier
    must match the dense tier lane for lane."""
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=4)
    prof = replace(TransportProfile.ai_full(), inc=True, name="ai_full+inc")
    p = SimParams(ticks=800)
    spec = coll.CollectiveSpec("all_reduce", tuple(range(8)), 24)
    wls = Workload.stack([coll.build_workload(spec, "tree"),
                          coll.build_workload(spec, "tree",
                                              inc_groups=False)])
    win = (0, 800)
    full = simulate_batch(g, wls, prof, p, trace="full")
    stats = simulate_batch(g, wls, prof, p, trace="stats",
                           goodput_window=win)
    for rs, rf in zip(stats, full):
        _assert_stats_match(rs, rf, win)
    assert int(stats[0].state.inc_reduced) > 0
    assert int(stats[1].state.inc_reduced) == 0


# ------------------------------------------------------------------------
# budgets: never-completing lanes, max_ticks bound, horizon-free cache
# ------------------------------------------------------------------------

def test_never_completing_lane_runs_to_budget():
    """One lane completes (early exit at its own boundary), the other
    can't finish in the budget: it must run to max_ticks exactly and
    report -1 completions."""
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    small = Workload.of([0, 1], [2, 3], 60)
    huge = Workload.of([0, 1], [2, 3], 10**6)
    p = SimParams(ticks=500)
    done, undone = simulate_batch(g, Workload.stack([small, huge]),
                                  TransportProfile.ai_full(), p)
    assert done.horizon < 500 and (done.completion_ticks() >= 0).all()
    assert undone.horizon == 500
    assert (undone.completion_ticks() == -1).all()
    assert undone.completion_tick() == -1
    # the early lane is frozen at its own boundary: bitwise == serial
    solo = simulate(g, small, TransportProfile.ai_full(), p)
    assert solo.horizon == done.horizon
    assert _state_equal(solo.state, done.state)


def test_max_ticks_is_a_plain_traced_bound():
    """max_ticks= overrides p.ticks, never overruns (non-chunk-multiple
    budgets freeze mid-chunk), and equals a run whose p.ticks says the
    same thing."""
    g, wl, p = _config_a()
    prof = TransportProfile.ai_full()
    a = simulate(g, wl, prof, p, max_ticks=100, trace="full")
    assert a.horizon == 100 and a.max_ticks == 100
    b = simulate(g, wl, prof, SimParams(ticks=100), trace="full")
    np.testing.assert_array_equal(a.delivered_per_tick, b.delivered_per_tick)
    assert _state_equal(a.state, b.state)
    assert a.completion_tick() == -1  # 200-packet messages can't finish


def test_one_executable_serves_every_horizon():
    """The compile-cache key must not depend on the tick budget: runs at
    different horizons (via p.ticks or max_ticks) share one executable."""
    g, wl, _ = _config_a()
    prof = TransportProfile.ai_full()
    assert (_cache_key(g, prof, SimParams(ticks=100), 3, False, "stats")
            == _cache_key(g, prof, SimParams(ticks=9999), 3, False, "stats"))
    from repro.network.fabric import _RUN_CACHE
    simulate(g, wl, prof, SimParams(ticks=64))
    n0 = len(_RUN_CACHE)
    simulate(g, wl, prof, SimParams(ticks=192))
    simulate(g, wl, prof, SimParams(ticks=64), max_ticks=320)
    assert len(_RUN_CACHE) == n0, "a new horizon recompiled the engine"
    # but the chunk size IS a compiled constant
    assert (_cache_key(g, prof, SimParams(chunk_ticks=64), 3, False, "stats")
            != _cache_key(g, prof, SimParams(chunk_ticks=128), 3, False,
                          "stats"))


# ------------------------------------------------------------------------
# goodput / completion semantics on early-exited traces
# ------------------------------------------------------------------------

def test_goodput_zero_extends_past_horizon():
    """Windows reaching past the horizon count the missing (quiescent)
    ticks as zero delivery — the value equals the fixed-horizon run's."""
    g, wl, p = _config_a()
    r = simulate(g, wl, TransportProfile.ai_full(), p, trace="full")
    assert r.horizon < 300
    gold = np.load(GOLDEN)
    want = gold["a_delivered"][0:300].mean(axis=0)
    np.testing.assert_allclose(r.goodput((0, 300)), want)
    # a window entirely past the horizon but inside the budget is legal
    # (and zero — nothing is delivered after quiescence)
    late = r.goodput((r.horizon, 300))
    np.testing.assert_array_equal(late, np.zeros(3))


def test_goodput_rejects_windows_past_the_budget():
    g, wl, p = _config_a()
    r = simulate(g, wl, TransportProfile.ai_full(), p, trace="full")
    with pytest.raises(ValueError, match="selects no ticks"):
        r.goodput((300, 400))        # starts at the budget
    with pytest.raises(ValueError, match="selects no ticks"):
        r.goodput((-5, 100))
    # w1 past the budget clamps (documented), denominator included
    np.testing.assert_array_equal(r.goodput((0, 10**9)), r.goodput((0, 300)))


def test_stats_goodput_registered_window_only():
    g, wl, p = _config_a()
    win = (100, 300)
    r = simulate(g, wl, TransportProfile.ai_full(), p, goodput_window=win)
    assert r.goodput(win).shape == (3,)
    assert r.goodput().shape == (3,)
    with pytest.raises(ValueError, match="pre-registered"):
        r.goodput((0, 50))


def test_horizon_exposed_on_both_tiers():
    g, wl, p = _config_a()
    rf = simulate(g, wl, TransportProfile.ai_full(), p, trace="full")
    rs = simulate(g, wl, TransportProfile.ai_full(), p)
    assert rf.horizon == rs.horizon == rf.delivered_per_tick.shape[0]
    assert rf.max_ticks == rs.max_ticks == 300


# ------------------------------------------------------------------------
# INC on/off as a traced axis
# ------------------------------------------------------------------------

def test_inc_profile_with_red_disabled_is_bitwise_inc_off():
    """``inc=True`` + ``red=-1`` lanes must compile-in the INC machinery
    yet produce bitwise the inc=False executable's lanes AND state — the
    property that lets a whole INC ablation share one executable per
    transport profile."""
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=4)
    spec = coll.CollectiveSpec("all_reduce", tuple(range(8)), 24)
    wl_on = coll.build_workload(spec, "tree")
    wl_off = coll.build_workload(spec, "tree", inc_groups=False)
    assert (np.asarray(wl_off.red) == -1).all()
    p = SimParams(ticks=700)
    ai = TransportProfile.ai_full()
    inc_prof = replace(ai, inc=True, name="ai_full+inc")
    a = simulate(g, wl_on, ai, p, trace="full")          # inc=False exe
    b = simulate(g, wl_off, inc_prof, p, trace="full")   # inc=True, red=-1
    np.testing.assert_array_equal(a.delivered_per_tick, b.delivered_per_tick)
    np.testing.assert_array_equal(a.cwnd_per_tick, b.cwnd_per_tick)
    np.testing.assert_array_equal(a.src_base_per_tick, b.src_base_per_tick)
    assert int(b.state.inc_reduced) == 0 and int(b.state.inc_emits) == 0
    # states match except the INC pytree itself (absent vs empty slots)
    sa = replace(a.state, inc=None)
    sb = replace(b.state, inc=None)
    assert _state_equal(jax.tree_util.tree_leaves(sa),
                        jax.tree_util.tree_leaves(sb))


def test_chunk_size_changes_horizon_not_trajectory():
    """chunk_ticks trades exit granularity for nothing else: the
    executed prefix is identical across chunk sizes."""
    g, wl, _ = _config_a()
    prof = TransportProfile.ai_full()
    a = simulate(g, wl, prof, SimParams(ticks=300, chunk_ticks=32),
                 trace="full")
    b = simulate(g, wl, prof, SimParams(ticks=300, chunk_ticks=128),
                 trace="full")
    assert a.horizon % 32 == 0 and b.horizon % 128 == 0
    assert a.horizon <= b.horizon
    np.testing.assert_array_equal(a.delivered_per_tick,
                                  b.delivered_per_tick[:a.horizon])
    np.testing.assert_array_equal(a.completion_ticks(), b.completion_ticks())
