"""Split the host CPU into 4 virtual XLA devices for the whole test
session so the device-sharded scenario engine (repro.network.shard,
tests/test_shard.py) is exercised under plain ``pytest``.

Must run before the first jax import anywhere in the process — jax
locks the backend on first use. Respected only when the user has not
set their own XLA_FLAGS; the unsharded engine's results do not depend
on the visible device count (everything runs on device 0 by default).
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")
