"""Batched scenario engine + fused SACK kernel: equivalence and parity.

* the fused record/advance/shift kernel agrees with the pds reference
  on edge cases (empty ring, full ring, base wrap-around) and between
  interpret and compiled modes (compiled only on TPU);
* `simulate_batch` lanes are bitwise identical to serial `simulate`
  calls across mixed workloads, seeds, and failure masks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pds
from repro.core.lb.schemes import LBScheme
from repro.kernels import ops
from repro.network.fabric import SimParams, Workload, simulate, simulate_batch
from repro.network.profile import TransportProfile
from repro.network.topology import leaf_spine

RNG = np.random.default_rng(11)


def _ref(ring, base, rtx, mask):
    """pds-composed reference: record (OR) -> advance -> shift both rings."""
    ring = ring | mask
    adv = pds.trailing_ones(ring)
    return (pds.shift_ring(ring, adv), base + adv.astype(jnp.uint32),
            pds.shift_ring(rtx, adv), adv)


def _assert_fused_matches(ring, base, rtx, mask, use_pallas):
    got = ops.sack_fused(ring, base, rtx, mask, use_pallas=use_pallas)
    want = _ref(ring, base, rtx, mask)
    for g, w, name in zip(got, want, ("ring", "base", "rtx", "adv")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_sack_fused_empty_ring(use_pallas):
    n, w = 9, 8
    ring = jnp.zeros((n, w), jnp.uint32)
    rtx = jnp.asarray(RNG.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    base = jnp.asarray(RNG.integers(0, 10000, n, dtype=np.uint32))
    mask = jnp.zeros((n, w), jnp.uint32)
    _assert_fused_matches(ring, base, rtx, mask, use_pallas)
    # empty ring + empty mask: nothing advances, nothing shifts
    r, b, x, a = ops.sack_fused(ring, base, rtx, mask, use_pallas=use_pallas)
    assert int(np.asarray(a).sum()) == 0
    np.testing.assert_array_equal(np.asarray(x), np.asarray(rtx))
    np.testing.assert_array_equal(np.asarray(b), np.asarray(base))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_sack_fused_full_ring(use_pallas):
    n, w = 5, 16
    ring = jnp.full((n, w), 0xFFFFFFFF, jnp.uint32)
    rtx = jnp.asarray(RNG.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    base = jnp.asarray(RNG.integers(0, 10000, n, dtype=np.uint32))
    mask = jnp.zeros((n, w), jnp.uint32)
    _assert_fused_matches(ring, base, rtx, mask, use_pallas)
    r, b, x, a = ops.sack_fused(ring, base, rtx, mask, use_pallas=use_pallas)
    np.testing.assert_array_equal(np.asarray(a), w * 32)  # full window
    assert int(np.asarray(r).sum()) == 0                  # fully drained
    assert int(np.asarray(x).sum()) == 0                  # rtx shifted out


@pytest.mark.parametrize("use_pallas", [False, True])
def test_sack_fused_base_wraparound(use_pallas):
    """base sits just below 2^32: the CACK advance must wrap modularly."""
    n, w = 4, 4
    ring = jnp.asarray([[0xFFFFFFFF, 0x1, 0, 0],
                        [0x7, 0, 0, 0],
                        [0, 0, 0, 0],
                        [0xFFFFFFFF] * 4], jnp.uint32)
    base = jnp.full((n,), 0xFFFFFFF0, jnp.uint32)
    rtx = jnp.asarray(RNG.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    mask = jnp.zeros((n, w), jnp.uint32)
    _assert_fused_matches(ring, base, rtx, mask, use_pallas)
    _, b, _, a = ops.sack_fused(ring, base, rtx, mask, use_pallas=use_pallas)
    adv = np.asarray(a).astype(np.uint32)
    np.testing.assert_array_equal(
        np.asarray(b), (np.asarray(base) + adv).astype(np.uint32))
    assert int(adv[3]) == w * 32 and int(np.asarray(b)[3]) < 0xFFFFFFF0


@pytest.mark.parametrize("n,w", [(1, 2), (64, 16), (130, 8)])
def test_sack_fused_random_parity(n, w):
    ring = jnp.asarray(RNG.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    rtx = jnp.asarray(RNG.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    mask = jnp.asarray(RNG.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    base = jnp.asarray(RNG.integers(0, 2 ** 32, n, dtype=np.uint32))
    _assert_fused_matches(ring, base, rtx, mask, use_pallas=True)
    _assert_fused_matches(ring, base, rtx, mask, use_pallas=False)


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="compiled Pallas path needs a TPU; interpret "
                           "mode is exercised everywhere else")
def test_sack_fused_interpret_vs_compiled():
    from repro.kernels.sack_fused import sack_fused as fused
    n, w = 96, 16
    ring = jnp.asarray(RNG.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    rtx = jnp.asarray(RNG.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    mask = jnp.asarray(RNG.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    base = jnp.asarray(RNG.integers(0, 2 ** 32, n, dtype=np.uint32))
    a = fused(ring, base, rtx, mask, interpret=True)
    b = fused(ring, base, rtx, mask, interpret=False)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------------------
# batched scenario engine
# ------------------------------------------------------------------------

def _state_equal(a, b) -> bool:
    return all(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b)))


def test_simulate_batch1_equals_simulate():
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2], [4, 5, 6], 200)
    prof = TransportProfile.ai_full()
    p = SimParams(ticks=300)
    r = simulate(g, wl, prof, p, trace="full")
    rb = simulate_batch(g, Workload.stack([wl]), prof, p, trace="full")[0]
    assert r.horizon == rb.horizon and r.max_ticks == 300
    np.testing.assert_array_equal(r.delivered_per_tick, rb.delivered_per_tick)
    np.testing.assert_array_equal(r.cwnd_per_tick, rb.cwnd_per_tick)
    np.testing.assert_array_equal(r.qlen_max, rb.qlen_max)
    assert _state_equal(r.state, rb.state)


@pytest.mark.slow
def test_simulate_batch8_bitwise_identical_to_serial():
    """Acceptance: 8 mixed scenarios (sizes x seeds x failure masks) in
    one vmapped scan == 8 serial runs, bitwise."""
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=8)
    prof = TransportProfile.ai_full(lb=LBScheme.REPS)
    p = SimParams(ticks=400, timeout_ticks=64, ooo_threshold=24)
    wls, masks, seeds, fqs = [], [], [], []
    for i in range(8):
        wls.append(Workload.of(list(range(8)), [8 + j for j in range(8)],
                               600 + 100 * i))
        m = np.zeros((g.num_queues,), bool)
        fq = ()
        if i % 2 == 1:
            q = int(g.up1_table[0, i % 4])
            m[q] = True
            fq = (q,)
        masks.append(m)
        fqs.append(fq)
        seeds.append(0x5EED + i)
    serial = [simulate(g, wls[i], prof, p, failed=fqs[i],
                       seed=seeds[i], trace="full") for i in range(8)]
    batch = simulate_batch(g, Workload.stack(wls), prof, p,
                           failed=np.stack(masks),
                           seeds=np.asarray(seeds, np.uint32),
                           trace="full")
    for i, (a, b) in enumerate(zip(serial, batch)):
        np.testing.assert_array_equal(
            a.delivered_per_tick, b.delivered_per_tick,
            err_msg=f"scenario {i}")
        np.testing.assert_array_equal(a.cwnd_per_tick, b.cwnd_per_tick,
                                      err_msg=f"scenario {i}")
        np.testing.assert_array_equal(a.qlen_max, b.qlen_max,
                                      err_msg=f"scenario {i}")
        assert _state_equal(a.state, b.state), f"scenario {i} state diverged"


def test_simulate_batch_failed_queue_masks_change_outcomes():
    """Failure masks are per-scenario: a dead uplink must show up as
    silent drops in that lane only."""
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    wl = Workload.of([0, 1], [2, 3], 300)
    p = SimParams(ticks=250, timeout_ticks=64)
    masks = np.zeros((2, g.num_queues), bool)
    masks[1, int(g.up1_table[0, 0])] = True
    healthy, degraded = simulate_batch(g, Workload.stack([wl, wl]),
                                       TransportProfile.ai_full(), p,
                                       failed=masks)
    assert int(healthy.state.drops) == 0
    assert int(degraded.state.drops) > 0


def test_record_rx_duplicate_lanes_or_semantics():
    """pds.or_mask's general path: duplicate (pdc, psn) lanes in one
    batch must set the bit once and report both lanes accepted."""
    t = pds.PSNTracker.create(2, 64)
    pdc = jnp.asarray([0, 0, 0, 1], jnp.int32)
    psn = jnp.asarray([3, 3, 4, 3], jnp.uint32)
    valid = jnp.asarray([True, True, True, True])
    t2, fresh = pds.record_rx(t, pdc, psn, valid)
    assert np.asarray(fresh).tolist() == [True, True, True, True]
    assert int(np.asarray(t2.ring)[0, 0]) == (1 << 3) | (1 << 4)
    assert int(np.asarray(t2.ring)[1, 0]) == 1 << 3


def test_record_rx_unique_rows_fast_path_matches_general():
    """unique_rows=True (dedup skipped) must agree with the general path
    whenever the batch really is one-lane-per-PDC."""
    rng = np.random.default_rng(5)
    t = pds.PSNTracker.create(8, 128)
    pdc = jnp.asarray(rng.permutation(8)[:6], jnp.int32)
    psn = jnp.asarray(rng.integers(0, 200, 6), jnp.uint32)  # some OOR
    valid = jnp.asarray([True, True, False, True, True, True])
    a, fa = pds.record_rx(t, pdc, psn, valid, unique_rows=True)
    b, fb = pds.record_rx(t, pdc, psn, valid, unique_rows=False)
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ------------------------------------------------------------------------
# dependency lane (Workload.dep) + INC: parity and golden anchoring
# ------------------------------------------------------------------------

def test_dep_gated_batch_vs_serial_bitwise():
    """Dep-scheduled collectives through simulate_batch are bitwise
    identical to serial simulate calls (sizes x seeds vary)."""
    from repro.network import collectives as coll
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    p = SimParams(ticks=350)
    prof = TransportProfile.ai_full()
    wls, seeds = [], []
    for i, s in enumerate((12, 16, 20)):
        spec = coll.CollectiveSpec("all_reduce", (0, 1, 2, 3), s)
        wls.append(coll.build_workload(spec, "ring"))
        seeds.append(0x5EED + i)
    serial = [simulate(g, wls[i], prof, p, seed=seeds[i], trace="full")
              for i in range(3)]
    batch = simulate_batch(g, Workload.stack(wls), prof, p,
                           seeds=np.asarray(seeds, np.uint32),
                           trace="full")
    # scenarios complete at different ticks: each batch lane must freeze
    # at ITS OWN chunk boundary, exactly like its serial run
    for a, b in zip(serial, batch):
        assert a.horizon == b.horizon
    for i, (a, b) in enumerate(zip(serial, batch)):
        np.testing.assert_array_equal(a.delivered_per_tick,
                                      b.delivered_per_tick,
                                      err_msg=f"scenario {i}")
        np.testing.assert_array_equal(a.src_base_per_tick,
                                      b.src_base_per_tick,
                                      err_msg=f"scenario {i}")
        assert _state_equal(a.state, b.state), f"scenario {i} diverged"


def test_inc_batch_vs_serial_bitwise():
    """The INC-enabled executable is batch/serial bitwise-stable too
    (accumulator slots ride the vmapped carry)."""
    from dataclasses import replace

    from repro.network import collectives as coll
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=4)
    prof = replace(TransportProfile.ai_full(), inc=True, name="ai_full+inc")
    p = SimParams(ticks=600)
    spec = coll.CollectiveSpec("all_reduce", tuple(range(8)), 24)
    wl = coll.build_workload(spec, "tree")
    a = simulate(g, wl, prof, p, trace="full")
    b = simulate_batch(g, Workload.stack([wl, wl]), prof, p,
                       trace="full")[1]
    assert int(a.state.inc_reduced) > 0
    np.testing.assert_array_equal(a.delivered_per_tick, b.delivered_per_tick)
    np.testing.assert_array_equal(a.src_base_per_tick, b.src_base_per_tick)
    assert _state_equal(a.state, b.state)


def test_explicit_dep_minus_one_matches_golden():
    """Golden anchor: a workload with dep/red lanes explicitly present
    (all -1) reproduces the pre-dep-lane engine bitwise (the golden
    lanes were captured before this PR)."""
    import os
    gold = np.load(os.path.join(os.path.dirname(__file__), "golden",
                                "fabric_golden.npz"))
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2], [4, 5, 6], 200,
                     dep=np.full(3, -1, np.int32),
                     red=np.full(3, -1, np.int32))
    r = simulate(g, wl, TransportProfile.ai_full(), SimParams(ticks=300),
                 trace="full")
    h = r.horizon
    np.testing.assert_array_equal(r.delivered_per_tick,
                                  gold["a_delivered"][:h])
    assert (gold["a_delivered"][h:] == 0).all()
    np.testing.assert_array_equal(r.cwnd_per_tick, gold["a_cwnd"][:h])
    np.testing.assert_array_equal(r.qlen_max, gold["a_qlen"][:h])
    np.testing.assert_array_equal(np.asarray(r.state.src_track.base),
                                  gold["a_state_src_base"])


def test_run_cache_distinguishes_same_named_graphs():
    """Two topologies with identical name/counts but different wiring
    must not share a compiled executable (routing is baked in)."""
    g1 = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    g2 = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    import dataclasses
    # rewire g2: swap the two uplinks of leaf 0
    up = g2.up1_table.copy()
    up[0] = up[0][::-1]
    g2 = dataclasses.replace(g2, up1_table=up)
    assert g1.name == g2.name
    wl = Workload.of([0, 1], [2, 3], 60)
    prof = TransportProfile.ai_full()
    p = SimParams(ticks=80)
    r1 = simulate(g1, wl, prof, p)
    r2 = simulate(g2, wl, prof, p)
    # both must run on their own wiring (no crash / no silent reuse);
    # delivery totals agree because the rewiring is symmetric
    assert int(r1.state.delivered.sum()) == int(r2.state.delivered.sum())
    from repro.network.fabric import _cache_key
    assert _cache_key(g1, prof, p, 2, False) != _cache_key(g2, prof, p, 2, False)
