"""musicgen-medium: decoder-only over EnCodec tokens; the EnCodec frontend
is a stub providing the token/frame stream [arXiv:2306.05284; hf]."""
from repro.configs.base import ArchConfig, pad_for_tp, MIXER_ATTN, FFN_MLP

CONFIG = pad_for_tp(ArchConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    head_dim=64, d_ff=6144, vocab_size=2048,
    pattern=((MIXER_ATTN, FFN_MLP),),
    frontend="encodec_stub",
    source="arXiv:2306.05284; hf",
))
