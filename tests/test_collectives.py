"""Dependency-scheduled collectives + in-network reduction (INC).

* flow-table builders: shapes, acyclic phase-ordered deps, validation;
* the fabric's dependency lane actually gates eligibility in-scan;
* whole collectives complete with EXACT per-host delivery totals
  (reliable transport => the schedule's phase totals are deterministic);
* INC: switch absorption conserves payload accounting (delivered +
  absorbed == expected), beats the INC-off tree on completion, and is a
  no-op for group-free schedules;
* netmodel: packet-level collective time >= the alpha-beta bound.
"""
import numpy as np
import pytest
from dataclasses import replace

from repro.network import collectives as coll
from repro.network.fabric import SimParams, Workload, simulate, simulate_batch
from repro.network.profile import TransportProfile
from repro.network.topology import leaf_spine


def _spec(kind="all_reduce", n=8, s=32):
    return coll.CollectiveSpec(kind, tuple(range(n)), s)


def _host_rx(wl, result, n):
    rx = np.zeros((n,), np.int64)
    np.add.at(rx, np.asarray(wl.dst), np.asarray(result.state.delivered,
                                                 np.int64))
    return rx


# ------------------------------------------------------------------ builders

def test_ring_allreduce_table():
    t = coll.flow_table(_spec(), "ring")
    n = 8
    assert len(t.src) == 2 * (n - 1) * n
    assert t.meta["chunk"] == 4  # ceil(32/8)
    # phase-ordered acyclic deps: every dep points at a lower flow index
    f = np.arange(len(t.src))
    has = t.dep >= 0
    assert (t.dep[has] < f[has]).all()
    # dep of flow (p, i) is the phase-(p-1) flow INTO host i
    assert (t.dst[t.dep[has]] == t.src[has]).all()


def test_recursive_doubling_tables():
    t = coll.flow_table(_spec(), "recursive_doubling")
    assert len(t.src) == 3 * 8          # log2(8) phases x 8 hosts
    has = t.dep >= 0
    assert (t.dst[t.dep[has]] == t.src[has]).all()
    # reduce-scatter halves, all-gather doubles; totals match (n-1)/n
    trs = coll.flow_table(_spec("reduce_scatter"), "recursive_doubling")
    tag = coll.flow_table(_spec("all_gather"), "recursive_doubling")
    assert int(trs.size[trs.src == 0].sum()) == 16 + 8 + 4    # 32*(7/8)
    assert int(tag.size[tag.src == 0].sum()) == 32 + 64 + 128  # doubling
    with pytest.raises(ValueError, match="power-of-two"):
        coll.flow_table(_spec(n=6), "recursive_doubling")


def test_tree_table_and_validation():
    t = coll.flow_table(_spec(), "tree")
    assert len(t.src) == 14
    assert (t.red[:7] == 0).all() and (t.red[7:] == -1).all()
    assert (t.dst[:7] == 0).all() and (t.src[7:] == 0).all()
    with pytest.raises(ValueError, match="all_reduce only"):
        coll.flow_table(_spec("all_gather"), "tree")
    with pytest.raises(ValueError):
        coll.CollectiveSpec("nope", (0, 1), 4)
    with pytest.raises(ValueError, match="distinct"):
        coll.CollectiveSpec("all_reduce", (0, 0), 4)
    assert coll.CollectiveSpec.from_bytes(
        "all-reduce", range(4), 10_000, mtu=4096).size_pkts == 3


def test_all_to_all_rounds_chained_per_host():
    t = coll.flow_table(_spec("all_to_all"), "ring")
    assert len(t.src) == 7 * 8
    has = t.dep >= 0
    # each host's rounds are serialized on its own previous round
    assert (t.src[t.dep[has]] == t.src[has]).all()


# ------------------------------------------------------------- dep gating

def test_dep_lane_gates_eligibility():
    """Flow 1 depends on flow 0: its first delivery must come after
    flow 0 fully completed at the source."""
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    wl = Workload.of([0, 1], [2, 3], [60, 60], dep=[-1, 0])
    r = simulate(g, wl, TransportProfile.ai_full(), SimParams(ticks=500),
                 trace="full")
    done0 = int(r.source_completion_ticks()[0])
    first1 = int(np.argmax(r.delivered_per_tick[:, 1] > 0))
    assert done0 > 0 and (r.delivered_per_tick[:, 1] > 0).any()
    assert first1 > done0
    # and without the dep both flows run concurrently
    r2 = simulate(g, Workload.of([0, 1], [2, 3], [60, 60]),
                  TransportProfile.ai_full(), SimParams(ticks=500),
                  trace="full")
    first1_free = int(np.argmax(r2.delivered_per_tick[:, 1] > 0))
    assert first1_free < first1


def test_ring_allreduce_exact_delivery_and_bound():
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=4)
    spec = _spec(n=8, s=32)
    wl = coll.build_workload(spec, "ring")
    r = simulate(g, wl, TransportProfile.ai_full(), SimParams(ticks=1200))
    ct = coll.collective_completion_ticks(r)
    assert ct >= coll.analytic_ticks(spec, "ring")
    np.testing.assert_array_equal(_host_rx(wl, r, 8),
                                  coll.expected_host_rx(spec, "ring"))


# ------------------------------------------------------------------- INC

def test_inc_tree_allreduce_correctness():
    """INC off: exact per-host phase totals. INC on: every flow still
    source-completes, the parent downlink carries strictly fewer packets
    (delivered + absorbed == expected), and completion is faster."""
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=4)
    n, s = 8, 32
    spec = _spec(n=n, s=s)
    wl = coll.build_workload(spec, "tree")
    p = SimParams(ticks=1500)
    ai = TransportProfile.ai_full()
    r_off = simulate(g, wl, ai, p)
    r_on = simulate(g, wl, replace(ai, inc=True, name="ai_full+inc"), p)
    expected = coll.expected_host_rx(spec, "tree")
    np.testing.assert_array_equal(_host_rx(wl, r_off, n), expected)
    assert int(r_off.state.inc_reduced) == 0

    # INC on: all 14 flows complete at the source
    assert coll.collective_completion_ticks(r_on) > 0
    reduced = int(r_on.state.inc_reduced)
    rx_on = _host_rx(wl, r_on, n)
    # strictly fewer packets on the root downlink, payload conserved
    assert reduced > 0
    assert rx_on[0] < expected[0]
    assert rx_on[0] + reduced == expected[0]
    # non-root hosts (broadcast) are untouched by INC
    np.testing.assert_array_equal(rx_on[1:], expected[1:])
    # and the switch win shows up in completion time
    assert (coll.collective_completion_ticks(r_on)
            < coll.collective_completion_ticks(r_off))


def test_inc_oversized_group_passes_through():
    """A group wider than the 32-bit child bitmap can never complete —
    it must pass through ENTIRELY (absorbing any child of an
    unemittable group would destroy its data)."""
    import jax.numpy as jnp

    from repro.core import inc as inc_mod
    f = 40
    red = jnp.zeros((f,), jnp.int32)          # one group, 40 members
    member, rank, gsz = inc_mod.member_ranks(red, jnp.ones((f,), bool))
    assert int(gsz[0]) == 40
    st = inc_mod.INCState.create(f, 8)
    lanes = 34
    st2, absorb, emit = inc_mod.process(
        st, lane_flow=jnp.arange(lanes, dtype=jnp.int32),
        lane_psn=jnp.zeros((lanes,), jnp.int32),
        lane_cand=jnp.ones((lanes,), bool),
        member=member, rank=rank, gsz=gsz, red=red,
        has_delivery=jnp.zeros((f,), bool))
    assert not bool(absorb.any()) and not bool(emit.any())
    # and a 32-wide group still aggregates
    red32 = jnp.where(jnp.arange(f) < 32, 0, -1).astype(jnp.int32)
    member, rank, gsz = inc_mod.member_ranks(red32, jnp.ones((f,), bool))
    _, absorb, emit = inc_mod.process(
        inc_mod.INCState.create(f, 8),
        lane_flow=jnp.arange(32, dtype=jnp.int32),
        lane_psn=jnp.zeros((32,), jnp.int32),
        lane_cand=jnp.ones((32,), bool),
        member=member, rank=rank, gsz=gsz, red=red32,
        has_delivery=jnp.zeros((f,), bool))
    assert int(absorb.sum()) == 31 and int(emit.sum()) == 1


def test_ring_and_rd_allgather_agree_on_traffic():
    """Both algorithms are per-rank-INPUT denominated: same per-host
    totals, (n-1)*S (the reviewer-caught factor-n mismatch)."""
    spec = _spec("all_gather", n=8, s=64)
    ring = coll.expected_host_rx(spec, "ring")
    rd = coll.expected_host_rx(spec, "recursive_doubling")
    np.testing.assert_array_equal(ring, rd)
    assert int(ring[0]) == 7 * 64


def test_inc_is_noop_without_reduction_groups():
    """An INC-enabled profile on a red=-1 schedule (ring) must produce
    identical lanes to INC off — aggregation is opportunistic, never a
    behavior change for group-free traffic."""
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    spec = _spec(n=4, s=16)
    wl = coll.build_workload(spec, "ring")
    p = SimParams(ticks=400)
    ai = TransportProfile.ai_full()
    r_off = simulate(g, wl, ai, p, trace="full")
    r_on = simulate(g, wl, replace(ai, inc=True, name="ai_full+inc"), p,
                    trace="full")
    np.testing.assert_array_equal(r_off.delivered_per_tick,
                                  r_on.delivered_per_tick)
    np.testing.assert_array_equal(r_off.cwnd_per_tick, r_on.cwnd_per_tick)
    assert int(r_on.state.inc_reduced) == 0


# -------------------------------------------------------- batching helpers

def test_stack_padded_heterogeneous_grid():
    """Ring (F=24), rd (F=8... different), tree (F=6) pad into one batch
    and every scenario completes; inert pad flows deliver nothing."""
    spec = _spec(n=4, s=16)
    wls = [coll.build_workload(spec, a)
           for a in ("ring", "recursive_doubling", "tree")]
    fs = [int(w.src.shape[0]) for w in wls]
    batch = coll.stack_padded(wls)
    assert batch.src.shape == (3, max(fs))
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    rs = simulate_batch(g, batch, TransportProfile.ai_full(),
                        SimParams(ticks=700))
    for f, r in zip(fs, rs):
        assert coll.collective_completion_ticks(r) > 0
        pad = np.asarray(r.state.delivered)[f:]
        assert (pad == 0).all()


@pytest.mark.slow
def test_collective_sweep_one_batch_call():
    """The full kind x algorithm x INC x profile grid (>=12 scenarios)
    runs as ONE simulate_batch call and shows the INC tree win."""
    from repro.network import workloads
    g, wls, profiles, names = workloads.collective_sweep()
    assert len(names) >= 12
    rs = simulate_batch(g, wls, profiles, SimParams(ticks=1600))
    cts = {nm: coll.collective_completion_ticks(r)
           for nm, r in zip(names, rs)}
    assert all(ct > 0 for ct in cts.values()), cts
    assert (cts["ai_full/all_reduce/tree/inc"]
            < cts["ai_full/all_reduce/tree"])


# ------------------------------------------------------------- netmodel

def test_simulated_collective_time_ge_analytic():
    from repro.distributed.netmodel import (FabricSpec,
                                            analytic_time_for_spec,
                                            simulated_collective_time)
    fs = FabricSpec()
    for kind, algo in (("all-reduce", "ring"),
                       ("all-reduce", "tree"),
                       ("all-gather", "ring")):
        t_sim = simulated_collective_time(kind, chips=8, size_pkts=24,
                                          algo=algo, fabric=fs)
        t_ana = analytic_time_for_spec(kind, 24, 8, fs)
        assert t_sim >= t_ana, (kind, algo, t_sim, t_ana)


def test_bytes_total_matches_size_pkts_denomination():
    """The bytes_total path is OUTPUT-denominated (HLO convention): for
    all-gather the per-rank input block is output/n, so both entry
    points must price the same schedule."""
    from repro.distributed.netmodel import (FabricSpec,
                                            simulated_collective_time)
    fs = FabricSpec()
    n, s = 4, 8
    t_pkts = simulated_collective_time("all-gather", chips=n, size_pkts=s,
                                       fabric=fs)
    t_bytes = simulated_collective_time(
        "all-gather", bytes_total=n * n * s * fs.mtu, chips=n, fabric=fs)
    assert t_bytes == t_pkts
    # all-reduce: output == input, no extra factor
    t_pkts = simulated_collective_time("all-reduce", chips=n, size_pkts=s,
                                       fabric=fs)
    t_bytes = simulated_collective_time(
        "all-reduce", bytes_total=n * s * fs.mtu, chips=n, fabric=fs)
    assert t_bytes == t_pkts


def test_simulated_efficiency_in_unit_interval():
    from repro.distributed.netmodel import simulated_efficiency
    eff = simulated_efficiency("all-reduce", hosts=4, size_pkts=16)
    assert 0.0 < eff <= 1.0


def test_pattern_workload_proxy_removed():
    """The seed's single-phase steady-state proxy is gone: collective
    workloads only come from the real dep-scheduled builders."""
    from repro.distributed import netmodel
    assert not hasattr(netmodel, "_pattern_workload")


def test_from_bytes_rounds_up_to_whole_packets():
    """Sub-packet and fractional byte counts must round UP and floor at
    one packet — the old int() truncation priced 4096.5 bytes as 1 pkt
    and 0.5 bytes as... also 1, but only by accident of the max()."""
    mtu = 4096
    spec = coll.CollectiveSpec.from_bytes("all_reduce", range(4), 4096.5, mtu)
    assert spec.size_pkts == 2
    assert coll.CollectiveSpec.from_bytes("all_reduce", range(4), 0.5,
                                     mtu).size_pkts == 1
    assert coll.CollectiveSpec.from_bytes("all_gather", range(4), 3 * mtu,
                                     mtu).size_pkts == 3
    # a sub-packet spec still lowers to a valid flow table: every flow
    # moves at least one packet
    t = coll.flow_table(coll.CollectiveSpec.from_bytes("all_gather", range(4), 10.0,
                                             mtu), "ring")
    assert (t.size >= 1).all()
