"""Per-architecture smoke tests: reduced configs, one forward + one train
step + one decode step on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step

BATCH, SEQ = 2, 32


def _inputs(cfg, key):
    if cfg.frontend == "vit_stub":
        return jax.random.normal(key, (BATCH, SEQ, cfg.d_model), jnp.float32)
    return jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab)


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_forward_and_decode(arch):
    cfg = configs.reduced(arch, seq=SEQ)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key, jnp.float32)
    fwd = lm.build_forward(cfg, mesh=None, remat=False)
    x = _inputs(cfg, key)
    logits, aux, _ = jax.jit(lambda p, x: fwd(p, x))(params, x)
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in forward logits"

    cache = lm.init_cache(cfg, BATCH, SEQ, jnp.float32)
    dfwd = lm.build_forward(cfg, mesh=None, decode=True, remat=False)
    tok = (jnp.zeros((BATCH, 1), jnp.int32) if cfg.frontend != "vit_stub"
           else jax.random.normal(key, (BATCH, 1, cfg.d_model)))
    dl, _, new_cache = jax.jit(
        lambda p, t, c: dfwd(p, t, cache=c, pos0=3))(params, tok, cache)
    assert dl.shape == (BATCH, 1, cfg.vocab)
    assert bool(jnp.isfinite(dl).all())
    # cache structure is preserved
    assert (jax.tree_util.tree_structure(new_cache)
            == jax.tree_util.tree_structure(cache))


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
@pytest.mark.slow
def test_train_step_reduces_shapes_and_is_finite(arch):
    cfg = configs.reduced(arch, seq=SEQ)
    key = jax.random.PRNGKey(1)
    params = lm.init_params(cfg, key, jnp.float32)
    ocfg = AdamWConfig(lr=1e-3)
    opt = init_opt_state(params, ocfg)
    step = make_train_step(cfg, mesh=None, opt_cfg=ocfg)
    batch = {
        "inputs": _inputs(cfg, key),
        "labels": jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab),
    }
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree_util.tree_map(lambda a, b: a - b, params, params2), 0.0)
    assert delta > 0.0


def test_full_configs_match_assignment():
    """The full (unreduced) configs carry the exact assigned dimensions."""
    expect = {
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "rwkv6-3b": (32, 2560, 0, 0, 8960, 65536),
    }
    for name, (L, D, H, KV, F, V) in expect.items():
        c = configs.get(name)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, D, H, KV, F, V), name


def test_moe_configs():
    for name, (e, k) in {"grok-1-314b": (8, 2), "mixtral-8x22b": (8, 2),
                         "jamba-1.5-large-398b": (16, 2)}.items():
        c = configs.get(name)
        assert (c.num_experts, c.experts_per_token) == (e, k)


def test_long_context_eligibility():
    """long_500k runs only for sub-quadratic archs (SWA / SSM / hybrid)."""
    subq = {n for n in configs.ARCH_NAMES
            if "long_500k" in configs.shapes_for(configs.get(n))}
    assert subq == {"mixtral-8x22b", "jamba-1.5-large-398b", "rwkv6-3b"}
