"""Ephemeral Packet Delivery Contexts — dynamic creation state machine
(Sec. 3.2.3, Fig. 6).

The defining property: a PDC is established *by the first arriving packet*
with zero additional latency — the source keeps sending at full rate while
still in SYN state, and the target-assigned PDCID is echoed back in ACKs.
Closing drains via QUIESCE -> ACK_WAIT -> CLOSED, initiated by the source
when idle (optionally nudged by the target via control packet/ACK flags).

Implemented as a dense transition table over int32 codes so a whole pool of
PDCs steps in one gather — the hardware-pipeline shape. The initiator and
target machines share the state enum (`PDCState`) but use different tables.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import PDCState


class InitEvent(enum.IntEnum):
    """Initiator-side events."""

    NONE = 0
    SEND_FIRST = 1     # SES asks to send, no PDC yet -> allocate, go SYN
    ACK_PDCID = 2      # first ACK carrying the target-assigned PDCID
    CLOSE_REQ = 3      # idle close decision (or target-requested via flags)
    DRAINED = 4        # all started messages fully sent
    ALL_ACKED = 5      # every outstanding reply arrived -> send final close
    CLOSE_ACK = 6      # final ACK for the close command
    PEER_DEAD = 7      # liveness lost: consecutive dead RTOs crossed the
    #                    teardown threshold -> abort straight to CLOSED
    #                    (no drain/handshake — the peer cannot answer)


class TgtEvent(enum.IntEnum):
    """Target-side events."""

    NONE = 0
    RX_SYN = 1         # first packet w/ SYN -> create PDC, assign PDCID
    RX_NOSYN = 2       # first packet without SYN -> initiator saw our PDCID
    RX_CLOSE = 3       # final close command
    SECURE_PENDING = 4  # TSS secure-PSN query (Sec. 3.4.2) -> PENDING
    SECURE_OK = 5      # accepted starting PSN


_S = PDCState
_NUM_STATES = len(_S)


def _table(rules: dict[tuple[int, int], int], num_events: int) -> np.ndarray:
    t = np.tile(np.arange(_NUM_STATES, dtype=np.int32)[:, None],
                (1, num_events))  # default: self-loop (event ignored)
    for (s, e), ns in rules.items():
        t[s, e] = ns
    return t


# Initiator transitions (Fig. 6 left). Unlisted (state, event) pairs hold.
_INIT_TABLE = _table({
    (_S.CLOSED, InitEvent.SEND_FIRST): _S.SYN,
    (_S.SYN, InitEvent.ACK_PDCID): _S.ESTABLISHED,
    # a close can begin from SYN too if the message drains before the ACK
    (_S.SYN, InitEvent.CLOSE_REQ): _S.QUIESCE,
    (_S.ESTABLISHED, InitEvent.CLOSE_REQ): _S.QUIESCE,
    (_S.QUIESCE, InitEvent.DRAINED): _S.ACK_WAIT,
    (_S.ACK_WAIT, InitEvent.CLOSE_ACK): _S.CLOSED,
    # liveness teardown: any live state aborts to CLOSED when the peer is
    # declared unreachable — there is no one left to drain against. The
    # orderly QUIESCE -> ACK_WAIT path is bypassed by design.
    (_S.SYN, InitEvent.PEER_DEAD): _S.CLOSED,
    (_S.ESTABLISHED, InitEvent.PEER_DEAD): _S.CLOSED,
    (_S.QUIESCE, InitEvent.PEER_DEAD): _S.CLOSED,
    (_S.ACK_WAIT, InitEvent.PEER_DEAD): _S.CLOSED,
}, len(InitEvent))

# Target transitions (Fig. 6 right).
_TGT_TABLE = _table({
    (_S.CLOSED, TgtEvent.RX_SYN): _S.SYN,
    (_S.CLOSED, TgtEvent.SECURE_PENDING): _S.PENDING,
    (_S.PENDING, TgtEvent.SECURE_OK): _S.SYN,
    (_S.SYN, TgtEvent.RX_NOSYN): _S.ESTABLISHED,
    (_S.SYN, TgtEvent.RX_CLOSE): _S.CLOSED,
    (_S.ESTABLISHED, TgtEvent.RX_CLOSE): _S.CLOSED,
}, len(TgtEvent))

INIT_TABLE = jnp.asarray(_INIT_TABLE)
TGT_TABLE = jnp.asarray(_TGT_TABLE)


def step_initiator(state: jax.Array, event: jax.Array) -> jax.Array:
    """Vectorized initiator transition: next = T[state, event]."""
    return INIT_TABLE[state, event]


def step_target(state: jax.Array, event: jax.Array) -> jax.Array:
    return TGT_TABLE[state, event]


def may_send_data(state: jax.Array) -> jax.Array:
    """Full-rate sending is allowed in SYN (the headline feature: "the
    source has been sending at full rate during PDC establishment!"),
    ESTABLISHED, and QUIESCE (started messages drain)."""
    return (state == _S.SYN) | (state == _S.ESTABLISHED) | (state == _S.QUIESCE)


def may_accept_new_message(state: jax.Array) -> jax.Array:
    """QUIESCE refuses new messages; CLOSED implicitly allocates."""
    return (state == _S.CLOSED) | (state == _S.SYN) | (state == _S.ESTABLISHED)


def unreachable(strikes: jax.Array, dead_after: int) -> jax.Array:
    """[N] bool liveness verdict: a PDC whose consecutive zero-progress
    RTO-expiry count has reached ``dead_after`` is declared unreachable
    and must take the PEER_DEAD teardown. ``dead_after <= 0`` disables
    (never unreachable) — the same contract as
    ``TransportProfile.pdc_dead_after``. The fabric engine's quarantine
    lanes (`repro.network.fabric`) mirror exactly this predicate on its
    per-flow ``rto_strikes`` counter."""
    if dead_after <= 0:
        return jnp.zeros(strikes.shape, bool)
    return strikes >= jnp.int32(dead_after)


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class PDCPool:
    """SoA pool of initiator-side PDCs.

    state:        [N] int32 PDCState
    peer:         [N] int32 destination FEP (-1 = free slot)
    local_id:     [N] int32 our PDCID (== slot index here)
    remote_id:    [N] int32 target-assigned PDCID (-1 until first ACK)
    next_psn:     [N] uint32 next PSN to stamp (starts random per Fig. 6)
    start_psn:    [N] uint32 first PSN of this PDC (for close bookkeeping)
    unacked:      [N] int32 packets outstanding
    active_msgs:  [N] int32 messages started and not finished
    tx_packets:   [N] int32 total request packets sent (TSS 2^31 close rule)
    mode:         [N] int32 DeliveryMode code — a PDC carries exactly one
                  delivery mode (Sec. 3.2.3: mixed-mode traffic between a
                  FEP pair opens one PDC per mode); set at open time and
                  immutable for the PDC's lifetime
    """

    state: jax.Array
    peer: jax.Array
    local_id: jax.Array
    remote_id: jax.Array
    next_psn: jax.Array
    start_psn: jax.Array
    unacked: jax.Array
    active_msgs: jax.Array
    tx_packets: jax.Array
    mode: jax.Array

    @staticmethod
    def create(n: int) -> "PDCPool":
        z = jnp.zeros((n,), jnp.int32)
        return PDCPool(
            state=jnp.full((n,), int(_S.CLOSED), jnp.int32),
            peer=jnp.full((n,), -1, jnp.int32),
            local_id=jnp.arange(n, dtype=jnp.int32),
            remote_id=jnp.full((n,), -1, jnp.int32),
            next_psn=jnp.zeros((n,), jnp.uint32),
            start_psn=jnp.zeros((n,), jnp.uint32),
            unacked=z, active_msgs=z, tx_packets=z,
            mode=z,  # DeliveryMode.RUD
        )


def open_pdc(pool: PDCPool, slot: jax.Array, peer: jax.Array,
             start_psn: jax.Array,
             mode: "jax.Array | int" = 0) -> PDCPool:
    """SES first-send: allocate slot, go SYN, PSN starts at a random value
    (Fig. 6 starts at PSN 4). ``mode`` is the DeliveryMode code the PDC
    will carry (one PDC per mode per peer)."""
    return PDCPool(
        state=pool.state.at[slot].set(int(_S.SYN)),
        peer=pool.peer.at[slot].set(peer),
        local_id=pool.local_id,
        remote_id=pool.remote_id.at[slot].set(-1),
        next_psn=pool.next_psn.at[slot].set(start_psn.astype(jnp.uint32)),
        start_psn=pool.start_psn.at[slot].set(start_psn.astype(jnp.uint32)),
        unacked=pool.unacked.at[slot].set(0),
        active_msgs=pool.active_msgs.at[slot].set(1),
        tx_packets=pool.tx_packets.at[slot].set(0),
        mode=pool.mode.at[slot].set(jnp.int32(mode)),
    )


def on_ack(pool: PDCPool, slot: jax.Array, remote_id: jax.Array,
           n_acked: jax.Array) -> PDCPool:
    """Process an ACK: learn the remote PDCID (SYN->ESTABLISHED), retire
    outstanding packets."""
    got_id = remote_id >= 0
    ev = jnp.where(got_id & (pool.state[slot] == _S.SYN),
                   int(InitEvent.ACK_PDCID), int(InitEvent.NONE))
    return PDCPool(
        state=pool.state.at[slot].set(step_initiator(pool.state[slot], ev)),
        peer=pool.peer, local_id=pool.local_id,
        remote_id=pool.remote_id.at[slot].set(
            jnp.where(got_id, remote_id, pool.remote_id[slot])),
        next_psn=pool.next_psn, start_psn=pool.start_psn,
        unacked=pool.unacked.at[slot].add(-n_acked),
        active_msgs=pool.active_msgs, tx_packets=pool.tx_packets,
        mode=pool.mode,
    )
