"""Sharding rules: parameter, activation, optimizer and cache layouts.

Production mesh: (data=16, model=16) per pod; multi-pod adds a leading
"pod" axis folded into data parallelism. Strategy per tensor family:

* 2-D parameter sharding (FSDP x TP): every weight is sharded over "data"
  on one dim (gathered per layer inside the scan — ZeRO-3 style) and over
  "model" on the TP dim (Megatron column/row split).
* Residual activations: sequence-sharded over "model" for attention
  architectures (Megatron sequence parallelism); batch over data; SSM and
  hybrid archs keep S unsharded (their time scan is sequential) and use
  channel-TP instead.
* MoE experts: FFN dim tensor-parallel; tokens stay on their data shard
  (the shard_map'd block in models/lm.py).
* Decode KV caches: head_dim over "model" for decode_32k (keeps the ring
  write local); sequence over "data" + head_dim over "model" for the
  B=1 long_500k cells (+ select-based ring write).

`param_pspecs` is name-based: it pattern-matches parameter paths, so new
architectures compose without new rules as long as they reuse the layer
vocabulary.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def tp_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


# name -> (spec for 2D [in, out]-style weights); leading R dim added later
_MATMUL_IN_OUT = {"wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_r", "w_k",
                  "w_v", "w_g", "w_decay"}
_MATMUL_OUT_IN = {"wo", "w_down", "w_out", "w_o"}


def param_pspec(path, leaf, dp, stacked: bool) -> P:
    """PartitionSpec for one parameter leaf."""
    name = _path_str(path).split("/")[-1]
    pre = (None,) if stacked else ()
    nd = leaf.ndim - (1 if stacked else 0)

    if name in ("embed",):
        return P("model", None)
    if name in ("head",):
        return P(dp, "model")
    if name in ("scale", "final_norm"):
        return P(*(pre + (None,) * nd))
    if name == "router":
        return P(*(pre + (dp, None)))
    if name in _MATMUL_IN_OUT:
        if nd == 3:  # MoE expert weights [E, D, F]
            return P(*(pre + (None, dp, "model")))
        return P(*(pre + (dp, "model")))
    if name in _MATMUL_OUT_IN:
        if nd == 3:  # MoE [E, F, D]
            return P(*(pre + (None, "model", dp)))
        return P(*(pre + ("model", dp)))
    if name == "w_bcdt":
        return P(*(pre + ("model", None)))
    if name == "a_log":
        return P(*(pre + ("model", None)))
    if name == "decay_bias":
        return P(*(pre + ("model",)))
    if name == "bonus":
        return P(*(pre + ("model", None)))
    # mix vectors, dt_bias, anything small: replicate
    return P(*(pre + (None,) * nd))


def param_pspecs(params_shape: Any, mesh: Mesh) -> Any:
    """Tree of PartitionSpecs matching a params pytree (from eval_shape)."""
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    def rule(path, leaf):
        stacked = _path_str(path).startswith("blocks/")
        return param_pspec(path, leaf, dp, stacked)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def batch_pspec(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> Any:
    """Specs for the input batch dict."""
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    dp_total = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_total *= mesh.shape[a]
    bshard = dp if shape.global_batch % dp_total == 0 else None
    if cfg.frontend == "vit_stub":
        toks = P(bshard, None, None)
    else:
        toks = P(bshard, None)
    return {"inputs": toks, "labels": P(bshard, None)}


def activation_pspec(cfg: ArchConfig, mesh: Mesh) -> P:
    """Residual-stream constraint: SP over model for attention archs."""
    from repro.configs.base import MIXER_ATTN
    pure_attn = all(m == MIXER_ATTN for m, _ in cfg.pattern)
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    if pure_attn:
        return P(dp, "model", None)
    return P(dp, None, None)


def cache_pspecs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                 cache_shape: Any) -> Any:
    """Specs for the decode-cache pytree (stacked [R, ...])."""
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    dp_total = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_total *= mesh.shape[a]
    long_ctx = shape.global_batch < dp_total  # B=1 long_500k cells

    def rule(path, leaf):
        name = _path_str(path).split("/")[-1]
        nd = leaf.ndim
        if name in ("k", "v"):  # [R, B, kv, C, hd]
            if long_ctx:
                return P(None, None, None, "data", "model")
            return P(None, dp, None, None, "model")
        if name == "pos" or name == "valid":
            if nd == 2 and name == "pos":   # [R, C]
                return P(None, "data") if long_ctx else P(None, None)
            if nd == 2:                      # valid [R, C]
                return P(None, "data") if long_ctx else P(None, None)
            return P(*([None] * nd))
        if name == "mamba" or name == "wkv":  # [R,B,di,N] / [R,B,H,hd,hd]
            b = None if long_ctx else dp
            if nd == 4:
                return P(None, b, "model", None)
            return P(None, b, "model", None, None)
        if name == "prev" or name == "ffn_prev":  # [R, B, D]
            return P(None, None if long_ctx else dp, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def attn_head_specs(cfg: ArchConfig, mesh: Mesh, decode: bool = False):
    """(q_sharding, kv_sharding) for [B, H, S, hd] attention internals.

    Train/prefill: heads over "model" (q heads are padded to divide TP;
    kv heads replicated when the GQA kv count is below the TP width).

    Decode: pin q/k/v to the KV cache's native layout — head_dim over
    "model" — so the scores einsum contracts the sharded hd dim (partial
    sums + one small all-reduce of [B,H,1,C]) instead of XLA choosing to
    ALL-GATHER THE WHOLE CACHE to head-sharded form every token (the
    dominant collective of the baseline decode cells; §Perf cell A).
    """
    if not any(m == "attn" for m, _ in cfg.pattern):
        return None
    if decode:
        dp = dp_axes(mesh)
        dp = dp if len(dp) > 1 else dp[0]
        spec = P(dp, None, None, "model")
        return (NamedSharding(mesh, spec), NamedSharding(mesh, spec))
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    tp = mesh.shape["model"]
    q = P(dp, "model", None, None)
    kv = P(dp, "model" if cfg.n_kv % tp == 0 else None, None, None)
    return (NamedSharding(mesh, q), NamedSharding(mesh, kv))


# ---------------------------------------------------------------------------
# §Perf hillclimb variants (EXPERIMENTS.md §Perf; selected by dryrun --opt)
# ---------------------------------------------------------------------------


def param_pspecs_tp_only(params_shape: Any, mesh: Mesh) -> Any:
    """Serving layout: params sharded over "model" only (no FSDP axis).

    Hypothesis (decode cells): FSDP storage forces an all-gather of every
    weight on every decoded token — decode is latency-bound and re-gathers
    the full model per step. Storing weights TP-only removes those
    collectives entirely at the cost of params/16 per chip instead of
    params/256 (fits: 33B bf16 / 16 = 4.1 GB).
    """
    def rule(path, leaf):
        stacked = _path_str(path).startswith("blocks/")
        spec = param_pspec(path, leaf, None, stacked)
        # drop the dp axis (None), keep "model" placements
        cleaned = tuple(a if a == "model" else None for a in spec)
        return P(*cleaned)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def batch_pspec_dp_wide(cfg: ArchConfig, shape: ShapeConfig,
                        mesh: Mesh) -> Any:
    """Small-model layout: the "model" axis joins data parallelism.

    Hypothesis (musicgen/internvl-class, d_model < 2k): 16-way TP of tiny
    matmuls is all gather latency and no math — run batch over
    (data x model) = 256-way DP with ZeRO over "data" instead.
    """
    axes = tuple(mesh.axis_names)  # ("data","model") or ("pod",...)
    if cfg.frontend == "vit_stub":
        return {"inputs": P(axes, None, None), "labels": P(axes, None)}
    return {"inputs": P(axes, None), "labels": P(axes, None)}


def param_pspecs_dp_wide(params_shape: Any, mesh: Mesh) -> Any:
    """Params for the dp-wide layout: ZeRO over "data", replicated over
    "model" (every model-group holds the same shard)."""
    def rule(path, leaf):
        stacked = _path_str(path).startswith("blocks/")
        spec = param_pspec(path, leaf, "data", stacked)
        cleaned = tuple(a if a == "data" else None for a in spec)
        return P(*cleaned)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


_ATTN_MLP = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"}


def param_pspecs_decode_row(params_shape: Any, mesh: Mesh) -> Any:
    """Decode-optimized layout (§Perf cell A, iteration 3).

    Attention/dense-MLP weights are ROW-parallel: the *contracting* (input)
    dim is sharded over "model", so single-token matmuls produce partial
    sums resolved by tiny [B,1,*] all-reduces — weights are never gathered
    and activations stay replicated. MoE expert weights keep the
    F-sharded layout (already gather-free under the shard_map block);
    SSM mixers keep channel-TP (state locality).
    """
    def rule(path, leaf):
        pathstr = _path_str(path)
        name = pathstr.split("/")[-1]
        stacked = pathstr.startswith("blocks/")
        pre = (None,) if stacked else ()
        nd = leaf.ndim - (1 if stacked else 0)
        if name == "embed":
            return P("model", None)
        if name == "head":
            return P("model", None)
        if name in _ATTN_MLP and nd == 2:
            return P(*(pre + ("model", None)))
        if name in _ATTN_MLP and nd == 3:  # MoE expert weights
            if name == "w_down":
                return P(*(pre + (None, "model", None)))
            return P(*(pre + (None, None, "model")))
        # ssm / norms / misc: TP-only cleaning of the base rule
        spec = param_pspec(path, leaf, None, stacked)
        return P(*tuple(a if a == "model" else None for a in spec))

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def cache_pspecs_decode_row(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                            cache_shape: Any) -> Any:
    """KV cache sharded on the sequence (C) dim over "model" — the scores
    softmax reduces over shards with scalar-sized all-reduces, and the
    ring write uses select (iota-compare), which is layout-local."""
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    def rule(path, leaf):
        name = _path_str(path).split("/")[-1]
        nd = leaf.ndim
        if name in ("k", "v"):   # [R, B, kv, C, hd]
            return P(None, dp, None, "model", None)
        if name in ("pos", "valid") and nd == 2:  # [R, C]
            return P(None, "model")
        if name == "mamba" or name == "wkv":
            b = dp
            if nd == 4:
                return P(None, b, "model", None)
            return P(None, b, "model", None, None)
        if name in ("prev", "ffn_prev"):
            return P(None, dp, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def param_pspecs_zero2(params_shape: Any, mesh: Mesh) -> Any:
    """ZeRO-2 layout (§Perf cell C): parameters TP-sharded over "model"
    but REPLICATED over "data"; optimizer moments stay 2-D sharded.

    Hypothesis: ZeRO-3 (2-D FSDP) gathers every weight over "data" in the
    forward AND the remat'd backward — twice-plus per step. With params
    replicated over "data" the gathers disappear; the cost is one
    all-gather of the UPDATED params after the optimizer step (the update
    itself computes on the 2-D-sharded moment slices) and bf16 params
    resident per chip / "model" shard only.
    """
    def rule(path, leaf):
        stacked = _path_str(path).startswith("blocks/")
        spec = param_pspec(path, leaf, None, stacked)
        return P(*tuple(a if a == "model" else None for a in spec))

    return jax.tree_util.tree_map_with_path(rule, params_shape)
