"""UET-aware collective network model — the bridge between the paper's
transport and the training framework.

Given the collective byte counts parsed from a compiled step (the dry-run
artifacts), this module estimates collective wall time on a UET backend
fabric two ways:

1. `analytic_collective_time` — algorithmic lower bound: ring/tree costs
   on `links` of `link_gbps`, the classical alpha-beta model. This is the
   roofline's collective term.
2. `simulated_collective_time` — run the WHOLE multi-phase collective
   (dependency-scheduled ring / recursive-doubling / tree schedules from
   `repro.network.collectives`) through the packet-level UET fabric
   simulator under a chosen transport profile, optionally with
   in-network reduction (INC), and price the collective term from the
   actual simulated completion tick. This replaced the seed's
   single-phase steady-state proxy (now removed): phase dependencies,
   stragglers, algorithm choice and switch-resident reduction all show
   up in the number. Full multi-collective *step* pricing — plan ->
   schedule -> simulated step time — lives in `repro.network.traffic`.

`simulated_efficiency` = analytic / simulated time for the same spec —
the derate factor the roofline and the sharding planner consume
(term = bytes / (chips * link_bw * efficiency)).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core.lb.schemes import LBScheme
from repro.network.fabric import SimParams, simulate
from repro.network.topology import leaf_spine


@dataclass(frozen=True)
class FabricSpec:
    link_gbps: float = 400.0   # per ICI/NIC link — paper's design point
    links_per_chip: int = 1
    mtu: int = 4096

    @property
    def tick_seconds(self) -> float:
        """One simulator tick == one MTU serialization on one link."""
        return self.mtu / (self.link_gbps * 1e9 / 8 * self.links_per_chip)


def analytic_collective_time(kind: str, bytes_total: float, chips: int,
                             fabric: FabricSpec = FabricSpec()) -> float:
    """Alpha-beta time for one collective of `bytes_total` output bytes.

    Ring all-reduce moves 2*(n-1)/n of the data per chip; all-gather and
    reduce-scatter (n-1)/n; all-to-all (n-1)/n across bisection;
    collective-permute exactly its payload.
    """
    bw = fabric.link_gbps * 1e9 / 8 * fabric.links_per_chip
    per_chip = bytes_total / max(chips, 1)
    n = max(chips, 2)
    factor = {
        "all-reduce": 2 * (n - 1) / n,
        "all-gather": (n - 1) / n,
        "reduce-scatter": (n - 1) / n,
        "all-to-all": (n - 1) / n,
        "collective-permute": 1.0,
    }.get(kind, 1.0)
    return per_chip * factor / bw


def collective_term_seconds(coll_bytes: dict, chips: int,
                            fabric: FabricSpec = FabricSpec(),
                            efficiency: float = 1.0) -> float:
    """Total collective seconds for a {kind: bytes} dict (per-device HLO
    numbers -> aggregate wall estimate at `efficiency` of line rate)."""
    t = 0.0
    for kind, b in coll_bytes.items():
        if kind == "total":
            continue
        t += analytic_collective_time(kind, b * chips, chips, fabric)
    return t / max(efficiency, 1e-6)


def analytic_time_for_spec(kind: str, size_pkts: int, chips: int,
                           fabric: FabricSpec = FabricSpec()) -> float:
    """Alpha-beta bound for a per-rank-INPUT-denominated collective (the
    `repro.network.collectives` convention, size in MTU packets).
    `analytic_collective_time` is OUTPUT-denominated; the two differ for
    all-gather, whose output is n x the per-rank input block."""
    kind = kind.replace("_", "-")
    mult = chips if kind == "all-gather" else 1
    return analytic_collective_time(
        kind, size_pkts * mult * fabric.mtu * chips, chips, fabric)


# ---------------------------------------------------------------------------
# packet-level collective time from the UET simulator
# ---------------------------------------------------------------------------

def _collective_fabric(chips: int, hosts_per_leaf: int, oversub: int):
    leaves = max(1, -(-chips // hosts_per_leaf))
    return leaf_spine(leaves=leaves, spines=max(2, leaves // max(oversub, 1)),
                      hosts_per_leaf=hosts_per_leaf)


def simulated_collective_time(kind: str = "all-reduce",
                              bytes_total: "float | None" = None,
                              chips: int = 8, *,
                              size_pkts: "int | None" = None,
                              algo: str = "ring",
                              profile=None,
                              inc: bool = False,
                              fabric: FabricSpec = FabricSpec(),
                              hosts_per_leaf: int = 4,
                              oversub: int = 1,
                              trimming: bool = True,
                              ticks: "int | None" = None) -> float:
    """Wall seconds for ONE whole collective, measured on the packet
    fabric: the dependency-scheduled schedule (ring / recursive_doubling
    / tree) runs to completion inside one compiled scan and the result
    is the source-completion tick times the MTU serialization time.

    Give the payload either as `bytes_total` (collective output bytes,
    converted to per-rank packets at `fabric.mtu`) or directly as
    `size_pkts` (per-rank packets). `inc=True` switches on in-network
    reduction (meaningful for the tree algorithm's fan-in phase).

    Raises RuntimeError if the collective does not complete within the
    tick budget (default: 6x the serialization lower bound + slack).
    """
    from repro.network import collectives as coll
    from repro.network.profile import TransportProfile

    if size_pkts is None:
        if bytes_total is None:
            raise ValueError("give bytes_total or size_pkts")
        per_rank = bytes_total / max(chips, 1)
        if kind.replace("_", "-") == "all-gather":
            # bytes_total is OUTPUT-denominated (HLO convention) but the
            # schedule wants the per-rank INPUT block = output/n
            per_rank /= max(chips, 1)
        spec = coll.CollectiveSpec.from_bytes(kind, range(chips), per_rank,
                                              fabric.mtu)
    else:
        spec = coll.CollectiveSpec(kind, tuple(range(chips)), int(size_pkts))
    if profile is None:
        profile = TransportProfile.ai_full()
    if inc and not profile.inc:
        profile = replace(profile, inc=True, name=profile.name + "+inc")
    g = _collective_fabric(chips, hosts_per_leaf, oversub)
    wl = coll.build_workload(spec, algo)
    est = coll.analytic_ticks(spec, algo)
    budget = ticks if ticks is not None else 6 * est + 800
    # the budget rides as the traced max_ticks bound: a size sweep with
    # its size-dependent budgets shares ONE executable, and the chunked
    # driver exits at quiescence, so a generous budget costs nothing
    r = simulate(g, wl, profile, SimParams(trimming=trimming),
                 max_ticks=budget)
    ct = coll.collective_completion_ticks(r)
    if ct < 0:
        raise RuntimeError(
            f"collective {spec.kind}/{algo} on {chips} chips did not "
            f"complete within {budget} ticks — raise ticks=")
    return ct * fabric.tick_seconds


def simulated_efficiency(kind: str = "all-reduce", hosts: int = 8,
                         size_pkts: int = 64,
                         lb: "LBScheme | None" = None,
                         profile=None,
                         algo: str = "ring",
                         inc: bool = False,
                         trimming: bool = True,
                         oversub: int = 1,
                         ticks: "int | None" = None) -> float:
    """Achieved efficiency of one collective on the packet-level UET
    fabric: analytic alpha-beta time / simulated completion time, in
    (0, 1]. This is the derate the roofline collective term divides by.

    ``profile`` selects the full transport composition; ``lb`` is the
    shorthand for the common collective ablation axis (ai_full profile
    with that scheme). Passing both is ambiguous and raises.
    """
    from repro.network.profile import TransportProfile
    if profile is None:
        profile = TransportProfile.ai_full(
            lb=LBScheme.OBLIVIOUS if lb is None else lb)
    elif lb is not None:
        raise ValueError("pass either profile= or lb=, not both — encode "
                         "the LB scheme in the profile")
    fabric = FabricSpec()
    t_sim = simulated_collective_time(
        kind, chips=hosts, size_pkts=size_pkts, algo=algo, profile=profile,
        inc=inc, fabric=fabric, oversub=oversub, trimming=trimming,
        ticks=ticks)
    t_ana = analytic_time_for_spec(kind, size_pkts, hosts, fabric)
    return float(min(1.0, t_ana / max(t_sim, 1e-12)))
