"""The unified decoder LM: pattern-based blocks, scan-over-layers, remat.

A model is `repeats` copies of a repeating *unit* (cfg.pattern), each unit a
short list of (mixer, ffn) positions — one position for uniform models,
eight for Jamba's 1:7 mamba:attention interleave. Parameters are stored
stacked over repeats ([R, ...] leading dim) and the layer stack runs as a
single `jax.lax.scan` whose body is `jax.checkpoint`-ed — one compiled
layer body regardless of depth, which keeps both compile time and HLO size
flat across the 24..72-layer architecture zoo.

MoE blocks run under `shard_map` so expert routing (top-k, sort,
ragged_dot grouped GEMM) stays *local to each data shard* — a global
argsort over a sharded token axis would otherwise turn into a giant
collective. The FFN dim of every expert is tensor-parallel over "model"
and contributes one psum per MoE block.

Decode carries a per-position cache pytree stacked over repeats, threaded
through the same scan.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import (ArchConfig, FFN_MLP, FFN_MOE, FFN_RWKV,
                                MIXER_ATTN, MIXER_MAMBA, MIXER_RWKV)
from repro.models import layers as L


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg: ArchConfig, key: jax.Array,
                dtype=jnp.bfloat16) -> dict:
    """Parameter pytree. Leaves of block params carry a leading [R] dim."""
    R = cfg.repeats
    keys = jax.random.split(key, 2 + len(cfg.pattern))
    D, V = cfg.d_model, cfg.vocab

    def stack(fn):
        """init fn(key)->tree, stacked over repeats."""
        def stacked(k):
            ks = jax.random.split(k, R)
            return jax.vmap(fn)(ks)
        return stacked

    blocks = {}
    for i, (mixer, ffn) in enumerate(cfg.pattern):
        def pos_init(k, mixer=mixer, ffn=ffn):
            km, kf = jax.random.split(k)
            p = {"norm1": L.init_rms(D, dtype), "norm2": L.init_rms(D, dtype)}
            if mixer == MIXER_ATTN:
                p["mixer"] = L.init_attention(km, D, cfg.n_q, cfg.n_kv,
                                              cfg.head_dim, dtype)
            elif mixer == MIXER_MAMBA:
                p["mixer"] = L.init_mamba(km, D, cfg.ssm_state,
                                          cfg.mamba_expand, dtype)
            elif mixer == MIXER_RWKV:
                p["mixer"] = _init_rwkv_padded(km, cfg, dtype)
            if ffn == FFN_MLP:
                p["ffn"] = L.init_mlp(kf, D, cfg.d_ff, dtype)
            elif ffn == FFN_MOE:
                p["ffn"] = L.init_moe(kf, D, cfg.d_ff, cfg.num_experts, dtype)
            elif ffn == FFN_RWKV:
                p["ffn"] = L.init_rwkv_mlp(kf, D, cfg.d_ff, dtype)
            return p
        blocks[f"pos{i}"] = stack(pos_init)(keys[2 + i])

    return {
        "embed": jax.random.normal(keys[0], (V, D), dtype) * 0.02,
        "head": jax.random.normal(keys[1], (D, V), dtype) * D ** -0.5,
        "final_norm": L.init_rms(D, dtype),
        "blocks": blocks,
    }


def _init_rwkv_padded(key, cfg: ArchConfig, dtype):
    """RWKV with inner dim padded so heads shard over TP=16."""
    D, DI = cfg.d_model, cfg.rwkv_inner
    ks = jax.random.split(key, 6)
    s = D ** -0.5
    return {
        "w_r": jax.random.normal(ks[0], (D, DI), dtype) * s,
        "w_k": jax.random.normal(ks[1], (D, DI), dtype) * s,
        "w_v": jax.random.normal(ks[2], (D, DI), dtype) * s,
        "w_g": jax.random.normal(ks[3], (D, DI), dtype) * s,
        "w_o": jax.random.normal(ks[4], (DI, D), dtype) * (DI ** -0.5),
        "w_decay": jax.random.normal(ks[5], (D, DI), dtype) * s,
        "decay_bias": jnp.full((DI,), -6.0, jnp.float32),
        "bonus": jnp.zeros((cfg.rwkv_heads, cfg.rwkv_head_dim), jnp.float32),
        "mix": jnp.full((5, D), 0.5, jnp.float32),
    }


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Decode cache pytree, stacked [R, ...] per pattern position.

    Attention: ring KV cache of `cache_len` (the sliding window for SWA).
    Mamba: [B, d_inner, N] state. RWKV: wkv matrix state + prev-token."""
    R = cfg.repeats
    caches = {}
    for i, (mixer, ffn) in enumerate(cfg.pattern):
        c: dict[str, Any] = {}
        if mixer == MIXER_ATTN:
            clen = min(cache_len, cfg.sliding_window) if cfg.sliding_window \
                else cache_len
            c["attn"] = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (R,) + x.shape),
                L.init_attention_cache(batch, cfg.n_kv, clen, cfg.head_dim,
                                       dtype))
        elif mixer == MIXER_MAMBA:
            di = cfg.mamba_expand * cfg.d_model
            c["mamba"] = jnp.zeros((R, batch, di, cfg.ssm_state), jnp.float32)
        elif mixer == MIXER_RWKV:
            c["rwkv"] = {
                "wkv": jnp.zeros((R, batch, cfg.rwkv_heads, cfg.rwkv_head_dim,
                                  cfg.rwkv_head_dim), jnp.float32),
                "prev": jnp.zeros((R, batch, cfg.d_model), dtype),
            }
        if ffn == FFN_RWKV:
            c["ffn_prev"] = jnp.zeros((R, batch, cfg.d_model), dtype)
        caches[f"pos{i}"] = c
    return caches


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _moe_block(cfg: ArchConfig, mesh, dp_axes, token_spec,
               capacity_factor: float = 1.25, sequential: bool = True):
    """Build the (optionally shard_map'd) MoE application fn.

    Dropless-ish capacity dispatch: tokens are sorted by expert and each
    expert processes a fixed-capacity contiguous slice (capacity =
    cf * T * k / E; overflow tokens are dropped, Switch-style). The expert
    GEMMs are batched einsums over [E, cap, D] — XLA counts their FLOPs
    exactly and, unlike `jax.lax.ragged_dot`, their VJP does not
    materialize dense [E, T, D] intermediates (the reason ragged_dot was
    abandoned here — see DESIGN.md §MoE).
    """
    top_k = cfg.experts_per_token

    def local_moe(xt, router, w_gate, w_up, w_down):
        T, D = xt.shape
        E = router.shape[1]
        # token chunking bounds the gather/scatter adjoint transients
        n_chunks = 1
        while T // n_chunks > 16384:
            n_chunks *= 2
        Tc = T // n_chunks
        cap = max(8, int(Tc * top_k * capacity_factor) // E)

        # NOTE: no preferred_element_type=f32 here — its VJP would emit an
        # f32 [T, D] d_xt and promote the whole token cotangent chain.
        logits = (xt @ router.astype(xt.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_all, experts_all = jax.lax.top_k(probs, top_k)
        gate_all = gate_all / jnp.sum(gate_all, axis=-1, keepdims=True)

        # per-expert FFN, checkpointed; Python-unrolled over experts (a
        # lax.scan body would be FLOP-counted once by XLA cost analysis)
        @partial(jax.checkpoint,
                 policy=jax.checkpoint_policies.nothing_saveable)
        def ffn(wg, wu, wd, xin):
            act = (jax.nn.silu(xin @ wg) * (xin @ wu)).astype(xin.dtype)
            return act @ wd

        @partial(jax.checkpoint,
                 policy=jax.checkpoint_policies.nothing_saveable)
        def chunk_moe(xc, gates, experts):
            """One token chunk: sort locally, one gather, E FFNs, one
            scatter. xc: [Tc, D]."""
            flat_expert = experts.reshape(-1)                  # [Tc*K]
            flat_token = jnp.repeat(jnp.arange(Tc), top_k)
            order = jnp.argsort(flat_expert)
            sorted_token = flat_token[order]
            group_sizes = jnp.bincount(flat_expert, length=E).astype(
                jnp.int32)
            starts = jnp.cumsum(group_sizes) - group_sizes
            gates_flat = gates.reshape(-1)[order].astype(xc.dtype)

            pos = starts[:, None] + jnp.arange(cap)[None]      # [E, cap]
            valid = jnp.arange(cap)[None] < group_sizes[:, None]
            pos_c = jnp.clip(pos, 0, Tc * top_k - 1).reshape(-1)
            tok_all = sorted_token[pos_c]                      # [E*cap]
            vmask = valid.reshape(-1)
            xin_all = xc[tok_all] * vmask[:, None].astype(xc.dtype)
            g_all = gates_flat[pos_c] * vmask.astype(xc.dtype)

            yos = [ffn(w_gate[e], w_up[e], w_down[e],
                       xin_all[e * cap:(e + 1) * cap]) for e in range(E)]
            yo_all = jnp.concatenate(yos, 0) * g_all[:, None]
            drop_tok = jnp.where(vmask, tok_all, Tc)           # OOB => drop
            return jnp.zeros((Tc, D), xc.dtype).at[drop_tok].add(
                yo_all, mode="drop")

        if sequential and n_chunks > 1:
            # lax.scan serializes chunk processing (bounds live memory);
            # used by the full/memory build. The FLOP-calibration variants
            # use the Python loop below so XLA counts every chunk.
            _, ys = jax.lax.scan(
                lambda c, xs: (c, chunk_moe(*xs)), 0,
                (xt.reshape(n_chunks, Tc, D),
                 gate_all.reshape(n_chunks, Tc, top_k),
                 experts_all.reshape(n_chunks, Tc, top_k)))
            out = ys.reshape(T, D)
        else:
            outs = [chunk_moe(xt[i * Tc:(i + 1) * Tc],
                              gate_all[i * Tc:(i + 1) * Tc],
                              experts_all[i * Tc:(i + 1) * Tc])
                    for i in range(n_chunks)]
            out = jnp.concatenate(outs, 0)
        if mesh is not None:
            out = jax.lax.psum(out, "model")
        me = probs.mean(axis=0)
        ce = jnp.bincount(experts_all.reshape(-1), length=E).astype(
            jnp.float32) / (T * top_k)
        aux = E * jnp.sum(me * ce)
        if mesh is not None:
            aux = jax.lax.pmean(aux, dp_axes)
        return out, aux

    if mesh is None:
        return local_moe

    wspec_in = P(None, None, "model")    # [E, D, F/tp]
    wspec_out = P(None, "model", None)   # [E, F/tp, D]
    return shard_map(
        local_moe, mesh=mesh,
        in_specs=(token_spec, P(None, None), wspec_in, wspec_in, wspec_out),
        out_specs=(token_spec, P()),
        check_rep=False,
    )


def build_forward(cfg: ArchConfig, mesh=None, dp_axes=("data",),
                  decode: bool = False, remat: bool = True,
                  moe_token_spec=None, select_write: bool = False,
                  act_spec=None, output: str = "logits",
                  scan_layers: bool = True, attn_head_specs=None,
                  sharded_cache_attn: bool = False,
                  remat_policy: str = "nothing"):
    """Return fwd(params, tokens_or_embeds, cache=None, pos0=0).

    Training/prefill: full-sequence forward, returns (logits, aux, cache').
    Decode: single-token step against the cache.
    """
    if moe_token_spec is None:
        moe_token_spec = P(dp_axes, None) if mesh is not None else None
    moe_fn = _moe_block(cfg, mesh, dp_axes, moe_token_spec,
                        sequential=scan_layers)
    cache_attn = (L.sharded_cache_attention(mesh, dp_axes)
                  if sharded_cache_attn and mesh is not None else None)
    has_moe = any(f == FFN_MOE for _, f in cfg.pattern)

    def unit_fn(x, positions, unit_params, unit_cache):
        """Apply one repeating unit. x: [B, S, D]."""
        aux_total = jnp.zeros((), jnp.float32)
        new_cache = {} if unit_cache is not None else None
        for i, (mixer, ffn) in enumerate(cfg.pattern):
            p = unit_params[f"pos{i}"]
            c = unit_cache[f"pos{i}"] if unit_cache is not None else None
            nc: dict[str, Any] = {}
            h = L.rms_norm(x, p["norm1"]["scale"])
            if mixer == MIXER_ATTN:
                out, ac = L.attention_fwd(
                    p["mixer"], h, positions, n_q=cfg.n_q, n_kv=cfg.n_kv,
                    head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
                    window=cfg.sliding_window,
                    cache=c["attn"] if c is not None else None,
                    select_write=select_write,
                    head_shardings=attn_head_specs,
                    cache_attn=cache_attn)
                if ac is not None:
                    nc["attn"] = ac
                from jax.ad_checkpoint import checkpoint_name
                out = checkpoint_name(out, "mixer_out")
            elif mixer == MIXER_MAMBA:
                out, st = L.mamba_fwd(p["mixer"], h,
                                      state=c["mamba"] if c is not None
                                      else None)
                if c is not None:
                    nc["mamba"] = st
            else:  # rwkv
                out, st = L.rwkv_fwd(p["mixer"], h,
                                     state=c["rwkv"] if c is not None
                                     else None, n_heads=cfg.rwkv_heads)
                if c is not None:
                    nc["rwkv"] = st
            x = x + out

            h = L.rms_norm(x, p["norm2"]["scale"])
            if ffn == FFN_MLP:
                out = L.mlp_fwd(p["ffn"], h)
            elif ffn == FFN_MOE:
                B, S, D = h.shape
                ht = h.reshape(B * S, D)
                out, aux = moe_fn(ht, p["ffn"]["router"], p["ffn"]["w_gate"],
                                  p["ffn"]["w_up"], p["ffn"]["w_down"])
                out = out.reshape(B, S, D)
                aux_total = aux_total + aux
            else:  # rwkv channel mix
                out, prev = L.rwkv_mlp_fwd(
                    p["ffn"], h,
                    prev=c["ffn_prev"] if c is not None else None)
                if c is not None:
                    nc["ffn_prev"] = prev
            x = x + out
            if new_cache is not None:
                new_cache[f"pos{i}"] = nc
        return x, aux_total, new_cache

    def fwd(params, inputs, cache=None, pos0=0):
        if cfg.frontend == "vit_stub" and inputs.ndim == 3:
            x = inputs.astype(params["embed"].dtype)  # precomputed embeds
        else:
            x = params["embed"][inputs]               # [B, S, D]
        B, S = x.shape[0], x.shape[1]
        positions = pos0 + jnp.arange(S, dtype=jnp.int32)

        body = unit_fn
        if remat and cache is None:
            policy = (jax.checkpoint_policies.save_only_these_names(
                "mixer_out") if remat_policy == "save_mixer"
                else jax.checkpoint_policies.nothing_saveable)
            body = jax.checkpoint(unit_fn, policy=policy, static_argnums=())

        def constrain(x):
            if act_spec is not None:
                return jax.lax.with_sharding_constraint(x, act_spec)
            return x

        x = constrain(x)
        if not scan_layers:
            # Python-unrolled layer stack: used by the dry-run's R=1/R=2
            # FLOP-calibration lowers (XLA cost analysis counts a while-loop
            # body once; unrolling makes per-unit costs measurable).
            aux = jnp.zeros((), jnp.float32)
            R = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]
            new_caches = []
            for r in range(R):
                up = jax.tree_util.tree_map(lambda a: a[r], params["blocks"])
                uc = (jax.tree_util.tree_map(lambda a: a[r], cache)
                      if cache is not None else None)
                x, a, nc = body(x, positions, up, uc)
                x = constrain(x)
                aux = aux + a
                if cache is not None:
                    new_caches.append(nc)
            new_cache = (jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_caches)
                if cache is not None else None)
        elif cache is None:
            def scan_body(carry, unit_params):
                x, aux = carry
                x, a, _ = body(x, positions, unit_params, None)
                return (constrain(x), aux + a), None
            (x, aux), _ = jax.lax.scan(
                scan_body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
            new_cache = None
        else:
            def scan_body(carry, xs):
                x, aux = carry
                unit_params, unit_cache = xs
                x, a, nc = body(x, positions, unit_params, unit_cache)
                return (x, aux + a), nc
            (x, aux), new_cache = jax.lax.scan(
                scan_body, (x, jnp.zeros((), jnp.float32)),
                (params["blocks"], cache))

        x = L.rms_norm(x, params["final_norm"]["scale"])
        if output == "hidden":
            return x, aux, new_cache
        logits = x @ params["head"]
        return logits, aux, new_cache

    return fwd
