"""internvl2-1b: InternViT + InternLM2 VLM; backbone only, ViT frontend is
a stub providing precomputed patch embeddings [arXiv:2404.16821; hf]."""
from repro.configs.base import ArchConfig, pad_for_tp, MIXER_ATTN, FFN_MLP

CONFIG = pad_for_tp(ArchConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    head_dim=64, d_ff=4864, vocab_size=151_655,
    pattern=((MIXER_ATTN, FFN_MLP),),
    frontend="vit_stub",
    source="arXiv:2404.16821; hf",
))
