"""jamba-1.5-large-398b: hybrid Mamba+attention 1:7 interleave, MoE 16e
top-2 [arXiv:2403.19887; hf]. Unit of 8 layers: attention at position 3,
MoE FFN at every other position."""
from repro.configs.base import (ArchConfig, pad_for_tp, MIXER_ATTN,
                                MIXER_MAMBA, FFN_MLP, FFN_MOE)

_UNIT = tuple(
    (MIXER_ATTN if i == 3 else MIXER_MAMBA,
     FFN_MOE if i % 2 == 1 else FFN_MLP)
    for i in range(8)
)

CONFIG = pad_for_tp(ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=24576, vocab_size=65536,
    num_experts=16, experts_per_token=2,
    pattern=_UNIT, ssm_state=16, mamba_expand=2,
    source="arXiv:2403.19887; hf",
))
