"""Endpoint-failure resilience: host faults, PDC liveness teardown, and
the priced checkpoint-restart recovery loop — contracts.

Locked here (see DESIGN.md "Endpoint failure & recovery contract"):

* host-fault lanes are OPT-IN and validated: addressing hosts needs
  ``num_hosts``-widened lanes, host ids are range-checked, widths
  compose across ``stack``/``with_seed``/``with_hosts`` with exactly
  one nonzero host count, and schedules are checked against the
  topology's host count at dispatch;
* all-healthy host lanes are bitwise inert — widening a schedule with
  ``num_hosts`` without scheduling an endpoint fault compiles and runs
  the exact pre-endpoint-fault program;
* QUARANTINE LIVENESS: a permanent endpoint death (dead host, or a
  never-healing outage of every uplink a host's traffic rides) under a
  ``pdc_dead_after`` profile is detected via consecutive zero-progress
  RTO strikes, torn down, and the run quiesces EARLY — strictly before
  the tick budget — with the surviving flows' delivered payload
  identical to the pdc-off twin's (which burns the whole budget);
* an ACK-live NIC stall is NOT death: nothing is abandoned and the
  stalled flows complete after heal;
* the new stat lanes (``flows_abandoned``, ``ticks_unreachable``,
  ``abandon_tick``, quarantine/strike state) are bitwise identical
  serial vs batched vs sharded with per-lane host faults riding the
  scenario axis;
* the PDC FSM takes PEER_DEAD from every live state straight to CLOSED
  and ``pdc.unreachable`` mirrors the engine's strike predicate;
* the recovery loop is PRICED: ``traffic.price_recovery`` measures
  detection/restore/replan for one lost DP host, and the Young/Daly
  closed forms in ``repro.ckpt.checkpointing`` are optimal (tau* is the
  availability argmax) and monotone in MTBF.
"""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pdc
from repro.core.types import PDCState
from repro.network.fabric import SimParams, Workload, simulate, simulate_batch
from repro.network.faults import FaultSchedule
from repro.network.profile import TransportProfile
from repro.network.topology import leaf_spine

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=4; set by tests/conftest.py unless overridden)")


def _state_equal(a, b) -> bool:
    return all(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b)))


def _small():
    """2 leaves x 2 spines, 4 hosts/leaf. Flows 0/1 cross leaves (ride
    leaf-0 uplinks), flow 2 stays inside leaf 0, flow 3 crosses from
    leaf 1 (rides leaf-1 uplinks + spine->leaf-0 downlinks only)."""
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2, 6], [4, 5, 3, 0], 150)
    ups = [int(g.up1_table[0, i]) for i in range(2)]
    return g, wl, ups


# ------------------------------------------------------------------------
# host-lane API validation + composition
# ------------------------------------------------------------------------

def test_host_builders_require_host_lanes():
    g, _, _ = _small()
    s = FaultSchedule.healthy(g.num_queues)
    with pytest.raises(ValueError, match="needs host lanes"):
        s.host_fail(0, 100)
    with pytest.raises(ValueError, match="needs host lanes"):
        s.nic_stall(0, 100)
    # with_hosts unlocks them
    s2 = s.with_hosts(g.num_hosts).host_fail(1, 100)
    assert s2.num_hosts == g.num_hosts and s2.has_host_faults


def test_host_ids_are_range_checked():
    g, _, _ = _small()
    s = FaultSchedule.healthy(g.num_queues, num_hosts=g.num_hosts)
    with pytest.raises(ValueError, match="host ids"):
        s.host_fail(g.num_hosts, 100)
    with pytest.raises(ValueError, match="host ids"):
        s.nic_stall(-1, 100)


def test_with_hosts_rewiden_is_an_error():
    g, _, _ = _small()
    s = FaultSchedule.healthy(g.num_queues, num_hosts=8)
    assert s.with_hosts(8) is s
    with pytest.raises(ValueError, match="re-widen"):
        s.with_hosts(4)


def test_stack_normalizes_mixed_host_widths():
    g, _, _ = _small()
    plain = FaultSchedule.healthy(g.num_queues)
    hosty = FaultSchedule.healthy(
        g.num_queues, num_hosts=g.num_hosts).host_fail(1, 100)
    st = FaultSchedule.stack([plain, hosty])
    assert st.num_hosts == g.num_hosts
    assert st.host_fail_at.shape == (2, g.num_hosts)
    # lane 0 widened all-healthy, lane 1 carries the window
    assert not np.asarray(st.host_dead_at(100))[0].any()
    assert np.asarray(st.host_dead_at(100))[1, 1]
    other = FaultSchedule.healthy(g.num_queues, num_hosts=4)
    with pytest.raises(ValueError, match="different host counts"):
        FaultSchedule.stack([hosty, other])


def test_with_seed_composes_with_host_lanes():
    g, _, ups = _small()
    s = (FaultSchedule.healthy(g.num_queues, num_hosts=g.num_hosts)
         .host_fail(2, 50, 90).nic_stall(3, 60)
         .lossy(ups[0], 0.1).with_seed(7))
    assert int(s.seed) == 7
    assert bool(np.asarray(s.host_dead_at(50))[2])
    assert not np.asarray(s.host_dead_at(90))[2]      # healed
    assert bool(np.asarray(s.nic_stalled_at(1000))[3])  # permanent
    assert s.has_host_faults


def test_schedule_host_count_validated_against_topology():
    g, wl, _ = _small()
    bad = FaultSchedule.healthy(g.num_queues, num_hosts=g.num_hosts + 1)
    with pytest.raises(ValueError, match="hosts"):
        simulate(g, wl, TransportProfile.resilient(), SimParams(ticks=10),
                 faults=bad)


def test_all_healthy_host_lanes_are_bitwise_inert():
    """Widened-but-empty host lanes must select the pre-endpoint-fault
    executable and reproduce the no-faults run bit for bit."""
    g, wl, _ = _small()
    p = SimParams(ticks=700)
    prof = TransportProfile.ai_full()
    idle = FaultSchedule.healthy(g.num_queues, num_hosts=g.num_hosts)
    assert not idle.has_host_faults
    a = simulate(g, wl, prof, p)
    b = simulate(g, wl, prof, p, faults=idle)
    assert a.horizon == b.horizon
    assert _state_equal(a.state, b.state)


# ------------------------------------------------------------------------
# profile knob + FSM
# ------------------------------------------------------------------------

def test_pdc_dead_after_validation_and_resilient_profile():
    with pytest.raises(ValueError, match="pdc_dead_after"):
        replace(TransportProfile.ai_full(), pdc_dead_after=-1)
    prof = TransportProfile.resilient()
    assert prof.pdc_dead_after > 0
    assert "pdc_dead_after" in prof.describe()
    assert TransportProfile.ai_full().pdc_dead_after == 0  # default off


def test_peer_dead_aborts_every_live_state_to_closed():
    ev = jnp.full((4,), int(pdc.InitEvent.PEER_DEAD), jnp.int32)
    live = jnp.asarray([int(PDCState.SYN), int(PDCState.ESTABLISHED),
                        int(PDCState.QUIESCE), int(PDCState.ACK_WAIT)],
                       jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(pdc.step_initiator(live, ev)),
        np.full(4, int(PDCState.CLOSED)))
    # CLOSED holds (self-loop): nothing to tear down
    closed = jnp.asarray([int(PDCState.CLOSED)], jnp.int32)
    assert int(pdc.step_initiator(closed, ev[:1])[0]) == int(PDCState.CLOSED)


def test_unreachable_mirrors_strike_threshold():
    strikes = jnp.asarray([0, 3, 4, 9], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(pdc.unreachable(strikes, 4)),
        [False, False, True, True])
    assert not np.asarray(pdc.unreachable(strikes, 0)).any()  # disabled


# ------------------------------------------------------------------------
# quarantine liveness: the regression the teardown path exists to fix
# ------------------------------------------------------------------------

def test_dead_uplinks_quiesce_early_with_survivors_intact():
    """A never-healing outage of BOTH leaf-0 uplinks strands the two
    cross-leaf flows sourced there forever. Without liveness teardown
    the run burns the entire tick budget (the pre-PR behavior, locked
    as the pdc-off twin); with ``pdc_dead_after`` the stranded flows
    quarantine and the run quiesces early — and the surviving flows
    (intra-leaf, and cross-leaf from the healthy side) deliver exactly
    the same payload either way."""
    g, wl, ups = _small()
    budget = 4000
    p = SimParams(ticks=budget, timeout_ticks=64)
    dead = FaultSchedule.healthy(g.num_queues).flap(ups, 120)  # forever
    on = TransportProfile.resilient()
    off = replace(on, pdc_dead_after=0, name="resilient-pdc_off")

    r_off = simulate(g, wl, off, p, faults=dead)
    assert r_off.horizon == budget, \
        f"pdc-off must burn the budget, exited at {r_off.horizon}"
    assert r_off.flows_abandoned == 0
    assert r_off.completion_tick() == -1

    r_on = simulate(g, wl, on, p, faults=dead)
    assert r_on.horizon < budget, \
        f"quarantine must quiesce early, ran {r_on.horizon}/{budget}"
    assert r_on.flows_abandoned == 2
    assert r_on.abandon_tick > 120
    assert r_on.ticks_unreachable > 0
    ct = r_on.completion_ticks()
    assert ct[0] == -1 and ct[1] == -1          # stranded, torn down
    assert ct[2] > 0 and ct[3] > 0              # survivors complete
    # identical delivered payload on the survivors, either engine
    dv_on = np.asarray(r_on.state.delivered)
    dv_off = np.asarray(r_off.state.delivered)
    np.testing.assert_array_equal(dv_on[2:], dv_off[2:])
    np.testing.assert_array_equal(dv_on[2:], np.asarray(wl.size)[2:])


def test_dead_host_zero_traffic_flow_still_quarantines():
    """A flow whose source dies BEFORE injecting anything never arms an
    RTO the normal way; the endpoint-overdue stall path must still
    strike it out (no silent budget burn)."""
    g, wl, _ = _small()
    budget = 4000
    p = SimParams(ticks=budget, timeout_ticks=64)
    sched = FaultSchedule.healthy(
        g.num_queues, num_hosts=g.num_hosts).host_fail(0, 0)  # dead at t=0
    r = simulate(g, wl, TransportProfile.resilient(), p, faults=sched)
    # host 0 sources flow 0 and sinks flow 3: both torn down
    assert r.flows_abandoned == 2
    assert r.horizon < budget
    ct = r.completion_ticks()
    assert ct[0] == -1 and ct[3] == -1
    assert ct[1] > 0 and ct[2] > 0


def test_nic_stall_is_not_death():
    """Injection frozen but ACK-live: the RTO strike path must NOT tear
    the flow down, and everything completes after the stall heals."""
    g, wl, _ = _small()
    p = SimParams(ticks=6000, timeout_ticks=64)
    sched = FaultSchedule.healthy(
        g.num_queues, num_hosts=g.num_hosts).nic_stall(0, 100, 900)
    r = simulate(g, wl, TransportProfile.resilient(), p, faults=sched)
    assert r.flows_abandoned == 0
    assert r.completion_tick() > 0
    np.testing.assert_array_equal(np.asarray(r.state.delivered),
                                  np.asarray(wl.size))


# ------------------------------------------------------------------------
# stat-lane parity: serial == batched == sharded with host lanes riding
# ------------------------------------------------------------------------

def _host_fault_grid():
    g, wl, _ = _small()
    healthy = FaultSchedule.healthy(g.num_queues, num_hosts=g.num_hosts)
    scheds = [
        healthy,                                # all-healthy widened lanes
        healthy.host_fail(0, 100),              # permanent host death
        healthy.host_fail(5, 100, 400),         # death that heals
        healthy.nic_stall(1, 100, 500),         # ACK-live stall
    ]
    p = SimParams(ticks=4000, timeout_ticks=64)
    return g, wl, scheds, TransportProfile.resilient(), p


def test_batched_host_faults_match_serial_lanes():
    g, wl, scheds, prof, p = _host_fault_grid()
    batch = simulate_batch(g, Workload.stack([wl] * len(scheds)), prof, p,
                           faults=FaultSchedule.stack(scheds))
    assert batch[1].flows_abandoned > 0      # the grid actually bites
    assert batch[0].flows_abandoned == 0
    for i, (sched, r) in enumerate(zip(scheds, batch)):
        solo = simulate(g, wl, prof, p, faults=sched)
        assert solo.horizon == r.horizon, f"scenario {i}"
        assert solo.flows_abandoned == r.flows_abandoned, f"scenario {i}"
        assert solo.ticks_unreachable == r.ticks_unreachable, f"scenario {i}"
        assert solo.abandon_tick == r.abandon_tick, f"scenario {i}"
        np.testing.assert_array_equal(solo.completion_ticks(),
                                      r.completion_ticks(),
                                      err_msg=f"scenario {i}")
        assert _state_equal(solo.state, r.state), f"scenario {i}"


@multi_device
def test_sharded_host_faults_match_batched_lanes():
    g, wl, scheds, prof, p = _host_fault_grid()
    wls = Workload.stack([wl] * len(scheds))
    fs = FaultSchedule.stack(scheds)
    base = simulate_batch(g, wls, prof, p, faults=fs)
    shd = simulate_batch(g, wls, prof, p, faults=fs, shard=True)
    for i, (a, b) in enumerate(zip(base, shd)):
        assert a.horizon == b.horizon, f"scenario {i}"
        assert a.flows_abandoned == b.flows_abandoned, f"scenario {i}"
        assert a.ticks_unreachable == b.ticks_unreachable, f"scenario {i}"
        assert a.abandon_tick == b.abandon_tick, f"scenario {i}"
        assert _state_equal(a.state, b.state), f"scenario {i}"


# ------------------------------------------------------------------------
# checkpoint-restart economics
# ------------------------------------------------------------------------

def test_young_daly_is_the_availability_argmax():
    from repro.ckpt.checkpointing import availability, young_daly_interval
    costs = dict(write_s=2.0, detect_s=1.0, restore_s=2.0, replan_s=3.0)
    for mtbf in (600.0, 3600.0, 86400.0):
        tau = young_daly_interval(mtbf, costs["write_s"])
        best = availability(tau, mtbf, **costs)
        for f in (0.25, 0.5, 0.9, 1.1, 2.0, 4.0):
            assert best > availability(tau * f, mtbf, **costs), (mtbf, f)


def test_availability_monotone_in_mtbf():
    from repro.ckpt.checkpointing import availability, young_daly_interval
    prev = 0.0
    for mtbf in (300.0, 1800.0, 3600.0, 7200.0, 86400.0):
        av = availability(young_daly_interval(mtbf, 1.5), mtbf,
                          write_s=1.5, detect_s=0.5, restore_s=1.0,
                          replan_s=2.0)
        assert 0.0 < av < 1.0
        assert av > prev, mtbf
        prev = av


def test_economics_validation_and_effective_rate():
    from repro.ckpt.checkpointing import (availability, effective_rate,
                                          young_daly_interval)
    with pytest.raises(ValueError, match="mtbf_s"):
        young_daly_interval(0.0, 1.0)
    with pytest.raises(ValueError, match="write_s"):
        young_daly_interval(100.0, -1.0)
    with pytest.raises(ValueError, match="interval_s"):
        availability(0.0, 100.0, write_s=1.0)
    with pytest.raises(ValueError, match="restore_s"):
        availability(10.0, 100.0, write_s=1.0, restore_s=-2.0)
    av = availability(10.0, 1000.0, write_s=1.0)
    assert effective_rate(500.0, 10.0, 1000.0, write_s=1.0) \
        == pytest.approx(500.0 * av)


# ------------------------------------------------------------------------
# replan + priced recovery
# ------------------------------------------------------------------------

def _train_plan(dp=4):
    from repro import configs
    from repro.distributed.plan import derive_plan
    return derive_plan(configs.get("deepseek-coder-33b"), "train_4k",
                       dp=dp, tp=4, layout="fsdp_tp")


def test_replan_onto_survivors():
    from repro.distributed.plan import replan_onto_survivors
    plan = _train_plan()
    p2 = replan_onto_survivors(plan, 1)
    assert p2.dp == plan.dp - 1
    assert (p2.tp, p2.pp, p2.arch, p2.shape, p2.layout) \
        == (plan.tp, plan.pp, plan.arch, plan.shape, plan.layout)
    assert p2.tokens_per_step == plan.tokens_per_step  # same global batch
    assert replan_onto_survivors(plan, 0) is plan
    with pytest.raises(ValueError, match="failed_hosts"):
        replan_onto_survivors(plan, -1)
    with pytest.raises(ValueError, match="surviving"):
        replan_onto_survivors(plan, plan.dp)


def test_price_recovery_rejects_unlosable_plans():
    from repro.network.traffic import checkpoint_seconds, price_recovery
    with pytest.raises(ValueError, match="DP axis"):
        price_recovery(_train_plan(dp=1))
    prof_off = TransportProfile.ai_full()
    with pytest.raises(ValueError, match="pdc_dead_after"):
        price_recovery(_train_plan(), profile=prof_off)
    with pytest.raises(ValueError, match="storage_gbps"):
        checkpoint_seconds(_train_plan(), storage_gbps=0.0)


@pytest.mark.slow
def test_price_recovery_end_to_end():
    """The full loop: healthy rate, one dead DP host detected via the
    simulated PDC teardown (early quiescence), restore + replan priced,
    degraded rate strictly below healthy."""
    from repro.ckpt.checkpointing import effective_rate
    from repro.network.traffic import checkpoint_seconds, price_recovery
    plan = _train_plan()
    rc = price_recovery(plan)
    assert rc.detect_ticks > 0 and rc.detect_s > 0
    assert rc.flows_abandoned > 0
    assert rc.horizon < rc.budget            # teardown ended the run early
    assert rc.restore_s == pytest.approx(checkpoint_seconds(plan))
    assert 0 < rc.degraded_tokens_per_sec < rc.healthy_tokens_per_sec
    assert rc.replan_s > 0 and rc.downtime_s > rc.restore_s
    eff = effective_rate(rc.healthy_tokens_per_sec, 60.0, 3600.0,
                         write_s=checkpoint_seconds(plan),
                         detect_s=rc.detect_s, restore_s=rc.restore_s,
                         replan_s=rc.replan_s)
    assert 0 < eff < rc.healthy_tokens_per_sec
