"""SES addressing (Sec. 3.1.1): FA -> JobID -> PIDonFEP -> Resource Index.

UE addresses a logical endpoint with (FA, JobID[24b], PIDonFEP[12b], RI[12b]).
Two modes exist, selected by the `rel` header bit:

* RELATIVE — parallel jobs: the JobID table at the FEP maps the packet's
  JobID to a per-job PIDonFEP table (the job's local processes); the
  PIDonFEP table entry points at the process's RI table.
* ABSOLUTE — client/server: PIDonFEP acts like a UDP port directly into a
  service table; the JobID is carried only as an authentication token.

This module implements the lookup pipeline as vectorized JAX gathers over
fixed-capacity tables so a whole batch of arriving packets resolves in one
fused op — the shape a hardware FEP pipeline would take. Authorization is
the JobID membership check (Sec. 3.1.1: "authorization to write to the
queue is provided by the Job ID").

Scalability claim reproduced here (tested in tests/test_addressing.py):
with relative addressing a source stores N node entries and computes the
target process as an offset, versus N*P direct entries — see
`directory_entries()`.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


JOBID_BITS = 24
PIDONFEP_BITS = 12
RI_BITS = 12

INVALID = jnp.int32(-1)


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class FEPTables:
    """Fixed-capacity addressing tables of one Fabric Endpoint.

    All arrays are int32. A -1 entry means "empty".

    jobid_keys:   [J]    JobIDs that have processes on this FEP
    jobid_to_pid: [J]    row index into pid_table for each job
    pid_table:    [J_cap, P] per-job PIDonFEP -> process slot (address space id)
    ri_table:     [Proc, R]  per-process RI -> receive-context id
    service_table:[S]    absolute mode: PIDonFEP -> receive-context id
    """

    jobid_keys: jax.Array
    jobid_to_pid: jax.Array
    pid_table: jax.Array
    ri_table: jax.Array
    service_table: jax.Array

    @staticmethod
    def create(num_jobs: int, procs_per_job: int, ris_per_proc: int,
               num_services: int = 64) -> "FEPTables":
        return FEPTables(
            jobid_keys=jnp.full((num_jobs,), INVALID),
            jobid_to_pid=jnp.full((num_jobs,), INVALID),
            pid_table=jnp.full((num_jobs, procs_per_job), INVALID),
            ri_table=jnp.full((num_jobs * procs_per_job, ris_per_proc), INVALID),
            service_table=jnp.full((num_services,), INVALID),
        )


def register_job(tables: FEPTables, slot: int, jobid: int,
                 proc_ids: jax.Array, ri_contexts: jax.Array) -> FEPTables:
    """Install a job at table row `slot` (management-plane operation).

    proc_ids: [P] local process slots for PIDonFEP 0..P-1 (or -1)
    ri_contexts: [P, R] receive-context ids per process per RI
    """
    pid_table = tables.pid_table.at[slot].set(proc_ids.astype(jnp.int32))
    base = slot * tables.pid_table.shape[1]
    ri_table = jax.lax.dynamic_update_slice(
        tables.ri_table, ri_contexts.astype(jnp.int32), (base, 0))
    return FEPTables(
        jobid_keys=tables.jobid_keys.at[slot].set(jobid),
        jobid_to_pid=tables.jobid_to_pid.at[slot].set(slot),
        pid_table=pid_table,
        ri_table=ri_table,
        service_table=tables.service_table,
    )


@partial(jax.jit, static_argnames=())
def resolve(tables: FEPTables, jobid: jax.Array, pid_on_fep: jax.Array,
            ri: jax.Array, rel: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Resolve a batch of arriving packets to receive-context ids.

    Args:
      jobid, pid_on_fep, ri, rel: int32 [B] header fields per packet.

    Returns:
      (ctx_id [B] int32, ok [B] bool). ctx_id == -1 where resolution or
      authorization failed (unknown JobID, out-of-range PID/RI, empty slot).
    """
    jobid = jobid.astype(jnp.int32)
    pid_on_fep = pid_on_fep.astype(jnp.int32)
    ri = ri.astype(jnp.int32)

    # --- relative mode: associative JobID match (CAM-style, vectorized) ---
    # [B, J] equality against the jobid CAM; empty rows never match.
    hits = (tables.jobid_keys[None, :] == jobid[:, None]) & (
        tables.jobid_keys[None, :] != INVALID)
    job_ok = hits.any(axis=1)
    job_row = jnp.where(job_ok, jnp.argmax(hits, axis=1), 0)

    P = tables.pid_table.shape[1]
    R = tables.ri_table.shape[1]
    pid_ok = (pid_on_fep >= 0) & (pid_on_fep < P)
    proc = tables.pid_table[job_row, jnp.clip(pid_on_fep, 0, P - 1)]
    proc_ok = pid_ok & (proc != INVALID)
    ri_ok = (ri >= 0) & (ri < R)
    ctx_rel = tables.ri_table[
        job_row * P + jnp.clip(pid_on_fep, 0, P - 1), jnp.clip(ri, 0, R - 1)]
    ok_rel = job_ok & proc_ok & ri_ok & (ctx_rel != INVALID)

    # --- absolute mode: PIDonFEP indexes the service table like a UDP port.
    # UE also supports merging PIDonFEP+RI into one table; we fold RI in by
    # using it as a low-order offset when the service entry allows it.
    S = tables.service_table.shape[0]
    svc_ok = (pid_on_fep >= 0) & (pid_on_fep < S)
    ctx_abs = tables.service_table[jnp.clip(pid_on_fep, 0, S - 1)]
    ok_abs = svc_ok & (ctx_abs != INVALID)

    is_rel = rel.astype(jnp.bool_)
    ok = jnp.where(is_rel, ok_rel, ok_abs)
    ctx = jnp.where(is_rel, ctx_rel, ctx_abs)
    return jnp.where(ok, ctx, INVALID), ok


def directory_entries(num_nodes: int, procs_per_node: int,
                      relative: bool) -> int:
    """Source-side directory size (Sec. 3.1.1 scalability argument).

    Direct addressing stores N*P entries; relative UE addressing stores N
    entries and computes the process as a PIDonFEP offset.
    """
    return num_nodes if relative else num_nodes * procs_per_node
