"""Quickstart: the UET transport in 60 seconds.

Builds the paper's Fig. 2 fabric (64 endpoints, 8-port switches), runs a
4->1 incast under RCCC and an 8-flow permutation under REPS spraying,
prints the bandwidth shares the paper predicts (Fig. 7 / Sec. 2.1), and
closes with a whole failure sweep batched into ONE compiled scan
(`simulate_batch`).

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.lb.schemes import LBScheme
from repro.network import workloads
from repro.network.fabric import SimParams, simulate, simulate_batch


def main():
    print("=== UET quickstart ===")

    print("\n[1] incast 4->1 with receiver-credit CC (RCCC, Sec 3.3.2)")
    g, wl, exp = workloads.incast(4, size=100000)
    r = simulate(g, wl, SimParams(ticks=1200, rccc=True, nscc=False))
    gp = r.goodput((300, 1200))
    print(f"    per-flow goodput: {np.round(gp, 3)} "
          f"(paper: {exp['share']:.2f} each — optimal)")

    print("\n[2] permutation traffic: static ECMP vs REPS spraying "
          "(Sec 2.1 polarization)")
    g, wl, _ = workloads.permutation(k=8, pods=4, shift=17, size=100000)
    for scheme in (LBScheme.STATIC, LBScheme.REPS):
        r = simulate(g, wl, SimParams(ticks=1500, nscc=True, lb=scheme))
        gp = r.goodput((700, 1500))
        print(f"    {scheme.name:9s}: mean {gp.mean():.3f}  "
              f"worst flow {gp.min():.3f}")

    print("\n[3] packet trimming vs timeout-only recovery (Sec 3.2.4)")
    g, wl, _ = workloads.incast(8, size=300)
    for trim in (True, False):
        p = SimParams(ticks=5000, nscc=True, trimming=trim,
                      timeout_ticks=300)
        r = simulate(g, wl, p)
        ct = r.completion_tick()
        done = "all done" if (ct >= 0).all() else "UNFINISHED"
        print(f"    trimming={str(trim):5s}: mean completion "
              f"{ct[ct >= 0].mean():7.1f} ticks ({done}, "
              f"trims={int(r.state.trims)}, drops={int(r.state.drops)})")

    print("\n[4] failure sweep, batched: healthy + one-dead-uplink x4, "
          "one vmapped scan (REPS, Sec 3.2.4)")
    g, wls, masks, exp = workloads.failure_sweep(spines=4, hosts_per_leaf=8)
    p = SimParams(ticks=3000, nscc=True, lb=LBScheme.REPS,
                  timeout_ticks=64, ooo_threshold=24)
    results = simulate_batch(g, wls, p, failed=masks)
    for i, r in enumerate(results):
        tag = "healthy   " if i == 0 else f"uplink {i - 1} dead"
        gp = r.goodput((1500, 3000)).mean()
        ref = exp["healthy_share"] if i == 0 else exp["degraded_share"]
        print(f"    {tag}: mean goodput {gp:.3f} (optimum {ref:.3f}, "
              f"drops {int(r.state.drops)})")


if __name__ == "__main__":
    main()
