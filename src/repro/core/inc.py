"""In-network collectives (INC): switch-resident reduction contexts.

The UE roadmap's next frontier (and EPIC's, arXiv 2605.18683, headline
result) is letting the *switch* reduce: when k member flows of one
reduction group converge on a parent host, the fan-in switch can
aggregate their payloads and forward ONE packet per PSN instead of k —
the parent's downlink (the incast bottleneck of every tree reduce)
carries 1/k of the traffic and completion drops accordingly.

Modeling contract (DESIGN.md has the full discussion):

* A reduction **group** is a set of flows sharing one destination host
  (the parent) and one message size, marked by ``Workload.red`` (group
  id, -1 = not reducible). The reduction context is resident at the
  parent's top-of-rack switch — the one point every member packet
  funnels through regardless of spraying, so aggregation needs no
  routing cooperation.
* Per (group, PSN) the context keeps an **accumulator slot**: the PSN it
  is aggregating and a **child-arrival bitmap** over the group's
  cross-leaf members (same-leaf members inject straight into the host
  downlink and deliver normally — they never traverse the ToR fabric
  side, so the switch cannot see them).
* Arrival of member packet (g, psn): all but the LAST expected child are
  **absorbed** — consumed at the switch, which ACKs the source on the
  control TC exactly as a delivery would (the source's PSN clears; it
  will never retransmit an absorbed packet). The child that completes
  the bitmap is **emitted**: it is forwarded into the downlink as the
  aggregate, keeping its own flow identity, so normal delivery / trim /
  NACK semantics apply to the aggregate unchanged. If the aggregate is
  trimmed, the NACK targets the emitting flow, whose source still owns
  that PSN and retransmits; the retransmit finds the bitmap full
  (``already``) and passes through untouched.
* Slots are a ring indexed by ``psn % slots``; a higher PSN reuses a
  slot by resetting it. Aggregation is *opportunistic*: any packet the
  context cannot safely account (stale PSN, duplicate child bit, slot
  owned by a newer PSN) passes through and delivers normally, so
  correctness never depends on aggregation — only the amount of
  upstream traffic saved does.

Stat lanes: the fabric counts ``inc_reduced`` (packets absorbed — each
one is a packet the parent downlink never carried) and ``inc_emits``
(aggregates forwarded). Upstream bytes saved = inc_reduced * MTU.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.pds import _popcount32

#: child-arrival bitmaps are one uint32 word: at most 32 cross-leaf
#: members per reduction group (larger groups pass through un-aggregated)
MAX_FANIN = 32


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class INCState:
    """Accumulator slots of every reduction context (SoA over groups).

    slot_psn:  [G, A] int32 — PSN the slot currently aggregates (-1 free)
    slot_bits: [G, A] uint32 — child-arrival bitmap (bit = member rank)
    """

    slot_psn: jax.Array
    slot_bits: jax.Array

    @staticmethod
    def create(groups: int, slots: int) -> "INCState":
        return INCState(
            slot_psn=jnp.full((groups, slots), -1, jnp.int32),
            slot_bits=jnp.zeros((groups, slots), jnp.uint32),
        )

    @staticmethod
    def empty() -> "INCState":
        """Zero-size placeholder carried when the profile has INC off."""
        return INCState.create(0, 1)


def member_ranks(red: jax.Array, cross_leaf: jax.Array,
                 allowed: "jax.Array | None" = None):
    """Per-flow INC membership, member rank, and effective fan-in.

    red:        [F] int32 reduction-group ids (-1 = none)
    cross_leaf: [F] bool — src and dst on different leaves (only those
                flows traverse the parent ToR and can be aggregated)
    allowed:    optional [F] bool extra gate (e.g. RUD-only)

    Returns (member [F] bool, rank [F] int32 — bit index within the
    group's child bitmap, gsz [F] int32 — the group's cross-leaf member
    count, i.e. the bitmap population that triggers emission).
    """
    member = (red >= 0) & cross_leaf
    if allowed is not None:
        member = member & allowed
    f = red.shape[0]
    idx = jnp.arange(f)
    same = (red[None, :] == red[:, None]) & member[None, :] & member[:, None]
    rank = (same & (idx[None, :] < idx[:, None])).sum(axis=1, dtype=jnp.int32)
    gsz = same.sum(axis=1, dtype=jnp.int32)
    return member, rank, gsz


def process(st: INCState, *, lane_flow: jax.Array, lane_psn: jax.Array,
            lane_cand: jax.Array, member: jax.Array, rank: jax.Array,
            gsz: jax.Array, red: jax.Array, has_delivery: jax.Array):
    """One tick of switch-resident aggregation over the forwarded lanes.

    lane_flow/lane_psn/lane_cand: [Q] — per-queue dequeued packet about
    to enter its destination host downlink (lane_cand False = not an INC
    candidate this tick). member/rank/gsz/red: [F] from `member_ranks`.
    has_delivery: [F] — flow already produced a delivery ACK this tick
    (absorption is deferred then: the engine's ACK lanes carry at most
    one ACK per flow per tick).

    Returns (state', absorb [Q] bool, emit [Q] bool). Absorbed lanes are
    removed from the enqueue set and ACKed at the switch; emitted lanes
    enqueue normally as the aggregate. Lanes with neither flag pass
    through untouched.
    """
    q = lane_flow.shape[0]
    g_count, slots = st.slot_psn.shape
    lane = jnp.arange(q)
    # groups wider than the bitmap word can never complete their child
    # bitmap — the WHOLE group passes through un-aggregated (absorbing
    # even one child of an unemittable group would destroy its data)
    m = lane_cand & member[lane_flow] & (gsz[lane_flow] <= MAX_FANIN)
    g = jnp.where(m, red[lane_flow], 0)
    slot = jnp.where(lane_psn >= 0, lane_psn, 0) % slots
    cur_psn = st.slot_psn[g, slot]
    cur_bits = st.slot_bits[g, slot]
    # a higher PSN resets (recycles) the slot; a lower one is stale
    fresh = lane_psn > cur_psn
    eff_bits = jnp.where(fresh, jnp.uint32(0), cur_bits)
    bit = jnp.uint32(1) << jnp.clip(rank[lane_flow], 0,
                                    MAX_FANIN - 1).astype(jnp.uint32)
    already = (eff_bits & bit) != 0      # retransmit of an accounted child
    usable = m & (lane_psn >= cur_psn) & ~already & ~has_delivery[lane_flow]
    # one absorption per flow per tick (preserves the <=1-ACK-per-flow
    # densification invariant); later same-flow lanes pass through
    samef = ((lane_flow[None, :] == lane_flow[:, None]) & usable[None, :]
             & (lane[None, :] < lane[:, None])).any(axis=1)
    ok = usable & ~samef
    # same (group, slot) hit by two PSNs in one tick: the higher PSN owns
    # the slot, the lower lane passes through
    key = jnp.where(ok, g * slots + slot, -1)
    beaten = ((key[None, :] == key[:, None]) & ok[None, :]
              & (lane_psn[None, :] > lane_psn[:, None])).any(axis=1)
    ok = ok & ~beaten
    key = jnp.where(ok, g * slots + slot, -1)
    # in-tick arrival order among lanes feeding the same slot: the lane
    # that completes the bitmap is the emitter, earlier ones absorb
    r_tick = ((key[None, :] == key[:, None]) & ok[None, :]
              & (lane[None, :] < lane[:, None])).sum(axis=1, dtype=jnp.int32)
    total = _popcount32(eff_bits).astype(jnp.int32) + r_tick + 1
    full = total >= gsz[lane_flow]
    emit = ok & full
    absorb = ok & ~full
    # state scatters (OOB group index => dropped lane)
    gi = jnp.where(ok, g, g_count)
    zi = jnp.where(ok & fresh, g, g_count)
    slot_bits = st.slot_bits.at[zi, slot].set(jnp.uint32(0), mode="drop")
    slot_bits = slot_bits.at[gi, slot].add(
        jnp.where(ok, bit, jnp.uint32(0)), mode="drop")
    slot_psn = st.slot_psn.at[gi, slot].max(lane_psn, mode="drop")
    return INCState(slot_psn=slot_psn, slot_bits=slot_bits), absorb, emit
