#!/usr/bin/env bash
# Fast smoke gate: tier-1 tests minus the slow-marked heavies, plus the
# header-stack paper bench as an import/consistency canary.
#
#   ./scripts/check.sh            # ~40s on a laptop CPU
#
# The full tier-1 gate (everything, including slow) stays
#   PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests (minus slow) =="
python -m pytest -x -q -m "not slow"

echo "== paper bench smoke: header stacks =="
python -m benchmarks.run --only headers

echo "OK"
