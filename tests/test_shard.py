"""Device-sharded scenario engine (repro.network.shard) + driver fast
path: bitwise parity contracts.

Contracts locked here (see DESIGN.md "Sharded scenario axis"):

* shard-vs-unshard bitwise parity — completion ticks, horizons, dense
  lanes, and the full final state — for uniform and per-scenario
  profile batches, ragged (non-divisible) scenario counts, per-scenario
  failure masks + seeds, and both trace tiers;
* padding lanes are inert: a padded sharded run returns exactly B
  results, none of them a padding artifact;
* the driver fast path (`lax.cond` between the select-free and masked
  chunk bodies) is bitwise invisible: budgets that are not a chunk
  multiple, and batches where one lane freezes while others run (the
  masked residual path), still match the goldens / serial runs;
* sharded executables are cached per device set, and the unsharded
  cache key is unchanged.

conftest.py forces 4 virtual CPU devices for the session; the tests
skip (not fail) if the user's own XLA_FLAGS leaves fewer than 2.
"""
import os

import jax
import numpy as np
import pytest

from repro.core.lb.schemes import LBScheme
from repro.network import workloads
from repro.network.fabric import (SimParams, Workload, _cache_key, simulate,
                                  simulate_batch)
from repro.network.profile import TransportProfile
from repro.network.shard import resolve_devices
from repro.network.topology import leaf_spine

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "fabric_golden.npz")

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=4; set by tests/conftest.py unless overridden)")


def _state_equal(a, b) -> bool:
    return all(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b)))


def _mixed_batch(b=6):
    """Ragged-by-design sweep: heterogeneous sizes (staggered horizons,
    so lanes freeze at different boundaries), per-scenario seeds, one
    failure mask."""
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=4)
    sizes = [40, 90, 140, 5000, 60, 220][:b]
    wls = Workload.stack(
        [Workload.of([0, 1, 2], [4, 5, 6], s) for s in sizes])
    masks = np.zeros((b, g.num_queues), bool)
    masks[2, int(g.up1_table[0, 0])] = True
    seeds = np.arange(b, dtype=np.uint32) + 0x5EED
    return g, wls, masks, seeds


# ------------------------------------------------------------------------
# padding helpers
# ------------------------------------------------------------------------

def test_pad_scenarios_shapes_and_inertness():
    _, wls, _, _ = _mixed_batch()
    padded, pad = workloads.pad_scenarios(wls, 4)
    assert pad == 2 and padded.src.shape == (8, 3)
    np.testing.assert_array_equal(np.asarray(padded.src[:6]),
                                  np.asarray(wls.src))
    assert (np.asarray(padded.size[6:]) == 0).all()
    assert (np.asarray(padded.dep[6:]) == -1).all()
    aligned, pad0 = workloads.pad_scenarios(wls, 3)
    assert pad0 == 0 and aligned is wls


def test_noop_scenarios_quiesce_at_first_chunk():
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    wls = workloads.noop_scenarios(f=2, b=2)
    rs = simulate_batch(g, wls, TransportProfile.ai_full(),
                        SimParams(ticks=2000))
    for r in rs:
        assert r.horizon == SimParams().chunk_ticks  # first boundary
        assert int(np.asarray(r.state.delivered).sum()) == 0


# ------------------------------------------------------------------------
# shard-vs-unshard bitwise parity
# ------------------------------------------------------------------------

@multi_device
def test_sharded_ragged_stats_parity():
    """B=6 on 4 devices (ragged), failure masks + seeds, stats tier."""
    g, wls, masks, seeds = _mixed_batch()
    p = SimParams(ticks=700)
    prof = TransportProfile.ai_full(lb=LBScheme.REPS)
    win = (100, 700)
    base = simulate_batch(g, wls, prof, p, failed=masks, seeds=seeds,
                          goodput_window=win)
    shd = simulate_batch(g, wls, prof, p, failed=masks, seeds=seeds,
                         goodput_window=win, shard=True)
    assert len(shd) == len(base) == 6
    for i, (a, b) in enumerate(zip(base, shd)):
        assert a.horizon == b.horizon, f"scenario {i}"
        np.testing.assert_array_equal(a.completion_ticks(),
                                      b.completion_ticks(),
                                      err_msg=f"scenario {i}")
        np.testing.assert_array_equal(a.source_completion_ticks(),
                                      b.source_completion_ticks(),
                                      err_msg=f"scenario {i}")
        np.testing.assert_array_equal(a.goodput(win), b.goodput(win),
                                      err_msg=f"scenario {i}")
        assert _state_equal(a.state, b.state), f"scenario {i} state"


@multi_device
def test_sharded_full_trace_parity():
    """trace="full": the dense per-tick lanes gathered from the sharded
    chunk loop match the unsharded ones bitwise, per lane horizon."""
    g, wls, masks, seeds = _mixed_batch()
    p = SimParams(ticks=500)
    prof = TransportProfile.ai_full()
    base = simulate_batch(g, wls, prof, p, failed=masks, seeds=seeds,
                          trace="full")
    shd = simulate_batch(g, wls, prof, p, failed=masks, seeds=seeds,
                         trace="full", shard=True)
    for i, (a, b) in enumerate(zip(base, shd)):
        assert a.horizon == b.horizon, f"scenario {i}"
        np.testing.assert_array_equal(a.delivered_per_tick,
                                      b.delivered_per_tick,
                                      err_msg=f"scenario {i}")
        np.testing.assert_array_equal(a.cwnd_per_tick, b.cwnd_per_tick,
                                      err_msg=f"scenario {i}")
        np.testing.assert_array_equal(a.qlen_max, b.qlen_max,
                                      err_msg=f"scenario {i}")
        np.testing.assert_array_equal(a.rx_base_per_tick,
                                      b.rx_base_per_tick,
                                      err_msg=f"scenario {i}")
        assert _state_equal(a.state, b.state), f"scenario {i} state"


@multi_device
def test_sharded_serial_cross_parity():
    """Sharded lanes equal the SERIAL engine too — the transitive
    contract (serial == batched == sharded)."""
    g, wls, masks, seeds = _mixed_batch(b=3)
    p = SimParams(ticks=600)
    prof = TransportProfile.ai_full()
    shd = simulate_batch(g, wls, prof, p, failed=masks[:3], seeds=seeds,
                         devices=2)
    for i, r in enumerate(shd):
        solo = simulate(g, jax.tree_util.tree_map(lambda a: a[i], wls),
                        prof, p, failed=np.asarray(masks[i]),
                        seed=int(seeds[i]))
        assert solo.horizon == r.horizon, f"scenario {i}"
        np.testing.assert_array_equal(solo.completion_ticks(),
                                      r.completion_ticks(),
                                      err_msg=f"scenario {i}")
        assert _state_equal(solo.state, r.state), f"scenario {i}"


@multi_device
def test_sharded_per_profile_groups_parity():
    """Per-scenario profiles: groups shard independently, results are
    reassembled in scenario order, bitwise == unsharded grouped run."""
    g, wls, masks, seeds = _mixed_batch()
    p = SimParams(ticks=500)
    profs = [TransportProfile.ai_full(), TransportProfile.ai_base(),
             TransportProfile.hpc()] * 2
    base = simulate_batch(g, wls, profs, p, failed=masks, seeds=seeds)
    shd = simulate_batch(g, wls, profs, p, failed=masks, seeds=seeds,
                         shard=True)
    for i, (a, b) in enumerate(zip(base, shd)):
        assert a.horizon == b.horizon, f"scenario {i}"
        np.testing.assert_array_equal(a.completion_ticks(),
                                      b.completion_ticks(),
                                      err_msg=f"scenario {i}")
        assert _state_equal(a.state, b.state), f"scenario {i} state"


@multi_device
def test_sharded_fault_schedule_parity():
    """Per-scenario FaultSchedules (flap / gray / permanent + recovery
    knobs) ride the sharded scenario axis bitwise; the padding lanes the
    ragged B=5 run adds are healthy and inert."""
    from repro.network.faults import FaultSchedule

    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=4)
    wls = Workload.stack(
        [Workload.of([0, 1, 2, 3], [4, 5, 6, 7], s)
         for s in (150, 90, 150, 150, 60)])
    ups = [int(g.up1_table[0, i]) for i in range(2)]
    scheds = FaultSchedule.stack([
        FaultSchedule.healthy(g.num_queues),
        FaultSchedule.healthy(g.num_queues).flap(ups, 120, 420),
        FaultSchedule.healthy(g.num_queues).lossy(ups, 0.05).with_seed(3),
        FaultSchedule.healthy(g.num_queues).flap(ups[0], 120),  # permanent
        FaultSchedule.healthy(g.num_queues).lossy(ups[1], 0.2),
    ])
    from dataclasses import replace as _rep
    prof = _rep(TransportProfile.ai_full(lb=LBScheme.REPS),
                ev_eviction=True, rto_backoff=2.0, name="sweep")
    p = SimParams(ticks=3000, timeout_ticks=64, ooo_threshold=24)
    base = simulate_batch(g, wls, prof, p, faults=scheds)
    shd = simulate_batch(g, wls, prof, p, faults=scheds, shard=True)
    assert len(shd) == len(base) == 5
    assert shd[1].ticks_degraded == 300
    assert shd[3].ev_evictions > 0
    for i, (a, b) in enumerate(zip(base, shd)):
        assert a.horizon == b.horizon, f"scenario {i}"
        np.testing.assert_array_equal(a.completion_ticks(),
                                      b.completion_ticks(),
                                      err_msg=f"scenario {i}")
        assert _state_equal(a.state, b.state), f"scenario {i} state"


@multi_device
@pytest.mark.slow
def test_sharded_wide_sweep_parity_four_devices():
    """The multi-device sweep: a 16-scenario heterogeneous-horizon batch
    across every visible device, non-chunk-multiple budget (fast +
    masked chunks both on the device path)."""
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=8)
    f = 8
    sizes = np.geomspace(40, 900, 16).astype(int)
    wls = Workload.stack(
        [Workload.of(list(range(f)), [f + i for i in range(f)], int(s))
         for s in sizes])
    seeds = np.arange(16, dtype=np.uint32)
    p = SimParams(ticks=2500, timeout_ticks=64)
    prof = TransportProfile.ai_full(lb=LBScheme.REPS)
    base = simulate_batch(g, wls, prof, p, seeds=seeds, max_ticks=2500 - 37)
    shd = simulate_batch(g, wls, prof, p, seeds=seeds, max_ticks=2500 - 37,
                         shard=True)
    assert len({r.horizon for r in base}) > 1, "sweep must be heterogeneous"
    for i, (a, b) in enumerate(zip(base, shd)):
        assert a.horizon == b.horizon, f"scenario {i}"
        np.testing.assert_array_equal(a.completion_ticks(),
                                      b.completion_ticks(),
                                      err_msg=f"scenario {i}")
        assert _state_equal(a.state, b.state), f"scenario {i} state"


# ------------------------------------------------------------------------
# device resolution + caching
# ------------------------------------------------------------------------

def test_resolve_devices_forms():
    assert resolve_devices(None, False) is None
    assert resolve_devices(0, False) is None          # 0/1: unsharded
    assert resolve_devices(1, False) is None
    with pytest.raises(ValueError, match="requested"):
        resolve_devices(10**6, False)
    if len(jax.devices()) >= 2:
        devs = resolve_devices(2, False)
        assert devs == tuple(jax.devices()[:2])
        assert resolve_devices(True, False) == tuple(jax.devices())
        assert resolve_devices(list(jax.devices()), False) \
            == tuple(jax.devices())


def test_cache_key_separates_shard_from_unsharded():
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    prof = TransportProfile.ai_full()
    p = SimParams()
    assert (_cache_key(g, prof, p, 2, True, "stats")
            != _cache_key(g, prof, p, 2, True, "stats", shard=(0, 1)))
    assert (_cache_key(g, prof, p, 2, True, "stats", shard=(0, 1))
            != _cache_key(g, prof, p, 2, True, "stats", shard=(0, 1, 2)))
    # and the budget stays traced on the sharded key too
    assert (_cache_key(g, prof, SimParams(ticks=1), 2, True, "stats",
                       shard=(0, 1))
            == _cache_key(g, prof, SimParams(ticks=9), 2, True, "stats",
                          shard=(0, 1)))


@multi_device
def test_sharded_runs_share_one_executable_across_horizons():
    from repro.network.fabric import _RUN_CACHE
    g, wls, masks, seeds = _mixed_batch()
    p = SimParams(ticks=300)
    prof = TransportProfile.ai_base()
    simulate_batch(g, wls, prof, p, shard=True)
    n0 = len(_RUN_CACHE)
    simulate_batch(g, wls, prof, p, max_ticks=550, shard=True)
    assert len(_RUN_CACHE) == n0, "a new horizon recompiled the sharded run"


# ------------------------------------------------------------------------
# driver fast path: the cond must be bitwise invisible
# ------------------------------------------------------------------------

def test_fastpath_non_chunk_multiple_budget_matches_golden_prefix():
    """Budget 300 with chunk 128: two fast chunks + one masked remainder
    must still be a bitwise prefix of the fixed-horizon golden."""
    gold = np.load(GOLDEN)
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2], [4, 5, 6], 200)
    r = simulate(g, wl, TransportProfile.ai_full(), SimParams(ticks=300),
                 trace="full")
    np.testing.assert_array_equal(r.delivered_per_tick,
                                  gold["a_delivered"][:r.horizon])
    np.testing.assert_array_equal(np.asarray(r.state.delivered),
                                  gold["a_state_delivered"])


def test_fastpath_chunk_alignment_is_bitwise_invisible():
    """A budget hit exactly at a chunk boundary (all-fast chunks) equals
    the same budget reached with a masked remainder (chunk misaligned):
    the cond branches must be bitwise interchangeable."""
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2], [4, 5, 6], 40)
    prof = TransportProfile.ai_full()
    budget = 384
    aligned = simulate(g, wl, prof, SimParams(ticks=budget, chunk_ticks=128),
                       trace="full")          # 3 fast chunks
    residual = simulate(g, wl, prof, SimParams(ticks=budget, chunk_ticks=80),
                        trace="full")         # 4 fast + masked remainder
    h = min(aligned.horizon, residual.horizon)
    np.testing.assert_array_equal(aligned.delivered_per_tick[:h],
                                  residual.delivered_per_tick[:h])
    np.testing.assert_array_equal(aligned.completion_ticks(),
                                  residual.completion_ticks())


def test_fastpath_frozen_lane_forces_masked_chunks_bitwise():
    """Once one batch lane freezes (quiescent) while another runs, every
    later chunk takes the masked body: the frozen lane must stay frozen
    and the live lane must match its serial run bitwise."""
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    small = Workload.of([0, 1], [2, 3], 30)       # freezes after chunk 1
    big = Workload.of([0, 1], [2, 3], 700)        # runs many chunks more
    p = SimParams(ticks=2000)
    prof = TransportProfile.ai_full()
    r_small, r_big = simulate_batch(g, Workload.stack([small, big]), prof, p)
    assert r_small.horizon < r_big.horizon
    for wl, r in ((small, r_small), (big, r_big)):
        solo = simulate(g, wl, prof, p)
        assert solo.horizon == r.horizon
        np.testing.assert_array_equal(solo.completion_ticks(),
                                      r.completion_ticks())
        assert _state_equal(solo.state, r.state)


def test_event_slot_type_only_clear_keeps_stats_equal_full():
    """The event-slot consume clears only the EVF_TYPE lane; stale
    payload lanes must stay invisible — both trace tiers and the final
    state (including ev_buf) agree on a congested REPS run."""
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=8)
    wl = Workload.of(list(range(8)), [8 + i for i in range(8)], 250)
    prof = TransportProfile.ai_full(lb=LBScheme.REPS)
    p = SimParams(ticks=640, timeout_ticks=64, ooo_threshold=24)
    rf = simulate(g, wl, prof, p, trace="full")
    rs = simulate(g, wl, prof, p, trace="stats")
    np.testing.assert_array_equal(rs.completion_ticks(),
                                  rf.completion_ticks())
    assert _state_equal(rs.state, rf.state)
    assert int(rf.state.trims) > 0, "run must exercise the NACK lanes"
