"""Device-sharded scenario engine: ``simulate_batch(..., shard=True)``.

Shards the scenario axis of a batched run across devices with
``shard_map``: every device runs the SAME hand-batched chunked
while-scan driver (``fabric._build_fns``) on its contiguous slice of
the scenario axis, entirely communication-free. Consequences:

* per-lane trajectories are bitwise identical to the unsharded path
  (and therefore to serial ``simulate`` — lanes never interact);
* each device's while loop exits at ITS lanes' quiescence boundary,
  not the global batch's. The unsharded engine pays the max-lane
  horizon for every lane (frozen lanes still ride the scan), so on a
  heterogeneous sweep sorted by expected horizon, sharding is a
  work-efficiency win on top of the device parallelism;
* ragged scenario counts are padded to a device multiple with inert
  no-op lanes (``workloads.pad_scenarios``) that quiesce at the first
  chunk boundary; the padding is dropped from the gathered results;
* per-profile executable groups compose: ``simulate_batch`` groups by
  profile first, then shards within each group.

Sharded executables live in the same compile cache as the unsharded
ones, keyed additionally on the device-id tuple. The carry is donated
per device shard, and budgets stay traced bounds.

CPU testing: export ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
*before the first jax import* to split the host into N virtual devices
(`scripts/check.sh` runs the 4-device smoke this way; `python -m
repro.network.shard` is that smoke).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.network import fabric

_AXIS = "scenarios"


def resolve_devices(devices, shard: bool):
    """Normalize the ``simulate_batch`` (devices=, shard=) pair to a
    device tuple, or None for the unsharded path (0 or 1 device)."""
    if isinstance(devices, bool):       # devices=True sugar for shard=True
        devices, shard = None, devices or shard
    if devices is None:
        if not shard:
            return None
        devs = tuple(jax.devices())
    elif isinstance(devices, int):
        if devices <= 1:            # 0/1 = sharding disabled
            return None
        avail = jax.devices()
        if devices > len(avail):
            raise ValueError(
                f"devices={devices} requested but {len(avail)} present "
                f"(CPU: set XLA_FLAGS=--xla_force_host_platform_"
                f"device_count=N before the first jax import)")
        devs = tuple(avail[:devices])
    else:
        devs = tuple(devices)
    return devs if len(devs) > 1 else None


def _sharded_fns(g, profile, p, F: int, trace: str, devs: tuple,
                 lossy: bool = False, tel=None, hosty: bool = False,
                 corrupty: bool = False, link=None):
    """Jitted + cached (init, run) pair whose scenario axis is sharded
    over `devs`. Same driver as the unsharded batched engine, wrapped in
    shard_map before jit; cached beside it under the device-id tuple.
    Telemetry lanes (``tel``: a TelemetrySpec) ride inside the stats
    carry, so the partition specs are untouched — every probe-ring leaf
    is sharded on its leading scenario axis like the other stat lanes."""
    key = fabric._cache_key(g, profile, p, F, True, trace,
                            shard=tuple(d.id for d in devs), lossy=lossy,
                            tel=tel, hosty=hosty, corrupty=corrupty,
                            link=link)
    fns = fabric._RUN_CACHE.get(key)
    if fns is None:
        init_fn, run = fabric._build_fns(g, profile, p, F, batched=True,
                                         trace=trace, lossy=lossy, tel=tel,
                                         hosty=hosty, corrupty=corrupty,
                                         link=link)
        mesh = Mesh(np.array(devs), (_AXIS,))
        sc, rep = P(_AXIS), P()
        if trace == "stats":
            # (s0, wl, fault, budget, w0, w1) -> (state, stats, horizon)
            in_specs = (sc, sc, sc, rep, rep, rep)
            out_specs = (sc, sc, sc)
        else:
            # (s0, stopped, tick0, wl, fault, budget)
            #   -> (state, stopped, time-major out lanes [T, B, ...])
            in_specs = (sc, sc, rep, sc, sc, rep)
            out_specs = (sc, sc, P(None, _AXIS))
        run_sh = shard_map(run, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False)
        init_sh = shard_map(init_fn, mesh=mesh, in_specs=(sc, sc),
                            out_specs=sc, check_rep=False)
        fns = (jax.jit(init_sh), jax.jit(run_sh, donate_argnums=(0,)))
        fabric._RUN_CACHE[key] = fns
    return fns


def run_sharded(g, wls, profile, p, fault, seeds, trace: str, budget: int,
                goodput_window, devs: tuple, tel=None,
                link=None) -> "list[fabric.SimResult]":
    """One profile group's batch, sharded over `devs`. Called by
    ``fabric._run_batch`` — same inputs/outputs, bitwise-identical
    per-scenario results. ``fault`` is a [B, Q]-leaved FaultSchedule;
    padding lanes get all-healthy schedules (inert, like their no-op
    workloads)."""
    from repro.network.faults import FaultSchedule
    from repro.network.workloads import pad_scenarios

    n = len(devs)
    B, F = wls.src.shape
    profile.delivery_modes(F)
    lossy = bool(np.asarray(fault.loss_p).any())
    hosty = fault.has_host_faults
    corrupty = fault.has_corruption
    wls_p, pad = pad_scenarios(wls, n)
    if pad:
        # padding lanes get all-healthy schedules at the batch's own
        # host-lane width (zero-width when no endpoint faults ride)
        fault = jax.tree_util.tree_map(
            lambda a, e: jnp.concatenate([a, e.astype(a.dtype)]),
            fault, FaultSchedule.healthy(g.num_queues, batch=pad,
                                         num_hosts=fault.num_hosts))
        seeds = jnp.concatenate(
            [seeds, jnp.full((pad,), fabric.DEFAULT_SEED, jnp.uint32)])
    init, run = _sharded_fns(g, profile, p, F, trace, devs, lossy, tel=tel,
                             hosty=hosty, corrupty=corrupty, link=link)
    s0 = init(wls_p, seeds)
    sizes = np.asarray(wls.size)
    if trace == "stats":
        w0, w1 = fabric._window_bounds(goodput_window, budget)
        final, st, horizon = run(s0, wls_p, fault, jnp.int32(budget),
                                 jnp.int32(w0), jnp.int32(w1))
        final = jax.device_get(final)
        st = jax.device_get(st)
        horizon = np.asarray(horizon)
        return fabric._split_stats_results(final, st, sizes, horizon,
                                           budget, goodput_window, B,
                                           tel=tel)
    final, outs, horizon = fabric._run_full_host(
        run, s0, wls_p, fault, budget, p.chunk_ticks, batch=B + pad)
    final = jax.device_get(final)
    return fabric._split_full_results(final, outs, sizes, horizon, budget, B)


def _smoke() -> int:  # pragma: no cover — CLI smoke for scripts/check.sh
    """Ragged sharded batch vs the unsharded engine: bitwise parity of
    completion ticks, horizons, and the full final state."""
    from repro.network.profile import TransportProfile
    from repro.network.topology import leaf_spine

    ndev = len(jax.devices())
    if ndev < 2:
        print("shard smoke: only 1 device visible — set XLA_FLAGS="
              "--xla_force_host_platform_device_count=4; skipping")
        return 0
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=4)
    sizes = [40, 80, 120, 160, 200, 240]      # B=6: ragged on 4 devices
    wls = fabric.Workload.stack(
        [fabric.Workload.of([0, 1, 2, 3], [4, 5, 6, 7], s) for s in sizes])
    p = fabric.SimParams(ticks=2000)
    prof = TransportProfile.ai_full()
    base = fabric.simulate_batch(g, wls, prof, p)
    shd = fabric.simulate_batch(g, wls, prof, p, shard=True)
    for i, (rb, rs) in enumerate(zip(base, shd)):
        assert rb.horizon == rs.horizon, (i, rb.horizon, rs.horizon)
        np.testing.assert_array_equal(rb.completion_ticks(),
                                      rs.completion_ticks(),
                                      err_msg=f"scenario {i}")
        eq = jax.tree_util.tree_map(
            lambda a, b: bool(np.array_equal(np.asarray(a), np.asarray(b))),
            rb.state, rs.state)
        assert all(jax.tree_util.tree_leaves(eq)), f"scenario {i} state"
    print(f"shard smoke ok: {ndev} devices, B={len(sizes)} (ragged), "
          f"bitwise parity with the unsharded engine")
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(_smoke())
