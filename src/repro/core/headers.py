"""UE packet header / overhead byte model (Sec. 3.2.2, Fig. 3).

The paper gives exact byte counts for every sublayer header. We reproduce
them as an accounting model: given a transport configuration, compute the
per-packet overhead and the wire efficiency (goodput fraction) for a given
MTU. These numbers feed the fabric simulator (packets are an MTU of payload
plus `header_bytes` of overhead) and `benchmarks/bench_headers.py`.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.types import TransportMode

# -- fixed Ethernet framing --------------------------------------------------
ETHERNET_HEADER = 14      # standard Ethernet header
ETHERNET_FCS = 4          # frame check sequence
# Physical-layer per-frame cost (preamble+SFD 8B, IPG 12B). Not a "header"
# in Fig. 3 but real wire occupancy; kept separate so tests can check both.
ETHERNET_PHY_OVERHEAD = 20

# -- L3/L4 encapsulation -----------------------------------------------------
IPV4_HEADER = 20
IPV6_HEADER = 40
UDP_HEADER = 8            # UET runs over UDP (dst port 4793) ...
IP_ENTROPY_HEADER = 4     # ... or natively over IP with a 4B entropy header

# -- PDS (Sec. 3.2.2): 12B for RUD/ROD (16 with RCCC), 8B RUDI, 4B UUD -------
PDS_HEADER = {
    TransportMode.RUD: 12,
    TransportMode.ROD: 12,
    TransportMode.RUDI: 8,
    TransportMode.UUD: 4,
}
PDS_RCCC_EXTRA = 4        # RCCC credit fields grow the RUD/ROD PDS header to 16B

# -- SES (Sec. 3.2.2) ---------------------------------------------------------
SES_HEADER_STD = 44       # standard operations
SES_HEADER_MATCH_8K = 32  # matching messages up to 8 KiB
SES_HEADER_MIN = 20       # minimal, non-matching

# -- integrity / security ------------------------------------------------------
E2E_CRC = 4               # optional trailing end-to-end CRC (before FCS)
TSS_HEADER = 12           # security header before the PDS header
TSS_HEADER_EXPLICIT_SRC = 16  # with explicit source identifiers
TSS_ICV = 16              # integrity check value at the end (before FCS)


@dataclass(frozen=True)
class HeaderConfig:
    """One concrete header stack choice."""

    mode: TransportMode = TransportMode.RUD
    ipv6: bool = False
    native_ip: bool = False       # native IP mode: 4B EV header replaces UDP
    rccc: bool = False            # RCCC congestion-control PDS fields
    ses: int = SES_HEADER_STD     # which SES header variant
    e2e_crc: bool = False
    tss: bool = False
    tss_explicit_src: bool = False

    def overhead_bytes(self) -> int:
        """Total non-payload bytes per packet (excluding PHY idle/preamble)."""
        total = ETHERNET_HEADER + ETHERNET_FCS
        total += IPV6_HEADER if self.ipv6 else IPV4_HEADER
        total += IP_ENTROPY_HEADER if self.native_ip else UDP_HEADER
        pds = PDS_HEADER[self.mode]
        if self.rccc and self.mode in (TransportMode.RUD, TransportMode.ROD):
            pds += PDS_RCCC_EXTRA
        total += pds
        total += self.ses
        if self.tss:
            total += (TSS_HEADER_EXPLICIT_SRC if self.tss_explicit_src
                      else TSS_HEADER) + TSS_ICV
            # The ICV is far stronger than the PDS CRC, which can be omitted
            # when an ICV is used (Sec. 3.2.2); e2e_crc is ignored under TSS.
        elif self.e2e_crc:
            total += E2E_CRC
        return total

    def wire_bytes(self, payload: int) -> int:
        """Bytes occupying the wire for `payload` bytes of user data."""
        return payload + self.overhead_bytes() + ETHERNET_PHY_OVERHEAD

    def efficiency(self, payload: int) -> float:
        """Goodput fraction at a given per-packet payload size."""
        return payload / self.wire_bytes(payload)


def max_efficiency_table(mtu: int = 4096) -> dict[str, float]:
    """Wire efficiency for the common stacks at full-MTU payload.

    Used by bench_headers to reproduce the Fig. 3 overhead discussion.
    """
    stacks = {
        "rud_udp_ipv4_std": HeaderConfig(),
        "rud_udp_ipv4_match": HeaderConfig(ses=SES_HEADER_MATCH_8K),
        "rud_native_ip_min": HeaderConfig(native_ip=True, ses=SES_HEADER_MIN),
        "rud_rccc_udp_ipv4": HeaderConfig(rccc=True),
        "rud_tss_udp_ipv6": HeaderConfig(ipv6=True, tss=True),
        "uud_udp_ipv4_min": HeaderConfig(mode=TransportMode.UUD, ses=SES_HEADER_MIN),
        "rudi_udp_ipv4_min": HeaderConfig(mode=TransportMode.RUDI, ses=SES_HEADER_MIN),
    }
    return {name: cfg.efficiency(mtu) for name, cfg in stacks.items()}
