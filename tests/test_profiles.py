"""TransportProfile semantics: golden parity against the pre-refactor
engine, CC-policy ablation divergence, per-flow delivery modes (ROD
in-order invariant), the legacy compat shim, and SimResult contracts.

The golden lanes in tests/golden/fabric_golden.npz were produced by the
PRE-refactor engine (inline NSCC wiring, SimParams-only API) on two
configs; ``TransportProfile.ai_full()`` on the new policy-composed engine
must reproduce them bitwise.
"""
import os
from dataclasses import replace

import numpy as np
import pytest

from repro.core.lb.schemes import LBScheme
from repro.network import workloads
from repro.network.fabric import SimParams, Workload, simulate, simulate_batch
from repro.network.profile import (CCAlgo, DeliveryMode, TransportProfile,
                                   cc_ablation)
from repro.network.topology import leaf_spine

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "fabric_golden.npz")


def _golden():
    return np.load(GOLDEN)


def _config_a():
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=4)
    wl = Workload.of([0, 1, 2], [4, 5, 6], 200)
    return g, wl, SimParams(ticks=300)


# ------------------------------------------------------------------------
# golden parity: ai_full == the pre-refactor default path, bitwise
# ------------------------------------------------------------------------

def test_ai_full_matches_pre_refactor_golden_lanes():
    """The chunked driver exits at the first quiescent chunk boundary;
    its trace must be a bitwise PREFIX of the fixed-horizon golden
    lanes, with the golden tail provably inert (no deliveries), and the
    frozen final state must match the golden final state."""
    gold = _golden()
    g, wl, p = _config_a()
    r = simulate(g, wl, TransportProfile.ai_full(), p, trace="full")
    h = r.horizon
    assert h <= 300 and h % p.chunk_ticks == 0
    np.testing.assert_array_equal(r.delivered_per_tick,
                                  gold["a_delivered"][:h])
    assert (gold["a_delivered"][h:] == 0).all()
    np.testing.assert_array_equal(r.cwnd_per_tick, gold["a_cwnd"][:h])
    np.testing.assert_array_equal(r.qlen_max, gold["a_qlen"][:h])
    np.testing.assert_array_equal(np.asarray(r.state.delivered),
                                  gold["a_state_delivered"])
    np.testing.assert_array_equal(np.asarray(r.state.next_psn),
                                  gold["a_state_next_psn"])
    np.testing.assert_array_equal(np.asarray(r.state.src_track.base),
                                  gold["a_state_src_base"])


def test_ai_full_reps_failure_matches_golden_batched():
    """Config B (REPS + dead uplink + non-default seed) through
    simulate_batch — acceptance: batched ai_full lanes are bitwise equal
    to the pre-refactor engine's serial run."""
    gold = _golden()
    g = leaf_spine(leaves=2, spines=4, hosts_per_leaf=8)
    wl = Workload.of(list(range(8)), [8 + i for i in range(8)], 700)
    q = int(gold["b_failed_queue"][0])
    p = SimParams(ticks=400, timeout_ticks=64, ooo_threshold=24)
    prof = TransportProfile.ai_full(lb=LBScheme.REPS)
    mask = np.zeros((1, g.num_queues), bool)
    mask[0, q] = True
    rb = simulate_batch(g, Workload.stack([wl]), prof, p, failed=mask,
                        seeds=np.asarray([0x5EED + 3], np.uint32),
                        trace="full")[0]
    # this config never completes within the budget: the chunked driver
    # must run the FULL horizon and still match the goldens bitwise
    assert rb.horizon == 400
    np.testing.assert_array_equal(rb.delivered_per_tick, gold["b_delivered"])
    np.testing.assert_array_equal(rb.cwnd_per_tick, gold["b_cwnd"])
    np.testing.assert_array_equal(rb.qlen_max, gold["b_qlen"])
    np.testing.assert_array_equal(np.asarray(rb.state.delivered),
                                  gold["b_state_delivered"])
    np.testing.assert_array_equal(np.asarray(rb.state.src_track.base),
                                  gold["b_state_src_base"])


# ------------------------------------------------------------------------
# CC ablation: the policies must actually behave differently
# ------------------------------------------------------------------------

@pytest.mark.slow
def test_nscc_vs_rccc_diverge_under_congested_incast():
    """NSCC (sender watches ECN/RTT) and RCCC (receiver splits its line
    rate) are different control loops: under a 4->1 incast their window
    trajectories and delivery patterns must diverge, while both keep the
    aggregate near the receiver line rate."""
    g, wl, exp = workloads.incast(4, size=100000)
    p = SimParams(ticks=1200)
    rs = {q.name: simulate(g, wl, q, p, trace="full")
          for q in cc_ablation()}  # nscc_only / rccc_only / hybrid
    nscc, rccc = rs["nscc_only"], rs["rccc_only"]
    assert not np.array_equal(nscc.delivered_per_tick,
                              rccc.delivered_per_tick)
    # reported window lanes: NSCC's moves, RCCC's is the static cap
    assert nscc.cwnd_per_tick.std() > 0
    assert rccc.cwnd_per_tick.std() == 0
    # RCCC incast sharing is exact (Fig. 7 group 4); NSCC is close but
    # statistical — both serve the incast near line rate
    for r in (nscc, rccc, rs["hybrid"]):
        gp = r.goodput((300, 1200))
        assert abs(float(gp.sum()) - 1.0) < 0.1
    np.testing.assert_allclose(rccc.goodput((300, 1200)), exp["share"],
                               atol=0.02)
    # the hybrid obeys BOTH loops: it cannot out-deliver either alone
    total = lambda r: int(r.state.delivered.sum())
    assert total(rs["hybrid"]) <= min(total(nscc), total(rccc)) + 50


# ------------------------------------------------------------------------
# delivery modes
# ------------------------------------------------------------------------

def test_rod_in_order_delivery_invariant():
    """ROD flows deliver strictly in order: at EVERY tick the cumulative
    delivered count equals the receiver's CACK advance (no packet is
    accepted past a gap), even under congestion-induced trimming."""
    g, wl, _ = workloads.incast(2, size=300)
    prof = TransportProfile(cc=CCAlgo.NSCC, delivery=DeliveryMode.ROD,
                            name="rod_test")
    r = simulate(g, wl, prof, SimParams(ticks=2500), trace="full")
    cum = r.delivered_per_tick.cumsum(axis=0)
    assert (cum[-1] == np.asarray(wl.size)).all(), "ROD must complete"
    np.testing.assert_array_equal(cum.astype(np.uint32),
                                  r.rx_base_per_tick)
    assert int(r.state.trims) > 0, "scenario must actually be congested"


def test_mixed_per_flow_delivery_modes():
    """One profile, different modes per flow: the ROD lane keeps the
    in-order invariant while RUD lanes spray and may complete OOO."""
    g, wl, p = _config_a()
    prof = TransportProfile(
        cc=CCAlgo.NSCC, lb=LBScheme.REPS,
        delivery=(DeliveryMode.RUD, DeliveryMode.ROD, DeliveryMode.RUDI),
        name="mixed")
    r = simulate(g, wl, prof, replace(p, ticks=800), trace="full")
    cum = r.delivered_per_tick.cumsum(axis=0)
    assert (cum[-1] == 200).all()
    np.testing.assert_array_equal(cum[:, 1].astype(np.uint32),
                                  r.rx_base_per_tick[:, 1])


def test_delivery_tuple_length_validated():
    g, wl, p = _config_a()
    prof = TransportProfile(delivery=(DeliveryMode.RUD, DeliveryMode.ROD))
    with pytest.raises(ValueError, match="per-flow delivery"):
        simulate(g, wl, prof, p)


# ------------------------------------------------------------------------
# batched profile grouping
# ------------------------------------------------------------------------

def test_batch_with_per_scenario_profiles_matches_serial():
    g, wl, p = _config_a()
    profs = [TransportProfile.ai_base(), TransportProfile.ai_full(),
             TransportProfile.hpc(), TransportProfile.ai_full()]
    rs = simulate_batch(g, Workload.stack([wl] * 4), profs, p,
                        trace="full")
    for prof, rb in zip(profs, rs):
        r = simulate(g, wl, prof, p, trace="full")
        np.testing.assert_array_equal(r.delivered_per_tick,
                                      rb.delivered_per_tick,
                                      err_msg=prof.name)
        np.testing.assert_array_equal(r.cwnd_per_tick, rb.cwnd_per_tick,
                                      err_msg=prof.name)


def test_profile_hash_ignores_name():
    """Cache identity is WHAT a profile does, not what it is called."""
    a = TransportProfile.ai_full()
    b = replace(a, name="renamed")
    assert a == b and hash(a) == hash(b)
    assert TransportProfile.ai_full() != TransportProfile.ai_base()


# ------------------------------------------------------------------------
# legacy compat shim + deprecations
# ------------------------------------------------------------------------

def test_legacy_simparams_signature_warns_and_matches():
    """The one remaining shim: positional SimParams in the profile slot
    warns for one release and runs as the explicit ai_full composition."""
    g, wl, p = _config_a()
    r_new = simulate(g, wl, TransportProfile.ai_full(), p, trace="full")
    with pytest.warns(DeprecationWarning, match="TransportProfile"):
        r_old = simulate(g, wl, SimParams(ticks=300), trace="full")
    np.testing.assert_array_equal(r_old.delivered_per_tick,
                                  r_new.delivered_per_tick)
    np.testing.assert_array_equal(r_old.cwnd_per_tick, r_new.cwnd_per_tick)


def test_simparams_legacy_fields_removed():
    """The deprecated composition/failure fields are gone from SimParams:
    constructing with them is a TypeError, and failed= is the only way to
    express static failures."""
    for kw in ({"nscc": True}, {"rccc": False}, {"mode": "flexible"},
               {"lb": LBScheme.OBLIVIOUS}, {"failed_queues": (3,)}):
        with pytest.raises(TypeError):
            SimParams(ticks=100, **kw)
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    wl = Workload.of([0, 1], [2, 3], 120)
    dead = (int(g.up1_table[0, 0]),)
    r = simulate(g, wl, TransportProfile.ai_full(),
                 SimParams(ticks=200, timeout_ticks=64), failed=dead)
    assert int(r.state.drops) > 0


def test_batch_accepts_int01_failure_masks():
    """A [B, Q] 0/1 integer array is a mask (the pre-profile API accepted
    those), NOT a queue-id list — an all-zeros int mask must mean 'no
    failures', and bad queue ids must raise instead of silently wrapping."""
    g = leaf_spine(leaves=2, spines=2, hosts_per_leaf=2)
    wl = Workload.of([0, 1], [2, 3], 150)
    p = SimParams(ticks=200, timeout_ticks=64)
    prof = TransportProfile.ai_full()
    none_int = np.zeros((2, g.num_queues), np.int64)
    healthy, healthy2 = simulate_batch(g, Workload.stack([wl, wl]), prof, p,
                                       failed=none_int)
    assert int(healthy.state.drops) == 0 and int(healthy2.state.drops) == 0
    with pytest.raises(ValueError, match="queue ids"):
        simulate(g, wl, prof, p, failed=(g.num_queues + 5,))


def test_rod_rejects_counted_separately_from_dups():
    """Go-back-N discards at a ROD receiver are not duplicate deliveries:
    they land in rod_rejects, and dups stays a true-duplicate count."""
    g, wl, _ = workloads.incast(2, size=300)
    prof = TransportProfile(cc=CCAlgo.NSCC, delivery=DeliveryMode.ROD,
                            name="rod")
    r = simulate(g, wl, prof, SimParams(ticks=2500))
    assert int(r.state.rod_rejects) > 0, "congested ROD must reject OOO"
    # what remains in dups really is duplicate deliveries: go-back-N
    # resends of packets the receiver already accepted (they arrive below
    # the receiver base -> tracker oor) or already-set in-range bits
    track_dups = (np.asarray(r.state.dst_track.dup)
                  + np.asarray(r.state.dst_track.oor)).sum()
    assert int(r.state.dups) == int(track_dups)


def test_new_api_rejects_legacy_composition_fields():
    with pytest.raises(TypeError):
        SimParams(ticks=100, nscc=False)


# ------------------------------------------------------------------------
# SimResult contracts
# ------------------------------------------------------------------------

def test_goodput_rejects_empty_or_inverted_window():
    g, wl, p = _config_a()
    r = simulate(g, wl, TransportProfile.ai_full(), p, trace="full")
    with pytest.raises(ValueError, match="selects no ticks"):
        r.goodput((200, 100))
    with pytest.raises(ValueError, match="selects no ticks"):
        r.goodput((300, 300))
    assert r.goodput((0, 300)).shape == (3,)


def test_completion_tick_plain_int():
    g, wl, p = _config_a()
    r = simulate(g, wl, TransportProfile.ai_full(), p)
    ct = r.completion_tick()
    assert type(ct) is int and ct >= 0
    per_flow = r.completion_ticks()
    assert per_flow.shape == (3,) and ct == int(per_flow.max())
    short = simulate(g, wl, TransportProfile.ai_full(),
                     SimParams(ticks=40))
    assert short.completion_tick() == -1
