"""Model-driven traffic: ParallelismPlan -> fabric schedule -> step time.

This is the co-design surface the ROADMAP's north star asks for: the
model zoo meets the packet fabric. The pipeline has three stages:

1. **plan** (`repro.distributed.plan.derive_plan`): ArchConfig + mesh
   shape -> per-step collective demand (kinds, group sizes, byte
   volumes), classified by the REAL sharding rules.
2. **compile** (`compile_step`): lower the plan to ONE fabric
   :class:`~repro.network.fabric.Workload` of dep-chained phases — param
   all-gathers, a per-layer TP all-reduce stream, PP point-to-point,
   DP grad reduce-scatter/all-gather, decode-time serving incast — each
   phase's root flows gated on the previous phase's source completion at
   the same host via the ``dep`` lane, so the whole step is one compiled
   adaptive-horizon run.
3. **price** (`price_step`): read per-phase durations from the simulated
   source-completion ticks, form a per-scope *achieved efficiency*
   (analytic serialization bound / measured ticks, in (0, 1]), and divide
   the plan's full-scale analytic seconds by it. The network term of the
   step time is therefore SIMULATED per operating point — topology,
   transport profile and sharding layout all move it — replacing the
   analytic-only `netmodel` collective term.

Scale contract: the fabric is priced on a DOWNSCALED representative
schedule (``sim_tp``/``sim_dp`` groups, ``layers_sim`` layers, sizes
clamped to ``max_pkts``) because the simulator's per-tick arbitration is
quadratic in lanes — the plan's real group sizes and byte volumes enter
through the analytic side of the efficiency ratio. TP groups map to
hosts within one leaf (TP traffic meets only host downlinks); DP groups
take one host per leaf (DP traffic crosses the oversubscribable fabric)
— which is exactly why topology and profile separate operating points.

What is static vs traced: the compiled schedule (flow count, dep
structure, phase slices) is static per (plan, graph) — it keys the
fabric's compile cache via F; byte-scaled sizes, starts and deps are
traced lanes, so a sweep of plans with equal flow counts shares
executables, and `stack_padded` merges unequal ones.

The module also closes the endpoint-failure loop ABOVE the fabric
(:func:`price_recovery`): kill one DP-replica host under a
liveness-enabled profile, read the simulated fault->PDC-teardown
detection latency off ``abandon_tick``, price the sharded checkpoint
restore (:func:`checkpoint_seconds`) and the replan onto survivors
(`replan_onto_survivors` + a degraded-rate step), and hand the
resulting :class:`RecoveryCosts` to `repro.ckpt.checkpointing`'s
Young/Daly closed forms for effective-throughput pricing.

``python -m repro.network.traffic`` runs a one-config canary asserting
the simulated step time lands within a sane band of the analytic bound.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass

import numpy as np

from repro.distributed.plan import (ParallelismPlan, derive_plan)
from repro.network import collectives as coll
from repro.network.collectives import CollectiveSpec
from repro.network.fabric import SimParams, SimResult, Workload, simulate, \
    simulate_batch
from repro.network.topology import QueueGraph, leaf_spine

# roofline-class hardware constants (launch/roofline.py uses the same
# numbers; duplicated here so network/ does not import launch/)
PEAK_FLOPS = 197e12
HBM_BW = 819e9


# ---------------------------------------------------------------------------
# schedule builder
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PhaseSlice:
    """One dep-chained phase of the compiled step: flows [lo, hi) of the
    workload, plus the phase's analytic serialization lower bound (ticks)
    used to form the achieved-efficiency ratio."""
    name: str
    scope: str        # plan scope: tp | dp | pp | serve
    lo: int
    hi: int
    ideal_ticks: int


@dataclass(frozen=True)
class CompiledStep:
    plan: ParallelismPlan
    graph_name: str
    workload: Workload           # [F] host-id flows, dep-chained phases
    phases: tuple                # tuple[PhaseSlice, ...] in chain order
    meta: dict

    @property
    def num_flows(self) -> int:
        return int(self.workload.src.shape[0])

    def default_budget(self) -> int:
        """Tick budget with the same slack rule netmodel uses (traced
        bound: sweeping it reuses executables)."""
        return 6 * sum(ph.ideal_ticks for ph in self.phases) + 800


class _StepBuilder:
    """Accumulates per-phase flow tables into one host-id workload.

    Chaining rule: a phase's root flows (table dep == -1) gate on the
    previous phase's LAST-algorithm-phase flow from the same source host
    (so e.g. the DP phase at host h cannot start before the last TP
    phase source-completes at h); hosts with no prior flow fall back to
    the previous phase's final flow as a representative barrier.
    """

    def __init__(self):
        self.src: list = []
        self.dst: list = []
        self.size: list = []
        self.dep: list = []
        self.n = 0
        self.phases: list[PhaseSlice] = []
        self._last_map: dict[int, int] = {}
        self._last_any = -1

    def add_rings(self, name: str, scope: str, specs: "list[CollectiveSpec]",
                  algo: str = "ring"):
        """One phase of concurrent collectives (disjoint host groups)."""
        lo = self.n
        ideal = 0
        new_map: dict[int, int] = {}
        for spec in specs:
            t = coll.flow_table(spec, algo)
            hosts = np.asarray(spec.hosts, np.int64)
            off = self.n
            src = hosts[t.src]
            dep = np.where(t.dep >= 0, t.dep + off, -1).astype(np.int64)
            for j in np.nonzero(t.dep < 0)[0]:
                dep[j] = self._last_map.get(int(src[j]), self._last_any)
            self.src += src.tolist()
            self.dst += hosts[t.dst].tolist()
            self.size += t.size.tolist()
            self.dep += dep.tolist()
            pmax = int(t.phase.max())
            for j in np.nonzero(t.phase == pmax)[0]:
                new_map[int(src[j])] = off + int(j)
            self.n += len(src)
            ideal = max(ideal, coll.analytic_ticks(spec, algo))
        self._finish(name, scope, lo, ideal, new_map)

    def add_flows(self, name: str, scope: str, src, dst, size,
                  ideal_ticks: int):
        """One phase of plain dep-rooted flows (incast / p2p)."""
        lo = self.n
        new_map: dict[int, int] = {}
        for s, d, z in zip(src, dst, size):
            self.src.append(int(s))
            self.dst.append(int(d))
            self.size.append(int(z))
            self.dep.append(self._last_map.get(int(s), self._last_any))
            new_map[int(s)] = self.n
            self.n += 1
        self._finish(name, scope, lo, ideal_ticks, new_map)

    def _finish(self, name, scope, lo, ideal, new_map):
        self.phases.append(PhaseSlice(name, scope, lo, self.n,
                                      max(1, int(ideal))))
        self._last_map = new_map
        self._last_any = self.n - 1

    def build(self) -> Workload:
        return Workload.of(np.asarray(self.src, np.int32),
                           np.asarray(self.dst, np.int32),
                           np.asarray(self.size, np.int32),
                           dep=np.asarray(self.dep, np.int32))


def _pkts(nbytes: float, max_pkts: int, mtu: int) -> int:
    return min(max_pkts, max(1, math.ceil(nbytes / mtu)))


def _sim_spec(kind: str, hosts, nbytes: float, max_pkts: int,
              mtu: int) -> CollectiveSpec:
    # route through from_bytes (the >=1-packet floor for sub-packet TP
    # messages lives there), then clamp to the sim's size budget
    s = CollectiveSpec.from_bytes(kind, hosts, nbytes, mtu)
    if s.size_pkts > max_pkts:
        s = CollectiveSpec(kind, s.hosts, max_pkts)
    return s


def compile_step(plan: ParallelismPlan, g: QueueGraph, *,
                 layers_sim: int = 2, max_pkts: int = 32,
                 mtu: int = 4096) -> CompiledStep:
    """Lower a plan to one dep-chained fabric workload on graph ``g``.

    Host mapping: TP group d takes ``sim_tp`` contiguous hosts of leaf d
    (TP = intra-leaf), DP group r takes host r of each used leaf (DP =
    cross-fabric). Decode adds ``fan`` client hosts from unused leaves
    incasting into the entry host. Raises if g is too small.
    """
    leaves = int(g.host_leaf.max()) + 1
    leaf_hosts = [np.nonzero(g.host_leaf == l)[0] for l in range(leaves)]
    hp = min(len(h) for h in leaf_hosts)

    sim_tp = 1 if plan.tp == 1 else min(plan.tp, 4, hp)
    sim_dp = 1 if plan.dp == 1 else min(plan.dp, 2, leaves)
    if plan.tp > 1 and sim_tp < 2:
        raise ValueError(f"graph {g.name} has {hp} hosts/leaf; need >= 2 "
                         f"for a TP group")
    if plan.dp > 1 and sim_dp < 2:
        raise ValueError(f"graph {g.name} has {leaves} leaves; need >= 2 "
                         f"for a DP group")
    layers_sim = max(1, min(layers_sim, plan.num_layers))

    # grid[d][r] = host id of DP replica d, TP rank r
    grid = [[int(leaf_hosts[d][r]) for r in range(sim_tp)]
            for d in range(sim_dp)]
    used = {h for row in grid for h in row}
    spare = [int(h) for l in range(leaves) for h in leaf_hosts[l]
             if int(h) not in used]
    entry = grid[0][0]

    by_phase = {d.phase: d for d in plan.demands}
    b = _StepBuilder()

    # 1. ZeRO-3 param all-gathers (fsdp_tp layout; step start)
    if "dp_param" in by_phase and sim_dp > 1:
        d = by_phase["dp_param"]
        b.add_rings("dp_param", d.scope, [
            _sim_spec("all_gather", [grid[dd][r] for dd in range(sim_dp)],
                      d.bytes_per_rank, max_pkts, mtu)
            for r in range(sim_tp)])

    # 2. the per-layer TP all-reduce stream
    if "tp_stream" in by_phase and sim_tp > 1:
        d = by_phase["tp_stream"]
        for i in range(layers_sim):
            b.add_rings(f"tp_layer{i}", d.scope, [
                _sim_spec("all_reduce", grid[dd], d.bytes_per_rank,
                          max_pkts, mtu)
                for dd in range(sim_dp)])

    # 3. PP activation send to the next stage (one representative hop)
    if "pp_p2p" in by_phase:
        d = by_phase["pp_p2p"]
        if not spare:
            raise ValueError(f"graph {g.name} has no spare host for the "
                             f"PP stage")
        pp_host = spare.pop(0)
        z = _pkts(d.bytes_per_rank, max_pkts, mtu)
        b.add_flows("pp_p2p", d.scope, [entry], [pp_host], [z],
                    ideal_ticks=z)

    # 4. DP gradient collectives (train)
    if "dp_grad" in by_phase and sim_dp > 1:
        for d in plan.demands:
            if d.phase != "dp_grad":
                continue
            groups = [[grid[dd][r] for dd in range(sim_dp)]
                      for r in range(sim_tp)]
            if d.kind == "reduce_scatter":
                b.add_rings("dp_grad_rs", d.scope, [
                    _sim_spec("reduce_scatter", hs, d.bytes_per_rank,
                              max_pkts, mtu) for hs in groups])
                b.add_rings("dp_grad_ag", d.scope, [
                    _sim_spec("all_gather", hs, d.bytes_per_rank / plan.dp,
                              max_pkts, mtu) for hs in groups])
            elif d.kind == "all_reduce":
                b.add_rings("dp_grad_ar", d.scope, [
                    _sim_spec("all_reduce", hs, d.bytes_per_rank,
                              max_pkts, mtu) for hs in groups])

    # 5. decode-time serving incast into the entry host
    if "serve_incast" in by_phase:
        d = by_phase["serve_incast"]
        fan = min(d.n, len(spare))
        if fan < 2:
            raise ValueError(f"graph {g.name} has {len(spare)} spare hosts; "
                             f"need >= 2 incast clients")
        z = _pkts(d.bytes_per_rank, max_pkts, mtu)
        b.add_flows("serve_incast", d.scope, spare[:fan], [entry] * fan,
                    [z] * fan, ideal_ticks=fan * z)

    if not b.phases:
        raise ValueError("plan has no network phases to simulate "
                         "(dp == tp == pp == 1 and not decode)")

    return CompiledStep(
        plan=plan, graph_name=g.name, workload=b.build(),
        phases=tuple(b.phases),
        meta={"sim_tp": sim_tp, "sim_dp": sim_dp, "layers_sim": layers_sim,
              "entry_host": entry, "max_pkts": max_pkts, "mtu": mtu})


# ---------------------------------------------------------------------------
# pricing
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StepTiming:
    """End-to-end step economics for one operating point."""
    step_s: float
    net_s: float              # simulated-efficiency network term
    analytic_net_s: float     # plan's alpha-beta lower bound
    compute_s: float
    memory_s: float
    tokens_per_sec: float     # served (decode) or trained tokens/sec
    eff: dict                 # scope -> achieved efficiency (0, 1]
    sim_ticks: int            # simulated schedule completion tick
    phases: tuple             # ({name, scope, ticks, ideal_ticks}, ...)

    def time_to_train(self, total_tokens: float = 1e12) -> float:
        """Wall seconds to push total_tokens through at this step rate."""
        return total_tokens / self.tokens_per_sec


def price_step(compiled: CompiledStep, result: SimResult, *,
               fabric=None, peak_flops: float = PEAK_FLOPS,
               hbm_bw: float = HBM_BW) -> StepTiming:
    """Price a simulated schedule: per-phase durations come from the
    streamed source-completion ticks (phases are strictly chained, so
    phase k runs from phase k-1's end to its own), per-scope efficiency
    is ideal/measured, and the plan's full-scale analytic seconds are
    divided by it. ``step_s = max(compute, memory) + net`` — no
    compute/communication overlap is modeled (documented pessimism)."""
    from repro.distributed.netmodel import FabricSpec
    fabric = fabric or FabricSpec()
    plan = compiled.plan

    src_comp = result.source_completion_ticks()
    ideal: dict[str, int] = {}
    measured: dict[str, int] = {}
    prev_end = 0
    detail = []
    for ph in compiled.phases:
        seg = src_comp[ph.lo:ph.hi]
        if (seg < 0).any():
            raise RuntimeError(
                f"phase {ph.name} did not complete within the tick budget "
                f"({result.max_ticks}) — raise max_ticks")
        end = int(seg.max())
        dur = max(1, end - prev_end)
        prev_end = max(prev_end, end)
        ideal[ph.scope] = ideal.get(ph.scope, 0) + ph.ideal_ticks
        measured[ph.scope] = measured.get(ph.scope, 0) + dur
        detail.append({"name": ph.name, "scope": ph.scope, "ticks": dur,
                       "ideal_ticks": ph.ideal_ticks})

    eff = {s: min(1.0, ideal[s] / max(1, measured[s])) for s in measured}
    bw = fabric.link_gbps * 1e9 / 8 * fabric.links_per_chip
    analytic = plan.analytic_net_seconds(bw)
    analytic_net = sum(analytic.values())
    net = sum(t / max(eff.get(s, 1.0), 1e-6) for s, t in analytic.items())
    compute = plan.compute_seconds(peak_flops)
    memory = plan.memory_seconds(hbm_bw)
    step = max(compute, memory) + net
    return StepTiming(
        step_s=step, net_s=net, analytic_net_s=analytic_net,
        compute_s=compute, memory_s=memory,
        tokens_per_sec=plan.tokens_per_step / step,
        eff=eff, sim_ticks=prev_end, phases=tuple(detail))


def step_time(plan: ParallelismPlan, g: "QueueGraph | None" = None,
              profile=None, p: "SimParams | None" = None, *,
              faults=None, failed=None, max_ticks: "int | None" = None,
              **compile_kw) -> StepTiming:
    """Convenience: compile -> simulate -> price, one operating point."""
    g = g if g is not None else leaf_spine(4, 4, 4)
    compiled = compile_step(plan, g, **compile_kw)
    budget = max_ticks if max_ticks is not None else compiled.default_budget()
    r = simulate(g, compiled.workload, profile, p or SimParams(),
                 faults=faults, failed=failed, max_ticks=budget)
    return price_step(compiled, r)


# ---------------------------------------------------------------------------
# recovery pricing: host fault -> PDC teardown -> checkpoint-restart economics
# ---------------------------------------------------------------------------

def checkpoint_seconds(plan: ParallelismPlan, *,
                       storage_gbps: float = 100.0,
                       state_factor: float = 3.0) -> float:
    """Seconds to write (or restore) one sharded checkpoint: every host
    moves only its own shard (`repro.ckpt.checkpointing.save`), so the
    cost is per-host state bytes over per-host storage bandwidth.
    ``state_factor`` scales params to full train state (params + grads +
    optimizer moments ~ 3x for Adam at matching precision)."""
    if storage_gbps <= 0:
        raise ValueError(f"storage_gbps must be > 0, got {storage_gbps}")
    bytes_per_host = state_factor * plan.param_bytes / plan.devices
    return bytes_per_host / (storage_gbps * 1e9 / 8)


@dataclass(frozen=True)
class RecoveryCosts:
    """Measured cost of losing one host, priced in seconds — the inputs
    to :func:`repro.ckpt.checkpointing.availability`.

    ``detect_s`` is SIMULATED: the gap between the scheduled host death
    and the fabric's PDC-teardown signal (``abandon_tick``), i.e. the
    backed-off RTO strike run that declares the peer unreachable.
    ``restore_s`` is the sharded checkpoint read; ``replan_s`` is one
    wasted step at the degraded (survivor) rate while the collective
    groups re-form."""
    detect_s: float
    detect_ticks: int
    restore_s: float
    replan_s: float
    healthy_tokens_per_sec: float
    degraded_tokens_per_sec: float
    flows_abandoned: int
    horizon: int              # fault run's quiescence tick
    budget: int               # fault run's tick budget

    @property
    def downtime_s(self) -> float:
        """Fixed per-failure cost, excluding the half-interval of lost
        work (that term depends on the checkpoint interval)."""
        return self.detect_s + self.restore_s + self.replan_s


def price_recovery(plan: ParallelismPlan, g: "QueueGraph | None" = None,
                   profile=None, p: "SimParams | None" = None, *,
                   fail_at: int = 64, fabric=None,
                   storage_gbps: float = 100.0,
                   **compile_kw) -> RecoveryCosts:
    """Price the endpoint-failure recovery loop for one plan.

    Three runs of the compiled step schedule:

    1. healthy — baseline tokens/sec;
    2. the same schedule with one DP-replica host killed at ``fail_at``
       under a liveness-enabled profile (``TransportProfile.resilient``
       by default): the victim's flows strike out, the PDC tears down,
       quarantine quiesces the run early, and ``abandon_tick - fail_at``
       is the measured detection latency in ticks (seconds via
       ``FabricSpec.tick_seconds``);
    3. the replanned schedule on the survivors
       (:func:`repro.distributed.plan.replan_onto_survivors`) — the
       degraded rate, whose step time also prices the replan barrier.

    Returns a :class:`RecoveryCosts`; feed it to
    :func:`repro.ckpt.checkpointing.availability` /
    :func:`~repro.ckpt.checkpointing.effective_rate` with an MTBF and
    checkpoint interval to get effective throughput."""
    from repro.distributed.netmodel import FabricSpec
    from repro.distributed.plan import replan_onto_survivors
    from repro.network.faults import FaultSchedule
    from repro.network.profile import TransportProfile

    g = g if g is not None else leaf_spine(4, 4, 4)
    profile = profile if profile is not None else TransportProfile.resilient()
    if profile.pdc_dead_after <= 0:
        raise ValueError(f"profile {profile.name!r} has pdc_dead_after=0: "
                         f"recovery pricing needs PDC liveness teardown")
    p = p if p is not None else SimParams(timeout_ticks=64)
    fabric = fabric or FabricSpec()

    compiled = compile_step(plan, g, **compile_kw)
    sim_dp = compiled.meta["sim_dp"]
    if sim_dp < 2:
        raise ValueError(f"plan dp={plan.dp}: recovery pricing needs a DP "
                         f"axis to lose (dp >= 2)")
    budget = compiled.default_budget() + 8000

    healthy = price_step(
        compiled,
        simulate(g, compiled.workload, profile, p, max_ticks=budget),
        fabric=fabric)

    # kill DP replica (sim_dp - 1)'s first host: the last leaf's rank-0
    # host in compile_step's grid — a full replica loss, not a TP peer
    victim = int(np.nonzero(np.asarray(g.host_leaf) == sim_dp - 1)[0][0])
    sched = FaultSchedule.healthy(
        g.num_queues, num_hosts=g.num_hosts).host_fail(victim, fail_at)
    rf = simulate(g, compiled.workload, profile, p, faults=sched,
                  max_ticks=budget)
    if rf.flows_abandoned == 0 or rf.abandon_tick < 0:
        raise RuntimeError(
            f"host {victim} died at tick {fail_at} but no flow was "
            f"abandoned within {budget} ticks — liveness teardown never "
            f"fired (pdc_dead_after={profile.pdc_dead_after})")
    detect_ticks = int(rf.abandon_tick) - fail_at
    detect_s = detect_ticks * fabric.tick_seconds

    plan2 = replan_onto_survivors(plan, 1)
    compiled2 = compile_step(plan2, g, **compile_kw)
    degraded = price_step(
        compiled2,
        simulate(g, compiled2.workload, profile, p,
                 max_ticks=compiled2.default_budget()),
        fabric=fabric)

    return RecoveryCosts(
        detect_s=detect_s, detect_ticks=detect_ticks,
        restore_s=checkpoint_seconds(plan, storage_gbps=storage_gbps),
        replan_s=degraded.step_s,
        healthy_tokens_per_sec=healthy.tokens_per_sec,
        degraded_tokens_per_sec=degraded.tokens_per_sec,
        flows_abandoned=int(rf.flows_abandoned),
        horizon=int(rf.horizon), budget=budget)


# ---------------------------------------------------------------------------
# the co-design sweep: topology x profile x sharding plan x model
# ---------------------------------------------------------------------------

def default_topologies() -> list:
    """(label, graph): full-bisection vs 2:1-oversubscribed leaf-spine."""
    return [("full", leaf_spine(4, 4, 4)),
            ("oversub2", leaf_spine(4, 2, 4))]


def default_profiles() -> list:
    from repro.network.profile import TransportProfile
    return [TransportProfile.ai_base(), TransportProfile.ai_full(),
            TransportProfile.hpc()]


def model_sweep_scenarios(arch_names=("deepseek-coder-33b", "mixtral-8x22b"),
                          shape: str = "decode_32k", *,
                          dp: int = 16, tp: int = 16,
                          topologies=None, profiles=None,
                          layouts=("fsdp_tp", "tp_only"),
                          max_pkts: int = 32):
    """Build the co-design grid: every (arch, topology, profile, layout)
    operating point as one scenario. Returns (graphs, workloads,
    profiles, points) aligned by index — feed the first three straight
    into ONE ``simulate_batch`` call (per-scenario graphs + profiles)."""
    from repro import configs
    topologies = topologies if topologies is not None else default_topologies()
    profiles = profiles if profiles is not None else default_profiles()

    graphs, wls, profs, points = [], [], [], []
    for arch in arch_names:
        cfg = configs.get(arch)
        for layout in layouts:
            plan = derive_plan(cfg, shape, dp=dp, tp=tp, layout=layout)
            for tlabel, g in topologies:
                compiled = compile_step(plan, g, max_pkts=max_pkts)
                for prof in profiles:
                    graphs.append(g)
                    wls.append(compiled.workload)
                    profs.append(prof)
                    points.append({
                        "arch": arch, "shape": shape, "layout": layout,
                        "topology": tlabel, "profile": prof.name,
                        "dp": dp, "tp": tp, "compiled": compiled})
    return graphs, wls, profs, points


def run_model_sweep(arch_names=("deepseek-coder-33b", "mixtral-8x22b"),
                    shape: str = "decode_32k", *,
                    dp: int = 16, tp: int = 16,
                    topologies=None, profiles=None,
                    layouts=("fsdp_tp", "tp_only"),
                    p: "SimParams | None" = None,
                    max_ticks: "int | None" = None,
                    shard: bool = False, devices=None) -> list:
    """Run the whole co-design grid in ONE ``simulate_batch`` call
    (scenarios grouped per (topology, profile) executable) and price
    every operating point. Returns one dict per point with the simulated
    step time, network term, efficiencies and tokens/sec."""
    graphs, wls, profs, points = model_sweep_scenarios(
        arch_names, shape, dp=dp, tp=tp, topologies=topologies,
        profiles=profiles, layouts=layouts)
    budget = max_ticks if max_ticks is not None else max(
        pt["compiled"].default_budget() for pt in points)
    rs = simulate_batch(graphs, coll.stack_padded(wls), profs,
                        p or SimParams(), max_ticks=budget,
                        shard=shard, devices=devices)
    out = []
    for pt, r in zip(points, rs):
        compiled = pt.pop("compiled")
        timing = price_step(compiled, r)
        out.append({
            **pt,
            "step_s": timing.step_s, "net_s": timing.net_s,
            "analytic_net_s": timing.analytic_net_s,
            "compute_s": timing.compute_s, "memory_s": timing.memory_s,
            "tokens_per_sec": timing.tokens_per_sec,
            "sim_ticks": timing.sim_ticks,
            "eff": {k: round(v, 4) for k, v in timing.eff.items()},
        })
    return out


# ---------------------------------------------------------------------------
# canary (scripts/check.sh): one small config, sim within a band of analytic
# ---------------------------------------------------------------------------

def _canary() -> int:
    from repro import configs
    from repro.network.profile import TransportProfile

    cfg = configs.get("deepseek-coder-33b")
    plan = derive_plan(cfg, "decode_32k", dp=4, tp=4, layout="fsdp_tp")
    g = leaf_spine(4, 2, 4)
    t0 = time.time()
    timing = step_time(plan, g, TransportProfile.ai_full())
    ratio = timing.net_s / max(timing.analytic_net_s, 1e-12)
    print(f"traffic canary: {plan.arch} x {plan.shape} on {g.name}: "
          f"step={timing.step_s * 1e3:.3f} ms "
          f"net={timing.net_s * 1e3:.3f} ms "
          f"(analytic {timing.analytic_net_s * 1e3:.3f} ms, "
          f"sim/analytic {ratio:.2f}x) "
          f"tok/s={timing.tokens_per_sec:.0f} "
          f"[{time.time() - t0:.1f}s]")
    assert 1.0 <= ratio <= 10.0, (
        f"simulated network term {ratio:.2f}x analytic is outside the sane "
        f"band [1, 10] — scheduling or pricing regression")
    assert timing.sim_ticks > 0 and timing.step_s > 0
    print("traffic canary OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(_canary())
