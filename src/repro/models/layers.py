"""Model building blocks: GQA attention (full / sliding-window), dense and
MoE FFNs, Mamba and RWKV6 mixers.

Everything is a pure function over explicit parameter dicts (no module
framework): `init_*` returns a param pytree, `*_fwd` consumes it. All
matmul dims are chosen/padded so they shard cleanly over the production
mesh's "model" axis (see repro/distributed/sharding.py).

Attention uses a blockwise online-softmax (flash-style) scan so that
[B, H, S, S] score tensors never materialize — mandatory for the 32k
prefill shapes. MoE uses top-k gating + sort + `jax.lax.ragged_dot`
(dropless grouped GEMM), the XLA-native shape of an expert dispatch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def init_rms(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rope(x, positions, theta: float):
    """Rotary embedding. x: [..., S, H, hd]; positions: [..., S].

    cos/sin are cast to x.dtype BEFORE the multiply: an f32 factor here
    would promote the whole backward cotangent chain (d_q, d_x, ...) to
    f32 and double activation memory across every layer.
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (jnp.log(theta) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# attention (GQA, RoPE, optional sliding window), flash-style blockwise
# ---------------------------------------------------------------------------


def init_attention(key, d_model, n_q, n_kv, head_dim, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d_model ** -0.5
    return {
        "wq": jax.random.normal(k1, (d_model, n_q * head_dim), dtype) * s,
        "wk": jax.random.normal(k2, (d_model, n_kv * head_dim), dtype) * s,
        "wv": jax.random.normal(k3, (d_model, n_kv * head_dim), dtype) * s,
        "wo": jax.random.normal(k4, (n_q * head_dim, d_model), dtype) * s,
    }


def _flash_block(q, k, v, q_pos, k_pos, window):
    """One (q-chunk x kv-chunk) attention tile with causal (+SWA) mask.

    q: [B, H, Tq, hd]; k,v: [B, H, Tk, hd] (kv already repeated to H).
    Returns (scores_max, exp_sums, out_chunk) for online softmax.
    """
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = k_pos[None, None, None, :] <= q_pos[None, None, :, None]
    if window > 0:
        mask &= k_pos[None, None, None, :] > (
            q_pos[None, None, :, None] - window)
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1)                          # [B,H,Tq]
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)                          # [B,H,Tq]
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, l, o


def flash_attention(q, k, v, q_positions, k_positions, window: int = 0,
                    q_chunk: int = 1024, kv_chunk: int = 1024):
    """Blockwise causal attention; never materializes [S, S].

    q: [B, Hq, Sq, hd]; k,v: [B, Hkv, Sk, hd]; positions are absolute token
    indices (enables decode with cache and sequence-sharded layouts).

    The kv-step body is checkpointed so the backward pass recomputes each
    (q-chunk x kv-chunk) score block instead of saving it — O(S) residual
    memory like a flash kernel, not O(S^2).
    """
    B, Hq, Sq, hd = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    Sk = k.shape[2]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = Sq // q_chunk
    nk = Sk // kv_chunk

    q_r = q.reshape(B, Hq, nq, q_chunk, hd).transpose(2, 0, 1, 3, 4)
    qp_r = q_positions.reshape(nq, q_chunk)

    k_r = k.reshape(B, Hq, nk, kv_chunk, hd).transpose(2, 0, 1, 3, 4)
    v_r = v.reshape(B, Hq, nk, kv_chunk, hd).transpose(2, 0, 1, 3, 4)
    kp_r = k_positions.reshape(nk, kv_chunk)

    def per_q_chunk(qc, qpc):
        @partial(jax.checkpoint,
                 policy=jax.checkpoint_policies.nothing_saveable)
        def kv_step(carry, xs):
            m_run, l_run, o_run = carry
            kc, vc, kpc = xs
            m, l, o = _flash_block(qc, kc, vc, qpc, kpc, window)
            m_new = jnp.maximum(m_run, m)
            alpha = jnp.exp(m_run - m_new)
            beta = jnp.exp(m - m_new)
            l_new = l_run * alpha + l * beta
            o_new = o_run * alpha[..., None] + o * beta[..., None]
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Hq, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hq, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, Hq, q_chunk, hd), jnp.float32)
        (m_f, l_f, o_f), _ = jax.lax.scan(
            kv_step, (m0, l0, o0), (k_r, v_r, kp_r))
        return o_f / jnp.maximum(l_f[..., None], 1e-30)

    out = jax.lax.map(lambda xs: per_q_chunk(*xs), (q_r, qp_r))
    out = out.transpose(1, 2, 0, 3, 4).reshape(B, Hq, Sq, hd)
    return out.astype(q.dtype)


def sharded_cache_attention(mesh, dp_axes):
    """Hand-distributed decode attention over a sequence-sharded KV cache.

    The SPMD partitioner, left to itself, re-shards the cache toward a
    kv-head layout and emits full-cache all-gathers (in f32!) every token
    — the dominant collective of baseline decode. Under shard_map the C
    (cache sequence) dim stays explicitly local and the softmax reduces
    with psum-max / psum-sum of [B,H,1]-sized tensors; the attention
    output psum is [B,H,1,hd] — a few hundred KB per layer instead of
    gigabytes. (§Perf decode iteration 3.)
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def local_attn(q, kk, vv, kpos, valid, pos_now, window_arr):
        # q [B,H,1,hd] replicated; kk/vv [B,H,C_loc,hd]; kpos/valid [C_loc]
        scale = q.shape[-1] ** -0.5
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kk,
                       preferred_element_type=jnp.float32) * scale
        mask = (kpos[None, None, None, :] <= pos_now) & \
            valid[None, None, None, :]
        w = window_arr[0]
        mask &= (w <= 0) | (kpos[None, None, None, :] > pos_now - w)
        s = jnp.where(mask, s, -jnp.inf)
        m = jax.lax.pmax(jnp.max(s, axis=-1), "model")        # [B,H,1]
        e = jnp.where(mask, jnp.exp(s - m[..., None]), 0.0)
        l = jax.lax.psum(jnp.sum(e, axis=-1), "model")        # [B,H,1]
        o = jnp.einsum("bhqk,bhkd->bhqd", e.astype(vv.dtype), vv,
                       preferred_element_type=jnp.float32)
        o = jax.lax.psum(o, "model")                          # [B,H,1,hd]
        return (o / jnp.maximum(l[..., None], 1e-30))

    return shard_map(
        local_attn, mesh=mesh,
        in_specs=(P(dp, None, None, None), P(dp, None, "model", None),
                  P(dp, None, "model", None), P("model"), P("model"),
                  P(), P(None)),
        out_specs=P(dp, None, None, None),
        check_rep=False)


def attention_fwd(params, x, positions, *, n_q, n_kv, head_dim,
                  rope_theta, window=0, cache=None, select_write=False,
                  head_shardings=None, cache_attn=None):
    """GQA attention. x: [B, S, D].

    cache: None for training, else dict(k=[B, n_kv, C, hd], v=...,
    pos=[C], valid=[C]) for decode — returns the updated cache. The cache
    is a ring over C slots (C == window for SWA, == context for full).

    select_write: write the new token via iota-compare-select instead of
    dynamic_update_slice — required when C is sharded (long-context decode
    shards the KV sequence over "data"); DUS on a sharded dim would gather.
    """
    B, S, D = x.shape
    q = (x @ params["wq"]).reshape(B, S, n_q, head_dim)
    k = (x @ params["wk"]).reshape(B, S, n_kv, head_dim)
    v = (x @ params["wv"]).reshape(B, S, n_kv, head_dim)
    q = rope(q, positions, rope_theta).transpose(0, 2, 1, 3)
    k = rope(k, positions, rope_theta).transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    if head_shardings is not None:
        # Megatron-SP transition: residual is sequence-sharded over
        # "model"; attention computes with heads over "model" and full S.
        # These constraints pin the reshard point so SPMD does not drag
        # S-sharding (and replicated heads) through the flash scan.
        q_s, kv_s = head_shardings
        q = jax.lax.with_sharding_constraint(q, q_s)
        k = jax.lax.with_sharding_constraint(k, kv_s)
        v = jax.lax.with_sharding_constraint(v, kv_s)

    if cache is None:
        out = flash_attention(q, k, v, positions, positions, window)
        new_cache = None
    else:
        C = cache["k"].shape[2]
        slot = positions[0] % C
        kc = k.astype(cache["k"].dtype)
        vc = v.astype(cache["v"].dtype)
        if select_write:
            sel = jax.lax.broadcasted_iota(jnp.int32, (C,), 0) == slot
            ck = jnp.where(sel[None, None, :, None], kc, cache["k"])
            cv = jnp.where(sel[None, None, :, None], vc, cache["v"])
            cpos = jnp.where(sel, positions[0], cache["pos"])
            cvalid = cache["valid"] | sel
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], kc, (0, 0, slot, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], vc, (0, 0, slot, 0))
            cpos = jax.lax.dynamic_update_slice(
                cache["pos"], positions.astype(cache["pos"].dtype), (slot,))
            cvalid = cache["valid"].at[slot].set(True)
        kk = jnp.repeat(ck, n_q // n_kv, axis=1)
        vv = jnp.repeat(cv, n_q // n_kv, axis=1)
        if cache_attn is not None:
            out = cache_attn(q, kk, vv, cpos, cvalid, positions[0],
                             jnp.asarray([window], jnp.int32))
        else:
            scale = head_dim ** -0.5
            s = jnp.einsum("bhqd,bhkd->bhqk", q, kk,
                           preferred_element_type=jnp.float32) * scale
            mask = (cpos[None, None, None, :]
                    <= positions[None, None, :, None])
            mask &= cvalid[None, None, None, :]
            if window > 0:
                mask &= cpos[None, None, None, :] > (
                    positions[None, None, :, None] - window)
            s = jnp.where(mask, s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            out = jnp.einsum("bhqk,bhkd->bhqd", p.astype(vv.dtype), vv,
                             preferred_element_type=jnp.float32)
        new_cache = {"k": ck, "v": cv, "pos": cpos, "valid": cvalid}

    out = out.astype(x.dtype).transpose(0, 2, 1, 3).reshape(B, S, -1)
    return out @ params["wo"], new_cache


def init_attention_cache(batch, n_kv, cache_len, head_dim, dtype):
    return {
        "k": jnp.zeros((batch, n_kv, cache_len, head_dim), dtype),
        "v": jnp.zeros((batch, n_kv, cache_len, head_dim), dtype),
        "pos": jnp.zeros((cache_len,), jnp.int32),
        "valid": jnp.zeros((cache_len,), jnp.bool_),
    }


# ---------------------------------------------------------------------------
# FFNs: dense SwiGLU and dropless MoE (top-k, ragged_dot)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s = d_model ** -0.5
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * s,
        "w_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s,
        "w_down": jax.random.normal(k3, (d_ff, d_model), dtype) * (d_ff ** -0.5),
    }


def mlp_fwd(params, x):
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]


def init_moe(key, d_model, d_ff, n_experts, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d_model ** -0.5
    return {
        "router": jax.random.normal(k1, (d_model, n_experts), jnp.float32) * s,
        "w_gate": jax.random.normal(k2, (n_experts, d_model, d_ff), dtype) * s,
        "w_up": jax.random.normal(k3, (n_experts, d_model, d_ff), dtype) * s,
        "w_down": jax.random.normal(k4, (n_experts, d_ff, d_model), dtype)
        * (d_ff ** -0.5),
    }


def moe_fwd(params, x, *, top_k: int):
    """Dropless token-choice MoE via sort + grouped GEMM (ragged_dot).

    x: [B, S, D] -> [B, S, D]. Aux losses (load balance) returned for
    training. Tokens stay on their data shard; experts' FFN dim is
    tensor-parallel over "model" (see sharding rules).
    """
    B, S, D = x.shape
    E = params["router"].shape[1]
    xt = x.reshape(B * S, D)
    T = B * S

    logits = (xt.astype(jnp.float32) @ params["router"])       # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, top_k)           # [T, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # flatten (token, k) assignments and sort by expert id
    flat_expert = experts.reshape(-1)                          # [T*K]
    flat_token = jnp.repeat(jnp.arange(T), top_k)
    order = jnp.argsort(flat_expert)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    group_sizes = jnp.bincount(sorted_expert, length=E).astype(jnp.int32)

    xin = xt[sorted_token]                                     # [T*K, D]
    h = jax.lax.ragged_dot(xin, params["w_gate"], group_sizes)
    u = jax.lax.ragged_dot(xin, params["w_up"], group_sizes)
    act = jax.nn.silu(h) * u
    yo = jax.lax.ragged_dot(act, params["w_down"], group_sizes)  # [T*K, D]

    gates_sorted = gate_vals.reshape(-1)[order]
    yo = yo * gates_sorted[:, None].astype(yo.dtype)
    out = jnp.zeros((T, D), yo.dtype).at[sorted_token].add(yo)

    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.bincount(flat_expert, length=E).astype(jnp.float32) / (T * top_k)
    aux = E * jnp.sum(me * ce)
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Mamba mixer (simplified selective SSM, Jamba-style)
# ---------------------------------------------------------------------------


def init_mamba(key, d_model, d_state, expand, dtype):
    d_inner = expand * d_model
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s = d_model ** -0.5
    return {
        "w_in": jax.random.normal(k1, (d_model, 2 * d_inner), dtype) * s,
        "w_out": jax.random.normal(k2, (d_inner, d_model), dtype)
        * (d_inner ** -0.5),
        "w_bcdt": jax.random.normal(k3, (d_inner, 2 * d_state + 1), dtype)
        * (d_inner ** -0.5),
        "a_log": jnp.zeros((d_inner, d_state), jnp.float32)
        + jnp.log(jnp.arange(1, d_state + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((), jnp.float32),
    }


def mamba_fwd(params, x, state=None):
    """Selective SSM scan. x: [B, S, D]; state: [B, d_inner, N] for decode.

    Linear-time in S (lax.scan over time, associative-scan-friendly form).
    """
    B, S, D = x.shape
    xi = x @ params["w_in"]
    d_inner = xi.shape[-1] // 2
    u, gate = jnp.split(xi, 2, axis=-1)                      # [B, S, d_inner]
    bcdt = u @ params["w_bcdt"]                               # [B,S,2N+1]
    N = params["a_log"].shape[1]
    Bc, Cc, dt = (bcdt[..., :N], bcdt[..., N:2 * N], bcdt[..., -1:])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])                             # [d_inner, N]
    dA = jnp.exp(dt[..., None] * A[None, None])               # [B,S,d_inner,N]
    dBu = (dt * u.astype(jnp.float32))[..., None] * Bc[..., None, :].astype(
        jnp.float32)                                          # [B,S,d_inner,N]

    def step(h, xs):
        dA_t, dBu_t, C_t = xs
        h = h * dA_t + dBu_t                                  # [B,d_inner,N]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    h0 = (jnp.zeros((B, d_inner, N), jnp.float32) if state is None
          else state.astype(jnp.float32))
    hT, ys = jax.lax.scan(
        step, h0,
        (dA.transpose(1, 0, 2, 3), dBu.transpose(1, 0, 2, 3),
         Cc.transpose(1, 0, 2).astype(jnp.float32)))
    y = ys.transpose(1, 0, 2).astype(x.dtype)                 # [B,S,d_inner]
    out = (y * jax.nn.silu(gate)) @ params["w_out"]
    return out, hT.astype(jnp.float32)


# ---------------------------------------------------------------------------
# RWKV6 (Finch) mixer: data-dependent decay, per-head matrix state
# ---------------------------------------------------------------------------


def init_rwkv(key, d_model, n_heads, dtype):
    hd = d_model // n_heads
    ks = jax.random.split(key, 6)
    s = d_model ** -0.5
    return {
        "w_r": jax.random.normal(ks[0], (d_model, d_model), dtype) * s,
        "w_k": jax.random.normal(ks[1], (d_model, d_model), dtype) * s,
        "w_v": jax.random.normal(ks[2], (d_model, d_model), dtype) * s,
        "w_g": jax.random.normal(ks[3], (d_model, d_model), dtype) * s,
        "w_o": jax.random.normal(ks[4], (d_model, d_model), dtype) * s,
        "w_decay": jax.random.normal(ks[5], (d_model, d_model), dtype) * s,
        "decay_bias": jnp.full((d_model,), -6.0, jnp.float32),
        "bonus": jnp.zeros((n_heads, hd), jnp.float32),
        "mix": jnp.full((5, d_model), 0.5, jnp.float32),
    }


def rwkv_fwd(params, x, state=None, *, n_heads):
    """RWKV6 time-mix. x: [B, S, D].

    state: dict(wkv=[B, H, hd, hd], prev=[B, D]) for decode; None = train
    (zero-init state, token shift from the sequence itself).
    Data-dependent decay w_t = exp(-exp(decay(x_t))) is the Finch feature.
    """
    B, S, D = x.shape
    DI = params["w_r"].shape[1]  # padded inner dim (heads * head_dim)
    hd = DI // n_heads
    prev = (jnp.zeros((B, 1, D), x.dtype) if state is None
            else state["prev"][:, None, :].astype(x.dtype))
    x_shift = jnp.concatenate([prev, x[:, :-1]], axis=1)

    def mixed(i):
        m = params["mix"][i].astype(x.dtype)
        return x * m + x_shift * (1 - m)

    r = (mixed(0) @ params["w_r"]).reshape(B, S, n_heads, hd)
    k = (mixed(1) @ params["w_k"]).reshape(B, S, n_heads, hd)
    v = (mixed(2) @ params["w_v"]).reshape(B, S, n_heads, hd)
    g = jax.nn.silu(mixed(3) @ params["w_g"])
    decay = (mixed(4) @ params["w_decay"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay + params["decay_bias"]))       # [B,S,DI] in (0,1)
    w = w.reshape(B, S, n_heads, hd)
    bonus = params["bonus"][None, :, :, None]                  # [1,H,hd_k,1]

    def step(h, xs):
        r_t, k_t, v_t, w_t = xs  # [B,H,hd]
        kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                        v_t.astype(jnp.float32))
        y = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                       h + bonus * kv)
        h = h * w_t.astype(jnp.float32)[..., None] + kv
        return h, y

    h0 = (jnp.zeros((B, n_heads, hd, hd), jnp.float32) if state is None
          else state["wkv"].astype(jnp.float32))
    hT, ys = jax.lax.scan(
        step, h0,
        (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
         v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, DI).astype(x.dtype)
    out = (y * g) @ params["w_o"]
    new_state = {"wkv": hT, "prev": x[:, -1]}
    return out, new_state


def init_rwkv_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s = d_model ** -0.5
    return {
        "w_k": jax.random.normal(k1, (d_model, d_ff), dtype) * s,
        "w_v": jax.random.normal(k2, (d_ff, d_model), dtype) * (d_ff ** -0.5),
        "w_r": jax.random.normal(k3, (d_model, d_model), dtype) * s,
        "mix": jnp.full((2, d_model), 0.5, jnp.float32),
    }


def rwkv_mlp_fwd(params, x, prev=None):
    """RWKV channel-mix (squared-relu FFN with token shift + receptance)."""
    B, S, D = x.shape
    pv = (jnp.zeros((B, 1, D), x.dtype) if prev is None
          else prev[:, None, :].astype(x.dtype))
    x_shift = jnp.concatenate([pv, x[:, :-1]], axis=1)
    mk = params["mix"][0].astype(x.dtype)
    mr = params["mix"][1].astype(x.dtype)
    xk = x * mk + x_shift * (1 - mk)
    xr = x * mr + x_shift * (1 - mr)
    k = jnp.square(jax.nn.relu(xk @ params["w_k"]))
    r = jax.nn.sigmoid(xr @ params["w_r"])
    return r * (k @ params["w_v"]), x[:, -1]
