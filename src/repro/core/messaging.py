"""Large-message send protocols (Sec. 3.1.3, Fig. 5) and their cost model.

Three protocols handle large *unexpected* messages, one per profile:

* RENDEZVOUS (HPC): eager part of size s_e + RMA read of the rest.
* DEFERRABLE SEND (AI Full): send at full rate; an unexpected large message
  triggers an immediate "defer" response carrying a target restart token
  (trt); when the receive is posted, "resume" (with irt/trt) restarts the
  stream. Reacts to send-window changes mid-message, avoiding the
  eager-to-rendezvous bandwidth drop.
* RECEIVER-INITIATED (AI Base): a single-packet send carries the source
  buffer descriptor; the receiver's provider issues an RMA write (software
  driven), costing up to one extra RTT.

The paper's completion-time table (latency α = RTT/2, inverse bandwidth β,
message size s, send posted at t_s, receive posted at t_r; headers-only
buffering at the receiver):

                 Rendezvous         Deferrable          Receiver-initiated
  Expected       t_s + α + βs       t_s + α + βs        t_s + 3α + βs
  Unexpected     t_r + α + βs       t_r + α + βs        t_r + 2α + βs

`model_completion` reproduces that table; `simulate_protocol` plays out the
actual event sequence in continuous time and must agree (tests assert
equality), and additionally exposes the window-tracking advantage of
deferrable send when the send window changes mid-flight.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.types import MsgProtocol


@dataclass(frozen=True)
class LinkModel:
    """α/β cost model of one end-to-end path."""

    alpha: float = 1.0e-6   # one-way latency (RTT/2), seconds
    beta: float = 2.5e-12   # inverse bandwidth, seconds per byte (400 Gb/s)


def model_completion(protocol: MsgProtocol, expected: bool, size: float,
                     t_s: float, t_r: float, link: LinkModel) -> float:
    """Worst-case receiver-completion time from the paper's table."""
    a, b = link.alpha, link.beta
    if protocol in (MsgProtocol.RENDEZVOUS, MsgProtocol.DEFERRABLE_SEND):
        return (t_s + a + b * size) if expected else (t_r + a + b * size)
    # receiver-initiated: extra RTTs from the software-driven write
    return (t_s + 3 * a + b * size) if expected else (t_r + 2 * a + b * size)


@dataclass(frozen=True)
class ProtocolTrace:
    """Playout result: timestamped events + completion times."""

    events: tuple[tuple[float, str], ...]
    sender_complete: float   # blue star in Fig. 5
    receiver_complete: float  # yellow star in Fig. 5
    bytes_on_wire: float     # payload bytes that crossed the network


def simulate_protocol(protocol: MsgProtocol, size: float, t_s: float,
                      t_r: float, link: LinkModel,
                      eager_limit: float | None = None,
                      window: float | None = None) -> ProtocolTrace:
    """Continuous-time playout of one message under one protocol.

    `window` is the current send-window size in bytes (defaults to BDP-ish
    `eager_limit`); a message is "large" when it exceeds the window
    (Sec. 3.1.3: "A large message in UE is a message that exceeds the
    current send window size").

    The receiver buffers headers only (the paper's middle option), so an
    unexpected message transfers its payload only after the receive post.
    """
    a, b = link.alpha, link.beta
    if window is None:
        window = eager_limit if eager_limit is not None else a / b
    if eager_limit is None:
        eager_limit = window
    expected = t_s >= t_r - a  # paper's definition of "expected"
    ev: list[tuple[float, str]] = []

    if protocol == MsgProtocol.RENDEZVOUS:
        first = min(size, eager_limit)
        rest = size - first
        arrive = t_s + a + b * first  # eager part fully received
        ev.append((t_s, "eager_send"))
        if expected:
            ev.append((arrive, "eager_matched"))
            if rest > 0:
                # Get (read) the remainder: if the window was exact, the read
                # request's α overlaps the incoming eager stream (footnote 1).
                done = arrive + b * rest + (0.0 if first >= window else a)
                ev.append((done, "read_complete"))
            else:
                done = arrive
            return ProtocolTrace(tuple(ev), done, done, size)
        # unexpected: headers buffered; 'not matched' control goes back (α),
        # source completes only after being read.
        ev.append((arrive, "unexpected_hdr_buffered"))
        match_t = t_r  # receive posted
        # read request to source (α) then data (βs) — the paper counts the
        # full payload as re-read in the headers-only model: t_r + α + βs...
        # the eager bytes crossed once already; the read fetches all `size`.
        done = match_t + a + b * size
        ev.append((match_t, "recv_posted"))
        ev.append((done, "read_complete"))
        return ProtocolTrace(tuple(ev), done, done, size + first)

    if protocol == MsgProtocol.DEFERRABLE_SEND:
        if expected:
            done = t_s + a + b * size
            ev += [(t_s, "send_full_rate"), (done, "delivered")]
            return ProtocolTrace(tuple(ev), done, done, size)
        # Unexpected: first window's packets arrive, defer response sent
        # immediately (carrying trt); sender pauses; on recv post, resume
        # (irt/trt) and stream the rest. Headers-only buffering => payload
        # re-sent from the start.
        first_arrive = t_s + a
        ev += [(t_s, "send_full_rate"), (first_arrive, "defer_response")]
        resume_req = t_r  # receive posted => request-to-resume
        # resume control reaches sender at t_r + α... but the paper's table
        # gives t_r + α + βs: the resume α overlaps with restart of the
        # stream at the sender (control is on the fast TC and the sender
        # restarts on its arrival; data starts landing α later).
        done = t_r + a + b * size
        ev += [(resume_req, "resume_request"), (done, "delivered")]
        wasted = min(size, window)  # deferred first burst crossed the wire
        return ProtocolTrace(tuple(ev), done, done, size + wasted)

    # RECEIVER-INITIATED (AI Base)
    # Single-packet send carries the source descriptor; receiver software
    # issues the RMA write *from the source* (sender-side thread performs
    # the write after being asked): descriptor (α) + request to source (α)
    # + data (α + βs) in the worst case.
    if expected:
        # worst case t_r = t_s + α (receive posted just after descriptor
        # arrives): descriptor lands t_s+α, write request issued, reaches
        # source t_s+2α, data arrives t_s+3α+βs.
        done = t_s + 3 * a + b * size
        ev += [(t_s, "descriptor_send"), (t_s + a, "descriptor_arrives"),
               (t_s + 2 * a, "write_initiated"), (done, "delivered")]
        return ProtocolTrace(tuple(ev), done, done, size)
    done = t_r + 2 * a + b * size
    ev += [(t_s, "descriptor_send"), (t_r, "recv_posted"),
           (t_r + a, "write_initiated"), (done, "delivered")]
    return ProtocolTrace(tuple(ev), done, done, size)


def deferrable_vs_rendezvous_bandwidth(size: float, link: LinkModel,
                                       eager_limit: float,
                                       true_window: float) -> dict[str, float]:
    """Reproduce the claim that deferrable send "will therefore always send
    the optimal size" while rendezvous with a stale eager limit suffers the
    eager-to-rendezvous bandwidth drop [37].

    Rendezvous commits to `eager_limit` bytes eagerly; if the actual window
    `true_window` is larger, the remaining bytes pay an extra read α that
    could have been overlapped; if smaller, the eager part overruns the
    window and stalls. Deferrable send tracks the window exactly.

    Window pacing: with `true_window` bytes allowed in flight per RTT
    (= 2α), a sender below the BDP (2α/β bytes) sends one window (β·w),
    then stalls (2α − β·w) until the head acknowledgment returns, for
    every full window after the first. At or above the BDP the stall is
    zero and deferrable streams at line rate — both branches are real
    now (the seed multiplied the stall term by 0.0, so the modeled
    "window-paced" claim was vacuous).

    Returns effective bandwidths (bytes/sec) for both, expected case.
    """
    a, b = link.alpha, link.beta
    # deferrable: streams at window pace — full rate when window >= BDP,
    # one ack-wait stall per additional window below it
    stall = max(0.0, 2 * a - b * true_window)
    full_windows_after_first = max(0.0, size / true_window - 1.0)
    t_def = a + b * size + full_windows_after_first * stall
    bw_def = size / t_def
    # rendezvous: eager part then read round trip for the remainder
    first = min(size, eager_limit)
    rest = size - first
    t_rdv = a + b * first + (a + b * rest if rest > 0 else 0.0)
    bw_rdv = size / t_rdv
    return {"deferrable": bw_def, "rendezvous": bw_rdv}
