"""Pallas TPU kernel: batched NSCC congestion-window update.

The paper bills UET as "potentially fully hardware-accelerated"; the NSCC
per-ACK control loop (Sec. 3.3.1) is the per-packet arithmetic a NIC does
at line rate. On a TPU-resident simulator the analogous hot spot is
updating *millions* of CCC windows per round — a pure VPU workload:
elementwise selects and FMAs over f32/i32 lanes.

Layout: the CCC pool is reshaped to [R, 128] (lane-aligned); the grid
blocks rows in chunks of BLOCK_R, so one program instance owns a
(BLOCK_R, 128) VMEM tile of every operand — comfortably below VMEM limits
(5 tiles x 8x128 x 4B = 160 KiB at BLOCK_R=8... we use 64 rows for fewer
grid steps: 5 x 64x128 x 4B = 160 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cms.nscc import NSCCParams
from repro.kernels import auto_interpret

BLOCK_R = 64
LANES = 128


def _nscc_kernel(cwnd_ref, ecn_ref, rtt_ref, count_ref, out_ref, *,
                 base_rtt: float, target_factor: float, md: float,
                 quick_gain: float, ai: float, min_cwnd: float,
                 max_cwnd: float):
    cwnd = cwnd_ref[...]
    ecn = ecn_ref[...] != 0
    rtt = rtt_ref[...]
    count = count_ref[...].astype(jnp.float32)

    target = base_rtt * target_factor
    high = rtt > target
    overload = jnp.clip((rtt - target) / jnp.maximum(rtt, 1e-6), 0.0, 1.0)
    dec = -md * overload
    gap = jnp.clip((target - rtt) / target, 0.0, 1.0)
    quick = quick_gain * gap
    gentle = ai / jnp.maximum(cwnd, 1.0)
    delta = jnp.where(ecn, jnp.where(high, dec, 0.0),
                      jnp.where(high, gentle, quick))
    new = jnp.where(count > 0, cwnd + delta * count, cwnd)
    out_ref[...] = jnp.clip(new, min_cwnd, max_cwnd)


@functools.partial(jax.jit, static_argnames=("params", "interpret"))
def nscc_update(cwnd: jax.Array, ecn: jax.Array, rtt: jax.Array,
                count: jax.Array, params: NSCCParams = NSCCParams(),
                interpret: bool | None = None) -> jax.Array:
    """Update N congestion windows in one fused VPU pass.

    Args:
      cwnd:  [N] float32
      ecn:   [N] bool/int32 — aggregated ECN-CE of this round's ACKs
      rtt:   [N] float32    — measured RTT (ticks or µs, caller's choice;
                              must match params.base_rtt units)
      count: [N] int32      — ACKed packets this round (0 = no update)
      interpret: run the kernel body in interpret mode (CPU validation);
        None = auto (compiled on TPU, interpreted elsewhere).
    """
    interpret = auto_interpret(interpret)
    n = cwnd.shape[0]
    rows = -(-n // LANES)
    pad = rows * LANES - n

    def prep(x, dtype):
        x = jnp.asarray(x).astype(dtype)
        return jnp.pad(x, (0, pad)).reshape(rows, LANES)

    cw = prep(cwnd, jnp.float32)
    ec = prep(ecn, jnp.int32)
    rt = prep(rtt, jnp.float32)
    ct = prep(count, jnp.int32)

    grid = (-(-rows // BLOCK_R),)
    spec = pl.BlockSpec((BLOCK_R, LANES), lambda i: (i, 0))
    kernel = functools.partial(
        _nscc_kernel, base_rtt=params.base_rtt,
        target_factor=params.target_factor, md=params.md,
        quick_gain=params.quick_gain, ai=params.ai,
        min_cwnd=params.min_cwnd, max_cwnd=params.max_cwnd)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(cw, ec, rt, ct)
    return out.reshape(-1)[:n]
