"""starcoder2-15b: GQA + RoPE dense decoder [arXiv:2402.19173; hf]."""
from repro.configs.base import ArchConfig, pad_for_tp, MIXER_ATTN, FFN_MLP

CONFIG = pad_for_tp(ArchConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
    head_dim=128, d_ff=24576, vocab_size=49152,
    rope_theta=100_000.0,
    pattern=((MIXER_ATTN, FFN_MLP),),
    source="arXiv:2402.19173; hf",
))
