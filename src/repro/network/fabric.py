"""Vectorized packet-level fabric simulator — the UET reproduction engine.

One simulator tick == the serialization time of one MTU packet on one link.
Every link is a FIFO queue; each tick every queue dequeues at most one
packet (line rate) and forwards it one hop. All protocol state — PSN
bitmaps, congestion windows, credit balances, EV recycle rings — is
structure-of-arrays, and a tick is a pure function stepped by
``jax.lax.scan`` under ``jit``. This is the TPU-native re-architecture of
the paper's protocol: what a hardware UET NIC does per packet, the
simulator does per *vector of flows* per tick.

The engine runs in two modes:

* ``simulate`` — one (workload, params) scenario per call;
* ``simulate_batch`` — a whole scenario sweep (different workloads, LB
  seeds, failure sets) ``vmap``-ed over a leading scenario axis, so an
  entire failure or incast sweep is ONE compiled ``scan``. Workloads,
  seeds and failed-queue masks are traced inputs: sweeping them never
  recompiles. Per-lane results are bitwise identical to serial
  ``simulate`` calls.

Modeled faithfully (paper sections in parens):

* ECMP spraying with per-packet EVs through a real Clos topology (2.1)
* egress ECN marking above a queue threshold (3.3.1)
* packet trimming on overflow -> fast NACK to the source (3.2.4)
* RUD selective-repeat with a source retransmit bitmap; ROD go-back-N on a
  single static path (3.2.1)
* receiver PSN tracking with SACK rings + MP_RANGE rejection (3.2.5)
* NSCC 4-case window control + Quick Adapt; RCCC receiver credits; both
  composable, as the spec prescribes (3.3)
* LB schemes: static / oblivious / RR-slots / REPS / EV-bitmap (3.3.5)
* OOO-count and EV-based loss inference, timeout fallback (3.2.4)
* control traffic (ACKs, NACKs, credits) rides the second traffic class,
  modeled as a fixed-latency uncongested return path (3.1.4)

Simplifications recorded in DESIGN.md: RCCC credit grants apply without
path delay (the grant *rate* is what the algorithm controls); trimmed
headers travel on the control TC (elevated priority per the spec).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pds
from repro.core.cms import nscc as nscc_mod
from repro.core.cms.rccc import RCCCState, grant_credits
from repro.core.lb import schemes as lb_schemes
from repro.core.lb.schemes import LBScheme, LBState, select_ev, on_ack as lb_on_ack
from repro.core.types import TransportMode
from repro.kernels import ops as kops
from repro.network.ecmp import DELIVERED, RoutingTables
from repro.network.topology import QueueGraph

# packet meta bits
META_TRIMMED = 1
META_ECN = 2

# event types
EV_NONE, EV_ACK, EV_NACK, EV_OOO = 0, 1, 2, 3

# packed packet-field lanes of SimState.q_pkt (one scatter/gather moves a
# whole packet record instead of five scalar planes)
PKT_FLOW, PKT_PSN, PKT_EV, PKT_META, PKT_TSENT, PKT_FIELDS = 0, 1, 2, 3, 4, 5
# packed control-event lanes of SimState.ev_buf
EVF_TYPE, EVF_FLOW, EVF_PSN, EVF_VAL, EVF_ECN, EVF_TSENT, EVF_FIELDS = \
    0, 1, 2, 3, 4, 5, 6

DEFAULT_SEED = 0x5EED


@dataclass(frozen=True)
class SimParams:
    """Static simulation parameters (hashable; closed over by jit)."""

    ticks: int = 2000
    queue_capacity: int = 64
    ecn_threshold: int = 12
    trimming: bool = True
    mode: TransportMode = TransportMode.RUD
    lb: LBScheme = LBScheme.OBLIVIOUS
    #: queue ids whose link is DOWN: packets routed into them are silently
    #: dropped (Configuration drops, Sec. 3.2.4) — the failure-mitigation
    #: scenario for REPS (dead-path EVs never return and leave circulation).
    #: Converted to a *traced* per-queue mask before the run, so sweeping
    #: failure sets (serially or via simulate_batch) never recompiles.
    failed_queues: tuple = ()
    nscc: bool = True
    rccc: bool = False
    dfc: bool = False
    ack_return_ticks: int = 4
    mp_range: int = 512           # receiver tracking window (PSNs)
    ev_slots: int = 16            # K for RR/REPS/EVBITMAP
    timeout_ticks: int = 256
    ooo_threshold: int = 0        # 0 = disabled
    max_cwnd: float = 48.0        # ~BDP in packets (optimistic start)
    base_rtt: float = 10.0        # unloaded RTT in ticks, for NSCC


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class Workload:
    """Flow set: src/dst host ids, message size (packets), start tick.

    All fields are traced arrays — a Workload can carry a leading scenario
    axis ([B, F]) for ``simulate_batch``; build one with ``Workload.stack``.
    """

    src: jax.Array   # [F] int32
    dst: jax.Array   # [F] int32
    size: jax.Array  # [F] int32
    start: jax.Array  # [F] int32

    @staticmethod
    def of(src, dst, size, start=None) -> "Workload":
        src = jnp.asarray(src, jnp.int32)
        f = src.shape[0]
        return Workload(
            src=src, dst=jnp.asarray(dst, jnp.int32),
            size=jnp.asarray(size, jnp.int32) * jnp.ones((f,), jnp.int32),
            start=(jnp.zeros((f,), jnp.int32) if start is None
                   else jnp.asarray(start, jnp.int32)),
        )

    @staticmethod
    def stack(wls: "list[Workload] | tuple[Workload, ...]") -> "Workload":
        """Stack same-F workloads along a leading scenario axis ([B, F])."""
        f = {int(w.src.shape[-1]) for w in wls}
        if len(f) != 1:
            raise ValueError(f"scenario batch needs a uniform flow count, "
                             f"got {sorted(f)}")
        return Workload(
            src=jnp.stack([w.src for w in wls]),
            dst=jnp.stack([w.dst for w in wls]),
            size=jnp.stack([w.size for w in wls]),
            start=jnp.stack([w.start for w in wls]),
        )


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class SimState:
    """The lax.scan carry: the entire fabric + protocol state."""

    # queues (ring buffers; packet records packed along the last axis so
    # one enqueue scatter / dequeue gather moves whole packets)
    q_pkt: jax.Array    # [Q, C, PKT_FIELDS] int32 (flow = -1 => empty)
    q_head: jax.Array   # [Q] int32
    q_len: jax.Array    # [Q] int32
    # sender state
    next_psn: jax.Array     # [F] int32
    inflight: jax.Array     # [F] int32
    src_track: pds.PSNTracker  # ACK tracking at the source (base = CACK)
    rtx: jax.Array          # [F, W] uint32 retransmit bitmap (rel. to base)
    last_progress: jax.Array  # [F] int32
    slot_last_ack: jax.Array  # [F, K] int32, EV-based loss detection
    # receiver state
    dst_track: pds.PSNTracker
    last_ooo_nack: jax.Array  # [F] int32
    # congestion control + LB
    nscc: nscc_mod.NSCCState
    rccc: RCCCState
    lb: LBState
    # control-TC delay ring (packed: type/flow/psn/ev/ecn/tsent lanes)
    ev_buf: jax.Array   # [D, E, EVF_FIELDS] int32
    # stats
    delivered: jax.Array  # [F] int32 packets delivered (first copies)
    trims: jax.Array      # [] int32
    drops: jax.Array      # [] int32
    dups: jax.Array       # [] int32
    retransmits: jax.Array  # [] int32


def _first_set_bit(ring: jax.Array) -> jax.Array:
    """Per-row index of the lowest set bit of a [N, W] uint32 ring, or -1."""
    nz = ring != 0
    has = nz.any(axis=1)
    W = ring.shape[1]
    first_w = jnp.argmax(nz, axis=1)
    w = ring[jnp.arange(ring.shape[0]), first_w]
    lsb = w & (jnp.uint32(0) - w)
    ctz = pds._popcount32(lsb - jnp.uint32(1))
    return jnp.where(has, first_w * 32 + ctz, -1).astype(jnp.int32)


def _bit_plane(off: jax.Array, valid: jax.Array, w: int) -> jax.Array:
    """[F, W] uint32 plane with row i's bit `off[i]` set (elementwise —
    the dense replacement for a one-lane-per-row bit scatter)."""
    o = jnp.clip(off, 0, w * 32 - 1)
    wordsel = jnp.arange(w)[None, :] == (o // 32)[:, None]
    bit = (jnp.uint32(1) << (o % 32).astype(jnp.uint32))[:, None]
    ok = valid & (off >= 0) & (off < w * 32)
    return jnp.where(ok[:, None] & wordsel, bit, jnp.uint32(0))


def _set_own_bit(ring: jax.Array, off: jax.Array,
                 valid: jax.Array) -> jax.Array:
    """Row i sets bit off[i] — elementwise, no scatter."""
    return ring | _bit_plane(off, valid, ring.shape[1])


def _clear_own_bit(ring: jax.Array, off: jax.Array,
                   valid: jax.Array) -> jax.Array:
    """Row i clears bit off[i] — elementwise, no scatter."""
    return ring & ~_bit_plane(off, valid, ring.shape[1])


def _pick(hot: jax.Array, vals: jax.Array) -> jax.Array:
    """Per-row value from <= 1 active lane: hot [R, L] bool, vals [L]."""
    return jnp.sum(jnp.where(hot, vals[None, :], 0), axis=1)


def _own_word(ring: jax.Array, off: jax.Array) -> jax.Array:
    """Row i's ring word containing bit offset off[i] (clipped)."""
    w = ring.shape[1]
    word = jnp.clip(off, 0, w * 32 - 1) // 32
    return jnp.take_along_axis(ring, word[:, None], axis=1)[:, 0]


def init_state(g: QueueGraph, wl: Workload, p: SimParams,
               seed: "int | jax.Array" = DEFAULT_SEED) -> SimState:
    Q, C = g.num_queues, p.queue_capacity
    F = wl.src.shape[0]
    D = p.ack_return_ticks + 1
    E = 2 * Q + 2 * F
    W = p.mp_range // 32
    nparams = nscc_mod.NSCCParams(base_rtt=p.base_rtt, max_cwnd=p.max_cwnd)
    q_pkt = jnp.zeros((Q, C, PKT_FIELDS), jnp.int32).at[:, :, PKT_FLOW].set(-1)
    return SimState(
        q_pkt=q_pkt,
        q_head=jnp.zeros((Q,), jnp.int32),
        q_len=jnp.zeros((Q,), jnp.int32),
        next_psn=jnp.zeros((F,), jnp.int32),
        inflight=jnp.zeros((F,), jnp.int32),
        src_track=pds.PSNTracker.create(F, p.mp_range),
        rtx=jnp.zeros((F, W), jnp.uint32),
        last_progress=jnp.zeros((F,), jnp.int32),
        slot_last_ack=jnp.full((F, p.ev_slots), -1, jnp.int32),
        dst_track=pds.PSNTracker.create(F, p.mp_range),
        last_ooo_nack=jnp.full((F,), -10**6, jnp.int32),
        nscc=nscc_mod.NSCCState.create(F, nparams),
        rccc=RCCCState.create(F, p.max_cwnd),
        lb=LBState.create(F, p.ev_slots, seed),
        ev_buf=jnp.zeros((D, E, EVF_FIELDS), jnp.int32),
        delivered=jnp.zeros((F,), jnp.int32),
        trims=jnp.int32(0), drops=jnp.int32(0), dups=jnp.int32(0),
        retransmits=jnp.int32(0),
    )


def _rank_within(target: jax.Array, valid: jax.Array,
                 base: jax.Array) -> tuple[jax.Array, jax.Array]:
    """For candidate lanes with target queue ids, compute each lane's
    arrival rank within its target and the resulting queue position.

    Segment-count scheme: rank[i] = #{j < i : target[j] == target[i] and
    valid[j]} via a masked pairwise count — a few fused vector passes
    instead of the per-tick stable argsort the seed used (XLA sorts are
    slow on CPU and batch poorly under vmap).

    Returns (pos, rank) where pos[i] = base[target[i]] + rank.
    """
    n = target.shape[0]
    t = jnp.where(valid, target, -1)
    lane = jnp.arange(n)
    same = (t[None, :] == t[:, None]) & valid[None, :] \
        & (lane[None, :] < lane[:, None])
    rank = same.sum(axis=1, dtype=jnp.int32)
    pos = base[jnp.where(valid, target, 0)] + rank
    return pos, rank


def make_step(g: QueueGraph, p: SimParams, F: int):
    """Build the per-tick transition function.

    The returned ``step(s, tick, wl, dead)`` takes the workload and the
    per-queue failure mask as *traced* arguments so one compiled step
    serves every scenario of a sweep (and vmaps over a scenario axis).
    """
    rt = RoutingTables(g)
    Q = g.num_queues
    C = p.queue_capacity
    D = p.ack_return_ticks + 1
    E = 2 * Q + 2 * F
    H = g.num_hosts
    K = p.ev_slots
    mp = p.mp_range
    W = mp // 32
    flow_ids = jnp.arange(F)
    nparams = nscc_mod.NSCCParams(base_rtt=p.base_rtt, max_cwnd=p.max_cwnd)
    lb_scheme = LBScheme.STATIC if p.mode == TransportMode.ROD else p.lb
    is_rod = p.mode == TransportMode.ROD
    is_rudi = p.mode == TransportMode.RUDI

    def step(s: SimState, tick: jax.Array, wl: Workload, dead: jax.Array):
        flow_src = wl.src
        flow_dst = wl.dst
        slot = tick % D

        # ------------------------------------------------ 1. control events
        evs = s.ev_buf[slot]                                  # [E, 6]
        et = evs[:, EVF_TYPE]
        ef = evs[:, EVF_FLOW]
        ep = evs[:, EVF_PSN]
        ee = evs[:, EVF_VAL]
        ec = evs[:, EVF_ECN]
        ets = evs[:, EVF_TSENT]
        is_ack = et == EV_ACK
        is_nack = (et == EV_NACK) | (et == EV_OOO)

        # Per-flow densification of the ACK lanes: a flow's ACKs all come
        # from its destination's single host downlink, so at most ONE ACK
        # lane per flow is active per tick. That turns every ACK-driven
        # update (SACK record, CC, LB, progress) into elementwise [F] or
        # [F, W] work — one [F, E] one-hot is the only lane-wide pass.
        # (NACK lanes stay lane-wise: several trims can hit one flow.)
        hot_ack = (ef[None, :] == flow_ids[:, None]) & is_ack[None, :]
        hot_nack = (ef[None, :] == flow_ids[:, None]) & is_nack[None, :]
        has_ack = hot_ack.any(axis=1)
        nack_count = hot_nack.sum(axis=1, dtype=jnp.int32)
        ack_psn = _pick(hot_ack, ep)

        # ACKs: record at source, advance CACK, shift the rtx ring in
        # lockstep — the fused SACK hot path (kernels/sack_fused.py).
        ack_off0 = (ack_psn.astype(jnp.uint32)
                    - s.src_track.base).astype(jnp.int32)
        ack_in_range = has_ack & (ack_off0 >= 0) & (ack_off0 < mp)
        ack_bit = jnp.uint32(1) << (ack_off0 % 32).astype(jnp.uint32)
        ack_already = ack_in_range & (
            (_own_word(s.src_track.ring, ack_off0) & ack_bit) != 0)
        ack_mask = _bit_plane(ack_off0, ack_in_range, W)
        src_ring, src_base, rtx, adv = kops.sack_fused(
            s.src_track.ring, s.src_track.base, s.rtx, ack_mask)
        one = jnp.uint32(1)
        src_track = pds.PSNTracker(
            base=src_base, ring=src_ring,
            rx_ok=s.src_track.rx_ok + jnp.where(
                ack_in_range & ~ack_already, one, 0),
            dup=s.src_track.dup + jnp.where(ack_already, one, 0),
            oor=s.src_track.oor + jnp.where(
                has_ack & ~ack_in_range, one, 0),
        )

        # retire inflight, CC + LB feedback
        retire = has_ack.astype(jnp.int32) + nack_count
        inflight = jnp.maximum(s.inflight - retire, 0)
        ack_ecn = _pick(hot_ack, ec).astype(jnp.bool_)
        rtt = (tick - _pick(hot_ack, ets)).astype(jnp.float32)
        nst = s.nscc
        if p.nscc:
            nst = nscc_mod.on_ack_per_flow(nst, nparams, ack_ecn, rtt,
                                           has_ack)
            nst = nscc_mod.on_loss_per_flow(nst, nack_count)
        if lb_scheme == LBScheme.REPS:
            # recycle EVs that came back on clean (un-marked) ACKs
            hot_clean = hot_ack & (ec[None, :] == 0)
            lbs = lb_schemes.reps_recycle(
                s.lb, _pick(hot_clean, ee), hot_clean.any(axis=1))
        elif lb_scheme == LBScheme.EVBITMAP:
            lbs = lb_on_ack(s.lb, lb_scheme, ef, ee,
                            ec.astype(jnp.bool_) | is_nack, is_ack | is_nack)
        else:
            lbs = s.lb  # STATIC / OBLIVIOUS / RR take no path feedback

        # progress clock: any ACK freshens the flow
        last_progress = jnp.where(has_ack, tick, s.last_progress)

        # ACK'd PSNs can't be pending retransmit anymore (rtx was already
        # shifted by the fused op, so offsets are relative to the new base)
        ack_off = ack_psn - src_track.base.astype(jnp.int32)
        rtx = _clear_own_bit(rtx, ack_off, has_ack)

        # NACKs (trim / OOO): mark PSN for selective retransmit (RUD);
        # ROD does go-back-N instead (handled at injection via next_psn).
        # Several NACKs may hit one flow, so this stays lane-wise — but
        # as a dense bitwise-OR fold over the NACK-capable lanes (ACK
        # lanes [0, Q) never carry NACKs), not a scatter: OR is naturally
        # duplicate-safe, so no dedup or already-set pass is needed.
        nf, nep = ef[Q:], ep[Q:]
        n_nack = is_nack[Q:]
        nack_off = nep - src_track.base[jnp.where(n_nack, nf, 0)].astype(jnp.int32)
        if not is_rod:
            n_ok = n_nack & (nack_off >= 0) & (nack_off < mp)
            no = jnp.clip(nack_off, 0, mp - 1)
            nbit = jnp.where(n_ok, jnp.uint32(1) << (no % 32).astype(jnp.uint32),
                             jnp.uint32(0))
            hot_n = (nf[None, :] == flow_ids[:, None]) & n_ok[None, :]
            contrib = jnp.where(
                hot_n[:, None, :]
                & ((no // 32)[None, None, :] == jnp.arange(W)[None, :, None]),
                nbit[None, None, :], jnp.uint32(0))       # [F, W, E-Q]
            rtx = rtx | jax.lax.reduce(contrib, jnp.uint32(0),
                                       jax.lax.bitwise_or, (2,))
        rod_gbn = hot_nack.any(axis=1)

        # EV-based loss detection (Sec. 3.2.4), RR_SLOTS layout:
        # slot i carries PSNs i, i+K, i+2K...; an ACK for PSN x implies
        # every unacked PSN x-K, x-2K... in the same slot was lost.
        slot_last_ack = s.slot_last_ack
        if p.lb == LBScheme.RR_SLOTS and not is_rod:
            sl = ack_psn % K
            prev = jnp.take_along_axis(slot_last_ack, sl[:, None],
                                       axis=1)[:, 0]
            # mark up to 2 predecessors (losses per ACK are almost always <=1)
            for back in (1, 2):
                miss = ack_psn - back * K
                off = miss - src_track.base.astype(jnp.int32)
                # skip PSNs already SACKed at the source (not actually lost)
                w_i = jnp.clip(off, 0, rtx.shape[1] * 32 - 1)
                sacked = (_own_word(src_track.ring, off)
                          >> (w_i % 32).astype(jnp.uint32)) & jnp.uint32(1)
                lost = has_ack & (miss > prev) & (miss >= 0) & (sacked == 0)
                rtx = _set_own_bit(rtx, off, lost)
            hot_sl = (jnp.arange(K)[None, :] == sl[:, None]) & has_ack[:, None]
            slot_last_ack = jnp.where(
                hot_sl, jnp.maximum(slot_last_ack, ack_psn[:, None]),
                slot_last_ack)

        # consume the slot (a whole-record clear is one dynamic-update-
        # slice; stale non-type lanes were masked by type==NONE anyway)
        ev_buf = s.ev_buf.at[slot].set(jnp.zeros((E, EVF_FIELDS), jnp.int32))

        # ------------------------------------------- 2. RCCC receiver grants
        done = src_track.base.astype(jnp.int32) >= wl.size
        rcc = s.rccc
        if p.rccc:
            active = ~done & (tick >= wl.start)
            rcc = grant_credits(rcc, flow_dst, active, H)

        # --------------------------------------------------- 3. injection
        has_rtx = (rtx != 0).any(axis=1) if not is_rod else jnp.zeros((F,), jnp.bool_)
        # ROD go-back-N: on NACK or timeout, rewind next_psn to base
        next_psn = s.next_psn
        if is_rod:
            timeout_rod = (inflight > 0) & (tick - last_progress > p.timeout_ticks)
            rewind = rod_gbn | timeout_rod
            next_psn = jnp.where(rewind, src_track.base.astype(jnp.int32), next_psn)
            inflight = jnp.where(rewind, 0, inflight)
            last_progress = jnp.where(rewind, tick, last_progress)

        window = jnp.floor(nst.cwnd).astype(jnp.int32) if p.nscc \
            else jnp.full((F,), int(p.max_cwnd), jnp.int32)
        win_ok = inflight < window
        if p.rccc:
            win_ok = win_ok & (rcc.balance >= 1.0)
        mp_ok = (next_psn - src_track.base.astype(jnp.int32)) < p.mp_range
        can_new = (next_psn < wl.size) & mp_ok
        eligible = (tick >= wl.start) & ~done & win_ok & (has_rtx | can_new)

        # fair per-host pick: per-tick pseudo-random rotation, flow id in
        # the low bits so exactly one winner exists per host
        from repro.core.lb.schemes import _mix32
        rot = (_mix32(jnp.arange(F, dtype=jnp.uint32) * jnp.uint32(2654435761)
                      ^ tick.astype(jnp.uint32)) >> 16).astype(jnp.int32)
        key = rot * F + jnp.arange(F)
        key = jnp.where(eligible, key, jnp.int32(2 ** 30))
        hot_host = flow_src[None, :] == jnp.arange(H)[:, None]   # [H, F]
        host_min = jnp.min(jnp.where(hot_host, key[None, :], 2 ** 30), axis=1)
        injected = eligible & (key == host_min[flow_src]) & (key < 2 ** 30)

        rtx_off = _first_set_bit(rtx)
        rtx_psn = src_track.base.astype(jnp.int32) + rtx_off
        use_rtx = injected & has_rtx & (rtx_off >= 0)
        psn_out = jnp.where(use_rtx, rtx_psn, next_psn)
        rtx = _clear_own_bit(rtx, rtx_off, use_rtx)
        next_psn = jnp.where(injected & ~use_rtx, next_psn + 1, next_psn)

        lbs2, ev_sel = select_ev(lbs, lb_scheme, psn_out.astype(jnp.uint32), tick)
        lbs = jax.tree_util.tree_map(
            lambda a, b: jnp.where(
                injected.reshape((-1,) + (1,) * (a.ndim - 1)), b, a),
            lbs, lbs2)
        inj_q = rt.injection_queue(flow_src, flow_dst, ev_sel)
        inflight = inflight + injected.astype(jnp.int32)
        if p.rccc:
            rcc = replace(rcc, balance=rcc.balance - injected.astype(jnp.float32))
        retransmits = s.retransmits + use_rtx.sum(dtype=jnp.int32)

        # ------------------------------------------------- 4. forwarding
        qidx = jnp.arange(Q)
        nonempty = s.q_len > 0
        hpos = s.q_head
        head_pkt = jnp.take_along_axis(
            s.q_pkt, hpos[:, None, None], axis=1)[:, 0]        # [Q, 5]
        pf = head_pkt[:, PKT_FLOW]
        pp = head_pkt[:, PKT_PSN]
        pe = head_pkt[:, PKT_EV]
        pm = head_pkt[:, PKT_META]
        pt = head_pkt[:, PKT_TSENT]
        # egress ECN marking: queue length at departure above threshold
        mark = nonempty & (s.q_len > p.ecn_threshold)
        pm = jnp.where(mark, pm | META_ECN, pm)
        q_head = jnp.where(nonempty, (s.q_head + 1) % C, s.q_head)
        q_len = jnp.where(nonempty, s.q_len - 1, s.q_len)

        safe_pf = jnp.where(nonempty, pf, 0)
        nq = rt.route_step(qidx, flow_src[safe_pf], flow_dst[safe_pf], pe)
        deliver = nonempty & (nq == DELIVERED)
        forward = nonempty & (nq >= 0)

        # --------------------------------------------- 5. delivery at FEPs
        dtrim = deliver & ((pm & META_TRIMMED) != 0)
        ddata = deliver & ~dtrim
        # one host downlink per destination => at most one delivery per
        # flow per tick: densify the [Q] delivery lanes to per-flow [F]
        # values and the whole receive path goes elementwise (no scatter)
        hot_d = (pf[None, :] == flow_ids[:, None]) & ddata[None, :]  # [F, Q]
        has_d = hot_d.any(axis=1)
        d_psn = _pick(hot_d, pp)
        d_off = (d_psn.astype(jnp.uint32)
                 - s.dst_track.base).astype(jnp.int32)
        d_in_range = has_d & (d_off >= 0) & (d_off < mp)
        d_bit = jnp.uint32(1) << (d_off % 32).astype(jnp.uint32)
        d_already = d_in_range & (
            (_own_word(s.dst_track.ring, d_off) & d_bit) != 0)
        fresh_f = d_in_range & ~d_already
        d_ring = s.dst_track.ring | _bit_plane(d_off, d_in_range, W)
        d_ring, d_base, _ = kops.sack_advance(d_ring, s.dst_track.base)
        dst_track = pds.PSNTracker(
            base=d_base, ring=d_ring,
            rx_ok=s.dst_track.rx_ok + jnp.where(fresh_f, one, 0),
            dup=s.dst_track.dup + jnp.where(d_already, one, 0),
            oor=s.dst_track.oor + jnp.where(has_d & ~d_in_range, one, 0),
        )
        dups = s.dups + (has_d & ~fresh_f).sum(dtype=jnp.int32)
        delivered_ctr = s.delivered + fresh_f.astype(jnp.int32)
        if is_rudi:
            # idempotent ops: re-applied duplicates also count as delivered
            delivered_ctr = delivered_ctr  # (payload applied; stats keep first-copy)
        if p.rccc:
            hot_seen = (pf[None, :] == flow_ids[:, None]) & deliver[None, :]
            rcc = replace(rcc, seen=rcc.seen | hot_seen.any(axis=1))

        # ------------------------------------- 6. OOO-count loss inference
        ooo_fire = jnp.zeros((F,), jnp.bool_)
        if p.ooo_threshold > 0:
            dist = pds.ooo_distance(dst_track)
            due = (dist > p.ooo_threshold) & (
                tick - s.last_ooo_nack > jnp.int32(p.base_rtt))
            ooo_fire = due
        last_ooo_nack = jnp.where(ooo_fire, tick, s.last_ooo_nack)

        # ------------------------------------------------- 7. enqueue phase
        # candidates: forwarded packets (Q lanes) + injections (F lanes)
        cand_q = jnp.concatenate([jnp.where(forward, nq, -1),
                                  jnp.where(injected, inj_q, -1)])
        cand_flow = jnp.concatenate([pf, jnp.arange(F)])
        cand_psn = jnp.concatenate([pp, psn_out])
        cand_ev = jnp.concatenate([pe, ev_sel])
        cand_meta = jnp.concatenate([pm, jnp.zeros((F,), jnp.int32)])
        cand_ts = jnp.concatenate([pt, jnp.full((F,), 1, jnp.int32) * tick])
        cvalid = cand_q >= 0
        # failed links (traced mask): packets routed into them vanish
        is_dead = dead[jnp.where(cvalid, cand_q, 0)] & cvalid
        cvalid = cvalid & ~is_dead
        pos, _ = _rank_within(cand_q, cvalid, q_len)
        fits = cvalid & (pos < C)
        overflow = cvalid & ~fits

        wslot = (q_head[jnp.where(cvalid, cand_q, 0)] + pos) % C
        tq = jnp.where(fits, cand_q, Q)
        cand_pkt = jnp.stack(
            [cand_flow, cand_psn, cand_ev, cand_meta, cand_ts], axis=-1)
        q_pkt = s.q_pkt.at[tq, wslot].set(cand_pkt, mode="drop")
        hot_enq = (cand_q[None, :] == qidx[:, None]) & fits[None, :]  # [Q, n]
        added = hot_enq.sum(axis=1, dtype=jnp.int32)
        q_len = q_len + added

        # overflow: trim (fast NACK via control TC) or drop
        if p.trimming:
            trims = s.trims + overflow.sum(dtype=jnp.int32)
            drops = s.drops
            nack_mask = overflow
        else:
            trims = s.trims
            drops = s.drops + overflow.sum(dtype=jnp.int32)
            nack_mask = jnp.zeros_like(overflow)
        # failed links drop silently: no trim header, no NACK — only
        # timeout / EV-based inference recovers (Sec. 3.2.4 config drops)
        drops = drops + is_dead.sum(dtype=jnp.int32)

        # ------------------------------------------- 8. schedule control TC
        out_slot = (tick + p.ack_return_ticks) % D
        # lanes [0, Q): ACKs from deliveries
        ack_lane_t = jnp.where(ddata, EV_ACK, EV_NONE)
        # lanes [Q, Q + (Q+F)): trim NACKs from enqueue overflow
        nack_lane_t = jnp.where(nack_mask, EV_NACK, EV_NONE)
        # lanes [2Q+F, 2Q+2F): OOO NACKs (psn = receiver base = first gap)
        ooo_lane_t = jnp.where(ooo_fire, EV_OOO, EV_NONE)
        new_type = jnp.concatenate([ack_lane_t, nack_lane_t, ooo_lane_t])
        new_flow = jnp.concatenate([safe_pf, cand_flow, jnp.arange(F)])
        new_psn = jnp.concatenate(
            [pp, cand_psn, dst_track.base.astype(jnp.int32)])
        new_val = jnp.concatenate([pe, cand_ev, jnp.zeros((F,), jnp.int32)])
        new_ecn = jnp.concatenate(
            [((pm & META_ECN) != 0).astype(jnp.int32),
             jnp.zeros((Q + F,), jnp.int32), jnp.zeros((F,), jnp.int32)])
        new_ts = jnp.concatenate([pt, cand_ts, jnp.zeros((F,), jnp.int32)])
        ev_buf = ev_buf.at[out_slot].set(jnp.stack(
            [new_type, new_flow, new_psn, new_val, new_ecn, new_ts],
            axis=-1))

        # ------------------------------------------------- 9. timeouts + QA
        if not is_rod:
            stalled = (inflight > 0) & (tick - last_progress > p.timeout_ticks) \
                & ~done
            rtx = _set_own_bit(rtx, jnp.zeros((F,), jnp.int32),
                               stalled)  # offset 0 == oldest unacked PSN
            # a timeout implies the outstanding packets are gone (dropped
            # without trim); reset the inflight estimate so the window
            # reopens — otherwise non-trimmed drops leak inflight forever.
            inflight = jnp.where(stalled, 0, inflight)
            last_progress = jnp.where(stalled, tick, last_progress)
            if p.nscc:
                nst = nscc_mod.on_loss_per_flow(nst, stalled.astype(jnp.int32))
        if p.nscc:
            nst = nscc_mod.quick_adapt(nst, nparams, tick)

        ns = SimState(
            q_pkt=q_pkt, q_head=q_head, q_len=q_len,
            next_psn=next_psn, inflight=inflight, src_track=src_track,
            rtx=rtx, last_progress=last_progress, slot_last_ack=slot_last_ack,
            dst_track=dst_track, last_ooo_nack=last_ooo_nack,
            nscc=nst, rccc=rcc, lb=lbs,
            ev_buf=ev_buf,
            delivered=delivered_ctr, trims=trims, drops=drops, dups=dups,
            retransmits=retransmits,
        )
        out = {
            "delivered": fresh_f.astype(jnp.int32),
            "cwnd": nst.cwnd,
            "qlen_max": q_len.max(),
        }
        return ns, out

    return step


@dataclass(frozen=True)
class SimResult:
    state: SimState
    delivered_per_tick: np.ndarray  # [T, F]
    cwnd_per_tick: np.ndarray       # [T, F]
    qlen_max: np.ndarray            # [T]

    def completion_tick(self) -> np.ndarray:
        """First tick by which each flow's full message was delivered."""
        cum = self.delivered_per_tick.cumsum(axis=0)
        size = cum[-1]
        reached = cum >= size[None, :]
        return np.where(reached.any(0), reached.argmax(axis=0), -1)

    def goodput(self, window: tuple[int, int] | None = None) -> np.ndarray:
        """Per-flow delivered packets / tick over a window (fraction of
        line rate, since line rate == 1 packet/tick)."""
        d = self.delivered_per_tick
        if window is not None:
            d = d[window[0]:window[1]]
        return d.mean(axis=0)


# --------------------------------------------------------------------------
# scenario engine: compiled-run cache + single and batched entry points
# --------------------------------------------------------------------------

#: compiled scan cache. Keyed on (topology identity, params minus the
#: failure set, flow count, batch mode): workloads, seeds and failure
#: masks are traced, so scenario sweeps reuse one executable. `id(g)` is
#: part of the key because the compiled step bakes in g's wiring tables
#: — two graphs sharing a name must not share an executable. (The cached
#: closure keeps `g` alive via its RoutingTables, so a live entry's id
#: can't be recycled by a different graph.)
_RUN_CACHE: dict = {}


def _cache_key(g: QueueGraph, p: SimParams, F: int, batched: bool):
    return (id(g), g.name, replace(p, failed_queues=()), F, batched)


def _get_fns(g: QueueGraph, p: SimParams, F: int, batched: bool):
    """(jitted init, jitted scan) pair. The scan donates the carry (`s0`
    buffers are reused in place); init is compiled so scenario setup
    costs microseconds, not eager-dispatch milliseconds."""
    key = _cache_key(g, p, F, batched)
    fns = _RUN_CACHE.get(key)
    if fns is None:
        step = make_step(g, p, F)

        def init_one(wl, seed):
            return init_state(g, wl, p, seed)

        def scan_one(s0, wl, dead):
            def body(s, tick):
                return step(s, tick, wl, dead)
            return jax.lax.scan(body, s0, jnp.arange(p.ticks, dtype=jnp.int32))

        if batched:
            init_one, scan_one = jax.vmap(init_one), jax.vmap(scan_one)
        fns = (jax.jit(init_one), jax.jit(scan_one, donate_argnums=(0,)))
        _RUN_CACHE[key] = fns
    return fns


def _dead_mask(g: QueueGraph, p: SimParams) -> np.ndarray:
    dead = np.zeros((g.num_queues,), bool)
    for fq in p.failed_queues:
        dead[fq] = True
    return dead


def _to_result(final: SimState, outs: dict) -> SimResult:
    return SimResult(
        state=jax.device_get(final),
        delivered_per_tick=np.asarray(outs["delivered"]),
        cwnd_per_tick=np.asarray(outs["cwnd"]),
        qlen_max=np.asarray(outs["qlen_max"]),
    )


def simulate(g: QueueGraph, wl: Workload, p: SimParams,
             seed: int = DEFAULT_SEED) -> SimResult:
    """Run the fabric for p.ticks; returns dense per-tick stats."""
    F = int(wl.src.shape[0])
    init, run = _get_fns(g, p, F, batched=False)
    s0 = init(wl, jnp.uint32(seed))
    final, outs = run(s0, wl, jnp.asarray(_dead_mask(g, p)))
    return _to_result(final, outs)


def simulate_batch(g: QueueGraph, wls: Workload, p: SimParams,
                   failed: "np.ndarray | None" = None,
                   seeds: "np.ndarray | None" = None) -> list[SimResult]:
    """Run B scenarios in one compiled, vmapped scan.

    wls:    Workload with a leading scenario axis ([B, F]); build with
            ``Workload.stack`` or pass a list of same-F Workloads.
    failed: optional [B, Q] bool — per-scenario failed-queue masks
            (default: every scenario uses p.failed_queues).
    seeds:  optional [B] — per-scenario LB/EV seeds (default: the same
            DEFAULT_SEED every ``simulate`` call uses).

    Returns one SimResult per scenario, bitwise identical to the
    corresponding serial ``simulate`` call: the tick function is the same
    compiled code, vmapped over the scenario axis with the carry donated.
    """
    if isinstance(wls, (list, tuple)):
        wls = Workload.stack(wls)
    B, F = wls.src.shape
    init, run = _get_fns(g, p, F, batched=True)
    if seeds is None:
        seeds = np.full((B,), DEFAULT_SEED, np.uint32)
    seeds = jnp.asarray(seeds, jnp.uint32)
    if failed is None:
        failed = np.broadcast_to(_dead_mask(g, p), (B, g.num_queues))
    dead = jnp.asarray(failed, bool)
    if dead.shape != (B, g.num_queues):
        raise ValueError(f"failed mask must be [B={B}, Q={g.num_queues}], "
                         f"got {dead.shape}")
    s0 = init(wls, seeds)
    final, outs = run(s0, wls, dead)
    final = jax.device_get(final)
    outs = jax.device_get(outs)
    return [
        SimResult(
            state=jax.tree_util.tree_map(lambda a: a[b], final),
            delivered_per_tick=np.asarray(outs["delivered"][b]),
            cwnd_per_tick=np.asarray(outs["cwnd"][b]),
            qlen_max=np.asarray(outs["qlen_max"][b]),
        )
        for b in range(B)
    ]
