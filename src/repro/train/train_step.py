"""Training step: chunked cross-entropy loss, remat'd backward, AdamW.

The LM-head matmul + softmax is the largest single activation in the
graph (logits [B, S, V] — 0.5 TB global for the 256k-vocab archs), so the
loss is computed in sequence chunks under jax.checkpoint: logits for each
chunk are materialized, reduced to a scalar, and recomputed on the
backward pass. This bounds loss memory to [B, chunk, V] regardless of S.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.train.optimizer import AdamWConfig, apply_updates

LOSS_CHUNK = 512
MOE_AUX_WEIGHT = 0.01


def chunked_ce_loss(hidden: jax.Array, head: jax.Array,
                    labels: jax.Array, chunk: int = LOSS_CHUNK):
    """Mean cross-entropy over [B, S] without materializing [B, S, V].

    SPMD-friendly formulation: the label pick is a one-hot contraction
    (works when V is sharded over "model"); logsumexp reduces over the
    sharded vocab axis with one small all-reduce per chunk.
    """
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    n = S // chunk
    hs = hidden.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one(h, l):
        logits = (h @ head).astype(jnp.float32)          # [B, c, V]
        lse = jax.nn.logsumexp(logits, axis=-1)           # [B, c]
        V = logits.shape[-1]
        onehot = jax.nn.one_hot(l, V, dtype=jnp.float32)
        true_logit = jnp.einsum("bcv,bcv->bc", logits, onehot)
        return jnp.sum(lse - true_logit)

    def body(acc, xs):
        h, l = xs
        return acc + one(h, l), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (B * S)


def make_train_step(cfg: ArchConfig, mesh=None, dp_axes=("data",),
                    opt_cfg: AdamWConfig = AdamWConfig(),
                    act_spec=None, moe_token_spec=None,
                    scan_layers: bool = True, attn_head_specs=None,
                    loss_spec=None, microbatches: int = 1,
                    remat_policy: str = "nothing"):
    """Build train_step(params, opt_state, batch) -> (params', opt', metrics).

    batch: {"inputs": [B, S] int32 (or [B, S, D] embeds for VLM stubs),
            "labels": [B, S] int32}
    """
    fwd = lm.build_forward(cfg, mesh=mesh, dp_axes=dp_axes, remat=True,
                           act_spec=act_spec, output="hidden",
                           moe_token_spec=moe_token_spec,
                           scan_layers=scan_layers,
                           attn_head_specs=attn_head_specs,
                           remat_policy=remat_policy)

    def loss_fn(params, batch):
        hidden, aux, _ = fwd(params, batch["inputs"])
        if loss_spec is not None:
            # gather the sequence dim before the loss scan: the chunked
            # scan must iterate a replicated axis (S is sequence-sharded
            # over "model" inside the layer stack)
            hidden = jax.lax.with_sharding_constraint(hidden, loss_spec)
        ce = chunked_ce_loss(hidden, params["head"], batch["labels"])
        return ce + MOE_AUX_WEIGHT * aux, (ce, aux)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, (ce, aux)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            # gradient accumulation: Python-unrolled microbatches (a scan
            # body would be FLOP-counted once by XLA cost analysis); the
            # per-microbatch graph is identical, so compile time is
            # amortized by CSE while live activation memory shrinks by
            # the microbatch factor.
            B = batch["labels"].shape[0]
            mb = B // microbatches
            loss = ce = aux = jnp.zeros((), jnp.float32)
            grads = None
            for i in range(microbatches):
                sl = {k: v[i * mb:(i + 1) * mb] for k, v in batch.items()}
                (l, (c, a)), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, sl)
                loss += l / microbatches
                ce += c / microbatches
                aux += a / microbatches
                grads = g if grads is None else jax.tree_util.tree_map(
                    jnp.add, grads, g)
            grads = jax.tree_util.tree_map(
                lambda x: x / microbatches, grads)
        params, opt_state, gnorm = apply_updates(params, grads, opt_state,
                                                 opt_cfg)
        metrics = {"loss": loss, "ce": ce, "moe_aux": aux,
                   "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step
