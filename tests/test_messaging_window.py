"""Deferrable-send window pacing (Sec. 3.1.3) — both regimes.

Kept OUT of tests/test_uet_core.py on purpose: that module is gated on
`pytest.importorskip("hypothesis")` and silently skips in environments
without dev deps, which would erase the only coverage of the
window-paced stall this PR implemented (the seed multiplied the stall
term by 0.0, so the modeled claim was vacuous).
"""
import pytest

from repro.core import messaging


def test_deferrable_window_pacing_both_branches():
    """At/above BDP deferrable streams at line rate; below it every
    extra window pays the ack-wait stall."""
    link = messaging.LinkModel(alpha=1e-6, beta=2.5e-12)
    a, b = link.alpha, link.beta
    size = 1e6
    bdp = 2 * a / b  # 800 kB
    # branch 1: window >= BDP — full rate, no stall
    hi = messaging.deferrable_vs_rendezvous_bandwidth(
        size, link, eager_limit=16384.0, true_window=bdp)
    assert hi["deferrable"] == pytest.approx(size / (a + b * size))
    # branch 2: window < BDP — explicit stall formula, strictly slower
    w = bdp / 8
    lo = messaging.deferrable_vs_rendezvous_bandwidth(
        size, link, eager_limit=16384.0, true_window=w)
    t_expect = a + b * size + (size / w - 1.0) * (2 * a - b * w)
    assert lo["deferrable"] == pytest.approx(size / t_expect)
    assert lo["deferrable"] < hi["deferrable"]
    # monotone in the window below BDP
    mid = messaging.deferrable_vs_rendezvous_bandwidth(
        size, link, eager_limit=16384.0, true_window=bdp / 2)
    assert lo["deferrable"] < mid["deferrable"] <= hi["deferrable"]


def test_deferrable_beats_stale_rendezvous_when_window_tracks():
    """The paper's claim needs the window actually tracked: with a stale
    small eager limit, rendezvous pays the read round trip while
    deferrable at the true (>=BDP) window streams at line rate."""
    link = messaging.LinkModel(alpha=1e-6, beta=2.5e-12)
    out = messaging.deferrable_vs_rendezvous_bandwidth(
        size=1e6, link=link, eager_limit=16384.0, true_window=1e6)
    assert out["deferrable"] > out["rendezvous"]
