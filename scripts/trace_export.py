#!/usr/bin/env python
"""Export a fabric telemetry run as Chrome-trace/Perfetto JSON.

Runs the canonical flap-victim scenario (``workloads.victim_sweep`` with
3 of 4 leaf-0 uplinks flapping mid-run) with telemetry on and writes the
probe lanes as counter tracks — queue-occupancy EWMA, per-queue
mark/trim/drop rates, per-flow RTT and cwnd, inflight — loadable
directly in ``chrome://tracing`` or https://ui.perfetto.dev.

    PYTHONPATH=src python scripts/trace_export.py --out fabric_trace.json
    PYTHONPATH=src python scripts/trace_export.py --ticks 6000 \
        --probe-every 8 --slots 128

One tick renders as one microsecond in the viewer.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="fabric_trace.json",
                    help="output JSON path (default: fabric_trace.json)")
    ap.add_argument("--ticks", type=int, default=3000,
                    help="tick budget (default: 3000)")
    ap.add_argument("--probe-every", type=int, default=16,
                    help="base sampling cadence in ticks (default: 16)")
    ap.add_argument("--slots", type=int, default=64,
                    help="telemetry ring capacity (default: 64)")
    args = ap.parse_args(argv)

    from dataclasses import replace

    from repro.network.fabric import simulate
    from repro.network.telemetry import TelemetrySpec, flap_victim_scenario

    g, wl, prof, p, sched, _, (fail_at, heal_at) = flap_victim_scenario()
    p = replace(p, ticks=args.ticks)
    spec = TelemetrySpec.on(probe_every=args.probe_every, slots=args.slots)
    print(f"simulating {args.ticks} ticks (flap window [{fail_at}, "
          f"{heal_at}), probe_every={args.probe_every}, "
          f"slots={args.slots}) ...")
    r = simulate(g, wl, prof, p, faults=sched, telemetry=spec)
    tr = r.telemetry
    tr.save_chrome_trace(args.out)
    s = tr.summary()
    print(f"wrote {args.out}: {tr.num_samples} samples at "
          f"{tr.sample_spacing}-tick spacing, "
          f"{len(tr.to_chrome_trace())} counter events")
    print(f"summary: occ p50/p99 {s['occ_p50']:.1f}/{s['occ_p99']:.1f}, "
          f"marks {s['marks_total']}, trims {s['trims_total']}, "
          f"drops {s['drops_total']}, goodput {s.get('goodput', 0):.2f} "
          f"pkts/tick")
    print("open in chrome://tracing or https://ui.perfetto.dev")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
