"""Transport Security Sublayer — protocol logic and overhead model
(Sec. 3.4).

We reproduce TSS at the level of its *protocol rules*, not the cipher:
AES-GCM itself is out of scope (DESIGN.md records this adaptation), but
everything the spec actually innovates on is here:

* secure domains (SD) with a shared symmetric key and derived per-source
  keys (KDF modes: direct SDK / per-source / client-server);
* nonce discipline: the IV is (TSC epoch:16 | packet counter:48) XOR an
  IV mask; `iv_for_packet` guarantees members never collide because the
  source id is folded into the derived key, and packet counters are
  strictly monotone;
* key-lifetime enforcement: between 2^27 and 2^34.5 packets per key
  (Sec. 3.4.1) with association-number (AN) rotation;
* anti-replay PSN establishment (Sec. 3.4.2): both the 1-RTT random-PSN
  scheme and the zero-RTT start_psn/expected_psn scheme, including the
  close-time expected_psn ratchet; PDCs must close after 2^31 packets;
* trimmed packets must not trigger PDC creation (zero-trust rule for
  unauthenticated switch-modified packets).

State is SoA over domain members so a fleet of FEPs updates vectorized.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp

from repro.core.types import (TSS_KEY_LIFETIME_MAX, TSS_KEY_LIFETIME_MIN,
                              TSS_PDC_MAX_PACKETS)

TSC_EPOCH_BITS = 16
TSC_COUNTER_BITS = 48


def _mix(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x45D9F3B)
    x = (x ^ (x >> 16)) * jnp.uint32(0x45D9F3B)
    return x ^ (x >> 16)


def kdf(domain_key: jax.Array, *args: jax.Array) -> jax.Array:
    """Deterministic, non-invertible key derivation (stand-in for HKDF):
    domain key + arguments -> derived key (uint32 lanes)."""
    out = _mix(domain_key)
    for a in args:
        out = _mix(out ^ _mix(jnp.asarray(a)))
    return out


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class SecureDomain:
    """One SD: shared key, IV mask, epoch, per-member packet counters.

    members are indexed 0..N-1; `an` is the association number (key
    generation) — receivers keep both AN keys during rotation.
    """

    sdk: jax.Array          # [] uint32 domain key (model of the 256b key)
    iv_mask: jax.Array      # [] uint32
    epoch: jax.Array        # [] int32 TSC epoch (SDME-managed)
    an: jax.Array           # [] int32 association number
    pkt_counter: jax.Array  # [N] int64-ish (uint32 pair folded to f64-safe)
    key_packets: jax.Array  # [N] int32 packets under the current key

    @staticmethod
    def create(n_members: int, seed: int = 0xD0        ) -> "SecureDomain":
        return SecureDomain(
            sdk=jnp.uint32(seed * 2654435761 & 0xFFFFFFFF),
            iv_mask=_mix(jnp.uint32(seed + 1)),
            epoch=jnp.int32(0),
            an=jnp.int32(0),
            pkt_counter=jnp.zeros((n_members,), jnp.uint32),
            key_packets=jnp.zeros((n_members,), jnp.int32),
        )


def source_key(sd: SecureDomain, member: jax.Array) -> jax.Array:
    """Per-source derived key (the 'distributed communication' KDF mode)."""
    return kdf(sd.sdk, member, sd.an)


def iv_for_packet(sd: SecureDomain, member: jax.Array) -> tuple[
        "SecureDomain", jax.Array, jax.Array]:
    """Assign the next nonce for `member` (batch ok): returns
    (sd', iv_hi, iv_lo). IV = (epoch | counter) ^ mask — never reused
    because counters are per-member monotone and the member id salts the
    derived key."""
    ctr = sd.pkt_counter[member]
    iv_lo = (ctr ^ sd.iv_mask)
    iv_hi = (_mix(sd.epoch.astype(jnp.uint32)) ^ (sd.iv_mask >> 16))
    n = sd.pkt_counter.shape[0]
    new_ctr = sd.pkt_counter.at[member].add(1)
    new_kp = sd.key_packets.at[member].add(1)
    return replace(sd, pkt_counter=new_ctr, key_packets=new_kp), iv_hi, iv_lo


def needs_key_rotation(sd: SecureDomain,
                       single_user: bool = True) -> jax.Array:
    """[N] bool — key lifetime exceeded (Sec. 3.4.1: 2^27..2^34.5 pkts)."""
    limit = TSS_KEY_LIFETIME_MIN if not single_user else min(
        TSS_KEY_LIFETIME_MAX, 2 ** 31 - 1)
    return sd.key_packets >= jnp.int32(limit)


def rotate_key(sd: SecureDomain) -> SecureDomain:
    """SDME key rotation: bump AN, refresh SDK, zero per-key counters."""
    return replace(
        sd, an=sd.an + 1, sdk=_mix(sd.sdk ^ jnp.uint32(0xA5A5A5A5)),
        key_packets=jnp.zeros_like(sd.key_packets))


def pdc_must_close(tx_packets: jax.Array) -> jax.Array:
    """Encrypted PDCs close+reopen before PSN wrap (2e9 pkts, Sec 3.4.2)."""
    return tx_packets >= jnp.int32(min(TSS_PDC_MAX_PACKETS, 2 ** 31 - 1))


# ---------------------------------------------------------------------------
# anti-replay PSN establishment (Sec. 3.4.2, zero-RTT scheme)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class PSNGuard:
    """Per-(SD, peer) start_psn / expected_psn ratchet state."""

    start_psn: jax.Array     # [N] uint32 — source side: next PDC's PSN
    expected_psn: jax.Array  # [N] uint32 — target side: min accepted PSN

    @staticmethod
    def create(n: int) -> "PSNGuard":
        z = jnp.zeros((n,), jnp.uint32)
        return PSNGuard(start_psn=z, expected_psn=z)


def accept_new_pdc(g: PSNGuard, peer: jax.Array,
                   psn: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Target check on a connection request: accept iff psn >=
    expected_psn; otherwise NACK carrying the PSN the source must use.

    Returns (accept [B] bool, nack_psn [B]).
    """
    exp = g.expected_psn[peer]
    ok = psn.astype(jnp.uint32) >= exp
    return ok, exp


def on_pdc_close(g: PSNGuard, peer: jax.Array,
                 last_psn: jax.Array) -> PSNGuard:
    """Close ratchet: expected_psn := last_psn + 1 (target), echoed to the
    source which sets start_psn likewise => future opens are zero-RTT and
    replayed packets from the closed PDC can never re-establish."""
    nxt = last_psn.astype(jnp.uint32) + 1
    return PSNGuard(
        start_psn=g.start_psn.at[peer].max(nxt),
        expected_psn=g.expected_psn.at[peer].max(nxt),
    )


def trimmed_packet_may_create_pdc() -> bool:
    """Zero-trust rule: trimmed packets are unauthenticated (switches are
    untrusted) and MUST NOT trigger PDC creation."""
    return False
