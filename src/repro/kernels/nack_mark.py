"""Pallas TPU kernel: duplicate-safe NACK retransmit-bit marking
(Sec. 3.2.4).

Every simulator tick, each control-TC NACK lane asks for one bit —
(flow row, PSN offset) — to be OR-ed into the source retransmit bitmap.
Several lanes may target one flow, and two lanes may carry the SAME
(flow, offset) (a packet and its retransmission trimmed in the same
tick), so the combine is OR, not add.

TPU adaptation: a scatter is not available across lanes, so the mark is
re-expressed as a contraction. For an F-row block,

    hits[r, m] = sum_l rowhot[r, l] * (off[l] == m)

is an MXU-friendly [R, L] x [L, MP] matmul (counts are small integers,
exact in f32), and `hits > 0` collapses duplicates back to the OR
semantics. The bool plane then packs into uint32 ring words on the VPU
— bits are distinct powers of two per word, so the pack-sum IS the OR.

Block layout: (BLOCK_F rows) x (MP bit-lanes, a multiple of 128) per
grid step; the lane operands (flow / off / valid) ride along whole, one
value per padded row, column 0 — the same carrier layout the SACK
kernels use for per-row scalars.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import auto_interpret

BLOCK_F = 64
WORD = 32


def _nack_kernel(rtx_ref, flow_ref, off_ref, valid_ref, out_ref,
                 *, w: int, lanes: int, num_flows: int):
    rtx = rtx_ref[...][:, :w]                        # [R, W] uint32
    flow = flow_ref[...][:, 0]                       # [Lp] int32
    off = off_ref[...][:, 0]                         # [Lp] int32
    valid = valid_ref[...][:, 0] != 0                # [Lp]
    R = rtx.shape[0]
    mp = w * WORD

    lane_col = jax.lax.broadcasted_iota(jnp.int32, (R, flow.shape[0]), 1)
    valid = valid & (lane_col[0] < lanes) & (flow >= 0) & (flow < num_flows)

    # global row ids of this block
    f0 = pl.program_id(0) * BLOCK_F
    row = jax.lax.broadcasted_iota(jnp.int32, (R, flow.shape[0]), 0) + f0
    rowhot = (flow[None, :] == row) & valid[None, :]          # [R, Lp]

    m = jax.lax.broadcasted_iota(jnp.int32, (flow.shape[0], mp), 1)
    posmat = (jnp.clip(off, 0, mp - 1)[:, None] == m)         # [Lp, MP]
    hits = jnp.dot(rowhot.astype(jnp.float32), posmat.astype(jnp.float32),
                   preferred_element_type=jnp.float32)        # [R, MP]
    plane = hits > 0.5

    words = (plane.reshape(R, w, WORD).astype(jnp.uint32)
             << jax.lax.broadcasted_iota(jnp.uint32, (R, w, WORD), 2)
             ).sum(axis=2, dtype=jnp.uint32)                  # [R, W]
    out = out_ref[...]
    out_ref[...] = out.at[:, :w].set(rtx | words)


@functools.partial(jax.jit, static_argnames=("interpret",))
def nack_mark(rtx: jax.Array, flow: jax.Array, off: jax.Array,
              valid: jax.Array, interpret: "bool | None" = None
              ) -> jax.Array:
    """OR lane-requested retransmit bits into [F, W] uint32 rings.

    flow/off: [L] int32 (off is a PSN offset in [0, W*32)); valid: [L]
    bool. Invalid, out-of-range-row lanes mark nothing.
    """
    interpret = auto_interpret(interpret)
    f, w = rtx.shape
    lanes = flow.shape[0]
    assert w <= 32
    rows = -(-f // BLOCK_F) * BLOCK_F
    lrows = -(-lanes // 8) * 8
    rtx_p = jnp.pad(rtx, ((0, rows - f), (0, 128 - w)))
    lane_pad = ((0, lrows - lanes), (0, 127))
    flow_p = jnp.pad(flow.reshape(-1, 1), lane_pad)
    off_p = jnp.pad(off.reshape(-1, 1), lane_pad)
    valid_p = jnp.pad(valid.astype(jnp.int32).reshape(-1, 1), lane_pad)

    grid = (rows // BLOCK_F,)
    spec128 = pl.BlockSpec((BLOCK_F, 128), lambda i: (i, 0))
    lane_spec = pl.BlockSpec((lrows, 128), lambda i: (0, 0))
    out = pl.pallas_call(
        functools.partial(_nack_kernel, w=w, lanes=lanes, num_flows=f),
        grid=grid,
        in_specs=[spec128, lane_spec, lane_spec, lane_spec],
        out_specs=spec128,
        out_shape=jax.ShapeDtypeStruct((rows, 128), jnp.uint32),
        interpret=interpret,
    )(rtx_p, flow_p, off_p, valid_p)
    return out[:f, :w]
